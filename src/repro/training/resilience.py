"""Fault-tolerant training driver: auto-restore, failure injection,
straggler watchdog, elastic restart.

The jitted step is pure SPMD; everything stateful-and-fragile lives here in
the host loop, mirroring how a 1000-node job actually survives:

  * **checkpoint cadence** — atomic save every `ckpt_every` steps
    (training/checkpoint.py), keep-last-k;
  * **auto-restore** — any exception from a step (a real XLA error on
    hardware, or an injected `InjectedFailure` in tests) rolls back to the
    last checkpoint and replays; the data pipeline is step-indexed and
    stateless (batch = f(step, seed)) so replayed steps see identical data —
    with the counter-based RNG this makes recovery bit-exact;
  * **straggler watchdog** — per-step wall time is tracked against a
    rolling median; a step slower than `straggler_factor` x median is
    recorded (and on a real fleet would trigger hot-spare swap; here the
    mitigation hook is pluggable so tests can assert it fires);
  * **elastic restart** — `restore` takes shardings for the *current* mesh,
    so the same checkpoint restarts a job on a different device count.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.training import checkpoint

PyTree = Any


class InjectedFailure(RuntimeError):
    """Simulated node failure (tests / chaos drills)."""


@dataclasses.dataclass
class ResilienceConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep_last: int = 3
    max_restores: int = 10
    straggler_factor: float = 3.0
    straggler_window: int = 20


@dataclasses.dataclass
class RunReport:
    steps_run: int = 0
    restores: int = 0
    stragglers: List[int] = dataclasses.field(default_factory=list)
    final_metrics: Optional[Dict[str, float]] = None
    step_times: List[float] = dataclasses.field(default_factory=list)


def run_resilient(
    step_fn: Callable[[PyTree, PyTree], tuple],
    batch_fn: Callable[[int], PyTree],
    state: PyTree,
    n_steps: int,
    cfg: ResilienceConfig,
    start_step: int = 0,
    failure_hook: Optional[Callable[[int], None]] = None,
    straggler_hook: Optional[Callable[[int, float], None]] = None,
    state_shardings: Optional[PyTree] = None,
) -> tuple:
    """Drive `step_fn` for n_steps with checkpoint/restore. Returns
    (final_state, RunReport)."""
    report = RunReport()
    step = start_step

    # initial checkpoint so step 0 failures can restore
    checkpoint.save(cfg.ckpt_dir, step, state, cfg.keep_last)

    while step < n_steps:
        try:
            if failure_hook is not None:
                failure_hook(step)  # may raise InjectedFailure
            t0 = time.perf_counter()
            batch = batch_fn(step)
            state, metrics = step_fn(state, batch)
            # block so the wall time is real (and failures surface here)
            import jax

            metrics = jax.tree.map(lambda x: np.asarray(x), metrics)
            dt = time.perf_counter() - t0
            report.step_times.append(dt)

            # straggler detection on a rolling median
            window = report.step_times[-cfg.straggler_window:]
            if len(window) >= 5:
                med = float(np.median(window))
                if dt > cfg.straggler_factor * med:
                    report.stragglers.append(step)
                    if straggler_hook is not None:
                        straggler_hook(step, dt / med)

            step += 1
            report.steps_run += 1
            report.final_metrics = {
                k: float(v) for k, v in metrics.items()
            }
            if step % cfg.ckpt_every == 0:
                checkpoint.save(cfg.ckpt_dir, step, state, cfg.keep_last)
        except InjectedFailure:
            if report.restores >= cfg.max_restores:
                raise
            report.restores += 1
            state, step = checkpoint.restore(
                cfg.ckpt_dir, state, shardings=state_shardings
            )
    checkpoint.save(cfg.ckpt_dir, step, state, cfg.keep_last)
    return state, report


def make_scheduled_failures(fail_at: Dict[int, int]) -> Callable[[int], None]:
    """failure_hook that raises the first `count` times step hits `fail_at`."""
    remaining = dict(fail_at)

    def hook(step: int) -> None:
        if remaining.get(step, 0) > 0:
            remaining[step] -= 1
            raise InjectedFailure(f"injected failure at step {step}")

    return hook
