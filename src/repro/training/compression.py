"""int8 error-feedback gradient compression for the data-parallel all-reduce.

At 1000+ nodes the cross-pod gradient all-reduce is DCN-bound; quantizing
the payload to int8 cuts it 4x.  Error feedback (Seide et al. 2014 / EF21)
keeps the quantization *residual* on-device and adds it back before the
next round, so compression error accumulates O(1) instead of O(T) and
convergence is preserved.

Mechanics (inside shard_map over the data axes):
  1. g_eff = grad + residual
  2. per-tensor symmetric int8 quantize (scale = max|g_eff| / 127)
  3. psum the int8 payload (as int32 accumulator) and the scales
  4. dequantize with the mean scale; residual' = g_eff - dequant(local)
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(
    grads: PyTree,
    residual: PyTree,
    axis_name,
) -> Tuple[PyTree, PyTree]:
    """Error-feedback int8 psum. Call inside shard_map with `axis_name` data axes.

    Returns (mean-reduced f32 grads, new residual).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, r):
        g_eff = g.astype(jnp.float32) + r
        # shards must agree on ONE scale before quantizing (summing int8
        # payloads quantized at different scales is not meaningful): a
        # cheap scalar pmax precedes the int8 all-reduce
        gmax = jax.lax.pmax(jnp.max(jnp.abs(g_eff)), axis_name)
        scale = jnp.maximum(gmax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(g_eff / scale), -127, 127).astype(jnp.int8)
        # int8 payload summed in int32 (the wire format is int8; the
        # accumulator must be wider to avoid overflow at n <= 2^23 devices)
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        reduced = q_sum.astype(jnp.float32) * scale / n
        new_r = g_eff - q.astype(jnp.float32) * scale
        return reduced, new_r

    flat_g, tree = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    reduced = jax.tree.unflatten(tree, [o[0] for o in out])
    new_res = jax.tree.unflatten(tree, [o[1] for o in out])
    return reduced, new_res


def init_residual(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
