"""Train-step factory: loss -> jitted, sharded, donated SPMD step.

`make_train_step` packages the standard production step:
    microbatched value_and_grad -> AdamW -> metrics
with in/out shardings resolved from the logical rule table, donated state
(params+opt buffers update in place), and optional ZeRO-1 optimizer-state
sharding (m/v sharded over the data axis on top of the param sharding).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distribution.sharding import RuleSet
from repro.training import microbatch, optim

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    adamw: optim.AdamWConfig = optim.AdamWConfig()
    n_micro: int = 1
    zero1: bool = False          # shard m/v over the data axis too
    donate: bool = True


def _zero1_spec(spec: P, mesh: Mesh, shape=None) -> P:
    """Add 'data' sharding to the largest unsharded *divisible* dim.

    ZeRO-1: optimizer moments get an extra data-axis shard on top of the
    parameter sharding, cutting their footprint by the DP degree.  Skipped
    for leaves where no unsharded dim divides the data-axis size.
    """
    parts = list(spec)
    used = set()
    for p in parts:
        if p is None:
            continue
        for a in (p if isinstance(p, tuple) else (p,)):
            used.add(a)
    if "data" in used or not parts:
        return spec
    n_data = mesh.shape.get("data", 1)
    candidates = [
        i for i, p in enumerate(parts)
        if p is None
        and (shape is None or (len(shape) > i and shape[i] % n_data == 0))
    ]
    if not candidates:
        return spec
    if shape is not None:
        i = max(candidates, key=lambda j: shape[j])
    else:
        i = candidates[0]
    parts[i] = "data"
    return P(*parts)


def state_shardings(
    param_logical: PyTree,
    rules: RuleSet,
    mesh: Mesh,
    zero1: bool = False,
    params_abs: Optional[PyTree] = None,
) -> Tuple[PyTree, optim.OptState]:
    """(param shardings, OptState shardings) from logical axes.

    Pass `params_abs` (shapes) so ZeRO-1 only shards divisible dims.
    """
    is_spec = lambda x: isinstance(x, tuple) and all(
        n is None or isinstance(n, str) for n in x
    )
    pspecs = jax.tree.map(
        lambda names: rules.spec(names, mesh), param_logical, is_leaf=is_spec
    )
    param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    if zero1:
        if params_abs is not None:
            opt_spec = jax.tree.map(
                lambda s, p: _zero1_spec(s, mesh, p.shape), pspecs, params_abs
            )
        else:
            opt_spec = jax.tree.map(lambda s: _zero1_spec(s, mesh), pspecs)
    else:
        opt_spec = pspecs
    opt_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), opt_spec)
    opt_state_sh = optim.OptState(
        m=opt_sh,
        v=jax.tree.map(lambda s: s, opt_sh),
        step=NamedSharding(mesh, P()),
    )
    return param_sh, opt_state_sh


def make_train_step(
    loss_fn: Callable[[PyTree, PyTree], jax.Array],
    cfg: TrainStepConfig,
) -> Callable:
    """Returns train_step((params, opt_state), batch) -> (state', metrics)."""

    def train_step(state, batch):
        params, opt_state = state
        loss, grads = microbatch.accumulated_grads(
            loss_fn, params, batch, cfg.n_micro
        )
        new_params, new_opt, metrics = optim.apply_updates(
            params, grads, opt_state, cfg.adamw
        )
        metrics["loss"] = loss
        return (new_params, new_opt), metrics

    return train_step


def jit_train_step(
    train_step: Callable,
    param_sharding: PyTree,
    opt_sharding: optim.OptState,
    batch_sharding: PyTree,
    donate: bool = True,
):
    return jax.jit(
        train_step,
        in_shardings=((param_sharding, opt_sharding), batch_sharding),
        out_shardings=((param_sharding, opt_sharding), None),
        donate_argnums=(0,) if donate else (),
    )


def batch_shardings(batch_logical: PyTree, rules: RuleSet, mesh: Mesh):
    is_spec = lambda x: isinstance(x, tuple) and all(
        n is None or isinstance(n, str) for n in x
    )
    return jax.tree.map(
        lambda names: NamedSharding(mesh, rules.spec(names, mesh)),
        batch_logical,
        is_leaf=is_spec,
    )
