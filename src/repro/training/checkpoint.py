"""Sharded, atomic, resharding-on-restore checkpointing.

Layout:  <dir>/step_<n>/   arrays.npz  (one file per host in multi-host;
                           single file here)
         <dir>/step_<n>/   meta.json   (step, pytree structure, logical axes)
         <dir>/LATEST      (atomic pointer, written last)

Guarantees the runtime needs at 1000+ nodes:
  * **atomicity** — a checkpoint directory is staged under a tmp name and
    os.replace'd into place; LATEST is updated only after the data is
    durable, so a crash mid-save can never corrupt the restore point;
  * **keep-last-k** — bounded disk usage;
  * **resharding restore** — arrays are saved device-agnostic (host numpy);
    `restore(..., shardings=...)` device_puts onto ANY mesh, so a job can
    restart on a different topology (elastic scaling after node loss).
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


# jax.tree.flatten_with_path only exists on newer JAX; the pinned version
# ships it under jax.tree_util only.
_flatten_with_path = getattr(
    jax.tree, "flatten_with_path", jax.tree_util.tree_flatten_with_path
)


def _flatten_with_names(tree: PyTree):
    flat, treedef = _flatten_with_path(tree)
    names = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, jax.tree.structure(tree)


def save(ckpt_dir: str, step: int, tree: PyTree, keep_last: int = 3) -> str:
    """Atomically persist `tree` as step `step`. Returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    names, leaves, _ = _flatten_with_names(tree)
    arrays = {f"a{i}": np.asarray(leaf) for i, leaf in enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "names": names}, f)

    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)

    # atomic LATEST pointer
    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))

    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int) -> None:
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    pointer = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(pointer):
        return None
    with open(pointer) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[1])


def restore(
    ckpt_dir: str,
    like: PyTree,
    step: Optional[int] = None,
    shardings: Optional[PyTree] = None,
) -> Tuple[PyTree, int]:
    """Restore into the structure of `like`; optionally reshard on load.

    `shardings` (a pytree of NamedSharding matching `like`) enables elastic
    restarts: the checkpoint written on mesh A is device_put onto mesh B.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    names, leaves, treedef = _flatten_with_names(like)
    if names != meta["names"]:
        raise ValueError(
            "checkpoint structure mismatch: "
            f"{set(meta['names']) ^ set(names)}"
        )
    restored = []
    for i, leaf in enumerate(leaves):
        arr = data[f"a{i}"]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {names[i]}: {arr.shape} vs {leaf.shape}"
            )
        restored.append(jnp.asarray(arr, dtype=leaf.dtype))
    tree = jax.tree.unflatten(treedef, restored)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree, step
