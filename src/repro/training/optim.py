"""AdamW + LR schedules + global-norm clipping, as pure pytree functions.

No optimizer-framework dependency: state is {m, v, step} mirroring the param
tree.  The m/v trees inherit the *parameter* shardings plus optional ZeRO-1
extra sharding (distribution decision made by the caller via out_shardings —
the math here is sharding-agnostic).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"     # 'cosine' | 'linear' | 'constant'
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    m: PyTree
    v: PyTree
    step: Array


def init(params: PyTree) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(
        m=zeros,
        v=jax.tree.map(jnp.copy, zeros),
        step=jnp.zeros((), jnp.int32),
    )


def abstract_state(params: PyTree) -> OptState:
    return jax.eval_shape(init, params)


def state_logical(param_logical_tree: PyTree) -> "OptState":
    """Logical axes for the optimizer state: mirror the params."""
    return OptState(
        m=param_logical_tree,
        v=jax.tree.map(
            lambda x: x, param_logical_tree,
            is_leaf=lambda x: isinstance(x, tuple),
        ),
        step=((),),  # placeholder; scalar is replicated
    )


def schedule_lr(cfg: AdamWConfig, step: Array) -> Array:
    step_f = step.astype(jnp.float32)
    warm = jnp.minimum(step_f / max(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step_f - cfg.warmup_steps)
        / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * frac)
        )
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_frac) * frac
    else:
        decay = jnp.asarray(1.0)
    return cfg.lr * warm * decay


def global_norm(tree: PyTree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def rowwise_adagrad_init(table: Array) -> Array:
    """Accumulator: ONE scalar per embedding row (production recsys optimizer
    — 128x less state than Adam on a (rows, dim) table, and no ZeRO
    resharding traffic because the state is tiny)."""
    return jnp.zeros((table.shape[0],), jnp.float32)


def rowwise_adagrad_update(
    table: Array, grad: Array, accum: Array, lr: float, eps: float = 1e-8
) -> Tuple[Array, Array]:
    g = grad.astype(jnp.float32)
    accum = accum + jnp.mean(g * g, axis=-1)
    step = lr * g / jnp.sqrt(accum + eps)[:, None]
    return (table.astype(jnp.float32) - step).astype(table.dtype), accum


def apply_updates(
    params: PyTree,
    grads: PyTree,
    state: OptState,
    cfg: AdamWConfig,
) -> Tuple[PyTree, OptState, Dict[str, Array]]:
    """One AdamW step. Returns (params', state', metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.m, grads)
    new_v = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * g * g, state.v, grads
    )

    def upd(p, m, v):
        mh = m / bc1
        vh = v / bc2
        step_ = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(new_m, new_v, step), metrics
