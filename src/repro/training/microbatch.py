"""Gradient accumulation: scan over microbatches inside one jit step.

Splitting the global batch into m microbatches divides peak activation
memory by m at the cost of m sequential passes — the standard lever when a
shape cell's activations exceed HBM.  The scan keeps the HLO O(1) in m.
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def accumulated_grads(
    loss_fn: Callable[..., jax.Array],
    params: PyTree,
    batch: PyTree,
    n_micro: int,
) -> Tuple[jax.Array, PyTree]:
    """Mean loss + grads over n_micro microbatches (axis 0 split).

    Every leaf of `batch` must have a leading dim divisible by n_micro.
    """
    if n_micro <= 1:
        return jax.value_and_grad(loss_fn)(params, batch)

    micro = jax.tree.map(
        lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]),
        batch,
    )
    grad_fn = jax.value_and_grad(loss_fn)

    def body(carry, mb):
        loss_acc, grad_acc = carry
        loss, grads = grad_fn(params, mb)
        grad_acc = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32), grad_acc, grads
        )
        return (loss_acc + loss, grad_acc), None

    zero = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    (loss_sum, grad_sum), _ = jax.lax.scan(
        body, (jnp.asarray(0.0, jnp.float32), zero), micro
    )
    inv = 1.0 / n_micro
    return loss_sum * inv, jax.tree.map(lambda g: g * inv, grad_sum)
