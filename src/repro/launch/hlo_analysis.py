"""Roofline-term extraction from a compiled dry-run artifact.

  compute term    = HLO_FLOPs / (chips * 197e12)          [bf16 MXU peak]
  memory term     = HLO_bytes / (chips * 819e9)           [HBM bandwidth]
  collective term = sum(collective bytes * op factor) / (chips * 50e9)

FLOPs/bytes come from compiled.cost_analysis().  Collective bytes are NOT
in cost_analysis: we parse the optimized (post-SPMD) HLO text and sum the
output-shape bytes of every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute, with standard per-op wire factors
(ring all-reduce moves ~2x the payload; ag/rs/a2a move ~1x; permute 1x).
Sizes in the partitioned HLO are already per-device shard sizes.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[^\s]+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_DEF_RE = re.compile(r"%([\w.\-]+) = (\(?\w+\[[\d,]*\])")
_GATHER_RE = re.compile(
    r"= (\w+\[[\d,]*\])[^\n]*? (gather|dynamic-slice)\(%([\w.\-]+)"
)
_SCATTER_RE = re.compile(
    r"= (\(?[\w\[\],]*\])[^\n]*? scatter\(%([\w.\-]+)"
)


def gather_scatter_overcount(hlo_text: str) -> float:
    """XLA's 'bytes accessed' counts the FULL operand of gather/scatter ops
    (verified empirically: a 128-row take from a 256 MB table reports
    2.56e8 bytes).  For index-driven workloads (Pixie CSR walks, embedding
    lookups, MoE dispatch) that inflates the memory term by orders of
    magnitude.  This estimates the overcount as sum(operand - 2*output)
    over gather-like ops so callers can report an adjusted memory term.
    Fusion-internal double counting makes this an estimate; it is clamped
    by the caller."""
    shapes: Dict[str, int] = {}
    for m in _DEF_RE.finditer(hlo_text):
        shapes[m.group(1)] = _shape_bytes(m.group(2))
    over = 0.0
    for m in _GATHER_RE.finditer(hlo_text):
        out_b = _shape_bytes(m.group(1))
        op_b = shapes.get(m.group(3), 0)
        over += max(op_b - 2 * out_b, 0)
    for m in _SCATTER_RE.finditer(hlo_text):
        # scatter's real traffic is a read-modify-write of the *touched*
        # rows plus the updates; cost analysis charges the whole buffer
        # twice (operand + output).  Subtract one full buffer copy.
        over += shapes.get(m.group(2), 0)
    return over


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Weighted per-device collective bytes by op kind (plus 'total')."""
    seen_done = set()
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVE_FACTOR}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        if "-done(" in line:
            continue  # async pair: count the -start only
        out[kind] += _shape_bytes(shape_str) * _COLLECTIVE_FACTOR[kind]
    out["total"] = sum(out[k] for k in _COLLECTIVE_FACTOR)
    return out


@dataclasses.dataclass
class RooflineTerms:
    """All quantities are PER-DEVICE: compiled.cost_analysis() describes the
    per-device SPMD program (calibrated: a 4-way-sharded matmul reports 1/4
    of the global FLOPs), and shapes in the partitioned HLO text are shard
    shapes.  So each term divides by a single chip's peak."""

    flops: float                 # per-device HLO FLOPs
    hbm_bytes: float             # per-device bytes accessed
    coll_bytes_per_dev: float    # weighted per-device collective bytes
    n_chips: int
    bytes_per_device: Optional[float] = None   # from memory_analysis

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_dev / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound(self) -> float:
        """No-overlap lower bound = max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "n_chips": self.n_chips,
            "bytes_per_device": self.bytes_per_device,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
        }


def analyze_compiled(compiled, n_chips: int) -> RooflineTerms:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    text = compiled.as_text()
    over = gather_scatter_overcount(text)
    # keep at least 5% of the raw figure (the adjustment is an estimate;
    # fusion-internal gathers can double-subtract)
    hbm = max(hbm - over, 0.05 * hbm)
    coll = collective_bytes(text)["total"]
    bpd = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            # works on TPU; CPU backend may not populate it
            bpd = float(
                getattr(ma, "argument_size_in_bytes", 0)
                + getattr(ma, "output_size_in_bytes", 0)
                + getattr(ma, "temp_size_in_bytes", 0)
            )
    except Exception:
        pass
    return RooflineTerms(
        flops=flops,
        hbm_bytes=hbm,
        coll_bytes_per_dev=coll,
        n_chips=n_chips,
        bytes_per_device=bpd,
    )
