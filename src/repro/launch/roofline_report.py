"""Roofline report: dryrun.jsonl -> EXPERIMENTS.md tables.

Adds the analytic MODEL_FLOPS term per cell (6ND train / 2ND inference,
N_active for MoE; structural estimates for GNN/recsys) so the
MODEL_FLOPS / HLO_FLOPS ratio exposes padding, remat and redundancy waste.

  PYTHONPATH=src python -m repro.launch.roofline_report \
      --in results/dryrun.jsonl --out results/roofline.md
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, Optional

from repro.configs import get_arch


def model_flops(arch: str, shape: str, kind: str) -> Optional[float]:
    """Analytic 'useful' FLOPs for the whole step (all devices)."""
    spec = get_arch(arch)
    cfg = spec.config
    cell = next(c for c in spec.shapes if c.name == shape)
    p = cell.params

    if spec.family == "lm":
        n_active = cfg.active_param_count()
        if kind == "train":
            tokens = p["global_batch"] * p["seq_len"]
            return 6.0 * n_active * tokens
        if kind == "prefill":
            tokens = p["global_batch"] * p["seq_len"]
            return 2.0 * n_active * tokens
        if kind == "decode":
            # one new token per sequence + KV-cache attention reads
            flops = 2.0 * n_active * p["global_batch"]
            attn = (
                4.0 * p["global_batch"] * p["seq_len"]
                * cfg.n_heads * cfg.head_dim * cfg.n_layers
            )
            return flops + attn

    if spec.family == "gnn":
        d = cfg.d_hidden
        if shape == "minibatch_lg":
            b, f = p["batch_nodes"], p["fanout"]
            nodes = b * (1 + f[0] + f[0] * f[1])
            edges = b * (f[0] + f[0] * f[1])
            d_in = p["d_feat"]
        elif shape == "molecule":
            nodes = p["n_nodes"] * p["batch"]
            edges = p["n_edges"] * p["batch"]
            d_in = p["d_feat"]
        else:
            nodes, edges, d_in = p["n_nodes"], p["n_edges"], p["d_feat"]
        fwd = (
            nodes * 2 * d_in * d                       # encoder
            + cfg.n_layers * (nodes * 4 * d * d + edges * d)  # MLPs + agg
            + nodes * 2 * d * p["n_classes"]
        )
        return 3.0 * fwd  # train: fwd + ~2x bwd

    if spec.family == "recsys":
        from repro.models.dlrm import DLRMConfig

        if isinstance(cfg, DLRMConfig):
            mlp = 0
            dims_b = cfg.bot_mlp
            for i in range(len(dims_b) - 1):
                mlp += 2 * dims_b[i] * dims_b[i + 1]
            dims_t = (cfg.top_in,) + cfg.top_mlp
            for i in range(len(dims_t) - 1):
                mlp += 2 * dims_t[i] * dims_t[i + 1]
            inter = 2 * (cfg.n_sparse + 1) ** 2 * cfg.embed_dim
            per_row = mlp + inter
            batch = p.get("n_candidates", p.get("batch", 1))
            mult = 3.0 if kind == "train" else 1.0
            return mult * per_row * batch
        # seqrec: per-user transformer encode + head
        d = cfg.embed_dim
        seq = cfg.seq_len + (1 if cfg.kind == "bst" else 0)
        blk_params = 4 * d * d + 2 * d * cfg.ff
        per_user = cfg.n_blocks * (
            2 * seq * blk_params + 4 * seq * seq * d
        )
        if cfg.kind == "bst":
            dims = ((cfg.seq_len + 1) * d,) + cfg.mlp_dims + (1,)
            for i in range(len(dims) - 1):
                per_user += 2 * dims[i] * dims[i + 1]
        if kind == "retrieval":
            # one user encoded; candidates scored by a single dot each
            n_cand = p["n_candidates"]
            if cfg.kind == "bst":
                return per_user * n_cand  # BST re-runs the CTR head per cand
            return per_user + 2.0 * n_cand * d
        batch = p.get("batch", 1)
        mult = 3.0 if kind == "train" else 1.0
        extra = 0.0
        if kind == "train" and cfg.kind == "sasrec":
            extra = (
                3.0 * 2 * batch * cfg.seq_len * (1 + cfg.n_negatives) * d
            )
        return mult * per_user * batch + extra

    return None  # pixie: walk FLOPs are not the useful-work metric


def load_latest(path: str) -> Dict:
    cells: Dict = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            cells[(r["arch"], r["shape"], r["mesh"])] = r
    return cells


def fmt_row(r: Dict) -> str:
    key = f"{r['arch']}/{r['shape']}"
    if r["status"] != "ok":
        return f"| {key} | {r['mesh']} | FAIL | | | | | | |"
    mf = model_flops(r["arch"], r["shape"], r["kind"])
    ratio = ""
    if mf is not None and r.get("flops"):
        ratio = f"{mf / r['n_chips'] / r['flops']:.2f}"
    ma = r.get("memory_analysis")
    mem_gb = ""
    if isinstance(ma, dict) and ma.get("temp_size") is not None:
        tot = (ma.get("argument_size") or 0) + (ma.get("temp_size") or 0)
        mem_gb = f"{tot / 2**30:.2f}"
    return (
        f"| {key} | {r['mesh']} | {r['t_compute_s']:.2e} "
        f"| {r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} "
        f"| {r['dominant']} | {mem_gb} | {ratio} |"
    )


HEADER = (
    "| cell | mesh | t_compute (s) | t_memory (s) | t_collective (s) "
    "| dominant | mem/dev (GiB) | MODEL/HLO |\n"
    "|---|---|---|---|---|---|---|---|"
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--infile", default="results/dryrun.jsonl")
    ap.add_argument("--out", default="results/roofline.md")
    args = ap.parse_args(argv)

    cells = load_latest(args.infile)
    lines = [HEADER]
    order = sorted(cells)
    for key in order:
        lines.append(fmt_row(cells[key]))
    text = "\n".join(lines) + "\n"
    with open(args.out, "w") as f:
        f.write(text)
    print(text)

    # summary stats
    ok = [r for r in cells.values() if r["status"] == "ok"]
    doms = {}
    for r in ok:
        if r["mesh"] == "single":
            doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    print(f"# {len(ok)}/{len(cells)} cells ok; single-pod dominant terms: "
          f"{doms}")


if __name__ == "__main__":
    main()
