"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — smoke tests must keep seeing 1 CPU device.

  single-pod: (data=16, model=16)           — 256 chips (one v5e pod)
  multi-pod:  (pod=2, data=16, model=16)    — 512 chips (2 pods)

'model' is the latency-critical axis (TP / EP / kv-sequence / graph shards:
everything that communicates per-step stays on intra-pod ICI); 'data' is
per-pod data parallelism; 'pod' carries only the once-per-step gradient
all-reduce (DCN-tolerant) — the paper's "walk never crosses machines"
principle lifted to pod scope.
"""

from __future__ import annotations

import contextlib

import jax
from jax.sharding import Mesh


def make_mesh_compat(shape, axes) -> Mesh:
    """jax.make_mesh across JAX versions: AxisType / the axis_types kwarg
    only exist on newer JAX; older versions take neither."""
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def set_mesh_compat(mesh: Mesh):
    """jax.set_mesh across JAX versions.  Older JAX has neither set_mesh
    nor sharding.use_mesh; callers there pass the mesh explicitly
    (shard_map(mesh=...), jit shardings), so this degrades to a null
    context."""
    setter = getattr(jax, "set_mesh", None) or getattr(
        jax.sharding, "use_mesh", None
    )
    if setter is not None:
        return setter(mesh)
    return contextlib.nullcontext()


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(shape=None, axes=("data", "model")) -> Mesh:
    """Small mesh over whatever devices this host actually has (tests)."""
    n = len(jax.devices())
    if shape is None:
        a = 1
        while (a * 2) * (a * 2) <= n or a * 2 * a <= n:
            if (a * 2) * a <= n:
                a *= 2
            else:
                break
        shape = (max(n // a, 1), a) if a <= n else (1, 1)
    return make_mesh_compat(shape, axes)


def data_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def n_chips(mesh: Mesh) -> int:
    return mesh.devices.size
