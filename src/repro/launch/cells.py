"""Dry-run cell builders: (arch x shape x mesh) -> a lowerable program.

Each builder returns (fn, abstract_args, in_shardings, out_shardings,
donate) such that

    jax.jit(fn, in_shardings=..., out_shardings=..., donate_argnums=...)
        .lower(*abstract_args).compile()

is exactly the production step for that cell.  Nothing here allocates:
parameters, optimizer state, caches and batches are ShapeDtypeStructs.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.registry import ArchSpec, ShapeCell
from repro.core import distributed as pixie_dist
from repro.core import walk as walk_lib
from repro.distribution import sharding as shlib
from repro.launch.mesh import data_axes
from repro.models import dlrm as dlrm_lib
from repro.models import embedding as emb_lib
from repro.models import gnn as gnn_lib
from repro.models import sequential_rec as sr
from repro.models import transformer as tf
from repro.training import optim, train_loop

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class Cell:
    fn: Any
    args: Tuple
    in_shardings: Any
    out_shardings: Any
    donate: Tuple[int, ...] = ()


def _ns(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def _batch_axes(mesh: Mesh):
    ax = data_axes(mesh)
    return ax if len(ax) > 1 else (ax[0] if ax else None)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _lm_train_rules(spec: ArchSpec) -> shlib.RuleSet:
    return shlib.LM_TRAIN_RULES.with_overrides(**spec.train_rule_overrides)


def _lm_serve_rules(spec: ArchSpec) -> shlib.RuleSet:
    rules = shlib.LM_SERVE_RULES.with_overrides(
        heads=None, embed=None
    )  # decode: attention DP, KV sequence-sharded
    return rules.with_overrides(**spec.serve_rule_overrides)


def build_lm_cell(
    spec: ArchSpec, cell: ShapeCell, mesh: Mesh, n_micro: int = 4
) -> Cell:
    cfg = spec.config
    seq = cell.params["seq_len"]
    batch = cell.params["global_batch"]
    bax = _batch_axes(mesh)

    if cell.kind == "train":
        rules = _lm_train_rules(spec)
        logical = tf.param_logical(cfg)
        params_abs = tf.abstract_params(cfg)
        opt_abs = optim.abstract_state(params_abs)
        param_sh, opt_sh = train_loop.state_shardings(
            logical, rules, mesh, zero1=True, params_abs=params_abs
        )
        batch_abs = {
            "tokens": SDS((batch, seq), jnp.int32),
            "labels": SDS((batch, seq), jnp.int32),
            "mask": SDS((batch, seq), jnp.float32),
        }
        batch_sh = {k: _ns(mesh, bax, None) for k in batch_abs}

        def loss_fn(p, b):
            return tf.loss_fn(
                p, b["tokens"], b["labels"], b["mask"], cfg, mesh=mesh
            )

        step = train_loop.make_train_step(
            loss_fn,
            train_loop.TrainStepConfig(n_micro=n_micro),
        )
        return Cell(
            fn=step,
            args=((params_abs, opt_abs), batch_abs),
            in_shardings=((param_sh, opt_sh), batch_sh),
            out_shardings=((param_sh, opt_sh), None),
            donate=(0,),
        )

    if cell.kind == "prefill":
        # training-style TP for the prompt pass; cache comes out seq-sharded
        rules = _lm_train_rules(spec)
        logical = tf.param_logical(cfg)
        params_abs = tf.abstract_params(cfg)
        is_spec = lambda x: isinstance(x, tuple) and all(
            n is None or isinstance(n, str) for n in x
        )
        param_sh = jax.tree.map(
            lambda names: rules.sharding(names, mesh), logical, is_leaf=is_spec
        )
        tokens_abs = SDS((batch, seq), jnp.int32)
        cache_logical = tf.kv_cache_logical()
        serve_rules = shlib.LM_SERVE_RULES.with_overrides(
            **spec.serve_rule_overrides
        )
        cache_sh = {
            k: serve_rules.sharding(v, mesh) for k, v in cache_logical.items()
        }

        def prefill_fn(p, tokens):
            return tf.prefill(p, tokens, cfg, max_seq=seq, mesh=mesh)

        return Cell(
            fn=prefill_fn,
            args=(params_abs, tokens_abs),
            in_shardings=(param_sh, _ns(mesh, bax, None)),
            out_shardings=(_ns(mesh, bax, None), cache_sh),
        )

    if cell.kind == "decode":
        rules = _lm_serve_rules(spec)
        if batch == 1:
            # batch of 1 cannot shard over data; keep it replicated
            rules = rules.with_overrides(batch=None)
            bax = None
        logical = tf.param_logical(cfg)
        params_abs = tf.abstract_params(cfg)
        is_spec = lambda x: isinstance(x, tuple) and all(
            n is None or isinstance(n, str) for n in x
        )
        param_sh = jax.tree.map(
            lambda names: rules.sharding(names, mesh), logical, is_leaf=is_spec
        )
        cache_abs = tf.abstract_kv_cache(cfg, batch, seq)
        cache_sh = {
            k: rules.sharding(v, mesh)
            for k, v in tf.kv_cache_logical().items()
        }
        tokens_abs = SDS((batch,), jnp.int32)
        pos_abs = SDS((), jnp.int32)

        def decode_fn(p, cache, tokens, pos):
            return tf.decode_step(p, cache, tokens, pos, cfg, mesh=mesh)

        return Cell(
            fn=decode_fn,
            args=(params_abs, cache_abs, tokens_abs, pos_abs),
            in_shardings=(
                param_sh, cache_sh, _ns(mesh, bax), _ns(mesh),
            ),
            out_shardings=(_ns(mesh, bax, None), cache_sh),
            donate=(1,),
        )

    raise ValueError(f"unknown LM cell kind {cell.kind}")


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------


def build_gnn_cell(spec: ArchSpec, cell: ShapeCell, mesh: Mesh) -> Cell:
    base: gnn_lib.GINConfig = spec.config
    p = cell.params
    edge_ax = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)

    if cell.name == "minibatch_lg":
        # fixed-fanout sampled block shapes
        batch = p["batch_nodes"]
        f = p["fanout"]
        n_nodes = batch * (1 + f[0] + f[0] * f[1])
        n_edges = batch * (f[0] + f[0] * f[1])
        d_feat, n_classes = p["d_feat"], p["n_classes"]
        readout = None
        n_graphs = 0
    elif cell.name == "molecule":
        n_nodes = p["n_nodes"] * p["batch"]
        n_edges = p["n_edges"] * p["batch"]
        d_feat, n_classes = p["d_feat"], p["n_classes"]
        readout = "sum"
        n_graphs = p["batch"]
    else:
        n_nodes, n_edges = p["n_nodes"], p["n_edges"]
        d_feat, n_classes = p["d_feat"], p["n_classes"]
        readout = None
        n_graphs = 0

    cfg = dataclasses.replace(
        base, d_in=d_feat, n_classes=n_classes, readout=readout
    )
    params_abs = gnn_lib.abstract_params(cfg)
    opt_abs = optim.abstract_state(params_abs)
    # GIN params are tiny: replicate everywhere
    rep = jax.tree.map(lambda _: _ns(mesh), params_abs)
    opt_rep = jax.tree.map(lambda _: _ns(mesh), opt_abs)

    # pad the edge count so the edge axis shards evenly
    n_shards = 1
    for a in edge_ax:
        n_shards *= mesh.shape[a]
    n_edges = -(-n_edges // n_shards) * n_shards

    if readout == "sum":
        batch_abs = {
            "feats": SDS((n_nodes, d_feat), jnp.float32),
            "edge_src": SDS((n_edges,), jnp.int32),
            "edge_dst": SDS((n_edges,), jnp.int32),
            "graph_ids": SDS((n_nodes,), jnp.int32),
            "labels": SDS((n_graphs,), jnp.int32),
        }

        def loss_fn(pp, b):
            return gnn_lib.graph_classification_loss(
                pp, b["feats"], b["edge_src"], b["edge_dst"],
                b["graph_ids"], b["labels"], cfg, n_graphs,
            )
    else:
        batch_abs = {
            "feats": SDS((n_nodes, d_feat), jnp.float32),
            "edge_src": SDS((n_edges,), jnp.int32),
            "edge_dst": SDS((n_edges,), jnp.int32),
            "labels": SDS((n_nodes,), jnp.int32),
            "mask": SDS((n_nodes,), jnp.float32),
        }

        def loss_fn(pp, b):
            return gnn_lib.node_classification_loss(
                pp, b["feats"], b["edge_src"], b["edge_dst"],
                b["labels"], b["mask"], cfg,
            )

    eax = edge_ax if len(edge_ax) > 1 else (edge_ax[0] if edge_ax else None)
    batch_sh = {
        k: _ns(mesh, eax) if k.startswith("edge_") else _ns(mesh)
        for k in batch_abs
    }
    step = train_loop.make_train_step(
        loss_fn, train_loop.TrainStepConfig(n_micro=1)
    )
    return Cell(
        fn=step,
        args=((params_abs, opt_abs), batch_abs),
        in_shardings=((rep, opt_rep), batch_sh),
        out_shardings=((rep, opt_rep), None),
        donate=(0,),
    )


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------


def build_recsys_cell(spec: ArchSpec, cell: ShapeCell, mesh: Mesh) -> Cell:
    cfg = spec.config
    bax = _batch_axes(mesh)
    if isinstance(cfg, dlrm_lib.DLRMConfig):
        return _build_dlrm_cell(spec, cell, mesh, bax)
    return _build_seqrec_cell(spec, cell, mesh, bax)


def _dlrm_shardings(cfg, mesh, zero1: bool):
    rules = shlib.RECSYS_RULES
    logical = dlrm_lib.param_logical(cfg)
    params_abs = dlrm_lib.abstract_params(cfg)
    opt_abs = optim.abstract_state(params_abs)
    param_sh, opt_sh = train_loop.state_shardings(
        logical, rules, mesh, zero1=zero1, params_abs=params_abs
    )
    return params_abs, opt_abs, param_sh, opt_sh


def _sharded_forward(cfg, mesh, bax):
    """DLRM forward using the shard_map mega-table lookup."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def forward(params, dense, sparse_ids):
        cd = cfg.compute_dtype
        bot = dlrm_lib._mlp_fwd(
            params["bot"], dense.astype(cd), len(cfg.bot_mlp) - 1, True
        )
        sparse = emb_lib.lookup_sharded(
            params["table"], sparse_ids, cfg.table, mesh,
            batch_axes=batch_axes,
        )
        inter = dlrm_lib._interact(bot, sparse.astype(cd))
        top_in = jnp.concatenate([bot, inter], axis=-1)
        logits = dlrm_lib._mlp_fwd(
            params["top"], top_in, len(cfg.top_mlp), False
        )
        return logits[:, 0].astype(jnp.float32)

    return forward


def _build_dlrm_cell(spec: ArchSpec, cell: ShapeCell, mesh: Mesh, bax) -> Cell:
    cfg: dlrm_lib.DLRMConfig = spec.config
    fwd = _sharded_forward(cfg, mesh, bax)

    if cell.kind == "train":
        batch = cell.params["batch"]
        # hybrid optimizer (the recsys production shape): the mega-table
        # trains with rowwise AdaGrad (one f32 scalar per row — no Adam
        # moments, no ZeRO resharding of a 96 GB tensor); dense MLPs use
        # AdamW + ZeRO-1.  See EXPERIMENTS.md §Perf (dlrm hillclimb).
        params_abs = dlrm_lib.abstract_params(cfg)
        dense_abs = {k: v for k, v in params_abs.items() if k != "table"}
        opt_abs = optim.abstract_state(dense_abs)
        accum_abs = SDS((cfg.table.total_rows,), jnp.float32)
        logical = dlrm_lib.param_logical(cfg)
        rules = shlib.RECSYS_RULES
        param_sh, _ = train_loop.state_shardings(
            logical, rules, mesh, zero1=False, params_abs=params_abs
        )
        dense_logical = {k: v for k, v in logical.items() if k != "table"}
        dense_sh, dense_opt_sh = train_loop.state_shardings(
            dense_logical, rules, mesh, zero1=True, params_abs=dense_abs
        )
        accum_sh = _ns(mesh, "model")
        batch_abs = {
            "dense": SDS((batch, cfg.n_dense), jnp.float32),
            "sparse": SDS((batch, cfg.n_sparse), jnp.int32),
            "labels": SDS((batch,), jnp.float32),
        }
        batch_sh = {
            "dense": _ns(mesh, bax, None),
            "sparse": _ns(mesh, bax, None),
            "labels": _ns(mesh, bax),
        }

        def loss_fn(p, b):
            logits = fwd(p, b["dense"], b["sparse"])
            y = b["labels"]
            return jnp.mean(
                jnp.maximum(logits, 0) - logits * y
                + jnp.log1p(jnp.exp(-jnp.abs(logits)))
            )

        adamw = optim.AdamWConfig()

        def step(state, b):
            params, opt_state, accum = state
            loss, grads = jax.value_and_grad(loss_fn)(params, b)
            table, t_accum = optim.rowwise_adagrad_update(
                params["table"], grads["table"], accum, lr=0.01
            )
            dense_p = {k: v for k, v in params.items() if k != "table"}
            dense_g = {k: v for k, v in grads.items() if k != "table"}
            new_dense, new_opt, metrics = optim.apply_updates(
                dense_p, dense_g, opt_state, adamw
            )
            new_params = dict(new_dense)
            new_params["table"] = table
            metrics["loss"] = loss
            return (new_params, new_opt, t_accum), metrics

        return Cell(
            fn=step,
            args=((params_abs, opt_abs, accum_abs), batch_abs),
            in_shardings=((param_sh, dense_opt_sh, accum_sh), batch_sh),
            out_shardings=((param_sh, dense_opt_sh, accum_sh), None),
            donate=(0,),
        )

    if cell.kind == "serve":
        batch = cell.params["batch"]
        params_abs, _, param_sh, _ = _dlrm_shardings(cfg, mesh, zero1=False)
        args = (
            params_abs,
            SDS((batch, cfg.n_dense), jnp.float32),
            SDS((batch, cfg.n_sparse), jnp.int32),
        )
        return Cell(
            fn=fwd,
            args=args,
            in_shardings=(
                param_sh, _ns(mesh, bax, None), _ns(mesh, bax, None)
            ),
            out_shardings=_ns(mesh, bax),
        )

    if cell.kind == "retrieval":
        n_cand = cell.params["n_candidates"]
        params_abs, _, param_sh, _ = _dlrm_shardings(cfg, mesh, zero1=False)

        def retrieval(params, dense, sparse_ids, candidates):
            n = candidates.shape[0]
            dense_b = jnp.broadcast_to(dense[None, :], (n, cfg.n_dense))
            ids_b = jnp.broadcast_to(sparse_ids[None, :], (n, cfg.n_sparse))
            ids_b = ids_b.at[:, 0].set(candidates)
            scores = fwd(params, dense_b, ids_b)
            vals, idx = jax.lax.top_k(scores, 100)
            return vals, jnp.take(candidates, idx)

        args = (
            params_abs,
            SDS((cfg.n_dense,), jnp.float32),
            SDS((cfg.n_sparse,), jnp.int32),
            SDS((n_cand,), jnp.int32),
        )
        return Cell(
            fn=retrieval,
            args=args,
            in_shardings=(param_sh, _ns(mesh), _ns(mesh), _ns(mesh, bax)),
            out_shardings=(_ns(mesh), _ns(mesh)),
        )

    raise ValueError(cell.kind)


def _build_seqrec_cell(spec: ArchSpec, cell: ShapeCell, mesh: Mesh, bax) -> Cell:
    cfg: sr.SeqRecConfig = spec.config
    # item tables at 10M x 50 fit per-chip: replicate (rows -> None);
    # ZeRO-1 shards the optimizer moments over 'data'.
    rules = shlib.RECSYS_RULES.with_overrides(rows=None)
    logical = sr.param_logical(cfg)
    params_abs = sr.abstract_params(cfg)
    opt_abs = optim.abstract_state(params_abs)
    param_sh, opt_sh = train_loop.state_shardings(
        logical, rules, mesh, zero1=True, params_abs=params_abs
    )

    if cell.kind == "train":
        batch = cell.params["batch"]
        if cfg.kind == "sasrec":
            batch_abs = {
                "seq": SDS((batch, cfg.seq_len), jnp.int32),
                "targets": SDS((batch, cfg.seq_len), jnp.int32),
                "negatives": SDS(
                    (batch, cfg.seq_len, cfg.n_negatives), jnp.int32
                ),
            }
            batch_sh = {
                "seq": _ns(mesh, bax, None),
                "targets": _ns(mesh, bax, None),
                "negatives": _ns(mesh, bax, None, None),
            }

            def loss_fn(p, b):
                return sr.sasrec_loss(
                    p, b["seq"], b["targets"], b["negatives"], cfg
                )
        else:
            batch_abs = {
                "seq": SDS((batch, cfg.seq_len), jnp.int32),
                "candidate": SDS((batch,), jnp.int32),
                "labels": SDS((batch,), jnp.float32),
            }
            batch_sh = {
                "seq": _ns(mesh, bax, None),
                "candidate": _ns(mesh, bax),
                "labels": _ns(mesh, bax),
            }

            def loss_fn(p, b):
                return sr.bst_loss(
                    p, b["seq"], b["candidate"], b["labels"], cfg
                )

        step = train_loop.make_train_step(
            loss_fn, train_loop.TrainStepConfig(n_micro=1)
        )
        return Cell(
            fn=step,
            args=((params_abs, opt_abs), batch_abs),
            in_shardings=((param_sh, opt_sh), batch_sh),
            out_shardings=((param_sh, opt_sh), None),
            donate=(0,),
        )

    if cell.kind == "serve":
        batch = cell.params["batch"]
        if cfg.kind == "sasrec":
            def serve(p, seq):
                return sr.sasrec_user_state(p, seq, cfg)

            args = (params_abs, SDS((batch, cfg.seq_len), jnp.int32))
            return Cell(
                fn=serve,
                args=args,
                in_shardings=(param_sh, _ns(mesh, bax, None)),
                out_shardings=_ns(mesh, bax, None),
            )
        else:
            def serve(p, seq, cand):
                return sr.bst_forward(p, seq, cand, cfg)

            args = (
                params_abs,
                SDS((batch, cfg.seq_len), jnp.int32),
                SDS((batch,), jnp.int32),
            )
            return Cell(
                fn=serve,
                args=args,
                in_shardings=(
                    param_sh, _ns(mesh, bax, None), _ns(mesh, bax)
                ),
                out_shardings=_ns(mesh, bax),
            )

    if cell.kind == "retrieval":
        n_cand = cell.params["n_candidates"]
        call_ax = tuple(
            a for a in ("pod", "data", "model") if a in mesh.axis_names
        )
        cax = call_ax if len(call_ax) > 1 else call_ax[0]
        n_dev = 1
        for a in call_ax:
            n_dev *= mesh.shape[a]
        n_cand = -(-n_cand // n_dev) * n_dev  # pad to shard evenly

        if cfg.kind == "sasrec":
            def retrieval(p, seq, candidates):
                state = sr.sasrec_user_state(p, seq, cfg)
                return sr.score_candidates(p, state, candidates, cfg, top_k=100)

            args = (
                params_abs,
                SDS((1, cfg.seq_len), jnp.int32),
                SDS((n_cand,), jnp.int32),
            )
            return Cell(
                fn=retrieval,
                args=args,
                in_shardings=(param_sh, _ns(mesh), _ns(mesh, cax)),
                out_shardings=(_ns(mesh), _ns(mesh)),
            )
        else:
            # BST retrieval: score 1M candidates through the CTR head
            def retrieval(p, seq, candidates):
                n = candidates.shape[0]
                seq_b = jnp.broadcast_to(seq, (n, cfg.seq_len))
                scores = sr.bst_forward(p, seq_b, candidates, cfg)
                vals, idx = jax.lax.top_k(scores, 100)
                return vals, jnp.take(candidates, idx)

            args = (
                params_abs,
                SDS((cfg.seq_len,), jnp.int32),
                SDS((n_cand,), jnp.int32),
            )
            return Cell(
                fn=retrieval,
                args=args,
                in_shardings=(param_sh, _ns(mesh), _ns(mesh, cax)),
                out_shardings=(_ns(mesh), _ns(mesh)),
            )

    raise ValueError(cell.kind)


# ---------------------------------------------------------------------------
# Pixie cells (the paper's own architecture)
# ---------------------------------------------------------------------------


def build_pixie_cell(spec: ArchSpec, cell: ShapeCell, mesh: Mesh) -> Cell:
    cfg = spec.config
    p = cell.params
    n_slots = cfg.n_slots

    if cell.kind == "pixie_sharded":
        n_shards = mesh.shape["model"]
        graph_abs = pixie_dist.abstract_sharded_graph(
            p["n_pins"], p["n_boards"], p["n_edges"], n_shards
        )
        gspec = pixie_dist.sharded_graph_specs("model")
        graph_sh = pixie_dist.ShardedGraph(
            p2b_offsets=NamedSharding(mesh, gspec.p2b_offsets),
            p2b_targets=NamedSharding(mesh, gspec.p2b_targets),
            b2p_offsets=NamedSharding(mesh, gspec.b2p_offsets),
            b2p_targets=NamedSharding(mesh, gspec.b2p_targets),
            n_pins=0, n_boards=0, n_shards=0,
        )

        def serve(g_off, g_tgt, b_off, b_tgt, qp, qw, key):
            graph = pixie_dist.ShardedGraph(
                g_off, g_tgt, b_off, b_tgt,
                graph_abs.n_pins, graph_abs.n_boards, n_shards,
            )
            res = pixie_dist.pixie_walk_sharded(
                graph, qp, qw, key, cfg.sharded_walk, mesh
            )
            return res.top_scores, res.top_pins, res.dropped

        args = (
            graph_abs.p2b_offsets, graph_abs.p2b_targets,
            graph_abs.b2p_offsets, graph_abs.b2p_targets,
            SDS((n_slots,), jnp.int32),
            SDS((n_slots,), jnp.float32),
            SDS((), jnp.uint32),
        )
        key_abs = jax.eval_shape(lambda: jax.random.key(0))
        args = args[:-1] + (key_abs,)
        return Cell(
            fn=serve,
            args=args,
            in_shardings=(
                graph_sh.p2b_offsets, graph_sh.p2b_targets,
                graph_sh.b2p_offsets, graph_sh.b2p_targets,
                _ns(mesh), _ns(mesh), _ns(mesh),
            ),
            out_shardings=(_ns(mesh), _ns(mesh), _ns(mesh)),
        )

    if cell.kind == "pixie_replicated":
        # graph replicated on every chip; the query batch is sharded over
        # the whole mesh (each chip is one serving replica — the fleet)
        from repro.core.graph import graph_abstract

        n_slots = cell.params.get("n_slots", n_slots)

        graph_abs = graph_abstract(
            p["n_pins"], p["n_boards"], p["n_edges"],
            offset_dtype=jnp.int32,
        )
        wcfg = dataclasses.replace(cfg.walk, count_boards=False)
        all_ax = tuple(
            a for a in ("pod", "data", "model") if a in mesh.axis_names
        )
        n_dev = 1
        for a in all_ax:
            n_dev *= mesh.shape[a]
        qbatch = n_dev  # one query per replica
        aax = all_ax if len(all_ax) > 1 else all_ax[0]

        def serve(p2b_off, p2b_tgt, b2p_off, b2p_tgt, qp, qw, feats, key):
            from repro.core.graph import CSR, PinBoardGraph

            graph = PinBoardGraph(
                p2b=CSR(p2b_off, p2b_tgt),
                b2p=CSR(b2p_off, b2p_tgt),
                n_pins=p["n_pins"], n_boards=p["n_boards"],
                max_pin_degree=4096,
            )
            keys = jax.random.split(key, qp.shape[0])

            def one(qp_i, qw_i, f_i, k_i):
                res = walk_lib.pixie_walk_events(
                    graph, qp_i, qw_i, f_i, k_i, wcfg
                )
                return walk_lib.recommend_from_events(
                    res, qp_i.shape[0], p["n_pins"], qp_i, wcfg.top_k
                )

            return jax.vmap(one)(qp, qw, feats, keys)

        args = (
            graph_abs.p2b.offsets, graph_abs.p2b.targets,
            graph_abs.b2p.offsets, graph_abs.b2p.targets,
            SDS((qbatch, n_slots), jnp.int32),
            SDS((qbatch, n_slots), jnp.float32),
            SDS((qbatch,), jnp.int32),
            jax.eval_shape(lambda: jax.random.key(0)),
        )
        return Cell(
            fn=serve,
            args=args,
            in_shardings=(
                _ns(mesh), _ns(mesh), _ns(mesh), _ns(mesh),
                _ns(mesh, aax, None), _ns(mesh, aax, None),
                _ns(mesh, aax), _ns(mesh),
            ),
            out_shardings=(_ns(mesh, aax, None), _ns(mesh, aax, None)),
        )

    raise ValueError(cell.kind)


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------


def build_cell(spec: ArchSpec, cell: ShapeCell, mesh: Mesh, **kw) -> Cell:
    if spec.family == "lm":
        return build_lm_cell(spec, cell, mesh, **kw)
    if spec.family == "gnn":
        return build_gnn_cell(spec, cell, mesh)
    if spec.family == "recsys":
        return build_recsys_cell(spec, cell, mesh)
    if spec.family == "pixie":
        return build_pixie_cell(spec, cell, mesh)
    raise ValueError(spec.family)


# ---------------------------------------------------------------------------
# Cost-model variants (see launch/dryrun.py)
#
# XLA's cost analysis counts while-loop bodies ONCE, so a scanned program
# under-reports FLOPs/bytes by ~the trip count.  The dry-run therefore also
# lowers each cell in a loop-free "cost-model" configuration at depth k=1
# and k=2 (layers unrolled, attention/loss chunk scans collapsed to a single
# full-size chunk — identical FLOPs, no loops; n_micro=1 — microbatching
# splits the same total work) and extrapolates
#     q(L) = q(1) + (L - 1) * (q(2) - q(1)),
# which is exact for homogeneous stacks.  Memory footprints always come from
# the REAL compile; only FLOPs/bytes/collective totals use the cost model.
# ---------------------------------------------------------------------------

_BIG = 1 << 30


def cost_depth(spec: ArchSpec, cell: ShapeCell) -> Optional[int]:
    """The trip count q() is linear in; None = the real program is loop-free."""
    if spec.family == "lm":
        return spec.config.n_layers - (1 if spec.config.first_dense_ff else 0)
    if spec.family == "gnn":
        return spec.config.n_layers
    if spec.family == "recsys":
        cfg = spec.config
        return getattr(cfg, "n_blocks", None)  # DLRM has no loops -> None
    if spec.family == "pixie":
        if cell.kind == "pixie_sharded":
            return spec.config.sharded_walk.n_supersteps
        return spec.config.walk.max_chunks()
    raise ValueError(spec.family)


def build_cost_cell(
    spec: ArchSpec, cell: ShapeCell, mesh: Mesh, k: int
) -> Cell:
    """The cell at depth k, loop-free (for cost_analysis extrapolation)."""
    if spec.family == "lm":
        cfg = spec.config
        cm = dataclasses.replace(
            cfg,
            n_layers=k + (1 if cfg.first_dense_ff else 0),
            unroll_layers=True,
            kv_chunk=_BIG,
            loss_chunk=_BIG,
        )
        return build_lm_cell(
            dataclasses.replace(spec, config=cm), cell, mesh, n_micro=1
        )
    if spec.family == "gnn":
        cm = dataclasses.replace(spec.config, n_layers=k, unroll_layers=True)
        return build_gnn_cell(dataclasses.replace(spec, config=cm), cell, mesh)
    if spec.family == "recsys":
        cm = dataclasses.replace(spec.config, n_blocks=k, unroll_layers=True)
        return build_recsys_cell(
            dataclasses.replace(spec, config=cm), cell, mesh
        )
    if spec.family == "pixie":
        if cell.kind == "pixie_sharded":
            sw = dataclasses.replace(
                spec.config.sharded_walk, n_supersteps=k, unroll=True
            )
            cm = dataclasses.replace(spec.config, sharded_walk=sw)
            return build_pixie_cell(
                dataclasses.replace(spec, config=cm), cell, mesh
            )
        return _build_pixie_replicated_cost(spec, cell, mesh, k)
    raise ValueError(spec.family)


def _build_pixie_replicated_cost(
    spec: ArchSpec, cell: ShapeCell, mesh: Mesh, k: int
) -> Cell:
    """Fixed-chunk (loop-free) twin of the replicated pixie serve cell."""
    from repro.core.graph import CSR, PinBoardGraph, graph_abstract

    cfg = spec.config
    p = cell.params
    n_slots = p.get("n_slots", cfg.n_slots)
    graph_abs = graph_abstract(
        p["n_pins"], p["n_boards"], p["n_edges"], offset_dtype=jnp.int32
    )
    wcfg = cfg.walk
    all_ax = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    n_dev = 1
    for a in all_ax:
        n_dev *= mesh.shape[a]
    qbatch = n_dev
    aax = all_ax if len(all_ax) > 1 else all_ax[0]

    def serve(p2b_off, p2b_tgt, b2p_off, b2p_tgt, qp, qw, feats, key):
        graph = PinBoardGraph(
            p2b=CSR(p2b_off, p2b_tgt), b2p=CSR(b2p_off, b2p_tgt),
            n_pins=p["n_pins"], n_boards=p["n_boards"], max_pin_degree=4096,
        )
        keys = jax.random.split(key, qp.shape[0])

        def one(qp_i, qw_i, f_i, k_i):
            res = walk_lib.pixie_walk_events_fixed(
                graph, qp_i, qw_i, f_i, k_i, wcfg, n_chunks=k
            )
            return walk_lib.recommend_from_events(
                res, qp_i.shape[0], p["n_pins"], qp_i, wcfg.top_k
            )

        return jax.vmap(one)(qp, qw, feats, keys)

    args = (
        graph_abs.p2b.offsets, graph_abs.p2b.targets,
        graph_abs.b2p.offsets, graph_abs.b2p.targets,
        SDS((qbatch, n_slots), jnp.int32),
        SDS((qbatch, n_slots), jnp.float32),
        SDS((qbatch,), jnp.int32),
        jax.eval_shape(lambda: jax.random.key(0)),
    )
    return Cell(
        fn=serve,
        args=args,
        in_shardings=(
            _ns(mesh), _ns(mesh), _ns(mesh), _ns(mesh),
            _ns(mesh, aax, None), _ns(mesh, aax, None),
            _ns(mesh, aax), _ns(mesh),
        ),
        out_shardings=(_ns(mesh, aax, None), _ns(mesh, aax, None)),
    )
