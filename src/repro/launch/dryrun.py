import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 placeholder devices.  Do not move
this into conftest/pyproject — smoke tests must see 1 device.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --out results/dryrun.jsonl
  python -m repro.launch.dryrun --all --subprocess   # isolation per cell

Per cell this prints/records: lower+compile status, memory_analysis,
cost_analysis FLOPs/bytes, per-device collective bytes by op, and the three
roofline terms (launch/hlo_analysis.py).
"""

import argparse
import json
import subprocess
import sys
import time
import traceback


def run_cell(
    arch: str, shape: str, mesh_kind: str, n_micro: int = 4,
    cost_model: bool = True,
) -> dict:
    import jax

    from repro.configs import get_arch
    from repro.launch.cells import build_cell, build_cost_cell, cost_depth
    from repro.launch.hlo_analysis import (
        RooflineTerms, analyze_compiled, collective_bytes,
    )
    from repro.launch.mesh import make_production_mesh

    spec = get_arch(arch)
    cell_spec = next(c for c in spec.shapes if c.name == shape)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size

    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_kind,
        "n_chips": n_chips, "kind": cell_spec.kind, "status": "start",
    }
    from repro.launch.mesh import set_mesh_compat

    t0 = time.time()
    # jax.set_mesh is absent on the pinned JAX; every jit below gets explicit
    # shardings, so the ambient mesh is optional there
    with set_mesh_compat(mesh):
        # ---- 1. the REAL program: proof-of-compile + memory + schedule ----
        kw = {"n_micro": n_micro} if spec.family == "lm" else {}
        cell = build_cell(spec, cell_spec, mesh, **kw)
        jitted = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate,
        )
        lowered = jitted.lower(*cell.args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        raw = analyze_compiled(compiled, n_chips)
        rec["raw"] = raw.as_dict()
        rec["collectives"] = {
            k: v for k, v in collective_bytes(compiled.as_text()).items()
            if v > 0
        }
        try:
            ma = compiled.memory_analysis()
            rec["memory_analysis"] = {
                "argument_size": getattr(ma, "argument_size_in_bytes", None),
                "output_size": getattr(ma, "output_size_in_bytes", None),
                "temp_size": getattr(ma, "temp_size_in_bytes", None),
                "generated_code_size": getattr(
                    ma, "generated_code_size_in_bytes", None
                ),
            }
        except Exception as e:  # CPU backend may not support it
            rec["memory_analysis"] = f"unavailable: {e}"

        # ---- 2. cost model: depth-1/depth-2 loop-free compiles -------------
        depth = cost_depth(spec, cell_spec)
        terms = raw
        if cost_model and depth is not None:
            # extrapolate from depths 2 and 3 (depth 1 sometimes triggers
            # pathological GSPMD layouts that break the linear fit)
            t2 = time.time()
            qs = []
            for k in (2, 3):
                c = build_cost_cell(spec, cell_spec, mesh, k)
                comp = jax.jit(
                    c.fn,
                    in_shardings=c.in_shardings,
                    out_shardings=c.out_shardings,
                    donate_argnums=c.donate,
                ).lower(*c.args).compile()
                qs.append(analyze_compiled(comp, n_chips))
            q1, q2 = qs

            def extrap(a, b):
                return max(a + (depth - 2) * (b - a), 0.0)

            terms = RooflineTerms(
                flops=extrap(q1.flops, q2.flops),
                hbm_bytes=extrap(q1.hbm_bytes, q2.hbm_bytes),
                coll_bytes_per_dev=extrap(
                    q1.coll_bytes_per_dev, q2.coll_bytes_per_dev
                ),
                n_chips=n_chips,
                bytes_per_device=raw.bytes_per_device,
            )
            rec["cost_model"] = {
                "depth": depth,
                "q2_flops": q1.flops, "q3_flops": q2.flops,
                "cost_compile_s": round(time.time() - t2, 1),
            }
        rec.update(terms.as_dict())
        rec["status"] = "ok"
    return rec


def _fmt(rec: dict) -> str:
    if rec["status"] != "ok":
        return f"FAIL {rec['arch']}/{rec['shape']}/{rec['mesh']}: {rec.get('error', '?')}"
    return (
        f"OK {rec['arch']}/{rec['shape']}/{rec['mesh']} "
        f"chips={rec['n_chips']} flops={rec['flops']:.3e} "
        f"hbm={rec['hbm_bytes']:.3e} coll/dev={rec['coll_bytes_per_dev']:.3e} "
        f"tc={rec['t_compute_s']:.2e}s tm={rec['t_memory_s']:.2e}s "
        f"tcoll={rec['t_collective_s']:.2e}s dom={rec['dominant']} "
        f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)"
    )


def all_cells():
    from repro.configs import all_archs, get_arch

    for arch in all_archs():
        spec = get_arch(arch)
        for cell in spec.shapes:
            yield arch, cell.name


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--subprocess", action="store_true",
                    help="one process per cell (isolation)")
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells already recorded OK in --out")
    ap.add_argument("--n-micro", type=int, default=4)
    args = ap.parse_args(argv)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = (
        list(all_cells()) if args.all else [(args.arch, args.shape)]
    )

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") == "ok":
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass

    failures = 0
    for arch, shape in cells:
        for mesh_kind in meshes:
            if (arch, shape, mesh_kind) in done:
                continue
            if args.subprocess:
                cmd = [
                    sys.executable, "-m", "repro.launch.dryrun",
                    "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                    "--out", args.out, "--n-micro", str(args.n_micro),
                ]
                r = subprocess.run(cmd)
                if r.returncode != 0:
                    failures += 1
                continue
            try:
                rec = run_cell(
                    arch, shape, mesh_kind, args.n_micro,
                    cost_model=(mesh_kind == "single"),
                )
            except Exception as e:
                rec = {
                    "arch": arch, "shape": shape, "mesh": mesh_kind,
                    "status": "fail", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:],
                }
                failures += 1
            print(_fmt(rec), flush=True)
            with open(args.out, "a") as f:
                slim = {k: v for k, v in rec.items() if k != "traceback"}
                f.write(json.dumps(slim) + "\n")
            if rec["status"] != "ok" and "traceback" in rec:
                print(rec["traceback"], file=sys.stderr, flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
