"""Synthetic GNN datasets shaped like the assigned gin-tu cells.

  * cora_like      — 2,708 nodes / 10,556 edges / 1,433 feats (full_graph_sm)
  * reddit_like    — 232,965 nodes / ~115M edges (minibatch_lg; edges are
    never materialized at full scale on this host — the *sampler* sees a
    degree-faithful CSR; reduced variants materialize fully)
  * products_like  — 2,449,029 nodes / 61,859,140 edges / 100 feats
    (full-batch-large; dry-run only at full scale)
  * molecules      — batches of ~30-node graphs (batched-small-graphs)

All are SBM-style planted-partition graphs: class-pure communities so GIN
training measurably learns (tests assert loss decreases).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Tuple

import numpy as np


class NodeGraph(NamedTuple):
    feats: np.ndarray      # (n, d) f32
    labels: np.ndarray     # (n,) int32
    edge_src: np.ndarray   # (e,) int32
    edge_dst: np.ndarray   # (e,) int32
    train_mask: np.ndarray  # (n,) f32


def planted_partition(
    n_nodes: int,
    n_edges: int,
    d_feat: int,
    n_classes: int,
    seed: int = 0,
    p_intra: float = 0.8,
) -> NodeGraph:
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    # class-informative features + noise
    centers = rng.normal(size=(n_classes, d_feat)).astype(np.float32)
    feats = centers[labels] + 0.5 * rng.normal(size=(n_nodes, d_feat)).astype(
        np.float32
    )
    # edges: intra-class with prob p_intra else uniform
    src = rng.integers(0, n_nodes, n_edges).astype(np.int64)
    intra = rng.random(n_edges) < p_intra
    # sample intra-class dst by rejection over a candidate pool
    cand = rng.integers(0, n_nodes, (n_edges, 8)).astype(np.int64)
    match = labels[cand] == labels[src][:, None]
    first = np.argmax(match, axis=1)
    has = match[np.arange(n_edges), first]
    dst_intra = cand[np.arange(n_edges), first]
    dst_rand = rng.integers(0, n_nodes, n_edges).astype(np.int64)
    dst = np.where(intra & has, dst_intra, dst_rand)
    train_mask = (rng.random(n_nodes) < 0.5).astype(np.float32)
    return NodeGraph(
        feats=feats,
        labels=labels,
        edge_src=src.astype(np.int32),
        edge_dst=dst.astype(np.int32),
        train_mask=train_mask,
    )


def cora_like(seed: int = 0, scale: float = 1.0) -> NodeGraph:
    n = max(int(2708 * scale), 64)
    e = max(int(10556 * scale), 256)
    d = max(int(1433 * scale), 16)
    return planted_partition(n, e, d, n_classes=7, seed=seed)


def reddit_like(seed: int = 0, scale: float = 1.0) -> NodeGraph:
    n = max(int(232_965 * scale), 256)
    e = max(int(114_615_892 * scale), 1024)
    return planted_partition(n, e, d_feat=602, n_classes=41, seed=seed)


def products_like(seed: int = 0, scale: float = 1.0) -> NodeGraph:
    n = max(int(2_449_029 * scale), 256)
    e = max(int(61_859_140 * scale), 1024)
    return planted_partition(n, e, d_feat=100, n_classes=47, seed=seed)


class MoleculeBatch(NamedTuple):
    feats: np.ndarray       # (total_nodes, d)
    edge_src: np.ndarray    # (total_edges,)
    edge_dst: np.ndarray
    graph_ids: np.ndarray   # (total_nodes,)
    labels: np.ndarray      # (batch,)


def molecule_batch(
    batch: int = 128,
    nodes_per: int = 30,
    edges_per: int = 64,
    d_feat: int = 16,
    n_classes: int = 2,
    seed: int = 0,
) -> MoleculeBatch:
    """Batched small graphs, flat layout with graph_ids readout."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, batch).astype(np.int32)
    feats, es, ed, gid = [], [], [], []
    for g in range(batch):
        base = g * nodes_per
        # label-dependent motif: class 1 graphs are rings, class 0 stars
        f = rng.normal(size=(nodes_per, d_feat)).astype(np.float32)
        f[:, 0] += labels[g] * 1.5
        feats.append(f)
        if labels[g] == 1:
            s = np.arange(nodes_per)
            d_ = (s + 1) % nodes_per
        else:
            s = np.zeros(nodes_per, np.int64)
            d_ = np.arange(nodes_per)
        extra = rng.integers(0, nodes_per, (2, edges_per - nodes_per))
        es.append(np.concatenate([s, extra[0]]) + base)
        ed.append(np.concatenate([d_, extra[1]]) + base)
        gid.append(np.full(nodes_per, g, np.int32))
    return MoleculeBatch(
        feats=np.concatenate(feats),
        edge_src=np.concatenate(es).astype(np.int32),
        edge_dst=np.concatenate(ed).astype(np.int32),
        graph_ids=np.concatenate(gid),
        labels=labels,
    )
