"""Synthetic Pinterest-like bipartite graphs with planted structure.

The paper's experiments need a graph with (a) heavy-tailed pin popularity,
(b) topically-focused small boards and diffuse large boards, (c) languages
attached to pins/boards, and (d) held-out "future save" edges for the link
prediction / hit-rate evaluations.  No public Pinterest graph exists, so the
benchmark substrate generates graphs with those properties planted, plus the
LDA-style topic vectors §3.2's pruning consumes (we generate Dirichlet topic
mixtures directly instead of running LDA on pin descriptions — same interface,
documented in DESIGN.md).
The multi-interest serving layer adds a USER substrate on top: a seeded
sampler of synthetic action histories with PLANTED multi-topic users
(``sample_user_histories``) — each user acts on pins drawn from a small
set of planted interest topics, so the PinnerSage-style clustering in
``core/service.build_user_query`` has real structure to recover and the
open-loop traffic generator (serving/traffic.py) can drive the
multi-interest intake with user-shaped load.
"""

from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional, Tuple

import numpy as np

from repro.core.graph import PinBoardGraph, build_graph


@dataclasses.dataclass(frozen=True)
class SyntheticGraphConfig:
    n_pins: int = 20_000
    n_boards: int = 2_000
    n_topics: int = 16
    n_langs: int = 4
    # mean pins per board; board sizes are log-normal so some boards are huge
    mean_board_size: int = 40
    board_size_sigma: float = 1.0
    # pin popularity zipf exponent (heavy tail)
    popularity_exponent: float = 1.1
    # fraction of "diverse" boards with near-uniform topic mixtures
    diverse_board_frac: float = 0.1
    # topic concentration of focused boards (lower = more focused)
    board_topic_alpha: float = 0.08
    pin_topic_alpha: float = 0.10
    # probability an edge ignores topic affinity (miscategorized pins, §3.2)
    noise_edge_frac: float = 0.05
    # language skew: lang 0 ("english") dominates
    lang_probs: Optional[Tuple[float, ...]] = None
    seed: int = 0


class SyntheticGraph(NamedTuple):
    graph: PinBoardGraph
    pin_topics: np.ndarray     # (n_pins, n_topics) float32 rows sum to 1
    board_topics: np.ndarray   # (n_boards, n_topics)
    pin_lang: np.ndarray       # (n_pins,) int32
    board_lang: np.ndarray     # (n_boards,) int32
    heldout_pins: np.ndarray   # (n_heldout,) future-save pin per board sample
    heldout_boards: np.ndarray


def _lang_probs(cfg: SyntheticGraphConfig) -> np.ndarray:
    if cfg.lang_probs is not None:
        p = np.asarray(cfg.lang_probs, dtype=np.float64)
        return p / p.sum()
    base = np.ones(cfg.n_langs)
    base[0] = max(1.0, cfg.n_langs * 2.0)  # dominant language
    return base / base.sum()


def generate(cfg: SyntheticGraphConfig, holdout_frac: float = 0.05) -> SyntheticGraph:
    rng = np.random.default_rng(cfg.seed)
    nt = cfg.n_topics

    # --- topic structure ----------------------------------------------------
    board_topics = rng.dirichlet(
        np.full(nt, cfg.board_topic_alpha), size=cfg.n_boards
    ).astype(np.float32)
    n_diverse = int(cfg.diverse_board_frac * cfg.n_boards)
    if n_diverse:
        diverse_idx = rng.choice(cfg.n_boards, size=n_diverse, replace=False)
        board_topics[diverse_idx] = rng.dirichlet(
            np.full(nt, 5.0), size=n_diverse
        ).astype(np.float32)
    pin_topics = rng.dirichlet(
        np.full(nt, cfg.pin_topic_alpha), size=cfg.n_pins
    ).astype(np.float32)

    # --- languages ----------------------------------------------------------
    lp = _lang_probs(cfg)
    board_lang = rng.choice(cfg.n_langs, size=cfg.n_boards, p=lp).astype(np.int32)
    pin_lang = rng.choice(cfg.n_langs, size=cfg.n_pins, p=lp).astype(np.int32)

    # --- pin popularity (zipf-ish) -------------------------------------------
    ranks = np.arange(1, cfg.n_pins + 1, dtype=np.float64)
    pop = ranks ** (-cfg.popularity_exponent)
    rng.shuffle(pop)

    # per-topic pin pools weighted by popularity and topic affinity
    pin_main_topic = pin_topics.argmax(axis=1)

    # --- board sizes ----------------------------------------------------------
    sizes = np.clip(
        rng.lognormal(
            mean=np.log(cfg.mean_board_size), sigma=cfg.board_size_sigma,
            size=cfg.n_boards,
        ).astype(np.int64),
        3,
        cfg.n_pins // 2,
    )

    # --- sample edges ----------------------------------------------------------
    edges_p, edges_b = [], []
    topic_pools = [np.where(pin_main_topic == t)[0] for t in range(nt)]
    pool_probs = []
    for t in range(nt):
        pool = topic_pools[t]
        w = pop[pool]
        pool_probs.append(w / w.sum() if w.size else None)
    all_probs = pop / pop.sum()

    for b in range(cfg.n_boards):
        size = int(sizes[b])
        # topic-matched picks: sample topics from the board's mixture,
        # then popular pins of that topic; same-language pins preferred.
        p_b = board_topics[b].astype(np.float64)
        p_b /= p_b.sum()
        topics = rng.choice(nt, size=size, p=p_b)
        picks = np.empty(size, dtype=np.int64)
        for i, t in enumerate(topics):
            pool = topic_pools[t]
            if pool.size == 0 or rng.random() < cfg.noise_edge_frac:
                picks[i] = rng.choice(cfg.n_pins, p=all_probs)
            else:
                picks[i] = rng.choice(pool, p=pool_probs[t])
        # language alignment: resample mismatched picks half the time
        mism = pin_lang[picks] != board_lang[b]
        for i in np.where(mism)[0]:
            if rng.random() < 0.7:
                pool = topic_pools[topics[i]]
                if pool.size:
                    lang_pool = pool[pin_lang[pool] == board_lang[b]]
                    if lang_pool.size:
                        w = pop[lang_pool]
                        picks[i] = rng.choice(lang_pool, p=w / w.sum())
        picks = np.unique(picks)
        edges_p.append(picks)
        edges_b.append(np.full(picks.shape, b, dtype=np.int64))

    pin_ids = np.concatenate(edges_p)
    board_ids = np.concatenate(edges_b)

    # --- hold out "future saves" for link prediction (§4.3) -------------------
    n_edges = pin_ids.shape[0]
    n_hold = int(holdout_frac * n_edges)
    hold_idx = rng.choice(n_edges, size=n_hold, replace=False)
    mask = np.ones(n_edges, dtype=bool)
    mask[hold_idx] = False
    heldout_pins = pin_ids[hold_idx].astype(np.int64)
    heldout_boards = board_ids[hold_idx].astype(np.int64)
    pin_ids, board_ids = pin_ids[mask], board_ids[mask]

    # drop boards that became empty from the holdout? (keep; walk guards deg-0)
    graph = build_graph(
        pin_ids,
        board_ids,
        n_pins=cfg.n_pins,
        n_boards=cfg.n_boards,
        # p2b edges sorted by target-board language, b2p by target-pin
        # language: the subrange operator biases toward same-language hops.
        edge_feat=board_lang[board_ids],
        n_feats=cfg.n_langs,
        edge_feat_b2p=pin_lang[pin_ids],
    )
    return SyntheticGraph(
        graph=graph,
        pin_topics=pin_topics,
        board_topics=board_topics,
        pin_lang=pin_lang,
        board_lang=board_lang,
        heldout_pins=heldout_pins,
        heldout_boards=heldout_boards,
    )


def small_test_graph(seed: int = 0) -> SyntheticGraph:
    """Tiny but well-connected graph for unit tests."""
    return generate(
        SyntheticGraphConfig(
            n_pins=300, n_boards=80, n_topics=6, n_langs=3,
            mean_board_size=30, popularity_exponent=0.6, seed=seed,
        )
    )


@dataclasses.dataclass(frozen=True)
class UserHistoryConfig:
    """Knobs of the planted multi-topic user sampler."""

    n_users: int = 16
    n_interests: int = 3        # planted topics per user
    mean_actions: int = 30      # Poisson mean actions per user
    max_age_hours: float = 72.0
    offtopic_frac: float = 0.1  # actions ignoring the planted interests
    seed: int = 0


class UserHistory(NamedTuple):
    """One sampled user: an action history plus its planted ground truth."""

    actions: list              # List[service.UserAction]
    topics: np.ndarray         # (n_interests,) planted interest topic ids
    mixture: np.ndarray        # (n_interests,) interest mixture weights


# action-type distribution of the sampler (weights from service.py's table
# don't matter here — only that the MIX is fixed and seeded)
_ACTION_TYPES = ("save", "click", "like", "view")
_ACTION_PROBS = (0.3, 0.3, 0.2, 0.2)


def sample_user_histories(
    sg: SyntheticGraph, cfg: UserHistoryConfig
) -> List[UserHistory]:
    """Seeded synthetic action histories with PLANTED multi-topic users.

    Every user gets ``n_interests`` distinct planted topics and a Dirichlet
    mixture over them; each action picks a planted topic by the mixture
    (or, with ``offtopic_frac``, any pin at all), then a pin of that topic
    weighted by graph degree — heavy users of a topic act on its popular
    pins, like the §5.1 homefeed assumption.  Deterministic for a given
    (graph, cfg): same seed, same histories, byte for byte.

    Returns the actions ALONGSIDE the planted ground truth, so tests can
    check the clustering layer recovers the planted structure and the
    traffic harness can label requests.
    """
    from repro.core.service import UserAction

    if cfg.n_interests < 1:
        raise ValueError(f"n_interests must be >= 1, got {cfg.n_interests}")
    rng = np.random.default_rng(cfg.seed)
    nt = sg.pin_topics.shape[1]
    if cfg.n_interests > nt:
        raise ValueError(
            f"n_interests={cfg.n_interests} exceeds the graph's "
            f"{nt} topics"
        )
    pin_main_topic = sg.pin_topics.argmax(axis=1)
    degs = np.asarray(sg.graph.p2b.degrees(), np.float64)
    pools, pool_probs = [], []
    for t in range(nt):
        pool = np.where((pin_main_topic == t) & (degs > 0))[0]
        pools.append(pool)
        w = degs[pool] if pool.size else None
        pool_probs.append(w / w.sum() if pool.size else None)
    # only plant topics that actually have connected pins
    plantable = np.array([t for t in range(nt) if pools[t].size > 0])
    if plantable.size < cfg.n_interests:
        raise ValueError(
            f"only {plantable.size} topics have connected pins; cannot "
            f"plant {cfg.n_interests} interests per user"
        )
    connected = np.where(degs > 0)[0]
    conn_probs = degs[connected] / degs[connected].sum()

    users: List[UserHistory] = []
    for _ in range(cfg.n_users):
        topics = rng.choice(plantable, size=cfg.n_interests, replace=False)
        mixture = rng.dirichlet(np.full(cfg.n_interests, 2.0))
        n_actions = max(cfg.n_interests, int(rng.poisson(cfg.mean_actions)))
        actions = []
        for _ in range(n_actions):
            if rng.random() < cfg.offtopic_frac:
                pin = int(rng.choice(connected, p=conn_probs))
            else:
                t = int(topics[rng.choice(cfg.n_interests, p=mixture)])
                pin = int(rng.choice(pools[t], p=pool_probs[t]))
            kind = str(rng.choice(_ACTION_TYPES, p=_ACTION_PROBS))
            age = float(rng.uniform(0.0, cfg.max_age_hours))
            actions.append(UserAction(pin=pin, action=kind, age_hours=age))
        users.append(UserHistory(
            actions=actions,
            topics=np.asarray(topics, np.int32),
            mixture=mixture.astype(np.float32),
        ))
    return users


def top_degree_pins(sg: SyntheticGraph, k: int = 16) -> np.ndarray:
    """Pins with the highest degree — safe query pins for tests/benchmarks."""
    degs = np.asarray(sg.graph.p2b.degrees())
    return np.argsort(-degs)[:k].astype(np.int32)


def sparse_wide_graph(
    seed: int, n_pins: int, n_boards: int, n_edges: int, hot_pins: int
) -> PinBoardGraph:
    """A graph with a huge pin-id space but edges concentrated on a small
    hot prefix — tiny CSR arrays, production-sized id space.

    This is how the wide-pack tests and benchmarks reach packed id spaces
    past 2**31 (e.g. 65536 query slots x 40000 pins) without a gigabyte of
    offsets: all ``n_edges`` edges land on pins ``[0, hot_pins)`` so the
    walk has somewhere to go, while ``n_pins`` stretches the id space.
    """
    rng = np.random.default_rng(seed)
    pins = rng.integers(0, hot_pins, n_edges)
    boards = rng.integers(0, n_boards, n_edges)
    return build_graph(pins, boards, n_pins=n_pins, n_boards=n_boards)
