"""Fanout neighbor sampler for minibatch GNN training (GraphSAGE-style).

`minibatch_lg` (Reddit-scale: 233k nodes / 115M edges, batch 1024, fanout
15-10) cannot train full-batch; the sampler draws a fixed-fanout L-hop
neighborhood around each seed batch and emits a *fixed-shape* subgraph
(padded) so the jitted train step never recompiles.

The sampler is host-side numpy over the same CSR layout as the Pixie graph
(core/graph.py) — random neighbor access on CSR is exactly Pixie's Eq. 4
access pattern, which is why this module shares that substrate.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Tuple

import numpy as np


class CSRGraph(NamedTuple):
    """Host CSR adjacency for sampling: neighbors of i in
    targets[offsets[i]:offsets[i+1]]."""

    offsets: np.ndarray   # (n_nodes + 1,) int64
    targets: np.ndarray   # (n_edges,) int32


def csr_from_edges(src: np.ndarray, dst: np.ndarray, n_nodes: int) -> CSRGraph:
    order = np.argsort(src, kind="stable")
    counts = np.bincount(src, minlength=n_nodes)
    offsets = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(counts, out=offsets[1:])
    return CSRGraph(offsets=offsets, targets=dst[order].astype(np.int32))


class SampledBlock(NamedTuple):
    """One fixed-shape sampled subgraph.

    nodes:    (max_nodes,) int32 global node ids (-1 pad); seeds first.
    edge_src: (max_edges,) int32 *local* indices into nodes (-1 pad).
    edge_dst: (max_edges,) int32 local indices (-1 pad).
    n_seeds:  int — first n_seeds entries of `nodes` are the loss targets.
    """

    nodes: np.ndarray
    edge_src: np.ndarray
    edge_dst: np.ndarray
    n_seeds: int


@dataclasses.dataclass(frozen=True)
class FanoutSampler:
    graph: CSRGraph
    fanouts: Tuple[int, ...] = (15, 10)
    seed: int = 0

    def max_nodes(self, batch: int) -> int:
        n = batch
        total = batch
        for f in self.fanouts:
            n = n * f
            total += n
        return total

    def max_edges(self, batch: int) -> int:
        n = batch
        total = 0
        for f in self.fanouts:
            total += n * f
            n = n * f
        return total

    def sample(self, seeds: np.ndarray, step: int) -> SampledBlock:
        """L-hop fixed-fanout expansion. Deterministic in (seed, step)."""
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        off, tgt = self.graph.offsets, self.graph.targets
        batch = seeds.shape[0]
        max_n = self.max_nodes(batch)
        max_e = self.max_edges(batch)

        node_of: Dict[int, int] = {}
        nodes = np.full(max_n, -1, np.int32)
        for i, s in enumerate(seeds):
            node_of[int(s)] = i
            nodes[i] = s
        n_nodes = batch

        es, ed = [], []
        frontier = list(int(s) for s in seeds)
        for f in self.fanouts:
            nxt = []
            for u in frontier:
                lo, hi = off[u], off[u + 1]
                deg = hi - lo
                if deg == 0:
                    continue
                picks = tgt[lo + rng.integers(0, deg, size=min(f, deg))]
                for v in picks:
                    v = int(v)
                    if v not in node_of:
                        if n_nodes >= max_n:
                            continue
                        node_of[v] = n_nodes
                        nodes[n_nodes] = v
                        n_nodes += 1
                        nxt.append(v)
                    # message flows neighbor -> frontier node
                    es.append(node_of[v])
                    ed.append(node_of[u])
            frontier = nxt

        edge_src = np.full(max_e, -1, np.int32)
        edge_dst = np.full(max_e, -1, np.int32)
        k = min(len(es), max_e)
        edge_src[:k] = es[:k]
        edge_dst[:k] = ed[:k]
        return SampledBlock(
            nodes=nodes, edge_src=edge_src, edge_dst=edge_dst, n_seeds=batch
        )


def block_to_arrays(
    block: SampledBlock,
    feats: np.ndarray,
    labels: np.ndarray,
) -> Dict[str, np.ndarray]:
    """Materialize padded features/labels/mask for the jitted step.

    Padding nodes get zero features and mask 0; padding edges self-loop on
    node 0 with (segment ids clipped) zero contribution via masking inside
    the model (edge -1 -> 0 with zero message is avoided by mapping pad
    edges to an unused slot: here we clip and rely on pad-node zero feats).
    """
    n = block.nodes.shape[0]
    valid = block.nodes >= 0
    safe = np.where(valid, block.nodes, 0)
    x = feats[safe] * valid[:, None]
    y = labels[safe] * valid
    mask = np.zeros(n, np.float32)
    mask[: block.n_seeds] = 1.0
    e_valid = block.edge_src >= 0
    return {
        "feats": x.astype(np.float32),
        "labels": y.astype(np.int32),
        "mask": mask,
        "edge_src": np.where(e_valid, block.edge_src, 0).astype(np.int32),
        # pad edges scatter to an out-of-range segment -> dropped
        "edge_dst": np.where(e_valid, block.edge_dst, n).astype(np.int32),
    }
