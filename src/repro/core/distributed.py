"""Sharded Pixie: the 3B-node graph across a pod, walkers migrating via ICI.

The paper's central systems claim is "the whole graph fits in one machine's
RAM, so the walk never crosses machines".  A v5e chip has 16 GB HBM; the
pruned production graph (3B nodes / 17B edges, ~100 GB as int32/int64 CSR)
cannot replicate.  The TPU-native translation keeps the *principle* one
level up: the graph is **node-range sharded across the 'model' axis of one
pod**, and walkers migrate between shards over ICI (~50 GB/s/link) — the
walk never leaves the pod (multi-pod = query parallelism on the 'pod'
axis, zero cross-pod traffic in the walk itself).

Mechanics (all inside one shard_map, shapes fully static):

  * shard s owns pins  [s, s+1) * pins_per_shard  and boards
    [s, s+1) * boards_per_shard, with local CSR slices (padded to the max
    shard size — host-side `shard_graph` compiler does this);
  * walker state = (slot, curr) int32 pairs; a walker always resides on the
    shard that owns its current pin;
  * one superstep = restart-mask -> local pin->board gather -> **all_to_all
    route to board owner** -> local board->pin gather -> **all_to_all route
    to pin owner** -> append visit event to the shard-local event buffer;
  * routing uses fixed per-destination capacity C = slack * W_local / S;
    walkers that overflow a bucket are dropped and respawn at a resident
    query pin (Pixie is a Monte Carlo estimator — bounded drops are the
    same kind of slack as the paper's early stopping, and the drop count is
    returned as a metric);
  * counts: shard-local bounded event buffers (the paper's N-bounded hash
    table, one per shard), aggregated at the end; final recommendation =
    per-shard boosted top-k -> all_gather(k) -> global re-top-k (k << N).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import counter as counter_lib
from repro.core import sampling
from repro.core.graph import PinBoardGraph

Array = jax.Array


# ---------------------------------------------------------------------------
# Host-side graph sharding (the production graph compiler's final stage)
# ---------------------------------------------------------------------------


class ShardedGraph(NamedTuple):
    """Node-range sharded CSR; every array has leading dim n_shards."""

    p2b_offsets: Array   # (S, pins_per_shard + 1) int
    p2b_targets: Array   # (S, max_p2b_edges) int32  (global board ids)
    b2p_offsets: Array   # (S, boards_per_shard + 1)
    b2p_targets: Array   # (S, max_b2p_edges) int32  (global pin ids)
    n_pins: int
    n_boards: int
    n_shards: int

    @property
    def pins_per_shard(self) -> int:
        return self.p2b_offsets.shape[1] - 1

    @property
    def boards_per_shard(self) -> int:
        return self.b2p_offsets.shape[1] - 1


def shard_graph(graph: PinBoardGraph, n_shards: int) -> ShardedGraph:
    """Split a host graph into node-range shards (padded to equal size)."""
    n_pins = -(-graph.n_pins // n_shards) * n_shards
    n_boards = -(-graph.n_boards // n_shards) * n_shards
    pps, bps = n_pins // n_shards, n_boards // n_shards

    p_off = np.asarray(graph.p2b.offsets)
    p_tgt = np.asarray(graph.p2b.targets)
    b_off = np.asarray(graph.b2p.offsets)
    b_tgt = np.asarray(graph.b2p.targets)

    def slice_csr(off, tgt, lo, hi, n_rows):
        o = off[lo:min(hi, len(off) - 1) + 1].astype(np.int64)
        seg = tgt[o[0]:o[-1]]
        o = o - o[0]
        if len(o) < n_rows + 1:  # pad ghost rows (degree 0)
            o = np.concatenate([o, np.full(n_rows + 1 - len(o), o[-1])])
        return o, seg

    po, pt, bo, bt = [], [], [], []
    for s in range(n_shards):
        o, t = slice_csr(p_off, p_tgt, s * pps, (s + 1) * pps, pps)
        po.append(o)
        pt.append(t - graph.n_pins)  # store board *indices*, not node ids
        o, t = slice_csr(b_off, b_tgt, s * bps, (s + 1) * bps, bps)
        bo.append(o)
        bt.append(t)
    max_pt = max(len(t) for t in pt)
    max_bt = max(len(t) for t in bt)
    pt = [np.pad(t, (0, max_pt - len(t))) for t in pt]
    bt = [np.pad(t, (0, max_bt - len(t))) for t in bt]
    return ShardedGraph(
        p2b_offsets=jnp.asarray(np.stack(po).astype(np.int32)),
        p2b_targets=jnp.asarray(np.stack(pt).astype(np.int32)),
        b2p_offsets=jnp.asarray(np.stack(bo).astype(np.int32)),
        b2p_targets=jnp.asarray(np.stack(bt).astype(np.int32)),
        n_pins=n_pins,
        n_boards=n_boards,
        n_shards=n_shards,
    )


def abstract_sharded_graph(
    n_pins: int, n_boards: int, n_edges: int, n_shards: int
) -> ShardedGraph:
    """ShapeDtypeStruct stand-in at production scale (dry-run only)."""
    sds = jax.ShapeDtypeStruct
    pps = -(-n_pins // n_shards)
    bps = -(-n_boards // n_shards)
    eps = int(n_edges // n_shards * 1.25)  # 25% imbalance headroom
    return ShardedGraph(
        p2b_offsets=sds((n_shards, pps + 1), jnp.int32),
        p2b_targets=sds((n_shards, eps), jnp.int32),
        b2p_offsets=sds((n_shards, bps + 1), jnp.int32),
        b2p_targets=sds((n_shards, eps), jnp.int32),
        n_pins=pps * n_shards,
        n_boards=bps * n_shards,
        n_shards=n_shards,
    )


def sharded_graph_specs(axis: str = "model") -> ShardedGraph:
    """PartitionSpecs for the sharded graph arrays (leading dim = shard)."""
    e = P(axis, None)
    return ShardedGraph(
        p2b_offsets=e, p2b_targets=e, b2p_offsets=e, b2p_targets=e,
        n_pins=0, n_boards=0, n_shards=0,
    )


# ---------------------------------------------------------------------------
# The sharded walk
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardedWalkConfig:
    n_supersteps: int = 64
    walkers_per_shard: int = 1024
    alpha: float = 0.5
    route_slack: float = 2.0
    top_k: int = 100
    unroll: bool = False     # cost-model mode (see launch/dryrun.py)

    def capacity(self, n_shards: int) -> int:
        c = int(self.route_slack * self.walkers_per_shard / n_shards)
        return max(8, -(-c // 8) * 8)


class ShardedWalkResult(NamedTuple):
    top_scores: Array    # (top_k,) f32 boosted scores
    top_pins: Array      # (top_k,) int32 global pin ids
    dropped: Array       # () int32 walkers dropped by routing overflow
    slot_events: Array   # (S, max_events) per-shard wide event slot lanes
    pin_events: Array    # (S, max_events) per-shard local-pin lanes


def _route(
    axis: str,
    n_shards: int,
    capacity: int,
    dest: Array,      # (L,) destination shard per walker (>= n_shards = dead)
    payload: Tuple[Array, ...],   # each (L,) int32
) -> Tuple[Array, Tuple[Array, ...], Array]:
    """all_to_all walker exchange with fixed per-pair capacity.

    Returns (valid_mask (S*C,), routed payload tuple (S*C,), n_dropped ()).
    """
    l = dest.shape[0]
    order = jnp.argsort(dest)
    dsort = dest[order]
    counts = jnp.bincount(jnp.minimum(dsort, n_shards), length=n_shards + 1)
    start = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]]
    )
    pos = jnp.arange(l, dtype=jnp.int32) - jnp.take(start, dsort).astype(jnp.int32)
    live = dsort < n_shards
    keep = live & (pos < capacity)
    slot = jnp.where(keep, dsort * capacity + pos, n_shards * capacity)
    dropped = jnp.sum(live & ~keep)

    out_payload = []
    for arr in payload:
        buf = jnp.zeros((n_shards * capacity + 1,), arr.dtype)
        buf = buf.at[slot].set(arr[order])
        routed = jax.lax.all_to_all(
            buf[:-1].reshape(n_shards, capacity), axis, 0, 0, tiled=False
        )  # (n_shards, capacity) received
        out_payload.append(routed.reshape(-1))
    vbuf = jnp.zeros((n_shards * capacity + 1,), jnp.bool_).at[slot].set(keep)
    valid = jax.lax.all_to_all(
        vbuf[:-1].reshape(n_shards, capacity), axis, 0, 0, tiled=False
    ).reshape(-1)
    return valid, tuple(out_payload), dropped


def pixie_walk_sharded(
    graph: ShardedGraph,
    query_pins: Array,      # (n_slots,) int32 global pin ids (-1 pad)
    query_weights: Array,   # (n_slots,) f32
    key: Array,
    cfg: ShardedWalkConfig,
    mesh: Mesh,
    axis: str = "model",
) -> ShardedWalkResult:
    """Multi-query Pixie walk on a node-range-sharded graph."""
    n_shards = mesh.shape[axis]
    s = n_shards
    wl = cfg.walkers_per_shard
    cap = cfg.capacity(s)
    recv = s * cap                        # walkers resident after a route
    n_slots = query_pins.shape[0]
    pps = graph.pins_per_shard
    bps = graph.boards_per_shard
    max_events = cfg.n_supersteps * recv
    # events are WIDE (slot, local_pin) int32 lane pairs — the per-shard
    # id space n_slots * pins_per_shard may exceed 2^31 with no dtype
    # change (the old packed-int64 branch is gone); the slot lane carries
    # n_slots for uncounted steps
    alpha_u32 = min(int(cfg.alpha * 2**32), 2**32 - 1)

    valid_q = (query_pins >= 0) & (query_weights > 0)
    safe_q = jnp.where(valid_q, query_pins, 0)

    def local_walk(p2b_off, p2b_tgt, b2p_off, b2p_tgt, qpins, qw, key):
        p2b_off, p2b_tgt = p2b_off[0], p2b_tgt[0]
        b2p_off, b2p_tgt = b2p_off[0], b2p_tgt[0]
        sid = jax.lax.axis_index(axis)
        pin_lo = sid * pps

        # ---- seed: each shard spawns walkers on its RESIDENT query pins ----
        owner = safe_q // pps
        resident = (owner == sid) & valid_q
        any_resident = jnp.any(resident)
        # weight-proportional slot choice among resident queries
        w_local = jnp.where(resident, qw, 0.0)
        csum = jnp.cumsum(w_local)
        total = jnp.maximum(csum[-1], 1e-9)
        u = jax.random.uniform(jax.random.fold_in(key, sid), (recv,)) * total
        slot0 = jnp.searchsorted(csum, u).astype(jnp.int32)
        slot0 = jnp.clip(slot0, 0, n_slots - 1)
        curr0 = jnp.take(safe_q, slot0)
        # seed only walkers_per_shard walkers; the buffer keeps route_slack
        # headroom so skewed hops don't immediately overflow capacity
        valid0 = any_resident & (jnp.arange(recv) < wl)

        sev0 = jnp.full((max_events,), n_slots, jnp.int32)
        pev0 = jnp.zeros((max_events,), jnp.int32)

        def superstep(carry, t):
            curr, slot, valid, sev, pev, dropped = carry
            k_t = jax.random.fold_in(jax.random.fold_in(key, sid), t)
            rb = jax.random.bits(k_t, (recv, 3), dtype=jnp.uint32)

            # restart: walker returns to its query pin (may be remote)
            restart = rb[:, 0] < jnp.uint32(alpha_u32)
            pos = jnp.where(restart, jnp.take(safe_q, slot), curr)

            # walkers whose position is non-resident (fresh restarts) route
            # through hop-1 on their home shard next superstep; here we
            # treat position as local when possible.
            local_pin = jnp.clip(pos - pin_lo, 0, pps - 1)
            is_local = (pos >= pin_lo) & (pos < pin_lo + pps)

            starts = jnp.take(p2b_off, local_pin)
            degs = jnp.take(p2b_off, local_pin + 1) - starts
            eidx = starts + (rb[:, 1].astype(jnp.int32) % jnp.maximum(degs, 1))
            board = jnp.take(p2b_tgt, eidx)         # board index [0, n_boards)
            hop1_ok = valid & is_local & (degs > 0)

            # route to board owner
            bdest = jnp.where(hop1_ok, board // bps, s)
            # non-local restarts and dead-end walkers route home (restart)
            home = jnp.take(safe_q, slot) // pps
            go_home = valid & (~is_local | (is_local & (degs <= 0)))
            dest1 = jnp.where(go_home, home, bdest)
            pay_pos = jnp.where(go_home, jnp.take(safe_q, slot), board)
            flag = go_home.astype(jnp.int32)  # 1 = restart-in-flight
            v1, (pos1, slot1, flag1), d1 = _route(
                axis, s, cap, jnp.where(valid, dest1, s),
                (pay_pos, slot, flag),
            )

            # hop 2 (only for walkers carrying a board)
            on_board = v1 & (flag1 == 0)
            local_board = jnp.clip(pos1 - sid * bps, 0, bps - 1)
            k2 = jax.random.fold_in(k_t, 1)
            rb2 = jax.random.bits(k2, (recv,), dtype=jnp.uint32)
            bstarts = jnp.take(b2p_off, local_board)
            bdegs = jnp.take(b2p_off, local_board + 1) - bstarts
            bidx = bstarts + (rb2.astype(jnp.int32) % jnp.maximum(bdegs, 1))
            pin = jnp.take(b2p_tgt, bidx)           # global pin id
            hop2_ok = on_board & (bdegs > 0)

            # dead-ends and in-flight restarts both continue at query pin
            tgt_pin = jnp.where(hop2_ok, pin, jnp.take(safe_q, slot1))
            counted = hop2_ok
            dest2 = jnp.where(v1, tgt_pin // pps, s)
            v2, (pos2, slot2, cnt2), d2 = _route(
                axis, s, cap, dest2,
                (tgt_pin, slot1, counted.astype(jnp.int32)),
            )

            # record visits (walkers now resident on this shard) — wide
            # (slot, local_pin) lanes, slot lane n_slots = uncounted
            local2 = jnp.clip(pos2 - pin_lo, 0, pps - 1)
            counted2 = v2 & (cnt2 == 1)
            ev_s = jnp.where(counted2, slot2, n_slots).astype(jnp.int32)
            ev_p = jnp.where(counted2, local2, 0).astype(jnp.int32)
            sev = jax.lax.dynamic_update_slice(sev, ev_s, (t * recv,))
            pev = jax.lax.dynamic_update_slice(pev, ev_p, (t * recv,))
            return (pos2, slot2, v2, sev, pev, dropped + d1 + d2), None

        carry0 = (
            curr0, slot0, valid0, sev0, pev0, jnp.asarray(0, jnp.int32)
        )
        (curr, slot, valid, sev, pev, dropped), _ = jax.lax.scan(
            superstep, carry0, jnp.arange(cfg.n_supersteps),
            unroll=cfg.unroll or 1,
        )

        # ---- shard-local aggregation + boosted top-k ----
        uniq_slot, uniq_pin, counts = counter_lib.events_to_counts(
            sev, pev, n_slots, max_events
        )
        pin_ids, boosted = counter_lib.boosted_from_events(
            uniq_slot, uniq_pin, counts, n_slots, pps, max_events
        )
        top_s, top_i = jax.lax.top_k(boosted, cfg.top_k)
        top_pins_local = jnp.where(
            top_i < max_events,
            jnp.take(pin_ids, top_i).astype(jnp.int32) + pin_lo,
            -1,
        )
        # hierarchical top-k: gather per-shard candidates, re-select
        all_s = jax.lax.all_gather(top_s, axis)      # (S, k)
        all_p = jax.lax.all_gather(top_pins_local, axis)
        gs, gi = jax.lax.top_k(all_s.reshape(-1), cfg.top_k)
        gp = jnp.take(all_p.reshape(-1), gi)
        dropped_total = jax.lax.psum(dropped, axis)
        return gs, gp, dropped_total, sev[None], pev[None]

    other_axes = tuple(a for a in mesh.axis_names if a != axis)
    rep = P()
    fn = shard_map(
        local_walk,
        mesh=mesh,
        in_specs=(
            P(axis, None), P(axis, None), P(axis, None), P(axis, None),
            rep, rep, rep,
        ),
        out_specs=(rep, rep, rep, P(axis, None), P(axis, None)),
        check_rep=False,
    )
    gs, gp, dropped, sev, pev = fn(
        graph.p2b_offsets, graph.p2b_targets,
        graph.b2p_offsets, graph.b2p_targets,
        safe_q, jnp.where(valid_q, query_weights, 0.0), key,
    )
    return ShardedWalkResult(
        top_scores=gs, top_pins=gp, dropped=dropped,
        slot_events=sev, pin_events=pev,
    )
