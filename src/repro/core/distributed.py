"""Sharded Pixie: the 3B-node graph across a pod, walkers migrating via ICI.

The paper's central systems claim is "the whole graph fits in one machine's
RAM, so the walk never crosses machines".  A v5e chip has 16 GB HBM; the
pruned production graph (3B nodes / 17B edges, ~100 GB as int32/int64 CSR)
cannot replicate.  The TPU-native translation keeps the *principle* one
level up: the graph is **node-range sharded across the 'model' axis of one
pod**, and walkers migrate between shards over ICI (~50 GB/s/link) — the
walk never leaves the pod (multi-pod = query parallelism on the 'pod'
axis, zero cross-pod traffic in the walk itself).

The sharded engine is a first-class consumer of the batched fused walk
machinery (core/walk.py, kernels/walk_step.py), not a separate walk
implementation:

  * shard s owns pins  [s, s+1) * pins_per_shard  and boards
    [s, s+1) * boards_per_shard, with local CSR slices (padded to the max
    shard size — host-side `shard_graph` compiler does this);
  * a walker's identity is its GLOBAL walker id (query-major, walker
    ``q * n_walkers + i`` — the PR 5 batch packing), so its random stream
    is position-independent: every shard derives the whole batch's
    counter-RNG bits per chunk (``walk_lib._chunk_rbits`` — replicated
    arithmetic, bit-identical to the unsharded engines) and a walker
    consumes its own lane wherever it happens to reside;
  * one superstep = restart kill/rebirth -> per-shard fused hop kernel
    (pin->board, ``kernels/ops.walk_hop`` — ONE ``pallas_call`` for the
    whole routed walker buffer, both ``gather_mode="scalar"`` and
    ``"dma"``) -> **all_to_all route to the board owner** -> fused hop
    (board->pin) + shard-local board counting -> **all_to_all route to
    the pin owner** -> wide (query, slot, local_pin) event accumulation
    into the shard's owned dense bins with the incremental ``n_high``
    crossing tally (``counter.accumulate_packed_events_with_high``);
  * restarts are kill + rebirth-at-home: a restarting walker's resident
    copy dies wherever it is and the walker re-enters at the shard owning
    its query pin — restart teleports ride the ordinary hop routes, no
    third collective;
  * early stop is GLOBAL per (query, slot): each shard carries its owned
    subrange's incremental crossing tally and a chunk-boundary ``psum``
    folds them into the Algorithm 3 statistic — never a reduction over
    the count buffers.  Stopped rows' walkers are killed (excluded from
    routing capacity) exactly like the PR 5 freeze semantics;
  * routing uses fixed per-(shard, shard) capacity
    ``route_capacity(S, W, slack)``; walkers that overflow are dropped
    and respawn at their query pin on their next restart draw (Pixie is
    a Monte Carlo estimator — bounded drops are the same kind of slack
    as the paper's early stopping, and the drop count is surfaced as a
    serving metric, never silent).

With zero drops the engine is BIT-IDENTICAL to the unsharded batched
engine on the same graph (counts, board counts, steps_taken, n_high):
``backend="xla"`` is the plain-XLA oracle twin (structural parity via
``kernels/ref.walk_hop_ref``), ``backend="pallas"`` the fused kernels —
tests/test_sharded_engine.py pins all three against each other.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import counter as counter_lib
from repro.core import sampling
from repro.core import walk as walk_lib
from repro.core.graph import PinBoardGraph
from repro.kernels import ops
from repro.kernels.walk_step import GATHER_MODES

Array = jax.Array


# ---------------------------------------------------------------------------
# Host-side graph sharding (the production graph compiler's final stage)
# ---------------------------------------------------------------------------


class ShardedGraph(NamedTuple):
    """Node-range sharded CSR; every array has leading dim n_shards."""

    p2b_offsets: Array   # (S, pins_per_shard + 1) int
    p2b_targets: Array   # (S, max_p2b_edges) int32  (board *indices*)
    b2p_offsets: Array   # (S, boards_per_shard + 1)
    b2p_targets: Array   # (S, max_b2p_edges) int32  (global pin ids)
    n_pins: int
    n_boards: int
    n_shards: int
    # static degree cap for Eq. 2 scaling (graph.max_pin_degree); trailing
    # default keeps older positional constructions compiling
    max_pin_degree: int = 4096

    @property
    def pins_per_shard(self) -> int:
        return self.p2b_offsets.shape[1] - 1

    @property
    def boards_per_shard(self) -> int:
        return self.b2p_offsets.shape[1] - 1


def shard_graph(graph: PinBoardGraph, n_shards: int) -> ShardedGraph:
    """Split a host graph into node-range shards (padded to equal size)."""
    n_pins = -(-graph.n_pins // n_shards) * n_shards
    n_boards = -(-graph.n_boards // n_shards) * n_shards
    pps, bps = n_pins // n_shards, n_boards // n_shards

    p_off = np.asarray(graph.p2b.offsets)
    p_tgt = np.asarray(graph.p2b.targets)
    b_off = np.asarray(graph.b2p.offsets)
    b_tgt = np.asarray(graph.b2p.targets)

    def slice_csr(off, tgt, lo, hi, n_rows):
        o = off[lo:min(hi, len(off) - 1) + 1].astype(np.int64)
        seg = tgt[o[0]:o[-1]]
        o = o - o[0]
        if len(o) < n_rows + 1:  # pad ghost rows (degree 0)
            o = np.concatenate([o, np.full(n_rows + 1 - len(o), o[-1])])
        return o, seg

    po, pt, bo, bt = [], [], [], []
    for s in range(n_shards):
        o, t = slice_csr(p_off, p_tgt, s * pps, (s + 1) * pps, pps)
        po.append(o)
        pt.append(t - graph.n_pins)  # store board *indices*, not node ids
        o, t = slice_csr(b_off, b_tgt, s * bps, (s + 1) * bps, bps)
        bo.append(o)
        bt.append(t)
    max_pt = max(len(t) for t in pt)
    max_bt = max(len(t) for t in bt)
    pt = [np.pad(t, (0, max_pt - len(t))) for t in pt]
    bt = [np.pad(t, (0, max_bt - len(t))) for t in bt]
    return ShardedGraph(
        p2b_offsets=jnp.asarray(np.stack(po).astype(np.int32)),
        p2b_targets=jnp.asarray(np.stack(pt).astype(np.int32)),
        b2p_offsets=jnp.asarray(np.stack(bo).astype(np.int32)),
        b2p_targets=jnp.asarray(np.stack(bt).astype(np.int32)),
        n_pins=n_pins,
        n_boards=n_boards,
        n_shards=n_shards,
        max_pin_degree=graph.max_pin_degree,
    )


def abstract_sharded_graph(
    n_pins: int, n_boards: int, n_edges: int, n_shards: int
) -> ShardedGraph:
    """ShapeDtypeStruct stand-in at production scale (dry-run only)."""
    sds = jax.ShapeDtypeStruct
    pps = -(-n_pins // n_shards)
    bps = -(-n_boards // n_shards)
    eps = int(n_edges // n_shards * 1.25)  # 25% imbalance headroom
    return ShardedGraph(
        p2b_offsets=sds((n_shards, pps + 1), jnp.int32),
        p2b_targets=sds((n_shards, eps), jnp.int32),
        b2p_offsets=sds((n_shards, bps + 1), jnp.int32),
        b2p_targets=sds((n_shards, eps), jnp.int32),
        n_pins=pps * n_shards,
        n_boards=bps * n_shards,
        n_shards=n_shards,
    )


def sharded_graph_specs(axis: str = "model") -> ShardedGraph:
    """PartitionSpecs for the sharded graph arrays (leading dim = shard)."""
    e = P(axis, None)
    return ShardedGraph(
        p2b_offsets=e, p2b_targets=e, b2p_offsets=e, b2p_targets=e,
        n_pins=0, n_boards=0, n_shards=0,
    )


# ---------------------------------------------------------------------------
# Routing fabric
# ---------------------------------------------------------------------------


def route_capacity(n_shards: int, n_walkers_total: int, slack: float) -> int:
    """Per-(shard, shard) route capacity for a pool of W walkers.

    Balanced hops put ``W / n_shards**2`` walkers on each (source, dest)
    pair; ``slack`` is the skew headroom before drops start.  Rounded up
    to a multiple of 8 (lane-friendly buffers), floor 8.
    """
    c = int(slack * n_walkers_total / (n_shards * n_shards))
    return max(8, -(-c // 8) * 8)


def _route(
    axis: str,
    n_shards: int,
    capacity: int,
    dest: Array,      # (L,) destination shard per walker (>= n_shards = dead)
    payload: Tuple[Array, ...],   # each (L,) int32
) -> Tuple[Array, Tuple[Array, ...], Array, Array]:
    """all_to_all walker exchange with fixed per-pair capacity.

    Returns ``(valid_mask (S*C,), routed payload tuple (S*C,),
    n_dropped (), max_occupancy ())`` — the last being the fullest
    outbound bucket before the capacity clamp, the serving-telemetry
    signal for tuning ``slack``.
    """
    l = dest.shape[0]
    order = jnp.argsort(dest)
    dsort = dest[order]
    counts = jnp.bincount(jnp.minimum(dsort, n_shards), length=n_shards + 1)
    start = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]]
    )
    pos = jnp.arange(l, dtype=jnp.int32) - jnp.take(start, dsort).astype(jnp.int32)
    live = dsort < n_shards
    keep = live & (pos < capacity)
    slot = jnp.where(keep, dsort * capacity + pos, n_shards * capacity)
    dropped = jnp.sum(live & ~keep)
    max_occ = jnp.max(counts[:n_shards]).astype(jnp.int32)

    out_payload = []
    for arr in payload:
        buf = jnp.zeros((n_shards * capacity + 1,), arr.dtype)
        buf = buf.at[slot].set(arr[order])
        routed = jax.lax.all_to_all(
            buf[:-1].reshape(n_shards, capacity), axis, 0, 0, tiled=False
        )  # (n_shards, capacity) received
        out_payload.append(routed.reshape(-1))
    vbuf = jnp.zeros((n_shards * capacity + 1,), jnp.bool_).at[slot].set(keep)
    valid = jax.lax.all_to_all(
        vbuf[:-1].reshape(n_shards, capacity), axis, 0, 0, tiled=False
    ).reshape(-1)
    return valid, tuple(out_payload), dropped, max_occ


# ---------------------------------------------------------------------------
# The pod-sharded batched fused walk engine
# ---------------------------------------------------------------------------


class ShardedBatchedWalkResult(NamedTuple):
    """Sharded twin of ``walk.WalkResult`` with routing telemetry.

    ``counts`` / ``board_counts`` stay SHARD-STACKED (each shard's
    query-major owned-subrange bins) — ``counter.fold_sharded_counts``
    reassembles the unsharded batched layout when a consumer needs the
    global id axis; serving keeps them sharded and runs the hierarchical
    top-k instead.
    """

    counts: Array                   # (S, B * n_slots * pins_per_shard) int32
    board_counts: Optional[Array]   # (S, B * n_slots * boards_per_shard)
    steps_taken: Array              # (B, n_slots) int32
    n_high: Array                   # (B, n_slots) int32, query pins debited
    dropped: Array                  # () int32 routing-overflow drops (total)
    max_occupancy: Array            # () int32 fullest route bucket seen
    # () int32 walkers lost to DEAD shards (``shard_dead_at``): residents
    # at the death superstep + walkers routed toward a dead shard after
    # it.  Telemetry distinct from ``dropped`` (capacity overflow): drops
    # tune ``slack``, kills quantify fault damage.  None on the healthy
    # code path (no fault schedule supplied).
    killed: Optional[Array] = None


def pixie_walk_sharded_batched(
    graph: ShardedGraph,
    query_pins: Array,      # (B, n_slots) int32 global pin ids, -1 pad
    query_weights: Array,   # (B, n_slots) f32, 0 for padding
    keys: Array,            # (B,) per-query PRNG keys (random.split)
    cfg: walk_lib.WalkConfig,
    mesh: Mesh,
    axis: str = "model",
    *,
    slack: float = 2.0,
    unroll: bool = False,
    shard_dead_at: Optional[Array] = None,
) -> ShardedBatchedWalkResult:
    """The batched fused walk engine on a node-range-sharded graph.

    The bit-parity twin of ``walk.pixie_random_walk_batched`` on the same
    (replicated) graph — identical counts, board counts, ``steps_taken``
    and ``n_high`` whenever no walker is dropped (raise ``slack`` until
    ``dropped == 0``; parity tests do).  Each per-shard superstep runs the
    fused hop kernel (``cfg.backend == "pallas"``, both gather modes) or
    its XLA oracle twin on the shard-local CSR slices; ONE bounded
    ``_route`` fabric per hop carries the whole query batch.

    ``cfg`` is the ordinary walk config; ``cfg.bias_beta`` must be 0 (the
    sharded CSR carries no feat_bounds).  ``unroll=True`` is cost-model
    mode (launch/dryrun.py): python loops instead of ``while``/``fori``,
    every chunk runs — mathematically identical (stopped rows are frozen
    by masking either way), just loop-free for XLA cost analysis.

    ``shard_dead_at`` (optional ``(n_shards,)`` int32) is DEGRADED MODE:
    shard ``s`` is dead from absolute superstep ``shard_dead_at[s]``
    onward (``np.iinfo(np.int32).max`` = never dies).  A dead shard's
    residents die with it, walkers routed toward it die in flight (both
    tallied in ``killed`` — distinct from capacity ``dropped``), its
    homed walkers stop being (re)injected, and any walker killed this way
    re-enters at its (live) home shard on its next restart draw — the
    ordinary PR 6 kill/rebirth-at-home machinery, no new collective.  At
    harvest a shard that died before the walk finished contributes ZERO
    counts/board counts and leaves the ``n_high`` tally: its HBM is gone,
    so Eq. 3 counting renormalizes over the surviving shards and the
    quality cost surfaces as overlap@k against an all-alive oracle
    (serving/resilience.py), never as a silent score shift.  Pure data on
    the replicated spec — flipping liveness never retraces — and
    ``None`` (every existing caller) traces the exact healthy program,
    byte-for-byte.  An all-``INT32_MAX`` schedule is value-identical to
    ``None`` (the masks it introduces are all-true), which is how the
    serving layer keeps one compiled program for both weathers.
    """
    if query_pins.ndim != 2:
        raise ValueError(
            f"query_pins must be (n_queries, n_slots), got {query_pins.shape}"
        )
    if cfg.n_v < 1:
        raise ValueError(
            f"n_v must be >= 1, got {cfg.n_v}; use "
            "cfg.without_early_stop() to disable early stopping"
        )
    if cfg.bias_beta > 0.0:
        raise ValueError(
            "the sharded graph carries no feat_bounds; set bias_beta=0 "
            "for sharded walks"
        )
    if cfg.gather_mode not in GATHER_MODES:
        raise ValueError(
            f"unknown gather_mode {cfg.gather_mode!r}; use {GATHER_MODES}"
        )
    n_queries, n_slots = query_pins.shape
    s_axis = mesh.shape[axis]
    if graph.n_shards not in (0, s_axis):
        raise ValueError(
            f"graph sharded {graph.n_shards} ways but mesh axis {axis!r} "
            f"has {s_axis} devices"
        )
    n_shards = s_axis
    # degraded mode is a PYTHON-level branch: shard_dead_at=None traces
    # the healthy program untouched (no dead masks in the jaxpr at all)
    faulty = shard_dead_at is not None
    if faulty:
        shard_dead_at = jnp.asarray(shard_dead_at, jnp.int32)
        if shard_dead_at.shape != (n_shards,):
            raise ValueError(
                f"shard_dead_at must be ({n_shards},) — one death "
                f"superstep per shard — got {shard_dead_at.shape}"
            )
    w = cfg.n_walkers
    w_total = n_queries * w
    pps = graph.pins_per_shard
    bps = graph.boards_per_shard
    cap = route_capacity(n_shards, w_total, slack)
    recv = n_shards * cap               # walker buffer after a route
    n_rows = n_queries * n_slots
    # per-shard dense bins must fit int32 indexing (the whole point of
    # sharding the count space: bins divide by n_shards)
    count_engine = walk_lib.select_count_engine(
        cfg.backend, n_rows, pps, bps if cfg.count_boards else 0
    )
    use_kernel = cfg.backend == "pallas"
    alpha_u32 = walk_lib._prob_u32(cfg.alpha)
    slot_sentinel = jnp.int32(n_slots)
    query_sentinel = jnp.int32(n_queries)

    valid_q = (query_pins >= 0) & (query_weights > 0)
    safe_q = jnp.where(valid_q, query_pins, 0)
    qid_of_walker = jnp.repeat(jnp.arange(n_queries, dtype=jnp.int32), w)

    def local_walk(p2b_off, p2b_tgt, b2p_off, b2p_tgt, qp, qw, vq, ks,
                   *fault):
        p2b_off, p2b_tgt = p2b_off[0], p2b_tgt[0]
        b2p_off, b2p_tgt = b2p_off[0], b2p_tgt[0]
        sid = jax.lax.axis_index(axis)
        pin_lo = sid * pps
        board_lo = sid * bps
        if faulty:
            (dead_at,) = fault            # (S,) replicated death schedule
            dead_self = jnp.take(dead_at, sid)

        # ---- replicated Eq. 1-2 setup: the same traced arithmetic as the
        # unsharded engine; query-pin degrees come from each shard's owned
        # rows, psum-replicated (ownership partitions the id space, so the
        # sum IS the lookup)
        owned_q = vq & (qp >= pin_lo) & (qp < pin_lo + pps)
        lq0 = jnp.where(owned_q, qp - pin_lo, 0)
        deg_own = (
            jnp.take(p2b_off, lq0 + 1) - jnp.take(p2b_off, lq0)
        ) * owned_q.astype(p2b_off.dtype)
        degs = jax.lax.psum(deg_own, axis)

        n_q = jax.vmap(
            lambda v, qwr, dg: sampling.allocate_steps(
                jnp.where(v, qwr, 0.0), dg,
                jnp.asarray(graph.max_pin_degree), cfg.n_steps,
            )
        )(vq, qw, degs)                                        # (B, S)
        slot_of_walker_q, _ = jax.vmap(
            lambda nq: sampling.allocate_walkers(nq, w)
        )(n_q)                                                 # (B, w)
        query_of_walker_q = jax.vmap(jnp.take)(qp, slot_of_walker_q)
        walkers_per_slot = jax.vmap(
            lambda so: jax.ops.segment_sum(
                jnp.ones((w,), jnp.int32), so, num_segments=n_slots
            )
        )(slot_of_walker_q).reshape(-1)                        # (B*S,)
        slot_of_walker = slot_of_walker_q.reshape(-1).astype(jnp.int32)
        query_of_walker = query_of_walker_q.reshape(-1).astype(jnp.int32)
        row_of_walker = qid_of_walker * n_slots + slot_of_walker
        home_of_walker = query_of_walker // pps

        valid_row = vq.reshape(-1)
        n_q_row = n_q.reshape(-1)

        def superstep(sstate, rb, row_active, first, step_abs):
            """One global hop for every live walker resident on this shard.

            ``rb`` is the whole batch's (w_total, 4) counter-RNG row for
            this absolute step; walkers index it by GLOBAL walker id, so
            each consumes bit-for-bit the unsharded engine's draws.
            ``step_abs`` is the absolute superstep index (None unless a
            fault schedule is active): liveness = ``step_abs < dead_at``.
            """
            if faulty:
                (res_v, res_g, res_p, counts, bcounts, high, dropped,
                 occ, killed) = sstate
                alive_vec = step_abs < dead_at                 # (S,) bool
                self_alive = step_abs < dead_self              # () bool
            else:
                (res_v, res_g, res_p, counts, bcounts, high, dropped,
                 occ) = sstate
            restart = rb[:, 0] < jnp.uint32(alpha_u32)         # (w_total,)
            active_w = jnp.take(row_active, row_of_walker)     # (w_total,)

            # kill + rebirth-at-home: restarting (or frozen-row) residents
            # leave the fabric; restarting walkers of active rows re-enter
            # at the shard owning their query pin with pos = query — the
            # unsharded `where(restart, query, curr)` applied BEFORE the
            # hop, so the reborn walker hops this same superstep
            res_live = (
                res_v
                & ~jnp.take(restart, res_g)
                & jnp.take(active_w, res_g)
            )
            inject = (restart | first) & active_w & (home_of_walker == sid)
            if faulty:
                # a dead shard kills its residents (tallied once, at the
                # death superstep) and stops (re)injecting its homed
                # walkers; a killed walker re-enters at its home on its
                # next restart draw — the ordinary rebirth path
                killed = killed + jnp.where(
                    step_abs == dead_self, jnp.sum(res_v), 0
                ).astype(jnp.int32)
                res_live = res_live & self_alive
                inject = inject & self_alive
            cand_v = jnp.concatenate([res_live, inject])
            cand_g = jnp.concatenate(
                [res_g, jnp.arange(w_total, dtype=jnp.int32)]
            )
            cand_p = jnp.concatenate([res_p, query_of_walker])
            order = jnp.argsort(~cand_v)       # stable: valid lanes first
            sel_v = jnp.take(cand_v, order)[:recv]
            sel_g = jnp.take(cand_g, order)[:recv]
            sel_p = jnp.take(cand_p, order)[:recv]
            d0 = (jnp.sum(cand_v) - jnp.sum(sel_v)).astype(jnp.int32)

            # ---- phase A: pin -> board, fused hop on the local p2b slice
            # (ONE pallas_call for the whole routed buffer, per shard)
            r1 = jnp.take(rb[:, 2], sel_g)
            b_pick, ok1 = ops.walk_hop(
                sel_p, sel_v, r1, p2b_off, p2b_tgt, pin_lo,
                use_kernel=use_kernel, gather_mode=cfg.gather_mode,
            )
            qpin = jnp.take(query_of_walker, sel_g)
            home = jnp.take(home_of_walker, sel_g)
            # dead-end pins force a restart: the walker routes home
            # carrying its query pin (flag 0 skips hop 2 and counting)
            dest1 = jnp.where(sel_v, jnp.where(ok1, b_pick // bps, home),
                              n_shards)
            pay1 = jnp.where(ok1, b_pick, qpin)
            if faulty:
                # walkers bound for a dead shard die in flight (the drop
                # sentinel keeps them out of the fabric); rebirth-at-home
                # on their next restart draw, like capacity drops
                tgt_dead1 = (dest1 < n_shards) & ~jnp.take(
                    alive_vec, jnp.minimum(dest1, n_shards - 1)
                )
                killed = killed + jnp.sum(tgt_dead1).astype(jnp.int32)
                dest1 = jnp.where(tgt_dead1, n_shards, dest1)
            v1, (g1, p1, f1), d1, o1 = _route(
                axis, n_shards, cap, dest1,
                (sel_g, pay1, ok1.astype(jnp.int32)),
            )

            # ---- phase B: board -> pin on the local b2p slice; board
            # visits count HERE, on the board's owner, gated by the full
            # step succeeding (the unsharded engine's bev validity)
            live1 = v1 & (f1 == 1)
            r2 = jnp.take(rb[:, 3], g1)
            pin_pick, ok2 = ops.walk_hop(
                p1, live1, r2, b2p_off, b2p_tgt, board_lo,
                use_kernel=use_kernel, gather_mode=cfg.gather_mode,
            )
            qpin1 = jnp.take(query_of_walker, g1)
            slot1 = jnp.take(slot_of_walker, g1)
            qid1 = jnp.take(qid_of_walker, g1)
            if cfg.count_boards:
                sev_b = jnp.where(ok2, slot1, slot_sentinel)
                qev_b = jnp.where(ok2, qid1, query_sentinel)
                bev = jnp.where(ok2, p1 - board_lo, 0)
                bcounts = counter_lib.accumulate_packed_events(
                    bcounts, sev_b, bev, n_slots, bps, count_engine,
                    query_events=qev_b, n_queries=n_queries,
                )
            # dead-end boards and in-flight restarts continue at the query
            nxt = jnp.where(ok2, pin_pick, qpin1)
            dest2 = jnp.where(v1, nxt // pps, n_shards)
            if faulty:
                tgt_dead2 = (dest2 < n_shards) & ~jnp.take(
                    alive_vec, jnp.minimum(dest2, n_shards - 1)
                )
                killed = killed + jnp.sum(tgt_dead2).astype(jnp.int32)
                dest2 = jnp.where(tgt_dead2, n_shards, dest2)
            v2, (g2, p2, e2), d2, o2 = _route(
                axis, n_shards, cap, dest2,
                (g1, nxt, ok2.astype(jnp.int32)),
            )

            # ---- arrival: wide (query, slot, local_pin) events into the
            # owned dense bins + the incremental crossing tally — never a
            # reduction over the count buffer
            cnt_ok = v2 & (e2 == 1)
            sev = jnp.where(
                cnt_ok, jnp.take(slot_of_walker, g2), slot_sentinel
            )
            qev = jnp.where(
                cnt_ok, jnp.take(qid_of_walker, g2), query_sentinel
            )
            pev = jnp.where(cnt_ok, p2 - pin_lo, 0)
            counts, high = counter_lib.accumulate_packed_events_with_high(
                counts, high, sev, pev, n_slots, pps, cfg.n_v, count_engine,
                query_events=qev, n_queries=n_queries,
            )
            occ = jnp.maximum(occ, jnp.maximum(o1, o2))
            out = (
                v2, g2, p2, counts, bcounts, high,
                dropped + d0 + d1 + d2, occ,
            )
            return out + (killed,) if faulty else out

        def chunk_body(it, state):
            if faulty:
                (res_v, res_g, res_p, counts, bcounts, high,
                 steps_taken, row_active, dropped, occ, killed) = state
            else:
                (res_v, res_g, res_p, counts, bcounts, high,
                 steps_taken, row_active, dropped, occ) = state
            step_base = it * cfg.chunk_steps
            # replicated whole-batch counter RNG: identical arithmetic to
            # _walk_chunk_batched, so walker q*w+i draws its unsharded bits
            rbits_q = jax.vmap(
                lambda k: walk_lib._chunk_rbits(
                    k, step_base, cfg.chunk_steps, w
                )
            )(ks)
            rbits = jnp.moveaxis(rbits_q, 0, 1).reshape(
                cfg.chunk_steps, w_total, 4
            )
            first0 = it == 0
            sstate = (res_v, res_g, res_p, counts, bcounts, high,
                      dropped, occ)
            if faulty:
                sstate = sstate + (killed,)
            if unroll:
                for s in range(cfg.chunk_steps):
                    sstate = superstep(
                        sstate, rbits[s], row_active, first0 & (s == 0),
                        (step_base + s) if faulty else None,
                    )
            else:
                sstate = jax.lax.fori_loop(
                    0, cfg.chunk_steps,
                    lambda s, st: superstep(
                        st, rbits[s], row_active, first0 & (s == 0),
                        (step_base + s) if faulty else None,
                    ),
                    sstate,
                )
            if faulty:
                (res_v, res_g, res_p, counts, bcounts, high,
                 dropped, occ, killed) = sstate
            else:
                (res_v, res_g, res_p, counts, bcounts, high,
                 dropped, occ) = sstate
            steps_taken = steps_taken + walkers_per_slot * row_active.astype(
                jnp.int32
            ) * cfg.chunk_steps
            # the chunk-boundary fold: psum of the carried per-shard
            # tallies IS the global Algorithm 3 statistic (ownership
            # partitions the bins, crossings sum)
            if faulty:
                # a dead shard's bins die with it, so its crossing tally
                # leaves the early-stop statistic the moment it does —
                # the statistic always describes HARVESTABLE counts
                alive_h = dead_self > (step_base + cfg.chunk_steps - 1)
                g_high = jax.lax.psum(
                    jnp.where(alive_h, high, 0), axis
                )
            else:
                g_high = jax.lax.psum(high, axis)
            row_active = (
                valid_row & (steps_taken < n_q_row) & (g_high <= cfg.n_p)
            )
            out = (res_v, res_g, res_p, counts, bcounts, high,
                   steps_taken, row_active, dropped, occ)
            return out + (killed,) if faulty else out

        state = (
            jnp.zeros((recv,), jnp.bool_),
            jnp.zeros((recv,), jnp.int32),
            jnp.zeros((recv,), jnp.int32),
            jnp.zeros((n_rows * pps,), jnp.int32),
            jnp.zeros((n_rows * bps,), jnp.int32)
            if cfg.count_boards else None,
            jnp.zeros((n_rows,), jnp.int32),
            jnp.zeros((n_rows,), jnp.int32),
            valid_row,
            jnp.asarray(0, jnp.int32),
            jnp.asarray(0, jnp.int32),
        )
        if faulty:
            state = state + (jnp.asarray(0, jnp.int32),)   # killed tally
        if unroll:
            # cost-model mode: loop-free, every chunk runs (stopped rows
            # are frozen by masking, so the math is unchanged)
            for it in range(cfg.max_chunks()):
                state = chunk_body(jnp.asarray(it, jnp.int32), state)
            n_chunks = jnp.asarray(cfg.max_chunks(), jnp.int32)
        else:
            def cond(st_it):
                st, it = st_it
                return jnp.any(st[7]) & (it < cfg.max_chunks())

            state, n_chunks = jax.lax.while_loop(
                cond,
                lambda st_it: (
                    chunk_body(st_it[1], st_it[0]), st_it[1] + 1
                ),
                (state, jnp.asarray(0, jnp.int32)),
            )
        if faulty:
            (_, _, _, counts, bcounts, high,
             steps_taken, _, dropped, occ, killed) = state
            # harvest liveness: a shard that died before the walk ended
            # harvests NOTHING (its HBM left with it) — zero its counts,
            # board counts, and crossing tally BEFORE the query-pin
            # debit, so the merge renormalizes over survivors; a shard
            # whose death superstep the walk never reached was healthy
            # the whole time and harvests normally
            supersteps_run = n_chunks * cfg.chunk_steps
            keep = (dead_self >= supersteps_run).astype(jnp.int32)
            counts = counts * keep
            if cfg.count_boards:
                bcounts = bcounts * keep
            high = high * keep
        else:
            (_, _, _, counts, bcounts, high,
             steps_taken, _, dropped, occ) = state

        # ---- query-pin debit, mirroring the unsharded engine bit-for-bit
        # (position-only ownership: invalid slots hit all-zero bins, the
        # same no-op as the unsharded unconditional `.set(0)`)
        c3 = counts.reshape(n_queries, n_slots, pps)
        own_q = (qp >= pin_lo) & (qp < pin_lo + pps)
        lq = jnp.where(own_q, qp - pin_lo, 0)
        b_i = jnp.arange(n_queries)[:, None]
        s_i = jnp.arange(n_slots)[None, :]
        vals = c3[b_i, s_i, lq]
        q_reach = (own_q & (vals >= cfg.n_v)).astype(jnp.int32)
        c3 = c3.at[b_i, s_i, lq].set(jnp.where(own_q, 0, vals))
        q_reached = jax.lax.psum(q_reach, axis)
        g_high = jax.lax.psum(high, axis).reshape(n_queries, n_slots)
        n_high = g_high - q_reached
        dropped_total = jax.lax.psum(dropped, axis)
        occ_max = jax.lax.pmax(occ, axis)
        out = (
            c3.reshape(-1)[None],
            bcounts[None] if cfg.count_boards else None,
            steps_taken.reshape(n_queries, n_slots),
            n_high,
            dropped_total,
            occ_max,
        )
        if faulty:
            out = out + (jax.lax.psum(killed, axis),)
        return out

    shd = P(axis, None)
    rep = P()
    fn = shard_map(
        local_walk,
        mesh=mesh,
        in_specs=(shd, shd, shd, shd, rep, rep, rep, rep)
        + ((rep,) if faulty else ()),
        out_specs=(
            shd, shd if cfg.count_boards else None, rep, rep, rep, rep
        ) + ((rep,) if faulty else ()),
        check_rep=False,
    )
    args = (
        graph.p2b_offsets, graph.p2b_targets,
        graph.b2p_offsets, graph.b2p_targets,
        safe_q, jnp.where(valid_q, query_weights, 0.0),
        valid_q, keys,
    )
    if faulty:
        counts, bcounts, steps_taken, n_high, dropped, occ, killed = fn(
            *args, shard_dead_at
        )
    else:
        counts, bcounts, steps_taken, n_high, dropped, occ = fn(*args)
        killed = None
    return ShardedBatchedWalkResult(
        counts=counts,
        board_counts=bcounts,
        steps_taken=steps_taken,
        n_high=n_high,
        dropped=dropped,
        max_occupancy=occ,
        killed=killed,
    )


def _hierarchical_topk(
    counts: Array,      # (S, B * n_slots * pps) shard-stacked counts
    n_shards: int,
    n_queries: int,
    n_slots: int,
    pps: int,
    k: int,
) -> Tuple[Array, Array]:
    """Exact global boosted top-k from shard-stacked counts.

    Eq. 3's boost is per-pin, so per-shard boost + top-k followed by a
    global re-top-k over ``S * k`` candidates is EXACT (never misses a
    global top-k pin: each shard forwards at least its own k best).
    """
    c = counts.reshape(n_shards, n_queries, n_slots, pps)

    def shard_topk(cs):  # (B, n_slots, pps) one shard's owned counts
        boosted = jax.vmap(counter_lib.boost_combine)(cs)       # (B, pps)
        return jax.vmap(lambda b: counter_lib.topk_dense(b, k))(boosted)

    scores, idx = jax.vmap(shard_topk)(c)                       # (S, B, k)
    pins = idx.astype(jnp.int32) + (
        jnp.arange(n_shards, dtype=jnp.int32) * pps
    )[:, None, None]
    flat_s = jnp.moveaxis(scores, 0, 1).reshape(n_queries, n_shards * k)
    flat_p = jnp.moveaxis(pins, 0, 1).reshape(n_queries, n_shards * k)
    gs, gi = jax.vmap(lambda v: jax.lax.top_k(v, k))(flat_s)
    gp = jnp.take_along_axis(flat_p, gi, axis=1)
    return gs, gp


def recommend_sharded_batched(
    graph: ShardedGraph,
    query_pins: Array,      # (B, n_slots)
    query_weights: Array,   # (B, n_slots)
    keys: Array,            # (B,) per-query PRNG keys
    cfg: walk_lib.WalkConfig,
    mesh: Mesh,
    axis: str = "model",
    *,
    slack: float = 2.0,
    shard_dead_at: Optional[Array] = None,
) -> Tuple[Array, Array, Array, Array, Array]:
    """Batch-native sharded serving: walk + hierarchical boosted top-k.

    Returns ``(scores (B, top_k), ids (B, top_k), steps_taken (B,
    n_slots), n_high (B, n_slots), dropped ())`` — the sharded twin of
    ``walk.recommend_with_stats_batched`` plus the routing-drop telemetry
    ``serve_batch(with_stats=True)`` surfaces.  ``shard_dead_at`` is the
    degraded-mode liveness schedule (``pixie_walk_sharded_batched``);
    the hierarchical top-k needs no change — a dead shard's owned counts
    arrive zeroed, so its candidates simply never win a slot.
    """
    res = pixie_walk_sharded_batched(
        graph, query_pins, query_weights, keys, cfg, mesh, axis,
        slack=slack, shard_dead_at=shard_dead_at,
    )
    n_queries, n_slots = query_pins.shape
    scores, ids = _hierarchical_topk(
        res.counts, mesh.shape[axis], n_queries, n_slots,
        graph.pins_per_shard, cfg.top_k,
    )
    return scores, ids, res.steps_taken, res.n_high, res.dropped


# ---------------------------------------------------------------------------
# Single-query convenience wrapper (launch cells, examples)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardedWalkConfig:
    """Single-query sharded walk knobs (``pixie_walk_sharded``).

    A thin recipe over the batched engine: ``n_supersteps`` global hops
    with ``n_shards * walkers_per_shard`` walkers, no early stopping
    (Algorithm 1 semantics, like the original sharded path).  ``slack``
    scales routing capacity (``route_capacity``); ``backend`` /
    ``gather_mode`` select the per-shard hop engine; ``unroll`` is the
    loop-free cost-model mode (launch/dryrun.py).
    """

    n_supersteps: int = 64
    walkers_per_shard: int = 1024
    alpha: float = 0.5
    slack: float = 2.0
    top_k: int = 100
    unroll: bool = False     # cost-model mode (see launch/dryrun.py)
    backend: str = "xla"
    gather_mode: str = "scalar"

    def capacity(self, n_shards: int) -> int:
        return route_capacity(
            n_shards, n_shards * self.walkers_per_shard, self.slack
        )


class ShardedWalkResult(NamedTuple):
    top_scores: Array    # (top_k,) f32 boosted scores
    top_pins: Array      # (top_k,) int32 global pin ids
    dropped: Array       # () int32 walkers dropped by routing overflow


def _wrapper_walk_config(
    cfg: ShardedWalkConfig, n_shards: int
) -> walk_lib.WalkConfig:
    """Map the single-query recipe onto the batched engine's config."""
    w_total = n_shards * cfg.walkers_per_shard
    n_ss = cfg.n_supersteps
    chunk = 8 if n_ss % 8 == 0 else (4 if n_ss % 4 == 0 else 1)
    return walk_lib.WalkConfig(
        n_steps=w_total * n_ss,
        alpha=cfg.alpha,
        n_walkers=w_total,
        chunk_steps=chunk,
        bias_beta=0.0,
        top_k=cfg.top_k,
        count_boards=False,
        backend=cfg.backend,
        gather_mode=cfg.gather_mode,
    ).without_early_stop()


def pixie_walk_sharded(
    graph: ShardedGraph,
    query_pins: Array,      # (n_slots,) int32 global pin ids (-1 pad)
    query_weights: Array,   # (n_slots,) f32
    key: Array,
    cfg: ShardedWalkConfig,
    mesh: Mesh,
    axis: str = "model",
) -> ShardedWalkResult:
    """Multi-query Pixie walk on a node-range-sharded graph (batch of 1).

    Runs the pod-sharded batched fused engine
    (``pixie_walk_sharded_batched``) for one query and finishes with the
    exact hierarchical boosted top-k.
    """
    wcfg = _wrapper_walk_config(cfg, mesh.shape[axis])
    keys = jax.random.split(key, 1)
    res = pixie_walk_sharded_batched(
        graph, query_pins[None], query_weights[None], keys, wcfg, mesh,
        axis, slack=cfg.slack, unroll=cfg.unroll,
    )
    n_slots = query_pins.shape[0]
    scores, pins = _hierarchical_topk(
        res.counts, mesh.shape[axis], 1, n_slots, graph.pins_per_shard,
        cfg.top_k,
    )
    return ShardedWalkResult(
        top_scores=scores[0], top_pins=pins[0], dropped=res.dropped
    )
