"""Graph pruning (paper §3.2): board-entropy pruning + degree pruning.

Runs host-side in numpy — this is the paper's offline "graph compiler" stage
(Hadoop pipeline + single big-RAM compiler box), not the serving path.

1. **Board entropy pruning** — compute each board's topic distribution from
   the topic vectors of its pins, score by entropy, drop the most-diverse
   fraction of boards with all their edges.
2. **Degree pruning** — for every pin with degree d, keep only the
   ceil(d**delta) edges whose board topic vectors have the highest cosine
   similarity to the pin's topic vector (Eq.: updated degree |E(p)|^delta).

The paper reports delta = 0.91 peaking link-prediction F1 at +58% with ~20%
of edges retained; benchmarks/bench_fig4_pruning.py sweeps delta on the
synthetic graph to reproduce the shape of Figure 4.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.graph import PinBoardGraph, build_graph, edge_list


@dataclasses.dataclass(frozen=True)
class PruneConfig:
    entropy_board_frac: float = 0.10   # drop this fraction of highest-entropy boards
    delta: float = 0.91                # degree pruning factor (Fig. 4 peak)
    min_keep: int = 2                  # never prune a pin below this degree


def board_entropy(
    pins: np.ndarray,
    boards: np.ndarray,
    pin_topics: np.ndarray,
    n_boards: int,
    eps: float = 1e-12,
) -> np.ndarray:
    """Entropy of each board's aggregated topic distribution (§3.2).

    The paper aggregates topic vectors of the latest pins of a board; the
    synthetic substrate has no timestamps, so all member pins are used.
    """
    nt = pin_topics.shape[1]
    sums = np.zeros((n_boards, nt), dtype=np.float64)
    np.add.at(sums, boards, pin_topics[pins].astype(np.float64))
    counts = np.bincount(boards, minlength=n_boards).astype(np.float64)
    dist = sums / np.maximum(counts, 1.0)[:, None]
    dist = dist / np.maximum(dist.sum(axis=1, keepdims=True), eps)
    ent = -np.sum(dist * np.log(np.maximum(dist, eps)), axis=1)
    ent[counts == 0] = 0.0
    return ent.astype(np.float32)


def cosine_sim(a: np.ndarray, b: np.ndarray, eps: float = 1e-12) -> np.ndarray:
    na = np.linalg.norm(a, axis=-1)
    nb = np.linalg.norm(b, axis=-1)
    return np.sum(a * b, axis=-1) / np.maximum(na * nb, eps)


def prune_graph(
    graph: PinBoardGraph,
    pin_topics: np.ndarray,
    board_topics: np.ndarray | None,
    cfg: PruneConfig,
    board_lang: np.ndarray | None = None,
    pin_lang: np.ndarray | None = None,
    n_langs: int = 0,
) -> Tuple[PinBoardGraph, dict]:
    """Apply both pruning stages; returns (pruned graph, stats)."""
    pins, boards = edge_list(graph)
    n_boards = graph.n_boards
    stats: dict = {"edges_before": int(pins.shape[0])}

    # -- stage 1: entropy-based board removal --------------------------------
    ent = board_entropy(pins, boards, pin_topics, n_boards)
    n_drop = int(cfg.entropy_board_frac * n_boards)
    if n_drop > 0:
        drop = np.argsort(-ent)[:n_drop]
        keep_board = np.ones(n_boards, dtype=bool)
        keep_board[drop] = False
        mask = keep_board[boards]
        pins, boards = pins[mask], boards[mask]
        stats["boards_dropped"] = int(n_drop)
    stats["edges_after_entropy"] = int(pins.shape[0])

    # board topic dists recomputed on the cleaned edge set
    if board_topics is None:
        nt = pin_topics.shape[1]
        sums = np.zeros((n_boards, nt), dtype=np.float64)
        np.add.at(sums, boards, pin_topics[pins].astype(np.float64))
        cnt = np.maximum(np.bincount(boards, minlength=n_boards), 1)
        board_topics = (sums / cnt[:, None]).astype(np.float32)

    # -- stage 2: degree pruning with cosine similarity ------------------------
    sim = cosine_sim(pin_topics[pins], board_topics[boards])
    # sort edges by (pin, -sim); keep the first ceil(deg^delta) per pin
    order = np.lexsort((-sim, pins))
    pins_s, boards_s = pins[order], boards[order]
    deg = np.bincount(pins_s, minlength=graph.n_pins)
    target = np.maximum(
        np.ceil(deg.astype(np.float64) ** cfg.delta).astype(np.int64),
        np.minimum(deg, cfg.min_keep),
    )
    # rank of each edge within its pin's sorted slice
    starts = np.zeros(graph.n_pins + 1, dtype=np.int64)
    np.cumsum(deg, out=starts[1:])
    rank = np.arange(pins_s.shape[0], dtype=np.int64) - starts[pins_s]
    keep = rank < target[pins_s]
    pins_f, boards_f = pins_s[keep], boards_s[keep]
    stats["edges_after"] = int(pins_f.shape[0])
    stats["edge_keep_frac"] = stats["edges_after"] / max(stats["edges_before"], 1)

    ef = board_lang[boards_f] if board_lang is not None else None
    ef2 = pin_lang[pins_f] if pin_lang is not None else None
    pruned = build_graph(
        pins_f,
        boards_f,
        n_pins=graph.n_pins,
        n_boards=n_boards,
        edge_feat=ef,
        n_feats=n_langs,
        edge_feat_b2p=ef2,
    )
    stats["bytes_before"] = graph.nbytes()
    stats["bytes_after"] = pruned.nbytes()
    return pruned, stats
