"""Baselines the paper compares against (Table 1 and Table 3).

* Content-based nearest neighbour over **textual** embeddings (paper: word2vec
  annotations + cosine distance) — here an embedding derived from the planted
  topic vectors plus noise, cosine distance.
* Content-based nearest neighbour over **visual** embeddings (paper: VGG-16
  fc6 + hamming distance over binarized codes) — here a second noisy view,
  binarized, hamming distance.
* Content-based **combined** — rank-sum fusion of the two.
* ``BasicRandomWalk`` (Algorithm 1) lives in core/walk.py and is the Table 3
  baseline.

These are real rankers (they score all pins per query), not stubs; the
benchmark reproduces Table 1's ordering: combined > single-modality content,
and Pixie >> content.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def make_content_embeddings(
    pin_topics: np.ndarray,
    dim: int = 64,
    noise: float = 0.25,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Project topic vectors into two noisy "modalities" (textual, visual)."""
    rng = np.random.default_rng(seed)
    nt = pin_topics.shape[1]
    proj_t = rng.normal(size=(nt, dim)).astype(np.float32)
    proj_v = rng.normal(size=(nt, dim)).astype(np.float32)
    text = pin_topics @ proj_t + noise * rng.normal(
        size=(pin_topics.shape[0], dim)
    ).astype(np.float32)
    vis = pin_topics @ proj_v + noise * rng.normal(
        size=(pin_topics.shape[0], dim)
    ).astype(np.float32)
    return text, vis


@jax.jit
def cosine_rank_scores(embeddings: Array, query: Array) -> Array:
    """Scores of every pin for a query pin under cosine similarity."""
    e = embeddings / jnp.maximum(
        jnp.linalg.norm(embeddings, axis=1, keepdims=True), 1e-9
    )
    q = e[query]
    return e @ q


@jax.jit
def hamming_rank_scores(embeddings: Array, query: Array) -> Array:
    """Binarize at 0 then score by negative hamming distance (visual path)."""
    bits = embeddings > 0.0
    q = bits[query]
    return -jnp.sum(bits != q[None, :], axis=1).astype(jnp.float32)


@jax.jit
def combined_rank_scores(text: Array, vis: Array, query: Array) -> Array:
    """Rank-sum fusion of textual-cosine and visual-hamming rankings."""
    st = cosine_rank_scores(text, query)
    sv = hamming_rank_scores(vis, query)

    def ranks(s):
        order = jnp.argsort(-s)
        r = jnp.zeros_like(order)
        return r.at[order].set(jnp.arange(s.shape[0]))

    return -(ranks(st) + ranks(sv)).astype(jnp.float32)


def hit_rate_at_k(scores: np.ndarray, target: int, ks=(10, 100, 1000)) -> dict:
    """Fraction helper: was `target` ranked in the top-k (per query)."""
    order = np.argsort(-scores)
    pos = int(np.where(order == target)[0][0])
    return {k: float(pos < k) for k in ks}
