"""Sequential numpy oracle of Algorithms 1-3, faithful to the paper's text.

Used by tests to validate the vectorized JAX engine *statistically*: on a
small graph the normalized visit distributions of the two implementations
must be close (the walkers are i.i.d., so the vectorized walk is the same
Markov chain run W times).  This file deliberately mirrors the paper's
pseudocode line-by-line, including the hash-table-style counter and the
per-step early-stopping check.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.graph import PinBoardGraph


class _HostGraph:
    """Numpy view of the CSR arrays for fast sequential access."""

    def __init__(self, g: PinBoardGraph):
        self.p2b_off = np.asarray(g.p2b.offsets)
        self.p2b_tgt = np.asarray(g.p2b.targets)
        self.b2p_off = np.asarray(g.b2p.offsets)
        self.b2p_tgt = np.asarray(g.b2p.targets)
        self.p2b_fb = (
            None if g.p2b.feat_bounds is None else np.asarray(g.p2b.feat_bounds)
        )
        self.b2p_fb = (
            None if g.b2p.feat_bounds is None else np.asarray(g.b2p.feat_bounds)
        )
        self.n_pins = g.n_pins
        self.max_pin_degree = g.max_pin_degree

    def pin_degree(self, p: int) -> int:
        return int(self.p2b_off[p + 1] - self.p2b_off[p])

    def sample_board(self, rng, p: int, feat: Optional[int], beta: float) -> int:
        lo, hi = int(self.p2b_off[p]), int(self.p2b_off[p + 1])
        if hi == lo:
            return -1
        if (
            feat is not None
            and self.p2b_fb is not None
            and rng.random() < beta
        ):
            flo = lo + int(self.p2b_fb[p, feat])
            fhi = lo + int(self.p2b_fb[p, feat + 1])
            if fhi > flo:
                return int(self.p2b_tgt[rng.integers(flo, fhi)])
        return int(self.p2b_tgt[rng.integers(lo, hi)])

    def sample_pin(self, rng, b_local: int, feat: Optional[int], beta: float) -> int:
        lo, hi = int(self.b2p_off[b_local]), int(self.b2p_off[b_local + 1])
        if hi == lo:
            return -1
        if (
            feat is not None
            and self.b2p_fb is not None
            and rng.random() < beta
        ):
            flo = lo + int(self.b2p_fb[b_local, feat])
            fhi = lo + int(self.b2p_fb[b_local, feat + 1])
            if fhi > flo:
                return int(self.b2p_tgt[rng.integers(flo, fhi)])
        return int(self.b2p_tgt[rng.integers(lo, hi)])


def sample_walk_length(rng, alpha: float, cap: int = 10_000) -> int:
    """Geometric(alpha) segment length — E[len] = 1/alpha."""
    return min(int(rng.geometric(alpha)), cap)


def basic_random_walk_ref(
    graph: PinBoardGraph, q: int, alpha: float, n_steps: int, seed: int = 0
) -> np.ndarray:
    """Algorithm 1, verbatim."""
    g = _HostGraph(graph)
    rng = np.random.default_rng(seed)
    visits = np.zeros(g.n_pins, dtype=np.int64)
    tot_steps = 0
    while tot_steps < n_steps:
        curr = q
        curr_steps = sample_walk_length(rng, alpha)
        for _ in range(curr_steps):
            b = g.sample_board(rng, curr, None, 0.0)
            if b < 0:
                break
            p = g.sample_pin(rng, b - g.n_pins, None, 0.0)
            if p < 0:
                break
            curr = p
            visits[curr] += 1
        tot_steps += curr_steps
    return visits


def pixie_random_walk_ref(
    graph: PinBoardGraph,
    q: int,
    user_feat: Optional[int],
    alpha: float,
    n_steps: int,
    n_p: int,
    n_v: int,
    beta: float = 0.9,
    seed: int = 0,
) -> np.ndarray:
    """Algorithm 2, verbatim (per-step early-stopping check)."""
    g = _HostGraph(graph)
    rng = np.random.default_rng(seed)
    visits = np.zeros(g.n_pins, dtype=np.int64)
    tot_steps = 0
    n_high = 0
    while True:
        curr = q
        curr_steps = sample_walk_length(rng, alpha)
        for _ in range(curr_steps):
            b = g.sample_board(rng, curr, user_feat, beta)
            if b < 0:
                break
            p = g.sample_pin(rng, b - g.n_pins, user_feat, beta)
            if p < 0:
                break
            curr = p
            visits[curr] += 1
            if visits[curr] == n_v:
                n_high += 1
        tot_steps += curr_steps
        if tot_steps >= n_steps or n_high > n_p:
            break
    return visits


def scaling_factor_ref(deg: int, max_deg: int) -> float:
    """Eq. 1."""
    if deg <= 0:
        return 0.0
    return deg * (max(max_deg, 1) - np.log(max(deg, 1)))


def pixie_random_walk_multiple_ref(
    graph: PinBoardGraph,
    query: Dict[int, float],
    user_feat: Optional[int],
    alpha: float,
    n_steps: int,
    n_p: int,
    n_v: int,
    beta: float = 0.9,
    seed: int = 0,
) -> np.ndarray:
    """Algorithm 3: per-query budgets (Eq. 2) + booster (Eq. 3)."""
    g = _HostGraph(graph)
    pins = list(query.keys())
    w = np.array([query[p] for p in pins], dtype=np.float64)
    s = np.array(
        [scaling_factor_ref(g.pin_degree(p), g.max_pin_degree) for p in pins]
    )
    ws = w * s
    denom = max(ws.sum(), 1e-9)
    boosted = np.zeros(g.n_pins, dtype=np.float64)
    for i, p in enumerate(pins):
        n_q = int(np.floor(ws[i] / denom * n_steps))
        if n_q <= 0:
            continue
        v = pixie_random_walk_ref(
            graph, p, user_feat, alpha, n_q, n_p, n_v, beta, seed=seed + i
        )
        boosted += np.sqrt(v.astype(np.float64))
    return boosted**2
