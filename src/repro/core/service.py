"""Query construction and response shaping (paper §5 use cases).

* **Homefeed** (§5.1): every user action creates/updates a query — each acted
  pin gets an initial weight by action type, decayed with half-life lambda.
* **Related pins** (§5.2): single-pin queries with a *shorter* walk (higher
  alpha) for narrow recommendations.
* **Board recs** (§5.3): query = last pins of a board; board counting on.

Queries are padded to a fixed slot count so batched serving stays SPMD.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import walk as walk_lib

ACTION_WEIGHTS: Dict[str, float] = {
    "save": 1.0,
    "click": 0.6,
    "like": 0.5,
    "view": 0.2,
}


@dataclasses.dataclass(frozen=True)
class UserAction:
    pin: int
    action: str
    age_hours: float


def build_query(
    actions: Sequence[UserAction],
    n_slots: int,
    half_life_hours: float = 24.0,
    default_weight: float | None = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Collapse a user's action history into (query_pins, weights).

    Weight = action weight * 0.5 ** (age / half_life); repeated pins sum.
    The top-``n_slots`` pins by weight are kept, rest padded with (-1, 0).
    Weight ties break by pin id, so for a given set of per-pin weights the
    truncation never depends on Python dict ordering.  (A pin's weight is
    a float sum over its actions, so *reordering one pin's actions* can
    still move it by an ulp — the tie-break fixes the data-structure
    nondeterminism, not float associativity.)

    Unrecognized action types raise — a typo'd action silently weighted
    0.1 skews every downstream walk budget; pass ``default_weight`` to
    opt into a catch-all weight instead.
    """
    acc: Dict[int, float] = {}
    for a in actions:
        base = ACTION_WEIGHTS.get(a.action, default_weight)
        if base is None:
            raise ValueError(
                f"unknown action type {a.action!r}; known: "
                f"{sorted(ACTION_WEIGHTS)} (pass default_weight to accept "
                "unrecognized actions)"
            )
        w = base * 0.5 ** (a.age_hours / half_life_hours)
        acc[a.pin] = acc.get(a.pin, 0.0) + w
    # weight descending, pin id ascending on ties: the truncation below is
    # deterministic across Python dict insertion orders
    items = sorted(acc.items(), key=lambda kv: (-kv[1], kv[0]))[:n_slots]
    pins = np.full((n_slots,), -1, dtype=np.int32)
    weights = np.zeros((n_slots,), dtype=np.float32)
    for i, (p, w) in enumerate(items):
        pins[i] = p
        weights[i] = w
    return pins, weights


def homefeed_config(base: walk_lib.WalkConfig) -> walk_lib.WalkConfig:
    """Broad, exploratory walk: longer segments (§5.1 / Explore)."""
    return dataclasses.replace(base, alpha=min(base.alpha, 0.3))


def related_pins_config(base: walk_lib.WalkConfig) -> walk_lib.WalkConfig:
    """Narrow walk — the §5.2 A/B result: shorter walks lift engagement."""
    return dataclasses.replace(base, alpha=max(base.alpha, 0.65))


def board_rec_config(base: walk_lib.WalkConfig) -> walk_lib.WalkConfig:
    return dataclasses.replace(base, count_boards=True)


def batch_queries(
    queries: List[Tuple[np.ndarray, np.ndarray]],
    user_feats: Sequence[int],
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Stack padded queries for batched serving.

    Validates the batch BEFORE stacking so a ragged or mistyped request
    fails with a message naming the offending query, not an opaque
    ``np.stack`` shape error three layers down: every query must have the
    same ``n_slots`` (pins and weights alike) and float weights.
    """
    if not queries:
        raise ValueError("batch_queries needs at least one query")
    if len(user_feats) != len(queries):
        raise ValueError(
            f"{len(queries)} queries but {len(user_feats)} user_feats; "
            "one personalization feature per query required"
        )
    n_slots = np.asarray(queries[0][0]).shape
    for i, (q_pins, q_weights) in enumerate(queries):
        p = np.asarray(q_pins)
        w = np.asarray(q_weights)
        if p.shape != n_slots or w.shape != n_slots:
            raise ValueError(
                f"query {i} is ragged: pins shape {p.shape}, weights shape "
                f"{w.shape}, but the batch's slot shape is {n_slots}; pad "
                "every query to the same n_slots (service.build_query does)"
            )
        if not np.issubdtype(w.dtype, np.floating):
            raise ValueError(
                f"query {i} weights have dtype {w.dtype}; weights must be "
                "float (integer weights silently skew Eq. 2 step budgets)"
            )
    pins = jnp.asarray(np.stack([np.asarray(q[0]) for q in queries]))
    weights = jnp.asarray(np.stack([np.asarray(q[1]) for q in queries]))
    feats = jnp.asarray(np.asarray(user_feats, dtype=np.int32))
    return pins, weights, feats


def serve_batch(
    graph,
    pins: jnp.ndarray,      # (batch, n_slots)
    weights: jnp.ndarray,   # (batch, n_slots)
    user_feats: jnp.ndarray,  # (batch,)
    key: jax.Array,
    cfg: walk_lib.WalkConfig,
    backend: str | None = None,
    with_stats: bool = False,
    mesh=None,
    axis: str = "model",
    slack: float = 2.0,
    rank=None,
    scenario: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, ...]:
    """One SPMD serving step: Pixie over a whole query batch.

    This is the TPU replacement for the paper's worker-thread-per-query
    model: a batch of queries is one program.  ``backend`` overrides
    ``cfg.backend`` ("xla" | "pallas") for the whole batch, so a serving
    fleet can flip the hot path to the fused Pallas walk engine without
    rebuilding its configs; both engines return bit-identical
    recommendations for the same key (core/walk.py) — including the
    early-stop observables, since both maintain the same incremental
    ``n_high`` tally.

    ``backend="pallas"`` routes through the BATCH-NATIVE engine
    (``walk_lib.recommend_with_stats_batched``): the whole batch's walkers
    run in one fused ``pallas_call`` per superstep chunk and counting is
    one query-major call per chunk, instead of a batch-sized grid
    replication per query under vmap.  ``backend="xla"`` keeps the vmapped
    per-query path — the oracle twin the batched engine is verified
    bit-identical against (tests/test_batchfuse.py).  The batched engine's
    query-major bins must fit int32 indexing
    (``walk_lib.batched_engine_fits``); a (graph, batch) shape past that
    envelope falls back to the vmapped formulation — same results, the
    per-query bins may still fit — rather than erroring where the old
    path served.

    ``key`` is either a scalar PRNG key — split into one stream per query,
    the original behavior — or a ``(batch,)`` typed key array used
    directly as the per-query streams.  Per-query keys are what makes a
    query's result independent of BATCH COMPOSITION: the bucketed server
    (serving/server.py) assigns each request its key at submit time
    (``fold_in`` of the request id), so deadline-aware batch formation can
    group requests however load dictates and still return bit-identical
    recommendations to the single-bucket flush oracle on the same
    requests.  (Padding a query into a wider ``n_slots`` shape is also
    bit-invariant: zero-weight slots get zero step budget and zero
    walkers, so bucket shape never changes a query's walk.)

    Returns ``(scores, ids)``; with ``with_stats=True`` returns
    ``(scores, ids, steps_taken, n_high)`` (each leading with the batch
    axis) so the fleet can monitor how much step budget Algorithm 3's
    early stopping saves per query shape.

    A ``distributed.ShardedGraph`` routes through the pod-sharded batched
    engine instead (``mesh`` required; ``axis`` names the shard axis,
    ``slack`` scales routing capacity): the same walk semantics with the
    graph node-range-sharded across the mesh, bit-identical to the
    unsharded engines whenever routing drops nothing.  ``with_stats=True``
    then returns ``(scores, ids, steps_taken, n_high, dropped)`` — the
    extra scalar is the routing-overflow drop count, the serving signal
    for raising ``slack`` (drops are bounded Monte Carlo slack, never
    silent).

    ``rank`` (a ``serving.ranker.RankRequest``) turns the step TWO-STAGE:
    retrieval runs with ``top_k`` overridden to ``rank.cfg.n_candidates``,
    then `serving.ranker.rank_candidates` re-scores the candidates with
    the per-request ``scenario`` head (``(batch,)`` int32 head indices;
    default head 0 for every query) — still one jitted program, still a
    constant ``pallas_call`` count independent of batch size.  Returned
    ``(scores, ids)`` are then the ranked ``(batch, final_k)`` results;
    ``with_stats=True`` keeps appending the stage-1 walk telemetry.
    Stage 2's float math is ONE shared program for both backends (the bag
    op's lowering is platform-defaulted, never backend-derived), so ranked
    serving inherits the walk's bit-parity contract end to end
    (`two_stage_backends_agree`).  Ranked serving over a ``ShardedGraph``
    raises: stage 2 gathers candidate neighborhoods from the full CSR,
    which a node-range shard doesn't hold — rank on an unsharded replica,
    or rank host-side from the sharded walk's ``(scores, ids)``.
    """
    if backend is not None and backend != cfg.backend:
        cfg = dataclasses.replace(cfg, backend=backend)
    if scenario is not None and rank is None:
        raise ValueError(
            "scenario= selects a ranker head and needs rank=; a bare "
            "retrieval step has no scenario axis"
        )
    if rank is not None and cfg.top_k != rank.cfg.n_candidates:
        cfg = dataclasses.replace(cfg, top_k=rank.cfg.n_candidates)
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key) and key.ndim == 1:
        if key.shape[0] != pins.shape[0]:
            raise ValueError(
                f"per-query key array has {key.shape[0]} keys for a batch "
                f"of {pins.shape[0]} queries; one key per query required"
            )
        keys = key
    else:
        keys = jax.random.split(key, pins.shape[0])

    from repro.core import distributed as dist_lib

    if isinstance(graph, dist_lib.ShardedGraph):
        if rank is not None:
            raise ValueError(
                "serve_batch(rank=...) over a ShardedGraph is not "
                "supported: stage 2 gathers candidate neighborhoods from "
                "the full CSR, which a node-range shard doesn't hold; rank "
                "on an unsharded replica or host-side from the sharded "
                "walk's (scores, ids)"
            )
        if mesh is None:
            raise ValueError(
                "serve_batch over a ShardedGraph needs the device mesh "
                "(pass mesh=...)"
            )
        scores, ids, steps, n_high, dropped = (
            dist_lib.recommend_sharded_batched(
                graph, pins, weights, keys, cfg, mesh, axis, slack=slack
            )
        )
        if with_stats:
            return scores, ids, steps, n_high, dropped
        return scores, ids

    if cfg.backend == "pallas" and walk_lib.batched_engine_fits(
        int(pins.shape[0]), int(pins.shape[1]), graph.n_pins,
        graph.n_boards, cfg.count_boards,
    ):
        scores, ids, steps, n_high = walk_lib.recommend_with_stats_batched(
            graph, pins, weights, user_feats, keys, cfg
        )
    else:

        def one(qp, qw, uf, k):
            return walk_lib.recommend_with_stats(graph, qp, qw, uf, k, cfg)

        scores, ids, steps, n_high = jax.vmap(one)(
            pins, weights, user_feats, keys
        )
    if rank is not None:
        from repro.serving import ranker as ranker_lib

        if scenario is None:
            scenario = jnp.zeros((pins.shape[0],), jnp.int32)
        scores, ids = ranker_lib.rank_candidates(
            rank.params, rank.cfg, graph, ids, scores, scenario
        )
    if with_stats:
        return scores, ids, steps, n_high
    return scores, ids
