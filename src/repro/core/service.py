"""Query construction and response shaping (paper §5 use cases).

* **Homefeed** (§5.1): every user action creates/updates a query — each acted
  pin gets an initial weight by action type, decayed with half-life lambda.
* **Related pins** (§5.2): single-pin queries with a *shorter* walk (higher
  alpha) for narrow recommendations.
* **Board recs** (§5.3): query = last pins of a board; board counting on.
* **Multi-interest users** (PinnerSage, PAPERS.md): a user's action history
  is clustered host-side into k interest clusters over pin topic vectors;
  each cluster is one weighted query lane with its own Eq. 2 step budget,
  all lanes of a user ride the batch axis of ONE
  ``walk.pixie_random_walk_batched`` call, and results merge back per user
  with ``walk.merge_interest_topk`` (Eq. 3 across clusters).

Queries are padded to a fixed slot count so batched serving stays SPMD.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import walk as walk_lib

ACTION_WEIGHTS: Dict[str, float] = {
    "save": 1.0,
    "click": 0.6,
    "like": 0.5,
    "view": 0.2,
}


@dataclasses.dataclass(frozen=True)
class UserAction:
    pin: int
    action: str
    age_hours: float


def _decayed_pin_weights(
    actions: Sequence[UserAction],
    half_life_hours: float,
    default_weight: float | None,
) -> Dict[int, float]:
    """Per-pin decayed action weights, summed in a CANONICAL order.

    Each pin's contributions are sorted ascending by value before the
    left-to-right float sum, so a pin's weight is a function of the
    MULTISET of its actions — reordering the action list can no longer
    move a weight by an ulp (regression-tested with a crafted history
    whose naive order-of-arrival sums round to different float32s).
    """
    contribs: Dict[int, List[float]] = {}
    for a in actions:
        base = ACTION_WEIGHTS.get(a.action, default_weight)
        if base is None:
            raise ValueError(
                f"unknown action type {a.action!r}; known: "
                f"{sorted(ACTION_WEIGHTS)} (pass default_weight to accept "
                "unrecognized actions)"
            )
        w = base * 0.5 ** (a.age_hours / half_life_hours)
        contribs.setdefault(a.pin, []).append(w)
    acc: Dict[int, float] = {}
    for pin, ws in contribs.items():
        total = 0.0
        for w in sorted(ws):
            total += w
        acc[pin] = total
    return acc


def build_query(
    actions: Sequence[UserAction],
    n_slots: int,
    half_life_hours: float = 24.0,
    default_weight: float | None = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Collapse a user's action history into (query_pins, weights).

    Weight = action weight * 0.5 ** (age / half_life); repeated pins sum.
    The top-``n_slots`` pins by weight are kept, rest padded with (-1, 0).
    Weight ties break by pin id, so for a given set of per-pin weights the
    truncation never depends on Python dict ordering, and each pin's float
    sum runs in a canonical (value-sorted) order so reordering the action
    list cannot move a weight by an ulp either — the query is a pure
    function of the action MULTISET.

    Unrecognized action types raise — a typo'd action silently weighted
    0.1 skews every downstream walk budget; pass ``default_weight`` to
    opt into a catch-all weight instead.
    """
    acc = _decayed_pin_weights(actions, half_life_hours, default_weight)
    # weight descending, pin id ascending on ties: the truncation below is
    # deterministic across Python dict insertion orders
    items = sorted(acc.items(), key=lambda kv: (-kv[1], kv[0]))[:n_slots]
    pins = np.full((n_slots,), -1, dtype=np.int32)
    weights = np.zeros((n_slots,), dtype=np.float32)
    for i, (p, w) in enumerate(items):
        pins[i] = p
        weights[i] = w
    return pins, weights


def homefeed_config(base: walk_lib.WalkConfig) -> walk_lib.WalkConfig:
    """Broad, exploratory walk: longer segments (§5.1 / Explore)."""
    return dataclasses.replace(base, alpha=min(base.alpha, 0.3))


def related_pins_config(base: walk_lib.WalkConfig) -> walk_lib.WalkConfig:
    """Narrow walk — the §5.2 A/B result: shorter walks lift engagement."""
    return dataclasses.replace(base, alpha=max(base.alpha, 0.65))


def board_rec_config(base: walk_lib.WalkConfig) -> walk_lib.WalkConfig:
    return dataclasses.replace(base, count_boards=True)


def batch_queries(
    queries: List[Tuple[np.ndarray, np.ndarray]],
    user_feats: Sequence[int],
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Stack padded queries for batched serving.

    Validates the batch BEFORE stacking so a ragged or mistyped request
    fails with a message naming the offending query, not an opaque
    ``np.stack`` shape error three layers down: every query must have the
    same ``n_slots`` (pins and weights alike) and float weights.
    """
    if not queries:
        raise ValueError("batch_queries needs at least one query")
    if len(user_feats) != len(queries):
        raise ValueError(
            f"{len(queries)} queries but {len(user_feats)} user_feats; "
            "one personalization feature per query required"
        )
    slot_shape = np.asarray(queries[0][0]).shape
    n_slots = slot_shape[0] if len(slot_shape) == 1 else slot_shape
    for i, (q_pins, q_weights) in enumerate(queries):
        p = np.asarray(q_pins)
        w = np.asarray(q_weights)
        if p.shape != slot_shape or w.shape != slot_shape:
            raise ValueError(
                f"query {i} is ragged: pins shape {p.shape}, weights shape "
                f"{w.shape}, but the batch has {n_slots} slots; pad "
                "every query to the same n_slots (service.build_query does)"
            )
        if not np.issubdtype(w.dtype, np.floating):
            raise ValueError(
                f"query {i} weights have dtype {w.dtype}; weights must be "
                "float (integer weights silently skew Eq. 2 step budgets)"
            )
    pins = jnp.asarray(np.stack([np.asarray(q[0]) for q in queries]))
    weights = jnp.asarray(np.stack([np.asarray(q[1]) for q in queries]))
    feats = jnp.asarray(np.asarray(user_feats, dtype=np.int32))
    return pins, weights, feats


# ---------------------------------------------------------------------------
# Multi-interest user queries (PinnerSage-style clustering, PAPERS.md)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class UserQuery:
    """One user's multi-interest query: k interest-cluster lanes.

    Built by ``build_user_query``.  Each row of ``cluster_pins`` /
    ``cluster_weights`` is a self-contained weighted query (the same shape
    ``build_query`` emits) for ONE interest cluster; ``importance`` is the
    cluster's share of the user's total action weight, normalized to sum
    to 1 over the live clusters.  Lanes are ordered by importance
    descending (ties: smallest member pin id), so a user's lane layout is
    deterministic.
    """

    cluster_pins: np.ndarray     # (k, n_slots) int32, -1 padded
    cluster_weights: np.ndarray  # (k, n_slots) float32, 0 padded
    importance: np.ndarray       # (k,) float32, sums to 1
    user_feat: int = 0

    @property
    def n_clusters(self) -> int:
        return int(self.cluster_pins.shape[0])

    @property
    def n_slots(self) -> int:
        return int(self.cluster_pins.shape[1])


def _agglomerate(
    vecs: np.ndarray, mass: np.ndarray, n_clusters: int
) -> List[List[int]]:
    """Deterministic average-linkage agglomeration to ``n_clusters``.

    Greedy centroid merging (PinnerSage's Ward-style host-side pass,
    shrunk to numpy): repeatedly merge the pair of clusters with the
    closest weighted centroids.  Distances are float64 and the argmin
    scans row-major, so ties break on the smallest (i, j) — no RNG, no
    dict-order dependence; the same action multiset always produces the
    same clustering.
    """
    members = [[i] for i in range(vecs.shape[0])]
    cent = np.asarray(vecs, np.float64).copy()
    mass = np.asarray(mass, np.float64).copy()
    while len(members) > n_clusters:
        diff = cent[:, None, :] - cent[None, :, :]
        d2 = np.einsum("ijk,ijk->ij", diff, diff)
        iu = np.triu_indices(len(members), k=1)
        flat = np.full_like(d2, np.inf)
        flat[iu] = d2[iu]
        i, j = np.unravel_index(int(np.argmin(flat)), flat.shape)
        tot = mass[i] + mass[j]
        cent[i] = (mass[i] * cent[i] + mass[j] * cent[j]) / tot
        mass[i] = tot
        members[i] = members[i] + members[j]
        del members[j]
        cent = np.delete(cent, j, axis=0)
        mass = np.delete(mass, j, axis=0)
    return members


def build_user_query(
    actions: Sequence[UserAction],
    pin_topics: np.ndarray,   # (n_pins, n_topics) pin embedding table
    n_slots: int,
    n_clusters: int = 3,
    half_life_hours: float = 24.0,
    default_weight: float | None = None,
    user_feat: int = 0,
) -> UserQuery:
    """Cluster a user's action history into a multi-interest ``UserQuery``.

    The PinnerSage translation of §5.1's flat homefeed query: instead of
    blending hundreds of acted pins into one weighted set (which washes
    distinct interests into a mushy centroid), the DISTINCT acted pins are
    agglomeratively clustered over their topic vectors and each cluster
    becomes its own weighted query lane:

      * per-pin weights are the same decayed action sums ``build_query``
        uses (canonical-order float sums — see ``_decayed_pin_weights``);
      * cluster importance I_c = the cluster's share of total action
        weight (``math.fsum`` over member pins, order-independent),
        normalized to sum to 1;
      * within a lane, pins keep their decayed weights, top-``n_slots``
        by (weight desc, pin asc) — ``build_query``'s truncation rule.

    Users with fewer distinct pins than ``n_clusters`` get one cluster per
    pin (k adapts down, never pads up); ``n_clusters=1`` reproduces the
    flat homefeed query exactly (same pins, same weights, one lane).
    Deterministic end to end — same action multiset, same ``UserQuery``.
    """
    if n_clusters < 1:
        raise ValueError(f"n_clusters must be >= 1, got {n_clusters}")
    acc = _decayed_pin_weights(actions, half_life_hours, default_weight)
    if not acc:
        raise ValueError("build_user_query needs at least one action")
    topics = np.asarray(pin_topics)
    pins = sorted(acc)
    if pins[0] < 0 or pins[-1] >= topics.shape[0]:
        raise ValueError(
            f"action pin ids span [{pins[0]}, {pins[-1]}] but pin_topics "
            f"covers [0, {topics.shape[0]})"
        )
    w64 = np.array([acc[p] for p in pins], dtype=np.float64)
    k = min(n_clusters, len(pins))
    members = _agglomerate(topics[pins].astype(np.float64), w64, k)

    clusters = []
    for mem in members:
        mem_pins = sorted(pins[m] for m in mem)
        imp = math.fsum(acc[p] for p in mem_pins)
        clusters.append((imp, mem_pins))
    # importance descending, smallest member pin breaking ties: lane order
    # is a pure function of the clustering, not of merge history
    clusters.sort(key=lambda c: (-c[0], c[1][0]))

    cluster_pins = np.full((k, n_slots), -1, dtype=np.int32)
    cluster_weights = np.zeros((k, n_slots), dtype=np.float32)
    imp64 = np.array([c[0] for c in clusters], dtype=np.float64)
    for ci, (_, mem_pins) in enumerate(clusters):
        items = sorted(
            ((p, acc[p]) for p in mem_pins), key=lambda kv: (-kv[1], kv[0])
        )[:n_slots]
        for si, (p, w) in enumerate(items):
            cluster_pins[ci, si] = p
            cluster_weights[ci, si] = w
    importance = (imp64 / imp64.sum()).astype(np.float32)
    return UserQuery(
        cluster_pins=cluster_pins,
        cluster_weights=cluster_weights,
        importance=importance,
        user_feat=int(user_feat),
    )


def cluster_step_budgets(importance: np.ndarray, n_steps: int) -> np.ndarray:
    """Eq. 2 applied at CLUSTER granularity: per-lane step totals.

    ``N_c = floor(I_c * N)`` with a min-1 floor for live clusters — the
    same shape as ``sampling.allocate_steps`` (clusters have no graph
    degree, so the Eq. 1 scaling s_p enters WITHIN each lane when the
    engine splits the lane total across its member pins).  Host-side
    numpy on normalized importance; every budget is <= ``n_steps``, the
    engine's static chunk bound.
    """
    imp = np.asarray(importance, np.float32)
    n_c = np.floor(imp * np.float32(n_steps)).astype(np.int32)
    return np.where(imp > 0, np.maximum(n_c, 1), 0).astype(np.int32)


class UserBatch(NamedTuple):
    """A batch of multi-interest users flattened to cluster lanes.

    The lane axis L = sum of every user's k is the SAME query axis the
    PR 5 batched engine fuses over — multi-interest serving adds lanes,
    never pallas_calls.  ``lane_user`` / ``lane_of_user`` are host-side
    numpy (static at trace time): the per-user lane map the merge uses to
    gather a user's lanes back together.
    """

    pins: jnp.ndarray          # (L, n_slots) int32
    weights: jnp.ndarray       # (L, n_slots) float32
    feats: jnp.ndarray         # (L,) int32
    importance: jnp.ndarray    # (L,) float32, per-user normalized
    step_budgets: jnp.ndarray  # (L,) int32 per-lane Eq. 2 totals
    lane_user: np.ndarray      # (L,) int32 lane -> user index
    lane_of_user: np.ndarray   # (n_users, k_max) int32 lane ids, -1 pad
    n_users: int


def batch_user_queries(
    users: Sequence[UserQuery], n_steps: int
) -> UserBatch:
    """Flatten users -> cluster lanes for one batched engine call.

    Ragged users (different k) flatten to different LANE COUNTS, not
    different shapes: every lane is (n_slots,) and budgets/importance are
    data, so any mix of users with the same total lane count shares one
    compiled program.  ``n_steps`` is the PER-USER walk budget (the flat
    path's ``cfg.n_steps``), split across each user's lanes by cluster
    importance — a k-cluster user costs the same step budget as a flat
    user, it just spends it per interest.
    """
    if not users:
        raise ValueError("batch_user_queries needs at least one user")
    n_slots = users[0].n_slots
    for i, u in enumerate(users):
        if u.n_slots != n_slots:
            raise ValueError(
                f"user {i} has {u.n_slots} slots but the batch has "
                f"{n_slots}; build every UserQuery with the same n_slots"
            )
    k_max = max(u.n_clusters for u in users)
    pins, weights, feats, imps, budgets, lane_user = [], [], [], [], [], []
    lane_of_user = np.full((len(users), k_max), -1, dtype=np.int32)
    for ui, u in enumerate(users):
        u_budgets = cluster_step_budgets(u.importance, n_steps)
        for ci in range(u.n_clusters):
            lane_of_user[ui, ci] = len(pins)
            lane_user.append(ui)
            pins.append(u.cluster_pins[ci])
            weights.append(u.cluster_weights[ci])
            feats.append(u.user_feat)
            imps.append(u.importance[ci])
            budgets.append(u_budgets[ci])
    return UserBatch(
        pins=jnp.asarray(np.stack(pins)),
        weights=jnp.asarray(np.stack(weights)),
        feats=jnp.asarray(np.asarray(feats, np.int32)),
        importance=jnp.asarray(np.asarray(imps, np.float32)),
        step_budgets=jnp.asarray(np.asarray(budgets, np.int32)),
        lane_user=np.asarray(lane_user, np.int32),
        lane_of_user=lane_of_user,
        n_users=len(users),
    )


def serve_batch(
    graph,
    pins: jnp.ndarray,      # (batch, n_slots)
    weights: jnp.ndarray,   # (batch, n_slots)
    user_feats: jnp.ndarray,  # (batch,)
    key: jax.Array,
    cfg: walk_lib.WalkConfig,
    backend: str | None = None,
    with_stats: bool = False,
    mesh=None,
    axis: str = "model",
    slack: float = 2.0,
    rank=None,
    scenario: jnp.ndarray | None = None,
    step_budgets: jnp.ndarray | None = None,
    shard_dead_at: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, ...]:
    """One SPMD serving step: Pixie over a whole query batch.

    This is the TPU replacement for the paper's worker-thread-per-query
    model: a batch of queries is one program.  ``backend`` overrides
    ``cfg.backend`` ("xla" | "pallas") for the whole batch, so a serving
    fleet can flip the hot path to the fused Pallas walk engine without
    rebuilding its configs; both engines return bit-identical
    recommendations for the same key (core/walk.py) — including the
    early-stop observables, since both maintain the same incremental
    ``n_high`` tally.

    ``backend="pallas"`` routes through the BATCH-NATIVE engine
    (``walk_lib.recommend_with_stats_batched``): the whole batch's walkers
    run in one fused ``pallas_call`` per superstep chunk and counting is
    one query-major call per chunk, instead of a batch-sized grid
    replication per query under vmap.  ``backend="xla"`` keeps the vmapped
    per-query path — the oracle twin the batched engine is verified
    bit-identical against (tests/test_batchfuse.py).  The batched engine's
    query-major bins must fit int32 indexing
    (``walk_lib.batched_engine_fits``); a (graph, batch) shape past that
    envelope falls back to the vmapped formulation — same results, the
    per-query bins may still fit — rather than erroring where the old
    path served.

    ``key`` is either a scalar PRNG key — split into one stream per query,
    the original behavior — or a ``(batch,)`` typed key array used
    directly as the per-query streams.  Per-query keys are what makes a
    query's result independent of BATCH COMPOSITION: the bucketed server
    (serving/server.py) assigns each request its key at submit time
    (``fold_in`` of the request id), so deadline-aware batch formation can
    group requests however load dictates and still return bit-identical
    recommendations to the single-bucket flush oracle on the same
    requests.  (Padding a query into a wider ``n_slots`` shape is also
    bit-invariant: zero-weight slots get zero step budget and zero
    walkers, so bucket shape never changes a query's walk.)

    Returns ``(scores, ids)``; with ``with_stats=True`` returns
    ``(scores, ids, steps_taken, n_high)`` (each leading with the batch
    axis) so the fleet can monitor how much step budget Algorithm 3's
    early stopping saves per query shape.

    A ``distributed.ShardedGraph`` routes through the pod-sharded batched
    engine instead (``mesh`` required; ``axis`` names the shard axis,
    ``slack`` scales routing capacity): the same walk semantics with the
    graph node-range-sharded across the mesh, bit-identical to the
    unsharded engines whenever routing drops nothing.  ``with_stats=True``
    then returns ``(scores, ids, steps_taken, n_high, dropped)`` — the
    extra scalar is the routing-overflow drop count, the serving signal
    for raising ``slack`` (drops are bounded Monte Carlo slack, never
    silent).

    ``rank`` (a ``serving.ranker.RankRequest``) turns the step TWO-STAGE:
    retrieval runs with ``top_k`` overridden to ``rank.cfg.n_candidates``,
    then `serving.ranker.rank_candidates` re-scores the candidates with
    the per-request ``scenario`` head (``(batch,)`` int32 head indices;
    default head 0 for every query) — still one jitted program, still a
    constant ``pallas_call`` count independent of batch size.  Returned
    ``(scores, ids)`` are then the ranked ``(batch, final_k)`` results;
    ``with_stats=True`` keeps appending the stage-1 walk telemetry.
    Stage 2's float math is ONE shared program for both backends (the bag
    op's lowering is platform-defaulted, never backend-derived), so ranked
    serving inherits the walk's bit-parity contract end to end
    (`two_stage_backends_agree`).  Ranked serving over a ``ShardedGraph``
    raises: stage 2 gathers candidate neighborhoods from the full CSR,
    which a node-range shard doesn't hold — rank on an unsharded replica,
    or rank host-side from the sharded walk's ``(scores, ids)``.

    ``step_budgets`` (optional ``(batch,)`` int32) overrides each query
    lane's Eq. 2 step total as DATA — the multi-interest layer rides its
    interest-cluster lanes on the batch axis with importance-proportional
    budgets (``batch_user_queries``), and ragged users share compiled
    programs because budgets never enter a shape.  ``None`` (every
    existing caller) leaves the classic static ``cfg.n_steps`` in place —
    same program, same results.  Unsupported over a ``ShardedGraph``.

    ``shard_dead_at`` (optional ``(n_shards,)`` int32, ``ShardedGraph``
    only) is the degraded-mode liveness schedule: shard ``s`` is dead
    from absolute superstep ``shard_dead_at[s]`` onward (``INT32_MAX`` =
    never).  Walkers routed to a dead shard are killed and reborn at
    home, dead shards' counts drop out of the merge, and the killed
    total is reported through the engine's telemetry — see
    ``distributed.pixie_walk_sharded_batched``.  Data, not shape: the
    serving layer flips liveness without retracing.
    """
    if backend is not None and backend != cfg.backend:
        cfg = dataclasses.replace(cfg, backend=backend)
    if scenario is not None and rank is None:
        raise ValueError(
            "scenario= selects a ranker head and needs rank=; a bare "
            "retrieval step has no scenario axis"
        )
    if rank is not None and cfg.top_k != rank.cfg.n_candidates:
        cfg = dataclasses.replace(cfg, top_k=rank.cfg.n_candidates)
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key) and key.ndim == 1:
        if key.shape[0] != pins.shape[0]:
            raise ValueError(
                f"per-query key array has {key.shape[0]} keys for a batch "
                f"of {pins.shape[0]} queries; one key per query required"
            )
        keys = key
    else:
        keys = jax.random.split(key, pins.shape[0])

    from repro.core import distributed as dist_lib

    if isinstance(graph, dist_lib.ShardedGraph):
        if step_budgets is not None:
            raise ValueError(
                "serve_batch(step_budgets=...) over a ShardedGraph is not "
                "supported: the pod-sharded engine allocates Eq. 2 budgets "
                "from cfg.n_steps; serve multi-interest lanes on an "
                "unsharded replica"
            )
        if rank is not None:
            raise ValueError(
                "serve_batch(rank=...) over a ShardedGraph is not "
                "supported: stage 2 gathers candidate neighborhoods from "
                "the full CSR, which a node-range shard doesn't hold; rank "
                "on an unsharded replica or host-side from the sharded "
                "walk's (scores, ids)"
            )
        if mesh is None:
            raise ValueError(
                "serve_batch over a ShardedGraph needs the device mesh "
                "(pass mesh=...)"
            )
        scores, ids, steps, n_high, dropped = (
            dist_lib.recommend_sharded_batched(
                graph, pins, weights, keys, cfg, mesh, axis, slack=slack,
                shard_dead_at=shard_dead_at,
            )
        )
        if with_stats:
            return scores, ids, steps, n_high, dropped
        return scores, ids

    if shard_dead_at is not None:
        raise ValueError(
            "serve_batch(shard_dead_at=...) needs a ShardedGraph: an "
            "unsharded replica has no shards to lose"
        )
    if cfg.backend == "pallas" and walk_lib.batched_engine_fits(
        int(pins.shape[0]), int(pins.shape[1]), graph.n_pins,
        graph.n_boards, cfg.count_boards,
    ):
        scores, ids, steps, n_high = walk_lib.recommend_with_stats_batched(
            graph, pins, weights, user_feats, keys, cfg,
            step_budgets=step_budgets,
        )
    elif step_budgets is None:

        def one(qp, qw, uf, k):
            return walk_lib.recommend_with_stats(graph, qp, qw, uf, k, cfg)

        scores, ids, steps, n_high = jax.vmap(one)(
            pins, weights, user_feats, keys
        )
    else:

        def one_budgeted(qp, qw, uf, k, sb):
            return walk_lib.recommend_with_stats(
                graph, qp, qw, uf, k, cfg, step_budget=sb
            )

        scores, ids, steps, n_high = jax.vmap(one_budgeted)(
            pins, weights, user_feats, keys,
            jnp.asarray(step_budgets, jnp.int32),
        )
    if rank is not None:
        from repro.serving import ranker as ranker_lib

        if scenario is None:
            scenario = jnp.zeros((pins.shape[0],), jnp.int32)
        scores, ids = ranker_lib.rank_candidates(
            rank.params, rank.cfg, graph, ids, scores, scenario
        )
    if with_stats:
        return scores, ids, steps, n_high
    return scores, ids
