"""Bipartite pin-board graph in contiguous CSR ("edgeVec") form.

This is the JAX port of Pixie's custom graph data structure (paper §3.3):

  * every node gets a dense integer id;
  * all adjacency lists are concatenated into one contiguous array
    (``targets``, the paper's ``edgeVec``) with an ``offsets`` array so the
    neighbours of node ``i`` live in ``targets[offsets[i]:offsets[i+1]]``;
  * sampling a neighbour is one gather:
    ``targets[offsets[i] + rand() % (offsets[i+1] - offsets[i])]`` (Eq. 4).

Extensions over the paper's struct, both used by the Pixie walk:

  * **feature-sorted adjacency** — within each node's neighbour slice, edges
    are sorted by a small categorical edge feature (language/topic bucket) and
    per-node subrange boundaries are stored, so the paper's
    ``PersonalizedNeighbor`` "subrange operator" (§3.1(1)) is two extra
    gathers;
  * **degrees are derived**, never stored (``offsets`` diff), matching the
    paper's memory layout.

Pins occupy ids ``[0, n_pins)`` and boards ``[n_pins, n_pins + n_boards)`` in
a single id space so a walk position is always one integer.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = Any


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CSR:
    """One direction of the bipartite adjacency in edgeVec form.

    Attributes:
      offsets:     (n_src + 1,) int32/int64 — prefix sums of degrees.
      targets:     (n_edges,) int — neighbour ids (the paper's edgeVec).
      feat_bounds: optional (n_src, n_feats + 1) int32 — per-node boundaries
                   of the feature-sorted sublists, *relative* to the node's
                   own slice (so values are in [0, degree]).  Column f gives
                   the start of feature-f edges; column f+1 its end.
    """

    offsets: Array
    targets: Array
    feat_bounds: Optional[Array] = None

    # -- pytree plumbing ----------------------------------------------------
    def tree_flatten(self):
        return (self.offsets, self.targets, self.feat_bounds), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    # -- basic queries -------------------------------------------------------
    @property
    def n_src(self) -> int:
        return self.offsets.shape[0] - 1

    @property
    def n_edges(self) -> int:
        return self.targets.shape[0]

    @property
    def n_feats(self) -> int:
        if self.feat_bounds is None:
            return 0
        return self.feat_bounds.shape[1] - 1

    def degrees(self) -> Array:
        return self.offsets[1:] - self.offsets[:-1]

    def degree(self, node: Array) -> Array:
        node = jnp.asarray(node)
        return jnp.take(self.offsets, node + 1) - jnp.take(self.offsets, node)

    def neighbor(self, node: Array, r: Array) -> Array:
        """Uniform neighbour sample: Eq. 4 of the paper.

        ``node`` and ``r`` are arrays of the same shape; ``r`` is raw random
        bits (any non-negative int).  Degree-0 nodes return -1.
        """
        node = jnp.asarray(node)
        start = jnp.take(self.offsets, node)
        deg = jnp.take(self.offsets, node + 1) - start
        safe_deg = jnp.maximum(deg, 1)
        idx = start + (r % safe_deg).astype(start.dtype)
        tgt = jnp.take(self.targets, idx)
        return jnp.where(deg > 0, tgt, -1)

    def biased_neighbor(self, node: Array, r: Array, feat: Array) -> Array:
        """PersonalizedNeighbor (§3.1(1)): sample within the feature subrange.

        Falls back to a uniform neighbour when the node has no edges with the
        requested feature.  ``feat`` broadcasts against ``node``.
        """
        if self.feat_bounds is None:
            return self.neighbor(node, r)
        node = jnp.asarray(node)
        start = jnp.take(self.offsets, node)
        deg = jnp.take(self.offsets, node + 1) - start
        feat = jnp.broadcast_to(jnp.asarray(feat), node.shape)
        lo = self.feat_bounds[node, feat].astype(start.dtype)
        hi = self.feat_bounds[node, feat + 1].astype(start.dtype)
        span = hi - lo
        has_feat = span > 0
        # subrange sample where possible, else uniform over the whole slice
        sub_idx = start + lo + (r % jnp.maximum(span, 1)).astype(start.dtype)
        uni_idx = start + (r % jnp.maximum(deg, 1)).astype(start.dtype)
        idx = jnp.where(has_feat, sub_idx, uni_idx)
        tgt = jnp.take(self.targets, idx)
        return jnp.where(deg > 0, tgt, -1)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PinBoardGraph:
    """The full bipartite object graph: pins <-> boards.

    ``p2b`` maps pin id -> board ids; ``b2p`` maps *local* board index
    (board_id - n_pins) -> pin ids.  Static metadata rides in aux_data so the
    object is a jit-stable pytree.
    """

    p2b: CSR
    b2p: CSR
    n_pins: int = dataclasses.field(metadata={"static": True})
    n_boards: int = dataclasses.field(metadata={"static": True})
    max_pin_degree: int = dataclasses.field(metadata={"static": True})

    def tree_flatten(self):
        return (self.p2b, self.b2p), (self.n_pins, self.n_boards, self.max_pin_degree)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    # -- queries --------------------------------------------------------------
    @property
    def n_nodes(self) -> int:
        return self.n_pins + self.n_boards

    @property
    def n_edges(self) -> int:
        return int(self.p2b.n_edges)

    def pin_degree(self, pin: Array) -> Array:
        return self.p2b.degree(pin)

    def board_local(self, board_id: Array) -> Array:
        """Global board id -> local row in b2p."""
        return board_id - self.n_pins

    def nbytes(self) -> int:
        total = 0
        for csr in (self.p2b, self.b2p):
            total += csr.offsets.size * csr.offsets.dtype.itemsize
            total += csr.targets.size * csr.targets.dtype.itemsize
            if csr.feat_bounds is not None:
                total += csr.feat_bounds.size * csr.feat_bounds.dtype.itemsize
        return int(total)


# ---------------------------------------------------------------------------
# Host-side graph construction (the "graph compiler" of §3.3)
# ---------------------------------------------------------------------------


def _build_csr(
    src: np.ndarray,
    dst: np.ndarray,
    n_src: int,
    edge_feat: Optional[np.ndarray],
    n_feats: int,
    offset_dtype=np.int32,
    target_dtype=np.int32,
) -> CSR:
    """Sort edges by (src, feat) and emit edgeVec CSR + feature bounds."""
    if edge_feat is not None:
        order = np.lexsort((edge_feat, src))
    else:
        order = np.argsort(src, kind="stable")
    src_s = src[order]
    dst_s = dst[order].astype(target_dtype)
    counts = np.bincount(src_s, minlength=n_src)
    offsets = np.zeros(n_src + 1, dtype=offset_dtype)
    np.cumsum(counts, out=offsets[1:])

    feat_bounds = None
    if edge_feat is not None:
        feat_s = edge_feat[order]
        # per (src, feat) counts -> relative prefix sums
        flat = src_s.astype(np.int64) * n_feats + feat_s
        per = np.bincount(flat, minlength=n_src * n_feats).reshape(n_src, n_feats)
        feat_bounds = np.zeros((n_src, n_feats + 1), dtype=np.int32)
        np.cumsum(per, axis=1, out=feat_bounds[:, 1:])

    return CSR(
        offsets=jnp.asarray(offsets),
        targets=jnp.asarray(dst_s),
        feat_bounds=None if feat_bounds is None else jnp.asarray(feat_bounds),
    )


def build_graph(
    pin_ids: np.ndarray,
    board_ids: np.ndarray,
    n_pins: int,
    n_boards: int,
    edge_feat: Optional[np.ndarray] = None,
    n_feats: int = 0,
    edge_feat_b2p: Optional[np.ndarray] = None,
) -> PinBoardGraph:
    """Compile an edge list (pin id, board id in [0, n_boards)) to CSR form.

    Mirrors the paper's offline graph compiler: runs on host (numpy), emits
    device arrays.  ``edge_feat`` is an optional per-edge small categorical
    (e.g. the target board's language) enabling the personalized subrange
    operator in the pin->board direction; ``edge_feat_b2p`` (default: same)
    is the feature used to sort the board->pin direction (typically the
    target pin's language).
    """
    pin_ids = np.asarray(pin_ids, dtype=np.int64)
    board_ids = np.asarray(board_ids, dtype=np.int64)
    if pin_ids.shape != board_ids.shape:
        raise ValueError("pin_ids and board_ids must align")
    if edge_feat is not None:
        edge_feat = np.asarray(edge_feat, dtype=np.int64)
        if n_feats <= 0:
            n_feats = int(edge_feat.max()) + 1 if edge_feat.size else 1
    if edge_feat_b2p is None:
        edge_feat_b2p = edge_feat
    else:
        edge_feat_b2p = np.asarray(edge_feat_b2p, dtype=np.int64)

    p2b = _build_csr(
        pin_ids, board_ids + n_pins, n_pins, edge_feat, n_feats
    )
    b2p = _build_csr(board_ids, pin_ids, n_boards, edge_feat_b2p, n_feats)
    degs = np.asarray(p2b.degrees())
    max_deg = int(degs.max()) if degs.size else 0
    return PinBoardGraph(
        p2b=p2b,
        b2p=b2p,
        n_pins=int(n_pins),
        n_boards=int(n_boards),
        max_pin_degree=max_deg,
    )


def edge_list(graph: PinBoardGraph) -> Tuple[np.ndarray, np.ndarray]:
    """Recover the (pin, local board) edge list from CSR (host-side)."""
    offsets = np.asarray(graph.p2b.offsets)
    targets = np.asarray(graph.p2b.targets)
    pins = np.repeat(np.arange(graph.n_pins, dtype=np.int64), np.diff(offsets))
    boards = targets.astype(np.int64) - graph.n_pins
    return pins, boards


# ---------------------------------------------------------------------------
# Persistence: binary shards + metadata, the paper's "persists it to disk in a
# binary format ... shared easily between machines".
# ---------------------------------------------------------------------------


def save_graph(graph: PinBoardGraph, path: str) -> None:
    os.makedirs(path, exist_ok=True)
    arrays: Dict[str, np.ndarray] = {
        "p2b_offsets": np.asarray(graph.p2b.offsets),
        "p2b_targets": np.asarray(graph.p2b.targets),
        "b2p_offsets": np.asarray(graph.b2p.offsets),
        "b2p_targets": np.asarray(graph.b2p.targets),
    }
    if graph.p2b.feat_bounds is not None:
        arrays["p2b_feat_bounds"] = np.asarray(graph.p2b.feat_bounds)
        arrays["b2p_feat_bounds"] = np.asarray(graph.b2p.feat_bounds)
    np.savez(os.path.join(path, "graph.npz"), **arrays)
    meta = {
        "n_pins": graph.n_pins,
        "n_boards": graph.n_boards,
        "max_pin_degree": graph.max_pin_degree,
        "has_feats": graph.p2b.feat_bounds is not None,
    }
    tmp = os.path.join(path, "meta.json.tmp")
    with open(tmp, "w") as f:
        json.dump(meta, f)
    os.replace(tmp, os.path.join(path, "meta.json"))


def load_graph(path: str) -> PinBoardGraph:
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "graph.npz"))
    has_feats = meta["has_feats"]
    p2b = CSR(
        offsets=jnp.asarray(data["p2b_offsets"]),
        targets=jnp.asarray(data["p2b_targets"]),
        feat_bounds=jnp.asarray(data["p2b_feat_bounds"]) if has_feats else None,
    )
    b2p = CSR(
        offsets=jnp.asarray(data["b2p_offsets"]),
        targets=jnp.asarray(data["b2p_targets"]),
        feat_bounds=jnp.asarray(data["b2p_feat_bounds"]) if has_feats else None,
    )
    return PinBoardGraph(
        p2b=p2b,
        b2p=b2p,
        n_pins=meta["n_pins"],
        n_boards=meta["n_boards"],
        max_pin_degree=meta["max_pin_degree"],
    )


def graph_abstract(
    n_pins: int,
    n_boards: int,
    n_edges: int,
    n_feats: int = 0,
    offset_dtype=jnp.int64,
    target_dtype=jnp.int32,
) -> PinBoardGraph:
    """ShapeDtypeStruct stand-in graph for .lower()/.compile() dry-runs.

    Full-production scale (3e9 nodes / 17e9 edges) never materializes on this
    host; the dry-run lowers against these specs.  Board adjacency reuses the
    same edge count (each edge appears once per direction).
    """
    sds = jax.ShapeDtypeStruct
    fb = None
    fb_b = None
    if n_feats > 0:
        fb = sds((n_pins, n_feats + 1), jnp.int32)
        fb_b = sds((n_boards, n_feats + 1), jnp.int32)
    p2b = CSR(
        offsets=sds((n_pins + 1,), offset_dtype),
        targets=sds((n_edges,), target_dtype),
        feat_bounds=fb,
    )
    b2p = CSR(
        offsets=sds((n_boards + 1,), offset_dtype),
        targets=sds((n_edges,), target_dtype),
        feat_bounds=fb_b,
    )
    return PinBoardGraph(
        p2b=p2b,
        b2p=b2p,
        n_pins=n_pins,
        n_boards=n_boards,
        max_pin_degree=4096,
    )
