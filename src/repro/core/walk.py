"""The Pixie Random Walk engine (paper §3.1, Algorithms 1-3), vectorized.

The paper's walk is sequential pointer chasing; the TPU-native form runs W
independent walkers in lockstep.  One *step* for every walker is:

    maybe-restart -> sample board from E(pin) -> sample pin from E(board)
    -> record visit

which is exactly Algorithm 2's inner loop, with ``SampleWalkLength(alpha)``
realised as a per-step Bernoulli(alpha) restart (geometric segment lengths,
E[len] = 1/alpha; see core/sampling.py).

Two interchangeable step engines (``WalkConfig.backend``):

  * ``"xla"``    — pure-XLA two-level gathers (kernels/ref.walk_chunk_ref);
                   the numerical reference, runs anywhere.
  * ``"pallas"`` — the fused multi-superstep Pallas kernel
                   (kernels/walk_step.walk_steps_fused): ONE kernel launch
                   per ``chunk_steps`` steps with walker state resident in
                   VMEM across the whole chunk, wide (slot, pin) visit
                   events emitted in-kernel, and counts recovered with the
                   scatter-free tile-scan ``visit_counter`` kernels.  Its
                   CSR gathers come in two bit-identical flavours
                   (``WalkConfig.gather_mode``): blocking per-walker
                   scalar loads ("scalar") or the phase-split
                   double-buffered async-DMA prefetch ("dma") that hides
                   each walker's HBM latency behind its neighbour's.  On
                   CPU hosts the kernel runs in interpret mode.

Events are WIDE — two int32 lanes, (slot, pin), slot lane ``n_slots`` as
the invalid-step sentinel — never the packed ``slot * n_pins + pin``
product, so BOTH engines cover production id spaces past 2**31 (the
paper's 3B-pin regime) with no int64 anywhere and no fallback: backend
choice is a pure performance knob at every scale.

Both engines consume the SAME counter-based random bits (one uint32
quadruple per walker-step, threefry fold-in of the step index), do the same
integer arithmetic on them, and therefore produce bit-for-bit identical
visit events — backend choice is a pure performance knob, verified by
tests/test_walk_backends.py.

Two counting backends (see core/counter.py):
  * dense  — per-(query-slot, pin) counts; benchmark-scale and per-shard
             production counting (a dense buffer inherently needs
             n_slots * n_pins < 2**31).  The xla engine scatter-adds; the
             pallas engine histograms the event lanes (no scatters).
  * events — bounded wide (slot, pin) lane buffers + pair-sort aggregation;
             scale-free, memory O(N) like the paper's hash table, id space
             unlimited.  Both engines emit the lane buffers directly.

Serving batches are BATCH-NATIVE (``pixie_random_walk_batched``): the
whole batch's walkers run on one walker axis with a per-walker query lane,
each chunk is one fused call (one ``pallas_call`` on the pallas engine)
plus one query-major counting call over (query, slot, pin) triple bins,
and a single shared while loop carries a per-(query, slot) early-stop
mask — bit-identical to vmapping the per-query engine over
``jax.random.split`` keys, which remains the oracle twin
(tests/test_batchfuse.py).

Early stopping (Algorithm 2 lines 10-13) is evaluated every chunk: a query
slot stops once >= n_p pins reached n_v visits or its step budget N_q is
spent; the whole walk stops when every slot stopped.  The statistic is
maintained INCREMENTALLY: the while-loop carries a (n_slots,) running
``n_high`` tally updated by ``counter_lib.accumulate_packed_events_with_high``
from just the chunk's own events (xla: sort the chunk and gather old/new
counts at the touched bins; pallas: threshold crossings emitted by the fused
``visit_counter_update_high`` kernel while the count tile is in VMEM) — the
loop body never reduces the full n_slots * n_pins buffer.  Event mode is
incremental too: ``counter_lib.EventHighState`` keeps each check window's
sorted runs, and the ``check_every`` body sorts ONLY the new window's
events (``events_high_fold``) — never the whole ``max_events`` buffer.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import counter as counter_lib
from repro.core import sampling
from repro.core.graph import PinBoardGraph
from repro.kernels import ops
from repro.kernels.walk_step import GATHER_MODES

Array = jax.Array

BACKENDS = ("xla", "pallas")


def packed_event_dtype(n_slots: int, n_pins: int):
    """Dtype of EACH wide event lane — always int32.

    Events are (slot, pin) lane pairs; no lane ever holds the packed
    ``slot * n_pins + pin`` product, so the lane dtype is int32 at every
    id-space scale (including the 3B-pin production graph that used to
    force int64 packing).  Kept as the single documented statement of the
    lane-dtype contract — nothing in the engine branches on it anymore,
    and tests pin that it stays int32 at production shapes.
    """
    del n_slots, n_pins  # wide lanes: scale no longer changes the dtype
    return jnp.int32


def select_count_engine(
    backend: str, n_slots: int, n_pins: int, n_boards: int = 0
) -> str:
    """Counting engine for a (slot, pin/board) id space: the backend itself.

    Wide event lanes removed the int32 packing cliff, so there is no
    fallback branch left — ``backend="pallas"`` counts with the wide
    tile-scan kernels at every id-space scale that dense counting can
    materialize at all, and event-mode counting has no scale limit on
    either engine.  Still the single shape-level validation point: dense
    counting inherently needs ``n_slots * max(n_pins, n_boards) < 2**31``
    (the count buffer is materialized), checked here loudly so production
    configs fail before a giant allocation, pointing at event mode.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown walk backend {backend!r}; use {BACKENDS}")
    n_bins = n_slots * max(n_pins, n_boards)
    if n_bins + 1 >= 2**31:
        raise ValueError(
            f"dense counting materializes n_slots * n_dim = {n_bins} bins, "
            "past int32 indexing; use event-mode counting "
            "(pixie_walk_events) for production-scale id spaces"
        )
    return backend


def batched_engine_fits(
    n_queries: int,
    n_slots: int,
    n_pins: int,
    n_boards: int = 0,
    count_boards: bool = False,
    n_shards: int = 1,
) -> bool:
    """Whether the batch-native dense engine can materialize its bins.

    The batched engine's query-major count buffer has
    ``n_queries * n_slots * n_pins`` int32-indexed bins (boards too when
    counted) — a STRICTER envelope than the vmapped per-query path, whose
    flat indexing only spans ``n_slots * n_pins`` per query even though
    its total memory is the same.  ``serve_batch`` consults this to fall
    back to the vmapped formulation instead of turning a
    previously-serving (graph, batch) shape into a trace-time error.
    Pure-int predicate so callers (and tests) can probe production shapes
    without materializing anything.

    ``n_shards > 1`` probes the pod-sharded batched engine: each shard
    only counts its OWNED id subrange, so the per-shard bin space divides
    by the shard count — the mechanism that brings the paper's 3B-pin
    id space under the int32 dense-count envelope (2e9 pins / 16 shards
    at n_slots = 16, batch 1: 2e9 bins < 2**31).
    """
    per_shard = -(-max(n_pins, n_boards if count_boards else 0) // n_shards)
    n_bins = n_queries * n_slots * per_shard
    return n_bins + 1 < 2**31


# disables Algorithm 2's early stopping: no pin can ever reach this many
# visits.  int32-safe because the tally machinery only COMPARES counts
# against n_v (never adds to it) — see accumulate_packed_events_with_high.
NO_EARLY_STOP_NV = jnp.iinfo(jnp.int32).max // 2


def _prob_u32(p: float) -> int:
    """Map a probability to the uint32 threshold used by both step engines."""
    return max(0, min(int(round(p * 2.0**32)), 2**32 - 1))


@dataclasses.dataclass(frozen=True)
class WalkConfig:
    """Hyper-parameters of the Pixie random walk.

    n_steps:      N — total step budget across all query pins (Eq. 2).
    alpha:        restart probability; E[walk segment] = 1/alpha.
    n_walkers:    number of parallel walkers (TPU adaptation; the paper's
                  sequential walker is n_walkers=1).
    chunk_steps:  steps fused per while-loop iteration between early-stop
                  checks (the paper checks per step; chunking trades slack
                  for device efficiency).  With backend="pallas" this is
                  also the number of supersteps fused into one kernel
                  launch.
    n_p, n_v:     early-stopping thresholds (>= n_p pins with >= n_v visits).
    bias_beta:    probability a step uses the personalized feature subrange
                  (PersonalizedNeighbor); 0 disables biasing (Algorithm 1).
    top_k:        number of recommendations extracted from the counter.
    count_boards: also accumulate board visit counts (for board recs, §5.3).
    backend:      "xla" (reference two-level gathers + scatter-add counts)
                  or "pallas" (fused multi-superstep kernel + tile-scan
                  histogram counts).  Both produce bit-identical visits.
    pallas_block_w: walkers per Pallas grid cell (None = auto).
    gather_mode:  how the pallas engine issues its per-walker CSR gathers:
                  "scalar" (blocking scalar loads) or "dma" (phase-split
                  double-buffered async-copy prefetch — hides the HBM
                  latency of walker i's rows behind walker i+1's).  Bit-
                  identical to "scalar" and to the xla engine; a pure
                  memory-latency knob on TPU hosts (interpret-mode CPU
                  timings don't show it).  Ignored by backend="xla".
    """

    n_steps: int = 100_000
    alpha: float = 0.5
    n_walkers: int = 1024
    chunk_steps: int = 8
    n_p: int = 2_000
    n_v: int = 4
    bias_beta: float = 0.9
    top_k: int = 1_000
    count_boards: bool = False
    backend: str = "xla"
    pallas_block_w: Optional[int] = None
    gather_mode: str = "scalar"

    def max_chunks(self) -> int:
        per_chunk = self.n_walkers * self.chunk_steps
        return max(1, -(-self.n_steps // per_chunk))

    def without_early_stop(self) -> "WalkConfig":
        """Algorithm 1 mode: run the full step budget, never stop early.

        Uses thresholds no walk can reach (``NO_EARLY_STOP_NV`` is compared
        against counts, never added to them, so the sentinel cannot
        overflow the incremental high tally).
        """
        return dataclasses.replace(
            self, n_p=self.n_steps + 1, n_v=NO_EARLY_STOP_NV
        )


class WalkResult(NamedTuple):
    """Dense-mode walk output."""

    counts: Array           # (n_slots, n_pins) int32 per-query visit counts
    board_counts: Optional[Array]  # (n_slots, n_boards) or None
    steps_taken: Array      # (n_slots,) int32
    n_high: Array           # (n_slots,) int32 pins that reached n_v visits
                            # (the loop's running tally, query pins debited)


class EventWalkResult(NamedTuple):
    """Event-mode walk output (scale-free, wide lanes)."""

    slot_events: Array      # (max_events,) int32 slot lane (n_slots = invalid)
    pin_events: Array       # (max_events,) int32 pin lane
    steps_taken: Array      # (n_slots,) int32
    chunks_run: Array       # () int32
    n_high: Array           # (n_slots,) int32 incremental Algorithm 3 tally
                            # as of the last completed check window (zeros
                            # when early stopping never checked)


# ---------------------------------------------------------------------------
# One chunk of steps for all walkers (shared by both modes and backends)
# ---------------------------------------------------------------------------


def _chunk_rbits(key: Array, step_base: Array, chunk_steps: int, w: int) -> Array:
    """Counter-based random bits for one chunk: (chunk_steps, w, 4) uint32.

    Column 0 drives the restart decision (< alpha threshold), column 1 the
    personalization decision (< beta threshold), columns 2/3 the board/pin
    neighbour picks.  Keyed by absolute step index so a restarted run
    replays the identical walk (fault-tolerance contract).
    """
    steps = step_base + jnp.arange(chunk_steps, dtype=jnp.int32)
    keys = jax.vmap(lambda s: sampling.step_key(key, s))(steps)
    return jax.vmap(lambda k: jax.random.bits(k, (w, 4)))(keys)


def _validated_bias_bounds(
    graph: PinBoardGraph, cfg: WalkConfig
) -> Tuple[Optional[Array], Optional[Array]]:
    """(p2b, b2p) feat bounds for a biased walk, or (None, None).

    Shared by the per-query and batched chunk drivers so both refuse a
    one-sided graph identically: a graph with feat_bounds on only one CSR
    side can't answer a biased walk, and refusing loudly beats silently
    dropping personalization.
    """
    if cfg.backend not in BACKENDS:
        raise ValueError(f"unknown walk backend {cfg.backend!r}; use {BACKENDS}")
    if cfg.gather_mode not in GATHER_MODES:
        raise ValueError(
            f"unknown gather_mode {cfg.gather_mode!r}; use {GATHER_MODES}"
        )
    has_p2b = graph.p2b.feat_bounds is not None
    has_b2p = graph.b2p.feat_bounds is not None
    if has_p2b != has_b2p and cfg.bias_beta > 0.0:
        raise ValueError(
            "graph has feat_bounds on only one CSR side; build both sides "
            "for biased walks or set bias_beta=0"
        )
    use_bias = has_p2b and has_b2p and cfg.bias_beta > 0.0
    return (
        graph.p2b.feat_bounds if use_bias else None,
        graph.b2p.feat_bounds if use_bias else None,
    )


def _walk_chunk(
    graph: PinBoardGraph,
    curr: Array,             # (W,) int32 current pin per walker
    query_of_walker: Array,  # (W,) int32 restart target
    user_feat: Array,        # () or (W,) int32 personalization feature
    slot_of_walker: Array,   # (W,) int32 query slot per walker
    key: Array,
    step_base: Array,        # () int32 global step counter (for counter RNG)
    cfg: WalkConfig,
    n_slots: int,
    unroll: bool = False,
) -> Tuple[Array, Array, Array, Optional[Array]]:
    """Run cfg.chunk_steps steps.

    Returns ``(new_curr, slot_events, pin_events, board_events)`` — wide
    int32 event lanes, each (chunk_steps, W); the slot lane carries
    ``n_slots`` for uncountable steps (dead-end forced restarts) and is
    shared by the pin and board lanes.  board_events is None unless
    cfg.count_boards.  Dispatches on cfg.backend; both engines consume the
    same random bits and agree bit-for-bit at every id-space scale — wide
    lanes have no int32 packing cliff, so there is no fallback.
    """
    p2b_fb, b2p_fb = _validated_bias_bounds(graph, cfg)
    w = curr.shape[0]
    rbits = _chunk_rbits(key, step_base, cfg.chunk_steps, w)
    feat = jnp.broadcast_to(jnp.asarray(user_feat, jnp.int32), (w,))
    return ops.walk_chunk_fused(
        curr,
        query_of_walker,
        feat,
        slot_of_walker,
        rbits,
        graph.p2b.offsets,
        graph.p2b.targets,
        graph.b2p.offsets,
        graph.b2p.targets,
        p2b_fb,
        b2p_fb,
        n_pins=graph.n_pins,
        n_slots=n_slots,
        n_boards=graph.n_boards,
        alpha_u32=_prob_u32(cfg.alpha),
        beta_u32=_prob_u32(cfg.bias_beta),
        count_boards=cfg.count_boards,
        unroll=unroll,
        block_w=cfg.pallas_block_w,
        gather_mode=cfg.gather_mode,
        use_kernel=(cfg.backend == "pallas"),
    )


def _walk_chunk_batched(
    graph: PinBoardGraph,
    curr: Array,             # (n_queries * w,) int32 current pin per walker
    query_of_walker: Array,  # (n_queries * w,) int32 restart target
    feat_of_walker: Array,   # (n_queries * w,) int32 personalization feature
    slot_of_walker: Array,   # (n_queries * w,) int32 query slot per walker
    qid_of_walker: Array,    # (n_queries * w,) int32 query id per walker
    keys: Array,             # (n_queries,) per-query PRNG keys
    step_base: Array,        # () int32 global step counter (for counter RNG)
    cfg: WalkConfig,
    n_slots: int,
    n_queries: int,
) -> Tuple[Array, Array, Array, Array, Optional[Array]]:
    """Batch-native chunk: every query's walkers in ONE fused call.

    Returns ``(new_curr, query_events, slot_events, pin_events,
    board_events)`` — the wide (query, slot, pin) int32 event triple, each
    lane (chunk_steps, n_queries * w).  The random bits are the EXACT
    per-query streams of the vmapped path: each query's
    ``jax.random.split``-derived key generates its own
    ``(chunk_steps, w, 4)`` block (``_chunk_rbits``), and the blocks are
    laid out query-major along the walker axis — so walker ``q * w + i``
    consumes bit-for-bit the same draws it would inside
    ``pixie_random_walk`` for query ``q`` alone.
    """
    p2b_fb, b2p_fb = _validated_bias_bounds(graph, cfg)
    w_total = curr.shape[0]
    w = w_total // n_queries
    rbits_q = jax.vmap(
        lambda k: _chunk_rbits(k, step_base, cfg.chunk_steps, w)
    )(keys)                                     # (n_queries, chunk_steps, w, 4)
    rbits = jnp.moveaxis(rbits_q, 0, 1).reshape(cfg.chunk_steps, w_total, 4)
    return ops.walk_chunk_fused_batched(
        curr,
        query_of_walker,
        feat_of_walker,
        slot_of_walker,
        qid_of_walker,
        rbits,
        graph.p2b.offsets,
        graph.p2b.targets,
        graph.b2p.offsets,
        graph.b2p.targets,
        p2b_fb,
        b2p_fb,
        n_pins=graph.n_pins,
        n_slots=n_slots,
        n_queries=n_queries,
        n_boards=graph.n_boards,
        alpha_u32=_prob_u32(cfg.alpha),
        beta_u32=_prob_u32(cfg.bias_beta),
        count_boards=cfg.count_boards,
        block_w=cfg.pallas_block_w,
        gather_mode=cfg.gather_mode,
        use_kernel=(cfg.backend == "pallas"),
    )


# ---------------------------------------------------------------------------
# Dense-mode multi-query walk (Algorithms 2 + 3)
# ---------------------------------------------------------------------------


def pixie_random_walk(
    graph: PinBoardGraph,
    query_pins: Array,     # (n_slots,) int32, padded with -1
    query_weights: Array,  # (n_slots,) float32, 0 for padding
    user_feat: Array,      # () int32 personalization feature (e.g. language)
    key: Array,
    cfg: WalkConfig,
    step_budget=None,      # optional () int32 override of cfg.n_steps
) -> WalkResult:
    """PIXIERANDOMWALKMULTIPLE: biased, weighted, early-stopped, boosted.

    Returns dense per-slot visit counts; combine with
    ``counter_lib.boost_combine`` + ``topk_dense`` for recommendations.

    ``step_budget`` overrides the Eq. 2 total ``cfg.n_steps`` as DATA (a
    Python int or a traced int32 scalar) — the multi-interest query layer
    gives each interest-cluster lane its own budget without recompiling
    per budget value.  Budgets are CLAMPED to ``cfg.n_steps``: the while
    loop's static chunk bound stays ``cfg.max_chunks()``, so a smaller
    budget exhausts via the per-slot ``steps_taken < n_q`` check, and a
    larger one — which the loop could never actually walk — is bounded
    up front instead of silently truncating with inconsistent
    ``steps_taken`` bookkeeping.
    """
    if cfg.n_v < 1:
        raise ValueError(
            f"n_v must be >= 1, got {cfg.n_v}; use "
            "cfg.without_early_stop() to disable early stopping"
        )
    n_slots = query_pins.shape[0]
    n_pins = graph.n_pins
    w = cfg.n_walkers
    # board ids are only counted when count_boards: a pin-only walk must
    # not be rejected because a board id space nobody counts would not fit
    # a dense buffer (the shape-level chooser makes the same distinction)
    n_boards_packed = graph.n_boards if cfg.count_boards else 0
    slot_sentinel = jnp.int32(n_slots)
    count_engine = select_count_engine(
        cfg.backend, n_slots, n_pins, n_boards_packed
    )

    valid_q = (query_pins >= 0) & (query_weights > 0)
    safe_q = jnp.where(valid_q, query_pins, 0)
    degs = graph.pin_degree(safe_q) * valid_q.astype(graph.p2b.offsets.dtype)

    # Eq. 1-2: per-slot step budgets; walker pool apportioned to match.
    n_q = sampling.allocate_steps(
        jnp.where(valid_q, query_weights, 0.0),
        degs,
        jnp.asarray(graph.max_pin_degree),
        cfg.n_steps if step_budget is None
        else jnp.minimum(jnp.asarray(step_budget, jnp.int32), cfg.n_steps),
    )
    slot_of_walker, _ = sampling.allocate_walkers(n_q, w)
    query_of_walker = jnp.take(safe_q, slot_of_walker).astype(jnp.int32)

    counts0 = jnp.zeros((n_slots * n_pins,), dtype=jnp.int32)
    bcounts0 = (
        jnp.zeros((n_slots * graph.n_boards,), dtype=jnp.int32)
        if cfg.count_boards
        else None
    )
    walkers_per_slot = jax.ops.segment_sum(
        jnp.ones((w,), jnp.int32), slot_of_walker, num_segments=n_slots
    )

    def cond(state):
        _, _, _, _, steps_taken, slot_active, it = state
        return jnp.any(slot_active) & (it < cfg.max_chunks())

    def body(state):
        curr, counts, bcounts, high, steps_taken, slot_active, it = state
        step_base = it * cfg.chunk_steps
        walker_active = jnp.take(slot_active, slot_of_walker)

        curr2, sev, pev, bev = _walk_chunk(
            graph, curr, query_of_walker, user_feat, slot_of_walker,
            key, step_base, cfg, n_slots,
        )
        curr = jnp.where(walker_active, curr2, curr)
        # masking the shared slot lane invalidates pin AND board events
        sev = jnp.where(walker_active[None, :], sev, slot_sentinel)
        # fused: accumulate the chunk AND update the running n_high tally —
        # no n_slots * n_pins reduction anywhere in this loop body
        counts, high = counter_lib.accumulate_packed_events_with_high(
            counts, high, sev, pev, n_slots, n_pins, cfg.n_v, count_engine
        )
        if cfg.count_boards:
            bcounts = counter_lib.accumulate_packed_events(
                bcounts, sev, bev, n_slots, graph.n_boards, count_engine
            )

        steps_taken = steps_taken + walkers_per_slot * slot_active.astype(
            jnp.int32
        ) * cfg.chunk_steps

        # early stopping: slot stops when n_high > n_p or budget exhausted
        slot_active = (
            valid_q
            & (steps_taken < n_q)
            & (high <= cfg.n_p)
        )
        return curr, counts, bcounts, high, steps_taken, slot_active, it + 1

    state0 = (
        query_of_walker,
        counts0,
        bcounts0,
        jnp.zeros((n_slots,), jnp.int32),
        jnp.zeros((n_slots,), jnp.int32),
        valid_q,
        jnp.asarray(0, jnp.int32),
    )
    curr, counts, bcounts, high, steps_taken, _, _ = jax.lax.while_loop(
        cond, body, state0
    )
    per_slot = counts.reshape(n_slots, n_pins)
    # never recommend the query pins themselves; the running tally counted
    # a query pin that reached n_v, so zeroing it must also debit the tally
    q_rows = jnp.arange(n_slots)
    q_reached = (per_slot[q_rows, safe_q] >= cfg.n_v).astype(jnp.int32)
    per_slot = per_slot.at[q_rows, safe_q].set(0)
    return WalkResult(
        counts=per_slot,
        board_counts=None
        if bcounts is None
        else bcounts.reshape(n_slots, graph.n_boards),
        steps_taken=steps_taken,
        n_high=high - q_reached,
    )


def basic_random_walk(
    graph: PinBoardGraph,
    query_pin: Array,
    key: Array,
    cfg: WalkConfig,
) -> Array:
    """Algorithm 1: unbiased, single query pin, fixed budget. -> (n_pins,)"""
    cfg_basic = dataclasses.replace(cfg, bias_beta=0.0).without_early_stop()
    res = pixie_random_walk(
        graph,
        jnp.asarray([query_pin], jnp.int32),
        jnp.ones((1,), jnp.float32),
        jnp.asarray(0, jnp.int32),
        key,
        cfg_basic,
    )
    return res.counts[0]


def recommend_with_stats(
    graph: PinBoardGraph,
    query_pins: Array,
    query_weights: Array,
    user_feat: Array,
    key: Array,
    cfg: WalkConfig,
    step_budget=None,
) -> Tuple[Array, Array, Array, Array]:
    """recommend plus walk telemetry -> (scores, ids, steps_taken, n_high).

    ``steps_taken``/``n_high`` are Algorithm 3's early-stop observables —
    the serving layer exports them so a fleet can see how much of the step
    budget early stopping is actually saving (paper §4's latency lever).
    ``step_budget`` is the optional per-lane Eq. 2 budget override
    (see ``pixie_random_walk``).
    """
    res = pixie_random_walk(
        graph, query_pins, query_weights, user_feat, key, cfg,
        step_budget=step_budget,
    )
    boosted = counter_lib.boost_combine(res.counts)
    scores, ids = counter_lib.topk_dense(boosted, cfg.top_k)
    return scores, ids, res.steps_taken, res.n_high


def recommend(
    graph: PinBoardGraph,
    query_pins: Array,
    query_weights: Array,
    user_feat: Array,
    key: Array,
    cfg: WalkConfig,
) -> Tuple[Array, Array]:
    """Full query path: walk -> Eq. 3 booster -> top-k (scores, pin ids).

    Dispatches on ``cfg.backend``: the whole walk loop runs on the fused
    Pallas engine when ``backend="pallas"``.
    """
    scores, ids, _, _ = recommend_with_stats(
        graph, query_pins, query_weights, user_feat, key, cfg
    )
    return scores, ids


# ---------------------------------------------------------------------------
# Batch-native multi-query walk: ONE fused engine for the whole serving batch
# ---------------------------------------------------------------------------


def pixie_random_walk_batched(
    graph: PinBoardGraph,
    query_pins: Array,     # (n_queries, n_slots) int32, padded with -1
    query_weights: Array,  # (n_queries, n_slots) float32, 0 for padding
    user_feats: Array,     # (n_queries,) int32 personalization features
    keys: Array,           # (n_queries,) per-query PRNG keys (random.split)
    cfg: WalkConfig,
    step_budgets: Optional[Array] = None,  # (n_queries,) int32 Eq. 2 totals
) -> WalkResult:
    """PIXIERANDOMWALKMULTIPLE over a whole serving batch, batch-natively.

    The bit-identical twin of ``jax.vmap(pixie_random_walk)`` over the same
    per-query keys — same counts, board counts, ``steps_taken`` and
    ``n_high`` for every batch size — but the batch is a first-class axis
    of the engine instead of a vmap wrapper:

      * every query's walkers are packed query-major along ONE walker axis,
        so each superstep chunk is a single fused call for the whole batch
        (with ``backend="pallas"``: one ``pallas_call`` per chunk, its DMA
        pipeline hiding latency behind ``n_queries * n_walkers`` rows,
        instead of a batch-sized leading grid dimension per query);
      * counting runs once per chunk over query-major ``(query, slot,
        pin)`` triple bins (``accumulate_packed_events_with_high`` with the
        query lane), not once per query over replicated dense buffers;
      * ONE shared ``while_loop`` carries a per-(query, slot) early-stop
        mask: a query that hits Algorithm 3's threshold stops emitting
        events and stops counting steps (its walker lanes are masked to
        the sentinel triple) while its batch neighbours keep walking —
        exactly the frozen-state semantics vmap gives the per-query loop.

    Per-query RNG streams are preserved exactly: walker ``q * w + i`` at
    global step ``s`` consumes the same ``_chunk_rbits(keys[q], ...)``
    draws as in the per-query engine.  Returns a ``WalkResult`` whose
    fields lead with the batch axis: counts ``(n_queries, n_slots,
    n_pins)``, board_counts ``(n_queries, n_slots, n_boards) | None``,
    steps_taken / n_high ``(n_queries, n_slots)``.

    ``step_budgets`` optionally overrides the Eq. 2 total PER QUERY LANE
    as data — the multi-interest layer rides its interest clusters on this
    axis, each with a budget proportional to cluster importance, and ragged
    users (different k) still share one compiled program because budgets
    are array values, not shapes.  Each budget is clamped to
    ``cfg.n_steps`` (the static chunk bound — a bigger budget could never
    be walked anyway); per-lane parity with the per-query engine at the
    same budget is preserved exactly.
    """
    if cfg.n_v < 1:
        raise ValueError(
            f"n_v must be >= 1, got {cfg.n_v}; use "
            "cfg.without_early_stop() to disable early stopping"
        )
    if query_pins.ndim != 2:
        raise ValueError(
            f"query_pins must be (n_queries, n_slots), got {query_pins.shape}"
        )
    n_queries, n_slots = query_pins.shape
    n_pins = graph.n_pins
    w = cfg.n_walkers
    n_rows = n_queries * n_slots
    n_boards_packed = graph.n_boards if cfg.count_boards else 0
    slot_sentinel = jnp.int32(n_slots)
    query_sentinel = jnp.int32(n_queries)
    # the dense buffers materialize n_queries * n_slots * n_pins bins
    count_engine = select_count_engine(
        cfg.backend, n_rows, n_pins, n_boards_packed
    )

    valid_q = (query_pins >= 0) & (query_weights > 0)          # (B, S)
    safe_q = jnp.where(valid_q, query_pins, 0)
    degs = graph.pin_degree(safe_q) * valid_q.astype(graph.p2b.offsets.dtype)

    # Eq. 1-2 per query — the same traced program the vmapped path runs
    if step_budgets is None:
        n_q = jax.vmap(
            lambda v, qw, dg: sampling.allocate_steps(
                jnp.where(v, qw, 0.0), dg,
                jnp.asarray(graph.max_pin_degree), cfg.n_steps,
            )
        )(valid_q, query_weights, degs)                        # (B, S)
    else:
        n_q = jax.vmap(
            lambda v, qw, dg, bt: sampling.allocate_steps(
                jnp.where(v, qw, 0.0), dg,
                jnp.asarray(graph.max_pin_degree), bt,
            )
        )(valid_q, query_weights, degs,
          jnp.minimum(jnp.asarray(step_budgets, jnp.int32),
                      cfg.n_steps))                            # (B, S)
    slot_of_walker_q, _ = jax.vmap(
        lambda nq: sampling.allocate_walkers(nq, w)
    )(n_q)                                                     # (B, w)
    query_of_walker_q = jax.vmap(jnp.take)(safe_q, slot_of_walker_q)
    walkers_per_slot = jax.vmap(
        lambda so: jax.ops.segment_sum(
            jnp.ones((w,), jnp.int32), so, num_segments=n_slots
        )
    )(slot_of_walker_q).reshape(-1)                            # (B*S,)

    # query-major walker packing: walkers of query q occupy [q*w, (q+1)*w)
    qid_of_walker = jnp.repeat(jnp.arange(n_queries, dtype=jnp.int32), w)
    slot_of_walker = slot_of_walker_q.reshape(-1).astype(jnp.int32)
    query_of_walker = query_of_walker_q.reshape(-1).astype(jnp.int32)
    feat_of_walker = jnp.repeat(jnp.asarray(user_feats, jnp.int32), w)
    row_of_walker = qid_of_walker * n_slots + slot_of_walker

    counts0 = jnp.zeros((n_rows * n_pins,), dtype=jnp.int32)
    bcounts0 = (
        jnp.zeros((n_rows * graph.n_boards,), dtype=jnp.int32)
        if cfg.count_boards
        else None
    )
    valid_row = valid_q.reshape(-1)
    n_q_row = n_q.reshape(-1)

    def cond(state):
        _, _, _, _, _, row_active, it = state
        return jnp.any(row_active) & (it < cfg.max_chunks())

    def body(state):
        curr, counts, bcounts, high, steps_taken, row_active, it = state
        step_base = it * cfg.chunk_steps
        walker_active = jnp.take(row_active, row_of_walker)

        curr2, qev, sev, pev, bev = _walk_chunk_batched(
            graph, curr, query_of_walker, feat_of_walker, slot_of_walker,
            qid_of_walker, keys, step_base, cfg, n_slots, n_queries,
        )
        curr = jnp.where(walker_active, curr2, curr)
        # masking the shared lanes to the sentinel triple invalidates pin
        # AND board events of stopped queries/slots
        qev = jnp.where(walker_active[None, :], qev, query_sentinel)
        sev = jnp.where(walker_active[None, :], sev, slot_sentinel)
        # fused: ONE call accumulates the whole batch's chunk AND updates
        # every (query, slot) running n_high tally — no per-query loop, no
        # n_rows * n_pins reduction anywhere in this body
        counts, high = counter_lib.accumulate_packed_events_with_high(
            counts, high, sev, pev, n_slots, n_pins, cfg.n_v, count_engine,
            query_events=qev, n_queries=n_queries,
        )
        if cfg.count_boards:
            bcounts = counter_lib.accumulate_packed_events(
                bcounts, sev, bev, n_slots, graph.n_boards, count_engine,
                query_events=qev, n_queries=n_queries,
            )

        steps_taken = steps_taken + walkers_per_slot * row_active.astype(
            jnp.int32
        ) * cfg.chunk_steps

        # per-(query, slot) early stopping, exactly the per-query rule
        row_active = (
            valid_row
            & (steps_taken < n_q_row)
            & (high <= cfg.n_p)
        )
        return curr, counts, bcounts, high, steps_taken, row_active, it + 1

    state0 = (
        query_of_walker,
        counts0,
        bcounts0,
        jnp.zeros((n_rows,), jnp.int32),
        jnp.zeros((n_rows,), jnp.int32),
        valid_row,
        jnp.asarray(0, jnp.int32),
    )
    curr, counts, bcounts, high, steps_taken, _, _ = jax.lax.while_loop(
        cond, body, state0
    )
    per_slot = counts.reshape(n_queries, n_slots, n_pins)
    # never recommend the query pins themselves; debit the tally like the
    # per-query engine does
    b_idx = jnp.arange(n_queries)[:, None]
    s_idx = jnp.arange(n_slots)[None, :]
    q_reached = (per_slot[b_idx, s_idx, safe_q] >= cfg.n_v).astype(jnp.int32)
    per_slot = per_slot.at[b_idx, s_idx, safe_q].set(0)
    return WalkResult(
        counts=per_slot,
        board_counts=None
        if bcounts is None
        else bcounts.reshape(n_queries, n_slots, graph.n_boards),
        steps_taken=steps_taken.reshape(n_queries, n_slots),
        n_high=(high - q_reached.reshape(-1)).reshape(n_queries, n_slots),
    )


def recommend_with_stats_batched(
    graph: PinBoardGraph,
    query_pins: Array,     # (n_queries, n_slots)
    query_weights: Array,  # (n_queries, n_slots)
    user_feats: Array,     # (n_queries,)
    keys: Array,           # (n_queries,) per-query PRNG keys
    cfg: WalkConfig,
    step_budgets: Optional[Array] = None,
) -> Tuple[Array, Array, Array, Array]:
    """Batch-native ``recommend_with_stats``: one fused engine, whole batch.

    Returns ``(scores (B, top_k), ids (B, top_k), steps_taken (B, n_slots),
    n_high (B, n_slots))`` — bit-identical to vmapping
    ``recommend_with_stats`` over the same per-query keys; the walk runs on
    the batch-native engine and only the cheap Eq. 3 booster / top-k run
    under vmap.  ``step_budgets`` is the optional (B,) per-lane Eq. 2
    budget override (see ``pixie_random_walk_batched``).
    """
    res = pixie_random_walk_batched(
        graph, query_pins, query_weights, user_feats, keys, cfg,
        step_budgets=step_budgets,
    )
    boosted = jax.vmap(counter_lib.boost_combine)(res.counts)
    scores, ids = jax.vmap(lambda b: counter_lib.topk_dense(b, cfg.top_k))(
        boosted
    )
    return scores, ids, res.steps_taken, res.n_high


# ---------------------------------------------------------------------------
# Multi-interest merge: Eq. 3 across a user's interest-cluster lanes
# ---------------------------------------------------------------------------

# id-lane sentinel that sorts AFTER every real pin id
_MERGE_ID_SENTINEL = jnp.iinfo(jnp.int32).max


def merge_interest_topk(
    scores: Array,      # (k, top_k) float32 per-cluster boosted scores
    ids: Array,         # (k, top_k) int32 per-cluster pin ids, -1 padded
    importance: Array,  # (k,) float32 cluster importance, 0 for pad lanes
    top_k: Optional[int] = None,
) -> Tuple[Array, Array]:
    """Merge one user's per-cluster top-k lists: Eq. 3 across clusters.

    The multi-hit booster applied a second time at the USER level:

        V[p] = (sum_c I_c * sqrt(V_c[p]))**2

    — the importance-weighted form of ``counter_lib.boost_combine``, so a
    pin surfacing in several of the user's interest clusters beats a
    same-mass single-cluster pin, exactly the paper's Eq. 3 rationale.

    Bit-reproducible BY CONSTRUCTION, which is what lets the fused serving
    path and the per-cluster oracle share this function and agree
    bit-identically (verdict ``multi_interest_agrees``):

      * entries are canonically ordered first — ``lax.sort`` on
        (id, contribution) — so equal inputs reach the sum in one order
        no matter how lanes were produced;
      * per-id sums are explicit left-to-right shift-adds (run length is
        bounded by k: within a lane ids are distinct), never a float
        ``Reduce`` whose association XLA may retile per program shape;
      * ties in the final top-k break on the id-sorted entry index, i.e.
        by ascending pin id — deterministic across batch compositions.

    Lanes with ``importance <= 0`` are padding (ragged users).  A user
    with exactly ONE live lane passes its lane through VERBATIM — k=1
    collapses bit-identically to the flat homefeed path instead of
    round-tripping scores through sqrt/square.

    Returns ``(scores (top_k,), ids (top_k,))``, id -1 / score 0 padded,
    with ``top_k`` defaulting to the per-lane top_k.
    """
    if scores.ndim != 2 or scores.shape != ids.shape:
        raise ValueError(
            f"scores/ids must be matching (k, top_k), got {scores.shape} "
            f"vs {ids.shape}"
        )
    k, per_lane_k = scores.shape
    out_k = per_lane_k if top_k is None else top_k
    live_lane = importance > 0
    valid = live_lane[:, None] & (ids >= 0) & (scores > 0)
    contrib = jnp.where(
        valid, importance[:, None] * jnp.sqrt(scores), 0.0
    ).reshape(-1)
    sort_ids = jnp.where(valid, ids, _MERGE_ID_SENTINEL).reshape(-1)
    sid, sc = jax.lax.sort((sort_ids, contrib), num_keys=2)

    # left-to-right sequential per-id sums via shift-adds: a pin appears in
    # at most k lanes (per-lane ids are distinct), so k-1 shifted adds
    # cover every run; each pass appends exactly one term to the running
    # sum, so the association is a fixed left-to-right chain — elementwise
    # adds XLA cannot reassociate, unlike a Reduce
    acc = sc
    for d in range(1, k):
        same = jnp.concatenate(
            [sid[d:] == sid[:-d],
             jnp.zeros((d,), bool)]
        )
        shifted = jnp.concatenate([sc[d:], jnp.zeros((d,), sc.dtype)])
        acc = acc + jnp.where(same, shifted, 0.0)

    first = jnp.concatenate(
        [jnp.ones((1,), bool), sid[1:] != sid[:-1]]
    )
    owner = first & (sid != _MERGE_ID_SENTINEL)
    merged = jnp.where(owner, acc * acc, -jnp.inf)
    vals, idx = jax.lax.top_k(merged, out_k)
    got = vals > -jnp.inf
    merged_scores = jnp.where(got, vals, 0.0).astype(scores.dtype)
    merged_ids = jnp.where(got, jnp.take(sid, idx), -1).astype(jnp.int32)

    # exact k=1 collapse: a single live lane is returned verbatim
    if out_k == per_lane_k:
        single = jnp.sum(live_lane.astype(jnp.int32)) == 1
        lane = jnp.argmax(live_lane)
        merged_scores = jnp.where(single, scores[lane], merged_scores)
        merged_ids = jnp.where(single, ids[lane], merged_ids)
    return merged_scores, merged_ids


# ---------------------------------------------------------------------------
# Event-mode walk — scale-free path used by the sharded production graph
# ---------------------------------------------------------------------------


def pixie_walk_events(
    graph: PinBoardGraph,
    query_pins: Array,
    query_weights: Array,
    user_feat: Array,
    key: Array,
    cfg: WalkConfig,
    check_every: int = 4,
    check_mode: str = "incremental",
) -> EventWalkResult:
    """Event-buffer walk: O(N) memory independent of graph size AND id space.

    The wide (slot, pin) lane buffers play the role of the paper's N-sized
    hash table; because no lane ever holds the packed ``slot * n_pins +
    pin`` product, this path serves packed id spaces past 2**31 (8 slots x
    2**28 pins and beyond) on either backend with plain int32.  With
    ``backend="pallas"`` the lanes come straight out of the fused kernel
    and are appended to the buffers — no packing arithmetic in XLA at all.

    Early stopping checks every ``check_every`` chunks.  ``check_mode``:

      * ``"incremental"`` (default) — the check body folds ONLY the new
        window's events into a carried ``counter_lib.EventHighState``
        (sorted runs per window + running tally): O(window log window) per
        check, no sort over the ``max_events`` buffer anywhere in the loop
        (pinned by jaxpr inspection in tests/test_widepack.py).
      * ``"full"`` — the pre-incremental formulation (re-sort the whole
        buffer each check via ``events_n_high_per_slot``); kept as the
        bit-identical oracle the incremental path is verified against.
    """
    if cfg.n_v < 1:
        # same contract as the dense engine: n_v=0 would mark every touched
        # run "hot" and silently truncate the walk at the first check
        raise ValueError(
            f"n_v must be >= 1, got {cfg.n_v}; use "
            "cfg.without_early_stop() to disable early stopping"
        )
    if check_mode not in ("incremental", "full"):
        raise ValueError(
            f"unknown check_mode {check_mode!r}; use 'incremental' or 'full'"
        )
    if cfg.count_boards:
        # event mode only buffers pin visits; don't make the chunk engine
        # emit board events nobody reads
        cfg = dataclasses.replace(cfg, count_boards=False)
    n_slots = query_pins.shape[0]
    n_pins = graph.n_pins
    w = cfg.n_walkers
    per_chunk = w * cfg.chunk_steps
    max_chunks = cfg.max_chunks()
    max_events = max_chunks * per_chunk
    slot_sentinel = jnp.int32(n_slots)
    # number of check windows that can actually fire; sizes the run-segment
    # state (check_every past max_chunks means checks never fire at all —
    # e.g. the check_every=10**9 idiom — and must not size anything)
    n_windows = max_chunks // check_every
    seg_cap = check_every * per_chunk

    valid_q = (query_pins >= 0) & (query_weights > 0)
    safe_q = jnp.where(valid_q, query_pins, 0)
    degs = graph.pin_degree(safe_q) * valid_q.astype(graph.p2b.offsets.dtype)
    n_q = sampling.allocate_steps(
        jnp.where(valid_q, query_weights, 0.0),
        degs,
        jnp.asarray(graph.max_pin_degree),
        cfg.n_steps,
    )
    slot_of_walker, _ = sampling.allocate_walkers(n_q, w)
    query_of_walker = jnp.take(safe_q, slot_of_walker).astype(jnp.int32)
    walkers_per_slot = jax.ops.segment_sum(
        jnp.ones((w,), jnp.int32), slot_of_walker, num_segments=n_slots
    )

    sev0 = jnp.full((max_events,), slot_sentinel, jnp.int32)
    pev0 = jnp.zeros((max_events,), jnp.int32)
    incremental = check_mode == "incremental" and n_windows > 0
    hstate0 = counter_lib.events_high_init(
        n_slots, n_windows if incremental else 0, seg_cap if incremental else 1
    )

    def cond(state):
        _, _, _, _, _, slot_active, it = state
        return jnp.any(slot_active) & (it < max_chunks)

    def body(state):
        curr, sev_buf, pev_buf, hstate, steps_taken, slot_active, it = state
        step_base = it * cfg.chunk_steps
        walker_active = jnp.take(slot_active, slot_of_walker)
        curr2, sev, pev, _ = _walk_chunk(
            graph, curr, query_of_walker, user_feat, slot_of_walker,
            key, step_base, cfg, n_slots,
        )
        curr = jnp.where(walker_active, curr2, curr)
        # mask BOTH lanes: sentinel events are uniformly (n_slots, 0), the
        # kernel's own convention, so aggregated run arrays stay sorted
        # end to end (events_high_fold binary-searches them)
        sev = jnp.where(
            walker_active[None, :], sev, slot_sentinel
        ).reshape(-1)
        pev = jnp.where(walker_active[None, :], pev, 0).reshape(-1)
        off = it * per_chunk
        sev_buf = jax.lax.dynamic_update_slice(sev_buf, sev, (off,))
        pev_buf = jax.lax.dynamic_update_slice(pev_buf, pev, (off,))
        steps_taken = steps_taken + walkers_per_slot * slot_active.astype(
            jnp.int32
        ) * cfg.chunk_steps

        do_check = (it + 1) % check_every == 0

        if incremental:

            def check(args):
                sev_buf, pev_buf, hstate, steps_taken, it = args
                # fold ONLY this window's events: the last check_every
                # chunks, ending at the chunk just written
                start = (it + 1) * per_chunk - seg_cap
                hstate = counter_lib.events_high_fold(
                    hstate,
                    jax.lax.dynamic_slice(sev_buf, (start,), (seg_cap,)),
                    jax.lax.dynamic_slice(pev_buf, (start,), (seg_cap,)),
                    n_slots, n_pins, cfg.n_v, seg_cap=seg_cap,
                )
                active = (
                    valid_q & (steps_taken < n_q) & (hstate.high <= cfg.n_p)
                )
                return active, hstate

        else:

            def check(args):
                sev_buf, pev_buf, hstate, steps_taken, it = args
                n_high = counter_lib.events_n_high_per_slot(
                    sev_buf, pev_buf, n_slots, n_pins, cfg.n_v, max_events
                )
                hstate = hstate._replace(high=n_high)
                return valid_q & (steps_taken < n_q) & (
                    n_high <= cfg.n_p
                ), hstate

        slot_active, hstate = jax.lax.cond(
            do_check,
            check,
            lambda args: (valid_q & (args[3] < n_q), args[2]),
            (sev_buf, pev_buf, hstate, steps_taken, it),
        )
        return curr, sev_buf, pev_buf, hstate, steps_taken, slot_active, it + 1

    state0 = (
        query_of_walker,
        sev0,
        pev0,
        hstate0,
        jnp.zeros((n_slots,), jnp.int32),
        valid_q,
        jnp.asarray(0, jnp.int32),
    )
    _, sev_buf, pev_buf, hstate, steps_taken, _, it = jax.lax.while_loop(
        cond, body, state0
    )
    return EventWalkResult(
        slot_events=sev_buf,
        pin_events=pev_buf,
        steps_taken=steps_taken,
        chunks_run=it,
        n_high=hstate.high,
    )


def pixie_walk_events_fixed(
    graph: PinBoardGraph,
    query_pins: Array,
    query_weights: Array,
    user_feat: Array,
    key: Array,
    cfg: WalkConfig,
    n_chunks: int,
    unroll: bool = True,
) -> EventWalkResult:
    """Cost-model twin of pixie_walk_events: exactly n_chunks chunks via an
    unrolled scan (no early stopping, no while loop).

    Exists because XLA's cost analysis counts while-loop bodies ONCE; the
    dry-run lowers this variant at n_chunks = 1 and 2 and extrapolates the
    linear-in-chunks cost to cfg.max_chunks() (launch/dryrun.py).
    """
    if cfg.count_boards:
        cfg = dataclasses.replace(cfg, count_boards=False)
    n_slots = query_pins.shape[0]
    w = cfg.n_walkers

    valid_q = (query_pins >= 0) & (query_weights > 0)
    safe_q = jnp.where(valid_q, query_pins, 0)
    degs = graph.pin_degree(safe_q) * valid_q.astype(graph.p2b.offsets.dtype)
    n_q = sampling.allocate_steps(
        jnp.where(valid_q, query_weights, 0.0),
        degs,
        jnp.asarray(graph.max_pin_degree),
        cfg.n_steps,
    )
    slot_of_walker, _ = sampling.allocate_walkers(n_q, w)
    query_of_walker = jnp.take(safe_q, slot_of_walker).astype(jnp.int32)

    def body(curr, it):
        step_base = it * cfg.chunk_steps
        curr2, sev, pev, _ = _walk_chunk(
            graph, curr, query_of_walker, user_feat, slot_of_walker,
            key, step_base, cfg, n_slots, unroll=unroll,
        )
        return curr2, (sev.reshape(-1), pev.reshape(-1))

    curr, (sev_chunks, pev_chunks) = jax.lax.scan(
        body, query_of_walker, jnp.arange(n_chunks), unroll=True
    )
    steps = jnp.full((n_slots,), n_chunks * cfg.chunk_steps, jnp.int32)
    return EventWalkResult(
        slot_events=sev_chunks.reshape(-1),
        pin_events=pev_chunks.reshape(-1),
        steps_taken=steps,
        chunks_run=jnp.asarray(n_chunks, jnp.int32),
        n_high=jnp.zeros((n_slots,), jnp.int32),
    )


def recommend_from_events(
    result: EventWalkResult,
    n_slots: int,
    n_pins: int,
    query_pins: Array,
    top_k: int,
) -> Tuple[Array, Array]:
    """Eq. 3 + top-k from wide event lane buffers. -> (scores, pin ids).

    Pure pair-sort aggregation on the int32 lanes: serves id spaces past
    2**31 packed ids without 64-bit arithmetic anywhere.
    """
    max_events = result.slot_events.shape[0]
    uniq_slot, uniq_pin, counts = counter_lib.events_to_counts(
        result.slot_events, result.pin_events, n_slots, max_events
    )
    pin_ids, boosted = counter_lib.boosted_from_events(
        uniq_slot, uniq_pin, counts, n_slots, n_pins, max_events
    )
    # mask out query pins
    is_query = jnp.isin(pin_ids, query_pins)
    boosted = jnp.where(is_query, 0.0, boosted)
    return counter_lib.topk_events(pin_ids, boosted, top_k)
