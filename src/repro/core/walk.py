"""The Pixie Random Walk engine (paper §3.1, Algorithms 1-3), vectorized.

The paper's walk is sequential pointer chasing; the TPU-native form runs W
independent walkers in lockstep.  One *step* for every walker is:

    maybe-restart -> sample board from E(pin) -> sample pin from E(board)
    -> record visit

which is exactly Algorithm 2's inner loop, with ``SampleWalkLength(alpha)``
realised as a per-step Bernoulli(alpha) restart (geometric segment lengths,
E[len] = 1/alpha; see core/sampling.py).

Two counting backends (see core/counter.py):
  * dense  — per-(query-slot, pin) scatter-add counts; benchmark-scale and
             per-shard production counting.
  * events — bounded (slot, pin) event buffer + sort aggregation; scale-free,
             memory O(N) like the paper's hash table.

Early stopping (Algorithm 2 lines 10-13) is evaluated every chunk: a query
slot stops once >= n_p pins reached n_v visits or its step budget N_q is
spent; the whole walk stops when every slot stopped.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import counter as counter_lib
from repro.core import sampling
from repro.core.graph import PinBoardGraph

Array = jax.Array


def packed_event_dtype(n_slots: int, n_pins: int):
    """Smallest int dtype that can hold packed (slot, pin) event ids.

    int32 covers every benchmark-scale graph; the 3B-pin production graph
    needs int64 (the dry-run launcher enables jax_enable_x64).
    """
    if n_slots * n_pins + 1 < 2**31:
        return jnp.int32
    return jnp.int64


@dataclasses.dataclass(frozen=True)
class WalkConfig:
    """Hyper-parameters of the Pixie random walk.

    n_steps:      N — total step budget across all query pins (Eq. 2).
    alpha:        restart probability; E[walk segment] = 1/alpha.
    n_walkers:    number of parallel walkers (TPU adaptation; the paper's
                  sequential walker is n_walkers=1).
    chunk_steps:  steps fused per while-loop iteration between early-stop
                  checks (the paper checks per step; chunking trades slack
                  for device efficiency).
    n_p, n_v:     early-stopping thresholds (>= n_p pins with >= n_v visits).
    bias_beta:    probability a step uses the personalized feature subrange
                  (PersonalizedNeighbor); 0 disables biasing (Algorithm 1).
    top_k:        number of recommendations extracted from the counter.
    count_boards: also accumulate board visit counts (for board recs, §5.3).
    """

    n_steps: int = 100_000
    alpha: float = 0.5
    n_walkers: int = 1024
    chunk_steps: int = 8
    n_p: int = 2_000
    n_v: int = 4
    bias_beta: float = 0.9
    top_k: int = 1_000
    count_boards: bool = False

    def max_chunks(self) -> int:
        per_chunk = self.n_walkers * self.chunk_steps
        return max(1, -(-self.n_steps // per_chunk))


class WalkResult(NamedTuple):
    """Dense-mode walk output."""

    counts: Array           # (n_slots, n_pins) int32 per-query visit counts
    board_counts: Optional[Array]  # (n_slots, n_boards) or None
    steps_taken: Array      # (n_slots,) int32
    n_high: Array           # (n_slots,) int32 pins that reached n_v visits


class EventWalkResult(NamedTuple):
    """Event-mode walk output (scale-free)."""

    events: Array           # (max_events,) int64 packed slot*n_pins+pin
    steps_taken: Array      # (n_slots,) int32
    chunks_run: Array       # () int32


# ---------------------------------------------------------------------------
# One chunk of steps for all walkers (shared by both modes)
# ---------------------------------------------------------------------------


def _walk_chunk(
    graph: PinBoardGraph,
    curr: Array,          # (W,) int32 current pin per walker
    query_of_walker: Array,  # (W,) int32 restart target
    user_feat: Array,     # () or (W,) int32 personalization feature
    key: Array,
    step_base: Array,     # () int32 global step counter (for counter RNG)
    cfg: WalkConfig,
    unroll: bool = False,
) -> Tuple[Array, Array, Array]:
    """Run cfg.chunk_steps steps; return (new_curr, visited, valid).

    visited/valid: (chunk_steps, W) — pin visited at each step and whether
    the visit is countable (False when a dead-end forced a restart).
    ``unroll`` replaces the fori_loop with a Python loop (cost-model mode).
    """
    w = curr.shape[0]

    def body(i, carry):
        curr, visited, valid = carry
        k = sampling.step_key(key, step_base + i)
        k_restart, k_bias, k_board, k_pin = jax.random.split(k, 4)

        # (1) restart with probability alpha (SampleWalkLength(alpha))
        restart = jax.random.bernoulli(k_restart, p=cfg.alpha, shape=(w,))
        pos = jnp.where(restart, query_of_walker, curr)

        # (2) pin -> board hop, personalized with prob bias_beta
        r_board = jax.random.randint(k_board, (w,), 0, jnp.iinfo(jnp.int32).max)
        use_bias = jax.random.bernoulli(k_bias, p=cfg.bias_beta, shape=(w,))
        if graph.p2b.feat_bounds is not None and cfg.bias_beta > 0.0:
            board_biased = graph.p2b.biased_neighbor(pos, r_board, user_feat)
            board_uni = graph.p2b.neighbor(pos, r_board)
            board = jnp.where(use_bias, board_biased, board_uni)
        else:
            board = graph.p2b.neighbor(pos, r_board)

        # (3) board -> pin hop
        r_pin = jax.random.randint(k_pin, (w,), 0, jnp.iinfo(jnp.int32).max)
        board_ok = board >= 0
        board_local = jnp.where(board_ok, board - graph.n_pins, 0)
        if graph.b2p.feat_bounds is not None and cfg.bias_beta > 0.0:
            pin_biased = graph.b2p.biased_neighbor(board_local, r_pin, user_feat)
            pin_uni = graph.b2p.neighbor(board_local, r_pin)
            nxt = jnp.where(use_bias, pin_biased, pin_uni)
        else:
            nxt = graph.b2p.neighbor(board_local, r_pin)
        ok = board_ok & (nxt >= 0)

        # dead ends restart (uncounted), matching a fresh SampleWalkLength
        new_curr = jnp.where(ok, nxt, query_of_walker).astype(curr.dtype)
        visited = visited.at[i].set(jnp.where(ok, new_curr, 0))
        valid = valid.at[i].set(ok)
        return new_curr, visited, valid

    visited0 = jnp.zeros((cfg.chunk_steps, w), dtype=curr.dtype)
    valid0 = jnp.zeros((cfg.chunk_steps, w), dtype=bool)
    if unroll:
        carry = (curr, visited0, valid0)
        for i in range(cfg.chunk_steps):
            carry = body(i, carry)
        return carry
    return jax.lax.fori_loop(0, cfg.chunk_steps, body, (curr, visited0, valid0))


def _walk_chunk_boards(
    graph: PinBoardGraph,
    curr: Array,
    query_of_walker: Array,
    user_feat: Array,
    key: Array,
    step_base: Array,
    cfg: WalkConfig,
) -> Tuple[Array, Array, Array, Array]:
    """Like _walk_chunk but also records the intermediate board hop."""
    w = curr.shape[0]

    def body(i, carry):
        curr, visited, valid, boards = carry
        k = sampling.step_key(key, step_base + i)
        k_restart, k_bias, k_board, k_pin = jax.random.split(k, 4)
        restart = jax.random.bernoulli(k_restart, p=cfg.alpha, shape=(w,))
        pos = jnp.where(restart, query_of_walker, curr)
        r_board = jax.random.randint(k_board, (w,), 0, jnp.iinfo(jnp.int32).max)
        use_bias = jax.random.bernoulli(k_bias, p=cfg.bias_beta, shape=(w,))
        if graph.p2b.feat_bounds is not None and cfg.bias_beta > 0.0:
            board = jnp.where(
                use_bias,
                graph.p2b.biased_neighbor(pos, r_board, user_feat),
                graph.p2b.neighbor(pos, r_board),
            )
        else:
            board = graph.p2b.neighbor(pos, r_board)
        r_pin = jax.random.randint(k_pin, (w,), 0, jnp.iinfo(jnp.int32).max)
        board_ok = board >= 0
        board_local = jnp.where(board_ok, board - graph.n_pins, 0)
        if graph.b2p.feat_bounds is not None and cfg.bias_beta > 0.0:
            nxt = jnp.where(
                use_bias,
                graph.b2p.biased_neighbor(board_local, r_pin, user_feat),
                graph.b2p.neighbor(board_local, r_pin),
            )
        else:
            nxt = graph.b2p.neighbor(board_local, r_pin)
        ok = board_ok & (nxt >= 0)
        new_curr = jnp.where(ok, nxt, query_of_walker).astype(curr.dtype)
        visited = visited.at[i].set(jnp.where(ok, new_curr, 0))
        valid = valid.at[i].set(ok)
        boards = boards.at[i].set(jnp.where(board_ok, board_local, 0))
        return new_curr, visited, valid, boards

    visited0 = jnp.zeros((cfg.chunk_steps, w), dtype=curr.dtype)
    valid0 = jnp.zeros((cfg.chunk_steps, w), dtype=bool)
    boards0 = jnp.zeros((cfg.chunk_steps, w), dtype=curr.dtype)
    return jax.lax.fori_loop(
        0, cfg.chunk_steps, body, (curr, visited0, valid0, boards0)
    )


# ---------------------------------------------------------------------------
# Dense-mode multi-query walk (Algorithms 2 + 3)
# ---------------------------------------------------------------------------


def pixie_random_walk(
    graph: PinBoardGraph,
    query_pins: Array,     # (n_slots,) int32, padded with -1
    query_weights: Array,  # (n_slots,) float32, 0 for padding
    user_feat: Array,      # () int32 personalization feature (e.g. language)
    key: Array,
    cfg: WalkConfig,
) -> WalkResult:
    """PIXIERANDOMWALKMULTIPLE: biased, weighted, early-stopped, boosted.

    Returns dense per-slot visit counts; combine with
    ``counter_lib.boost_combine`` + ``topk_dense`` for recommendations.
    """
    n_slots = query_pins.shape[0]
    n_pins = graph.n_pins
    w = cfg.n_walkers

    valid_q = (query_pins >= 0) & (query_weights > 0)
    safe_q = jnp.where(valid_q, query_pins, 0)
    degs = graph.pin_degree(safe_q) * valid_q.astype(graph.p2b.offsets.dtype)

    # Eq. 1-2: per-slot step budgets; walker pool apportioned to match.
    n_q = sampling.allocate_steps(
        jnp.where(valid_q, query_weights, 0.0),
        degs,
        jnp.asarray(graph.max_pin_degree),
        cfg.n_steps,
    )
    slot_of_walker, _ = sampling.allocate_walkers(n_q, w)
    query_of_walker = jnp.take(safe_q, slot_of_walker).astype(jnp.int32)

    counts0 = jnp.zeros((n_slots * n_pins,), dtype=jnp.int32)
    bcounts0 = (
        jnp.zeros((n_slots * graph.n_boards,), dtype=jnp.int32)
        if cfg.count_boards
        else None
    )
    walkers_per_slot = jax.ops.segment_sum(
        jnp.ones((w,), jnp.int32), slot_of_walker, num_segments=n_slots
    )

    def cond(state):
        _, _, _, steps_taken, slot_active, it = state
        return jnp.any(slot_active) & (it < cfg.max_chunks())

    def body(state):
        curr, counts, bcounts, steps_taken, slot_active, it = state
        step_base = it * cfg.chunk_steps
        walker_active = jnp.take(slot_active, slot_of_walker)

        if cfg.count_boards:
            curr2, visited, valid, boards = _walk_chunk_boards(
                graph, curr, query_of_walker, user_feat, key, step_base, cfg
            )
        else:
            curr2, visited, valid = _walk_chunk(
                graph, curr, query_of_walker, user_feat, key, step_base, cfg
            )
            boards = None
        curr = jnp.where(walker_active, curr2, curr)
        valid = valid & walker_active[None, :]

        # scatter events into flat (slot, pin) counts
        idt = packed_event_dtype(n_slots, max(n_pins, graph.n_boards))
        slot_b = jnp.broadcast_to(slot_of_walker[None, :], visited.shape)
        flat_idx = slot_b.astype(idt) * n_pins + visited.astype(idt)
        counts = counts.at[jnp.where(valid, flat_idx, 0)].add(
            valid.astype(jnp.int32), mode="drop"
        )
        if cfg.count_boards:
            bflat = slot_b.astype(idt) * graph.n_boards + boards.astype(idt)
            bvalid = valid  # board hop validity coincides with pin validity
            bcounts = bcounts.at[jnp.where(bvalid, bflat, 0)].add(
                bvalid.astype(jnp.int32), mode="drop"
            )

        steps_taken = steps_taken + walkers_per_slot * slot_active.astype(
            jnp.int32
        ) * cfg.chunk_steps

        # early stopping: slot stops when n_high > n_p or budget exhausted
        per_slot = counts.reshape(n_slots, n_pins)
        n_high = counter_lib.n_high_visited(per_slot, cfg.n_v)
        slot_active = (
            valid_q
            & (steps_taken < n_q)
            & (n_high <= cfg.n_p)
        )
        return curr, counts, bcounts, steps_taken, slot_active, it + 1

    state0 = (
        query_of_walker,
        counts0,
        bcounts0,
        jnp.zeros((n_slots,), jnp.int32),
        valid_q,
        jnp.asarray(0, jnp.int32),
    )
    curr, counts, bcounts, steps_taken, _, _ = jax.lax.while_loop(
        cond, body, state0
    )
    per_slot = counts.reshape(n_slots, n_pins)
    # never recommend the query pins themselves
    per_slot = per_slot.at[jnp.arange(n_slots), safe_q].set(0)
    n_high = counter_lib.n_high_visited(per_slot, cfg.n_v)
    return WalkResult(
        counts=per_slot,
        board_counts=None
        if bcounts is None
        else bcounts.reshape(n_slots, graph.n_boards),
        steps_taken=steps_taken,
        n_high=n_high,
    )


def basic_random_walk(
    graph: PinBoardGraph,
    query_pin: Array,
    key: Array,
    cfg: WalkConfig,
) -> Array:
    """Algorithm 1: unbiased, single query pin, fixed budget. -> (n_pins,)"""
    cfg_basic = dataclasses.replace(
        cfg, bias_beta=0.0, n_p=cfg.n_steps + 1, n_v=jnp.iinfo(jnp.int32).max // 2
    )
    res = pixie_random_walk(
        graph,
        jnp.asarray([query_pin], jnp.int32),
        jnp.ones((1,), jnp.float32),
        jnp.asarray(0, jnp.int32),
        key,
        cfg_basic,
    )
    return res.counts[0]


def recommend(
    graph: PinBoardGraph,
    query_pins: Array,
    query_weights: Array,
    user_feat: Array,
    key: Array,
    cfg: WalkConfig,
) -> Tuple[Array, Array]:
    """Full query path: walk -> Eq. 3 booster -> top-k (scores, pin ids)."""
    res = pixie_random_walk(graph, query_pins, query_weights, user_feat, key, cfg)
    boosted = counter_lib.boost_combine(res.counts)
    return counter_lib.topk_dense(boosted, cfg.top_k)


# ---------------------------------------------------------------------------
# Event-mode walk — scale-free path used by the sharded production graph
# ---------------------------------------------------------------------------


def pixie_walk_events(
    graph: PinBoardGraph,
    query_pins: Array,
    query_weights: Array,
    user_feat: Array,
    key: Array,
    cfg: WalkConfig,
    check_every: int = 4,
) -> EventWalkResult:
    """Event-buffer walk: O(N) memory independent of graph size.

    The event buffer plays the role of the paper's N-sized hash table;
    early stopping re-aggregates the buffer every ``check_every`` chunks.
    """
    n_slots = query_pins.shape[0]
    n_pins = graph.n_pins
    w = cfg.n_walkers
    per_chunk = w * cfg.chunk_steps
    max_chunks = cfg.max_chunks()
    max_events = max_chunks * per_chunk
    idt = packed_event_dtype(n_slots, n_pins)
    sentinel = jnp.asarray(n_slots * n_pins, dtype=idt)

    valid_q = (query_pins >= 0) & (query_weights > 0)
    safe_q = jnp.where(valid_q, query_pins, 0)
    degs = graph.pin_degree(safe_q) * valid_q.astype(graph.p2b.offsets.dtype)
    n_q = sampling.allocate_steps(
        jnp.where(valid_q, query_weights, 0.0),
        degs,
        jnp.asarray(graph.max_pin_degree),
        cfg.n_steps,
    )
    slot_of_walker, _ = sampling.allocate_walkers(n_q, w)
    query_of_walker = jnp.take(safe_q, slot_of_walker).astype(jnp.int32)
    walkers_per_slot = jax.ops.segment_sum(
        jnp.ones((w,), jnp.int32), slot_of_walker, num_segments=n_slots
    )

    events0 = jnp.full((max_events,), sentinel, dtype=idt)

    def cond(state):
        _, _, _, slot_active, it = state
        return jnp.any(slot_active) & (it < max_chunks)

    def body(state):
        curr, events, steps_taken, slot_active, it = state
        step_base = it * cfg.chunk_steps
        walker_active = jnp.take(slot_active, slot_of_walker)
        curr2, visited, valid = _walk_chunk(
            graph, curr, query_of_walker, user_feat, key, step_base, cfg
        )
        curr = jnp.where(walker_active, curr2, curr)
        valid = valid & walker_active[None, :]
        slot_b = jnp.broadcast_to(slot_of_walker[None, :], visited.shape)
        packed = jnp.where(
            valid,
            slot_b.astype(idt) * n_pins + visited.astype(idt),
            sentinel,
        ).reshape(-1)
        events = jax.lax.dynamic_update_slice(events, packed, (it * per_chunk,))
        steps_taken = steps_taken + walkers_per_slot * slot_active.astype(
            jnp.int32
        ) * cfg.chunk_steps

        def check(args):
            events, steps_taken = args
            uniq, counts = counter_lib.events_to_counts(
                events, n_slots, max_events
            )
            hot = (counts >= cfg.n_v) & (uniq < sentinel)
            slot_of_run = jnp.where(hot, uniq // n_pins, n_slots)
            n_high = jax.ops.segment_sum(
                hot.astype(jnp.int32),
                slot_of_run.astype(jnp.int32),
                num_segments=n_slots + 1,
            )[:n_slots]
            return valid_q & (steps_taken < n_q) & (n_high <= cfg.n_p)

        do_check = (it + 1) % check_every == 0
        slot_active = jax.lax.cond(
            do_check,
            check,
            lambda args: valid_q & (args[1] < n_q),
            (events, steps_taken),
        )
        return curr, events, steps_taken, slot_active, it + 1

    state0 = (
        query_of_walker,
        events0,
        jnp.zeros((n_slots,), jnp.int32),
        valid_q,
        jnp.asarray(0, jnp.int32),
    )
    _, events, steps_taken, _, it = jax.lax.while_loop(cond, body, state0)
    return EventWalkResult(events=events, steps_taken=steps_taken, chunks_run=it)


def pixie_walk_events_fixed(
    graph: PinBoardGraph,
    query_pins: Array,
    query_weights: Array,
    user_feat: Array,
    key: Array,
    cfg: WalkConfig,
    n_chunks: int,
    unroll: bool = True,
) -> EventWalkResult:
    """Cost-model twin of pixie_walk_events: exactly n_chunks chunks via an
    unrolled scan (no early stopping, no while loop).

    Exists because XLA's cost analysis counts while-loop bodies ONCE; the
    dry-run lowers this variant at n_chunks = 1 and 2 and extrapolates the
    linear-in-chunks cost to cfg.max_chunks() (launch/dryrun.py).
    """
    n_slots = query_pins.shape[0]
    n_pins = graph.n_pins
    w = cfg.n_walkers
    per_chunk = w * cfg.chunk_steps
    max_events = n_chunks * per_chunk
    idt = packed_event_dtype(n_slots, n_pins)
    sentinel = jnp.asarray(n_slots * n_pins, dtype=idt)

    valid_q = (query_pins >= 0) & (query_weights > 0)
    safe_q = jnp.where(valid_q, query_pins, 0)
    degs = graph.pin_degree(safe_q) * valid_q.astype(graph.p2b.offsets.dtype)
    n_q = sampling.allocate_steps(
        jnp.where(valid_q, query_weights, 0.0),
        degs,
        jnp.asarray(graph.max_pin_degree),
        cfg.n_steps,
    )
    slot_of_walker, _ = sampling.allocate_walkers(n_q, w)
    query_of_walker = jnp.take(safe_q, slot_of_walker).astype(jnp.int32)

    def body(curr, it):
        step_base = it * cfg.chunk_steps
        curr2, visited, valid = _walk_chunk(
            graph, curr, query_of_walker, user_feat, key, step_base, cfg,
            unroll=unroll,
        )
        slot_b = jnp.broadcast_to(slot_of_walker[None, :], visited.shape)
        packed = jnp.where(
            valid,
            slot_b.astype(idt) * n_pins + visited.astype(idt),
            sentinel,
        ).reshape(-1)
        return curr2, packed

    curr, chunks = jax.lax.scan(
        body, query_of_walker, jnp.arange(n_chunks), unroll=True
    )
    steps = jnp.full((n_slots,), n_chunks * cfg.chunk_steps, jnp.int32)
    return EventWalkResult(
        events=chunks.reshape(-1),
        steps_taken=steps,
        chunks_run=jnp.asarray(n_chunks, jnp.int32),
    )


def recommend_from_events(
    result: EventWalkResult,
    n_slots: int,
    n_pins: int,
    query_pins: Array,
    top_k: int,
) -> Tuple[Array, Array]:
    """Eq. 3 + top-k from an event buffer. -> (scores, pin ids)."""
    max_events = result.events.shape[0]
    sentinel = n_slots * n_pins
    uniq, counts = counter_lib.events_to_counts(result.events, n_slots, max_events)
    pin_ids, boosted = counter_lib.boosted_from_events(
        uniq, counts, n_pins, sentinel, max_events
    )
    # mask out query pins
    is_query = jnp.isin(pin_ids, query_pins)
    boosted = jnp.where(is_query, 0.0, boosted)
    return counter_lib.topk_events(pin_ids, boosted, top_k)
