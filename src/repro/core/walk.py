"""The Pixie Random Walk engine (paper §3.1, Algorithms 1-3), vectorized.

The paper's walk is sequential pointer chasing; the TPU-native form runs W
independent walkers in lockstep.  One *step* for every walker is:

    maybe-restart -> sample board from E(pin) -> sample pin from E(board)
    -> record visit

which is exactly Algorithm 2's inner loop, with ``SampleWalkLength(alpha)``
realised as a per-step Bernoulli(alpha) restart (geometric segment lengths,
E[len] = 1/alpha; see core/sampling.py).

Two interchangeable step engines (``WalkConfig.backend``):

  * ``"xla"``    — pure-XLA two-level gathers (kernels/ref.walk_chunk_ref);
                   the numerical reference, runs anywhere.
  * ``"pallas"`` — the fused multi-superstep Pallas kernel
                   (kernels/walk_step.walk_steps_fused): ONE kernel launch
                   per ``chunk_steps`` steps with walker state resident in
                   VMEM across the whole chunk, packed (slot, pin) visit
                   events emitted in-kernel, and counts recovered with the
                   scatter-free tile-scan ``visit_counter`` kernel.  On CPU
                   hosts the kernel runs in interpret mode.

Both engines consume the SAME counter-based random bits (one uint32
quadruple per walker-step, threefry fold-in of the step index), do the same
integer arithmetic on them, and therefore produce bit-for-bit identical
visit events — backend choice is a pure performance knob, verified by
tests/test_walk_backends.py.

Two counting backends (see core/counter.py):
  * dense  — per-(query-slot, pin) counts; benchmark-scale and per-shard
             production counting.  The xla engine scatter-adds; the pallas
             engine histograms the packed event chunk (no scatters).
  * events — bounded (slot, pin) event buffer + sort aggregation; scale-free,
             memory O(N) like the paper's hash table.  Both engines emit the
             packed buffer directly.

Early stopping (Algorithm 2 lines 10-13) is evaluated every chunk: a query
slot stops once >= n_p pins reached n_v visits or its step budget N_q is
spent; the whole walk stops when every slot stopped.  The statistic is
maintained INCREMENTALLY: the while-loop carries a (n_slots,) running
``n_high`` tally updated by ``counter_lib.accumulate_packed_events_with_high``
from just the chunk's own events (xla: sort the chunk and gather old/new
counts at the touched bins; pallas: threshold crossings emitted by the fused
``visit_counter_update_high`` kernel while the count tile is in VMEM) — the
loop body never reduces the full n_slots * n_pins buffer.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import counter as counter_lib
from repro.core import sampling
from repro.core.graph import PinBoardGraph
from repro.kernels import ops

Array = jax.Array

BACKENDS = ("xla", "pallas")


def packed_event_dtype(n_slots: int, n_pins: int):
    """Smallest int dtype that can hold packed (slot, pin) event ids.

    int32 covers every benchmark-scale graph; the 3B-pin production graph
    needs int64 (the dry-run launcher enables jax_enable_x64).
    """
    if n_slots * n_pins + 1 < 2**31:
        return jnp.int32
    return jnp.int64


def select_count_engine(
    backend: str, n_slots: int, n_pins: int, n_boards: int = 0
) -> str:
    """Counting engine for a packed (slot, pin/board) id space.

    The fused walk and counter kernels pack ids as int32; graphs whose
    packed id space needs int64 (``n_slots * n_pins >= 2**31``, the 3B-pin
    production scale) fall back to the xla engine — results are identical
    either way.  Pure shape arithmetic so production configs can be
    validated without materializing a graph.
    """
    idt = packed_event_dtype(n_slots, max(n_pins, n_boards))
    return backend if idt == jnp.int32 else "xla"


# disables Algorithm 2's early stopping: no pin can ever reach this many
# visits.  int32-safe because the tally machinery only COMPARES counts
# against n_v (never adds to it) — see accumulate_packed_events_with_high.
NO_EARLY_STOP_NV = jnp.iinfo(jnp.int32).max // 2


def _prob_u32(p: float) -> int:
    """Map a probability to the uint32 threshold used by both step engines."""
    return max(0, min(int(round(p * 2.0**32)), 2**32 - 1))


@dataclasses.dataclass(frozen=True)
class WalkConfig:
    """Hyper-parameters of the Pixie random walk.

    n_steps:      N — total step budget across all query pins (Eq. 2).
    alpha:        restart probability; E[walk segment] = 1/alpha.
    n_walkers:    number of parallel walkers (TPU adaptation; the paper's
                  sequential walker is n_walkers=1).
    chunk_steps:  steps fused per while-loop iteration between early-stop
                  checks (the paper checks per step; chunking trades slack
                  for device efficiency).  With backend="pallas" this is
                  also the number of supersteps fused into one kernel
                  launch.
    n_p, n_v:     early-stopping thresholds (>= n_p pins with >= n_v visits).
    bias_beta:    probability a step uses the personalized feature subrange
                  (PersonalizedNeighbor); 0 disables biasing (Algorithm 1).
    top_k:        number of recommendations extracted from the counter.
    count_boards: also accumulate board visit counts (for board recs, §5.3).
    backend:      "xla" (reference two-level gathers + scatter-add counts)
                  or "pallas" (fused multi-superstep kernel + tile-scan
                  histogram counts).  Both produce bit-identical visits.
    pallas_block_w: walkers per Pallas grid cell (None = auto).
    """

    n_steps: int = 100_000
    alpha: float = 0.5
    n_walkers: int = 1024
    chunk_steps: int = 8
    n_p: int = 2_000
    n_v: int = 4
    bias_beta: float = 0.9
    top_k: int = 1_000
    count_boards: bool = False
    backend: str = "xla"
    pallas_block_w: Optional[int] = None

    def max_chunks(self) -> int:
        per_chunk = self.n_walkers * self.chunk_steps
        return max(1, -(-self.n_steps // per_chunk))

    def without_early_stop(self) -> "WalkConfig":
        """Algorithm 1 mode: run the full step budget, never stop early.

        Uses thresholds no walk can reach (``NO_EARLY_STOP_NV`` is compared
        against counts, never added to them, so the sentinel cannot
        overflow the incremental high tally).
        """
        return dataclasses.replace(
            self, n_p=self.n_steps + 1, n_v=NO_EARLY_STOP_NV
        )


class WalkResult(NamedTuple):
    """Dense-mode walk output."""

    counts: Array           # (n_slots, n_pins) int32 per-query visit counts
    board_counts: Optional[Array]  # (n_slots, n_boards) or None
    steps_taken: Array      # (n_slots,) int32
    n_high: Array           # (n_slots,) int32 pins that reached n_v visits
                            # (the loop's running tally, query pins debited)


class EventWalkResult(NamedTuple):
    """Event-mode walk output (scale-free)."""

    events: Array           # (max_events,) int64 packed slot*n_pins+pin
    steps_taken: Array      # (n_slots,) int32
    chunks_run: Array       # () int32


# ---------------------------------------------------------------------------
# One chunk of steps for all walkers (shared by both modes and backends)
# ---------------------------------------------------------------------------


def _chunk_rbits(key: Array, step_base: Array, chunk_steps: int, w: int) -> Array:
    """Counter-based random bits for one chunk: (chunk_steps, w, 4) uint32.

    Column 0 drives the restart decision (< alpha threshold), column 1 the
    personalization decision (< beta threshold), columns 2/3 the board/pin
    neighbour picks.  Keyed by absolute step index so a restarted run
    replays the identical walk (fault-tolerance contract).
    """
    steps = step_base + jnp.arange(chunk_steps, dtype=jnp.int32)
    keys = jax.vmap(lambda s: sampling.step_key(key, s))(steps)
    return jax.vmap(lambda k: jax.random.bits(k, (w, 4)))(keys)


def _walk_chunk(
    graph: PinBoardGraph,
    curr: Array,             # (W,) int32 current pin per walker
    query_of_walker: Array,  # (W,) int32 restart target
    user_feat: Array,        # () or (W,) int32 personalization feature
    slot_of_walker: Array,   # (W,) int32 query slot per walker
    key: Array,
    step_base: Array,        # () int32 global step counter (for counter RNG)
    cfg: WalkConfig,
    n_slots: int,
    event_dtype,
    unroll: bool = False,
) -> Tuple[Array, Array, Optional[Array]]:
    """Run cfg.chunk_steps steps; return (new_curr, events, board_events).

    events: (chunk_steps, W) packed ``slot * n_pins + pin`` in
    ``event_dtype``, sentinel ``n_slots * n_pins`` for uncountable steps
    (dead-end forced restarts).  board_events is None unless
    cfg.count_boards.  Dispatches on cfg.backend; both engines consume the
    same random bits and agree bit-for-bit.

    The fused kernel packs events as int32, so graphs whose packed id
    space needs int64 (n_slots * n_pins >= 2**31) silently fall back to
    the xla engine — the results are identical either way.
    """
    if cfg.backend not in BACKENDS:
        raise ValueError(f"unknown walk backend {cfg.backend!r}; use {BACKENDS}")
    w = curr.shape[0]
    rbits = _chunk_rbits(key, step_base, cfg.chunk_steps, w)
    feat = jnp.broadcast_to(jnp.asarray(user_feat, jnp.int32), (w,))
    has_p2b = graph.p2b.feat_bounds is not None
    has_b2p = graph.b2p.feat_bounds is not None
    if has_p2b != has_b2p and cfg.bias_beta > 0.0:
        # a one-sided graph can't answer a biased walk; refusing loudly
        # beats silently dropping personalization
        raise ValueError(
            "graph has feat_bounds on only one CSR side; build both sides "
            "for biased walks or set bias_beta=0"
        )
    use_bias = has_p2b and has_b2p and cfg.bias_beta > 0.0
    return ops.walk_chunk_fused(
        curr,
        query_of_walker,
        feat,
        slot_of_walker,
        rbits,
        graph.p2b.offsets,
        graph.p2b.targets,
        graph.b2p.offsets,
        graph.b2p.targets,
        graph.p2b.feat_bounds if use_bias else None,
        graph.b2p.feat_bounds if use_bias else None,
        n_pins=graph.n_pins,
        n_slots=n_slots,
        n_boards=graph.n_boards,
        alpha_u32=_prob_u32(cfg.alpha),
        beta_u32=_prob_u32(cfg.bias_beta),
        count_boards=cfg.count_boards,
        event_dtype=event_dtype,
        unroll=unroll,
        block_w=cfg.pallas_block_w,
        use_kernel=(cfg.backend == "pallas" and event_dtype == jnp.int32),
    )


# ---------------------------------------------------------------------------
# Dense-mode multi-query walk (Algorithms 2 + 3)
# ---------------------------------------------------------------------------


def pixie_random_walk(
    graph: PinBoardGraph,
    query_pins: Array,     # (n_slots,) int32, padded with -1
    query_weights: Array,  # (n_slots,) float32, 0 for padding
    user_feat: Array,      # () int32 personalization feature (e.g. language)
    key: Array,
    cfg: WalkConfig,
) -> WalkResult:
    """PIXIERANDOMWALKMULTIPLE: biased, weighted, early-stopped, boosted.

    Returns dense per-slot visit counts; combine with
    ``counter_lib.boost_combine`` + ``topk_dense`` for recommendations.
    """
    if cfg.n_v < 1:
        raise ValueError(
            f"n_v must be >= 1, got {cfg.n_v}; use "
            "cfg.without_early_stop() to disable early stopping"
        )
    n_slots = query_pins.shape[0]
    n_pins = graph.n_pins
    w = cfg.n_walkers
    # board ids are only packed when count_boards: a pin-only walk must not
    # lose the int32 fast path to a board id space nobody counts (the fused
    # kernel's own overflow guard makes the same distinction)
    n_boards_packed = graph.n_boards if cfg.count_boards else 0
    idt = packed_event_dtype(n_slots, max(n_pins, n_boards_packed))
    sentinel = jnp.asarray(n_slots * n_pins, idt)
    bsentinel = (
        jnp.asarray(n_slots * graph.n_boards, idt) if cfg.count_boards
        else None
    )
    count_engine = select_count_engine(
        cfg.backend, n_slots, n_pins, n_boards_packed
    )

    valid_q = (query_pins >= 0) & (query_weights > 0)
    safe_q = jnp.where(valid_q, query_pins, 0)
    degs = graph.pin_degree(safe_q) * valid_q.astype(graph.p2b.offsets.dtype)

    # Eq. 1-2: per-slot step budgets; walker pool apportioned to match.
    n_q = sampling.allocate_steps(
        jnp.where(valid_q, query_weights, 0.0),
        degs,
        jnp.asarray(graph.max_pin_degree),
        cfg.n_steps,
    )
    slot_of_walker, _ = sampling.allocate_walkers(n_q, w)
    query_of_walker = jnp.take(safe_q, slot_of_walker).astype(jnp.int32)

    counts0 = jnp.zeros((n_slots * n_pins,), dtype=jnp.int32)
    bcounts0 = (
        jnp.zeros((n_slots * graph.n_boards,), dtype=jnp.int32)
        if cfg.count_boards
        else None
    )
    walkers_per_slot = jax.ops.segment_sum(
        jnp.ones((w,), jnp.int32), slot_of_walker, num_segments=n_slots
    )

    def cond(state):
        _, _, _, _, steps_taken, slot_active, it = state
        return jnp.any(slot_active) & (it < cfg.max_chunks())

    def body(state):
        curr, counts, bcounts, high, steps_taken, slot_active, it = state
        step_base = it * cfg.chunk_steps
        walker_active = jnp.take(slot_active, slot_of_walker)

        curr2, events, bevents = _walk_chunk(
            graph, curr, query_of_walker, user_feat, slot_of_walker,
            key, step_base, cfg, n_slots, idt,
        )
        curr = jnp.where(walker_active, curr2, curr)
        events = jnp.where(walker_active[None, :], events, sentinel)
        # fused: accumulate the chunk AND update the running n_high tally —
        # no n_slots * n_pins reduction anywhere in this loop body
        counts, high = counter_lib.accumulate_packed_events_with_high(
            counts, high, events, n_slots, n_pins, cfg.n_v, count_engine
        )
        if cfg.count_boards:
            bevents = jnp.where(walker_active[None, :], bevents, bsentinel)
            bcounts = counter_lib.accumulate_packed_events(
                bcounts, bevents, n_slots * graph.n_boards, count_engine
            )

        steps_taken = steps_taken + walkers_per_slot * slot_active.astype(
            jnp.int32
        ) * cfg.chunk_steps

        # early stopping: slot stops when n_high > n_p or budget exhausted
        slot_active = (
            valid_q
            & (steps_taken < n_q)
            & (high <= cfg.n_p)
        )
        return curr, counts, bcounts, high, steps_taken, slot_active, it + 1

    state0 = (
        query_of_walker,
        counts0,
        bcounts0,
        jnp.zeros((n_slots,), jnp.int32),
        jnp.zeros((n_slots,), jnp.int32),
        valid_q,
        jnp.asarray(0, jnp.int32),
    )
    curr, counts, bcounts, high, steps_taken, _, _ = jax.lax.while_loop(
        cond, body, state0
    )
    per_slot = counts.reshape(n_slots, n_pins)
    # never recommend the query pins themselves; the running tally counted
    # a query pin that reached n_v, so zeroing it must also debit the tally
    q_rows = jnp.arange(n_slots)
    q_reached = (per_slot[q_rows, safe_q] >= cfg.n_v).astype(jnp.int32)
    per_slot = per_slot.at[q_rows, safe_q].set(0)
    return WalkResult(
        counts=per_slot,
        board_counts=None
        if bcounts is None
        else bcounts.reshape(n_slots, graph.n_boards),
        steps_taken=steps_taken,
        n_high=high - q_reached,
    )


def basic_random_walk(
    graph: PinBoardGraph,
    query_pin: Array,
    key: Array,
    cfg: WalkConfig,
) -> Array:
    """Algorithm 1: unbiased, single query pin, fixed budget. -> (n_pins,)"""
    cfg_basic = dataclasses.replace(cfg, bias_beta=0.0).without_early_stop()
    res = pixie_random_walk(
        graph,
        jnp.asarray([query_pin], jnp.int32),
        jnp.ones((1,), jnp.float32),
        jnp.asarray(0, jnp.int32),
        key,
        cfg_basic,
    )
    return res.counts[0]


def recommend_with_stats(
    graph: PinBoardGraph,
    query_pins: Array,
    query_weights: Array,
    user_feat: Array,
    key: Array,
    cfg: WalkConfig,
) -> Tuple[Array, Array, Array, Array]:
    """recommend plus walk telemetry -> (scores, ids, steps_taken, n_high).

    ``steps_taken``/``n_high`` are Algorithm 3's early-stop observables —
    the serving layer exports them so a fleet can see how much of the step
    budget early stopping is actually saving (paper §4's latency lever).
    """
    res = pixie_random_walk(graph, query_pins, query_weights, user_feat, key, cfg)
    boosted = counter_lib.boost_combine(res.counts)
    scores, ids = counter_lib.topk_dense(boosted, cfg.top_k)
    return scores, ids, res.steps_taken, res.n_high


def recommend(
    graph: PinBoardGraph,
    query_pins: Array,
    query_weights: Array,
    user_feat: Array,
    key: Array,
    cfg: WalkConfig,
) -> Tuple[Array, Array]:
    """Full query path: walk -> Eq. 3 booster -> top-k (scores, pin ids).

    Dispatches on ``cfg.backend``: the whole walk loop runs on the fused
    Pallas engine when ``backend="pallas"``.
    """
    scores, ids, _, _ = recommend_with_stats(
        graph, query_pins, query_weights, user_feat, key, cfg
    )
    return scores, ids


# ---------------------------------------------------------------------------
# Event-mode walk — scale-free path used by the sharded production graph
# ---------------------------------------------------------------------------


def pixie_walk_events(
    graph: PinBoardGraph,
    query_pins: Array,
    query_weights: Array,
    user_feat: Array,
    key: Array,
    cfg: WalkConfig,
    check_every: int = 4,
) -> EventWalkResult:
    """Event-buffer walk: O(N) memory independent of graph size.

    The event buffer plays the role of the paper's N-sized hash table;
    early stopping re-aggregates the buffer every ``check_every`` chunks.
    With ``backend="pallas"`` the packed events come straight out of the
    fused kernel and are appended to the buffer — no packing arithmetic in
    XLA at all.
    """
    if cfg.n_v < 1:
        # same contract as the dense engine: n_v=0 would mark every touched
        # run "hot" and silently truncate the walk at the first check
        raise ValueError(
            f"n_v must be >= 1, got {cfg.n_v}; use "
            "cfg.without_early_stop() to disable early stopping"
        )
    if cfg.count_boards:
        # event mode only buffers pin visits; don't make the chunk engine
        # emit board events nobody reads
        cfg = dataclasses.replace(cfg, count_boards=False)
    n_slots = query_pins.shape[0]
    n_pins = graph.n_pins
    w = cfg.n_walkers
    per_chunk = w * cfg.chunk_steps
    max_chunks = cfg.max_chunks()
    max_events = max_chunks * per_chunk
    idt = packed_event_dtype(n_slots, n_pins)
    sentinel = jnp.asarray(n_slots * n_pins, dtype=idt)

    valid_q = (query_pins >= 0) & (query_weights > 0)
    safe_q = jnp.where(valid_q, query_pins, 0)
    degs = graph.pin_degree(safe_q) * valid_q.astype(graph.p2b.offsets.dtype)
    n_q = sampling.allocate_steps(
        jnp.where(valid_q, query_weights, 0.0),
        degs,
        jnp.asarray(graph.max_pin_degree),
        cfg.n_steps,
    )
    slot_of_walker, _ = sampling.allocate_walkers(n_q, w)
    query_of_walker = jnp.take(safe_q, slot_of_walker).astype(jnp.int32)
    walkers_per_slot = jax.ops.segment_sum(
        jnp.ones((w,), jnp.int32), slot_of_walker, num_segments=n_slots
    )

    events0 = jnp.full((max_events,), sentinel, dtype=idt)

    def cond(state):
        _, _, _, slot_active, it = state
        return jnp.any(slot_active) & (it < max_chunks)

    def body(state):
        curr, events, steps_taken, slot_active, it = state
        step_base = it * cfg.chunk_steps
        walker_active = jnp.take(slot_active, slot_of_walker)
        curr2, chunk_events, _ = _walk_chunk(
            graph, curr, query_of_walker, user_feat, slot_of_walker,
            key, step_base, cfg, n_slots, idt,
        )
        curr = jnp.where(walker_active, curr2, curr)
        packed = jnp.where(
            walker_active[None, :], chunk_events, sentinel
        ).reshape(-1)
        events = jax.lax.dynamic_update_slice(events, packed, (it * per_chunk,))
        steps_taken = steps_taken + walkers_per_slot * slot_active.astype(
            jnp.int32
        ) * cfg.chunk_steps

        def check(args):
            events, steps_taken = args
            n_high = counter_lib.events_n_high_per_slot(
                events, n_slots, n_pins, cfg.n_v, max_events
            )
            return valid_q & (steps_taken < n_q) & (n_high <= cfg.n_p)

        do_check = (it + 1) % check_every == 0
        slot_active = jax.lax.cond(
            do_check,
            check,
            lambda args: valid_q & (args[1] < n_q),
            (events, steps_taken),
        )
        return curr, events, steps_taken, slot_active, it + 1

    state0 = (
        query_of_walker,
        events0,
        jnp.zeros((n_slots,), jnp.int32),
        valid_q,
        jnp.asarray(0, jnp.int32),
    )
    _, events, steps_taken, _, it = jax.lax.while_loop(cond, body, state0)
    return EventWalkResult(events=events, steps_taken=steps_taken, chunks_run=it)


def pixie_walk_events_fixed(
    graph: PinBoardGraph,
    query_pins: Array,
    query_weights: Array,
    user_feat: Array,
    key: Array,
    cfg: WalkConfig,
    n_chunks: int,
    unroll: bool = True,
) -> EventWalkResult:
    """Cost-model twin of pixie_walk_events: exactly n_chunks chunks via an
    unrolled scan (no early stopping, no while loop).

    Exists because XLA's cost analysis counts while-loop bodies ONCE; the
    dry-run lowers this variant at n_chunks = 1 and 2 and extrapolates the
    linear-in-chunks cost to cfg.max_chunks() (launch/dryrun.py).
    """
    if cfg.count_boards:
        cfg = dataclasses.replace(cfg, count_boards=False)
    n_slots = query_pins.shape[0]
    n_pins = graph.n_pins
    w = cfg.n_walkers
    idt = packed_event_dtype(n_slots, n_pins)

    valid_q = (query_pins >= 0) & (query_weights > 0)
    safe_q = jnp.where(valid_q, query_pins, 0)
    degs = graph.pin_degree(safe_q) * valid_q.astype(graph.p2b.offsets.dtype)
    n_q = sampling.allocate_steps(
        jnp.where(valid_q, query_weights, 0.0),
        degs,
        jnp.asarray(graph.max_pin_degree),
        cfg.n_steps,
    )
    slot_of_walker, _ = sampling.allocate_walkers(n_q, w)
    query_of_walker = jnp.take(safe_q, slot_of_walker).astype(jnp.int32)

    def body(curr, it):
        step_base = it * cfg.chunk_steps
        curr2, chunk_events, _ = _walk_chunk(
            graph, curr, query_of_walker, user_feat, slot_of_walker,
            key, step_base, cfg, n_slots, idt, unroll=unroll,
        )
        return curr2, chunk_events.reshape(-1)

    curr, chunks = jax.lax.scan(
        body, query_of_walker, jnp.arange(n_chunks), unroll=True
    )
    steps = jnp.full((n_slots,), n_chunks * cfg.chunk_steps, jnp.int32)
    return EventWalkResult(
        events=chunks.reshape(-1),
        steps_taken=steps,
        chunks_run=jnp.asarray(n_chunks, jnp.int32),
    )


def recommend_from_events(
    result: EventWalkResult,
    n_slots: int,
    n_pins: int,
    query_pins: Array,
    top_k: int,
) -> Tuple[Array, Array]:
    """Eq. 3 + top-k from an event buffer. -> (scores, pin ids)."""
    max_events = result.events.shape[0]
    sentinel = n_slots * n_pins
    uniq, counts = counter_lib.events_to_counts(result.events, n_slots, max_events)
    pin_ids, boosted = counter_lib.boosted_from_events(
        uniq, counts, n_pins, sentinel, max_events
    )
    # mask out query pins
    is_query = jnp.isin(pin_ids, query_pins)
    boosted = jnp.where(is_query, 0.0, boosted)
    return counter_lib.topk_events(pin_ids, boosted, top_k)
