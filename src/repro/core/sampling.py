"""Walk-length sampling and per-query step allocation (paper §3.1).

``SampleWalkLength(alpha)`` is left abstract in the paper; the standard
random-walk-with-restart reading (the paper cites Tong et al. [28]) is a
geometric walk-segment length with restart probability ``alpha`` — i.e. after
every step the walk restarts at the query pin with probability ``alpha``,
giving E[segment length] = 1/alpha.  We vectorize that as a per-step restart
mask, which is distributionally identical and keeps every walker the same
shape.

Step allocation across weighted query pins implements Eq. 1-2 exactly:

    s_q = |E(q)| * (C - log|E(q)|)                       (Eq. 1)
    N_q = w_q * N * s_q / sum_r w_r * s_r                (Eq. 2)

(The paper's Eq. 2 writes w_q N s_q / sum s_r; the weights enter the
normalisation so that sum_q N_q = N.  We follow the normalised form so the
total step budget is preserved, and unit-test that property.)
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def restart_mask(key: Array, shape, alpha: float) -> Array:
    """Per-walker Bernoulli(alpha) restart decisions for one step."""
    return jax.random.bernoulli(key, p=alpha, shape=shape)


def step_key(base: Array, step: Array) -> Array:
    """Counter-based per-step key: stateless, restart-reproducible."""
    return jax.random.fold_in(base, step)


def scaling_factor(degree: Array, max_degree: Array) -> Array:
    """Eq. 1.  ``degree`` >= 0; degree-0 query pins get weight 0."""
    deg = degree.astype(jnp.float32)
    c = jnp.log(jnp.maximum(max_degree.astype(jnp.float32), 1.0))
    # Paper: s_q = |E(q)| * (C - log|E(q)|) with C = max pin degree.  Taking
    # C as log of the max degree keeps the factor positive and sub-linear,
    # matching the stated design goal ("increases sub-linearly with the query
    # pin degree"); with raw C = max degree the -log term is negligible and
    # the allocation is effectively linear.  We implement the literal formula
    # with C = max degree and clamp at zero; see tests for monotonicity.
    c_lit = jnp.maximum(max_degree.astype(jnp.float32), 1.0)
    s = deg * (c_lit - jnp.log(jnp.maximum(deg, 1.0)))
    del c
    return jnp.where(degree > 0, jnp.maximum(s, 0.0), 0.0)


def allocate_steps(
    weights: Array, degrees: Array, max_degree: Array, n_total
) -> Array:
    """Eq. 2: integer step budget per query pin, summing to ~n_total.

    Guarantees every active (weight>0, degree>0) query pin gets at least one
    step ("pins with low degrees also receive sufficient number of steps").

    ``n_total`` may be a Python int (the classic static budget) or a traced
    int32 scalar — multi-interest serving allocates each cluster lane its
    own budget as DATA so ragged users share one compiled program.  Both
    forms produce bit-identical budgets for equal values: the product below
    is the same single f32 multiply either way.
    """
    s = scaling_factor(degrees, max_degree)
    w = weights.astype(jnp.float32) * s
    denom = jnp.maximum(jnp.sum(w), 1e-9)
    frac = w / denom
    n_q = jnp.floor(frac * jnp.asarray(n_total, jnp.float32)).astype(jnp.int32)
    active = w > 0
    n_q = jnp.where(active, jnp.maximum(n_q, 1), 0)
    return n_q


def allocate_walkers(n_q: Array, n_walkers: int) -> Tuple[Array, Array]:
    """Split a walker pool proportionally to per-query step budgets.

    Returns (slot_of_walker (n_walkers,), steps_per_walker (n_slots,)).
    Deterministic largest-remainder apportionment so results are stable.
    """
    n_slots = n_q.shape[0]
    total = jnp.maximum(jnp.sum(n_q), 1)
    ideal = n_q.astype(jnp.float32) * (n_walkers / total.astype(jnp.float32))
    base = jnp.floor(ideal).astype(jnp.int32)
    base = jnp.where(n_q > 0, jnp.maximum(base, 1), 0)
    # distribute the remainder to the largest fractional parts
    short = n_walkers - jnp.sum(base)
    frac = ideal - jnp.floor(ideal)
    order = jnp.argsort(-frac)
    rank_of_slot = jnp.argsort(order)
    bonus = (rank_of_slot < short).astype(jnp.int32)
    per_slot = jnp.maximum(base + bonus, 0)
    # clip: if we overshot (many min-1 slots), trim from the largest slots
    overshoot = jnp.sum(per_slot) - n_walkers
    trim_order = jnp.argsort(-per_slot)
    trim_rank = jnp.argsort(trim_order)
    per_slot = jnp.where(
        (trim_rank < overshoot) & (per_slot > 0), per_slot - 1, per_slot
    )
    # walker -> slot assignment by repeat; build with cumsum comparison
    bounds = jnp.cumsum(per_slot)
    walker_idx = jnp.arange(n_walkers, dtype=jnp.int32)
    slot = jnp.sum((walker_idx[:, None] >= bounds[None, :]).astype(jnp.int32), axis=1)
    slot = jnp.clip(slot, 0, n_slots - 1)
    steps_per_walker = jnp.where(
        per_slot > 0,
        jnp.ceil(n_q.astype(jnp.float32) / jnp.maximum(per_slot, 1)).astype(jnp.int32),
        0,
    )
    return slot, steps_per_walker
