"""Visit counters: the TPU-native replacement for Pixie's hash table (§3.3).

The paper uses an open-addressing hash table with linear probing, sized by the
step budget N (the number of distinct visited pins can never exceed the number
of steps).  Pointer-chasing hash tables are the wrong shape for a TPU, so we
keep the *bound* and change the *mechanism*:

  * ``dense``  — scatter-add (``.at[].add``) into a dense count vector.  Used
    when the (per-shard) pin range fits comfortably in HBM; this is the fast
    path for the sharded production graph (each shard only counts its own
    node range) and for all benchmark-scale graphs.
  * ``events`` — walkers emit bounded (pin, query-slot) event buffers; counts
    are recovered with sort + segment-sum.  Scale-free: memory is O(N events)
    exactly like the paper's table, independent of graph size.

Both paths implement the multi-hit booster (Eq. 3):
    V[p] = (sum_q sqrt(V_q[p]))**2
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Dense counters
# ---------------------------------------------------------------------------


def dense_accumulate(counts: Array, pins: Array, valid: Array) -> Array:
    """Scatter-add a batch of visit events into per-query-slot dense counts.

    counts: (n_slots, n_pins) int32
    pins:   (n_slots, m) int32 visited pin ids (may contain junk where invalid)
    valid:  (n_slots, m) bool
    """
    n_slots, n_pins = counts.shape
    safe = jnp.where(valid, pins, 0).astype(jnp.int32)
    inc = valid.astype(counts.dtype)

    def one(c, p, i):
        return c.at[p].add(i, mode="drop")

    return jax.vmap(one)(counts, safe, inc)


def dense_accumulate_flat(counts: Array, pins: Array, valid: Array) -> Array:
    """Single-slot variant: counts (n_pins,), pins/valid (m,)."""
    safe = jnp.where(valid, pins, 0).astype(jnp.int32)
    return counts.at[safe].add(valid.astype(counts.dtype), mode="drop")


def accumulate_packed_events(
    counts: Array, events: Array, n_bins: int, backend: str
) -> Array:
    """Accumulate packed ``slot * n_pins + pin`` events into flat counts.

    Events >= n_bins are the walk's invalid-step sentinel and are dropped.
    Two engines, matching the walk backends (core/walk.py):

      * "xla"    — scatter-add (``.at[].add``): random writes, fine on
                   CPU/GPU, the worst access pattern on TPU.
      * "pallas" — the tile-scan histogram kernel (kernels/visit_counter):
                   each count tile scans the event chunk with vectorized
                   compares in VMEM; no scatters anywhere.
    """
    if backend == "pallas":
        from repro.kernels import ops  # local import: kernels layer on top

        return counts + ops.visit_counts(
            events.reshape(-1).astype(jnp.int32), n_bins, use_kernel=True
        )
    # not dense_accumulate_flat: that helper casts indices to int32, which
    # would corrupt int64 packed ids on production-scale graphs
    valid = events < n_bins
    safe = jnp.where(valid, events, 0)
    return counts.at[safe.reshape(-1)].add(
        valid.astype(counts.dtype).reshape(-1), mode="drop"
    )


def boost_combine(counts_q: Array, weights: Array | None = None) -> Array:
    """Multi-hit booster, Eq. 3:  V[p] = (sum_q w_q * sqrt(V_q[p]))**2.

    With a single slot this reduces to the raw count (paper's note that a
    single-query visit count is unchanged).  ``weights`` generalizes the
    equal-weight paper formula; pass None for the faithful version.
    """
    root = jnp.sqrt(counts_q.astype(jnp.float32))
    if weights is not None:
        root = root * weights[:, None].astype(jnp.float32)
    s = jnp.sum(root, axis=0)
    return s * s


def n_high_visited(counts_q: Array, n_v: int) -> Array:
    """Per-slot count of pins whose visit count reached n_v (early stopping)."""
    return jnp.sum((counts_q >= n_v).astype(jnp.int32), axis=-1)


def topk_dense(boosted: Array, k: int) -> Tuple[Array, Array]:
    """Top-k (scores, pin ids) from a dense boosted count vector."""
    vals, idx = jax.lax.top_k(boosted, k)
    return vals, idx


# ---------------------------------------------------------------------------
# Event-buffer (sort-based) counters — scale-free path
# ---------------------------------------------------------------------------


def events_to_counts(
    event_ids: Array, n_slots: int, max_unique: int
) -> Tuple[Array, Array]:
    """Aggregate visit events by (slot, pin) without dense graph-size state.

    event_ids: (m,) int64 packed events ``slot * n_pins + pin``; invalid
               events are encoded as a sentinel larger than every valid id.
    Returns (unique_packed_ids, counts) each (max_unique,), padded with the
    sentinel / zero.  Equivalent to the paper's hash-table contents.
    """
    m = event_ids.shape[0]
    sorted_ids = jnp.sort(event_ids)
    # boundary[i] = 1 where a new run starts
    boundary = jnp.concatenate(
        [jnp.ones((1,), jnp.int32), (sorted_ids[1:] != sorted_ids[:-1]).astype(jnp.int32)]
    )
    run_idx = jnp.cumsum(boundary) - 1  # which unique slot each event maps to
    counts = jax.ops.segment_sum(
        jnp.ones((m,), jnp.int32), run_idx, num_segments=max_unique
    )
    # representative id per run
    uniq = jax.ops.segment_max(sorted_ids, run_idx, num_segments=max_unique)
    return uniq, counts


def boosted_from_events(
    uniq_packed: Array,
    counts: Array,
    n_pins_total: int,
    sentinel: int,
    max_unique: int,
) -> Tuple[Array, Array]:
    """Apply Eq. 3 across query slots given (slot*n_pins + pin, count) pairs.

    Strategy: map every (slot, pin, count) run to (pin, sqrt(count)), then
    aggregate again by pin with a second sort, and square.  Returns
    (pin_ids, boosted_scores) padded with (sentinel, 0).
    """
    pin = jnp.where(uniq_packed >= sentinel, sentinel, uniq_packed % n_pins_total)
    root = jnp.where(uniq_packed >= sentinel, 0.0, jnp.sqrt(counts.astype(jnp.float32)))
    order = jnp.argsort(pin)
    pin_s = pin[order]
    root_s = root[order]
    boundary = jnp.concatenate(
        [jnp.ones((1,), jnp.int32), (pin_s[1:] != pin_s[:-1]).astype(jnp.int32)]
    )
    run_idx = jnp.cumsum(boundary) - 1
    summed = jax.ops.segment_sum(root_s, run_idx, num_segments=max_unique)
    rep_pin = jax.ops.segment_max(pin_s, run_idx, num_segments=max_unique)
    boosted = summed * summed
    boosted = jnp.where(rep_pin >= sentinel, 0.0, boosted)
    return rep_pin, boosted


def topk_events(pin_ids: Array, scores: Array, k: int) -> Tuple[Array, Array]:
    vals, idx = jax.lax.top_k(scores, k)
    return vals, jnp.take(pin_ids, idx)


@partial(jax.jit, static_argnames=("n_v", "max_unique"))
def n_high_from_events(event_ids: Array, n_v: int, max_unique: int) -> Array:
    """Early-stopping statistic from an event buffer: #(slot,pin) runs >= n_v."""
    _, counts = events_to_counts(event_ids, 1, max_unique)
    return jnp.sum((counts >= n_v).astype(jnp.int32))
