"""Visit counters: the TPU-native replacement for Pixie's hash table (§3.3).

The paper uses an open-addressing hash table with linear probing, sized by the
step budget N (the number of distinct visited pins can never exceed the number
of steps).  Pointer-chasing hash tables are the wrong shape for a TPU, so we
keep the *bound* and change the *mechanism*:

  * ``dense``  — scatter-add (``.at[].add``) into a dense count vector.  Used
    when the (per-shard) pin range fits comfortably in HBM; this is the fast
    path for the sharded production graph (each shard only counts its own
    node range) and for all benchmark-scale graphs.
  * ``events`` — walkers emit bounded (slot, pin) event buffers; counts are
    recovered with sort + segment-sum.  Scale-free: memory is O(N events)
    exactly like the paper's table, independent of graph size.

Events are WIDE: two int32 lanes, ``(slot, pin)``, never the packed
``slot * n_pins + pin`` product — so the event representation has no int32
cliff at production id spaces (``n_slots * n_pins >= 2**31``, the paper's
3B-pin regime).  An event is invalid iff its slot lane holds ``n_slots``
(value lane 0).  Dense counting still materializes an
``(n_slots * n_pins,)`` buffer, which *inherently* requires the flat bin
space to fit (< 2**31 bins — enforced loudly here); beyond that scale the
event path carries the lanes end-to-end and aggregates by lexicographic
pair sort (``lax.sort(..., num_keys=2)``), no 64-bit ids anywhere.

Both paths implement the multi-hit booster (Eq. 3):
    V[p] = (sum_q sqrt(V_q[p]))**2

Event-mode early stopping is INCREMENTAL: ``EventHighState`` keeps the
sorted (slot, pin, count) runs of every previous check window plus the
running per-slot ``n_high`` tally; ``events_high_fold`` folds in ONE new
window by sorting only that window's events (O(window log window)) and
binary-searching prior runs for the old counts — the check body never
sorts the whole ``max_events`` buffer again (``events_n_high_per_slot``
remains as the full re-sort oracle the incremental tally must match
bit-for-bit).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

def _require_dense_bins(n_bins: int) -> None:
    """Dense counting materializes an (n_bins,) buffer: must fit int32."""
    # single source of truth lives with the kernels (local import: the
    # kernels layer sits on top of core)
    from repro.kernels.visit_counter import _require_dense_bins as _req

    _req(n_bins)


def _valid_lanes(slot_ev: Array, id_ev: Array, n_slots: int, n_dim: int):
    return (
        (slot_ev >= 0) & (slot_ev < n_slots)
        & (id_ev >= 0) & (id_ev < n_dim)
    )


# ---------------------------------------------------------------------------
# Dense counters
# ---------------------------------------------------------------------------


def dense_accumulate(counts: Array, pins: Array, valid: Array) -> Array:
    """Scatter-add a batch of visit events into per-query-slot dense counts.

    counts: (n_slots, n_pins) int32
    pins:   (n_slots, m) int32 visited pin ids (may contain junk where invalid)
    valid:  (n_slots, m) bool
    """
    n_slots, n_pins = counts.shape
    safe = jnp.where(valid, pins, 0).astype(jnp.int32)
    inc = valid.astype(counts.dtype)

    def one(c, p, i):
        return c.at[p].add(i, mode="drop")

    return jax.vmap(one)(counts, safe, inc)


def dense_accumulate_flat(counts: Array, pins: Array, valid: Array) -> Array:
    """Single-slot variant: counts (n_pins,), pins/valid (m,)."""
    safe = jnp.where(valid, pins, 0).astype(jnp.int32)
    return counts.at[safe].add(valid.astype(counts.dtype), mode="drop")


def accumulate_packed_events(
    counts: Array,
    slot_events: Array,
    id_events: Array,
    n_slots: int,
    n_dim: int,
    backend: str,
    query_events: Array | None = None,
    n_queries: int = 0,
) -> Array:
    """Accumulate wide (slot, id) event lanes into flat dense counts.

    counts: (n_slots * n_dim,) int32.  An event is counted iff
    ``0 <= slot < n_slots`` and ``0 <= id < n_dim`` (the walk's
    invalid-step sentinel, slot = ``n_slots``, is dropped).  Two engines,
    matching the walk backends (core/walk.py):

      * "xla"    — scatter-add (``.at[].add``): random writes, fine on
                   CPU/GPU, the worst access pattern on TPU.
      * "pallas" — the wide tile-scan histogram kernel
                   (kernels/visit_counter): each count tile scans the event
                   chunk with vectorized compares in VMEM, the flat bin id
                   formed in-register; no scatters anywhere.

    Batch-native mode: pass ``query_events`` (the third wide lane, query
    sentinel ``n_queries``) and ``n_queries > 0`` — ``counts`` is then the
    ``n_queries * n_slots * n_dim`` query-major triple space and one call
    accumulates a whole serving batch's chunk; validity additionally
    requires ``0 <= query < n_queries``.
    """
    with_query = query_events is not None
    n_rows = n_queries * n_slots if with_query else n_slots
    _require_dense_bins(n_rows * n_dim)
    sev = slot_events.reshape(-1).astype(jnp.int32)
    iev = id_events.reshape(-1).astype(jnp.int32)
    qev = query_events.reshape(-1).astype(jnp.int32) if with_query else None
    if backend == "pallas":
        from repro.kernels import ops  # local import: kernels layer on top

        return counts + ops.visit_counts_wide(
            sev, iev, n_slots=n_slots, n_dim=n_dim,
            query_events=qev, n_queries=n_queries, use_kernel=True,
        )
    valid = _valid_lanes(sev, iev, n_slots, n_dim)
    row = sev
    if with_query:
        valid &= (qev >= 0) & (qev < n_queries)
        row = qev * n_slots + sev
    # pack on masked values only: garbage lanes must not overflow int32
    flat = jnp.where(valid, row, 0) * n_dim + jnp.where(valid, iev, 0)
    return counts.at[flat].add(valid.astype(counts.dtype), mode="drop")


def accumulate_packed_events_with_high(
    counts: Array,
    high: Array,
    slot_events: Array,
    pin_events: Array,
    n_slots: int,
    n_pins: int,
    n_v: int,
    backend: str,
    query_events: Array | None = None,
    n_queries: int = 0,
) -> Tuple[Array, Array]:
    """Accumulate wide events AND maintain the early-stop tally (Alg. 3).

    counts: (n_slots * n_pins,) int32 running visit counts.
    high:   (n_slots,) int32 running count of pins that reached ``n_v``
            visits (the quantity Algorithm 3 compares against ``n_p``).
    slot_events / pin_events: wide int32 event lanes; slot ``n_slots`` is
            the walk's invalid-step sentinel and is dropped.

    Returns ``(new_counts, new_high)``.  The point of this API is that the
    caller's while-loop body no longer reduces the whole
    ``n_slots * n_pins`` buffer per iteration to recompute ``n_high``:

      * "pallas" — the fused wide ``visit_counter_update_high`` kernel: the
        count tile is updated in VMEM and per-slot threshold crossings come
        out of the same kernel launch.
      * "xla"    — chunk-local twin: scatter-add the events, then find the
        crossings by sorting only the CHUNK's events (O(E log E),
        E = chunk_steps * n_walkers) — old/new counts are gathered at the
        touched bins, a bin that crossed is counted once via the sort's
        first-occurrence mask.

    Both paths do identical integer arithmetic, so counts and tallies are
    bit-identical (tests/test_earlystop_parity.py).  Dense counting
    inherently requires ``n_slots * n_pins < 2**31`` (the counts buffer is
    materialized); larger id spaces use event-mode counting, which has no
    such limit.  Requires ``n_v >= 1``: counts start at zero, so a
    non-positive threshold could never *cross* and the tally would
    disagree with a full recount.

    Batch-native mode: pass ``query_events`` (query sentinel
    ``n_queries``) and ``n_queries > 0`` — counts/high then cover the
    whole serving batch (``n_queries * n_slots * n_pins`` query-major bins
    / ``n_queries * n_slots`` rows) and ONE call per chunk maintains every
    query's tally.  The xla twin's chunk sort is over the query-major flat
    bin ids, which *is* the lexicographic (query, slot, pin) triple sort
    (the flat id is a monotone encoding of the triple); the pallas twin is
    the same ``visit_counter_update_high`` kernel with the query lane
    packed in VMEM.
    """
    if n_v < 1:
        raise ValueError(f"n_v must be >= 1 for crossing tallies, got {n_v}")
    with_query = query_events is not None
    n_rows = n_queries * n_slots if with_query else n_slots
    n_bins = n_rows * n_pins
    _require_dense_bins(n_bins)
    sev = slot_events.reshape(-1).astype(jnp.int32)
    pev = pin_events.reshape(-1).astype(jnp.int32)
    qev = query_events.reshape(-1).astype(jnp.int32) if with_query else None
    if backend == "pallas":
        from repro.kernels import ops  # local import: kernels layer on top

        new_counts, delta = ops.visit_counts_update_high(
            counts, sev, pev, n_slots=n_slots, n_pins=n_pins, n_v=n_v,
            query_events=qev, n_queries=n_queries, use_kernel=True,
        )
        return new_counts, high + delta

    valid = _valid_lanes(sev, pev, n_slots, n_pins)
    row = sev
    if with_query:
        valid &= (qev >= 0) & (qev < n_queries)
        row = qev * n_slots + sev
    flat = jnp.where(valid, row, 0) * n_pins + jnp.where(valid, pev, 0)
    flat = jnp.where(valid, flat, n_bins)
    idx = jnp.where(valid, flat, 0)
    new_counts = counts.at[idx].add(valid.astype(counts.dtype), mode="drop")
    # crossings from the touched bins only: sort the chunk, dedup runs
    # (the flat-id sort is the lexicographic (query, slot, pin) sort)
    sorted_e = jnp.sort(flat)
    first = jnp.concatenate(
        [jnp.ones((1,), jnp.bool_), sorted_e[1:] != sorted_e[:-1]]
    )
    in_range = sorted_e < n_bins
    safe = jnp.where(in_range, sorted_e, 0)
    old_c = jnp.take(counts, safe)
    new_c = jnp.take(new_counts, safe)
    crossed = first & in_range & (old_c < n_v) & (new_c >= n_v)
    slot = jnp.where(in_range, safe // n_pins, n_rows).astype(jnp.int32)
    delta = jax.ops.segment_sum(
        crossed.astype(jnp.int32), slot, num_segments=n_rows + 1
    )[:n_rows]
    return new_counts, high + delta


def fold_sharded_counts(
    shard_counts: Array,
    n_queries: int,
    n_slots: int,
    per_shard_dim: int,
) -> Array:
    """Fold per-shard dense counts into the unsharded batched layout.

    shard_counts: (n_shards, n_queries * n_slots * per_shard_dim) int32 —
    each shard's query-major counts over its OWNED id subrange (shard s
    owns global ids ``[s * per_shard_dim, (s + 1) * per_shard_dim)``).
    Because ownership partitions the id space, folding is a pure layout
    move (no adds): returns ``(n_queries, n_slots,
    n_shards * per_shard_dim)`` with the global id axis reassembled in
    shard order, directly comparable to the unsharded batched engine's
    counts (padded ids past the real ``n_pins`` stay zero — no walker can
    emit them).
    """
    n_shards = shard_counts.shape[0]
    blocks = shard_counts.reshape(n_shards, n_queries, n_slots, per_shard_dim)
    return jnp.moveaxis(blocks, 0, 2).reshape(
        n_queries, n_slots, n_shards * per_shard_dim
    )


def boost_combine(counts_q: Array, weights: Array | None = None) -> Array:
    """Multi-hit booster, Eq. 3:  V[p] = (sum_q w_q * sqrt(V_q[p]))**2.

    With a single slot this reduces to the raw count (paper's note that a
    single-query visit count is unchanged).  ``weights`` generalizes the
    equal-weight paper formula; pass None for the faithful version.
    """
    root = jnp.sqrt(counts_q.astype(jnp.float32))
    if weights is not None:
        root = root * weights[:, None].astype(jnp.float32)
    s = jnp.sum(root, axis=0)
    return s * s


def n_high_visited(counts_q: Array, n_v: int) -> Array:
    """Per-slot count of pins whose visit count reached n_v (early stopping)."""
    return jnp.sum((counts_q >= n_v).astype(jnp.int32), axis=-1)


def topk_dense(boosted: Array, k: int) -> Tuple[Array, Array]:
    """Top-k (scores, pin ids) from a dense boosted count vector."""
    vals, idx = jax.lax.top_k(boosted, k)
    return vals, idx


# ---------------------------------------------------------------------------
# Event-buffer (sort-based) counters — scale-free path, wide lanes
# ---------------------------------------------------------------------------


def events_to_counts(
    slot_ids: Array,
    pin_ids: Array,
    n_slots: int,
    max_unique: int,
) -> Tuple[Array, Array, Array]:
    """Aggregate wide visit events by (slot, pin) with a lexicographic sort.

    slot_ids / pin_ids: (m,) int32 event lanes; invalid events carry slot
    ``n_slots`` (they aggregate into trailing sentinel runs the consumers
    mask out).  Returns ``(uniq_slot, uniq_pin, counts)`` each
    (max_unique,), lexicographically sorted by (slot, pin) with unused
    bins normalized to the (``n_slots``, 0) sentinel — the arrays stay
    sorted end to end, which is what lets ``events_high_fold`` binary
    search them.  Equivalent to the paper's hash-table contents; no lane
    ever holds the packed ``slot * n_pins + pin`` product, so this works
    unchanged past 2**31 packed ids.
    """
    m = slot_ids.shape[0]
    s_sorted, p_sorted = jax.lax.sort(
        (slot_ids.astype(jnp.int32), pin_ids.astype(jnp.int32)), num_keys=2
    )
    # boundary[i] = 1 where a new (slot, pin) run starts
    boundary = jnp.concatenate(
        [
            jnp.ones((1,), jnp.int32),
            (
                (s_sorted[1:] != s_sorted[:-1])
                | (p_sorted[1:] != p_sorted[:-1])
            ).astype(jnp.int32),
        ]
    )
    run_idx = jnp.cumsum(boundary) - 1  # which unique bin each event maps to
    counts = jax.ops.segment_sum(
        jnp.ones((m,), jnp.int32), run_idx, num_segments=max_unique
    )
    uniq_slot = jax.ops.segment_max(s_sorted, run_idx, num_segments=max_unique)
    uniq_pin = jax.ops.segment_max(p_sorted, run_idx, num_segments=max_unique)
    # unused trailing bins come back as int32 min from segment_max; pin the
    # sentinel so the run arrays remain lexicographically sorted
    used = counts > 0
    uniq_slot = jnp.where(used, uniq_slot, n_slots)
    uniq_pin = jnp.where(used, uniq_pin, 0)
    return uniq_slot, uniq_pin, counts


def boosted_from_events(
    uniq_slot: Array,
    uniq_pin: Array,
    counts: Array,
    n_slots: int,
    n_pins: int,
    max_unique: int,
) -> Tuple[Array, Array]:
    """Apply Eq. 3 across query slots given (slot, pin, count) runs.

    Strategy: map every (slot, pin, count) run to (pin, sqrt(count)), then
    aggregate again by pin with a second sort, and square.  Returns
    (pin_ids, boosted_scores) padded with (``n_pins``, 0).
    """
    valid = _valid_lanes(uniq_slot, uniq_pin, n_slots, n_pins) & (counts > 0)
    pin = jnp.where(valid, uniq_pin, n_pins)
    root = jnp.where(valid, jnp.sqrt(counts.astype(jnp.float32)), 0.0)
    order = jnp.argsort(pin)
    pin_s = pin[order]
    root_s = root[order]
    boundary = jnp.concatenate(
        [jnp.ones((1,), jnp.int32), (pin_s[1:] != pin_s[:-1]).astype(jnp.int32)]
    )
    run_idx = jnp.cumsum(boundary) - 1
    summed = jax.ops.segment_sum(root_s, run_idx, num_segments=max_unique)
    rep_pin = jax.ops.segment_max(pin_s, run_idx, num_segments=max_unique)
    boosted = summed * summed
    boosted = jnp.where(
        (rep_pin >= 0) & (rep_pin < n_pins), boosted, 0.0
    )
    return rep_pin, boosted


def topk_events(pin_ids: Array, scores: Array, k: int) -> Tuple[Array, Array]:
    vals, idx = jax.lax.top_k(scores, k)
    return vals, jnp.take(pin_ids, idx)


def events_n_high_per_slot(
    slot_ids: Array,
    pin_ids: Array,
    n_slots: int,
    n_pins: int,
    n_v: int,
    max_unique: int,
) -> Array:
    """Per-slot Algorithm 3 statistic by FULL re-aggregation of the buffer.

    Returns (n_slots,) int32 — the number of pins of each query slot whose
    aggregated visit count reached ``n_v``.  This sorts the whole event
    buffer (O(max_events log max_events)) and exists as the
    obviously-correct oracle: the event walk's check body now carries
    ``EventHighState`` and folds in only each new window
    (``events_high_fold``), and the two must agree bit-for-bit at every
    check point (tests/test_widepack.py).
    """
    uniq_slot, uniq_pin, counts = events_to_counts(
        slot_ids, pin_ids, n_slots, max_unique
    )
    hot = (counts >= n_v) & _valid_lanes(uniq_slot, uniq_pin, n_slots, n_pins)
    slot_of_run = jnp.where(hot, uniq_slot, n_slots)
    return jax.ops.segment_sum(
        hot.astype(jnp.int32),
        slot_of_run.astype(jnp.int32),
        num_segments=n_slots + 1,
    )[:n_slots]


# ---------------------------------------------------------------------------
# Incremental event-mode early stopping: sorted runs folded window by window
# ---------------------------------------------------------------------------


class EventHighState(NamedTuple):
    """Carried state of the incremental event-mode ``n_high`` tally.

    ``seg_slot`` / ``seg_pin`` / ``seg_count`` hold one SORTED run segment
    per completed check window, laid out back to back (segment k occupies
    ``[k * seg_cap, (k + 1) * seg_cap)``); unwritten segments hold the
    (``n_slots``, 0, 0) sentinel, which no valid lookup can match.  A
    (slot, pin) key that appears in several windows has its count spread
    over their segments — its cumulative prior count is the sum of its
    matches, which is how ``events_high_fold`` detects the (unique)
    check window where the key crosses ``n_v``.
    """

    seg_slot: Array    # (n_segments * seg_cap,) int32
    seg_pin: Array     # (n_segments * seg_cap,) int32
    seg_count: Array   # (n_segments * seg_cap,) int32
    high: Array        # (n_slots,) int32 running Algorithm 3 tally
    n_checks: Array    # () int32 windows folded so far


def events_high_init(
    n_slots: int, n_segments: int, seg_cap: int
) -> EventHighState:
    """Fresh state sized for ``n_segments`` check windows of ``seg_cap``."""
    m = max(1, n_segments) * seg_cap
    return EventHighState(
        seg_slot=jnp.full((m,), n_slots, jnp.int32),
        seg_pin=jnp.zeros((m,), jnp.int32),
        seg_count=jnp.zeros((m,), jnp.int32),
        high=jnp.zeros((n_slots,), jnp.int32),
        n_checks=jnp.asarray(0, jnp.int32),
    )


def _searchsorted_pair(
    keys_slot: Array, keys_pin: Array, q_slot: Array, q_pin: Array
) -> Array:
    """Left insertion points of (q_slot, q_pin) into lexicographically
    sorted (keys_slot, keys_pin) — a vectorized binary search (no sort)."""
    n = keys_slot.shape[0]
    lo = jnp.zeros(q_slot.shape, jnp.int32)
    hi = jnp.full(q_slot.shape, n, jnp.int32)

    def step(_, lohi):
        lo, hi = lohi
        live = lo < hi
        mid = (lo + hi) // 2
        ms = jnp.take(keys_slot, jnp.minimum(mid, n - 1))
        mp = jnp.take(keys_pin, jnp.minimum(mid, n - 1))
        less = (ms < q_slot) | ((ms == q_slot) & (mp < q_pin))
        lo = jnp.where(live & less, mid + 1, lo)
        hi = jnp.where(live & ~less, mid, hi)
        return lo, hi

    lo, _ = jax.lax.fori_loop(0, n.bit_length() + 1, step, (lo, hi))
    return lo


def events_high_fold(
    state: EventHighState,
    slot_events: Array,
    pin_events: Array,
    n_slots: int,
    n_pins: int,
    n_v: int,
    *,
    seg_cap: int,
) -> EventHighState:
    """Fold ONE check window's events into the running ``n_high`` tally.

    The only sort here is over the window's own events (``seg_cap`` of
    them) — O(window log window), never the full buffer.  Prior counts of
    the window's keys come from vectorized binary searches into the
    segments written so far (k segments at the k-th check, each a
    log-window probe), so no operation ever touches an
    O(max_events)-sized operand.  Bit-identical to re-aggregating the
    full buffer with ``events_n_high_per_slot`` at every check point.

    CONTRACT: the state must be sized (``events_high_init``'s
    ``n_segments``) for every fold that will ever run.  A fold past
    capacity keeps stored segments intact but cannot store its own runs,
    so LATER folds would see stale priors and could re-count a crossing —
    size for the worst case (``pixie_walk_events`` sizes exactly).  The
    run segments cost ~3 int32 lanes of window capacity per check window
    (same O(events) class as the buffers, ~2.5x the constant); the
    ROADMAP notes LSM-style segment merging as the follow-up that cuts
    both this and the per-check probe count.
    """
    sev = slot_events.reshape(-1).astype(jnp.int32)
    pev = pin_events.reshape(-1).astype(jnp.int32)
    if sev.shape[0] != seg_cap:
        raise ValueError(
            f"window has {sev.shape[0]} events but seg_cap={seg_cap}"
        )
    uniq_slot, uniq_pin, counts = events_to_counts(
        sev, pev, n_slots, seg_cap
    )

    n_segments = state.seg_slot.shape[0] // seg_cap

    def lookup(k, prior):
        ss = jax.lax.dynamic_slice(state.seg_slot, (k * seg_cap,), (seg_cap,))
        sp = jax.lax.dynamic_slice(state.seg_pin, (k * seg_cap,), (seg_cap,))
        sc = jax.lax.dynamic_slice(state.seg_count, (k * seg_cap,), (seg_cap,))
        pos = _searchsorted_pair(ss, sp, uniq_slot, uniq_pin)
        pos_c = jnp.minimum(pos, seg_cap - 1)
        match = (
            (pos < seg_cap)
            & (jnp.take(ss, pos_c) == uniq_slot)
            & (jnp.take(sp, pos_c) == uniq_pin)
        )
        return prior + jnp.where(match, jnp.take(sc, pos_c), 0)

    # only the segments actually written so far (a traced bound is fine
    # for fori_loop): the early checks of a long walk must not pay for
    # the whole window capacity
    prior = jax.lax.fori_loop(
        0, jnp.minimum(state.n_checks, n_segments), lookup,
        jnp.zeros((seg_cap,), jnp.int32)
    )

    valid_run = (
        _valid_lanes(uniq_slot, uniq_pin, n_slots, n_pins) & (counts > 0)
    )
    crossed = valid_run & (prior < n_v) & (prior + counts >= n_v)
    slot_of = jnp.where(crossed, uniq_slot, n_slots)
    delta = jax.ops.segment_sum(
        crossed.astype(jnp.int32), slot_of, num_segments=n_slots + 1
    )[:n_slots]

    # callers must size the state for every fold (pixie_walk_events does);
    # a fold past capacity must not clobber a stored segment — its runs
    # are dropped (so LATER folds would see stale priors), never a prior
    # window's (which would corrupt the tally retroactively)
    def store(seg_slot, seg_pin, seg_count):
        off = state.n_checks * seg_cap
        return (
            jax.lax.dynamic_update_slice(seg_slot, uniq_slot, (off,)),
            jax.lax.dynamic_update_slice(seg_pin, uniq_pin, (off,)),
            jax.lax.dynamic_update_slice(seg_count, counts, (off,)),
        )

    seg_slot, seg_pin, seg_count = jax.lax.cond(
        state.n_checks < n_segments,
        store,
        lambda a, b, c: (a, b, c),
        state.seg_slot, state.seg_pin, state.seg_count,
    )
    return EventHighState(
        seg_slot=seg_slot,
        seg_pin=seg_pin,
        seg_count=seg_count,
        high=state.high + delta,
        n_checks=state.n_checks + 1,
    )
