"""Visit counters: the TPU-native replacement for Pixie's hash table (§3.3).

The paper uses an open-addressing hash table with linear probing, sized by the
step budget N (the number of distinct visited pins can never exceed the number
of steps).  Pointer-chasing hash tables are the wrong shape for a TPU, so we
keep the *bound* and change the *mechanism*:

  * ``dense``  — scatter-add (``.at[].add``) into a dense count vector.  Used
    when the (per-shard) pin range fits comfortably in HBM; this is the fast
    path for the sharded production graph (each shard only counts its own
    node range) and for all benchmark-scale graphs.
  * ``events`` — walkers emit bounded (pin, query-slot) event buffers; counts
    are recovered with sort + segment-sum.  Scale-free: memory is O(N events)
    exactly like the paper's table, independent of graph size.

Both paths implement the multi-hit booster (Eq. 3):
    V[p] = (sum_q sqrt(V_q[p]))**2
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Dense counters
# ---------------------------------------------------------------------------


def dense_accumulate(counts: Array, pins: Array, valid: Array) -> Array:
    """Scatter-add a batch of visit events into per-query-slot dense counts.

    counts: (n_slots, n_pins) int32
    pins:   (n_slots, m) int32 visited pin ids (may contain junk where invalid)
    valid:  (n_slots, m) bool
    """
    n_slots, n_pins = counts.shape
    safe = jnp.where(valid, pins, 0).astype(jnp.int32)
    inc = valid.astype(counts.dtype)

    def one(c, p, i):
        return c.at[p].add(i, mode="drop")

    return jax.vmap(one)(counts, safe, inc)


def dense_accumulate_flat(counts: Array, pins: Array, valid: Array) -> Array:
    """Single-slot variant: counts (n_pins,), pins/valid (m,)."""
    safe = jnp.where(valid, pins, 0).astype(jnp.int32)
    return counts.at[safe].add(valid.astype(counts.dtype), mode="drop")


def accumulate_packed_events(
    counts: Array, events: Array, n_bins: int, backend: str
) -> Array:
    """Accumulate packed ``slot * n_pins + pin`` events into flat counts.

    Events >= n_bins are the walk's invalid-step sentinel and are dropped.
    Two engines, matching the walk backends (core/walk.py):

      * "xla"    — scatter-add (``.at[].add``): random writes, fine on
                   CPU/GPU, the worst access pattern on TPU.
      * "pallas" — the tile-scan histogram kernel (kernels/visit_counter):
                   each count tile scans the event chunk with vectorized
                   compares in VMEM; no scatters anywhere.
    """
    if backend == "pallas":
        from repro.kernels import ops  # local import: kernels layer on top

        return counts + ops.visit_counts(
            events.reshape(-1).astype(jnp.int32), n_bins, use_kernel=True
        )
    # not dense_accumulate_flat: that helper casts indices to int32, which
    # would corrupt int64 packed ids on production-scale graphs
    valid = events < n_bins
    safe = jnp.where(valid, events, 0)
    return counts.at[safe.reshape(-1)].add(
        valid.astype(counts.dtype).reshape(-1), mode="drop"
    )


def accumulate_packed_events_with_high(
    counts: Array,
    high: Array,
    events: Array,
    n_slots: int,
    n_pins: int,
    n_v: int,
    backend: str,
) -> Tuple[Array, Array]:
    """Accumulate packed events AND maintain the early-stop tally (Alg. 3).

    counts: (n_slots * n_pins,) int32 running visit counts.
    high:   (n_slots,) int32 running count of pins that reached ``n_v``
            visits (the quantity Algorithm 3 compares against ``n_p``).
    events: packed ``slot * n_pins + pin`` ids; values >= n_slots * n_pins
            are the walk's invalid-step sentinel and are dropped.

    Returns ``(new_counts, new_high)``.  The point of this API is that the
    caller's while-loop body no longer reduces the whole
    ``n_slots * n_pins`` buffer per iteration to recompute ``n_high``:

      * "pallas" — the fused ``visit_counter_update_high`` kernel: the
        count tile is updated in VMEM and per-slot threshold crossings come
        out of the same kernel launch.
      * "xla"    — chunk-local twin: scatter-add the events, then find the
        crossings by sorting only the CHUNK's events (O(E log E),
        E = chunk_steps * n_walkers) — old/new counts are gathered at the
        touched bins, a bin that crossed is counted once via the sort's
        first-occurrence mask.

    Both paths do identical integer arithmetic, so counts and tallies are
    bit-identical (tests/test_earlystop_parity.py).  Graphs whose packed id
    space overflows int32 (``n_slots * n_pins >= 2**31``) fall back to the
    xla path exactly like the fused walk kernel does.  Requires
    ``n_v >= 1``: counts start at zero, so a non-positive threshold could
    never *cross* and the tally would disagree with a full recount.
    """
    if n_v < 1:
        raise ValueError(f"n_v must be >= 1 for crossing tallies, got {n_v}")
    n_bins = n_slots * n_pins
    flat = events.reshape(-1)
    if (
        backend == "pallas"
        and n_bins + 1 < 2**31
        and flat.dtype == jnp.int32
    ):
        from repro.kernels import ops  # local import: kernels layer on top

        new_counts, delta = ops.visit_counts_update_high(
            counts, flat, n_slots=n_slots, n_pins=n_pins, n_v=n_v,
            use_kernel=True,
        )
        return new_counts, high + delta

    # the id space can be wider than the event dtype (int32 events against
    # an int64-scale n_bins only happens in shape-level tests — the walk
    # emits int64 events at that scale — but the bound must not overflow)
    dt_max = int(jnp.iinfo(flat.dtype).max)
    oob = min(n_bins, dt_max)
    valid = (flat >= 0) & (flat < oob)
    idx = jnp.where(valid, flat, 0)
    new_counts = counts.at[idx].add(valid.astype(counts.dtype), mode="drop")
    # crossings from the touched bins only: sort the chunk, dedup runs
    sorted_e = jnp.sort(jnp.where(valid, flat, oob))
    first = jnp.concatenate(
        [jnp.ones((1,), jnp.bool_), sorted_e[1:] != sorted_e[:-1]]
    )
    in_range = sorted_e < oob
    safe = jnp.where(in_range, sorted_e, 0)
    old_c = jnp.take(counts, safe)
    new_c = jnp.take(new_counts, safe)
    crossed = first & in_range & (old_c < n_v) & (new_c >= n_v)
    slot = jnp.where(in_range, safe // n_pins, n_slots).astype(jnp.int32)
    delta = jax.ops.segment_sum(
        crossed.astype(jnp.int32), slot, num_segments=n_slots + 1
    )[:n_slots]
    return new_counts, high + delta


def boost_combine(counts_q: Array, weights: Array | None = None) -> Array:
    """Multi-hit booster, Eq. 3:  V[p] = (sum_q w_q * sqrt(V_q[p]))**2.

    With a single slot this reduces to the raw count (paper's note that a
    single-query visit count is unchanged).  ``weights`` generalizes the
    equal-weight paper formula; pass None for the faithful version.
    """
    root = jnp.sqrt(counts_q.astype(jnp.float32))
    if weights is not None:
        root = root * weights[:, None].astype(jnp.float32)
    s = jnp.sum(root, axis=0)
    return s * s


def n_high_visited(counts_q: Array, n_v: int) -> Array:
    """Per-slot count of pins whose visit count reached n_v (early stopping)."""
    return jnp.sum((counts_q >= n_v).astype(jnp.int32), axis=-1)


def topk_dense(boosted: Array, k: int) -> Tuple[Array, Array]:
    """Top-k (scores, pin ids) from a dense boosted count vector."""
    vals, idx = jax.lax.top_k(boosted, k)
    return vals, idx


# ---------------------------------------------------------------------------
# Event-buffer (sort-based) counters — scale-free path
# ---------------------------------------------------------------------------


def events_to_counts(
    event_ids: Array, n_slots: int, max_unique: int
) -> Tuple[Array, Array]:
    """Aggregate visit events by (slot, pin) without dense graph-size state.

    event_ids: (m,) int64 packed events ``slot * n_pins + pin``; invalid
               events are encoded as a sentinel larger than every valid id.
    Returns (unique_packed_ids, counts) each (max_unique,), padded with the
    sentinel / zero.  Equivalent to the paper's hash-table contents.
    """
    m = event_ids.shape[0]
    sorted_ids = jnp.sort(event_ids)
    # boundary[i] = 1 where a new run starts
    boundary = jnp.concatenate(
        [jnp.ones((1,), jnp.int32), (sorted_ids[1:] != sorted_ids[:-1]).astype(jnp.int32)]
    )
    run_idx = jnp.cumsum(boundary) - 1  # which unique slot each event maps to
    counts = jax.ops.segment_sum(
        jnp.ones((m,), jnp.int32), run_idx, num_segments=max_unique
    )
    # representative id per run
    uniq = jax.ops.segment_max(sorted_ids, run_idx, num_segments=max_unique)
    return uniq, counts


def boosted_from_events(
    uniq_packed: Array,
    counts: Array,
    n_pins_total: int,
    sentinel: int,
    max_unique: int,
) -> Tuple[Array, Array]:
    """Apply Eq. 3 across query slots given (slot*n_pins + pin, count) pairs.

    Strategy: map every (slot, pin, count) run to (pin, sqrt(count)), then
    aggregate again by pin with a second sort, and square.  Returns
    (pin_ids, boosted_scores) padded with (sentinel, 0).
    """
    pin = jnp.where(uniq_packed >= sentinel, sentinel, uniq_packed % n_pins_total)
    root = jnp.where(uniq_packed >= sentinel, 0.0, jnp.sqrt(counts.astype(jnp.float32)))
    order = jnp.argsort(pin)
    pin_s = pin[order]
    root_s = root[order]
    boundary = jnp.concatenate(
        [jnp.ones((1,), jnp.int32), (pin_s[1:] != pin_s[:-1]).astype(jnp.int32)]
    )
    run_idx = jnp.cumsum(boundary) - 1
    summed = jax.ops.segment_sum(root_s, run_idx, num_segments=max_unique)
    rep_pin = jax.ops.segment_max(pin_s, run_idx, num_segments=max_unique)
    boosted = summed * summed
    boosted = jnp.where(rep_pin >= sentinel, 0.0, boosted)
    return rep_pin, boosted


def topk_events(pin_ids: Array, scores: Array, k: int) -> Tuple[Array, Array]:
    vals, idx = jax.lax.top_k(scores, k)
    return vals, jnp.take(pin_ids, idx)


@partial(jax.jit, static_argnames=("n_v", "max_unique"))
def n_high_from_events(event_ids: Array, n_v: int, max_unique: int) -> Array:
    """Early-stopping statistic from an event buffer: #(slot,pin) runs >= n_v."""
    _, counts = events_to_counts(event_ids, 1, max_unique)
    return jnp.sum((counts >= n_v).astype(jnp.int32))


def events_n_high_per_slot(
    event_ids: Array, n_slots: int, n_pins: int, n_v: int, max_unique: int
) -> Array:
    """Per-slot Algorithm 3 statistic from a packed event buffer.

    Returns (n_slots,) int32 — the number of pins of each query slot whose
    aggregated visit count reached ``n_v``.  This is the event-mode twin of
    the dense engine's running ``n_high`` tally (the buffer has no dense
    counts to tally incrementally, so it re-aggregates by sort; the walk
    only calls it every ``check_every`` chunks).
    """
    sentinel = n_slots * n_pins
    uniq, counts = events_to_counts(event_ids, n_slots, max_unique)
    hot = (counts >= n_v) & (uniq < sentinel)
    slot_of_run = jnp.where(hot, uniq // n_pins, n_slots)
    return jax.ops.segment_sum(
        hot.astype(jnp.int32),
        slot_of_run.astype(jnp.int32),
        num_segments=n_slots + 1,
    )[:n_slots]
