"""Pallas TPU kernel: tiled dense histogram over a bounded event buffer.

This is the TPU-native replacement for Pixie's open-addressing visit-count
hash table (paper §3.3).  The paper bounds the table by the step budget N;
we keep the same bound on the event buffer and flip the data structure
inside-out: instead of scattering events into a table (random writes — the
worst TPU access pattern), each grid cell owns a *tile of the count table*
in VMEM and scans the event buffer with vectorized compares:

    counts[t] = sum_m 1[events[m] == tile_base + t]

The compare matrix (event_chunk x tile) lives entirely in VREGs/VMEM, the
event buffer streams through VMEM once per count tile, and there are no
scatters anywhere.  Grid = (n_tiles, n_chunks); the chunk axis is innermost
so each tile block accumulates across event chunks in place.

VMEM budget per program: tile (TILE,) int32 + chunk (CHUNK,) int32 + the
(CHUNK, TILE) one-hot intermediate = 4*(512 + 2048 + 512*2048) B ~ 4.2 MiB,
comfortably inside the ~16 MiB v5e VMEM.

Three entry points share the tile-scan core:

* ``visit_counter`` — plain histogram of a flat-id event buffer (kept as
  the minimal kernel; generic id histograms).
* ``visit_counter_wide`` — histogram of WIDE (slot, id) int32 event lane
  pairs; the flat ``slot * n_dim + id`` bin id is formed inside the
  kernel, so the lanes themselves never carry the packed product.
* ``visit_counter_update_high`` — the fused early-stop counter for the
  dense walk engine (Algorithm 3), also wide: takes the PRIOR running counts as an
  input, accumulates the chunk's events on top of them *inside VMEM*, and
  additionally emits, per query slot, how many count-table entries crossed
  the ``n_v`` visit threshold during this update.  The walk loop's
  early-stop condition then reads a ``(n_slots,)`` running tally instead of
  re-reducing the whole ``n_slots * n_pins`` buffer every while-loop
  iteration — the last O(n_slots*n_pins)-per-chunk cost on the dense path.

This kernel is the aggregation half of the fused walk engine
(``WalkConfig(backend="pallas")``): ``kernels/walk_step.walk_steps_fused``
emits WIDE (slot, pin) int32 event lanes (slot lane sentinel ``n_slots``
for invalid steps) and ``core/counter.accumulate_packed_events[_with_high]``
histograms each chunk over ``n_slots * n_pins`` bins with the ``*_wide``
kernels instead of XLA scatter-add.  The wide kernels pack
``slot * n_pins + pin`` INSIDE the kernel, in VMEM: dense counting
inherently requires the flat bin space to fit a materialized buffer
(< 2**31 bins — enforced by the wrapper), so the in-kernel product is
always int32-safe; sentinel events map to bin ``n_slots * n_pins`` which
never matches a live tile and drops out of the histogram for free.
Id spaces PAST 2**31 never reach these kernels — they use the event-mode
(sort-based) counting path, which consumes the wide lanes directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 512     # count-table entries per grid cell (lane-dim multiple)
DEFAULT_CHUNK = 2048   # events streamed per inner grid step
SLOT_PAD = 8           # sublane-friendly padding of the per-slot high output


def _visit_counter_kernel(events_ref, counts_ref, *, tile: int, chunk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    tile_base = pl.program_id(0) * tile
    ev = events_ref[...]                                   # (chunk,)
    # (chunk, tile) one-hot compare — vectorized, no scatter
    ids = tile_base + jax.lax.broadcasted_iota(jnp.int32, (chunk, tile), 1)
    hit = (ev[:, None] == ids).astype(jnp.int32)
    counts_ref[...] += jnp.sum(hit, axis=0)


@functools.partial(
    jax.jit, static_argnames=("n_bins", "tile", "chunk", "interpret")
)
def visit_counter(
    events: jax.Array,
    n_bins: int,
    *,
    tile: int = DEFAULT_TILE,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool | None = None,
) -> jax.Array:
    """Histogram of `events` over [0, n_bins). Out-of-range ids are dropped.

    events: (m,) int32 — visited pin ids; pad/invalid entries may be any
    value outside [0, n_bins) (the walk uses -1).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m = events.shape[0]
    # pad events to a chunk multiple with an out-of-range sentinel
    m_pad = -(-m // chunk) * chunk
    if m_pad != m:
        events = jnp.concatenate(
            [events, jnp.full((m_pad - m,), -1, events.dtype)]
        )
    n_pad = -(-n_bins // tile) * tile
    grid = (n_pad // tile, m_pad // chunk)
    out = pl.pallas_call(
        functools.partial(_visit_counter_kernel, tile=tile, chunk=chunk),
        grid=grid,
        in_specs=[pl.BlockSpec((chunk,), lambda i, j: (j,))],
        out_specs=pl.BlockSpec((tile,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.int32),
        interpret=interpret,
    )(events.astype(jnp.int32))
    return out[:n_bins]


# ---------------------------------------------------------------------------
# Wide-event tile-scan histogram: (slot, id) int32 lanes in, flat bins out
# ---------------------------------------------------------------------------


def _require_dense_bins(n_bins: int) -> None:
    """Dense counting materializes an (n_bins,) buffer: must fit int32."""
    if n_bins + 1 >= 2**31:
        raise ValueError(
            f"dense counting needs n_slots * n_dim < 2**31, got {n_bins}; "
            "id spaces past int32 use event-mode (sort-based) counting"
        )


def _flat_ids_from_lanes(
    slot_ev, id_ev, n_slots: int, n_dim: int, q_ev=None, n_queries: int = 0
):
    """Pack wide lanes to flat bin ids in-register; invalid events -> -1.

    With a query lane (``q_ev``, batch-native mode) the bins are
    query-major — ``(query * n_slots + slot) * n_dim + id`` — formed right
    here in VMEM, so no lane ever carries a packed product outside the
    kernel; validity then additionally requires ``0 <= query < n_queries``
    (the walk's query sentinel is ``n_queries``).  The products are
    int32-safe because the wide wrappers only accept bin spaces that fit a
    dense buffer (``n_rows * n_dim < 2**31``).
    """
    valid = (
        (slot_ev >= 0) & (slot_ev < n_slots)
        & (id_ev >= 0) & (id_ev < n_dim)
    )
    row = slot_ev
    if q_ev is not None:
        valid &= (q_ev >= 0) & (q_ev < n_queries)
        row = q_ev * jnp.int32(n_slots) + slot_ev
    flat = (
        jnp.where(valid, row, 0) * jnp.int32(n_dim)
        + jnp.where(valid, id_ev, 0)
    )
    return jnp.where(valid, flat, jnp.int32(-1))


def _visit_counter_wide_kernel(
    *refs, tile: int, chunk: int, n_slots: int, n_dim: int,
    n_queries: int = 0,
):
    """Tile-scan histogram over wide lanes; with ``n_queries > 0`` the
    event refs lead with a query lane and bins are query-major."""
    j = pl.program_id(1)
    counts_ref = refs[-1]

    @pl.when(j == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    tile_base = pl.program_id(0) * tile
    if n_queries:
        q_ref, slot_ref, id_ref = refs[:3]
        ev = _flat_ids_from_lanes(
            slot_ref[...], id_ref[...], n_slots, n_dim,
            q_ev=q_ref[...], n_queries=n_queries,
        )                                                  # (chunk,)
    else:
        slot_ref, id_ref = refs[:2]
        ev = _flat_ids_from_lanes(
            slot_ref[...], id_ref[...], n_slots, n_dim
        )                                                  # (chunk,)
    ids = tile_base + jax.lax.broadcasted_iota(jnp.int32, (chunk, tile), 1)
    hit = (ev[:, None] == ids).astype(jnp.int32)
    counts_ref[...] += jnp.sum(hit, axis=0)


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_slots", "n_dim", "n_queries", "tile", "chunk", "interpret"
    ),
)
def visit_counter_wide(
    slot_events: jax.Array,
    id_events: jax.Array,
    query_events: jax.Array | None = None,
    *,
    n_slots: int,
    n_dim: int,
    n_queries: int = 0,
    tile: int = DEFAULT_TILE,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool | None = None,
) -> jax.Array:
    """Histogram of wide (slot, id) lanes over ``n_slots * n_dim`` flat bins.

    slot_events / id_events: (m,) int32; an event counts iff
    ``0 <= slot < n_slots`` and ``0 <= id < n_dim`` (the walk's invalid
    sentinel, slot = ``n_slots``, is dropped for free).  Returns
    ``(n_slots * n_dim,)`` int32.

    Batch-native mode: pass ``query_events`` (the third wide lane, query
    sentinel ``n_queries``) and ``n_queries > 0`` to histogram a whole
    serving batch's events in one call over
    ``n_queries * n_slots * n_dim`` query-major bins — the triple is
    packed to flat bin ids inside the kernel, in VMEM.
    """
    with_query = query_events is not None
    if with_query and n_queries <= 0:
        raise ValueError("query_events given but n_queries not set (> 0)")
    n_rows = n_queries * n_slots if with_query else n_slots
    n_bins = n_rows * n_dim
    _require_dense_bins(n_bins)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m = slot_events.shape[0]
    if m == 0:  # zero-size grid is illegal; nothing to count either way
        return jnp.zeros((n_bins,), jnp.int32)
    lanes = ([query_events] if with_query else []) + [slot_events, id_events]
    lanes = [l.astype(jnp.int32) for l in lanes]
    m_pad = -(-m // chunk) * chunk
    if m_pad != m:
        pad = jnp.full((m_pad - m,), -1, jnp.int32)
        lanes = [jnp.concatenate([l, pad]) for l in lanes]
    n_pad = -(-n_bins // tile) * tile
    grid = (n_pad // tile, m_pad // chunk)
    ev_spec = pl.BlockSpec((chunk,), lambda i, j: (j,))
    out = pl.pallas_call(
        functools.partial(
            _visit_counter_wide_kernel, tile=tile, chunk=chunk,
            n_slots=n_slots, n_dim=n_dim,
            n_queries=n_queries if with_query else 0,
        ),
        grid=grid,
        in_specs=[ev_spec] * len(lanes),
        out_specs=pl.BlockSpec((tile,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.int32),
        interpret=interpret,
    )(*lanes)
    return out[:n_bins]


# ---------------------------------------------------------------------------
# Fused count-update + incremental early-stop tally (dense walk hot path)
# ---------------------------------------------------------------------------


def _visit_counter_high_kernel(
    *refs,
    tile: int, chunk: int, n_chunks: int, n_slots: int, n_pins: int,
    n_v: int, slot_pad: int, n_queries: int = 0,
):
    """Tile-scan histogram on top of PRIOR counts, plus threshold crossings.

    Events arrive as wide (slot, pin) int32 lanes — led by a query lane in
    batch-native mode (``n_queries > 0``) — and are packed to flat bin ids
    in-register (int32-safe: the wrapper enforces the dense-bin
    precondition; query-major ``(query * n_slots + slot) * n_pins + pin``
    when the query lane is present).  The count tile is initialised from
    the prior running counts, stays in VMEM while every event chunk
    streams past (inner grid axis), and after the last chunk the tile is
    compared against its prior values: entries that crossed
    ``count >= n_v`` during this update are summed per count row
    (``bin // n_pins`` — the query slot, or the (query, slot) pair in
    batch mode) with a one-hot compare — no scatter, no full-buffer
    reduction outside the kernel.
    """
    if n_queries:
        q_ref, slot_ref, pin_ref, prior_ref, counts_ref, high_ref = refs
    else:
        slot_ref, pin_ref, prior_ref, counts_ref, high_ref = refs
        q_ref = None
    j = pl.program_id(1)
    tile_base = pl.program_id(0) * tile

    @pl.when(j == 0)
    def _init():
        counts_ref[...] = prior_ref[...]
        high_ref[...] = jnp.zeros_like(high_ref)

    ev = _flat_ids_from_lanes(
        slot_ref[...], pin_ref[...], n_slots, n_pins,
        q_ev=None if q_ref is None else q_ref[...],
        n_queries=n_queries,
    )                                                      # (chunk,)
    ids = tile_base + jax.lax.broadcasted_iota(jnp.int32, (chunk, tile), 1)
    hit = (ev[:, None] == ids).astype(jnp.int32)
    counts_ref[...] += jnp.sum(hit, axis=0)

    @pl.when(j == n_chunks - 1)
    def _emit_high():
        prior = prior_ref[...]                             # (tile,)
        new = counts_ref[...]
        # n_v is compared, never added: a huge disable-early-stop sentinel
        # (e.g. int32max // 2) cannot overflow anything here.
        crossed = ((prior < n_v) & (new >= n_v)).astype(jnp.int32)
        bin_row = tile_base + jax.lax.broadcasted_iota(
            jnp.int32, (1, tile), 1
        )                                                  # (1, tile)
        slot_row = bin_row // n_pins
        slot_col = jax.lax.broadcasted_iota(
            jnp.int32, (slot_pad, tile), 0
        )
        onehot = (slot_col == slot_row).astype(jnp.int32)  # (slot_pad, tile)
        high_ref[...] = jnp.sum(
            onehot * crossed[None, :], axis=1
        )[None, :]


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_slots", "n_pins", "n_v", "n_queries", "tile", "chunk", "interpret"
    ),
)
def visit_counter_update_high(
    prior_counts: jax.Array,
    slot_events: jax.Array,
    pin_events: jax.Array,
    query_events: jax.Array | None = None,
    *,
    n_slots: int,
    n_pins: int,
    n_v: int,
    n_queries: int = 0,
    tile: int = DEFAULT_TILE,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused ``new = prior + hist(events)`` plus per-slot n_v crossings.

    prior_counts: (n_slots * n_pins,) int32 running visit counts.
    slot_events / pin_events: (m,) int32 wide event lanes; an event counts
                  iff ``0 <= slot < n_slots`` and ``0 <= pin < n_pins``
                  (the walk's invalid-step sentinel, slot = ``n_slots``,
                  is dropped).
    Returns ``(new_counts (n_slots * n_pins,), delta_high (n_slots,))``
    where ``delta_high[s]`` counts bins of slot s whose visit count crossed
    from below ``n_v`` to ``>= n_v`` during this update.  Requires
    ``n_v >= 1`` (counts start at zero, so a non-positive threshold would
    be "already crossed" and never increment the tally).

    Batch-native mode: pass ``query_events`` (query sentinel
    ``n_queries``) and ``n_queries > 0`` to update a whole serving batch's
    running counts in one call — ``prior_counts`` then has
    ``n_queries * n_slots * n_pins`` query-major bins and ``delta_high``
    one entry per (query, slot) row, query-major.
    """
    if n_v < 1:
        raise ValueError(f"n_v must be >= 1 for crossing tallies, got {n_v}")
    with_query = query_events is not None
    if with_query and n_queries <= 0:
        raise ValueError("query_events given but n_queries not set (> 0)")
    n_rows = n_queries * n_slots if with_query else n_slots
    n_bins = n_rows * n_pins
    _require_dense_bins(n_bins)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m = slot_events.shape[0]
    if m == 0:  # zero-size grid is illegal; nothing to count either way
        return (
            prior_counts.astype(jnp.int32),
            jnp.zeros((n_rows,), jnp.int32),
        )
    lanes = ([query_events] if with_query else []) + [slot_events, pin_events]
    lanes = [l.astype(jnp.int32) for l in lanes]
    m_pad = -(-m // chunk) * chunk
    if m_pad != m:
        pad = jnp.full((m_pad - m,), -1, jnp.int32)
        lanes = [jnp.concatenate([l, pad]) for l in lanes]
    n_pad = -(-n_bins // tile) * tile
    prior = prior_counts.astype(jnp.int32)
    if n_pad != n_bins:
        prior = jnp.concatenate(
            [prior, jnp.zeros((n_pad - n_bins,), jnp.int32)]
        )
    slot_pad = -(-n_rows // SLOT_PAD) * SLOT_PAD
    n_tiles, n_chunks = n_pad // tile, m_pad // chunk
    ev_spec = pl.BlockSpec((chunk,), lambda i, j: (j,))
    counts, high_parts = pl.pallas_call(
        functools.partial(
            _visit_counter_high_kernel,
            tile=tile, chunk=chunk, n_chunks=n_chunks,
            n_slots=n_slots, n_pins=n_pins, n_v=n_v, slot_pad=slot_pad,
            n_queries=n_queries if with_query else 0,
        ),
        grid=(n_tiles, n_chunks),
        in_specs=[ev_spec] * len(lanes) + [
            pl.BlockSpec((tile,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda i, j: (i,)),
            pl.BlockSpec((1, slot_pad), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles, slot_pad), jnp.int32),
        ],
        interpret=interpret,
    )(*lanes, prior)
    # (n_tiles, slot_pad) partials: a tiny reduction, NOT O(n_rows*n_pins)
    return counts[:n_bins], jnp.sum(high_parts, axis=0)[:n_rows]
