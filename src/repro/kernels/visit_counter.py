"""Pallas TPU kernel: tiled dense histogram over a bounded event buffer.

This is the TPU-native replacement for Pixie's open-addressing visit-count
hash table (paper §3.3).  The paper bounds the table by the step budget N;
we keep the same bound on the event buffer and flip the data structure
inside-out: instead of scattering events into a table (random writes — the
worst TPU access pattern), each grid cell owns a *tile of the count table*
in VMEM and scans the event buffer with vectorized compares:

    counts[t] = sum_m 1[events[m] == tile_base + t]

The compare matrix (event_chunk x tile) lives entirely in VREGs/VMEM, the
event buffer streams through VMEM once per count tile, and there are no
scatters anywhere.  Grid = (n_tiles, n_chunks); the chunk axis is innermost
so each tile block accumulates across event chunks in place.

VMEM budget per program: tile (TILE,) int32 + chunk (CHUNK,) int32 + the
(CHUNK, TILE) one-hot intermediate = 4*(512 + 2048 + 512*2048) B ~ 4.2 MiB,
comfortably inside the ~16 MiB v5e VMEM.

This kernel is the aggregation half of the fused walk engine
(``WalkConfig(backend="pallas")``): ``kernels/walk_step.walk_steps_fused``
emits packed ``slot * n_pins + pin`` events (sentinel = ``n_slots * n_pins``,
conveniently out-of-range here, so invalid steps drop out of the histogram
for free) and ``core/counter.accumulate_packed_events`` histograms each
chunk over ``n_slots * n_pins`` bins with this kernel instead of XLA
scatter-add.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 512     # count-table entries per grid cell (lane-dim multiple)
DEFAULT_CHUNK = 2048   # events streamed per inner grid step


def _visit_counter_kernel(events_ref, counts_ref, *, tile: int, chunk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    tile_base = pl.program_id(0) * tile
    ev = events_ref[...]                                   # (chunk,)
    # (chunk, tile) one-hot compare — vectorized, no scatter
    ids = tile_base + jax.lax.broadcasted_iota(jnp.int32, (chunk, tile), 1)
    hit = (ev[:, None] == ids).astype(jnp.int32)
    counts_ref[...] += jnp.sum(hit, axis=0)


@functools.partial(
    jax.jit, static_argnames=("n_bins", "tile", "chunk", "interpret")
)
def visit_counter(
    events: jax.Array,
    n_bins: int,
    *,
    tile: int = DEFAULT_TILE,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool | None = None,
) -> jax.Array:
    """Histogram of `events` over [0, n_bins). Out-of-range ids are dropped.

    events: (m,) int32 — visited pin ids; pad/invalid entries may be any
    value outside [0, n_bins) (the walk uses -1).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m = events.shape[0]
    # pad events to a chunk multiple with an out-of-range sentinel
    m_pad = -(-m // chunk) * chunk
    if m_pad != m:
        events = jnp.concatenate(
            [events, jnp.full((m_pad - m,), -1, events.dtype)]
        )
    n_pad = -(-n_bins // tile) * tile
    grid = (n_pad // tile, m_pad // chunk)
    out = pl.pallas_call(
        functools.partial(_visit_counter_kernel, tile=tile, chunk=chunk),
        grid=grid,
        in_specs=[pl.BlockSpec((chunk,), lambda i, j: (j,))],
        out_specs=pl.BlockSpec((tile,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.int32),
        interpret=interpret,
    )(events.astype(jnp.int32))
    return out[:n_bins]
