"""Pallas TPU kernel: flash-decoding GQA attention for one new token.

The LM serving path (decode_32k / long_500k cells) attends one query token
against a long KV cache.  The cache never fits VMEM, so the kernel streams
KV blocks HBM->VMEM and keeps the online-softmax state (running max m,
normalizer l, weighted accumulator acc) in VMEM scratch across the KV grid
axis — the flash-decoding recurrence:

    m'   = max(m, rowmax(s))
    l'   = l * exp(m - m') + rowsum(exp(s - m'))
    acc' = acc * exp(m - m') + exp(s - m') @ V

Grid = (batch, kv_heads, s_blocks); the s axis is innermost so scratch
carries across it.  GQA falls out of blocking the query-head axis by
kv-head: each program holds the (group, dh) query slice that shares one
kv head.  Length masking handles ragged cache fill.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 512
NEG_INF = -1e30


def _decode_attn_kernel(
    len_ref, q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref,
    *, block_s: int, scale: float,
):
    s_idx = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                 # (group, dh)
    k = k_ref[0, 0].astype(jnp.float32)                 # (block_s, dh)
    v = v_ref[0, 0].astype(jnp.float32)                 # (block_s, dh)
    length = len_ref[0]

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                        # (group, block_s)
    span = s_idx * block_s + jax.lax.broadcasted_iota(
        jnp.int32, scores.shape, 1
    )
    scores = jnp.where(span < length, scores, NEG_INF)

    m_prev = m_ref[...]                              # (group, 1)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    p = jnp.exp(scores - m_new)                      # (group, block_s)
    corr = jnp.exp(m_prev - m_new)                   # (group, 1)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(s_idx == n_s - 1)
    def _finish():
        out_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_s", "interpret")
)
def decode_attention(
    q: jax.Array,        # (b, h, dh)
    k: jax.Array,        # (b, s, kh, dh)
    v: jax.Array,        # (b, s, kh, dh)
    lengths: jax.Array,  # (b,) int32
    *,
    block_s: int = DEFAULT_BLOCK_S,
    interpret: bool | None = None,
) -> jax.Array:
    """Single-token GQA decode attention -> (b, h, dh) f32."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, h, dh = q.shape
    s, kh = k.shape[1], k.shape[2]
    assert h % kh == 0, (h, kh)
    group = h // kh
    scale = dh ** -0.5
    block_s = min(block_s, s)
    s_pad = -(-s // block_s) * block_s
    if s_pad != s:
        pad = [(0, 0), (0, s_pad - s), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    # (b, kh, s, dh) layout so the kv-head axis is blockable
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    qg = q.reshape(b, kh, group, dh)

    grid = (b, kh, s_pad // block_s)
    out = pl.pallas_call(
        functools.partial(_decode_attn_kernel, block_s=block_s, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda ib, ik, is_: (ib,)),
            pl.BlockSpec((1, 1, group, dh), lambda ib, ik, is_: (ib, ik, 0, 0)),
            pl.BlockSpec((1, 1, block_s, dh), lambda ib, ik, is_: (ib, ik, is_, 0)),
            pl.BlockSpec((1, 1, block_s, dh), lambda ib, ik, is_: (ib, ik, is_, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, group, dh), lambda ib, ik, is_: (ib, ik, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, kh, group, dh), jnp.float32),
        scratch_shapes=[
            # m, l, acc carry the online-softmax state across the s grid axis
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, dh), jnp.float32),
        ],
        interpret=interpret,
    )(
        lengths.astype(jnp.int32),
        qg.reshape(b, kh, group, dh),
        kt,
        vt,
    )
    return out.reshape(b, h, dh)
