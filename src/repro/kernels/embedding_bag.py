"""Pallas TPU kernel: EmbeddingBag (gather + pool) for the recsys substrate.

JAX has no native EmbeddingBag; the oracle is `take + segment-style pooling`
(ref.py).  The kernel tiles the *batch* of bags into VMEM, leaves the
embedding table in HBM (memory_space=ANY — recsys tables are 10^6..10^9
rows and never fit VMEM), and gathers + accumulates rows per bag with the
feature dimension vectorized across lanes.  This is the v5e analogue of the
SparseCore lookup: ids are small VMEM-resident integers, each id costs one
HBM row fetch of d*4 bytes, pooling is free (accumulated in VREGs).

Fixed bag size with -1 padding keeps every shape static (SPMD-friendly);
multi-hot recsys features and DLRM single-hot lookups (bag size 1) are both
instances.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 64  # bags per grid cell


def _embedding_bag_kernel(
    ids_ref, weights_ref, table_ref, out_ref, *, block_b: int, bag: int,
    mean: bool,
):
    d = out_ref.shape[-1]

    def bag_body(b, acc):
        def elem_body(l, inner):
            acc, wsum = inner
            idx = ids_ref[b, l]
            valid = idx >= 0
            safe = jnp.where(valid, idx, 0)
            row = table_ref[pl.ds(safe, 1), :]  # (1, d)
            w = weights_ref[b, l] * valid.astype(jnp.float32)
            acc = acc + row[0].astype(jnp.float32) * w
            return acc, wsum + w

        acc_b, wsum = jax.lax.fori_loop(
            0, bag, elem_body, (jnp.zeros((d,), jnp.float32), 0.0)
        )
        if mean:
            acc_b = acc_b / jnp.maximum(wsum, 1.0)
        return acc.at[b].set(acc_b)

    out = jax.lax.fori_loop(
        0, block_b, bag_body, jnp.zeros((block_b, d), jnp.float32)
    )
    out_ref[...] = out.astype(out_ref.dtype)


def _bag_pallas_call(
    ids2: jax.Array,      # (n, bag) int32, -1 padding
    weights2: jax.Array,  # (n, bag) f32
    table: jax.Array,     # (v, d)
    *,
    mode: str,
    block_b: int,
    interpret: bool,
) -> jax.Array:
    """Shared launch: tile flattened bags ``block_b`` rows per grid cell.

    ONE copy of the pad-and-launch plumbing for both the per-bag and the
    query-batched entry points, wrapping the ONE kernel body
    (`_embedding_bag_kernel`) — bit-parity between the two public shapes is
    structural, not re-proved.
    """
    n, bag = ids2.shape
    v, d = table.shape
    n_pad = -(-n // block_b) * block_b
    if n_pad != n:
        ids2 = jnp.concatenate(
            [ids2, jnp.full((n_pad - n, bag), -1, ids2.dtype)]
        )
        weights2 = jnp.concatenate(
            [weights2, jnp.zeros((n_pad - n, bag), weights2.dtype)]
        )
    grid = (n_pad // block_b,)
    out = pl.pallas_call(
        functools.partial(
            _embedding_bag_kernel,
            block_b=block_b,
            bag=bag,
            mean=(mode == "mean"),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, bag), lambda i: (i, 0)),
            pl.BlockSpec((block_b, bag), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d), table.dtype),
        interpret=interpret,
    )(ids2.astype(jnp.int32), weights2.astype(jnp.float32), table)
    return out[:n]


@functools.partial(
    jax.jit, static_argnames=("mode", "block_b", "interpret")
)
def embedding_bag_batched(
    table: jax.Array,                 # (v, d)
    ids: jax.Array,                   # (b, k, l) int32, -1 padding
    weights: Optional[jax.Array] = None,  # (b, k, l) f32
    *,
    mode: str = "sum",
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool | None = None,
) -> jax.Array:
    """Query-batched pooled lookup: (b, k, l) bags -> (b, k, d).

    The serving-path shape of :func:`embedding_bag`: a whole batch of
    queries' candidate neighborhoods pooled together.  Bags are flattened
    query-major onto the row axis and tiled ``block_b`` rows per grid cell
    over a rank-1 grid, so a batched two-stage serve step stays at ONE
    ``pallas_call`` per bag op regardless of batch size (the two-stage
    lowering pin in tests/test_two_stage.py counts on this) — batch only
    changes the number of grid cells, never the number of launches.

    Accumulation inside each bag runs in ascending element order (the
    kernel's inner fori_loop), the same chain order as
    ``ref.embedding_bag_batched_ref`` — the tightest parity two separately
    compiled float programs can promise: the compiler may still contract a
    mul+add into an FMA on one side and not the other, so kernel-vs-oracle
    is pinned at tight tolerance, not array_equal.  EXACT cross-backend
    serving parity (`two_stage_backends_agree`) comes from the layer above:
    both walk backends share ONE stage-2 bag lowering
    (ops.embedding_bag_batched's platform default), the same trick that
    keeps the walk's float scores exact (shared boost over integer counts).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    if ids.ndim != 3:
        raise ValueError(
            f"embedding_bag_batched wants (batch, bags, bag_size) ids, got "
            f"shape {ids.shape}; for plain (bags, bag_size) use embedding_bag"
        )
    bq, k, bag = ids.shape
    n = bq * k
    ids2 = ids.reshape(n, bag)
    if weights is None:
        weights2 = jnp.ones((n, bag), jnp.float32)
    else:
        weights2 = weights.reshape(n, bag)
    out = _bag_pallas_call(
        ids2, weights2, table,
        mode=mode, block_b=block_b, interpret=interpret,
    )
    return out.reshape(bq, k, table.shape[1])


@functools.partial(
    jax.jit, static_argnames=("mode", "block_b", "interpret")
)
def embedding_bag(
    table: jax.Array,                 # (v, d)
    ids: jax.Array,                   # (b, l) int32, -1 padding
    weights: Optional[jax.Array] = None,  # (b, l) f32
    *,
    mode: str = "sum",
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool | None = None,
) -> jax.Array:
    """Pooled embedding lookup -> (b, d), dtype = table dtype."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, bag = ids.shape
    if weights is None:
        weights = jnp.ones((b, bag), jnp.float32)
    return _bag_pallas_call(
        ids, weights, table,
        mode=mode, block_b=block_b, interpret=interpret,
    )
