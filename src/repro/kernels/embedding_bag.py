"""Pallas TPU kernel: EmbeddingBag (gather + pool) for the recsys substrate.

JAX has no native EmbeddingBag; the oracle is `take + segment-style pooling`
(ref.py).  The kernel tiles the *batch* of bags into VMEM, leaves the
embedding table in HBM (memory_space=ANY — recsys tables are 10^6..10^9
rows and never fit VMEM), and gathers + accumulates rows per bag with the
feature dimension vectorized across lanes.  This is the v5e analogue of the
SparseCore lookup: ids are small VMEM-resident integers, each id costs one
HBM row fetch of d*4 bytes, pooling is free (accumulated in VREGs).

Fixed bag size with -1 padding keeps every shape static (SPMD-friendly);
multi-hot recsys features and DLRM single-hot lookups (bag size 1) are both
instances.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 64  # bags per grid cell


def _embedding_bag_kernel(
    ids_ref, weights_ref, table_ref, out_ref, *, block_b: int, bag: int,
    mean: bool,
):
    d = out_ref.shape[-1]

    def bag_body(b, acc):
        def elem_body(l, inner):
            acc, wsum = inner
            idx = ids_ref[b, l]
            valid = idx >= 0
            safe = jnp.where(valid, idx, 0)
            row = table_ref[pl.ds(safe, 1), :]  # (1, d)
            w = weights_ref[b, l] * valid.astype(jnp.float32)
            acc = acc + row[0].astype(jnp.float32) * w
            return acc, wsum + w

        acc_b, wsum = jax.lax.fori_loop(
            0, bag, elem_body, (jnp.zeros((d,), jnp.float32), 0.0)
        )
        if mean:
            acc_b = acc_b / jnp.maximum(wsum, 1.0)
        return acc.at[b].set(acc_b)

    out = jax.lax.fori_loop(
        0, block_b, bag_body, jnp.zeros((block_b, d), jnp.float32)
    )
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("mode", "block_b", "interpret")
)
def embedding_bag(
    table: jax.Array,                 # (v, d)
    ids: jax.Array,                   # (b, l) int32, -1 padding
    weights: Optional[jax.Array] = None,  # (b, l) f32
    *,
    mode: str = "sum",
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool | None = None,
) -> jax.Array:
    """Pooled embedding lookup -> (b, d), dtype = table dtype."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, bag = ids.shape
    v, d = table.shape
    if weights is None:
        weights = jnp.ones((b, bag), jnp.float32)
    b_pad = -(-b // block_b) * block_b
    if b_pad != b:
        ids = jnp.concatenate(
            [ids, jnp.full((b_pad - b, bag), -1, ids.dtype)]
        )
        weights = jnp.concatenate(
            [weights, jnp.zeros((b_pad - b, bag), weights.dtype)]
        )
    grid = (b_pad // block_b,)
    out = pl.pallas_call(
        functools.partial(
            _embedding_bag_kernel,
            block_b=block_b,
            bag=bag,
            mean=(mode == "mean"),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, bag), lambda i: (i, 0)),
            pl.BlockSpec((block_b, bag), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b_pad, d), table.dtype),
        interpret=interpret,
    )(ids.astype(jnp.int32), weights.astype(jnp.float32), table)
    return out[:b]
