"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` is the semantic ground truth: simple, obviously-correct jnp
code with no tiling or memory-space tricks.  Kernel tests sweep shapes and
dtypes and ``assert_allclose`` kernel-vs-oracle (exact for integer kernels).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# visit_counter: bounded-event histogram (the paper's open-addressing table)
# ---------------------------------------------------------------------------


def visit_counter_ref(events: Array, n_bins: int) -> Array:
    """Count occurrences of each id in [0, n_bins); ids outside are dropped.

    events: (m,) int32.  Returns (n_bins,) int32.
    """
    valid = (events >= 0) & (events < n_bins)
    safe = jnp.where(valid, events, 0)
    counts = jnp.zeros((n_bins,), jnp.int32)
    return counts.at[safe].add(valid.astype(jnp.int32))


# ---------------------------------------------------------------------------
# walk_step: one fused pin->board->pin superstep for a walker block
# ---------------------------------------------------------------------------


def walk_step_ref(
    curr: Array,          # (w,) int32 current pin ids
    query: Array,         # (w,) int32 restart pins
    rbits: Array,         # (w, 3) uint32 random bits: restart, board, pin
    p2b_offsets: Array,   # (n_pins + 1,) int32
    p2b_targets: Array,   # (e,) int32 board ids (global, >= n_pins)
    b2p_offsets: Array,   # (n_boards + 1,) int32
    b2p_targets: Array,   # (e,) int32 pin ids
    n_pins: int,
    alpha_u32: int,       # restart iff rbits[:,0] < alpha_u32
) -> Tuple[Array, Array, Array]:
    """Returns (next_pin, visited_pin, valid) each (w,)."""
    restart = rbits[:, 0] < jnp.uint32(alpha_u32)
    pos = jnp.where(restart, query, curr)

    start = jnp.take(p2b_offsets, pos)
    deg = jnp.take(p2b_offsets, pos + 1) - start
    idx = start + (rbits[:, 1].astype(jnp.int32) % jnp.maximum(deg, 1))
    board = jnp.take(p2b_targets, idx)
    board_ok = deg > 0

    b_local = jnp.where(board_ok, board - n_pins, 0)
    bstart = jnp.take(b2p_offsets, b_local)
    bdeg = jnp.take(b2p_offsets, b_local + 1) - bstart
    bidx = bstart + (rbits[:, 2].astype(jnp.int32) % jnp.maximum(bdeg, 1))
    nxt = jnp.take(b2p_targets, bidx)
    ok = board_ok & (bdeg > 0)

    next_pin = jnp.where(ok, nxt, query).astype(curr.dtype)
    visited = jnp.where(ok, nxt, 0).astype(curr.dtype)
    return next_pin, visited, ok


# ---------------------------------------------------------------------------
# embedding_bag: fixed-bag-size gather + pool (JAX has no native EmbeddingBag)
# ---------------------------------------------------------------------------


def embedding_bag_ref(
    table: Array,          # (v, d)
    ids: Array,            # (b, l) int32, -1 = padding
    weights: Optional[Array] = None,  # (b, l) f32
    mode: str = "sum",
) -> Array:
    """Per-bag pooled embedding lookup. Returns (b, d) in table dtype."""
    valid = ids >= 0
    safe = jnp.where(valid, ids, 0)
    rows = jnp.take(table, safe, axis=0)           # (b, l, d)
    w = valid.astype(table.dtype)
    if weights is not None:
        w = w * weights.astype(table.dtype)
    pooled = jnp.sum(rows * w[..., None], axis=1)  # (b, d)
    if mode == "mean":
        denom = jnp.maximum(jnp.sum(w, axis=1, keepdims=True), 1.0)
        pooled = pooled / denom
    return pooled


# ---------------------------------------------------------------------------
# decode_attention: single-token GQA attention over a (possibly long) KV cache
# ---------------------------------------------------------------------------


def decode_attention_ref(
    q: Array,        # (b, h, dh)
    k: Array,        # (b, s, kh, dh)
    v: Array,        # (b, s, kh, dh)
    lengths: Array,  # (b,) int32 valid KV length per sequence
    scale: Optional[float] = None,
) -> Array:
    """Flash-decoding semantics: softmax(q k^T / sqrt(dh)) v with length mask.

    h = kh * group; query head i attends to kv head i // group.
    Returns (b, h, dh) f32.
    """
    b, h, dh = q.shape
    s, kh = k.shape[1], k.shape[2]
    group = h // kh
    if scale is None:
        scale = dh ** -0.5
    qg = q.reshape(b, kh, group, dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, kf) * scale
    mask = jnp.arange(s)[None, :] < lengths[:, None]          # (b, s)
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, vf)
    return out.reshape(b, h, dh)
