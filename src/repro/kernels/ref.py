"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` is the semantic ground truth: simple, obviously-correct jnp
code with no tiling or memory-space tricks.  Kernel tests sweep shapes and
dtypes and ``assert_allclose`` kernel-vs-oracle (exact for integer kernels).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

_RMASK = 0x7FFFFFFF  # keep modulo operands non-negative int32


# ---------------------------------------------------------------------------
# visit_counter: bounded-event histogram (the paper's open-addressing table)
# ---------------------------------------------------------------------------


def visit_counter_ref(events: Array, n_bins: int) -> Array:
    """Count occurrences of each id in [0, n_bins); ids outside are dropped.

    events: (m,) int32.  Returns (n_bins,) int32.
    """
    valid = (events >= 0) & (events < n_bins)
    safe = jnp.where(valid, events, 0)
    counts = jnp.zeros((n_bins,), jnp.int32)
    return counts.at[safe].add(valid.astype(jnp.int32))


def visit_counter_wide_ref(
    slot_events: Array,
    id_events: Array,
    n_slots: int,
    n_dim: int,
    query_events: Optional[Array] = None,
    n_queries: int = 0,
) -> Array:
    """Histogram of wide (slot, id) event lanes over n_slots * n_dim bins.

    slot_events / id_events: (m,) int32 lanes; an event is valid iff
    ``0 <= slot < n_slots`` and ``0 <= id < n_dim`` (the walk's invalid
    sentinel is slot = n_slots).  Returns (n_slots * n_dim,) int32.  Only
    meaningful when the flat bin space fits a dense buffer — the wrapper
    layer enforces ``n_slots * n_dim < 2**31``.

    With a ``query_events`` lane (batch-native mode, ``n_queries > 0``) an
    event is additionally required to have ``0 <= query < n_queries``
    (query sentinel ``n_queries``) and the flat bins become query-major:
    ``(query * n_slots + slot) * n_dim + id`` over
    ``n_queries * n_slots * n_dim`` bins.
    """
    valid = (
        (slot_events >= 0) & (slot_events < n_slots)
        & (id_events >= 0) & (id_events < n_dim)
    )
    row = slot_events.astype(jnp.int32)
    n_rows = n_slots
    if query_events is not None:
        valid &= (query_events >= 0) & (query_events < n_queries)
        row = query_events.astype(jnp.int32) * n_slots + row
        n_rows = n_queries * n_slots
    flat = jnp.where(valid, row * n_dim + id_events.astype(jnp.int32), 0)
    counts = jnp.zeros((n_rows * n_dim,), jnp.int32)
    return counts.at[flat].add(valid.astype(jnp.int32))


def visit_counter_update_high_ref(
    prior_counts: Array,
    slot_events: Array,
    id_events: Array,
    n_slots: int,
    n_pins: int,
    n_v: int,
    query_events: Optional[Array] = None,
    n_queries: int = 0,
) -> Tuple[Array, Array]:
    """Oracle for the fused count-update + early-stop tally kernel.

    Returns ``(prior + hist(events), delta_high)`` where ``delta_high[s]``
    is the number of bins of query slot s whose count crossed ``>= n_v``
    during this update.  Deliberately does the full O(n_slots * n_pins)
    reduction — this is the obviously-correct ground truth the fused kernel
    (and the chunk-local XLA twin in core/counter.py) must match exactly.
    In batch-native mode (``query_events`` lane, ``n_queries > 0``) the
    rows are the ``n_queries * n_slots`` (query, slot) pairs and
    ``delta_high`` has one entry per row.
    """
    n_rows = n_queries * n_slots if query_events is not None else n_slots
    new = prior_counts + visit_counter_wide_ref(
        slot_events, id_events, n_slots, n_pins, query_events, n_queries
    )
    crossed = (prior_counts < n_v) & (new >= n_v)
    delta = jnp.sum(
        crossed.reshape(n_rows, n_pins).astype(jnp.int32), axis=1
    )
    return new, delta


# ---------------------------------------------------------------------------
# walk_step: one fused pin->board->pin superstep for a walker block
# ---------------------------------------------------------------------------


def walk_step_ref(
    curr: Array,          # (w,) int32 current pin ids
    query: Array,         # (w,) int32 restart pins
    rbits: Array,         # (w, 3) uint32 random bits: restart, board, pin
    p2b_offsets: Array,   # (n_pins + 1,) int32
    p2b_targets: Array,   # (e,) int32 board ids (global, >= n_pins)
    b2p_offsets: Array,   # (n_boards + 1,) int32
    b2p_targets: Array,   # (e,) int32 pin ids
    n_pins: int,
    alpha_u32: int,       # restart iff rbits[:,0] < alpha_u32
) -> Tuple[Array, Array, Array]:
    """Returns (next_pin, visited_pin, valid) each (w,)."""
    restart = rbits[:, 0] < jnp.uint32(alpha_u32)
    pos = jnp.where(restart, query, curr)

    # mask BEFORE the int32 cast — a high-bit draw cast raw would become a
    # negative modulo operand whose result depends on the lowering (same
    # contract as walk_chunk_ref below and both Pallas kernels)
    r_board = (rbits[:, 1] & jnp.uint32(_RMASK)).astype(jnp.int32)
    r_pin = (rbits[:, 2] & jnp.uint32(_RMASK)).astype(jnp.int32)

    start = jnp.take(p2b_offsets, pos)
    deg = jnp.take(p2b_offsets, pos + 1) - start
    idx = start + (r_board % jnp.maximum(deg, 1))
    board = jnp.take(p2b_targets, idx)
    board_ok = deg > 0

    b_local = jnp.where(board_ok, board - n_pins, 0)
    bstart = jnp.take(b2p_offsets, b_local)
    bdeg = jnp.take(b2p_offsets, b_local + 1) - bstart
    bidx = bstart + (r_pin % jnp.maximum(bdeg, 1))
    nxt = jnp.take(b2p_targets, bidx)
    ok = board_ok & (bdeg > 0)

    next_pin = jnp.where(ok, nxt, query).astype(curr.dtype)
    visited = jnp.where(ok, nxt, 0).astype(curr.dtype)
    return next_pin, visited, ok


# ---------------------------------------------------------------------------
# walk_chunk: chunk_steps fused supersteps, wide (slot, pin) event emission
# (the XLA twin of kernels/walk_step.walk_steps_fused — same random bits,
# same arithmetic, so the two backends agree bit-for-bit)
# ---------------------------------------------------------------------------


def walk_chunk_ref(
    curr: Array,          # (w,) int32 current pin per walker
    query: Array,         # (w,) int32 restart pin per walker
    feat: Array,          # (w,) int32 personalization feature per walker
    slot: Array,          # (w,) int32 query-slot id per walker
    rbits: Array,         # (chunk_steps, w, 4) uint32
    p2b_offsets: Array,
    p2b_targets: Array,
    b2p_offsets: Array,
    b2p_targets: Array,
    p2b_feat_bounds: Optional[Array] = None,
    b2p_feat_bounds: Optional[Array] = None,
    *,
    n_pins: int,
    n_slots: int,
    n_boards: int,
    alpha_u32: int,
    beta_u32: int,
    count_boards: bool = False,
    unroll: bool = False,
) -> Tuple[Array, Array, Array, Optional[Array]]:
    """chunk_steps walk supersteps; two-level vectorized gathers per step.

    Returns ``(next_curr (w,), slot_events (chunk_steps, w), pin_events
    (chunk_steps, w), board_events | None)``.  Events are WIDE (slot, pin)
    int32 lane pairs with slot = ``n_slots`` as the invalid-step sentinel
    (value lanes 0) — identical emission to the fused Pallas kernel; the
    board lane shares the slot lane.  ``unroll`` replaces the fori_loop
    over steps with a Python loop (XLA cost-model mode, see
    launch/dryrun.py).
    """
    chunk_steps, w = rbits.shape[0], rbits.shape[1]
    # biasing needs BOTH hop tables; one-sided bounds mean no bias (the
    # walk layer rejects that combination before it gets here)
    use_bias = (
        p2b_feat_bounds is not None
        and b2p_feat_bounds is not None
        and beta_u32 > 0
    )
    slot_sentinel = jnp.int32(n_slots)
    curr = curr.astype(jnp.int32)
    query = query.astype(jnp.int32)
    slot = slot.astype(jnp.int32)
    off_dt = p2b_offsets.dtype

    def one_step(s, carry):
        curr, sev, pev, bev = carry
        restart = rbits[s, :, 0] < jnp.uint32(alpha_u32)
        use_b = rbits[s, :, 1] < jnp.uint32(beta_u32)
        r_board = (rbits[s, :, 2] & jnp.uint32(_RMASK)).astype(jnp.int32)
        r_pin = (rbits[s, :, 3] & jnp.uint32(_RMASK)).astype(jnp.int32)
        pos = jnp.where(restart, query, curr)

        start = jnp.take(p2b_offsets, pos)
        deg = jnp.take(p2b_offsets, pos + 1) - start
        base, span = start, jnp.maximum(deg, 1)
        if use_bias:
            lo = p2b_feat_bounds[pos, feat].astype(off_dt)
            hi = p2b_feat_bounds[pos, feat + 1].astype(off_dt)
            sub_ok = use_b & (hi > lo)
            base = jnp.where(sub_ok, start + lo, base)
            span = jnp.where(sub_ok, hi - lo, span)
        board_ok = deg > 0
        eidx = jnp.where(board_ok, base + (r_board % span).astype(off_dt), 0)
        board = jnp.take(p2b_targets, eidx).astype(jnp.int32)
        b_local = jnp.where(board_ok, board - n_pins, 0)

        bstart = jnp.take(b2p_offsets, b_local)
        bdeg = jnp.take(b2p_offsets, b_local + 1) - bstart
        bbase, bspan = bstart, jnp.maximum(bdeg, 1)
        if use_bias:
            blo = b2p_feat_bounds[b_local, feat].astype(off_dt)
            bhi = b2p_feat_bounds[b_local, feat + 1].astype(off_dt)
            bsub_ok = use_b & (bhi > blo)
            bbase = jnp.where(bsub_ok, bstart + blo, bbase)
            bspan = jnp.where(bsub_ok, bhi - blo, bspan)
        ok = board_ok & (bdeg > 0)
        bidx = jnp.where(ok, bbase + (r_pin % bspan).astype(off_dt), 0)
        pin = jnp.take(b2p_targets, bidx).astype(jnp.int32)

        new_curr = jnp.where(ok, pin, query)
        sev = sev.at[s].set(jnp.where(ok, slot, slot_sentinel))
        pev = pev.at[s].set(jnp.where(ok, pin, 0))
        if count_boards:
            bev = bev.at[s].set(jnp.where(ok, b_local, 0))
        return new_curr, sev, pev, bev

    carry = (
        curr,
        jnp.full((chunk_steps, w), slot_sentinel, jnp.int32),
        jnp.zeros((chunk_steps, w), jnp.int32),
        jnp.zeros((chunk_steps, w) if count_boards else (1, 1), jnp.int32),
    )
    if unroll:
        for s in range(chunk_steps):
            carry = one_step(s, carry)
    else:
        carry = jax.lax.fori_loop(0, chunk_steps, one_step, carry)
    new_curr, sev, pev, bev = carry
    return new_curr, sev, pev, bev if count_boards else None


def walk_chunk_batched_ref(
    curr: Array,          # (n_queries * w,) int32 current pin per walker
    query: Array,         # (n_queries * w,) int32 restart pin per walker
    feat: Array,          # (n_queries * w,) int32 personalization feature
    slot: Array,          # (n_queries * w,) int32 query-slot id per walker
    qid: Array,           # (n_queries * w,) int32 query id per walker
    rbits: Array,         # (chunk_steps, n_queries * w, 4) uint32
    p2b_offsets: Array,
    p2b_targets: Array,
    b2p_offsets: Array,
    b2p_targets: Array,
    p2b_feat_bounds: Optional[Array] = None,
    b2p_feat_bounds: Optional[Array] = None,
    *,
    n_pins: int,
    n_slots: int,
    n_queries: int,
    n_boards: int,
    alpha_u32: int,
    beta_u32: int,
    count_boards: bool = False,
    unroll: bool = False,
) -> Tuple[Array, Array, Array, Array, Optional[Array]]:
    """Batch-native oracle: the whole serving batch's walkers in one chunk.

    Returns ``(next_curr, query_events, slot_events, pin_events,
    board_events | None)`` — the (query, slot, pin) wide event triple.  The
    walk arithmetic is EXACTLY ``walk_chunk_ref`` (one copy — structural
    parity with the fused kernel's batch mode rests on this); the query
    lane is derived from the slot lane's validity, mirroring the kernel's
    shared-validity emission: query sentinel ``n_queries`` wherever the
    slot lane carries its ``n_slots`` sentinel.
    """
    nxt, sev, pev, bev = walk_chunk_ref(
        curr, query, feat, slot, rbits,
        p2b_offsets, p2b_targets, b2p_offsets, b2p_targets,
        p2b_feat_bounds, b2p_feat_bounds,
        n_pins=n_pins, n_slots=n_slots, n_boards=n_boards,
        alpha_u32=alpha_u32, beta_u32=beta_u32,
        count_boards=count_boards, unroll=unroll,
    )
    ok = sev != jnp.int32(n_slots)
    qev = jnp.where(ok, qid.astype(jnp.int32)[None, :], jnp.int32(n_queries))
    return nxt, qev, sev, pev, bev


def walk_hop_ref(
    pos: Array,       # (l,) int32 global node ids (pins OR boards)
    gate: Array,      # (l,) bool/int32 — walkers allowed to hop
    r: Array,         # (l,) uint32 raw random bits for the edge pick
    offsets: Array,   # (rows + 1,) shard-local CSR offsets (rebased to 0)
    targets: Array,   # (edges,) shard-local CSR targets
    row_base: Array,  # () or (1,) int32 — first global id this slice owns
) -> Tuple[Array, Array]:
    """ONE hop of the walk on a shard-local CSR slice (sharded superstep).

    The half-step twin of ``walk_chunk_ref``'s ``one_step``: the same
    ``r & _RMASK`` masking, the same ``where(ok, start + r % max(deg, 1),
    0)`` edge pick, the same gather — split at the hop boundary so the
    sharded engine can run ``_route`` between the pin->board and
    board->pin halves.  ``row_base`` rebases global ids onto the slice
    (the shard-local subrange offset); callers guarantee ``gate`` implies
    ``row_base <= pos < row_base + rows``.

    Returns ``(tgt (l,), ok (l,))``: the sampled neighbour where ``ok``
    (= gate and degree > 0), 0 elsewhere — exactly the masked values the
    unsharded oracle produces for its ``board``/``pin`` intermediates.
    """
    gate = gate.astype(jnp.bool_)
    row_base = jnp.asarray(row_base, jnp.int32).reshape(())
    local = jnp.where(gate, pos.astype(jnp.int32) - row_base, 0)
    start = jnp.take(offsets, local)
    deg = jnp.take(offsets, local + 1) - start
    ok = gate & (deg > 0)
    r_m = (r & jnp.uint32(_RMASK)).astype(jnp.int32)
    eidx = jnp.where(
        ok, start + (r_m % jnp.maximum(deg, 1)).astype(offsets.dtype), 0
    )
    tgt = jnp.take(targets, eidx).astype(jnp.int32)
    return jnp.where(ok, tgt, 0), ok


# ---------------------------------------------------------------------------
# embedding_bag: fixed-bag-size gather + pool (JAX has no native EmbeddingBag)
# ---------------------------------------------------------------------------


def embedding_bag_ref(
    table: Array,          # (v, d)
    ids: Array,            # (b, l) int32, -1 = padding
    weights: Optional[Array] = None,  # (b, l) f32
    mode: str = "sum",
) -> Array:
    """Per-bag pooled embedding lookup. Returns (b, d) in table dtype."""
    valid = ids >= 0
    safe = jnp.where(valid, ids, 0)
    rows = jnp.take(table, safe, axis=0)           # (b, l, d)
    w = valid.astype(table.dtype)
    if weights is not None:
        w = w * weights.astype(table.dtype)
    pooled = jnp.sum(rows * w[..., None], axis=1)  # (b, d)
    if mode == "mean":
        denom = jnp.maximum(jnp.sum(w, axis=1, keepdims=True), 1.0)
        pooled = pooled / denom
    return pooled


def embedding_bag_batched_ref(
    table: Array,          # (v, d)
    ids: Array,            # (b, k, l) int32, -1 = padding
    weights: Optional[Array] = None,  # (b, k, l) f32
    mode: str = "sum",
) -> Array:
    """Query-batched pooled lookup -> (b, k, d): the oracle twin of
    ``embedding_bag.embedding_bag_batched``.

    Unlike :func:`embedding_bag_ref` (a ``jnp.sum`` reduction XLA may tree
    up however it likes), this twin accumulates each bag as a chain of
    adds in ascending element order — the same per-bag operation sequence
    as the kernel's inner fori_loop, so the only divergence left is
    compiler FMA contraction (last-ulp), pinned at tight tolerance in
    tests/test_kernels.py.  The serving path never depends on that last
    ulp: both walk backends share one bag lowering (see
    ops.embedding_bag_batched), making `two_stage_backends_agree` exact by
    construction.
    """
    b, k, l = ids.shape
    d = table.shape[1]
    acc = jnp.zeros((b, k, d), jnp.float32)
    wsum = jnp.zeros((b, k), jnp.float32)
    for j in range(l):
        idx = ids[:, :, j]
        valid = idx >= 0
        safe = jnp.where(valid, idx, 0)
        rows = jnp.take(table, safe, axis=0)       # (b, k, d)
        if weights is None:
            w = jnp.ones((b, k), jnp.float32) * valid.astype(jnp.float32)
        else:
            w = (
                weights[:, :, j].astype(jnp.float32)
                * valid.astype(jnp.float32)
            )
        acc = acc + rows.astype(jnp.float32) * w[..., None]
        wsum = wsum + w
    if mode == "mean":
        acc = acc / jnp.maximum(wsum, 1.0)[..., None]
    return acc.astype(table.dtype)


# ---------------------------------------------------------------------------
# decode_attention: single-token GQA attention over a (possibly long) KV cache
# ---------------------------------------------------------------------------


def decode_attention_ref(
    q: Array,        # (b, h, dh)
    k: Array,        # (b, s, kh, dh)
    v: Array,        # (b, s, kh, dh)
    lengths: Array,  # (b,) int32 valid KV length per sequence
    scale: Optional[float] = None,
) -> Array:
    """Flash-decoding semantics: softmax(q k^T / sqrt(dh)) v with length mask.

    h = kh * group; query head i attends to kv head i // group.
    Returns (b, h, dh) f32.
    """
    b, h, dh = q.shape
    s, kh = k.shape[1], k.shape[2]
    group = h // kh
    if scale is None:
        scale = dh ** -0.5
    qg = q.reshape(b, kh, group, dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, kf) * scale
    mask = jnp.arange(s)[None, :] < lengths[:, None]          # (b, s)
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, vf)
    return out.reshape(b, h, dh)
