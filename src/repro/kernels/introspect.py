"""Jaxpr introspection helpers for lowering pins.

The batch-native engine's structural claim — a constant number of
``pallas_call`` eqns per serve step with no batch-sized grid dimension —
is asserted both by tests (tests/test_batchfuse.py) and by the CI-gated
``batchfuse`` benchmark verdict.  ONE copy of the jaxpr walker lives here
so a future JAX upgrade that moves ``grid_mapping`` breaks both consumers
the same way instead of letting them disagree about the same lowering.
"""

from __future__ import annotations

from typing import List, Tuple


def pallas_grids(jaxpr) -> List[Tuple[int, ...]]:
    """Every ``pallas_call`` grid in a ClosedJaxpr, nested jaxprs included.

    Returns the grids in eqn order (while/cond/scan bodies walked
    recursively), each as a tuple of ints.
    """
    grids: List[Tuple[int, ...]] = []

    def rec(jx):
        for eqn in jx.eqns:
            if eqn.primitive.name == "pallas_call":
                grids.append(
                    tuple(int(d) for d in eqn.params["grid_mapping"].grid)
                )
            for v in eqn.params.values():
                if hasattr(v, "jaxpr"):
                    rec(v.jaxpr)
                elif isinstance(v, (list, tuple)):
                    for x in v:
                        if hasattr(x, "jaxpr"):
                            rec(x.jaxpr)

    rec(jaxpr.jaxpr)
    return grids
