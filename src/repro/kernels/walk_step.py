"""Pallas TPU kernel: one fused Pixie walk superstep for a walker block.

The paper's inner loop (Algorithm 2 lines 6-13) is three dependent random
memory accesses per step: offsets[pin] -> targets[...] (board), then
offsets[board] -> targets[...] (pin).  On TPU the CSR arrays live in HBM
(memory_space=ANY — gigabytes, never blockable into VMEM), the walker state
is tiled into VMEM, and the two-level gather is issued per walker from
inside the kernel.  Fusing restart + both hops + visit emission into one
kernel keeps all walker state resident in VMEM across the superstep, which
is the point: the paper's "walk never leaves the machine" becomes "walker
state never leaves VMEM; only the unavoidable CSR gathers touch HBM".

Random bits are generated *outside* (counter-based threefry, one uint32
triple per walker-step) so the kernel is a pure function and byte-for-byte
reproducible across restarts — the fault-tolerance contract of the runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_W = 256  # walkers per grid cell


def _walk_step_kernel(
    # scalar-ish VMEM blocks
    curr_ref, query_ref, rbits_ref,
    # full CSR arrays, left in HBM/ANY
    p2b_off_ref, p2b_tgt_ref, b2p_off_ref, b2p_tgt_ref,
    # outputs
    next_ref, visited_ref, valid_ref,
    *,
    n_pins: int,
    alpha_u32: int,
    block_w: int,
):
    curr = curr_ref[...]
    query = query_ref[...]
    restart = rbits_ref[:, 0] < jnp.uint32(alpha_u32)
    pos = jnp.where(restart, query, curr)
    r_board = rbits_ref[:, 1].astype(jnp.int32)
    r_pin = rbits_ref[:, 2].astype(jnp.int32)

    def body(i, carry):
        nxt, vis, ok_acc = carry
        p = pos[i]
        # hop 1: pin -> board
        start = p2b_off_ref[pl.ds(p, 1)][0]
        end = p2b_off_ref[pl.ds(p + 1, 1)][0]
        deg = end - start
        eidx = start + r_board[i] % jnp.maximum(deg, 1)
        board = p2b_tgt_ref[pl.ds(eidx, 1)][0]
        board_ok = deg > 0
        b_local = jnp.where(board_ok, board - n_pins, 0)
        # hop 2: board -> pin
        bstart = b2p_off_ref[pl.ds(b_local, 1)][0]
        bend = b2p_off_ref[pl.ds(b_local + 1, 1)][0]
        bdeg = bend - bstart
        bidx = bstart + r_pin[i] % jnp.maximum(bdeg, 1)
        pin = b2p_tgt_ref[pl.ds(bidx, 1)][0]
        ok = board_ok & (bdeg > 0)
        nxt = nxt.at[i].set(jnp.where(ok, pin, query[i]))
        vis = vis.at[i].set(jnp.where(ok, pin, 0))
        ok_acc = ok_acc.at[i].set(ok)
        return nxt, vis, ok_acc

    init = (
        jnp.zeros((block_w,), jnp.int32),
        jnp.zeros((block_w,), jnp.int32),
        jnp.zeros((block_w,), jnp.bool_),
    )
    nxt, vis, ok = jax.lax.fori_loop(0, block_w, body, init)
    next_ref[...] = nxt
    visited_ref[...] = vis
    valid_ref[...] = ok


@functools.partial(
    jax.jit, static_argnames=("n_pins", "alpha_u32", "block_w", "interpret")
)
def walk_step(
    curr: jax.Array,         # (w,) int32
    query: jax.Array,        # (w,) int32
    rbits: jax.Array,        # (w, 3) uint32
    p2b_offsets: jax.Array,  # (n_pins + 1,) int32
    p2b_targets: jax.Array,  # (e,) int32
    b2p_offsets: jax.Array,  # (n_boards + 1,) int32
    b2p_targets: jax.Array,  # (e,) int32
    *,
    n_pins: int,
    alpha_u32: int,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool | None = None,
):
    """One superstep for all walkers. Returns (next, visited, valid)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    w = curr.shape[0]
    if w % block_w != 0:
        raise ValueError(f"n_walkers {w} must be a multiple of {block_w}")
    grid = (w // block_w,)
    blk = lambda i: (i,)
    any_spec = pl.BlockSpec(memory_space=pl.ANY)
    out_sds = jax.ShapeDtypeStruct((w,), jnp.int32)
    return pl.pallas_call(
        functools.partial(
            _walk_step_kernel,
            n_pins=n_pins,
            alpha_u32=alpha_u32,
            block_w=block_w,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_w,), blk),
            pl.BlockSpec((block_w,), blk),
            pl.BlockSpec((block_w, 3), lambda i: (i, 0)),
            any_spec, any_spec, any_spec, any_spec,
        ],
        out_specs=[
            pl.BlockSpec((block_w,), blk),
            pl.BlockSpec((block_w,), blk),
            pl.BlockSpec((block_w,), blk),
        ],
        out_shape=[out_sds, out_sds, jax.ShapeDtypeStruct((w,), jnp.bool_)],
        interpret=interpret,
    )(
        curr.astype(jnp.int32),
        query.astype(jnp.int32),
        rbits.astype(jnp.uint32),
        p2b_offsets.astype(jnp.int32),
        p2b_targets.astype(jnp.int32),
        b2p_offsets.astype(jnp.int32),
        b2p_targets.astype(jnp.int32),
    )
