"""Pallas TPU kernels for the Pixie walk inner loop.

Two generations of kernel live here:

* ``walk_step``       — the original one-superstep-per-``pallas_call`` kernel
                        (kept as the minimal reference kernel; one launch per
                        walk step).
* ``walk_steps_fused``— the serving-path engine: ONE ``pallas_call`` executes
                        ``chunk_steps`` supersteps.  Walker state (``curr``,
                        per-walker restart pin, per-walker personalization
                        feature, query-slot id) is loaded into VMEM once and
                        stays resident across every step of the chunk; only
                        the unavoidable CSR gathers touch HBM.  Each step the
                        kernel also *emits* wide (slot, pin) visit events —
                        two int32 lanes per event, slot lane sentinel
                        ``n_slots`` for invalid / dead-end steps — straight
                        into bounded ``(chunk_steps, w)`` event buffers, so
                        the host-side walk loop never scatter-adds: events
                        are aggregated afterwards by the tile-scan
                        ``visit_counter`` kernels.  Wide lanes mean the
                        packed id space ``n_slots * n_pins`` may exceed
                        2**31 (the paper's 3B-pin regime): no lane ever
                        holds the packed product, so there is no int32
                        cliff and no xla fallback.

The paper's inner loop (Algorithm 2 lines 6-13) is three dependent random
memory accesses per step: offsets[pin] -> targets[...] (board), then
offsets[board] -> targets[...] (pin).  On TPU the CSR arrays live in HBM
(memory_space=ANY — gigabytes, never blockable into VMEM); the fused kernel
keeps everything *else* out of HBM: random bits are blocked into VMEM with
the walker state, all decision logic (restart select, bias select, modulo,
event packing) is vectorized across the walker block, and only the
per-walker two-level CSR gathers touch HBM (they are data-dependent random
access — there is no vector shape for them).  The paper's "walk never
leaves the machine" becomes "walker state never leaves VMEM between
supersteps; one kernel launch per *chunk*, not per step".

Those unavoidable CSR gathers come in two flavours (``gather_mode``):

* ``"scalar"`` — each walker's rows are loaded with blocking scalar reads
  inside the per-walker loop (the original formulation; every load eats a
  full HBM round trip back to back).
* ``"dma"``    — each superstep is split into hop *phases* (offset rows,
  then target rows; bias-bound rows ride the offset phase).  Within a
  phase the per-walker rows are staged into VMEM scratch by a
  double-buffered ``pltpu.make_async_copy`` pipeline: walker *i+1*'s row
  copy is started before walker *i*'s is waited on, so one HBM latency
  hides behind the neighbouring walker's and the phase's decision
  arithmetic runs vectorized over the whole block once the rows are
  resident.  Scratch rows + DMA semaphores are allocated with
  ``pl.run_scoped``; the same code path runs under interpret mode on CPU
  hosts (the interpreter executes the copies synchronously), so CI
  exercises the dma kernel bit-for-bit.

Both gather modes do identical integer arithmetic on identical random bits
and are bit-for-bit interchangeable (tests/test_dma_gather.py); the mode is
purely a memory-latency knob for real TPU hosts.

Random bits are generated *outside* (counter-based threefry, one uint32
quadruple per walker-step) so the kernel is a pure function and byte-for-byte
reproducible across restarts — the fault-tolerance contract of the runtime.
The XLA reference backend (`kernels/ref.walk_chunk_ref`) consumes the *same*
bits with the same arithmetic, which is what makes the two backends
bit-for-bit comparable (tests/test_walk_backends.py).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_W = 256  # walkers per grid cell

GATHER_MODES = ("scalar", "dma")

_RMASK = 0x7FFFFFFF  # keep modulo operands non-negative int32


def _walk_step_kernel(
    # scalar-ish VMEM blocks
    curr_ref, query_ref, rbits_ref,
    # full CSR arrays, left in HBM/ANY
    p2b_off_ref, p2b_tgt_ref, b2p_off_ref, b2p_tgt_ref,
    # outputs
    next_ref, visited_ref, valid_ref,
    *,
    n_pins: int,
    alpha_u32: int,
    block_w: int,
):
    curr = curr_ref[...]
    query = query_ref[...]
    restart = rbits_ref[:, 0] < jnp.uint32(alpha_u32)
    pos = jnp.where(restart, query, curr)
    # mask BEFORE the int32 cast: a high-bit draw would otherwise become a
    # negative modulo operand whose result depends on the lowering (same
    # contract as the fused kernel; pinned in tests/test_dma_gather.py)
    r_board = (rbits_ref[:, 1] & jnp.uint32(_RMASK)).astype(jnp.int32)
    r_pin = (rbits_ref[:, 2] & jnp.uint32(_RMASK)).astype(jnp.int32)

    def body(i, carry):
        nxt, vis, ok_acc = carry
        p = pos[i]
        # hop 1: pin -> board
        start = p2b_off_ref[pl.ds(p, 1)][0]
        end = p2b_off_ref[pl.ds(p + 1, 1)][0]
        deg = end - start
        eidx = start + r_board[i] % jnp.maximum(deg, 1)
        board = p2b_tgt_ref[pl.ds(eidx, 1)][0]
        board_ok = deg > 0
        b_local = jnp.where(board_ok, board - n_pins, 0)
        # hop 2: board -> pin
        bstart = b2p_off_ref[pl.ds(b_local, 1)][0]
        bend = b2p_off_ref[pl.ds(b_local + 1, 1)][0]
        bdeg = bend - bstart
        bidx = bstart + r_pin[i] % jnp.maximum(bdeg, 1)
        pin = b2p_tgt_ref[pl.ds(bidx, 1)][0]
        ok = board_ok & (bdeg > 0)
        nxt = nxt.at[i].set(jnp.where(ok, pin, query[i]))
        vis = vis.at[i].set(jnp.where(ok, pin, 0))
        ok_acc = ok_acc.at[i].set(ok)
        return nxt, vis, ok_acc

    init = (
        jnp.zeros((block_w,), jnp.int32),
        jnp.zeros((block_w,), jnp.int32),
        jnp.zeros((block_w,), jnp.bool_),
    )
    nxt, vis, ok = jax.lax.fori_loop(0, block_w, body, init)
    next_ref[...] = nxt
    visited_ref[...] = vis
    valid_ref[...] = ok


@functools.partial(
    jax.jit, static_argnames=("n_pins", "alpha_u32", "block_w", "interpret")
)
def walk_step(
    curr: jax.Array,         # (w,) int32
    query: jax.Array,        # (w,) int32
    rbits: jax.Array,        # (w, 3) uint32
    p2b_offsets: jax.Array,  # (n_pins + 1,) int32
    p2b_targets: jax.Array,  # (e,) int32
    b2p_offsets: jax.Array,  # (n_boards + 1,) int32
    b2p_targets: jax.Array,  # (e,) int32
    *,
    n_pins: int,
    alpha_u32: int,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool | None = None,
):
    """One superstep for all walkers. Returns (next, visited, valid)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    w = curr.shape[0]
    if w % block_w != 0:
        raise ValueError(f"n_walkers {w} must be a multiple of {block_w}")
    grid = (w // block_w,)
    blk = lambda i: (i,)
    any_spec = pl.BlockSpec(memory_space=pl.ANY)
    out_sds = jax.ShapeDtypeStruct((w,), jnp.int32)
    return pl.pallas_call(
        functools.partial(
            _walk_step_kernel,
            n_pins=n_pins,
            alpha_u32=alpha_u32,
            block_w=block_w,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_w,), blk),
            pl.BlockSpec((block_w,), blk),
            pl.BlockSpec((block_w, 3), lambda i: (i, 0)),
            any_spec, any_spec, any_spec, any_spec,
        ],
        out_specs=[
            pl.BlockSpec((block_w,), blk),
            pl.BlockSpec((block_w,), blk),
            pl.BlockSpec((block_w,), blk),
        ],
        out_shape=[out_sds, out_sds, jax.ShapeDtypeStruct((w,), jnp.bool_)],
        interpret=interpret,
    )(
        curr.astype(jnp.int32),
        query.astype(jnp.int32),
        rbits.astype(jnp.uint32),
        p2b_offsets.astype(jnp.int32),
        p2b_targets.astype(jnp.int32),
        b2p_offsets.astype(jnp.int32),
        b2p_targets.astype(jnp.int32),
    )


# ---------------------------------------------------------------------------
# Fused multi-superstep kernel — the serving hot path
# ---------------------------------------------------------------------------


def _pick_edge(start, deg, r, use_b, fb, gate):
    """Sampled CSR edge index for one hop: uniform over [start, start+deg),
    or the personalized feat subrange when the bias draw fires and the
    subrange is non-empty; 0 where ``gate`` is off.  Elementwise jnp — the
    scalar gather path calls it with per-walker scalars, the dma path with
    block vectors, so both modes share the ONE copy of the decision
    arithmetic the bit-identity contract rests on.  ``fb`` is a (lo, hi)
    bound pair, or None when biasing is off.
    """
    base, span = start, jnp.maximum(deg, 1)
    if fb is not None:
        lo, hi = fb
        sub_ok = use_b & (hi > lo)
        base = jnp.where(sub_ok, start + lo, base)
        span = jnp.where(sub_ok, hi - lo, span)
    return jnp.where(gate, base + r % span, 0)


def _dma_row_gather(src_row, dst_ref, sem, n: int, extra=None):
    """``dst_ref[i] <- src_row(i)`` for i < n, double-buffered async DMA.

    The copy for row i+1 is started before row i's is waited on, so two
    copies are always in flight and each walker's HBM latency hides behind
    its neighbour's.  Semaphore slots alternate (i % 2): waiting on row i
    frees its slot just before row i+2 reuses it, and every start is
    matched by a wait, so the pair leaves the phase balanced.

    ``extra`` is an optional second (src_row, dst_ref, sem) triple gathered
    in the SAME pipeline — its copies ride each iteration concurrently on
    their own semaphore pair (how the bias-bound rows ride the offset
    phase instead of paying a second drained pipeline).
    """

    def dma(i):
        return pltpu.make_async_copy(src_row(i), dst_ref.at[i], sem.at[i % 2])

    def dma2(i):
        src_row2, dst_ref2, sem2 = extra
        return pltpu.make_async_copy(
            src_row2(i), dst_ref2.at[i], sem2.at[i % 2]
        )

    dma(0).start()
    if extra is not None:
        dma2(0).start()

    def body(i, carry):
        @pl.when(i + 1 < n)
        def _prefetch():
            dma(i + 1).start()
            if extra is not None:
                dma2(i + 1).start()

        dma(i).wait()
        if extra is not None:
            dma2(i).wait()
        return carry

    jax.lax.fori_loop(0, n, body, 0)


def _walk_steps_fused_kernel(
    *refs,
    n_pins: int,
    n_slots: int,
    n_boards: int,
    n_queries: int,
    alpha_u32: int,
    beta_u32: int,
    chunk_steps: int,
    block_w: int,
    use_bias: bool,
    count_boards: bool,
    gather_mode: str,
):
    """chunk_steps supersteps for one walker block, state resident in VMEM.

    Ref layout (inputs then outputs; qid / query_events present only when
    ``n_queries > 0``, bias bounds only if use_bias):
      curr, query, feat, slot, [qid], rbits,
      p2b_off, p2b_tgt, b2p_off, b2p_tgt, [p2b_fb, b2p_fb],
      -> next, [query_events], slot_events, pin_events, [board_events]

    ``n_queries > 0`` is the batch-native mode: the walker block carries a
    per-walker query id (which serving request of the batch the walker
    belongs to) and each step additionally emits a query event lane — the
    third wide lane of the (query, slot, pin) triple, sentinel
    ``n_queries`` for invalid steps, sharing the slot lane's validity mask
    exactly like the board lane does.  This is what lets ONE ``pallas_call``
    execute a chunk for a whole serving batch instead of one call per query.

    ``gather_mode`` picks how the per-walker CSR rows reach the compute:
    blocking scalar loads ("scalar") or the phase-split double-buffered
    async-copy pipeline ("dma").  Both modes share the random-bit decode
    and event emission below, and do identical integer arithmetic on the
    gathered rows — they are bit-for-bit interchangeable.
    """
    with_query = n_queries > 0
    curr_ref, query_ref, feat_ref, slot_ref = refs[:4]
    i = 4
    qid_ref = None
    if with_query:
        qid_ref = refs[i]
        i += 1
    (rbits_ref, p2b_off_ref, p2b_tgt_ref,
     b2p_off_ref, b2p_tgt_ref) = refs[i:i + 5]
    i += 5
    if use_bias:
        p2b_fb_ref, b2p_fb_ref = refs[i:i + 2]
        i += 2
    next_ref = refs[i]
    i += 1
    qev_ref = None
    if with_query:
        qev_ref = refs[i]
        i += 1
    sev_ref, pev_ref = refs[i:i + 2]
    bev_ref = refs[i + 2] if count_boards else None

    # Walker state + the whole chunk's random bits: loaded into
    # VREGs/VMEM once, resident for all chunk_steps supersteps.
    query = query_ref[...]
    slot = slot_ref[...]
    feat = feat_ref[...]
    qid = qid_ref[...] if with_query else None
    rbits = rbits_ref[...]                       # (chunk_steps, block_w, 4)
    # wide-event invalid sentinel: slot lane carries n_slots, value lanes 0
    slot_sentinel = jnp.int32(n_slots)
    query_sentinel = jnp.int32(n_queries)

    def draws(s):
        """Decode step s's random bits — shared by both gather modes."""
        restart = rbits[s, :, 0] < jnp.uint32(alpha_u32)
        use_b = rbits[s, :, 1] < jnp.uint32(beta_u32)
        r_board = (rbits[s, :, 2] & jnp.uint32(_RMASK)).astype(jnp.int32)
        r_pin = (rbits[s, :, 3] & jnp.uint32(_RMASK)).astype(jnp.int32)
        return restart, use_b, r_board, r_pin

    def emit(s, carry, nxt, vis, bvis, okv):
        """Wide (slot, pin) lane emission — the pin, board, and query lanes
        share the slot lane (same validity mask)."""
        _, qev, sev, pev, bev = carry
        sev = sev.at[s].set(jnp.where(okv, slot, slot_sentinel))
        pev = pev.at[s].set(jnp.where(okv, vis, 0))
        if with_query:
            qev = qev.at[s].set(jnp.where(okv, qid, query_sentinel))
        if count_boards:
            bev = bev.at[s].set(jnp.where(okv, bvis, 0))
        return nxt, qev, sev, pev, bev

    def one_step_scalar(s, carry):
        curr = carry[0]
        restart, use_b, r_board, r_pin = draws(s)
        pos = jnp.where(restart, query, curr)

        # per-walker two-level CSR gather (data-dependent random access)
        def walker(i, acc):
            nxt, vis, bvis, okv = acc
            p = pos[i]
            off = p2b_off_ref[pl.ds(p, 2)]
            start, deg = off[0], off[1] - off[0]
            fb = None
            if use_bias:
                fbr = p2b_fb_ref[pl.ds(p, 1), pl.ds(feat[i], 2)][0]
                fb = (fbr[0], fbr[1])
            board_ok = deg > 0
            eidx = _pick_edge(start, deg, r_board[i], use_b[i], fb, board_ok)
            board = p2b_tgt_ref[pl.ds(eidx, 1)][0]
            b_local = jnp.where(board_ok, board - n_pins, 0)

            boff = b2p_off_ref[pl.ds(b_local, 2)]
            bstart, bdeg = boff[0], boff[1] - boff[0]
            bfb = None
            if use_bias:
                bfbr = b2p_fb_ref[pl.ds(b_local, 1), pl.ds(feat[i], 2)][0]
                bfb = (bfbr[0], bfbr[1])
            ok = board_ok & (bdeg > 0)
            bidx = _pick_edge(bstart, bdeg, r_pin[i], use_b[i], bfb, ok)
            pin = b2p_tgt_ref[pl.ds(bidx, 1)][0]

            nxt = nxt.at[i].set(jnp.where(ok, pin, query[i]))
            vis = vis.at[i].set(pin)
            bvis = bvis.at[i].set(b_local)
            okv = okv.at[i].set(ok)
            return nxt, vis, bvis, okv

        init = (
            jnp.zeros((block_w,), jnp.int32),
            jnp.zeros((block_w,), jnp.int32),
            jnp.zeros((block_w,), jnp.int32),
            jnp.zeros((block_w,), jnp.bool_),
        )
        nxt, vis, bvis, okv = jax.lax.fori_loop(0, block_w, walker, init)
        return emit(s, carry, nxt, vis, bvis, okv)

    def one_step_dma(s, carry, off_scr, tgt_scr, sem, fb_scr, fb_sem):
        """Phase-split superstep: gather a whole hop's rows into VMEM
        scratch via the double-buffered DMA pipeline, then run the hop's
        decision arithmetic vectorized over the block.  Same arithmetic as
        the scalar walker loop, phase by phase."""
        curr = carry[0]
        restart, use_b, r_board, r_pin = draws(s)
        pos = jnp.where(restart, query, curr)

        # hop 1, offset phase: (start, end) rows; bias-bound rows ride the
        # same pipeline on their own semaphore pair
        _dma_row_gather(
            lambda i: p2b_off_ref.at[pl.ds(pos[i], 2)], off_scr, sem, block_w,
            extra=(
                lambda i: p2b_fb_ref.at[pl.ds(pos[i], 1), pl.ds(feat[i], 2)],
                fb_scr, fb_sem,
            ) if use_bias else None,
        )
        off = off_scr[...]                            # (block_w, 2)
        start, deg = off[:, 0], off[:, 1] - off[:, 0]
        fb = None
        if use_bias:
            fbr = fb_scr[...]                         # (block_w, 1, 2)
            fb = (fbr[:, 0, 0], fbr[:, 0, 1])
        board_ok = deg > 0
        eidx = _pick_edge(start, deg, r_board, use_b, fb, board_ok)

        # hop 1, target phase: the sampled board ids
        _dma_row_gather(
            lambda i: p2b_tgt_ref.at[pl.ds(eidx[i], 1)], tgt_scr, sem, block_w
        )
        board = tgt_scr[...][:, 0]
        b_local = jnp.where(board_ok, board - n_pins, 0)

        # hop 2, offset phase
        _dma_row_gather(
            lambda i: b2p_off_ref.at[pl.ds(b_local[i], 2)],
            off_scr, sem, block_w,
            extra=(
                lambda i: b2p_fb_ref.at[
                    pl.ds(b_local[i], 1), pl.ds(feat[i], 2)
                ],
                fb_scr, fb_sem,
            ) if use_bias else None,
        )
        boff = off_scr[...]
        bstart, bdeg = boff[:, 0], boff[:, 1] - boff[:, 0]
        bfb = None
        if use_bias:
            bfbr = fb_scr[...]
            bfb = (bfbr[:, 0, 0], bfbr[:, 0, 1])
        ok = board_ok & (bdeg > 0)
        bidx = _pick_edge(bstart, bdeg, r_pin, use_b, bfb, ok)

        # hop 2, target phase: the sampled pin ids
        _dma_row_gather(
            lambda i: b2p_tgt_ref.at[pl.ds(bidx[i], 1)], tgt_scr, sem, block_w
        )
        pin = tgt_scr[...][:, 0]

        nxt = jnp.where(ok, pin, query)
        return emit(s, carry, nxt, pin, b_local, ok)

    carry0 = (
        curr_ref[...],
        jnp.full(
            (chunk_steps, block_w) if with_query else (1, 1),
            query_sentinel, jnp.int32,
        ),
        jnp.full((chunk_steps, block_w), slot_sentinel, jnp.int32),
        jnp.zeros((chunk_steps, block_w), jnp.int32),
        jnp.zeros(
            (chunk_steps, block_w) if count_boards else (1, 1), jnp.int32
        ),
    )

    def finish(carry):
        curr, qev, sev, pev, bev = carry
        next_ref[...] = curr
        if with_query:
            qev_ref[...] = qev
        sev_ref[...] = sev
        pev_ref[...] = pev
        if count_boards:
            bev_ref[...] = bev

    if gather_mode == "dma":

        def scoped(off_scr, tgt_scr, sem, *fb_refs):
            fb_scr, fb_sem = fb_refs if use_bias else (None, None)

            def step(s, carry):
                return one_step_dma(
                    s, carry, off_scr, tgt_scr, sem, fb_scr, fb_sem
                )

            finish(jax.lax.fori_loop(0, chunk_steps, step, carry0))

        scope = [
            pltpu.VMEM((block_w, 2), jnp.int32),    # offset (start, end) rows
            pltpu.VMEM((block_w, 1), jnp.int32),    # gathered target ids
            pltpu.SemaphoreType.DMA((2,)),          # double-buffer pair
        ]
        if use_bias:
            scope += [
                pltpu.VMEM((block_w, 1, 2), jnp.int32),  # feat-bound rows
                pltpu.SemaphoreType.DMA((2,)),
            ]
        pl.run_scoped(scoped, *scope)
    else:
        finish(jax.lax.fori_loop(0, chunk_steps, one_step_scalar, carry0))


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_pins", "n_slots", "n_boards", "n_queries", "alpha_u32",
        "beta_u32", "count_boards", "block_w", "gather_mode", "interpret",
    ),
)
def walk_steps_fused(
    curr: jax.Array,          # (w,) int32 current pin per walker
    query: jax.Array,         # (w,) int32 restart pin per walker
    feat: jax.Array,          # (w,) int32 personalization feature per walker
    slot: jax.Array,          # (w,) int32 query-slot id per walker
    rbits: jax.Array,         # (chunk_steps, w, 4) uint32
    p2b_offsets: jax.Array,   # (n_pins + 1,)
    p2b_targets: jax.Array,   # (e,)
    b2p_offsets: jax.Array,   # (n_boards + 1,)
    b2p_targets: jax.Array,   # (e,)
    p2b_feat_bounds: Optional[jax.Array] = None,  # (n_pins, n_feats + 1)
    b2p_feat_bounds: Optional[jax.Array] = None,  # (n_boards, n_feats + 1)
    qid: Optional[jax.Array] = None,  # (w,) int32 query id per walker
    *,
    n_pins: int,
    n_slots: int,
    n_boards: int,
    n_queries: int = 0,
    alpha_u32: int,
    beta_u32: int,
    count_boards: bool = False,
    block_w: int = DEFAULT_BLOCK_W,
    gather_mode: str = "scalar",
    interpret: bool | None = None,
):
    """``chunk_steps`` fused walk supersteps in ONE ``pallas_call``.

    rbits columns: 0 = restart draw (< alpha_u32 restarts), 1 = bias draw
    (< beta_u32 uses the personalized subrange), 2 = board pick, 3 = pin
    pick.  Returns ``(next_curr (w,), slot_events (chunk_steps, w),
    pin_events (chunk_steps, w))`` plus ``board_events (chunk_steps, w)``
    when ``count_boards``.  Events are WIDE: the slot lane holds the query
    slot (sentinel ``n_slots`` for invalid / dead-end steps, value lanes 0)
    and the pin/board lanes hold the visited id — no lane ever carries the
    packed ``slot * n_pins + pin`` product, so id spaces past 2**31 (the
    production 3B-pin regime) run on this kernel with plain int32 lanes.
    The board lane shares the slot lane (identical validity mask).
    Aggregate with the tile-scan ``visit_counter`` kernels — no scatters
    anywhere on the hot path.

    BATCH-NATIVE MODE: pass ``qid`` (per-walker query id) and
    ``n_queries > 0`` to run a whole serving batch's walkers in this one
    call.  The walker axis then packs all queries' pools back to back and
    the return grows a query event lane: ``(next_curr, query_events,
    slot_events, pin_events, board_events | None)`` — query lane sentinel
    ``n_queries``, sharing the slot lane's validity mask.  The per-query
    vmapped formulation lowers to one kernel per query (a batch-sized
    leading grid dim under vmap); this mode is ONE ``pallas_call`` per
    chunk with ``n_queries * w`` walker rows for the DMA pipeline to hide
    latency behind.

    ``gather_mode="dma"`` replaces the blocking per-walker scalar CSR
    gathers with the phase-split double-buffered ``make_async_copy``
    pipeline (module docstring); bit-identical to ``"scalar"`` and to the
    XLA reference, and interpret-safe on CPU hosts.
    """
    if gather_mode not in GATHER_MODES:
        raise ValueError(
            f"unknown gather_mode {gather_mode!r}; use {GATHER_MODES}"
        )
    with_query = qid is not None
    if with_query and n_queries <= 0:
        raise ValueError("qid given but n_queries not set (> 0 required)")
    if not with_query:
        n_queries = 0  # one kernel variant per (qid, n_queries) pairing
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    chunk_steps, w = rbits.shape[0], rbits.shape[1]
    if w % block_w != 0:
        raise ValueError(f"n_walkers {w} must be a multiple of {block_w}")
    use_bias = (
        p2b_feat_bounds is not None
        and b2p_feat_bounds is not None
        and beta_u32 > 0
    )
    grid = (w // block_w,)
    blk = lambda i: (i,)
    any_spec = pl.BlockSpec(memory_space=pl.ANY)

    in_specs = [
        pl.BlockSpec((block_w,), blk),                       # curr
        pl.BlockSpec((block_w,), blk),                       # query
        pl.BlockSpec((block_w,), blk),                       # feat
        pl.BlockSpec((block_w,), blk),                       # slot
    ]
    args = [
        curr.astype(jnp.int32),
        query.astype(jnp.int32),
        feat.astype(jnp.int32),
        slot.astype(jnp.int32),
    ]
    if with_query:
        in_specs.append(pl.BlockSpec((block_w,), blk))       # qid
        args.append(qid.astype(jnp.int32))
    in_specs += [
        pl.BlockSpec((chunk_steps, block_w, 4), lambda i: (0, i, 0)),
        any_spec, any_spec, any_spec, any_spec,              # CSR arrays
    ]
    args += [
        rbits.astype(jnp.uint32),
        p2b_offsets.astype(jnp.int32),
        p2b_targets.astype(jnp.int32),
        b2p_offsets.astype(jnp.int32),
        b2p_targets.astype(jnp.int32),
    ]
    if use_bias:
        in_specs += [any_spec, any_spec]
        args += [
            p2b_feat_bounds.astype(jnp.int32),
            b2p_feat_bounds.astype(jnp.int32),
        ]

    ev_spec = pl.BlockSpec((chunk_steps, block_w), lambda i: (0, i))
    ev_sds = jax.ShapeDtypeStruct((chunk_steps, w), jnp.int32)
    out_specs = [pl.BlockSpec((block_w,), blk)]
    out_shape = [jax.ShapeDtypeStruct((w,), jnp.int32)]
    if with_query:
        out_specs.append(ev_spec)
        out_shape.append(ev_sds)
    out_specs += [ev_spec, ev_spec]
    out_shape += [ev_sds, ev_sds]
    if count_boards:
        out_specs.append(ev_spec)
        out_shape.append(ev_sds)

    out = pl.pallas_call(
        functools.partial(
            _walk_steps_fused_kernel,
            n_pins=n_pins,
            n_slots=n_slots,
            n_boards=n_boards,
            n_queries=n_queries,
            alpha_u32=alpha_u32,
            beta_u32=beta_u32,
            chunk_steps=chunk_steps,
            block_w=block_w,
            use_bias=use_bias,
            count_boards=count_boards,
            gather_mode=gather_mode,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    i = 1
    qev = None
    if with_query:
        qev = out[i]
        i += 1
    sev, pev = out[i], out[i + 1]
    bev = out[i + 2] if count_boards else None
    if with_query:
        return out[0], qev, sev, pev, bev
    return out[0], sev, pev, bev


# ---------------------------------------------------------------------------
# Hop-phase fused kernel — walk_steps_fused split at the hop boundary
# ---------------------------------------------------------------------------


def _walk_hop_kernel(
    pos_ref, gate_ref, r_ref, base_ref,
    off_ref, tgt_ref,            # shard-local CSR slice, HBM/ANY
    out_ref, ok_ref,
    *,
    block_l: int,
    gather_mode: str,
):
    """One CSR hop for a block of routed walkers.

    ``walk_steps_fused`` runs both hops of a step back to back because the
    replicated graph owns every row; the sharded engine must ``_route``
    walkers between hops, so this kernel is the fused kernel's per-hop
    half: the same ``_RMASK`` decode, the same ``_pick_edge`` arithmetic,
    the same scalar/dma gather pipelines — over a shard-local CSR slice
    whose rows are rebased by the traced ``row_base`` scalar (the
    shard-local subrange offset, ``shard_id * rows_per_shard``).
    """
    pos = pos_ref[...]
    gate = gate_ref[...] != 0
    r = (r_ref[...] & jnp.uint32(_RMASK)).astype(jnp.int32)
    row_base = base_ref[0]
    # clamp non-gated walkers to row 0: their position may be a global id
    # another shard owns (or a sentinel) — the result is masked anyway
    local = jnp.where(gate, pos - row_base, 0)

    if gather_mode == "dma":

        def scoped(off_scr, tgt_scr, sem):
            # offset phase: (start, end) rows, double-buffered
            _dma_row_gather(
                lambda i: off_ref.at[pl.ds(local[i], 2)], off_scr, sem,
                block_l,
            )
            off = off_scr[...]                        # (block_l, 2)
            start, deg = off[:, 0], off[:, 1] - off[:, 0]
            ok = gate & (deg > 0)
            eidx = _pick_edge(start, deg, r, False, None, ok)
            # target phase: the sampled neighbour ids
            _dma_row_gather(
                lambda i: tgt_ref.at[pl.ds(eidx[i], 1)], tgt_scr, sem,
                block_l,
            )
            tgt = tgt_scr[...][:, 0]
            out_ref[...] = jnp.where(ok, tgt, 0)
            ok_ref[...] = ok

        pl.run_scoped(
            scoped,
            pltpu.VMEM((block_l, 2), jnp.int32),
            pltpu.VMEM((block_l, 1), jnp.int32),
            pltpu.SemaphoreType.DMA((2,)),
        )
    else:

        def walker(i, acc):
            out, okv = acc
            off = off_ref[pl.ds(local[i], 2)]
            start, deg = off[0], off[1] - off[0]
            ok = gate[i] & (deg > 0)
            eidx = _pick_edge(start, deg, r[i], False, None, ok)
            t = tgt_ref[pl.ds(eidx, 1)][0]
            out = out.at[i].set(jnp.where(ok, t, 0))
            okv = okv.at[i].set(ok)
            return out, okv

        out, okv = jax.lax.fori_loop(
            0, block_l, walker,
            (jnp.zeros((block_l,), jnp.int32),
             jnp.zeros((block_l,), jnp.bool_)),
        )
        out_ref[...] = out
        ok_ref[...] = okv


@functools.partial(
    jax.jit, static_argnames=("block_l", "gather_mode", "interpret")
)
def walk_hop_fused(
    pos: jax.Array,       # (l,) int32 global node ids
    gate: jax.Array,      # (l,) bool — walkers allowed to hop
    r: jax.Array,         # (l,) uint32 raw bits for the edge pick
    row_base: jax.Array,  # (1,) int32 traced shard-local subrange offset
    offsets: jax.Array,   # (rows + 1,) shard-local CSR offsets
    targets: jax.Array,   # (edges,) shard-local CSR targets
    *,
    block_l: int = DEFAULT_BLOCK_W,
    gather_mode: str = "scalar",
    interpret: bool | None = None,
):
    """ONE walk hop in one ``pallas_call`` (the sharded superstep phase).

    Returns ``(tgt (l,) int32, ok (l,) bool)`` — the sampled neighbour
    where ``ok`` (= ``gate`` and the row has edges), 0 elsewhere —
    bit-identical to ``kernels/ref.walk_hop_ref`` and to the matching
    half of ``walk_steps_fused``'s superstep.  ``row_base`` is a traced
    (1,) array, NOT a static int: every shard of a ``shard_map`` runs the
    same program with its own ``axis_index``-derived base, so baking it
    in would force one kernel variant per shard.
    """
    if gather_mode not in GATHER_MODES:
        raise ValueError(
            f"unknown gather_mode {gather_mode!r}; use {GATHER_MODES}"
        )
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    l = pos.shape[0]
    if l % block_l != 0:
        raise ValueError(f"walker count {l} must be a multiple of {block_l}")
    grid = (l // block_l,)
    blk = lambda i: (i,)
    any_spec = pl.BlockSpec(memory_space=pl.ANY)
    return pl.pallas_call(
        functools.partial(
            _walk_hop_kernel, block_l=block_l, gather_mode=gather_mode
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_l,), blk),           # pos
            pl.BlockSpec((block_l,), blk),           # gate
            pl.BlockSpec((block_l,), blk),           # r
            pl.BlockSpec((1,), lambda i: (0,)),      # row_base
            any_spec, any_spec,                      # CSR slice
        ],
        out_specs=[
            pl.BlockSpec((block_l,), blk),
            pl.BlockSpec((block_l,), blk),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((l,), jnp.int32),
            jax.ShapeDtypeStruct((l,), jnp.bool_),
        ],
        interpret=interpret,
    )(
        pos.astype(jnp.int32),
        gate.astype(jnp.int32),
        r.astype(jnp.uint32),
        jnp.asarray(row_base, jnp.int32).reshape((1,)),
        offsets.astype(jnp.int32),
        targets.astype(jnp.int32),
    )
