"""Public jit'd entry points for the Pallas kernels, with oracle fallbacks.

Every op takes `use_kernel`:
  * True  — run the Pallas kernel (interpret mode on CPU, compiled on TPU);
  * False — run the pure-jnp oracle from ref.py (always available, used by
    the distributed paths where the op must trace under shard_map/jit with
    shapes the kernel grid doesn't cover).

The default is the oracle on CPU hosts and the kernel on TPU: the oracle
*is* the mathematically identical program, so higher layers never branch on
backend.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_kernel
from repro.kernels.embedding_bag import embedding_bag as _bag_kernel
from repro.kernels.embedding_bag import (
    embedding_bag_batched as _bag_batched_kernel,
)
from repro.kernels.visit_counter import visit_counter as _counter_kernel
from repro.kernels.visit_counter import (
    visit_counter_wide as _counter_wide_kernel,
)
from repro.kernels.visit_counter import (
    visit_counter_update_high as _counter_high_kernel,
)
from repro.kernels.walk_step import walk_step as _walk_kernel
from repro.kernels.walk_step import DEFAULT_BLOCK_W as _DEFAULT_BLOCK_W
from repro.kernels.walk_step import walk_steps_fused as _fused_kernel
from repro.kernels.walk_step import walk_hop_fused as _hop_kernel

Array = jax.Array


def _default_use_kernel() -> bool:
    return jax.default_backend() == "tpu"


def visit_counts(
    events: Array, n_bins: int, *, use_kernel: Optional[bool] = None
) -> Array:
    """Histogram of visit events over [0, n_bins)."""
    if use_kernel is None:
        use_kernel = _default_use_kernel()
    if use_kernel:
        return _counter_kernel(events, n_bins)
    return ref.visit_counter_ref(events, n_bins)


def visit_counts_wide(
    slot_events: Array,
    id_events: Array,
    *,
    n_slots: int,
    n_dim: int,
    query_events: Optional[Array] = None,
    n_queries: int = 0,
    use_kernel: Optional[bool] = None,
) -> Array:
    """Histogram of wide (slot, id) event lanes over n_slots * n_dim bins.

    With a ``query_events`` lane (batch-native mode, ``n_queries > 0``)
    the bins are the ``n_queries * n_slots * n_dim`` query-major triple
    space and one call covers a whole serving batch.
    """
    if use_kernel is None:
        use_kernel = _default_use_kernel()
    if use_kernel:
        return _counter_wide_kernel(
            slot_events, id_events, query_events,
            n_slots=n_slots, n_dim=n_dim, n_queries=n_queries,
        )
    return ref.visit_counter_wide_ref(
        slot_events, id_events, n_slots, n_dim, query_events, n_queries
    )


def visit_counts_update_high(
    prior_counts: Array,
    slot_events: Array,
    pin_events: Array,
    *,
    n_slots: int,
    n_pins: int,
    n_v: int,
    query_events: Optional[Array] = None,
    n_queries: int = 0,
    use_kernel: Optional[bool] = None,
) -> Tuple[Array, Array]:
    """Fused running-count update + per-slot n_v-crossing tally (wide events).

    Returns ``(new_counts (n_slots * n_pins,), delta_high (n_slots,))`` —
    the incremental early-stop statistic of the dense walk engine
    (Algorithm 3): the while-loop carries a running ``n_high`` tally instead
    of re-reducing the whole count buffer each chunk.  With a
    ``query_events`` lane (batch-native mode, ``n_queries > 0``) the bins
    are query-major over the whole batch and ``delta_high`` has one entry
    per (query, slot) row.
    """
    if use_kernel is None:
        use_kernel = _default_use_kernel()
    if use_kernel:
        return _counter_high_kernel(
            prior_counts, slot_events, pin_events, query_events,
            n_slots=n_slots, n_pins=n_pins, n_v=n_v, n_queries=n_queries,
        )
    return ref.visit_counter_update_high_ref(
        prior_counts, slot_events, pin_events, n_slots, n_pins, n_v,
        query_events, n_queries,
    )


def walk_step(
    curr: Array,
    query: Array,
    rbits: Array,
    p2b_offsets: Array,
    p2b_targets: Array,
    b2p_offsets: Array,
    b2p_targets: Array,
    *,
    n_pins: int,
    alpha_u32: int,
    use_kernel: Optional[bool] = None,
) -> Tuple[Array, Array, Array]:
    """One fused biased walk superstep -> (next, visited, valid)."""
    if use_kernel is None:
        use_kernel = _default_use_kernel()
    if use_kernel:
        return _walk_kernel(
            curr, query, rbits,
            p2b_offsets, p2b_targets, b2p_offsets, b2p_targets,
            n_pins=n_pins, alpha_u32=alpha_u32,
        )
    return ref.walk_step_ref(
        curr, query, rbits,
        p2b_offsets, p2b_targets, b2p_offsets, b2p_targets,
        n_pins=n_pins, alpha_u32=alpha_u32,
    )


def walk_chunk_fused(
    curr: Array,
    query: Array,
    feat: Array,
    slot: Array,
    rbits: Array,
    p2b_offsets: Array,
    p2b_targets: Array,
    b2p_offsets: Array,
    b2p_targets: Array,
    p2b_feat_bounds: Optional[Array] = None,
    b2p_feat_bounds: Optional[Array] = None,
    *,
    n_pins: int,
    n_slots: int,
    n_boards: int,
    alpha_u32: int,
    beta_u32: int,
    count_boards: bool = False,
    unroll: bool = False,
    block_w: Optional[int] = None,
    gather_mode: str = "scalar",
    use_kernel: Optional[bool] = None,
) -> Tuple[Array, Array, Array, Optional[Array]]:
    """chunk_steps fused walk supersteps.

    Returns ``(next, slot_events, pin_events, board_events | None)`` —
    wide (slot, pin) int32 event lanes (slot lane sentinel ``n_slots`` for
    invalid steps; the board lane shares the slot lane), so both engines
    cover packed id spaces past 2**31 with no fallback.  The kernel path
    runs ALL chunk_steps steps in one pallas_call with walker state
    resident in VMEM; the oracle path is the same arithmetic as two-level
    XLA gathers (this is the walk's "xla" backend).  Both consume the same
    (chunk_steps, w, 4) uint32 counter-RNG bits, so their emitted events
    agree bit-for-bit.

    ``gather_mode`` ("scalar" | "dma") selects how the kernel path issues
    its CSR gathers — blocking scalar loads or the double-buffered
    async-copy pipeline; both are bit-identical to the oracle.  The oracle
    path has no gather modes (XLA vector gathers) and ignores it.
    """
    if use_kernel is None:
        use_kernel = _default_use_kernel()
    if use_kernel:
        w = curr.shape[0]
        if block_w is None:
            # one grid cell per DEFAULT_BLOCK_W walkers when it divides the
            # pool; otherwise a single block (small / odd walker counts)
            block_w = _DEFAULT_BLOCK_W if w % _DEFAULT_BLOCK_W == 0 else w
        return _fused_kernel(
            curr, query, feat, slot, rbits,
            p2b_offsets, p2b_targets, b2p_offsets, b2p_targets,
            p2b_feat_bounds, b2p_feat_bounds,
            n_pins=n_pins, n_slots=n_slots, n_boards=n_boards,
            alpha_u32=alpha_u32, beta_u32=beta_u32,
            count_boards=count_boards, block_w=block_w,
            gather_mode=gather_mode,
        )
    return ref.walk_chunk_ref(
        curr, query, feat, slot, rbits,
        p2b_offsets, p2b_targets, b2p_offsets, b2p_targets,
        p2b_feat_bounds, b2p_feat_bounds,
        n_pins=n_pins, n_slots=n_slots, n_boards=n_boards,
        alpha_u32=alpha_u32, beta_u32=beta_u32,
        count_boards=count_boards, unroll=unroll,
    )


def walk_chunk_fused_batched(
    curr: Array,
    query: Array,
    feat: Array,
    slot: Array,
    qid: Array,
    rbits: Array,
    p2b_offsets: Array,
    p2b_targets: Array,
    b2p_offsets: Array,
    b2p_targets: Array,
    p2b_feat_bounds: Optional[Array] = None,
    b2p_feat_bounds: Optional[Array] = None,
    *,
    n_pins: int,
    n_slots: int,
    n_queries: int,
    n_boards: int,
    alpha_u32: int,
    beta_u32: int,
    count_boards: bool = False,
    unroll: bool = False,
    block_w: Optional[int] = None,
    gather_mode: str = "scalar",
    use_kernel: Optional[bool] = None,
) -> Tuple[Array, Array, Array, Array, Optional[Array]]:
    """Batch-native chunk: a whole serving batch's walkers in ONE call.

    Identical contract to :func:`walk_chunk_fused` except the walker axis
    packs every query's pool back to back (``qid`` says which query each
    walker serves) and the return grows the query event lane:
    ``(next, query_events, slot_events, pin_events, board_events | None)``
    — the wide (query, slot, pin) int32 triple, query lane sentinel
    ``n_queries`` sharing the slot lane's validity.  The kernel path is
    ONE ``pallas_call`` per chunk for the whole batch (vs a batch-sized
    leading grid dim when the per-query op is vmapped); the oracle path is
    ``ref.walk_chunk_batched_ref`` — the same single-copy walk arithmetic
    as the per-query oracle, so parity is structural.
    """
    if use_kernel is None:
        use_kernel = _default_use_kernel()
    if use_kernel:
        w = curr.shape[0]
        if block_w is None:
            block_w = _DEFAULT_BLOCK_W if w % _DEFAULT_BLOCK_W == 0 else w
        return _fused_kernel(
            curr, query, feat, slot, rbits,
            p2b_offsets, p2b_targets, b2p_offsets, b2p_targets,
            p2b_feat_bounds, b2p_feat_bounds, qid,
            n_pins=n_pins, n_slots=n_slots, n_boards=n_boards,
            n_queries=n_queries,
            alpha_u32=alpha_u32, beta_u32=beta_u32,
            count_boards=count_boards, block_w=block_w,
            gather_mode=gather_mode,
        )
    return ref.walk_chunk_batched_ref(
        curr, query, feat, slot, qid, rbits,
        p2b_offsets, p2b_targets, b2p_offsets, b2p_targets,
        p2b_feat_bounds, b2p_feat_bounds,
        n_pins=n_pins, n_slots=n_slots, n_queries=n_queries,
        n_boards=n_boards,
        alpha_u32=alpha_u32, beta_u32=beta_u32,
        count_boards=count_boards, unroll=unroll,
    )


def walk_hop(
    pos: Array,
    gate: Array,
    r: Array,
    offsets: Array,
    targets: Array,
    row_base: Array,
    *,
    use_kernel: Optional[bool] = None,
    block_l: Optional[int] = None,
    gather_mode: str = "scalar",
) -> Tuple[Array, Array]:
    """ONE walk hop on a shard-local CSR slice -> (tgt, ok).

    The half-step twin of :func:`walk_chunk_fused` used by the sharded
    superstep: walkers hop once (pin->board or board->pin) on a node-range
    CSR slice whose first owned row is ``row_base``, then migrate over the
    routing fabric before the next hop.  The kernel path is ONE
    ``pallas_call`` for the whole routed walker buffer (per shard, not per
    query); the oracle path (``ref.walk_hop_ref``) is the same arithmetic
    as XLA gathers, bit-identical per the usual twin contract.
    """
    if use_kernel is None:
        use_kernel = _default_use_kernel()
    if not use_kernel:
        return ref.walk_hop_ref(pos, gate, r, offsets, targets, row_base)
    l = pos.shape[0]
    if block_l is None:
        block_l = _DEFAULT_BLOCK_W if l % _DEFAULT_BLOCK_W == 0 else l
    return _hop_kernel(
        pos, gate, r, row_base, offsets, targets,
        block_l=block_l, gather_mode=gather_mode,
    )


def embedding_bag(
    table: Array,
    ids: Array,
    weights: Optional[Array] = None,
    *,
    mode: str = "sum",
    use_kernel: Optional[bool] = None,
) -> Array:
    """Pooled (sum/mean) embedding lookup -> (b, d)."""
    if use_kernel is None:
        use_kernel = _default_use_kernel()
    if use_kernel:
        return _bag_kernel(table, ids, weights, mode=mode)
    return ref.embedding_bag_ref(table, ids, weights, mode=mode)


def embedding_bag_batched(
    table: Array,
    ids: Array,
    weights: Optional[Array] = None,
    *,
    mode: str = "sum",
    use_kernel: Optional[bool] = None,
) -> Array:
    """Query-batched pooled embedding lookup: (b, k, l) bags -> (b, k, d).

    The two-stage serving path's bag op.  `use_kernel` keeps the module's
    platform default (kernel on TPU, oracle on CPU) and — deliberately —
    is NOT derived from the walk backend by the serving path: stage 2's
    float math runs as ONE shared program under both ``backend="xla"`` and
    ``backend="pallas"``, so `two_stage_backends_agree` is exact by
    construction (the same design that keeps walk scores exact: shared
    float boost over bit-identical integer counts).  Kernel-vs-oracle
    parity is pinned separately at tight tolerance (matched accumulation
    order; only compiler FMA contraction may differ in the last ulp).
    """
    if use_kernel is None:
        use_kernel = _default_use_kernel()
    if use_kernel:
        return _bag_batched_kernel(table, ids, weights, mode=mode)
    return ref.embedding_bag_batched_ref(table, ids, weights, mode=mode)


def decode_attention(
    q: Array,
    k: Array,
    v: Array,
    lengths: Array,
    *,
    use_kernel: Optional[bool] = None,
) -> Array:
    """Single-token GQA decode attention -> (b, h, dh) f32."""
    if use_kernel is None:
        use_kernel = _default_use_kernel()
    if use_kernel:
        return _decode_kernel(q, k, v, lengths)
    return ref.decode_attention_ref(q, k, v, lengths)
