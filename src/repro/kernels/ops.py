"""Public jit'd entry points for the Pallas kernels, with oracle fallbacks.

Every op takes `use_kernel`:
  * True  — run the Pallas kernel (interpret mode on CPU, compiled on TPU);
  * False — run the pure-jnp oracle from ref.py (always available, used by
    the distributed paths where the op must trace under shard_map/jit with
    shapes the kernel grid doesn't cover).

The default is the oracle on CPU hosts and the kernel on TPU: the oracle
*is* the mathematically identical program, so higher layers never branch on
backend.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_kernel
from repro.kernels.embedding_bag import embedding_bag as _bag_kernel
from repro.kernels.visit_counter import visit_counter as _counter_kernel
from repro.kernels.walk_step import walk_step as _walk_kernel

Array = jax.Array


def _default_use_kernel() -> bool:
    return jax.default_backend() == "tpu"


def visit_counts(
    events: Array, n_bins: int, *, use_kernel: Optional[bool] = None
) -> Array:
    """Histogram of visit events over [0, n_bins)."""
    if use_kernel is None:
        use_kernel = _default_use_kernel()
    if use_kernel:
        return _counter_kernel(events, n_bins)
    return ref.visit_counter_ref(events, n_bins)


def walk_step(
    curr: Array,
    query: Array,
    rbits: Array,
    p2b_offsets: Array,
    p2b_targets: Array,
    b2p_offsets: Array,
    b2p_targets: Array,
    *,
    n_pins: int,
    alpha_u32: int,
    use_kernel: Optional[bool] = None,
) -> Tuple[Array, Array, Array]:
    """One fused biased walk superstep -> (next, visited, valid)."""
    if use_kernel is None:
        use_kernel = _default_use_kernel()
    if use_kernel:
        return _walk_kernel(
            curr, query, rbits,
            p2b_offsets, p2b_targets, b2p_offsets, b2p_targets,
            n_pins=n_pins, alpha_u32=alpha_u32,
        )
    return ref.walk_step_ref(
        curr, query, rbits,
        p2b_offsets, p2b_targets, b2p_offsets, b2p_targets,
        n_pins=n_pins, alpha_u32=alpha_u32,
    )


def embedding_bag(
    table: Array,
    ids: Array,
    weights: Optional[Array] = None,
    *,
    mode: str = "sum",
    use_kernel: Optional[bool] = None,
) -> Array:
    """Pooled (sum/mean) embedding lookup -> (b, d)."""
    if use_kernel is None:
        use_kernel = _default_use_kernel()
    if use_kernel:
        return _bag_kernel(table, ids, weights, mode=mode)
    return ref.embedding_bag_ref(table, ids, weights, mode=mode)


def decode_attention(
    q: Array,
    k: Array,
    v: Array,
    lengths: Array,
    *,
    use_kernel: Optional[bool] = None,
) -> Array:
    """Single-token GQA decode attention -> (b, h, dh) f32."""
    if use_kernel is None:
        use_kernel = _default_use_kernel()
    if use_kernel:
        return _decode_kernel(q, k, v, lengths)
    return ref.decode_attention_ref(q, k, v, lengths)
