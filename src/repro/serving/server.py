"""Pixie serving fleet, TPU-shaped (paper §3.3 "Pixie Server").

The paper's server: IO threads deserialize queries, worker threads each own
a counter and run one query at a time; ~1,200 QPS / 60 ms p99 per machine.
The batch-SPMD translation:

  * requests accumulate in a queue and are **padded/bucketed into a fixed
    (batch, n_slots) shape** — one jitted `serve_batch` program replaces the
    worker pool (each vmapped lane is "a worker with its own counter");
  * the graph array is the shared read-only segment (the paper's
    HugePages-backed mmap) — donated into none, replicated or sharded;
  * a background "graph swap" hook models the daily graph reload: the server
    holds a generation number and swaps the graph handle between batches
    (serving never blocks on the swap — the old graph serves until the new
    one is resident, exactly like the paper's restart-with-shared-memory).

Latency accounting is wall-clock around the jitted call; on CPU this gives
the *shape* of Fig. 1 (runtime vs steps / query size), which is what
benchmarks/bench_fig1_runtime.py reports.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import service, walk as walk_lib
from repro.core.graph import PinBoardGraph


@dataclasses.dataclass
class ServerStats:
    latencies_ms: List[float] = dataclasses.field(default_factory=list)
    queries: int = 0
    batches: int = 0
    graph_generation: int = 0

    def percentile(self, p: float) -> float:
        if not self.latencies_ms:
            return 0.0
        return float(np.percentile(self.latencies_ms, p))

    def qps(self, wall_seconds: float) -> float:
        return self.queries / max(wall_seconds, 1e-9)


class PixieServer:
    """Single-host Pixie serving replica (batched SPMD worker pool)."""

    def __init__(
        self,
        graph: PinBoardGraph,
        cfg: walk_lib.WalkConfig,
        batch_size: int = 8,
        n_slots: int = 8,
        seed: int = 0,
        backend: Optional[str] = None,
        mesh=None,
        axis: str = "model",
        slack: float = 2.0,
    ):
        """``backend`` overrides cfg.backend ("xla" | "pallas") so a fleet
        can flip every replica onto the fused Pallas walk engine at server
        construction; recommendations are bit-identical either way.

        A ``distributed.ShardedGraph`` replica (graph too big for one
        chip) needs ``mesh``; ``axis``/``slack`` configure the walker
        routing fabric (core/distributed.py).  The sharded graph is
        closed over rather than passed through jit — its static int
        metadata must stay Python ints — so ``swap_graph`` re-jits on a
        sharded replica (the daily reload already pays a retrace for the
        new graph constants)."""
        if backend is not None and backend != cfg.backend:
            cfg = dataclasses.replace(cfg, backend=backend)
        self.graph = graph
        self.cfg = cfg
        self.batch_size = batch_size
        self.n_slots = n_slots
        self.mesh = mesh
        self.axis = axis
        self.slack = slack
        self.stats = ServerStats()
        self._key = jax.random.key(seed)
        self._queue: List[Tuple[np.ndarray, np.ndarray, int]] = []
        self._build_serve()

    def _build_serve(self) -> None:
        from repro.core import distributed as dist_lib

        cfg = self.cfg
        if isinstance(self.graph, dist_lib.ShardedGraph):
            graph, mesh, axis, slack = (
                self.graph, self.mesh, self.axis, self.slack
            )
            sharded = jax.jit(
                lambda pins, weights, feats, key: service.serve_batch(
                    graph, pins, weights, feats, key, cfg,
                    mesh=mesh, axis=axis, slack=slack,
                )
            )
            self._serve = lambda _g, p, w, f, k: sharded(p, w, f, k)
        else:
            # the plain jitted program takes the graph as an argument, so
            # a same-shape daily swap reuses the compiled program
            if getattr(self, "_plain_serve", None) is None:
                self._plain_serve = jax.jit(
                    lambda graph, pins, weights, feats, key:
                        service.serve_batch(
                            graph, pins, weights, feats, key, cfg
                        )
                )
            self._serve = self._plain_serve

    # -- request path ---------------------------------------------------------
    def submit(self, pins: Sequence[int], weights: Sequence[float], user_feat: int = 0):
        qp, qw = np.full(self.n_slots, -1, np.int32), np.zeros(
            self.n_slots, np.float32
        )
        n = min(len(pins), self.n_slots)
        qp[:n] = np.asarray(pins[:n], np.int32)
        qw[:n] = np.asarray(weights[:n], np.float32)
        self._queue.append((qp, qw, user_feat))

    def flush(self) -> List[Tuple[np.ndarray, np.ndarray]]:
        """Serve every queued request (padding the final partial batch)."""
        out: List[Tuple[np.ndarray, np.ndarray]] = []
        while self._queue:
            batch = self._queue[: self.batch_size]
            self._queue = self._queue[self.batch_size:]
            n_real = len(batch)
            while len(batch) < self.batch_size:  # pad with empty queries
                batch.append(
                    (np.full(self.n_slots, -1, np.int32),
                     np.zeros(self.n_slots, np.float32), 0)
                )
            pins = jnp.asarray(np.stack([b[0] for b in batch]))
            weights = jnp.asarray(np.stack([b[1] for b in batch]))
            feats = jnp.asarray(np.asarray([b[2] for b in batch], np.int32))
            self._key, sub = jax.random.split(self._key)
            t0 = time.perf_counter()
            scores, ids = self._serve(self.graph, pins, weights, feats, sub)
            scores.block_until_ready()
            dt_ms = (time.perf_counter() - t0) * 1e3
            self.stats.batches += 1
            self.stats.queries += n_real
            # per-query latency = batch latency (SPMD lanes are concurrent)
            self.stats.latencies_ms.extend([dt_ms] * n_real)
            s_np, i_np = np.asarray(scores), np.asarray(ids)
            out.extend((s_np[i], i_np[i]) for i in range(n_real))
        return out

    # -- graph swap (the daily reload, §3.3) -----------------------------------
    def swap_graph(self, new_graph) -> None:
        self.graph = new_graph
        self.stats.graph_generation += 1
        self._build_serve()
