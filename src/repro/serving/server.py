"""Pixie serving fleet, TPU-shaped (paper §3.3 "Pixie Server").

The paper's server: IO threads deserialize queries, worker threads each own
a counter and run one query at a time; ~1,200 QPS / 60 ms p99 per machine.
The batch-SPMD translation, now shaped for CONTINUOUS traffic rather than a
synchronous flush loop:

  * requests route into **shape buckets** — small/medium/large
    ``(batch_size, n_slots)`` pairs, each lowering to its own cached jitted
    program (jit's compile cache is keyed on shape, so a straggler 16-pin
    query pads a 16-slot bucket, not the whole fleet shape);
  * batches form **deadline-aware**: a bucket dispatches when FULL or when
    its oldest request has waited ``max_wait_ms``, whichever first —
    freshness over batch occupancy ("Related Pins": tail latency, not
    throughput, is the production objective);
  * dispatch is **async**: the jitted call is enqueued and ``submit``/
    ``pump`` return immediately; ``jax.block_until_ready`` happens in
    ``harvest``, off the intake path;
  * every request gets its PRNG stream at submit time
    (``fold_in(server_key, req_id)``), so batch composition NEVER changes a
    query's walk — bucketed serving is bit-identical to the single-bucket
    ``flush()`` oracle on the same requests (the ``traffic_buckets_agree``
    CI verdict);
  * the graph array is the shared read-only segment (the paper's
    HugePages-backed mmap); ``swap_graph`` models the daily reload — the
    old graph serves until the new one is resident, in-flight batches
    complete on the generation they dispatched under, and every
    ``QueryResult`` carries its generation number.

Latency accounting is per query: ``latency = queue wait + dispatch +
compute`` (wait stamped at ``submit``, compute wall-clocked around the
device round-trip).  ``ServerStats`` keeps bounded ring buffers — a
long-lived replica never grows memory with traffic.  On CPU the Pallas
engine interprets, so the latency numbers measure plumbing; the
benchmarks/bench_traffic.py agreement verdict is the regression signal.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import service, walk as walk_lib
from repro.core.graph import PinBoardGraph
from repro.serving.resilience import ResilienceConfig, elastic_step_budget

# "this shard never dies": the liveness sentinel for sharded replicas
_NEVER_DIES = np.iinfo(np.int32).max


class LatencyRing:
    """Bounded float ring buffer with list-ish edges (append/extend/clear).

    Replaces the unbounded ``List[float]`` that leaked memory under
    continuous traffic: a long-lived replica keeps only the most recent
    ``capacity`` samples, and ``percentile`` is exact over that window.
    """

    __slots__ = ("capacity", "_buf", "_n", "_head")

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError(f"ring capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._buf = np.zeros((self.capacity,), np.float64)
        self._n = 0      # valid samples (<= capacity)
        self._head = 0   # next write position

    def append(self, x: float) -> None:
        self._buf[self._head] = float(x)
        self._head = (self._head + 1) % self.capacity
        self._n = min(self._n + 1, self.capacity)

    def extend(self, xs) -> None:
        for x in xs:
            self.append(x)

    def clear(self) -> None:
        self._n = 0
        self._head = 0

    def values(self) -> np.ndarray:
        """Samples oldest-first (only the retained window)."""
        if self._n < self.capacity:
            return self._buf[: self._n].copy()
        return np.roll(self._buf, -self._head)

    def percentile(self, p: float) -> float:
        """Exact percentile over the retained window; 0.0 when empty (an
        idle replica's dashboard shows 0, not a NaN crash)."""
        if not self._n:
            return 0.0
        return float(np.percentile(self.values(), p))

    def __len__(self) -> int:
        return self._n

    def __iter__(self):
        return iter(self.values())


@dataclasses.dataclass
class ServerStats:
    """Continuous-serving telemetry with bounded memory.

    ``latencies_ms[i] = wait_ms[i] + compute_ms[i]`` per query: queue wait
    (enqueue -> dispatch, stamped in ``submit``) plus dispatch+compute
    (host enqueue of the jitted call through ``block_until_ready``).  The
    old accounting dropped the wait term entirely — under load that hid
    exactly the queueing delay the paper's 60 ms p99 target is about.
    """

    capacity: int = 4096
    latencies_ms: LatencyRing = None
    wait_ms: LatencyRing = None
    compute_ms: LatencyRing = None
    queries: int = 0
    batches: int = 0
    dropped: int = 0          # total refused work (rejections + harness drops)
    # submit-time admission rejections PER BUCKET (keyed by n_slots) —
    # previously these were folded into ``dropped`` with no bucket
    # attribution, so an operator couldn't see WHICH shape was overloaded
    rejected: Dict[int, int] = None
    graph_generation: int = 0

    def __post_init__(self):
        if self.latencies_ms is None:
            self.latencies_ms = LatencyRing(self.capacity)
        if self.wait_ms is None:
            self.wait_ms = LatencyRing(self.capacity)
        if self.compute_ms is None:
            self.compute_ms = LatencyRing(self.capacity)
        if self.rejected is None:
            self.rejected = {}

    @property
    def rejected_total(self) -> int:
        """Submit-time rejections across every bucket."""
        return sum(self.rejected.values())

    def percentile(self, p: float, which: str = "latency") -> float:
        ring = {
            "latency": self.latencies_ms,
            "wait": self.wait_ms,
            "compute": self.compute_ms,
        }[which]
        return ring.percentile(p)

    def qps(self, wall_seconds: float) -> float:
        return self.queries / max(wall_seconds, 1e-9)


class QueryResult:
    """Per-query serving result.

    Unpacks as ``scores, ids = result`` (the historical flush() contract)
    and additionally carries the request id, the graph generation the
    batch dispatched under (§3.3: results produced before a swap report
    the OLD generation), the latency split, and ``budget`` — the Eq. 2
    step total the request actually dispatched with (the full lane budget
    unless the resilience layer shed it; a multi-interest user reports
    the sum over its cluster lanes).  Degraded service is visible on the
    result, never silent.
    """

    __slots__ = ("req_id", "scores", "ids", "generation", "wait_ms",
                 "compute_ms", "latency_ms", "batch_seq", "budget")

    def __init__(self, req_id, scores, ids, generation, wait_ms,
                 compute_ms, batch_seq, budget=0):
        self.req_id = req_id
        self.scores = scores
        self.ids = ids
        self.generation = generation
        self.wait_ms = wait_ms
        self.compute_ms = compute_ms
        self.latency_ms = wait_ms + compute_ms
        self.batch_seq = batch_seq
        self.budget = budget

    def __iter__(self):
        return iter((self.scores, self.ids))

    def __getitem__(self, i):
        return (self.scores, self.ids)[i]

    def __len__(self):
        return 2

    def __repr__(self):
        return (f"QueryResult(req_id={self.req_id}, gen={self.generation}, "
                f"wait={self.wait_ms:.2f}ms, compute={self.compute_ms:.2f}ms)")


@dataclasses.dataclass
class _Pending:
    req_id: int
    pins: np.ndarray      # (bucket n_slots,) int32, -1 padded
    weights: np.ndarray   # (bucket n_slots,) float32, 0 padded
    feat: int
    key: jax.Array        # per-request PRNG stream (fold_in at submit)
    t_enqueue: float      # logical seconds (wall by default)
    scenario: int = 0     # ranker head index (ranked servers only)
    budget: int = 0       # per-lane Eq. 2 step total (0 = cfg.n_steps)
    user_id: Optional[int] = None   # owning user request (cluster lanes)
    cluster_idx: int = 0  # lane index within the owning user


@dataclasses.dataclass
class _UserAssembly:
    """One multi-interest user awaiting its cluster-lane results.

    ``generation`` is stamped at ``submit_user`` — the user's lanes are
    guaranteed to dispatch under that generation because ``swap_graph``
    drains every queue before moving the handle (the generation barrier);
    the old harvest-side ``max`` over lane generations could silently
    blend walks from two graphs into one merged result.
    """

    n_clusters: int
    importance: np.ndarray           # (k,) float32, normalized
    t_enqueue: float
    generation: int
    parts: Dict[int, Tuple[np.ndarray, np.ndarray]] = dataclasses.field(
        default_factory=dict
    )
    wait_ms: float = 0.0
    compute_ms: float = 0.0
    batch_seq: int = -1
    budget: int = 0                  # summed dispatched lane budgets


@dataclasses.dataclass
class _InFlight:
    entries: List[_Pending]   # real requests only (padding not recorded)
    scores: jax.Array
    ids: jax.Array
    generation: int           # stamped at DISPATCH: swaps don't rewrite it
    t_dispatch: float         # logical clock (matches submit's ``now``)
    t_dispatch_wall: float    # wall clock, for the compute measurement
    batch_seq: int
    budgets: List[int] = None  # per-entry dispatched Eq. 2 step totals


class PixieServer:
    """Single-host Pixie serving replica (bucketed, deadline-aware)."""

    def __init__(
        self,
        graph: PinBoardGraph,
        cfg: walk_lib.WalkConfig,
        batch_size: int = 8,
        n_slots: int = 8,
        seed: int = 0,
        backend: Optional[str] = None,
        mesh=None,
        axis: str = "model",
        slack: float = 2.0,
        buckets: Optional[Sequence[Tuple[int, int]]] = None,
        max_wait_ms: float = 5.0,
        max_queue_per_bucket: Optional[int] = None,
        stats_capacity: int = 4096,
        ranker=None,
        pin_topics: Optional[np.ndarray] = None,
        n_clusters: int = 3,
        resilience: Optional[ResilienceConfig] = None,
    ):
        """``backend`` overrides cfg.backend ("xla" | "pallas") so a fleet
        can flip every replica onto the fused Pallas walk engine at server
        construction; recommendations are bit-identical either way.

        ``buckets`` is the shape-specialization table: ``(batch_size,
        n_slots)`` pairs, e.g. ``[(8, 2), (4, 8), (2, 16)]``.  A request
        routes to the smallest bucket whose ``n_slots`` fits its pin
        count; each bucket shape lowers to its own cached jitted program.
        ``None`` keeps the single-bucket legacy shape ``[(batch_size,
        n_slots)]``.  ``max_wait_ms`` is the batch-formation deadline
        (``pump`` dispatches a partial bucket once its oldest request has
        waited this long); ``max_queue_per_bucket`` bounds admission —
        a full queue sheds the request (returns None, counted in
        ``stats.dropped``) instead of growing without bound.

        A ``distributed.ShardedGraph`` replica (graph too big for one
        chip) needs ``mesh``; ``axis``/``slack`` configure the walker
        routing fabric (core/distributed.py).  The sharded graph is
        closed over rather than passed through jit — its static int
        metadata must stay Python ints — so ``swap_graph`` re-jits on a
        sharded replica (the daily reload already pays a retrace for the
        new graph constants).

        ``ranker`` (a ``serving.ranker.RankRequest``) makes this a
        TWO-STAGE replica: every dispatched batch runs retrieval (top_k
        overridden to ``ranker.cfg.n_candidates``) + the scenario ranker
        head inside the same jitted program, and ``submit(scenario=...)``
        selects each request's head (related-pins vs homefeed).  Ranked
        results keep the ``(scores, ids)`` contract, now ``final_k`` wide.
        Ranker params are closed over like the walk config; a sharded
        replica rejects ``ranker`` (stage 2 needs the full CSR).

        ``pin_topics`` opens the MULTI-INTEREST intake (``submit_user``):
        action histories cluster host-side into up to ``n_clusters``
        interest lanes (``service.build_user_query`` over this topic
        table), each lane routes through the normal shape buckets with an
        importance-proportional Eq. 2 step budget, and ``harvest``
        reassembles users from their lane results via
        ``walk.merge_interest_topk``.  Budgets ride every dispatched batch
        as a ``(batch,)`` data array (flat requests carry the full
        ``cfg.n_steps`` — bit-identical to the budget-less program), so
        ragged users share the per-bucket compiled programs; bucket CHOICE
        keys on each cluster lane's own pin count, never on k.

        ``resilience`` (a ``serving.resilience.ResilienceConfig``) turns
        on degraded-mode serving: once a request's queue wait passes
        ``shed_start_ms``, it dispatches with a deadline-proportionally
        SHRUNK step budget instead of being dropped — budgets are data on
        the same ``(batch,)`` axis the multi-interest lanes use, so
        shedding never retraces.  Elastic shedding needs the budgets
        axis: ranked replicas must set ``elastic=False`` (their compiled
        program carries a scenario axis instead) and sharded replicas
        reject elastic configs (the pod engine allocates from
        ``cfg.n_steps``).  A sharded replica additionally gets the shard
        liveness controls ``kill_shard``/``revive_shards``: dead shards
        ride every dispatched batch as a ``(n_shards,)`` death-superstep
        array (data, no retrace), walkers routed to them are killed and
        reborn at home, and counting renormalizes over survivors
        (core/distributed.py)."""
        if backend is not None and backend != cfg.backend:
            cfg = dataclasses.replace(cfg, backend=backend)
        if pin_topics is not None and ranker is not None:
            raise ValueError(
                "a multi-interest replica can't rank in-batch: stage 2 "
                "re-scores the MERGED per-user candidate bag, which only "
                "exists after harvest; rank via "
                "recommend.recommend_multi_interest(rank=...) instead"
            )
        if n_clusters < 1:
            raise ValueError(f"n_clusters must be >= 1, got {n_clusters}")
        self.pin_topics = (
            None if pin_topics is None else np.asarray(pin_topics)
        )
        self.n_clusters = int(n_clusters)
        self.ranker = ranker
        self.graph = graph
        self.cfg = cfg
        self.batch_size = batch_size
        self.n_slots = n_slots
        self.mesh = mesh
        self.axis = axis
        self.slack = slack
        self.max_wait_ms = float(max_wait_ms)
        if resilience is not None:
            if ranker is not None and resilience.elastic:
                raise ValueError(
                    "elastic shedding rides the step_budgets data axis, "
                    "which a ranked replica's compiled program doesn't "
                    "carry (its batch axis is scenario); use "
                    "ResilienceConfig(elastic=False) for admission-only"
                )
            if resilience.max_queue_per_bucket is not None:
                if (max_queue_per_bucket is not None
                        and max_queue_per_bucket
                        != resilience.max_queue_per_bucket):
                    raise ValueError(
                        f"max_queue_per_bucket given twice and disagreeing: "
                        f"server={max_queue_per_bucket} vs "
                        f"resilience={resilience.max_queue_per_bucket}"
                    )
                max_queue_per_bucket = resilience.max_queue_per_bucket
        self.resilience = resilience
        self.max_queue_per_bucket = max_queue_per_bucket
        self.stats = ServerStats(capacity=stats_capacity)
        self._key = jax.random.key(seed)
        # one deterministic stream for padding lanes (results discarded)
        self._pad_key = jax.random.fold_in(self._key, jnp.iinfo(jnp.int32).max)
        self._seq = 0        # next auto-assigned request id
        self._batch_seq = 0  # dispatch order (monotone)
        if buckets is None:
            buckets = [(batch_size, n_slots)]
        if not buckets:
            raise ValueError("need at least one (batch_size, n_slots) bucket")
        # smallest-slots-first: routing picks the tightest fitting shape
        self._buckets: List[Tuple[int, int]] = sorted(
            ((int(b), int(s)) for b, s in buckets), key=lambda bs: bs[1]
        )
        seen = set()
        for b, s in self._buckets:
            if b < 1 or s < 1:
                raise ValueError(f"bucket ({b}, {s}) must be positive")
            if s in seen:
                raise ValueError(
                    f"two buckets share n_slots={s}; routing by pin count "
                    "needs distinct slot shapes"
                )
            seen.add(s)
        self.max_slots = self._buckets[-1][1]
        self._queues: Dict[int, List[_Pending]] = {
            s: [] for _, s in self._buckets
        }
        self._inflight: List[_InFlight] = []
        self._users: Dict[int, _UserAssembly] = {}
        # jit cache keys on (k, top_k) shapes: users with the same cluster
        # count share one compiled merge program
        self._merge = jax.jit(walk_lib.merge_interest_topk)
        self._build_serve()

    def _build_serve(self) -> None:
        from repro.core import distributed as dist_lib

        cfg = self.cfg
        if isinstance(self.graph, dist_lib.ShardedGraph):
            if self.ranker is not None:
                raise ValueError(
                    "a sharded replica can't rank: stage 2 gathers "
                    "candidate neighborhoods from the full CSR, which a "
                    "node-range shard doesn't hold; rank on an unsharded "
                    "replica"
                )
            if self.pin_topics is not None:
                raise ValueError(
                    "a sharded replica can't serve multi-interest users: "
                    "per-lane step budgets are not threaded through the "
                    "pod-sharded engine; serve them on an unsharded replica"
                )
            if self.resilience is not None and self.resilience.elastic:
                raise ValueError(
                    "a sharded replica can't shed elastically: the pod "
                    "engine allocates every walker from the static "
                    "cfg.n_steps bound; use ResilienceConfig(elastic="
                    "False) for admission control + dead-shard tolerance"
                )
            graph, mesh, axis, slack = (
                self.graph, self.mesh, self.axis, self.slack
            )
            # shard liveness rides every dispatch as a (n_shards,) DATA
            # array of death supersteps (INT32_MAX = never dies), so
            # kill_shard/revive_shards never retrace; a graph swap
            # revives everything (the daily reload replaces the pods)
            self._shard_dead_at = np.full(
                (graph.n_shards,), _NEVER_DIES, np.int32
            )
            sharded = jax.jit(
                lambda pins, weights, feats, keys, dead: service.serve_batch(
                    graph, pins, weights, feats, keys, cfg,
                    mesh=mesh, axis=axis, slack=slack, shard_dead_at=dead,
                )
            )
            self._serve = lambda _g, p, w, f, k: sharded(
                p, w, f, k, jnp.asarray(self._shard_dead_at)
            )
            self._takes_budgets = False
        else:
            # ONE jitted callable for every bucket: jit's compile cache is
            # keyed on argument shapes, so each (batch, n_slots) bucket
            # gets its own cached program, and a same-shape daily graph
            # swap reuses the compiled program (no retrace) — pinned by
            # _plain_serve._cache_size() in tests/test_traffic.py
            if getattr(self, "_plain_serve", None) is None:
                if self.ranker is None:
                    # EVERY non-ranker replica compiles the budgeted
                    # program: per-lane Eq. 2 budgets ride every batch as
                    # a (batch,) DATA array.  Flat requests carry
                    # cfg.n_steps, which allocates bit-identically to the
                    # static budget (core/sampling.allocate_steps), so
                    # multi-interest lanes, elastic shed budgets, and
                    # plain traffic all share the same cached programs —
                    # shedding can never retrace
                    self._plain_serve = jax.jit(
                        lambda graph, pins, weights, feats, keys, budgets:
                            service.serve_batch(
                                graph, pins, weights, feats, keys, cfg,
                                step_budgets=budgets,
                            )
                    )
                else:
                    # ranker params close over like cfg; scenario rides as
                    # a (batch,) argument so one cached program serves
                    # every head mix
                    rank = self.ranker
                    self._plain_serve = jax.jit(
                        lambda graph, pins, weights, feats, keys, scen:
                            service.serve_batch(
                                graph, pins, weights, feats, keys, cfg,
                                rank=rank, scenario=scen,
                            )
                    )
            self._serve = self._plain_serve
            self._takes_budgets = self.ranker is None

    # -- request path ---------------------------------------------------------
    def _route(self, n_pins: int) -> Tuple[int, int]:
        """Smallest bucket whose n_slots fits the query; raises past the
        largest — a query must NEVER be silently truncated (dropping pins
        silently skews every Eq. 2 step budget downstream)."""
        for b, s in self._buckets:
            if n_pins <= s:
                return b, s
        raise ValueError(
            f"query has {n_pins} pins but the largest bucket holds "
            f"{self.max_slots} slots; shrink the query (service.build_query "
            f"keeps the top-n_slots pins by weight) or add a larger bucket"
        )

    def submit(
        self,
        pins: Sequence[int],
        weights: Sequence[float],
        user_feat: int = 0,
        now: Optional[float] = None,
        req_id: Optional[int] = None,
        scenario: int = 0,
        budget: Optional[int] = None,
    ) -> Optional[int]:
        """Enqueue one request; returns its request id (None if shed).

        ``budget`` pins the request's Eq. 2 step total (1..cfg.n_steps)
        instead of the full ``cfg.n_steps`` — the replay knob the chaos
        verdict uses to dispatch an unloaded oracle with the exact shrunk
        budgets a loaded run shed to.  Elastic shedding may shrink it
        further at dispatch, never grow it.

        ``scenario`` picks the request's ranker head on a two-stage
        replica (``ranker.cfg.scenario_id`` maps names to indices);
        validated here so a bad surface id fails at intake, not as a
        garbage gather inside a dispatched batch.

        Validates up front: ``len(weights)`` must equal ``len(pins)`` (a
        mismatch used to either crash with an opaque NumPy broadcast error
        or silently misalign weights to the wrong pins), and the pin count
        must fit a bucket (no silent truncation).  Stamps the enqueue time
        for the wait component of latency; ``now`` injects a logical clock
        (the open-loop traffic harness), defaulting to wall time.
        ``req_id`` overrides the auto-assigned id — the id seeds the
        request's PRNG stream (``fold_in``), so a workload replayed with
        the same ids gets bit-identical walks regardless of batching.
        """
        if len(weights) != len(pins):
            raise ValueError(
                f"query has {len(pins)} pins but {len(weights)} weights; "
                "one weight per pin required (mismatched lengths silently "
                "misalign weights to the wrong pins)"
            )
        if self.ranker is None:
            if scenario != 0:
                raise ValueError(
                    f"scenario={scenario} on a retrieval-only server; pass "
                    "ranker= to PixieServer to open the scenario axis"
                )
        elif not 0 <= int(scenario) < self.ranker.cfg.n_scenarios:
            raise ValueError(
                f"scenario={scenario} out of range for heads "
                f"{list(self.ranker.cfg.scenarios)}"
            )
        if budget is not None and not 1 <= int(budget) <= self.cfg.n_steps:
            raise ValueError(
                f"budget={budget} outside [1, cfg.n_steps="
                f"{self.cfg.n_steps}]: the engine's chunk grid is sized "
                "for cfg.n_steps and a zero-step walk is a drop"
            )
        if budget is not None and not getattr(self, "_takes_budgets", False):
            raise ValueError(
                "this replica's compiled program has no budgets axis "
                "(ranked or sharded); per-request budgets need a plain "
                "or multi-interest replica"
            )
        n = len(pins)
        _, slots = self._route(n)
        if now is None:
            now = time.perf_counter()
        if req_id is None:
            req_id = self._seq
            self._seq += 1
        else:
            self._seq = max(self._seq, req_id + 1)
        queue = self._queues[slots]
        if (self.max_queue_per_bucket is not None
                and len(queue) >= self.max_queue_per_bucket):
            # dropped stays the TOTAL refused-work counter; rejected is
            # the per-bucket breakdown an operator needs to see WHICH
            # shape is overloaded
            self.stats.dropped += 1
            self.stats.rejected[slots] = self.stats.rejected.get(slots, 0) + 1
            return None
        qp = np.full(slots, -1, np.int32)
        qw = np.zeros(slots, np.float32)
        qp[:n] = np.asarray(pins, np.int32)
        qw[:n] = np.asarray(weights, np.float32)
        queue.append(_Pending(
            req_id=req_id, pins=qp, weights=qw, feat=int(user_feat),
            key=jax.random.fold_in(self._key, req_id), t_enqueue=now,
            scenario=int(scenario),
            budget=0 if budget is None else int(budget),
        ))
        return req_id

    def submit_user(
        self,
        actions: Sequence[service.UserAction],
        user_feat: int = 0,
        now: Optional[float] = None,
        req_id: Optional[int] = None,
        half_life_hours: float = 24.0,
    ) -> Optional[int]:
        """Enqueue one multi-interest USER (an action history, not a query).

        The PinnerSage intake: the history clusters host-side into up to
        ``n_clusters`` interest lanes (``service.build_user_query`` over
        the replica's ``pin_topics``), and EACH lane enqueues like a flat
        request — routed to the smallest bucket fitting its own pin count,
        budgeted by cluster importance (``service.cluster_step_budgets``
        splits the flat path's ``cfg.n_steps`` across the user's lanes),
        keyed ``fold_in(fold_in(server_key, req_id), cluster_idx)`` so
        every (user, cluster) pair owns a PRNG stream independent of batch
        composition.  ``harvest`` reassembles the user once all lanes
        return and emits ONE merged ``QueryResult`` under the returned
        request id (Eq. 3 across clusters via ``walk.merge_interest_topk``;
        a single-cluster user's lane passes through verbatim — the flat
        homefeed path).

        Admission is all-or-nothing: if any lane would overflow its bucket
        queue the WHOLE user sheds (returns None, one ``stats.dropped``) —
        partially-walked users would silently skew the merge.
        """
        if self.pin_topics is None:
            raise ValueError(
                "submit_user needs a multi-interest replica; pass "
                "pin_topics= to PixieServer to open the clustered intake"
            )
        uq = service.build_user_query(
            actions, self.pin_topics, n_slots=self.max_slots,
            n_clusters=self.n_clusters, half_life_hours=half_life_hours,
            user_feat=user_feat,
        )
        budgets = service.cluster_step_budgets(uq.importance, self.cfg.n_steps)
        if now is None:
            now = time.perf_counter()
        if req_id is None:
            req_id = self._seq
            self._seq += 1
        else:
            self._seq = max(self._seq, req_id + 1)
        # all-or-nothing admission: count this user's demand per bucket
        lanes = []
        demand: Dict[int, int] = {}
        for ci in range(uq.n_clusters):
            n = int(np.sum(uq.cluster_pins[ci] >= 0))
            _, slots = self._route(n)
            demand[slots] = demand.get(slots, 0) + 1
            lanes.append((ci, slots, n))
        if self.max_queue_per_bucket is not None:
            for slots, extra in demand.items():
                if len(self._queues[slots]) + extra > self.max_queue_per_bucket:
                    self.stats.dropped += 1
                    self.stats.rejected[slots] = (
                        self.stats.rejected.get(slots, 0) + 1
                    )
                    return None
        user_key = jax.random.fold_in(self._key, req_id)
        for ci, slots, n in lanes:
            # cluster rows fill valid entries first, so the prefix copy is
            # the whole lane; padding past it is bit-invariant to the walk
            qp = np.full(slots, -1, np.int32)
            qw = np.zeros(slots, np.float32)
            qp[:n] = uq.cluster_pins[ci][:n]
            qw[:n] = uq.cluster_weights[ci][:n]
            self._queues[slots].append(_Pending(
                req_id=req_id, pins=qp, weights=qw, feat=int(user_feat),
                key=jax.random.fold_in(user_key, ci), t_enqueue=now,
                budget=int(budgets[ci]), user_id=req_id, cluster_idx=ci,
            ))
        self._users[req_id] = _UserAssembly(
            n_clusters=uq.n_clusters,
            importance=np.asarray(uq.importance, np.float32),
            t_enqueue=now,
            # stamped HERE, not at harvest: swap_graph's drain barrier
            # guarantees every lane dispatches under this generation
            generation=self.stats.graph_generation,
        )
        return req_id

    # -- batch formation ------------------------------------------------------
    def _dispatch(self, batch_size: int, slots: int, now: float) -> None:
        """Form one batch from a bucket queue and enqueue the jitted call.

        Async: no ``block_until_ready`` here — the device round-trip is
        paid in ``harvest``, off the intake path."""
        queue = self._queues[slots]
        entries = queue[:batch_size]
        del queue[:batch_size]
        n_real = len(entries)
        pad = batch_size - n_real
        pins = np.full((batch_size, slots), -1, np.int32)
        weights = np.zeros((batch_size, slots), np.float32)
        feats = np.zeros((batch_size,), np.int32)
        scen = np.zeros((batch_size,), np.int32)
        for i, e in enumerate(entries):
            pins[i] = e.pins
            weights[i] = e.weights
            feats[i] = e.feat
            scen[i] = e.scenario
        keys = jnp.stack(
            [e.key for e in entries] + [self._pad_key] * pad
        )
        args = (
            self.graph, jnp.asarray(pins), jnp.asarray(weights),
            jnp.asarray(feats), keys,
        )
        if self.ranker is not None:
            args += (jnp.asarray(scen),)
        if self._takes_budgets:
            rcfg = self.resilience
            shed = rcfg is not None and rcfg.elastic
            budgets = np.full((batch_size,), self.cfg.n_steps, np.int32)
            for i, e in enumerate(entries):
                b = e.budget if e.budget else self.cfg.n_steps
                if shed:
                    # deadline-aware elastic shed: queue wait on the
                    # LOGICAL clock, so a chaos replay reproduces every
                    # shrink bit-for-bit
                    wait_ms = max(0.0, (now - e.t_enqueue) * 1e3)
                    b = elastic_step_budget(b, wait_ms, rcfg)
                budgets[i] = b
            args += (jnp.asarray(budgets),)
            entry_budgets = [int(budgets[i]) for i in range(n_real)]
        else:
            entry_budgets = [self.cfg.n_steps] * n_real
        t_wall = time.perf_counter()
        scores, ids = self._serve(*args)
        self._inflight.append(_InFlight(
            entries=entries, scores=scores, ids=ids,
            generation=self.stats.graph_generation,
            t_dispatch=now, t_dispatch_wall=t_wall,
            batch_seq=self._batch_seq, budgets=entry_budgets,
        ))
        self._batch_seq += 1
        self.stats.batches += 1

    def _deadline_of(self, entry: _Pending) -> float:
        """Logical dispatch deadline of one queued request.  The SINGLE
        float expression shared by ``pump`` and ``next_deadline`` — a
        caller pumping at exactly ``next_deadline()`` must trigger the
        dispatch (two differently-rounded formulations would make the
        returned deadline land an ulp short of its own check)."""
        return entry.t_enqueue + self.max_wait_ms / 1e3

    def pump(self, now: Optional[float] = None) -> int:
        """Deadline-aware batch formation: dispatch every FULL bucket, and
        every bucket whose oldest request has waited >= ``max_wait_ms``
        (dispatch on max-wait OR full, whichever first).  Returns the
        number of batches dispatched.  Non-blocking."""
        if now is None:
            now = time.perf_counter()
        dispatched = 0
        for batch_size, slots in self._buckets:
            queue = self._queues[slots]
            while len(queue) >= batch_size:
                self._dispatch(batch_size, slots, now)
                dispatched += 1
            if queue and now >= self._deadline_of(queue[0]):
                self._dispatch(batch_size, slots, now)
                dispatched += 1
        return dispatched

    def next_deadline(self) -> Optional[float]:
        """Logical time at which the oldest queued request hits its
        max-wait deadline (None when every queue is empty) — the traffic
        harness uses this to fire deadline dispatches deterministically."""
        heads = [
            self._deadline_of(q[0]) for q in self._queues.values() if q
        ]
        return min(heads) if heads else None

    def pending(self) -> int:
        """Requests queued but not yet dispatched."""
        return sum(len(q) for q in self._queues.values())

    # -- completion path ------------------------------------------------------
    def harvest(self) -> List[QueryResult]:
        """Collect every in-flight batch (blocking) and account latency.

        Per query: ``wait = dispatch - enqueue`` on the logical clock,
        ``compute = harvest_wall - dispatch_wall`` (host dispatch enqueue
        + device compute + transfer), ``latency = wait + compute``.
        Results carry the generation their batch dispatched under.
        """
        out: List[QueryResult] = []
        for fl in self._inflight:
            jax.block_until_ready(fl.scores)
            t_done_wall = time.perf_counter()
            compute_ms = (t_done_wall - fl.t_dispatch_wall) * 1e3
            s_np, i_np = np.asarray(fl.scores), np.asarray(fl.ids)
            for i, e in enumerate(fl.entries):
                wait_ms = max(0.0, (fl.t_dispatch - e.t_enqueue) * 1e3)
                if e.user_id is not None:
                    # a cluster lane: park it in the user's assembly; the
                    # merged user-level result is emitted below once every
                    # lane has returned
                    asm = self._users[e.user_id]
                    asm.parts[e.cluster_idx] = (s_np[i], i_np[i])
                    asm.wait_ms = max(asm.wait_ms, wait_ms)
                    asm.compute_ms = max(asm.compute_ms, compute_ms)
                    asm.batch_seq = max(asm.batch_seq, fl.batch_seq)
                    asm.budget += fl.budgets[i]
                    continue
                out.append(QueryResult(
                    req_id=e.req_id, scores=s_np[i], ids=i_np[i],
                    generation=fl.generation, wait_ms=wait_ms,
                    compute_ms=compute_ms, batch_seq=fl.batch_seq,
                    budget=fl.budgets[i],
                ))
                self.stats.queries += 1
                self.stats.wait_ms.append(wait_ms)
                self.stats.compute_ms.append(compute_ms)
                self.stats.latencies_ms.append(wait_ms + compute_ms)
        self._inflight = []
        # emit users whose lanes all returned: Eq. 3 across clusters via
        # the SAME bit-reproducible merge the fused service path uses.
        # wait/compute are the max over the user's lanes (the user is done
        # when its slowest interest is), batch_seq the last lane's, the
        # generation the one stamped at submit_user (the swap_graph drain
        # barrier guarantees every lane ran under it) — one queries/
        # latency sample per USER, not per lane.
        done = [rid for rid, a in self._users.items()
                if len(a.parts) == a.n_clusters]
        for rid in sorted(done):
            asm = self._users.pop(rid)
            scores = jnp.asarray(
                np.stack([asm.parts[c][0] for c in range(asm.n_clusters)])
            )
            ids = jnp.asarray(
                np.stack([asm.parts[c][1] for c in range(asm.n_clusters)])
            )
            ms, mi = self._merge(scores, ids, jnp.asarray(asm.importance))
            out.append(QueryResult(
                req_id=rid, scores=np.asarray(ms), ids=np.asarray(mi),
                generation=asm.generation, wait_ms=asm.wait_ms,
                compute_ms=asm.compute_ms, batch_seq=asm.batch_seq,
                budget=asm.budget,
            ))
            self.stats.queries += 1
            self.stats.wait_ms.append(asm.wait_ms)
            self.stats.compute_ms.append(asm.compute_ms)
            self.stats.latencies_ms.append(asm.wait_ms + asm.compute_ms)
        return out

    def flush(self, now: Optional[float] = None) -> List[QueryResult]:
        """Serve every queued request synchronously (padding partials).

        The single-bucket oracle path: with one bucket this reproduces the
        historical flush loop — batches formed in submit order — and the
        bucketed deadline path is verified score-for-score identical to it
        (``traffic_buckets_agree``).  Results return in request-id order
        and still unpack as ``(scores, ids)`` pairs.
        """
        if now is None:
            now = time.perf_counter()
        for batch_size, slots in self._buckets:
            while self._queues[slots]:
                self._dispatch(batch_size, slots, now)
        out = self.harvest()
        out.sort(key=lambda r: r.req_id)
        return out

    # -- graph swap (the daily reload, §3.3) -----------------------------------
    def swap_graph(self, new_graph, now: Optional[float] = None) -> None:
        """Swap in the freshly built daily graph, under load.

        Increments the generation exactly once; batches already in flight
        (or already dispatched) keep serving from the OLD graph handle —
        the swap never blocks serving, and their results report the old
        generation.  A same-shape plain-graph swap reuses the compiled
        serve programs (the graph is a jit ARGUMENT, not a closure).

        The GENERATION BARRIER: every still-queued request dispatches on
        the old graph (partial batches padded, async — the swap doesn't
        block on compute) before the handle moves.  Without it a multi-
        interest user whose cluster lanes straddled the swap would merge
        walks from two different graphs into one result; with it the
        generation stamped at ``submit_user`` is always the generation
        every lane actually ran under.  ``now`` injects the logical clock
        for deterministic harness replays (defaults to wall time).

        A sharded replica's swap also revives all shards (the daily
        reload replaces the pods)."""
        if now is None:
            now = time.perf_counter()
        for batch_size, slots in self._buckets:
            while self._queues[slots]:
                self._dispatch(batch_size, slots, now)
        self.graph = new_graph
        self.stats.graph_generation += 1
        self._build_serve()

    # -- shard liveness (degraded-mode serving) --------------------------------
    def kill_shard(self, shard: int, at_superstep: int = 0) -> None:
        """Mark one pod shard dead from absolute superstep ``at_superstep``
        of every subsequently dispatched walk (0 = dead from the start).

        Pure data: the liveness array rides the next dispatch, nothing
        retraces.  Walkers routed to a dead shard are killed and reborn
        at their home shard, walkers homed there stop being (re)injected,
        and its counts drop out of the merge — counting renormalizes over
        the survivors (core/distributed.py).  The quality cost is
        quantified by ``resilience.overlap_at_k`` against an all-alive
        oracle in benchmarks/bench_chaos.py, never silent."""
        from repro.core import distributed as dist_lib

        if not isinstance(self.graph, dist_lib.ShardedGraph):
            raise ValueError(
                "kill_shard needs a sharded replica; a plain graph has "
                "no shards to lose"
            )
        if not 0 <= int(shard) < self._shard_dead_at.shape[0]:
            raise ValueError(
                f"shard {shard} out of range for "
                f"{self._shard_dead_at.shape[0]} shards"
            )
        if int(at_superstep) < 0:
            raise ValueError(
                f"at_superstep={at_superstep} must be >= 0"
            )
        self._shard_dead_at[int(shard)] = int(at_superstep)

    def revive_shards(self) -> None:
        """Bring every shard back to life (subsequent dispatches only)."""
        from repro.core import distributed as dist_lib

        if not isinstance(self.graph, dist_lib.ShardedGraph):
            raise ValueError("revive_shards needs a sharded replica")
        self._shard_dead_at[:] = _NEVER_DIES

    def dead_shards(self) -> List[int]:
        """Shards currently marked dead (empty on a healthy replica)."""
        dead = getattr(self, "_shard_dead_at", None)
        if dead is None:
            return []
        return [int(i) for i in np.flatnonzero(dead != _NEVER_DIES)]
