"""Open-loop traffic for the Pixie server (paper §3.3: 1,200 QPS / 60 ms p99).

An OPEN-LOOP load generator offers requests at arrival times drawn from a
seeded Poisson process — arrivals never wait for the server, so queueing
delay shows up honestly in the latency distribution instead of being
absorbed by a closed loop's back-pressure ("Related Pins": freshness and
tail latency, not batch throughput, are the production objective).

The harness drives ``PixieServer`` on a deterministic VIRTUAL clock:

  * arrivals and batch-formation deadlines advance logical time (so the
    arrival pattern, the bucket composition of every batch, and therefore
    every query's walk are bit-reproducible from the seed);
  * per-batch COMPUTE is wall-clock measured around the real jitted call,
    then folded into a single-executor queueing model — batch k's service
    starts at ``max(dispatch_k, done_{k-1})`` — which is what turns
    offered-QPS sweeps into the classic hockey-stick latency curve even
    though the host serves batches one at a time;
  * per-query latency = queue wait (arrival -> dispatch) + executor queue
    (dispatch -> service start) + compute, reported with the split;
  * load shedding: an arrival finding the executor backlogged past
    ``max_backlog_s`` is DROPPED and counted — drop rate is a first-class
    output, never silent.

On CPU hosts the compute term measures interpret-mode plumbing, so the
absolute curve is only meaningful on TPU hosts; the shape (wait exploding
as offered load approaches capacity) and the ``traffic_buckets_agree``
verdict (bucketed deadline-aware serving bit-identical to the
single-bucket flush oracle) are host-independent.

CHAOS MODE: ``FaultSchedule`` injects faults as pure functions of a seed
(``sample_fault_schedule``) driven through the same virtual clock, so a
chaos run is bit-reproducible on interpret-mode CPU hosts:

  * **traffic bursts** — a deterministic time-warp applied to the arrival
    schedule up front (``apply_traffic_bursts``): arrivals inside a burst
    window compress toward its start, spiking instantaneous offered QPS
    without touching payloads or request ids (walks unchanged);
  * **dispatch latency spikes** — suppression windows on the DISPATCH
    clock: any batch formation that would fire inside a window defers to
    its end (the device hiccuped, the intake didn't), so queue waits grow
    and the resilience layer's elastic budgets shrink, deterministically;
  * **shard deaths** — at the event's logical time the harness calls
    ``server.kill_shard``; every later dispatch rides the dead-shard
    tolerance path in core/distributed.py.

Zero faults + resilience thresholds that never engage reproduce the plain
open-loop run bit-for-bit — the ``degraded_serving_agrees`` verdict leans
on exactly that.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.server import PixieServer, QueryResult


@dataclasses.dataclass(frozen=True)
class Request:
    """One offered request: arrival time plus the query payload.

    Two payload shapes share the schedule: a FLAT query (``pins`` +
    ``weights``, the classic homefeed request) or a MULTI-INTEREST user
    (``actions`` set — a raw action history the server clusters into
    interest lanes via ``submit_user``).  ``actions`` wins when both are
    present; flat requests leave it ``None``.
    """

    req_id: int
    t_arrival: float            # seconds since epoch start
    pins: Tuple[int, ...]
    weights: Tuple[float, ...]
    user_feat: int
    actions: Optional[Tuple] = None   # Tuple[service.UserAction, ...]


@dataclasses.dataclass(frozen=True)
class OpenLoopConfig:
    """Seeded Poisson workload shape.

    ``offered_qps`` sets the exponential inter-arrival rate; query sizes
    draw uniformly from ``1..max_pins`` (mixed sizes exercise bucket
    routing), weights decay from 1.0 with seeded jitter, feats draw from
    ``n_feats``.  Same seed -> same arrivals, payloads, and (via request
    ids seeding the server's per-query ``fold_in`` streams) same walks.
    """

    offered_qps: float
    n_requests: int
    seed: int = 0
    max_pins: int = 8
    n_feats: int = 4


def poisson_requests(
    candidate_pins: np.ndarray, cfg: OpenLoopConfig
) -> List[Request]:
    """Draw the open-loop arrival schedule and query payloads."""
    if cfg.offered_qps <= 0:
        raise ValueError(f"offered_qps must be > 0, got {cfg.offered_qps}")
    if cfg.max_pins > len(candidate_pins):
        raise ValueError(
            f"max_pins={cfg.max_pins} exceeds the {len(candidate_pins)} "
            "candidate pins to sample from"
        )
    rng = np.random.default_rng(cfg.seed)
    gaps = rng.exponential(1.0 / cfg.offered_qps, size=cfg.n_requests)
    arrivals = np.cumsum(gaps)
    out: List[Request] = []
    for i in range(cfg.n_requests):
        k = int(rng.integers(1, cfg.max_pins + 1))
        pins = rng.choice(candidate_pins, size=k, replace=False)
        # weight profile: leading pin strongest, seeded decay after it
        weights = np.maximum(
            1.0 * (0.6 ** np.arange(k)) * rng.uniform(0.5, 1.0, size=k),
            0.05,
        )
        out.append(Request(
            req_id=i,
            t_arrival=float(arrivals[i]),
            pins=tuple(int(p) for p in pins),
            weights=tuple(float(w) for w in weights),
            user_feat=int(rng.integers(0, cfg.n_feats)),
        ))
    return out


def poisson_user_requests(
    histories: Sequence, cfg: OpenLoopConfig
) -> List[Request]:
    """Open-loop arrivals whose payloads are USER ACTION HISTORIES.

    ``histories`` is a sequence of ``graphs.synthetic.UserHistory`` (or
    anything with ``.actions``); arrival ``i`` carries history
    ``i % len(histories)`` — the round-robin keeps every planted user in
    rotation while the Poisson schedule stays identical to the flat
    generator's for the same ``(seed, offered_qps, n_requests)``, so QPS
    sweeps compare flat vs multi-interest serving under the SAME arrival
    pattern.  Feats draw from the same seeded stream position the flat
    generator uses for sizes, so the schedules stay seeded-deterministic
    but are NOT bitwise-coupled to flat payloads (they don't need to be:
    the request ids, not the payload stream, seed the walks).
    """
    if cfg.offered_qps <= 0:
        raise ValueError(f"offered_qps must be > 0, got {cfg.offered_qps}")
    if not histories:
        raise ValueError("poisson_user_requests needs at least one history")
    rng = np.random.default_rng(cfg.seed)
    gaps = rng.exponential(1.0 / cfg.offered_qps, size=cfg.n_requests)
    arrivals = np.cumsum(gaps)
    out: List[Request] = []
    for i in range(cfg.n_requests):
        h = histories[i % len(histories)]
        out.append(Request(
            req_id=i,
            t_arrival=float(arrivals[i]),
            pins=(),
            weights=(),
            user_feat=int(rng.integers(0, cfg.n_feats)),
            actions=tuple(h.actions),
        ))
    return out


# ---------------------------------------------------------------------------
# Seeded fault injection (degraded-mode serving, serving/resilience.py)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One injected fault on the virtual clock.

    ``kind`` is ``"latency_spike"`` (dispatch suppression over
    ``[t_start, t_start + duration_s)``), ``"traffic_burst"`` (arrivals in
    the window compress toward ``t_start`` by ``factor``), or
    ``"shard_death"`` (``shard`` dies at walk superstep ``at_superstep``
    for every batch dispatched at or after ``t_start``).
    """

    kind: str
    t_start: float
    duration_s: float = 0.0
    factor: float = 1.0
    shard: int = -1
    at_superstep: int = 0


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """A set of fault events, a pure function of the chaos seed.

    Immutable and host-side: applying the same schedule to the same
    request list and server seed replays the whole degraded run
    bit-for-bit (budgets, batch composition, walks, everything).
    """

    events: Tuple[FaultEvent, ...] = ()

    def of_kind(self, kind: str) -> Tuple[FaultEvent, ...]:
        return tuple(
            sorted(
                (e for e in self.events if e.kind == kind),
                key=lambda e: e.t_start,
            )
        )

    def defer(self, t: float) -> float:
        """Earliest non-suppressed instant at or after ``t``.

        A dispatch landing inside a latency-spike window slides to the
        window's end; cascading windows chain (the loop runs to a fixed
        point, so overlapping spikes behave like one long one).
        """
        spikes = self.of_kind("latency_spike")
        moved = True
        while moved:
            moved = False
            for e in spikes:
                if e.t_start <= t < e.t_start + e.duration_s:
                    t = e.t_start + e.duration_s
                    moved = True
        return t


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Knobs for ``sample_fault_schedule`` — how much of each fault kind.

    ``horizon_s`` spans the window fault start times draw from (uniform,
    seeded).  ``n_shards`` must be set when ``n_shard_deaths > 0`` (the
    victim shard draws from it); ``death_max_superstep`` bounds the drawn
    in-walk death step.
    """

    horizon_s: float
    seed: int = 0
    n_spikes: int = 0
    spike_duration_s: float = 0.05
    n_bursts: int = 0
    burst_duration_s: float = 0.2
    burst_factor: float = 4.0
    n_shard_deaths: int = 0
    n_shards: int = 0
    death_max_superstep: int = 8

    def __post_init__(self):
        if self.horizon_s <= 0:
            raise ValueError(f"horizon_s must be > 0, got {self.horizon_s}")
        if self.burst_factor < 1.0:
            raise ValueError(
                f"burst_factor={self.burst_factor} must be >= 1 (a burst "
                "compresses arrivals; use fewer requests to thin traffic)"
            )
        if self.n_shard_deaths > 0 and self.n_shards < 1:
            raise ValueError(
                "n_shard_deaths > 0 needs n_shards (the victim pool)"
            )


def sample_fault_schedule(cfg: ChaosConfig) -> FaultSchedule:
    """Draw a fault schedule — same ``ChaosConfig`` -> same schedule."""
    rng = np.random.default_rng(cfg.seed)
    events: List[FaultEvent] = []
    for _ in range(cfg.n_spikes):
        events.append(FaultEvent(
            kind="latency_spike",
            t_start=float(rng.uniform(0.0, cfg.horizon_s)),
            duration_s=cfg.spike_duration_s,
        ))
    for _ in range(cfg.n_bursts):
        events.append(FaultEvent(
            kind="traffic_burst",
            t_start=float(rng.uniform(0.0, cfg.horizon_s)),
            duration_s=cfg.burst_duration_s,
            factor=cfg.burst_factor,
        ))
    for _ in range(cfg.n_shard_deaths):
        events.append(FaultEvent(
            kind="shard_death",
            t_start=float(rng.uniform(0.0, cfg.horizon_s)),
            shard=int(rng.integers(0, cfg.n_shards)),
            at_superstep=int(rng.integers(0, cfg.death_max_superstep + 1)),
        ))
    events.sort(key=lambda e: (e.t_start, e.kind))
    return FaultSchedule(events=tuple(events))


def apply_traffic_bursts(
    requests: Sequence[Request], faults: FaultSchedule
) -> List[Request]:
    """Deterministic arrival time-warp for every burst event.

    Arrivals inside ``[t_start, t_start + duration_s)`` compress toward
    ``t_start`` by ``factor`` (monotone within the window, so arrival
    ORDER never changes); payloads and request ids are untouched, so the
    walks — keyed by request id — are bit-identical to the unwarped
    run's, only their queueing differs.  Applied once, up front: the
    burst is part of the offered schedule, not a serving-time effect.
    """
    out = list(requests)
    for e in faults.of_kind("traffic_burst"):
        warped = []
        for r in out:
            t = r.t_arrival
            if e.t_start <= t < e.t_start + e.duration_s:
                t = e.t_start + (t - e.t_start) / e.factor
            warped.append(
                dataclasses.replace(r, t_arrival=t) if t != r.t_arrival
                else r
            )
        out = warped
    return out


@dataclasses.dataclass
class TrafficReport:
    """Aggregate + per-request accounting of one open-loop run."""

    offered_qps: float
    n_offered: int
    n_served: int
    n_dropped: int
    makespan_s: float
    latency_ms: np.ndarray        # (n_served,) wait + exec queue + compute
    wait_ms: np.ndarray           # batch-formation wait
    queue_ms: np.ndarray          # executor backlog wait
    compute_ms: np.ndarray        # measured device round-trip
    results: Dict[int, QueryResult]  # req_id -> result (scores/ids/gen)
    generations: Dict[int, int]   # req_id -> graph generation served under
    # submit-time admission rejections (bounded bucket queues) — part of
    # n_dropped, broken out so total refused work is attributable
    n_rejected: int = 0
    # req_id -> the Eq. 2 step budget the request actually dispatched
    # with (shrunk under elastic shed) — the replay record the chaos
    # verdict feeds back through ``submit(budget=...)``
    budgets: Dict[int, int] = dataclasses.field(default_factory=dict)

    @property
    def drop_rate(self) -> float:
        """Total refused work (backlog sheds + admission rejections)
        over offered — rejections are NOT extra on top of n_dropped."""
        return self.n_dropped / max(self.n_offered, 1)

    @property
    def achieved_qps(self) -> float:
        return self.n_served / max(self.makespan_s, 1e-9)

    def percentile(self, p: float) -> float:
        if self.latency_ms.size == 0:
            return 0.0
        return float(np.percentile(self.latency_ms, p))

    def summary(self) -> Dict:
        return {
            "offered_qps": round(self.offered_qps, 3),
            "achieved_qps": round(self.achieved_qps, 3),
            "n_offered": self.n_offered,
            "n_served": self.n_served,
            "n_dropped": self.n_dropped,
            "n_rejected": self.n_rejected,
            "drop_rate": round(self.drop_rate, 4),
            "p50_ms": round(self.percentile(50), 3),
            "p95_ms": round(self.percentile(95), 3),
            "p99_ms": round(self.percentile(99), 3),
            "mean_wait_ms": round(float(self.wait_ms.mean()), 3)
            if self.wait_ms.size else 0.0,
            "mean_queue_ms": round(float(self.queue_ms.mean()), 3)
            if self.queue_ms.size else 0.0,
            "mean_compute_ms": round(float(self.compute_ms.mean()), 3)
            if self.compute_ms.size else 0.0,
        }


def run_open_loop(
    server: PixieServer,
    requests: Sequence[Request],
    max_backlog_s: Optional[float] = None,
    swap_at: Optional[int] = None,
    swap_graph=None,
    faults: Optional[FaultSchedule] = None,
) -> TrafficReport:
    """Offer ``requests`` to ``server`` on the virtual clock.

    ``max_backlog_s`` bounds the executor backlog an arrival may join
    (open-loop load shedding; ``None`` admits everything — required for
    the agreement verdict, where every request must be served).
    ``swap_at``/``swap_graph`` exercise the daily graph reload (§3.3)
    UNDER load: after offering ``swap_at`` requests the new graph swaps
    in; requests dispatched before the swap carry the old generation.

    ``faults`` injects the seeded chaos schedule: traffic bursts warp the
    arrival times up front (``apply_traffic_bursts``), latency spikes
    defer every dispatch landing in their window to the window's end
    (waits grow, elastic budgets shrink — all on the virtual clock, so
    the degraded run replays bit-for-bit), and shard deaths call
    ``server.kill_shard`` once the clock passes their start time.  An
    empty schedule is exactly no schedule.

    Multi-interest requests (``Request.actions`` set) route through
    ``server.submit_user``; each user surfaces as ONE harvested result
    once its slowest cluster lane lands.  The executor model then sees
    only user-FINAL batches: a user's ``compute_ms``/``wait_ms`` are the
    max over its lanes and its ``batch_seq`` the last lane's, so the
    queueing curve is an honest APPROXIMATION under multi-interest load
    (batches holding only non-final lanes don't advance the executor).
    The bit-level regression signal is the ``multi_interest_agrees``
    verdict, never this model's latency numbers.
    """
    if faults is not None:
        requests = apply_traffic_bursts(requests, faults)
        deaths = list(faults.of_kind("shard_death"))
        eff = faults.defer          # dispatch-time suppression mapping
    else:
        deaths = []
        eff = lambda t: t
    requests = sorted(requests, key=lambda r: r.t_arrival)
    busy_until = 0.0
    harvested: List[QueryResult] = []
    dispatch_time: Dict[int, float] = {}  # batch_seq -> logical dispatch t
    n_dropped = 0
    rejected_before = server.stats.rejected_total

    def _account():
        """Harvest any newly dispatched batches and note dispatch times."""
        for fl in server._inflight:
            dispatch_time[fl.batch_seq] = fl.t_dispatch
        harvested.extend(server.harvest())

    for i, req in enumerate(requests):
        while deaths and deaths[0].t_start <= req.t_arrival:
            e = deaths.pop(0)
            server.kill_shard(e.shard, at_superstep=e.at_superstep)
        if swap_at is not None and i == swap_at:
            if swap_graph is None:
                raise ValueError("swap_at set but no swap_graph given")
            # the swap's generation barrier may dispatch queued partials
            # on the old graph — account them before serving continues
            server.swap_graph(swap_graph, now=eff(req.t_arrival))
            _account()
        # fire every deadline that ripens before this arrival, in order;
        # a deadline landing in a latency-spike window fires (with every
        # other dispatch due by then) at the window's end
        while True:
            d = server.next_deadline()
            if d is None or d > req.t_arrival:
                break
            server.pump(now=eff(d))
            _account()
        if max_backlog_s is not None and (
            busy_until - req.t_arrival > max_backlog_s
        ):
            n_dropped += 1
            server.stats.dropped += 1
            continue
        if req.actions is not None:
            # multi-interest user: the server clusters the history into
            # lanes; all-or-nothing admission may shed the whole user
            # (returns None) — already counted in server.stats.dropped.
            admitted = server.submit_user(
                list(req.actions), req.user_feat,
                now=req.t_arrival, req_id=req.req_id,
            )
        else:
            admitted = server.submit(
                list(req.pins), list(req.weights), req.user_feat,
                now=req.t_arrival, req_id=req.req_id,
            )
        if admitted is None:
            # admission rejection (bounded bucket queue): counted here so
            # the drop rate reflects TOTAL refused work, and per-bucket
            # in server.stats.rejected
            n_dropped += 1
            server.pump(now=eff(req.t_arrival))
            _account()
            busy_until = _advance_executor(
                harvested, dispatch_time, busy_until
            )
            continue
        server.pump(now=eff(req.t_arrival))  # full-bucket dispatches
        _account()
        # fold harvested compute into the executor model as batches land
        busy_until = _advance_executor(harvested, dispatch_time, busy_until)

    # drain: remaining partials dispatch at their deadlines
    while server.pending():
        d = server.next_deadline()
        server.pump(now=eff(d))
        _account()
    busy_until = _advance_executor(harvested, dispatch_time, busy_until)

    # executor queueing model over the full run (batch_seq = dispatch order)
    per_batch: Dict[int, List[QueryResult]] = {}
    for r in harvested:
        per_batch.setdefault(r.batch_seq, []).append(r)
    busy = 0.0
    lat, wait, queue, comp = [], [], [], []
    results: Dict[int, QueryResult] = {}
    generations: Dict[int, int] = {}
    budgets: Dict[int, int] = {}
    for seq in sorted(per_batch):
        rs = per_batch[seq]
        t_d = dispatch_time[seq]
        start = max(t_d, busy)
        compute_s = rs[0].compute_ms / 1e3
        done = start + compute_s
        busy = done
        for r in rs:
            t_arr = t_d - r.wait_ms / 1e3
            lat.append((done - t_arr) * 1e3)
            wait.append(r.wait_ms)
            queue.append((start - t_d) * 1e3)
            comp.append(r.compute_ms)
            results[r.req_id] = r
            generations[r.req_id] = r.generation
            budgets[r.req_id] = int(r.budget)

    makespan = max(
        [busy] + [r.t_arrival for r in requests[-1:]]
    ) if requests else 0.0
    return TrafficReport(
        offered_qps=(
            len(requests) / max(requests[-1].t_arrival, 1e-9)
            if requests else 0.0
        ),
        n_offered=len(requests),
        n_served=len(results),
        n_dropped=n_dropped,
        makespan_s=makespan,
        latency_ms=np.asarray(lat),
        wait_ms=np.asarray(wait),
        queue_ms=np.asarray(queue),
        compute_ms=np.asarray(comp),
        results=results,
        generations=generations,
        n_rejected=server.stats.rejected_total - rejected_before,
        budgets=budgets,
    )


def _advance_executor(harvested, dispatch_time, busy_until: float) -> float:
    """Current executor-free time given everything harvested so far."""
    busy = 0.0
    seen: Dict[int, float] = {}
    for r in harvested:
        seen.setdefault(r.batch_seq, r.compute_ms / 1e3)
    for seq in sorted(seen):
        start = max(dispatch_time[seq], busy)
        busy = start + seen[seq]
    return max(busy_until, busy)
