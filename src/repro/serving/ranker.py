"""Stage 2 of the serving path: rank Pixie candidates with scenario heads.

PinSage's key trick (PAPERS.md: "Graph Convolutional Neural Networks for
Web-Scale Recommender Systems" — same authors, same object graph) is that
importance-sampled neighborhoods are exactly what a random walk's visit
counts already are.  The retrieval stage here hands us that for free: the
walk's boosted per-(query, slot) visit counts ARE an importance-weighted
sample of the query's graph neighborhood.  Stage 2 therefore needs no
second sampling pass —

  * the **query embedding** pools the retrieved candidate set itself,
    weighted by ``sqrt(walk score)`` (undoing the Eq. 3 multi-hit boost
    back to visit-count scale — PinSage's importance pooling);
  * each **candidate embedding** pools a deterministic 2-hop fan gathered
    from the SAME CSR the walk ran on (pin -> board -> pin, Eq. 4's
    gather arithmetic with fixed instead of random picks);
  * both pools are one Pallas ``embedding_bag_batched`` call for the whole
    batch (kernels/embedding_bag.py), so a batched two-stage serve step
    keeps a constant ``pallas_call`` count regardless of batch size;
  * a small per-scenario head (PinnerSage motivates heads per surface:
    related-pins vs homefeed) scores candidates against the query.

Everything float in this module is ONE shared program for both walk
backends — ``use_kernel`` for the bag op defaults by platform, never by
walk backend — which is what makes the fused pallas two-stage path
bit-identical to the XLA oracle (`two_stage_backends_agree`, verdict 15):
the backends diverge only inside the integer-exact walk engines.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.graph import PinBoardGraph
from repro.kernels import ops
from repro.models import layers

Array = jax.Array

SCENARIOS: Tuple[str, ...] = ("related_pins", "homefeed")


@dataclasses.dataclass(frozen=True)
class RankerConfig:
    """Shape of the stage-2 ranker.

    ``n_items`` must equal the graph's ``n_pins`` — candidate ids index the
    item table directly.  ``n_candidates`` is the stage-1 walk top-k fed to
    the ranker (it overrides ``WalkConfig.top_k`` on the serving path);
    ``final_k`` of those come back ranked.
    """

    n_items: int
    d_model: int = 32
    n_neighbors: int = 8          # 2-hop fan size per candidate
    n_candidates: int = 64        # stage-1 top-k handed to stage 2
    final_k: int = 16
    scenarios: Tuple[str, ...] = SCENARIOS

    def __post_init__(self):
        if self.final_k > self.n_candidates:
            raise ValueError(
                f"final_k={self.final_k} > n_candidates={self.n_candidates}: "
                "stage 2 can only return candidates stage 1 retrieved"
            )
        if len(set(self.scenarios)) != len(self.scenarios) or not self.scenarios:
            raise ValueError(
                f"scenarios must be non-empty and unique, got {self.scenarios}"
            )

    @property
    def n_scenarios(self) -> int:
        return len(self.scenarios)

    def scenario_id(self, name: str) -> int:
        """Scenario name -> head index; raises on unknown names so a typo'd
        surface never silently scores with head 0."""
        try:
            return self.scenarios.index(name)
        except ValueError:
            raise ValueError(
                f"unknown scenario {name!r}; known: {list(self.scenarios)}"
            ) from None


class RankRequest(NamedTuple):
    """What `service.serve_batch(rank=...)` needs to run stage 2."""

    params: Dict[str, Any]
    cfg: RankerConfig


def init_ranker_params(key: Array, cfg: RankerConfig) -> Dict[str, Any]:
    """Item table + one (w_self, w_neigh, w_query, b) head per scenario,
    stacked on a leading scenario axis so a batch can gather its per-request
    head with one ``jnp.take``."""
    kt, k_self, k_neigh, k_query = jax.random.split(key, 4)
    d = cfg.d_model

    def per_scenario(k: Array) -> Array:
        ks = jax.random.split(k, cfg.n_scenarios)
        return jnp.stack([layers.dense_init(kk, (d, d)) for kk in ks])

    return {
        "items": layers.embed_init(kt, (cfg.n_items, d)),
        "heads": {
            "w_self": per_scenario(k_self),
            "w_neigh": per_scenario(k_neigh),
            "w_query": per_scenario(k_query),
            "b": jnp.zeros((cfg.n_scenarios, d), jnp.float32),
        },
    }


def candidate_neighborhoods(
    graph: PinBoardGraph,
    cand_ids: Array,      # (..., k) int32 pin ids, anything under valid=False ignored
    valid: Array,         # (..., k) bool
    n_neighbors: int,
) -> Tuple[Array, Array]:
    """Deterministic 2-hop fan per candidate from the walk's own CSR.

    Neighbor j of candidate c is ``b2p[p2b[c][j % deg(c)]][(j*31 + 7) %
    deg(board)]`` — Eq. 4's two gathers with a fixed stride instead of a
    random draw (the 31/7 stride decorrelates the board-side pick from the
    pin-side pick so fan-in isn't all copies of one pin).  Pure integer
    arithmetic: both walk backends compute identical neighborhoods by
    construction.

    Returns ``(nbr_ids, nbr_w)``, each ``(..., k, n_neighbors)``: ids are
    -1 where the fan dead-ends (invalid candidate, isolated pin, empty
    board) and weights are a ``1 / (1 + j)`` position decay zeroed on dead
    ends — CSR adjacency is feature-sorted, so low j is a stable, not
    random, subset.
    """
    off_dt = graph.p2b.offsets.dtype
    safe_c = jnp.where(valid, cand_ids, 0).astype(off_dt)
    start = jnp.take(graph.p2b.offsets, safe_c)
    deg = (jnp.take(graph.p2b.offsets, safe_c + 1) - start).astype(jnp.int32)
    j = jnp.arange(n_neighbors, dtype=jnp.int32)          # (L,)
    bsel = j % jnp.maximum(deg, 1)[..., None]             # (..., k, L)
    board = jnp.take(graph.p2b.targets, start[..., None] + bsel.astype(off_dt))
    board_ok = (deg > 0)[..., None]
    b_local = jnp.where(board_ok, board.astype(jnp.int32) - graph.n_pins, 0)
    bstart = jnp.take(graph.b2p.offsets, b_local.astype(off_dt))
    bdeg = (
        jnp.take(graph.b2p.offsets, b_local.astype(off_dt) + 1) - bstart
    ).astype(jnp.int32)
    psel = (j * 31 + 7) % jnp.maximum(bdeg, 1)
    nbr = jnp.take(graph.b2p.targets, bstart + psel.astype(off_dt))
    ok = valid[..., None] & board_ok & (bdeg > 0)
    nbr_ids = jnp.where(ok, nbr.astype(jnp.int32), -1)
    nbr_w = ok.astype(jnp.float32) / (1.0 + j.astype(jnp.float32))
    return nbr_ids, nbr_w


def rank_candidates(
    params: Dict[str, Any],
    cfg: RankerConfig,
    graph: PinBoardGraph,
    cand_ids: Array,      # (batch, k) int32 from stage-1 top-k
    cand_scores: Array,   # (batch, k) f32 boosted walk scores (0 = padding)
    scenario: Array,      # (batch,) int32 head index per request
    *,
    use_kernel: Optional[bool] = None,
) -> Tuple[Array, Array]:
    """Stage 2: score a batch's retrieved candidates with scenario heads.

    Takes the stage-1 output ``(ids, scores)`` DIRECTLY — this is the stage
    boundary: callers with precomputed walk stats (a cache, a replayed
    batch, a different retrieval engine) enter here without re-walking.

    Returns ``(final_scores, final_ids)``, each ``(batch, final_k)``;
    ids are -1 (and scores -inf) where a query retrieved fewer than
    ``final_k`` real candidates, mirroring the walk top-k's contract.
    """
    if cand_ids.ndim != 2:
        raise ValueError(
            f"rank_candidates is batched: want (batch, k) candidate ids, "
            f"got shape {cand_ids.shape}"
        )
    if cfg.n_items != graph.n_pins:
        raise ValueError(
            f"ranker table has {cfg.n_items} items but the graph has "
            f"{graph.n_pins} pins; candidate ids index the item table"
        )
    table = params["items"]
    d = table.shape[1]
    scenario = jnp.broadcast_to(
        jnp.asarray(scenario, jnp.int32), cand_ids.shape[:1]
    )
    valid = cand_scores > 0

    # candidate side: self embedding + pooled 2-hop neighborhood
    nbr_ids, nbr_w = candidate_neighborhoods(
        graph, cand_ids, valid, cfg.n_neighbors
    )
    neigh_emb = ops.embedding_bag_batched(
        table, nbr_ids, nbr_w, mode="mean", use_kernel=use_kernel
    )                                                       # (b, k, d)
    self_emb = (
        jnp.take(table, jnp.where(valid, cand_ids, 0), axis=0)
        * valid[..., None].astype(table.dtype)
    )                                                       # (b, k, d)

    # query side: the retrieved set itself IS the importance-weighted
    # neighborhood — sqrt undoes the Eq. 3 boost back to visit-count scale
    q_ids = jnp.where(valid, cand_ids, -1)[:, None, :]      # (b, 1, k)
    q_w = jnp.sqrt(jnp.maximum(cand_scores, 0.0))[:, None, :]
    query_emb = ops.embedding_bag_batched(
        table, q_ids, q_w, mode="mean", use_kernel=use_kernel
    )[:, 0]                                                 # (b, d)

    heads = params["heads"]
    w_self = jnp.take(heads["w_self"], scenario, axis=0)    # (b, d, d)
    w_neigh = jnp.take(heads["w_neigh"], scenario, axis=0)
    w_query = jnp.take(heads["w_query"], scenario, axis=0)
    bias = jnp.take(heads["b"], scenario, axis=0)           # (b, d)

    h = jax.nn.relu(
        jnp.einsum("bkd,bde->bke", self_emb, w_self)
        + jnp.einsum("bkd,bde->bke", neigh_emb, w_neigh)
        + bias[:, None, :]
    )
    qv = jnp.einsum("bd,bde->be", query_emb, w_query)
    raw = jnp.einsum("bke,be->bk", h, qv) / jnp.sqrt(float(d))
    rank_scores = jnp.where(valid, raw, -jnp.inf)
    vals, idx = jax.lax.top_k(rank_scores, cfg.final_k)
    sel_valid = jnp.take_along_axis(valid, idx, axis=1)
    ids = jnp.where(sel_valid, jnp.take_along_axis(cand_ids, idx, axis=1), -1)
    return vals, ids
