"""LM text generation: prefill + greedy/temperature decode loop.

Thin host loop over the jitted `transformer.prefill` / `decode_step`; used
by the examples and the decode smoke tests.  The per-step program is the
exact program the decode_* dry-run cells lower.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as tf

Array = jax.Array


def generate(
    params: Dict[str, Any],
    prompt: Array,            # (b, s0) int32
    cfg: tf.LMConfig,
    max_new_tokens: int = 32,
    temperature: float = 0.0,
    key: Optional[Array] = None,
) -> Array:
    """Returns (b, s0 + max_new_tokens) generated token ids."""
    b, s0 = prompt.shape
    max_seq = s0 + max_new_tokens
    logits, cache = tf.prefill(params, prompt, cfg, max_seq=max_seq)
    step_fn = jax.jit(
        lambda p, c, t, pos: tf.decode_step(p, c, t, pos, cfg)
    )

    tokens = [prompt]
    cur = _sample(logits, temperature, key, 0)
    for i in range(max_new_tokens):
        tokens.append(cur[:, None])
        if i == max_new_tokens - 1:
            break
        logits, cache = step_fn(
            params, cache, cur, jnp.asarray(s0 + i, jnp.int32)
        )
        cur = _sample(logits, temperature, key, i + 1)
    return jnp.concatenate(tokens, axis=1)


def _sample(logits: Array, temperature: float, key, i: int) -> Array:
    if temperature <= 0.0 or key is None:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    k = jax.random.fold_in(key, i)
    return jax.random.categorical(k, logits / temperature).astype(jnp.int32)
