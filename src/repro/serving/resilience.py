"""Degraded-mode serving policy: elastic walk budgets + degradation metrics.

The paper's headline number (1,200 QPS at 60 ms p99, §4.4) is a FAIR-
WEATHER number; this module is the bad-day policy layer.  Pixie's step
budget is naturally elastic — Eq. 2 allocates steps per query and fewer
steps is a lower-quality-but-valid Monte Carlo estimate, which Related
Pins runs in production as graceful quality degradation under load.  The
PR 9 ``step_budgets``-as-data machinery makes the knob free at serving
time: budgets are a ``(batch,)`` int32 array riding every dispatched
batch, so shrinking one NEVER retraces a program.

Two pieces live here, both pure functions (the whole point — chaos runs
replay bit-identically from a seed):

  * ``elastic_step_budget`` — the deadline-aware shed policy
    ``PixieServer`` applies at DISPATCH time: once a request's queue wait
    has eaten past ``shed_start_ms`` of its ``deadline_ms``, its step
    budget shrinks linearly toward ``min_budget_frac`` (never below —
    availability over quality, a shed request is served, not dropped).
    Deterministic from the logical clock: the same (submit, dispatch)
    times always produce the same budget, which is what lets the
    ``degraded_serving_agrees`` verdict compare a loaded chaos run
    bit-for-bit against an unloaded oracle dispatched with the same
    shrunk budgets.

  * ``overlap_at_k`` — the degradation metric for dead-shard serving:
    fraction of the all-shards-alive oracle's top-k ids the degraded run
    recovered.  Dead shards renormalize counting over survivors
    (core/distributed.py) but the quality loss must be QUANTIFIED, never
    silent — the chaos bench reports this per fault scenario.

Admission control (bounded intake queues) lives on the server
(``max_queue_per_bucket``); ``ResilienceConfig`` can carry the bound so
the whole degraded-mode policy is one object, and submit-time rejections
are accounted per bucket in ``ServerStats.rejected``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Degraded-mode serving policy for one ``PixieServer`` replica.

    ``deadline_ms`` is the per-request end-to-end latency target (the
    paper's 60 ms p99); ``shed_start_ms`` is the queue wait at which
    budget shrink begins (waits below it serve the FULL budget, so an
    unloaded replica is bit-identical to one with no resilience layer at
    all — the zero-fault leg of the chaos verdict); ``min_budget_frac``
    floors the shrink (a request past its whole deadline still gets this
    fraction of its steps — served late and coarse beats dropped).

    ``max_queue_per_bucket`` optionally carries the admission bound so
    the policy is self-contained; ``None`` defers to the server argument.
    ``elastic=False`` keeps admission accounting but never shrinks a
    budget (the knob for ranked replicas, whose compiled program has no
    budgets axis).
    """

    deadline_ms: float = 60.0
    shed_start_ms: float = 10.0
    min_budget_frac: float = 0.25
    elastic: bool = True
    max_queue_per_bucket: Optional[int] = None

    def __post_init__(self):
        if self.deadline_ms <= 0:
            raise ValueError(
                f"deadline_ms must be > 0, got {self.deadline_ms}"
            )
        if not 0 <= self.shed_start_ms < self.deadline_ms:
            raise ValueError(
                f"shed_start_ms={self.shed_start_ms} must lie in "
                f"[0, deadline_ms={self.deadline_ms}): shrink must start "
                "before the deadline or the policy can never engage"
            )
        if not 0 < self.min_budget_frac <= 1:
            raise ValueError(
                f"min_budget_frac={self.min_budget_frac} must be in "
                "(0, 1]: zero-step service is a drop wearing a hat"
            )


def elastic_step_budget(
    n_steps: int, wait_ms: float, rcfg: ResilienceConfig
) -> int:
    """Deadline-aware Eq. 2 budget for one request at dispatch time.

    A pure host-side function of ``(n_steps, wait_ms, policy)`` — no
    clocks, no RNG — so the server's shed decision replays exactly:

      * ``wait_ms <= shed_start_ms``          -> full ``n_steps``;
      * linear shrink across the remaining deadline window, floored at
        ``min_budget_frac * n_steps`` (and never below 1 step);
      * waits past the deadline hold at the floor — quality degrades,
        availability doesn't.

    ``n_steps`` is the request's own lane budget (a multi-interest
    cluster lane sheds proportionally from its importance-scaled
    allocation), never above the engine's static ``cfg.n_steps`` bound.
    """
    if wait_ms <= rcfg.shed_start_ms:
        return int(n_steps)
    span = rcfg.deadline_ms - rcfg.shed_start_ms
    frac = (rcfg.deadline_ms - wait_ms) / span
    frac = max(rcfg.min_budget_frac, min(1.0, frac))
    return max(1, int(frac * n_steps))


def overlap_at_k(
    ids_a: np.ndarray, ids_b: np.ndarray, k: Optional[int] = None
) -> float:
    """Top-k id overlap between a degraded run and its oracle, in [0, 1].

    Set intersection over the first ``k`` ids of each row (default: the
    full width), averaged over the batch; ids < 0 (padding) are ignored.
    1.0 means the degraded run recovered the oracle's candidate set
    exactly; the chaos bench reports this per dead-shard scenario so the
    quality cost of a fault is a NUMBER, not a silent ranking shift.
    """
    a = np.atleast_2d(np.asarray(ids_a))
    b = np.atleast_2d(np.asarray(ids_b))
    if a.shape[0] != b.shape[0]:
        raise ValueError(
            f"overlap_at_k got {a.shape[0]} degraded rows vs "
            f"{b.shape[0]} oracle rows; compare the same queries"
        )
    if k is None:
        k = min(a.shape[1], b.shape[1])
    fracs = []
    for i in range(a.shape[0]):
        sa = set(int(x) for x in a[i, :k] if x >= 0)
        sb = set(int(x) for x in b[i, :k] if x >= 0)
        if not sb:
            fracs.append(1.0 if not sa else 0.0)
            continue
        fracs.append(len(sa & sb) / len(sb))
    return float(np.mean(fracs)) if fracs else 1.0
