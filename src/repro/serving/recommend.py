"""Two-stage recommendation: Pixie retrieval -> learned ranker.

This is how the paper's system composes with the assigned recsys archs
(DESIGN.md §4): Pixie's random walk generates candidates from the
interaction graph (the paper's Related Pins / Homefeed sources), and a
ranking model (DLRM / SASRec / BST) re-scores them — the same two-stage
shape as Pinterest's production stack ([22] in the paper).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import walk as walk_lib
from repro.core.graph import PinBoardGraph
from repro.models import sequential_rec as sr

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TwoStageConfig:
    n_candidates: int = 200      # Pixie walk top-k fed to the ranker
    final_k: int = 20


def pixie_then_rank(
    graph: PinBoardGraph,
    query_pins: Array,          # (n_slots,)
    query_weights: Array,
    user_feat: Array,
    key: Array,
    walk_cfg: walk_lib.WalkConfig,
    ranker: Callable[[Array], Array],   # candidate ids (k,) -> scores (k,)
    cfg: TwoStageConfig,
) -> Tuple[Array, Array]:
    """Returns (final scores (final_k,), item ids (final_k,))."""
    walk_cfg = dataclasses.replace(walk_cfg, top_k=cfg.n_candidates)
    walk_scores, cand = walk_lib.recommend(
        graph, query_pins, query_weights, user_feat, key, walk_cfg
    )
    rank_scores = ranker(cand)
    # candidates with zero walk score are padding — mask them out
    rank_scores = jnp.where(walk_scores > 0, rank_scores, -jnp.inf)
    vals, idx = jax.lax.top_k(rank_scores, cfg.final_k)
    # when fewer than final_k candidates carry positive walk score, top_k
    # still fills the tail with entries whose idx points at arbitrary
    # padding candidates — report those as id -1, never a real pin id.
    # Keyed on the padding condition itself (zero walk score), not the
    # ranker's -inf, so a real candidate a ranker scores -inf keeps its id.
    ids = jnp.where(jnp.take(walk_scores, idx) > 0, jnp.take(cand, idx), -1)
    return vals, ids


def sasrec_ranker(
    params: Dict[str, Any],
    user_history: Array,        # (s,) item ids
    cfg: sr.SeqRecConfig,
) -> Callable[[Array], Array]:
    """Build a candidate-scoring closure from a SASRec user state."""
    state = sr.sasrec_user_state(params, user_history[None], cfg)[0]  # (d,)

    def score(cand: Array) -> Array:
        emb = jnp.take(params["items"], jnp.maximum(cand, 0), axis=0)
        return emb @ state

    return score
