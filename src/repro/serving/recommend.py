"""Two-stage recommendation: Pixie retrieval -> learned ranker.

This is how the paper's system composes with the assigned recsys archs
(DESIGN.md §4): Pixie's random walk generates candidates from the
interaction graph (the paper's Related Pins / Homefeed sources), and a
ranking model (DLRM / SASRec / BST) re-scores them — the same two-stage
shape as Pinterest's production stack ([22] in the paper).
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import walk as walk_lib
from repro.core.graph import PinBoardGraph
from repro.models import sequential_rec as sr

if TYPE_CHECKING:  # import cycle: service -> ranker, recommend -> service
    from repro.serving import ranker as ranker_lib

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TwoStageConfig:
    n_candidates: int = 200      # Pixie walk top-k fed to the ranker
    final_k: int = 20


def rank_retrieved(
    walk_scores: Array,         # (k,) stage-1 scores, 0 = padding
    cand: Array,                # (k,) stage-1 candidate ids
    ranker: Callable[[Array], Array],   # candidate ids (k,) -> scores (k,)
    final_k: int,
) -> Tuple[Array, Array]:
    """Stage 2 alone: re-score a PRECOMPUTED retrieval ``(scores, ids)``.

    This is the stage boundary: anything that already holds walk output —
    a cache hit, a replayed request log, `serve_batch(with_stats=True)`
    telemetry — enters here without re-running retrieval
    (``pixie_then_rank`` is now just walk + this).
    """
    rank_scores = ranker(cand)
    # candidates with zero walk score are padding — mask them out
    rank_scores = jnp.where(walk_scores > 0, rank_scores, -jnp.inf)
    vals, idx = jax.lax.top_k(rank_scores, final_k)
    # when fewer than final_k candidates carry positive walk score, top_k
    # still fills the tail with entries whose idx points at arbitrary
    # padding candidates — report those as id -1, never a real pin id.
    # Keyed on the padding condition itself (zero walk score), not the
    # ranker's -inf, so a real candidate a ranker scores -inf keeps its id.
    ids = jnp.where(jnp.take(walk_scores, idx) > 0, jnp.take(cand, idx), -1)
    return vals, ids


def pixie_then_rank(
    graph: PinBoardGraph,
    query_pins: Array,          # (n_slots,)
    query_weights: Array,
    user_feat: Array,
    key: Array,
    walk_cfg: walk_lib.WalkConfig,
    ranker: Callable[[Array], Array],   # candidate ids (k,) -> scores (k,)
    cfg: TwoStageConfig,
) -> Tuple[Array, Array]:
    """Returns (final scores (final_k,), item ids (final_k,))."""
    walk_cfg = dataclasses.replace(walk_cfg, top_k=cfg.n_candidates)
    walk_scores, cand = walk_lib.recommend(
        graph, query_pins, query_weights, user_feat, key, walk_cfg
    )
    return rank_retrieved(walk_scores, cand, ranker, cfg.final_k)


def sasrec_ranker(
    params: Dict[str, Any],
    user_history: Array,        # (s,) item ids
    cfg: sr.SeqRecConfig,
) -> Callable[[Array], Array]:
    """Build a candidate-scoring closure from a SASRec user state."""
    state = sr.sasrec_user_state(params, user_history[None], cfg)[0]  # (d,)

    def score(cand: Array) -> Array:
        # -1 marks an under-full candidate slot; score it -inf instead of
        # quietly embedding item 0 (which would let pin 0's affinity leak
        # into every short retrieval).  rank_retrieved re-masks on walk
        # score anyway, but other callers of this closure get the honest
        # scores too.
        emb = jnp.take(params["items"], jnp.maximum(cand, 0), axis=0)
        return jnp.where(cand >= 0, emb @ state, -jnp.inf)

    return score


def recommend_two_stage(
    graph: PinBoardGraph,
    pins: Array,                # (batch, n_slots)
    weights: Array,             # (batch, n_slots)
    user_feats: Array,          # (batch,)
    key: Array,
    walk_cfg: walk_lib.WalkConfig,
    rank: "ranker_lib.RankRequest",
    scenario: Optional[Array] = None,   # (batch,) head index per request
    backend: Optional[str] = None,
    with_stats: bool = False,
) -> Tuple[Array, ...]:
    """The fused two-stage serving step: batched Pixie retrieval -> scenario
    ranker heads, ONE jitted program end to end.

    Stage 1 is `service.serve_batch`'s engine routing (batch-native pallas
    walk or the vmapped XLA oracle twin) with ``top_k`` overridden to
    ``rank.cfg.n_candidates``; stage 2 is `serving.ranker.rank_candidates`
    on the walk's own visit-count scores.  Riding the PR 5 query axis, a
    batched serve step lowers to a constant number of ``pallas_call``s
    independent of batch size (2 walk-engine calls per chunk + 2 embedding
    bags — pinned in tests/test_two_stage.py).

    Returns ``(final_scores, final_ids)`` each ``(batch, final_k)``; with
    ``with_stats=True`` appends the stage-1 ``(steps_taken, n_high)``
    telemetry.  Thin alias for ``service.serve_batch(rank=..., ...)`` so
    callers holding a ranker need not know the engine-routing layer.
    """
    from repro.core import service

    return service.serve_batch(
        graph, pins, weights, user_feats, key, walk_cfg,
        backend=backend, with_stats=with_stats,
        rank=rank, scenario=scenario,
    )


def recommend_multi_interest(
    graph: PinBoardGraph,
    batch,                      # service.UserBatch (users -> cluster lanes)
    key: Array,
    walk_cfg: walk_lib.WalkConfig,
    backend: Optional[str] = None,
    with_stats: bool = False,
    rank: "Optional[ranker_lib.RankRequest]" = None,
    scenario: Optional[Array] = None,   # (n_users,) head index per user
) -> Tuple[Array, ...]:
    """Multi-interest serving: every user's interest clusters in ONE walk.

    The PinnerSage-shaped request path end to end:

      1. all users' cluster lanes (``service.batch_user_queries``) ride the
         PR 5 query axis of ONE ``serve_batch`` call — per-lane Eq. 2 step
         budgets from cluster importance, constant ``pallas_call`` count no
         matter how many clusters the batch carries (lanes add rows, not
         kernel launches);
      2. each user's lanes gather back through the host-static lane map
         and merge with ``walk.merge_interest_topk`` — Eq. 3 across
         clusters, importance-weighted, bit-reproducible, so the fused
         path agrees bit-for-bit with per-cluster single-query walks
         merged the same way (verdict ``multi_interest_agrees``);
      3. single-cluster users (k=1) pass their lane through VERBATIM —
         the flat §5.1 homefeed path, unchanged.

    ``key`` is either a scalar PRNG key (split into one stream per LANE)
    or a ``(n_lanes,)`` typed key array — the bucketed server derives
    per-(user, cluster) streams by double ``fold_in`` and passes them
    here, keeping a user's recommendations independent of batch
    composition.

    ``rank`` turns the step two-stage ON THE MERGED candidate set: the
    user-level query-bag the scenario ranker head re-scores is built from
    all of the user's interests at once (``walk_cfg.top_k`` is overridden
    to ``rank.cfg.n_candidates`` so the merge emits a full candidate
    bag), with ``scenario`` indexed per USER, not per lane.

    Returns ``(scores, ids)`` each ``(n_users, top_k)``; with
    ``with_stats=True`` appends the LANE-level ``(steps_taken, n_high)``
    telemetry — per-cluster observables, mapped to users by
    ``batch.lane_user`` — so a fleet can see which interest burns budget.
    """
    import numpy as np

    if rank is not None and walk_cfg.top_k != rank.cfg.n_candidates:
        walk_cfg = dataclasses.replace(
            walk_cfg, top_k=rank.cfg.n_candidates
        )
    if scenario is not None and rank is None:
        raise ValueError(
            "scenario= selects a ranker head and needs rank=; a bare "
            "multi-interest retrieval has no scenario axis"
        )
    from repro.core import service

    scores, ids, steps, n_high = service.serve_batch(
        graph, batch.pins, batch.weights, batch.feats, key, walk_cfg,
        backend=backend, with_stats=True,
        step_budgets=batch.step_budgets,
    )

    lane_map = np.asarray(batch.lane_of_user)        # (U, k_max), static
    take_idx = jnp.asarray(np.where(lane_map >= 0, lane_map, 0))
    live = jnp.asarray((lane_map >= 0).astype(np.float32))
    lane_scores = jnp.take(scores, take_idx, axis=0)  # (U, k_max, K)
    lane_ids = jnp.take(ids, take_idx, axis=0)
    lane_imp = jnp.take(batch.importance, take_idx) * live
    merged_scores, merged_ids = jax.vmap(walk_lib.merge_interest_topk)(
        lane_scores, lane_ids, lane_imp
    )
    if rank is not None:
        from repro.serving import ranker as ranker_lib

        if scenario is None:
            scenario = jnp.zeros((batch.n_users,), jnp.int32)
        merged_scores, merged_ids = ranker_lib.rank_candidates(
            rank.params, rank.cfg, graph, merged_ids, merged_scores,
            scenario,
        )
    if with_stats:
        return merged_scores, merged_ids, steps, n_high
    return merged_scores, merged_ids
