"""Stateless, step-indexed synthetic data pipelines.

Every batch is a pure function of (step, seed) — `batch = f(step)` — which
is the property the resilience layer depends on: replaying a step after a
restore reproduces the exact batch, making recovery deterministic.  All
generators run on host numpy (the production analogue is a sharded data
service) and are cheap enough to never bottleneck the CPU smoke runs.

  * `TokenPipeline`     — zipf-distributed LM token streams with a planted
    bigram structure (so loss actually falls);
  * `ClickLogPipeline`  — DLRM-style click logs: dense features + zipf
    sparse ids, labels from a planted logistic model (learnable);
  * `SeqRecPipeline`    — user item-sequences with Markov item-item
    transitions for SASRec/BST (+ negatives).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

Array = np.ndarray


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.2

    def __call__(self, step: int) -> Dict[str, Array]:
        rng = _rng(self.seed, step)
        # planted structure: token t prefers to be followed by (t*7+3) % V
        base = np.minimum(
            rng.zipf(self.zipf_a, size=(self.batch, self.seq_len)),
            self.vocab_size - 1,
        ).astype(np.int32)
        follow = (base * 7 + 3) % self.vocab_size
        use_follow = rng.random((self.batch, self.seq_len)) < 0.5
        tokens = base.copy()
        tokens[:, 1:] = np.where(
            use_follow[:, 1:], follow[:, :-1], base[:, 1:]
        )
        labels = np.zeros_like(tokens)
        labels[:, :-1] = tokens[:, 1:]
        mask = np.ones_like(tokens, np.float32)
        mask[:, -1] = 0.0
        return {"tokens": tokens, "labels": labels, "mask": mask}


@dataclasses.dataclass(frozen=True)
class ClickLogPipeline:
    n_dense: int
    feature_rows: Tuple[int, ...]
    batch: int
    seed: int = 0

    def __call__(self, step: int) -> Dict[str, Array]:
        rng = _rng(self.seed, step)
        dense = rng.normal(size=(self.batch, self.n_dense)).astype(np.float32)
        sparse = np.stack(
            [
                np.minimum(rng.zipf(1.2, size=self.batch) - 1, rows - 1)
                for rows in self.feature_rows
            ],
            axis=1,
        ).astype(np.int32)
        # planted logistic model over dense feats + a few id buckets
        w = _rng(self.seed, 0).normal(size=self.n_dense)
        logit = dense @ w + 0.3 * ((sparse[:, 0] % 7) - 3)
        prob = 1.0 / (1.0 + np.exp(-logit))
        labels = (rng.random(self.batch) < prob).astype(np.float32)
        return {"dense": dense, "sparse": sparse, "labels": labels}


@dataclasses.dataclass(frozen=True)
class SeqRecPipeline:
    n_items: int
    batch: int
    seq_len: int
    n_negatives: int = 0
    with_candidate: bool = False   # BST mode
    seed: int = 0

    def __call__(self, step: int) -> Dict[str, Array]:
        rng = _rng(self.seed, step)
        # Markov chain: item i tends to transition to (i*13+7) % V
        first = np.minimum(
            rng.zipf(1.3, size=self.batch) - 1, self.n_items - 1
        ).astype(np.int32)
        seq = np.zeros((self.batch, self.seq_len + 1), np.int32)
        seq[:, 0] = first
        for t in range(1, self.seq_len + 1):
            hot = (seq[:, t - 1] * 13 + 7) % self.n_items
            rand = np.minimum(
                rng.zipf(1.3, size=self.batch) - 1, self.n_items - 1
            )
            seq[:, t] = np.where(rng.random(self.batch) < 0.6, hot, rand)
        out: Dict[str, Array] = {"seq": seq[:, :-1]}
        if self.with_candidate:
            # candidate = true next item half the time (label 1), else random
            pos = seq[:, -1]
            neg = rng.integers(0, self.n_items, self.batch).astype(np.int32)
            is_pos = rng.random(self.batch) < 0.5
            out["candidate"] = np.where(is_pos, pos, neg).astype(np.int32)
            out["labels"] = is_pos.astype(np.float32)
        else:
            out["targets"] = seq[:, 1:]
            if self.n_negatives:
                out["negatives"] = rng.integers(
                    0, self.n_items,
                    (self.batch, self.seq_len, self.n_negatives),
                ).astype(np.int32)
        return out
