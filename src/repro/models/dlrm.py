"""DLRM (arXiv:1906.00091): mega-table embeddings + dot interaction + MLPs.

Covers the dlrm-mlperf and dlrm-rm2 assigned configs.  The sparse lookup is
the hot path (see models/embedding.py); the dot interaction is the lower
triangle of Z Z^T over the stacked [bottom-MLP output; 26 embeddings]
matrix, exactly as in the paper.

`retrieval_score` implements the retrieval_cand cell: one user scored
against n_candidates items by varying a single sparse slot — a batched
forward over the candidate axis (sharded over the whole mesh), not a loop.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models import embedding, layers

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str
    n_dense: int
    embed_dim: int
    bot_mlp: Tuple[int, ...]      # includes input dim, e.g. (13, 512, 256, 128)
    top_mlp: Tuple[int, ...]      # hidden dims + 1 output, e.g. (1024, 1024, 512, 256, 1)
    feature_rows: Tuple[int, ...]  # rows per sparse feature
    compute_dtype: Any = jnp.float32
    table_dtype: Any = jnp.float32   # bf16 halves lookup/grad wire at scale

    @property
    def n_sparse(self) -> int:
        return len(self.feature_rows)

    @property
    def table(self) -> embedding.MegaTableConfig:
        return embedding.MegaTableConfig(self.feature_rows, self.embed_dim)

    @property
    def n_interactions(self) -> int:
        f = self.n_sparse + 1
        return f * (f - 1) // 2

    @property
    def top_in(self) -> int:
        return self.bot_mlp[-1] + self.n_interactions

    def param_count(self) -> int:
        n = self.table.total_rows * self.embed_dim
        dims_b = self.bot_mlp
        for i in range(len(dims_b) - 1):
            n += dims_b[i] * dims_b[i + 1] + dims_b[i + 1]
        dims_t = (self.top_in,) + self.top_mlp
        for i in range(len(dims_t) - 1):
            n += dims_t[i] * dims_t[i + 1] + dims_t[i + 1]
        return n


def _init_mlp(key: Array, dims: Sequence[int]) -> Dict[str, Array]:
    p = {}
    ks = jax.random.split(key, len(dims) - 1)
    for i in range(len(dims) - 1):
        p[f"w{i}"] = layers.dense_init(ks[i], (dims[i], dims[i + 1]))
        p[f"b{i}"] = jnp.zeros((dims[i + 1],), jnp.float32)
    return p


def _mlp_logical(dims: Sequence[int]) -> Dict[str, Tuple]:
    p = {}
    for i in range(len(dims) - 1):
        p[f"w{i}"] = ("mlp_in", "mlp_out")
        p[f"b{i}"] = ("mlp_out",)
    return p


def _mlp_fwd(p: Dict[str, Array], x: Array, n: int, final_act: bool) -> Array:
    for i in range(n):
        x = x @ p[f"w{i}"] + p[f"b{i}"]
        if i < n - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def init_params(key: Array, cfg: DLRMConfig) -> Dict[str, Any]:
    kt, kb, ktp = jax.random.split(key, 3)
    return {
        "table": embedding.init_table(kt, cfg.table, dtype=cfg.table_dtype),
        "bot": _init_mlp(kb, cfg.bot_mlp),
        "top": _init_mlp(ktp, (cfg.top_in,) + cfg.top_mlp),
    }


def param_logical(cfg: DLRMConfig) -> Dict[str, Any]:
    return {
        "table": embedding.table_logical(),
        "bot": _mlp_logical(cfg.bot_mlp),
        "top": _mlp_logical((cfg.top_in,) + cfg.top_mlp),
    }


def abstract_params(cfg: DLRMConfig) -> Dict[str, Any]:
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


def _interact(bot_out: Array, sparse: Array) -> Array:
    """Dot interaction: lower triangle of Z Z^T, Z = [bot; embeddings]."""
    z = jnp.concatenate([bot_out[:, None, :], sparse], axis=1)  # (b, f+1, d)
    zz = jnp.einsum("bfd,bgd->bfg", z, z)                       # (b, f+1, f+1)
    f = z.shape[1]
    ii, jj = jnp.tril_indices(f, k=-1)
    return zz[:, ii, jj]                                        # (b, f(f-1)/2)


def forward(
    params: Dict[str, Any],
    dense: Array,     # (b, n_dense) f32
    sparse_ids: Array,  # (b, n_sparse) int32 per-feature local ids
    cfg: DLRMConfig,
) -> Array:
    """Returns CTR logits (b,) f32."""
    cd = cfg.compute_dtype
    bot_out = _mlp_fwd(
        params["bot"], dense.astype(cd), len(cfg.bot_mlp) - 1, final_act=True
    )
    sparse = embedding.lookup(params["table"], sparse_ids, cfg.table)
    inter = _interact(bot_out, sparse.astype(cd))
    top_in = jnp.concatenate([bot_out, inter], axis=-1)
    logits = _mlp_fwd(
        params["top"], top_in, len(cfg.top_mlp), final_act=False
    )
    return logits[:, 0].astype(jnp.float32)


def bce_loss(
    params: Dict[str, Any],
    dense: Array,
    sparse_ids: Array,
    labels: Array,    # (b,) float 0/1
    cfg: DLRMConfig,
) -> Array:
    logits = forward(params, dense, sparse_ids, cfg)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def retrieval_score(
    params: Dict[str, Any],
    dense: Array,          # (n_dense,) one user's dense features
    sparse_ids: Array,     # (n_sparse,) one user's sparse ids
    candidates: Array,     # (n_cand,) candidate ids for sparse slot 0
    cfg: DLRMConfig,
    top_k: int = 100,
) -> Tuple[Array, Array]:
    """Score one user against n_cand items (slot 0 varies). -> (scores, ids)."""
    n = candidates.shape[0]
    dense_b = jnp.broadcast_to(dense[None, :], (n, cfg.n_dense))
    ids_b = jnp.broadcast_to(sparse_ids[None, :], (n, cfg.n_sparse))
    ids_b = ids_b.at[:, 0].set(candidates)
    scores = forward(params, dense_b, ids_b, cfg)
    vals, idx = jax.lax.top_k(scores, top_k)
    return vals, jnp.take(candidates, idx)
