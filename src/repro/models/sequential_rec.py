"""Sequential recommenders: SASRec (arXiv:1808.09781) and BST (1905.06874).

SASRec — causal self-attention over the user's item sequence; next-item
training with sampled softmax (full-vocab softmax at 10^6+ items is neither
the paper's loss nor shippable).  Serving scores the last-position user
state against candidate item embeddings (two-tower style dot product).

BST — Behavior Sequence Transformer: bidirectional attention over
[behavior sequence; candidate item], then an MLP head on the flattened
transformer output produces the CTR logit.

Both share the item mega-table (models/embedding.py) so the retrieval cell
(1 user x 10^6 candidates) is the same sharded gather + batched dot.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import embedding, layers

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SeqRecConfig:
    name: str
    kind: str                 # 'sasrec' | 'bst'
    n_items: int
    embed_dim: int
    seq_len: int
    n_blocks: int
    n_heads: int
    mlp_dims: Tuple[int, ...] = ()   # BST head MLP (hidden dims, out=1 appended)
    d_ff: Optional[int] = None       # pointwise FFN width (default 4*dim... paper uses dim)
    n_negatives: int = 127           # sampled-softmax negatives (training)
    dropout: float = 0.0             # kept for config fidelity; eval path only
    compute_dtype: Any = jnp.float32
    unroll_layers: bool = False      # cost-model mode (see launch/dryrun.py)

    @property
    def ff(self) -> int:
        return self.d_ff if self.d_ff is not None else self.embed_dim

    @property
    def table(self) -> embedding.MegaTableConfig:
        return embedding.MegaTableConfig((self.n_items,), self.embed_dim)

    def param_count(self) -> int:
        d = self.embed_dim
        blk = 4 * d * d + 2 * d * self.ff + 4 * d  # qkvo + ffn + norms
        n = self.n_items * d + self.seq_len * d + self.n_blocks * blk
        if self.kind == "bst":
            dims = ((self.seq_len + 1) * d,) + self.mlp_dims + (1,)
            for i in range(len(dims) - 1):
                n += dims[i] * dims[i + 1] + dims[i + 1]
        return n


def _init_block(key: Array, cfg: SeqRecConfig) -> Dict[str, Array]:
    d = cfg.embed_dim
    ks = jax.random.split(key, 6)
    return {
        "ln1_w": jnp.ones((d,), jnp.float32),
        "ln1_b": jnp.zeros((d,), jnp.float32),
        "wq": layers.dense_init(ks[0], (d, d)),
        "wk": layers.dense_init(ks[1], (d, d)),
        "wv": layers.dense_init(ks[2], (d, d)),
        "wo": layers.dense_init(ks[3], (d, d)),
        "ln2_w": jnp.ones((d,), jnp.float32),
        "ln2_b": jnp.zeros((d,), jnp.float32),
        "w1": layers.dense_init(ks[4], (d, cfg.ff)),
        "b1": jnp.zeros((cfg.ff,), jnp.float32),
        "w2": layers.dense_init(ks[5], (cfg.ff, d)),
        "b2": jnp.zeros((d,), jnp.float32),
    }


def init_params(key: Array, cfg: SeqRecConfig) -> Dict[str, Any]:
    kt, kp, kb, kh = jax.random.split(key, 4)
    total_len = cfg.seq_len + (1 if cfg.kind == "bst" else 0)
    p: Dict[str, Any] = {
        "items": embedding.init_table(kt, cfg.table),
        "pos": layers.embed_init(kp, (total_len, cfg.embed_dim)),
        "blocks": jax.vmap(lambda k: _init_block(k, cfg))(
            jax.random.split(kb, cfg.n_blocks)
        ),
        "final_ln_w": jnp.ones((cfg.embed_dim,), jnp.float32),
        "final_ln_b": jnp.zeros((cfg.embed_dim,), jnp.float32),
    }
    if cfg.kind == "bst":
        dims = ((cfg.seq_len + 1) * cfg.embed_dim,) + cfg.mlp_dims + (1,)
        head = {}
        ks = jax.random.split(kh, len(dims) - 1)
        for i in range(len(dims) - 1):
            head[f"w{i}"] = layers.dense_init(ks[i], (dims[i], dims[i + 1]))
            head[f"b{i}"] = jnp.zeros((dims[i + 1],), jnp.float32)
        p["head"] = head
    return p


def param_logical(cfg: SeqRecConfig) -> Dict[str, Any]:
    blk = {
        "ln1_w": ("layers", None), "ln1_b": ("layers", None),
        "wq": ("layers", "dim", "dim"), "wk": ("layers", "dim", "dim"),
        "wv": ("layers", "dim", "dim"), "wo": ("layers", "dim", "dim"),
        "ln2_w": ("layers", None), "ln2_b": ("layers", None),
        "w1": ("layers", "dim", "mlp_out"), "b1": ("layers", "mlp_out"),
        "w2": ("layers", "mlp_out", "dim"), "b2": ("layers", "dim"),
    }
    p: Dict[str, Any] = {
        "items": embedding.table_logical(),
        "pos": ("seq", "dim"),
        "blocks": blk,
        "final_ln_w": (None,),
        "final_ln_b": (None,),
    }
    if cfg.kind == "bst":
        dims = ((cfg.seq_len + 1) * cfg.embed_dim,) + cfg.mlp_dims + (1,)
        head = {}
        for i in range(len(dims) - 1):
            head[f"w{i}"] = ("mlp_in", "mlp_out")
            head[f"b{i}"] = ("mlp_out",)
        p["head"] = head
    return p


def abstract_params(cfg: SeqRecConfig) -> Dict[str, Any]:
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


# ---------------------------------------------------------------------------
# Transformer encoder over item sequences
# ---------------------------------------------------------------------------


def _encode(
    params: Dict[str, Any],
    seq_ids: Array,            # (b, s) int32, -1 padding
    cfg: SeqRecConfig,
    causal: bool,
    extra: Optional[Array] = None,   # (b, 1, d) appended position (BST target)
) -> Array:
    cd = cfg.compute_dtype
    b, s = seq_ids.shape
    valid = seq_ids >= 0
    safe = jnp.where(valid, seq_ids, 0)
    x = jnp.take(params["items"], safe, axis=0).astype(cd)
    x = x * valid[..., None].astype(cd)
    if extra is not None:
        x = jnp.concatenate([x, extra.astype(cd)], axis=1)
        s = s + 1
    x = x + params["pos"][:s].astype(cd)[None]

    def block(x, p):
        h = layers.layernorm(x, p["ln1_w"], p["ln1_b"])
        q = (h @ p["wq"]).reshape(b, s, cfg.n_heads, -1)
        k = (h @ p["wk"]).reshape(b, s, cfg.n_heads, -1)
        v = (h @ p["wv"]).reshape(b, s, cfg.n_heads, -1)
        attn = layers.flash_attention(
            q, k, v, causal=causal, kv_chunk=min(512, s)
        )
        x = x + attn.reshape(b, s, cfg.embed_dim) @ p["wo"]
        h2 = layers.layernorm(x, p["ln2_w"], p["ln2_b"])
        ff = jax.nn.relu(h2 @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]
        return x + ff, None

    x, _ = jax.lax.scan(
        block, x, params["blocks"], unroll=cfg.unroll_layers or 1
    )
    return layers.layernorm(x, params["final_ln_w"], params["final_ln_b"])


# ---------------------------------------------------------------------------
# SASRec: next-item with sampled softmax
# ---------------------------------------------------------------------------


def sasrec_loss(
    params: Dict[str, Any],
    seq_ids: Array,        # (b, s) history, -1 padding
    targets: Array,        # (b, s) next item at each position, -1 = no loss
    negatives: Array,      # (b, s, n_neg) sampled negative item ids
    cfg: SeqRecConfig,
) -> Array:
    h = _encode(params, seq_ids, cfg, causal=True)         # (b, s, d)
    valid = (targets >= 0).astype(jnp.float32)
    pos_emb = jnp.take(params["items"], jnp.maximum(targets, 0), axis=0)
    neg_emb = jnp.take(params["items"], negatives, axis=0)  # (b, s, n, d)
    pos_logit = jnp.sum(h * pos_emb, axis=-1, keepdims=True)
    neg_logit = jnp.einsum("bsd,bsnd->bsn", h, neg_emb)
    logits = jnp.concatenate([pos_logit, neg_logit], axis=-1)
    # sampled softmax: positive is class 0
    lse = jax.nn.logsumexp(logits, axis=-1)
    nll = (lse - logits[..., 0]) * valid
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1.0)


def sasrec_user_state(
    params: Dict[str, Any], seq_ids: Array, cfg: SeqRecConfig
) -> Array:
    """Last-position hidden state per user -> (b, d)."""
    h = _encode(params, seq_ids, cfg, causal=True)
    return h[:, -1]


def score_candidates(
    params: Dict[str, Any],
    user_state: Array,     # (b, d)
    candidates: Array,     # (n_cand,) item ids
    cfg: SeqRecConfig,
    top_k: int = 100,
) -> Tuple[Array, Array]:
    """Batched dot-product retrieval -> (scores (b, k), ids (b, k))."""
    cand_emb = jnp.take(params["items"], candidates, axis=0)  # (n, d)
    scores = user_state @ cand_emb.T                          # (b, n)
    vals, idx = jax.lax.top_k(scores, top_k)
    return vals, jnp.take(candidates, idx)


# ---------------------------------------------------------------------------
# BST: CTR prediction for (behavior sequence, candidate item)
# ---------------------------------------------------------------------------


def bst_forward(
    params: Dict[str, Any],
    seq_ids: Array,        # (b, s)
    candidate: Array,      # (b,) target item
    cfg: SeqRecConfig,
) -> Array:
    """CTR logits (b,)."""
    cand_emb = jnp.take(params["items"], candidate, axis=0)[:, None, :]
    h = _encode(params, seq_ids, cfg, causal=False, extra=cand_emb)
    b = h.shape[0]
    flat = h.reshape(b, -1)
    x = flat
    n = len(cfg.mlp_dims) + 1
    for i in range(n):
        x = x @ params["head"][f"w{i}"] + params["head"][f"b{i}"]
        if i < n - 1:
            x = jax.nn.leaky_relu(x)
    return x[:, 0].astype(jnp.float32)


def bst_loss(
    params: Dict[str, Any],
    seq_ids: Array,
    candidate: Array,
    labels: Array,         # (b,) 0/1
    cfg: SeqRecConfig,
) -> Array:
    logits = bst_forward(params, seq_ids, candidate, cfg)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
