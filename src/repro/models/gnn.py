"""GIN (Graph Isomorphism Network) via segment-sum message passing.

JAX sparse is BCOO-only, so message passing is implemented directly as an
edge-index gather -> `jax.ops.segment_sum` scatter — the canonical TPU form
(arXiv:1810.00826 GIN; sum aggregator, learnable eps):

    h_v' = MLP((1 + eps) * h_v + sum_{u in N(v)} h_u)

Supports three input regimes behind one forward:
  * full-graph  — (n_nodes, d) features + (2, n_edges) edge index;
  * sampled     — same arrays produced by graphs/sampler.py fanout sampling;
  * batched small graphs — flat node/edge arrays + graph_ids readout.

Distribution: the edge array carries the 'edges' logical axis (sharded over
every mesh axis); segment_sum over sharded edges yields per-device partial
node states that GSPMD combines with one all-reduce per layer.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class GINConfig:
    name: str
    n_layers: int = 5
    d_hidden: int = 64
    d_in: int = 1433
    n_classes: int = 7
    train_eps: bool = True
    readout: Optional[str] = None  # None (node-level) | 'sum' (graph-level)
    compute_dtype: Any = jnp.float32
    unroll_layers: bool = False    # cost-model mode (see launch/dryrun.py)

    def param_count(self) -> int:
        mlp = 2 * self.d_hidden * self.d_hidden + 2 * self.d_hidden
        enc = self.d_in * self.d_hidden + self.d_hidden
        head = self.d_hidden * self.n_classes + self.n_classes
        return enc + self.n_layers * (mlp + 1) + head


def init_params(key: Array, cfg: GINConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)

    def mlp_init(k):
        k1, k2 = jax.random.split(k)
        return {
            "w1": layers.dense_init(k1, (cfg.d_hidden, cfg.d_hidden)),
            "b1": jnp.zeros((cfg.d_hidden,), jnp.float32),
            "w2": layers.dense_init(k2, (cfg.d_hidden, cfg.d_hidden)),
            "b2": jnp.zeros((cfg.d_hidden,), jnp.float32),
            "eps": jnp.zeros((), jnp.float32),
        }

    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    return {
        "encoder": {
            "w": layers.dense_init(ks[1], (cfg.d_in, cfg.d_hidden)),
            "b": jnp.zeros((cfg.d_hidden,), jnp.float32),
        },
        "layers": jax.vmap(mlp_init)(layer_keys),
        "head": {
            "w": layers.dense_init(ks[2], (cfg.d_hidden, cfg.n_classes)),
            "b": jnp.zeros((cfg.n_classes,), jnp.float32),
        },
    }


def param_logical(cfg: GINConfig) -> Dict[str, Any]:
    return {
        "encoder": {"w": ("feat", "hidden"), "b": ("hidden",)},
        "layers": {
            "w1": ("layers", "hidden", "hidden"),
            "b1": ("layers", "hidden"),
            "w2": ("layers", "hidden", "hidden"),
            "b2": ("layers", "hidden"),
            "eps": ("layers",),
        },
        "head": {"w": ("hidden", None), "b": (None,)},
    }


def abstract_params(cfg: GINConfig) -> Dict[str, Any]:
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


def forward(
    params: Dict[str, Any],
    feats: Array,        # (n_nodes, d_in)
    edge_src: Array,     # (n_edges,) int32
    edge_dst: Array,     # (n_edges,) int32
    cfg: GINConfig,
    graph_ids: Optional[Array] = None,   # (n_nodes,) for batched readout
    n_graphs: int = 0,
) -> Array:
    """Returns (n_nodes, n_classes) node logits, or (n_graphs, n_classes)."""
    cd = cfg.compute_dtype
    n_nodes = feats.shape[0]
    h = feats.astype(cd) @ params["encoder"]["w"].astype(cd)
    h = h + params["encoder"]["b"].astype(cd)
    h = jax.nn.relu(h)

    def gin_layer(h, p):
        msgs = jnp.take(h, edge_src, axis=0)                    # (e, d)
        agg = jax.ops.segment_sum(msgs, edge_dst, num_segments=n_nodes)
        z = (1.0 + p["eps"]).astype(cd) * h + agg
        z = jax.nn.relu(z @ p["w1"].astype(cd) + p["b1"].astype(cd))
        z = z @ p["w2"].astype(cd) + p["b2"].astype(cd)
        return jax.nn.relu(z), None

    h, _ = jax.lax.scan(
        gin_layer, h, params["layers"], unroll=cfg.unroll_layers or 1
    )

    if cfg.readout == "sum" and graph_ids is not None:
        h = jax.ops.segment_sum(h, graph_ids, num_segments=n_graphs)

    return (
        h @ params["head"]["w"].astype(cd) + params["head"]["b"].astype(cd)
    ).astype(jnp.float32)


def node_classification_loss(
    params: Dict[str, Any],
    feats: Array,
    edge_src: Array,
    edge_dst: Array,
    labels: Array,       # (n_nodes,) int32
    mask: Array,         # (n_nodes,) — train mask / target-node mask
    cfg: GINConfig,
) -> Array:
    logits = forward(params, feats, edge_src, edge_dst, cfg)
    return layers.cross_entropy_logits(logits, labels, mask.astype(jnp.float32))


def graph_classification_loss(
    params: Dict[str, Any],
    feats: Array,
    edge_src: Array,
    edge_dst: Array,
    graph_ids: Array,
    labels: Array,       # (n_graphs,)
    cfg: GINConfig,
    n_graphs: int,
) -> Array:
    logits = forward(
        params, feats, edge_src, edge_dst, cfg,
        graph_ids=graph_ids, n_graphs=n_graphs,
    )
    mask = jnp.ones((n_graphs,), jnp.float32)
    return layers.cross_entropy_logits(logits, labels, mask)
