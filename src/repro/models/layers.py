"""Shared neural building blocks: RMSNorm, RoPE, flash attention, SwiGLU, CE.

Everything is a pure function over explicit parameter pytrees (no framework
dependency).  Attention is the memory-efficient chunked (flash) form — a
`lax.scan` over KV blocks with an online-softmax carry — so no (seq, seq)
score tensor ever materializes; this is what keeps the 32k-prefill cells
inside HBM and is also the right roofline shape (compute-bound MXU matmuls
over VMEM-resident tiles once XLA fuses the scan body).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms and activations
# ---------------------------------------------------------------------------


def rmsnorm(x: Array, weight: Array, eps: float = 1e-6) -> Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dtype)


def swiglu(gate: Array, up: Array) -> Array:
    return jax.nn.silu(gate) * up


def layernorm(x: Array, weight: Array, bias: Array, eps: float = 1e-6) -> Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (out * weight + bias).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10_000.0) -> Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, freqs: Array) -> Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (.., s, half)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked (flash) attention — scan over KV blocks, online softmax
# ---------------------------------------------------------------------------


def flash_attention(
    q: Array,           # (b, sq, h, dh)
    k: Array,           # (b, skv, kh, dh)
    v: Array,           # (b, skv, kh, dh)
    causal: bool = True,
    q_offset: int = 0,  # absolute position of q[0] (for decode/prefill splits)
    kv_chunk: int = 512,
    scale: Optional[float] = None,
) -> Array:
    """Memory-efficient GQA attention -> (b, sq, h, dh), dtype of q.

    No (sq, skv) tensor is ever materialized; the scan carries
    (m, l, acc) running-softmax state per query position.
    """
    b, sq, h, dh = q.shape
    skv, kh = k.shape[1], k.shape[2]
    group = h // kh
    if scale is None:
        scale = dh ** -0.5
    kv_chunk = min(kv_chunk, skv)
    n_chunks = -(-skv // kv_chunk)
    pad = n_chunks * kv_chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qg = (q.reshape(b, sq, kh, group, dh) * scale).astype(jnp.float32)
    kc = k.reshape(b, n_chunks, kv_chunk, kh, dh)
    vc = v.reshape(b, n_chunks, kv_chunk, kh, dh)
    kc = jnp.moveaxis(kc, 1, 0)  # (n_chunks, b, kv_chunk, kh, dh)
    vc = jnp.moveaxis(vc, 1, 0)

    q_pos = q_offset + jnp.arange(sq)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, c_idx = xs
        kv_pos = c_idx * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum(
            "bqkgd,bjkd->bqkgj", qg, kb.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )  # (b, sq, kh, group, kv_chunk)
        mask = kv_pos[None, :] <= q_pos[:, None] if causal else (
            jnp.ones((sq, kv_chunk), bool)
        )
        mask = mask & (kv_pos < skv)[None, :]
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum(
            "bqkgj,bjkd->bqkgd", p, vb.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, kh, group), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kh, group), jnp.float32)
    a0 = jnp.zeros((b, sq, kh, group, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, sq, h, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def cross_entropy_logits(logits: Array, labels: Array, mask: Array) -> Array:
    """Token-mean CE.  logits (..., v) f32; labels/mask (...)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_softmax_xent(
    hidden: Array,       # (b, s, d) final hidden states
    lm_head: Array,      # (d, v)
    labels: Array,       # (b, s) int32
    mask: Array,         # (b, s)
    chunk: int = 1024,
    n_valid_vocab: Optional[int] = None,  # mask padded vocab columns
) -> Array:
    """CE without materializing (b, s, v) logits: scan over seq chunks.

    The (b, chunk, v) logits chunk is produced, reduced to (lse, ll), and
    dropped before the next chunk — the standard fix for vocab-dominated
    activation memory at 150k vocabularies.
    """
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    n = -(-s // chunk)
    pad = n * chunk - s
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    hc = jnp.moveaxis(hidden.reshape(b, n, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, n, chunk), 1, 0)
    mc = jnp.moveaxis(mask.reshape(b, n, chunk), 1, 0)

    v = lm_head.shape[-1]
    vocab_ok = None
    if n_valid_vocab is not None and n_valid_vocab < v:
        vocab_ok = jnp.arange(v) < n_valid_vocab

    def body(carry, xs):
        total, count = carry
        hb, lb, mb = xs
        logits = (hb @ lm_head).astype(jnp.float32)       # (b, chunk, v)
        if vocab_ok is not None:
            logits = jnp.where(vocab_ok, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # one-hot pick instead of take_along_axis: elementwise + reduce
        # partitions cleanly when the vocab axis is TP-sharded (a gather
        # along a sharded axis forces GSPMD into full rematerialization)
        onehot = jax.nn.one_hot(lb, v, dtype=logits.dtype)
        ll = jnp.sum(logits * onehot, axis=-1)
        nll = (lse - ll) * mb
        return (total + jnp.sum(nll), count + jnp.sum(mb)), None

    (total, count), _ = jax.lax.scan(
        body, (jnp.asarray(0.0, jnp.float32), jnp.asarray(0.0, jnp.float32)),
        (hc, lc, mc),
    )
    return total / jnp.maximum(count, 1.0)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key: Array, shape: Tuple[int, ...], scale: str = "fan_in") -> Array:
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    std = (1.0 / fan_in) ** 0.5
    return jax.random.normal(key, shape, jnp.float32) * std


def embed_init(key: Array, shape: Tuple[int, ...], std: float = 0.02) -> Array:
    return jax.random.normal(key, shape, jnp.float32) * std
