"""Mixture-of-Experts FFN with sort-based capacity dispatch (EP-shardable).

Implements the DeepSeekMoE / granite shape: optional shared experts that see
every token, plus E routed experts with top-k gating.  Dispatch is the
production "dropping" formulation:

  1. top-k routing per token, gate weights renormalized over the selected k;
  2. (token, expert) assignments sorted by expert id; each assignment gets a
     position-in-expert by cumulative count;
  3. assignments beyond per-expert capacity C are dropped (weight mass of
     dropped tokens is simply lost, as in GShard/Switch);
  4. kept tokens are scattered into an (E, C, d) buffer, experts run as one
     batched einsum, results scatter-added back per token.

FLOPs are proportional to the *routed* compute (E x C x d x ff), not to
E x T — this is what makes the MoE cells' roofline numbers honest.  The
(E, C, d) buffer carries the 'experts' logical axis, so EP sharding places
each expert's rows on its owner and XLA lowers the dispatch/return to
all-to-alls across the 'model' axis.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # shared experts (DeepSeekMoE)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01   # load-balance aux loss (Switch)
    # physical expert padding so EP shards evenly (granite: 40 -> 48 over a
    # 16-way axis).  Pad experts' router logits are masked to -inf: they
    # receive no tokens and no gradient.
    pad_experts_to: Optional[int] = None
    # expert-parallel dispatch via shard_map (tokens never migrate; one
    # (t_local, d) psum per layer replaces the GSPMD scatter all-reduce of
    # the whole (E, C, d) buffer — the §Perf hillclimb for the MoE cells)
    ep_shard_map: bool = False

    @property
    def n_experts_padded(self) -> int:
        return self.pad_experts_to or self.n_experts

    def capacity(self, n_tokens: int) -> int:
        c = int(n_tokens * self.top_k * self.capacity_factor / self.n_experts)
        return max(8, -(-c // 8) * 8)  # pad to 8 for clean tiling


def init_moe_params(
    key: Array, d_model: int, cfg: MoEConfig
) -> Dict[str, Array]:
    ks = jax.random.split(key, 5)
    ep = cfg.n_experts_padded
    p = {
        "router": layers.dense_init(ks[0], (d_model, ep)),
        "w_gate": layers.dense_init(ks[1], (ep, d_model, cfg.d_ff_expert)),
        "w_up": layers.dense_init(ks[2], (ep, d_model, cfg.d_ff_expert)),
        "w_down": layers.dense_init(ks[3], (ep, cfg.d_ff_expert, d_model)),
    }
    if cfg.n_shared > 0:
        ff_sh = cfg.n_shared * cfg.d_ff_expert
        ksh = jax.random.split(ks[4], 3)
        p["shared_gate"] = layers.dense_init(ksh[0], (d_model, ff_sh))
        p["shared_up"] = layers.dense_init(ksh[1], (d_model, ff_sh))
        p["shared_down"] = layers.dense_init(ksh[2], (ff_sh, d_model))
    return p


def moe_param_specs(cfg: MoEConfig) -> Dict[str, Tuple]:
    """Logical axis names per parameter (leading 'layers' added by the LM)."""
    p = {
        "router": ("embed", "experts"),
        "w_gate": ("experts", "embed", "expert_mlp"),
        "w_up": ("experts", "embed", "expert_mlp"),
        "w_down": ("experts", "expert_mlp", "embed"),
    }
    if cfg.n_shared > 0:
        p["shared_gate"] = ("embed", "mlp")
        p["shared_up"] = ("embed", "mlp")
        p["shared_down"] = ("mlp", "embed")
    return p


def moe_ffn(
    x: Array,                  # (t, d) flattened tokens
    params: Dict[str, Array],
    cfg: MoEConfig,
) -> Tuple[Array, Array]:
    """Returns (output (t, d), aux_loss scalar)."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = cfg.capacity(t)
    compute_dtype = x.dtype

    e_pad = cfg.n_experts_padded

    # ---- routing ----------------------------------------------------------
    logits = (x.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    if e_pad != e:  # mask pad experts: no tokens, no gradient
        logits = jnp.where(jnp.arange(e_pad) < e, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)                    # (t, e_pad)
    probs = probs[:, :e]
    gate, sel = jax.lax.top_k(probs, k)                        # (t, k)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)

    # Switch-style load-balance aux loss
    density = jnp.mean(
        jax.nn.one_hot(sel[:, 0], e, dtype=jnp.float32), axis=0
    )
    density_proxy = jnp.mean(probs, axis=0)
    aux = cfg.router_aux_weight * e * jnp.sum(density * density_proxy)

    # ---- sort-based dispatch ------------------------------------------------
    # buffers are sized over the PADDED expert count so the expert axis of
    # every array matches the (possibly padded) expert weights; pad experts
    # receive no tokens (their buffer rows stay zero)
    flat_expert = sel.reshape(-1)                              # (t*k,)
    flat_token = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_gate = gate.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    se = flat_expert[order]
    st = flat_token[order]
    sg = flat_gate[order]
    # position of each assignment within its expert segment
    counts = jnp.bincount(se, length=e_pad)                    # (e_pad,)
    seg_start = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]]
    )
    pos = jnp.arange(t * k, dtype=jnp.int32) - jnp.take(seg_start, se).astype(jnp.int32)
    keep = pos < cap
    dest = jnp.where(keep, se * cap + pos, e_pad * cap)        # drop slot at end

    buf = jnp.zeros((e_pad * cap + 1, d), compute_dtype)
    buf = buf.at[dest].add(jnp.take(x, st, axis=0) * keep[:, None].astype(compute_dtype))
    buf = buf[:-1].reshape(e_pad, cap, d)

    # ---- batched expert FFN -------------------------------------------------
    g = jnp.einsum(
        "ecd,edf->ecf", buf, params["w_gate"].astype(compute_dtype),
        preferred_element_type=jnp.float32,
    )
    u = jnp.einsum(
        "ecd,edf->ecf", buf, params["w_up"].astype(compute_dtype),
        preferred_element_type=jnp.float32,
    )
    h = layers.swiglu(g, u).astype(compute_dtype)
    y = jnp.einsum(
        "ecf,efd->ecd", h, params["w_down"].astype(compute_dtype),
        preferred_element_type=jnp.float32,
    ).astype(compute_dtype)                                    # (e, cap, d)

    # ---- combine ------------------------------------------------------------
    y_flat = jnp.concatenate([y.reshape(e_pad * cap, d), jnp.zeros((1, d), y.dtype)])
    contrib = jnp.take(y_flat, dest, axis=0) * (
        sg * keep.astype(jnp.float32)
    )[:, None].astype(y.dtype)
    out = jnp.zeros((t, d), compute_dtype).at[st].add(contrib)

    # ---- shared experts ------------------------------------------------------
    if cfg.n_shared > 0:
        gs = x @ params["shared_gate"].astype(compute_dtype)
        us = x @ params["shared_up"].astype(compute_dtype)
        out = out + layers.swiglu(gs, us) @ params["shared_down"].astype(compute_dtype)

    return out, aux


# ---------------------------------------------------------------------------
# Expert-parallel dispatch via shard_map (the §Perf MoE hillclimb)
#
# Key insight: the token batch is sharded over the DATA axes and replicated
# over 'model', so expert parallelism needs NO token movement at all — each
# model shard routes its (replicated) local tokens, keeps only assignments
# to its own experts, runs them, and one psum of the (t_local, d) partial
# outputs over 'model' combines everything.  The GSPMD baseline instead
# scatters into a replicated (E, C, d) buffer and all-reduces ~16 GB per
# layer; this path all-reduces ~50 MB.
# ---------------------------------------------------------------------------


def moe_ffn_sharded(
    x: Array,                  # (t, d) flattened tokens, sharded over data
    params: Dict[str, Array],
    cfg: MoEConfig,
    mesh,
    model_axis: str = "model",
) -> Tuple[Array, Array]:
    """EP MoE: shard_map over the mesh, experts owned by 'model' shards.

    Requires cfg.n_experts_padded % mesh.shape[model_axis] == 0.
    Shared experts are NOT handled here (caller adds them; they are dense
    TP matmuls).  Returns (out (t, d), aux scalar).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    e, k = cfg.n_experts, cfg.top_k
    e_pad = cfg.n_experts_padded
    n_model = mesh.shape[model_axis]
    assert e_pad % n_model == 0, (e_pad, n_model)
    e_loc = e_pad // n_model
    data_axes = tuple(a for a in mesh.axis_names if a != model_axis)
    dspec = data_axes if len(data_axes) > 1 else (
        data_axes[0] if data_axes else None
    )
    compute_dtype = x.dtype

    def local_fn(x_loc, router, wg, wu, wd):
        t_loc, d = x_loc.shape
        m_idx = jax.lax.axis_index(model_axis)
        cap = cfg.capacity(t_loc)

        logits = x_loc.astype(jnp.float32) @ router.astype(jnp.float32)
        if e_pad != e:
            logits = jnp.where(jnp.arange(e_pad) < e, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)[:, :e]
        gate, sel = jax.lax.top_k(probs, k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

        density = jnp.mean(jax.nn.one_hot(sel[:, 0], e, dtype=jnp.float32), 0)
        density_proxy = jnp.mean(probs, axis=0)
        aux = cfg.router_aux_weight * e * jnp.sum(density * density_proxy)
        aux = jax.lax.pmean(aux, data_axes) if data_axes else aux

        flat_e = sel.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(t_loc, dtype=jnp.int32), k)
        flat_g = gate.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        se, st, sg = flat_e[order], flat_t[order], flat_g[order]
        counts = jnp.bincount(se, length=e_pad)
        seg_start = jnp.concatenate(
            [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]]
        )
        pos = (
            jnp.arange(t_loc * k, dtype=jnp.int32)
            - jnp.take(seg_start, se).astype(jnp.int32)
        )
        own = (se >= m_idx * e_loc) & (se < (m_idx + 1) * e_loc)
        keep = own & (pos < cap)
        local_e = jnp.where(own, se - m_idx * e_loc, 0)
        dest = jnp.where(keep, local_e * cap + pos, e_loc * cap)

        buf = jnp.zeros((e_loc * cap + 1, d), compute_dtype)
        buf = buf.at[dest].add(
            jnp.take(x_loc, st, axis=0) * keep[:, None].astype(compute_dtype)
        )
        buf = buf[:-1].reshape(e_loc, cap, d)

        g = jnp.einsum(
            "ecd,edf->ecf", buf, wg.astype(compute_dtype),
            preferred_element_type=jnp.float32,
        )
        u = jnp.einsum(
            "ecd,edf->ecf", buf, wu.astype(compute_dtype),
            preferred_element_type=jnp.float32,
        )
        h = layers.swiglu(g, u).astype(compute_dtype)
        y = jnp.einsum(
            "ecf,efd->ecd", h, wd.astype(compute_dtype),
            preferred_element_type=jnp.float32,
        ).astype(compute_dtype)

        y_flat = jnp.concatenate(
            [y.reshape(e_loc * cap, d), jnp.zeros((1, d), y.dtype)]
        )
        contrib = jnp.take(y_flat, dest, axis=0) * (
            sg * keep.astype(jnp.float32)
        )[:, None].astype(y.dtype)
        out = jnp.zeros((t_loc, d), compute_dtype).at[st].add(contrib)
        # the ONLY cross-shard traffic: (t_loc, d) partial-output psum
        out = jax.lax.psum(out, model_axis)
        return out, aux

    out, aux = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            P(dspec, None),
            P(),                          # router replicated
            P(model_axis, None, None),    # expert weights EP-sharded
            P(model_axis, None, None),
            P(model_axis, None, None),
        ),
        out_specs=(P(dspec, None), P()),
        check_rep=False,
    )(x, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])
    return out, aux
