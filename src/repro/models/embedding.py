"""Sharded mega-table embeddings for the recsys substrate.

All per-feature tables are concatenated into ONE (total_rows, dim) array
("mega table") with per-feature row offsets — the standard production recsys
layout (a 10^8..10^9-row table that only exists row-sharded).  Two lookup
paths:

  * `lookup`         — plain `jnp.take`; correct under any sharding but lets
    GSPMD choose the comm pattern (fine replicated; may all-gather sharded).
  * `lookup_sharded` — explicit shard_map over the 'model' axis: each shard
    masks ids outside its row range, gathers locally, and one psum combines.
    Traffic per lookup = ids + (batch, dim) partial sums — never the table.
    This is the TPU-native EmbeddingBag the assignment calls out, and it is
    also the access pattern of Pixie's board->pin gathers, which is why the
    recsys substrate and the paper's serving layer share this module.

Multi-hot features pool with segment-sum semantics (kernels/embedding_bag.py
is the Pallas twin of the pooled path).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.models import layers

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MegaTableConfig:
    feature_rows: Tuple[int, ...]   # rows per sparse feature
    dim: int
    pad_to_multiple: int = 512      # row padding so any mesh axis divides

    @property
    def n_features(self) -> int:
        return len(self.feature_rows)

    @property
    def total_rows(self) -> int:
        raw = int(sum(self.feature_rows))
        m = self.pad_to_multiple
        return -(-raw // m) * m

    def offsets(self) -> jnp.ndarray:
        import numpy as np

        return jnp.asarray(
            np.concatenate([[0], np.cumsum(self.feature_rows)[:-1]]),
            jnp.int32,
        )


def init_table(key: Array, cfg: MegaTableConfig, dtype=jnp.float32) -> Array:
    scale = cfg.dim ** -0.5
    return jax.random.normal(key, (cfg.total_rows, cfg.dim), dtype) * scale


def abstract_table(cfg: MegaTableConfig, dtype=jnp.float32):
    return jax.ShapeDtypeStruct((cfg.total_rows, cfg.dim), dtype)


def table_logical() -> Tuple[str, str]:
    return ("rows", "dim")


def global_ids(ids: Array, cfg: MegaTableConfig) -> Array:
    """Per-feature local ids (b, f) -> global mega-table rows."""
    return ids + cfg.offsets()[None, :]


def lookup(table: Array, ids: Array, cfg: MegaTableConfig) -> Array:
    """(b, f) local ids -> (b, f, dim). GSPMD chooses the comm pattern."""
    return jnp.take(table, global_ids(ids, cfg), axis=0)


def lookup_sharded(
    table: Array,
    ids: Array,
    cfg: MegaTableConfig,
    mesh: Mesh,
    *,
    shard_axis: str = "model",
    batch_axes: Tuple[str, ...] = ("data",),
) -> Array:
    """Row-sharded lookup: local masked take + one psum over `shard_axis`.

    table must be sharded P(shard_axis, None) and its row count divisible by
    the axis size; ids (b, f) sharded over batch_axes.
    """
    n_shards = mesh.shape[shard_axis]
    rows_per = cfg.total_rows // n_shards
    batch_spec = tuple(a for a in batch_axes if a in mesh.axis_names)
    bspec = batch_spec if len(batch_spec) > 1 else (
        batch_spec[0] if batch_spec else None
    )

    def local_lookup(local_table, ids_local):
        # which shard owns each row
        rows = global_ids(ids_local, cfg)
        shard_id = jax.lax.axis_index(shard_axis)
        lo = shard_id * rows_per
        mine = (rows >= lo) & (rows < lo + rows_per)
        local_rows = jnp.where(mine, rows - lo, 0)
        vals = jnp.take(local_table, local_rows, axis=0)        # (b, f, d)
        vals = vals * mine[..., None].astype(vals.dtype)
        return jax.lax.psum(vals, axis_name=shard_axis)

    return shard_map(
        local_lookup,
        mesh=mesh,
        in_specs=(P(shard_axis, None), P(bspec, None)),
        out_specs=P(bspec, None, None),
        check_rep=False,
    )(table, ids)


def pooled_lookup(
    table: Array,
    ids: Array,          # (b, f, l) multi-hot ids, -1 padding
    cfg: MegaTableConfig,
    mode: str = "sum",
) -> Array:
    """Multi-hot pooled lookup -> (b, f, dim) (EmbeddingBag semantics)."""
    valid = ids >= 0
    safe = jnp.where(valid, ids, 0) + cfg.offsets()[None, :, None]
    rows = jnp.take(table, safe, axis=0)                 # (b, f, l, d)
    w = valid.astype(table.dtype)[..., None]
    pooled = jnp.sum(rows * w, axis=2)
    if mode == "mean":
        denom = jnp.maximum(jnp.sum(w, axis=2), 1.0)
        pooled = pooled / denom
    return pooled
