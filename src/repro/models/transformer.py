"""Decoder-only LM: GQA + RoPE + RMSNorm + SwiGLU, dense or MoE FFN.

Covers all five assigned LM architectures (qwen2.5-3b, minitron-4b,
smollm-360m, granite-moe-3b-a800m, deepseek-moe-16b) from one config.

Structure notes:
  * **scan over layers** with stacked (L, ...) params — keeps the HLO size
    O(1) in depth (compile-time critical on this host) and gives the remat
    policy a single boundary per layer;
  * **GQA as KV broadcast**: K/V are expanded to the full head count before
    attention so the head axis shards cleanly under Megatron TP (the
    (kh, group) reshape of packed GQA does not partition; the expanded form
    does, and the expansion is local on each shard);
  * **chunked-softmax CE**: the (b, s, 151k-vocab) logits tensor never
    materializes (layers.chunked_softmax_xent);
  * **decode**: one-token serve step against a KV cache; the cache carries
    the 'kv_seq' logical axis so long-context cells shard it along 'model'
    (sequence parallelism — softmax stats are the only cross-shard traffic).

Params are plain nested dicts of f32 arrays; `param_logical()` mirrors the
tree with logical-axis tuples consumed by distribution/sharding.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.moe import (
    MoEConfig, init_moe_params, moe_ffn, moe_ffn_sharded, moe_param_specs,
)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    first_dense_ff: Optional[int] = None  # DeepSeekMoE: layer 0 dense FFN
    norm_eps: float = 1e-6
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    kv_chunk: int = 512
    loss_chunk: int = 1024
    # cost-model mode: unroll depth loops so compiled.cost_analysis() counts
    # every layer (XLA counts while-loop bodies ONCE; see launch/dryrun.py)
    unroll_layers: bool = False
    # physical head padding: jit argument shardings must divide the mesh
    # axis exactly, so archs whose head count doesn't divide 16 (smollm 15,
    # minitron/granite 24) pad Q/O projections to this many heads.  Pad
    # heads are masked out of the attention output (zero contribution,
    # zero gradient); the waste is visible as MODEL_FLOPS/HLO_FLOPs < 1.
    pad_heads_to: Optional[int] = None
    # same for vocab (granite's 49155): pad logits are masked to -inf in
    # the loss and decode paths, so the softmax is exact
    pad_vocab_to: Optional[int] = None
    cache_dtype: Any = jnp.bfloat16   # KV-cache storage dtype

    @property
    def n_heads_padded(self) -> int:
        return self.pad_heads_to or self.n_heads

    @property
    def vocab_padded(self) -> int:
        return self.pad_vocab_to or self.vocab_size

    @property
    def qkv_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Total parameters (for 6ND model-FLOPs accounting)."""
        d, l = self.d_model, self.n_layers
        attn = d * self.qkv_dim + 2 * d * self.kv_dim + self.qkv_dim * d
        if self.qkv_bias:
            attn += self.qkv_dim + 2 * self.kv_dim
        if self.moe is not None:
            m = self.moe
            ffn = d * m.n_experts + 3 * m.n_experts * d * m.d_ff_expert
            if m.n_shared:
                ffn += 3 * d * m.d_ff_expert * m.n_shared
            n_moe = l - (1 if self.first_dense_ff else 0)
            total = n_moe * (attn + ffn + 2 * d)
            if self.first_dense_ff:
                total += attn + 3 * d * self.first_dense_ff + 2 * d
        else:
            ffn = 3 * d * self.d_ff
            total = l * (attn + ffn + 2 * d)
        total += self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += d * self.vocab_size
        total += d  # final norm
        return total

    def physical_param_count(self) -> int:
        """param_count plus padding zeros (actual array elements)."""
        extra_h = self.n_heads_padded - self.n_heads
        per_layer = 2 * self.d_model * extra_h * self.head_dim  # wq + wo
        if self.qkv_bias:
            per_layer += extra_h * self.head_dim
        total = self.param_count() + self.n_layers * per_layer
        extra_v = self.vocab_padded - self.vocab_size
        total += extra_v * self.d_model * (1 if self.tie_embeddings else 2)
        if self.moe is not None:
            extra_e = self.moe.n_experts_padded - self.moe.n_experts
            per_moe_layer = extra_e * (
                self.d_model + 3 * self.d_model * self.moe.d_ff_expert
            )
            n_moe = self.n_layers - (1 if self.first_dense_ff else 0)
            total += n_moe * per_moe_layer
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        d, l, m = self.d_model, self.n_layers, self.moe
        attn = d * self.qkv_dim + 2 * d * self.kv_dim + self.qkv_dim * d
        ffn_act = d * m.n_experts + 3 * m.top_k * d * m.d_ff_expert
        if m.n_shared:
            ffn_act += 3 * d * m.d_ff_expert * m.n_shared
        n_moe = l - (1 if self.first_dense_ff else 0)
        total = n_moe * (attn + ffn_act + 2 * d)
        if self.first_dense_ff:
            total += attn + 3 * d * self.first_dense_ff + 2 * d
        total += self.vocab_size * d
        if not self.tie_embeddings:
            total += d * self.vocab_size
        return total


# ---------------------------------------------------------------------------
# Parameter init + logical specs
# ---------------------------------------------------------------------------


def _init_block(key: Array, cfg: LMConfig) -> Dict[str, Array]:
    ks = jax.random.split(key, 10)
    d = cfg.d_model
    # per-head 3-D projections: the head axis is a real array axis, so TP
    # shards it directly (fused H*dh reshapes break GSPMD propagation when
    # H doesn't divide the axis size; see DESIGN.md hardware-adaptation)
    hp = cfg.n_heads_padded
    p = {
        "ln1": jnp.ones((d,), jnp.float32),
        "wq": layers.dense_init(ks[0], (d, hp, cfg.head_dim)),
        "wk": layers.dense_init(ks[1], (d, cfg.n_kv_heads, cfg.head_dim)),
        "wv": layers.dense_init(ks[2], (d, cfg.n_kv_heads, cfg.head_dim)),
        "wo": layers.dense_init(ks[3], (hp, cfg.head_dim, d)),
        "ln2": jnp.ones((d,), jnp.float32),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hp, cfg.head_dim), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, cfg.head_dim), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, cfg.head_dim), jnp.float32)
    if cfg.moe is not None:
        p["moe"] = init_moe_params(ks[4], d, cfg.moe)
    else:
        p["w_gate"] = layers.dense_init(ks[5], (d, cfg.d_ff))
        p["w_up"] = layers.dense_init(ks[6], (d, cfg.d_ff))
        p["w_down"] = layers.dense_init(ks[7], (cfg.d_ff, d))
    return p


def _block_logical(cfg: LMConfig) -> Dict[str, Tuple]:
    p = {
        "ln1": ("layers", None),
        "wq": ("layers", "embed", "heads", "head_dim"),
        # KV projections are tiny (d x kh x dh); FSDP-sharding their
        # contraction dim makes GSPMD all-reduce (b,s,kh,dh) activations
        # instead of gathering a ~3 MB weight — keep them un-FSDP'd
        "wk": ("layers", "embed_kv", "kv_heads", "head_dim"),
        "wv": ("layers", "embed_kv", "kv_heads", "head_dim"),
        "wo": ("layers", "heads", "head_dim", "embed"),
        "ln2": ("layers", None),
    }
    if cfg.qkv_bias:
        p["bq"] = ("layers", "heads", "head_dim")
        p["bk"] = ("layers", "kv_heads", "head_dim")
        p["bv"] = ("layers", "kv_heads", "head_dim")
    if cfg.moe is not None:
        p["moe"] = {
            k: ("layers",) + v for k, v in moe_param_specs(cfg.moe).items()
        }
    else:
        p["w_gate"] = ("layers", "embed", "mlp")
        p["w_up"] = ("layers", "embed", "mlp")
        p["w_down"] = ("layers", "mlp", "embed")
    return p


def init_params(key: Array, cfg: LMConfig) -> Dict[str, Any]:
    k_embed, k_blocks, k_head, k_d0 = jax.random.split(key, 4)
    n_scan = cfg.n_layers - (1 if cfg.first_dense_ff else 0)
    block_keys = jax.random.split(k_blocks, n_scan)
    params: Dict[str, Any] = {
        "embed": layers.embed_init(k_embed, (cfg.vocab_padded, cfg.d_model)),
        "blocks": jax.vmap(lambda k: _init_block(k, cfg))(block_keys),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(
            k_head, (cfg.d_model, cfg.vocab_padded)
        )
    if cfg.first_dense_ff:
        dense_cfg = dataclasses.replace(
            cfg, moe=None, d_ff=cfg.first_dense_ff
        )
        params["dense0"] = _init_block(k_d0, dense_cfg)
    return params


def param_logical(cfg: LMConfig) -> Dict[str, Any]:
    # the embedding table is 1-D sharded on vocab only: a gather from a
    # table that is ALSO sharded on its feature dim forces GSPMD into full
    # rematerialization (replicate + reshard) on every lookup
    tree: Dict[str, Any] = {
        "embed": ("vocab", None),
        "blocks": _block_logical(cfg),
        "final_norm": (None,),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = (None, "vocab")
    if cfg.first_dense_ff:
        dense_cfg = dataclasses.replace(cfg, moe=None, d_ff=cfg.first_dense_ff)
        d0 = _block_logical(dense_cfg)
        tree["dense0"] = {k: v[1:] for k, v in d0.items()}
    return tree


def abstract_params(cfg: LMConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct pytree — used by the dry-run (no allocation)."""
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _attention(
    p: Dict[str, Array],
    x: Array,                    # (b, s, d) compute dtype
    cfg: LMConfig,
    freqs: Array,
    q_offset: int = 0,
) -> Array:
    b, s, _ = x.shape
    cd = cfg.compute_dtype
    h = layers.rmsnorm(x, p["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    pos = q_offset + jnp.arange(s)
    q = layers.apply_rope(q, jnp.broadcast_to(pos, (b, s)), freqs)
    k = layers.apply_rope(k, jnp.broadcast_to(pos, (b, s)), freqs)
    # GQA -> full (padded) heads; gather, not reshape, stays shardable
    hp = cfg.n_heads_padded
    group = cfg.n_heads // cfg.n_kv_heads
    if group > 1 or hp != cfg.n_kv_heads:
        h2kv = jnp.minimum(jnp.arange(hp) // group, cfg.n_kv_heads - 1)
        k = jnp.take(k, h2kv, axis=2)
        v = jnp.take(v, h2kv, axis=2)
    attn = layers.flash_attention(
        q, k, v, causal=True, q_offset=q_offset, kv_chunk=cfg.kv_chunk
    )
    if hp != cfg.n_heads:  # zero the pad heads (value + gradient)
        mask = (jnp.arange(hp) < cfg.n_heads).astype(attn.dtype)
        attn = attn * mask[None, None, :, None]
    return x + jnp.einsum("bshk,hkd->bsd", attn, p["wo"].astype(cd))


def _ffn(
    p: Dict[str, Array], x: Array, cfg: LMConfig, mesh=None
) -> Tuple[Array, Array]:
    cd = cfg.compute_dtype
    h = layers.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe is not None and "moe" in p:
        b, s, d = h.shape
        flat = h.reshape(b * s, d)
        n_data = 1
        if mesh is not None:
            for a in mesh.axis_names:
                if a != "model":
                    n_data *= mesh.shape[a]
        # EP shard_map needs the token count to split over the data axes;
        # decode at batch 1 falls back to GSPMD dispatch (tiny there)
        use_ep = (
            cfg.moe.ep_shard_map and mesh is not None
            and (b * s) % n_data == 0
        )
        if use_ep:
            out, aux = moe_ffn_sharded(flat, p["moe"], cfg.moe, mesh)
            if cfg.moe.n_shared > 0:  # dense TP matmuls, outside shard_map
                gs = flat @ p["moe"]["shared_gate"].astype(cd)
                us = flat @ p["moe"]["shared_up"].astype(cd)
                out = out + layers.swiglu(gs, us) @ p["moe"][
                    "shared_down"
                ].astype(cd)
        else:
            out, aux = moe_ffn(flat, p["moe"], cfg.moe)
        return x + out.reshape(b, s, d), aux
    g = h @ p["w_gate"].astype(cd)
    u = h @ p["w_up"].astype(cd)
    out = layers.swiglu(g, u) @ p["w_down"].astype(cd)
    return x + out, jnp.asarray(0.0, jnp.float32)


def _block_fwd(p, x, cfg: LMConfig, freqs, q_offset: int = 0, mesh=None):
    x = _attention(p, x, cfg, freqs, q_offset)
    x, aux = _ffn(p, x, cfg, mesh)
    return x, aux


def forward(
    params: Dict[str, Any],
    tokens: Array,             # (b, s) int32
    cfg: LMConfig,
    mesh=None,                 # enables shard_map paths (EP MoE)
) -> Tuple[Array, Array]:
    """Token ids -> final hidden states (b, s, d). Returns (hidden, aux_loss)."""
    cd = cfg.compute_dtype
    freqs = layers.rope_frequencies(cfg.head_dim, cfg.rope_theta)
    # cast BEFORE the gather: the vocab-sharded lookup resolves to a masked
    # partial gather + all-reduce of (tokens, d) — bf16 halves that wire
    x = jnp.take(params["embed"].astype(cd), tokens, axis=0)

    if cfg.first_dense_ff:
        dense_cfg = dataclasses.replace(cfg, moe=None, d_ff=cfg.first_dense_ff)
        x, _ = _block_fwd(params["dense0"], x, dense_cfg, freqs)

    block = lambda p, x: _block_fwd(p, x, cfg, freqs, mesh=mesh)
    if cfg.remat:
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable
        )

    def scan_body(x, p):
        x, aux = block(p, x)
        return x, aux

    x, auxes = jax.lax.scan(
        scan_body, x, params["blocks"], unroll=cfg.unroll_layers or 1
    )
    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, jnp.sum(auxes)


def lm_head_weight(params: Dict[str, Any], cfg: LMConfig) -> Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def loss_fn(
    params: Dict[str, Any],
    tokens: Array,    # (b, s)
    labels: Array,    # (b, s)
    mask: Array,      # (b, s)
    cfg: LMConfig,
    mesh=None,
) -> Array:
    hidden, aux = forward(params, tokens, cfg, mesh=mesh)
    head = lm_head_weight(params, cfg).astype(cfg.compute_dtype)
    ce = layers.chunked_softmax_xent(
        hidden, head, labels, mask, chunk=cfg.loss_chunk,
        n_valid_vocab=cfg.vocab_size,
    )
    return ce + aux


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with KV cache
# ---------------------------------------------------------------------------


def init_kv_cache(
    cfg: LMConfig, batch: int, max_seq: int, dtype=None
) -> Dict[str, Array]:
    dtype = dtype or cfg.cache_dtype
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def abstract_kv_cache(
    cfg: LMConfig, batch: int, max_seq: int, dtype=None
) -> Dict[str, Array]:
    dtype = dtype or cfg.cache_dtype
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    sds = jax.ShapeDtypeStruct
    return {"k": sds(shape, dtype), "v": sds(shape, dtype)}


def kv_cache_logical() -> Dict[str, Tuple]:
    ax = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    return {"k": ax, "v": ax}


def _decode_attention_ref(q, k_cache, v_cache, length, cfg: LMConfig):
    """One-token GQA attention vs cache (jnp oracle; Pallas twin on TPU).

    Gather-expanded form (q heads may be padded beyond kh * group, and the
    expanded head axis shards cleanly under TP).
    """
    b, hp, dh = q.shape
    s, kh = k_cache.shape[1], k_cache.shape[2]
    group = max(cfg.n_heads // cfg.n_kv_heads, 1)
    h2kv = jnp.minimum(jnp.arange(hp) // group, kh - 1)
    ke = jnp.take(k_cache, h2kv, axis=2).astype(jnp.float32)
    ve = jnp.take(v_cache, h2kv, axis=2).astype(jnp.float32)
    scale = dh ** -0.5
    scores = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32), ke) * scale
    mask = jnp.arange(s)[None, None, :] < length
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", probs, ve)


def decode_step(
    params: Dict[str, Any],
    cache: Dict[str, Array],
    tokens: Array,           # (b,) int32 — the newest token per sequence
    pos: Array,              # () int32 — its position (same across batch)
    cfg: LMConfig,
    mesh=None,
) -> Tuple[Array, Dict[str, Array]]:
    """Append one token, return (logits (b, v) f32, updated cache)."""
    cd = cfg.compute_dtype
    b = tokens.shape[0]
    freqs = layers.rope_frequencies(cfg.head_dim, cfg.rope_theta)
    x = jnp.take(params["embed"].astype(cd), tokens, axis=0)[:, None, :]

    blocks = params["blocks"]
    if cfg.first_dense_ff:
        # fold the leading dense block into the scan by treating it separately
        pass

    def one_layer(x, layer_in):
        p, ck, cv = layer_in
        h = layers.rmsnorm(x, p["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(cd))
        k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(cd))
        v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(cd))
        if cfg.qkv_bias:
            q = q + p["bq"].astype(cd)
            k = k + p["bk"].astype(cd)
            v = v + p["bv"].astype(cd)
        posb = jnp.broadcast_to(pos, (b, 1))
        q = layers.apply_rope(q, posb, freqs)
        k = layers.apply_rope(k, posb, freqs)
        ck = jax.lax.dynamic_update_slice(
            ck, k.astype(ck.dtype), (0, pos, 0, 0)
        )
        cv = jax.lax.dynamic_update_slice(
            cv, v.astype(cv.dtype), (0, pos, 0, 0)
        )
        attn = _decode_attention_ref(q[:, 0], ck, cv, pos + 1, cfg)
        hp = cfg.n_heads_padded
        if hp != cfg.n_heads:
            hmask = (jnp.arange(hp) < cfg.n_heads).astype(attn.dtype)
            attn = attn * hmask[None, :, None]
        x = x + jnp.einsum(
            "bshk,hkd->bsd", attn[:, None].astype(cd), p["wo"].astype(cd)
        )
        x, _ = _ffn(p, x, cfg, mesh)
        return x, (ck, cv)

    if cfg.first_dense_ff:
        dense_cfg = dataclasses.replace(cfg, moe=None, d_ff=cfg.first_dense_ff)
        x, (ck0, cv0) = one_layer(
            x, (params["dense0"], cache["k"][0], cache["v"][0])
        )
        scan_blocks, ck_rest, cv_rest = blocks, cache["k"][1:], cache["v"][1:]
    else:
        scan_blocks, ck_rest, cv_rest = blocks, cache["k"], cache["v"]

    x, (new_k, new_v) = jax.lax.scan(
        one_layer, x, (scan_blocks, ck_rest, cv_rest),
        unroll=cfg.unroll_layers or 1,
    )
    if cfg.first_dense_ff:
        new_k = jnp.concatenate([ck0[None], new_k])
        new_v = jnp.concatenate([cv0[None], new_v])

    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = (
        x[:, 0] @ lm_head_weight(params, cfg).astype(cd)
    ).astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab_size:
        logits = jnp.where(
            jnp.arange(cfg.vocab_padded) < cfg.vocab_size, logits, -1e30
        )
    return logits, {"k": new_k, "v": new_v}


def prefill(
    params: Dict[str, Any],
    tokens: Array,            # (b, s)
    cfg: LMConfig,
    max_seq: Optional[int] = None,
    mesh=None,
) -> Tuple[Array, Dict[str, Array]]:
    """Run the prompt, build the KV cache. Returns (last-token logits, cache).

    The cache layout matches decode_step; padding beyond s is zeros.
    """
    cd = cfg.compute_dtype
    b, s = tokens.shape
    if max_seq is None:
        max_seq = s
    freqs = layers.rope_frequencies(cfg.head_dim, cfg.rope_theta)
    x = jnp.take(params["embed"].astype(cd), tokens, axis=0)

    def block_kv(p, x, block_cfg):
        h = layers.rmsnorm(x, p["ln1"], block_cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(cd))
        k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(cd))
        v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(cd))
        if block_cfg.qkv_bias:
            q = q + p["bq"].astype(cd)
            k = k + p["bk"].astype(cd)
            v = v + p["bv"].astype(cd)
        posb = jnp.broadcast_to(jnp.arange(s), (b, s))
        q = layers.apply_rope(q, posb, freqs)
        k = layers.apply_rope(k, posb, freqs)
        hp = block_cfg.n_heads_padded
        group = block_cfg.n_heads // block_cfg.n_kv_heads
        if group > 1 or hp != block_cfg.n_kv_heads:
            h2kv = jnp.minimum(
                jnp.arange(hp) // group, block_cfg.n_kv_heads - 1
            )
            ke = jnp.take(k, h2kv, axis=2)
            ve = jnp.take(v, h2kv, axis=2)
        else:
            ke, ve = k, v
        attn = layers.flash_attention(
            q, ke, ve, causal=True, kv_chunk=block_cfg.kv_chunk
        )
        if hp != block_cfg.n_heads:
            hmask = (jnp.arange(hp) < block_cfg.n_heads).astype(attn.dtype)
            attn = attn * hmask[None, None, :, None]
        x = x + jnp.einsum("bshk,hkd->bsd", attn, p["wo"].astype(cd))
        x, _ = _ffn(p, x, block_cfg, mesh)
        pad = ((0, 0), (0, max_seq - s), (0, 0), (0, 0))
        return x, (
            jnp.pad(k, pad).astype(block_cfg.cache_dtype),
            jnp.pad(v, pad).astype(block_cfg.cache_dtype),
        )

    if cfg.first_dense_ff:
        dense_cfg = dataclasses.replace(cfg, moe=None, d_ff=cfg.first_dense_ff)
        x, (ck0, cv0) = block_kv(params["dense0"], x, dense_cfg)

    def scan_body(x, p):
        return block_kv(p, x, cfg)

    if cfg.remat:
        scan_body = jax.checkpoint(
            scan_body, policy=jax.checkpoint_policies.nothing_saveable
        )
    x, (new_k, new_v) = jax.lax.scan(
        scan_body, x, params["blocks"], unroll=cfg.unroll_layers or 1
    )
    if cfg.first_dense_ff:
        new_k = jnp.concatenate([ck0[None], new_k])
        new_v = jnp.concatenate([cv0[None], new_v])

    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = (
        x[:, -1] @ lm_head_weight(params, cfg).astype(cd)
    ).astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab_size:
        logits = jnp.where(
            jnp.arange(cfg.vocab_padded) < cfg.vocab_size, logits, -1e30
        )
    return logits, {"k": new_k, "v": new_v}
