"""Logical-axis sharding: one rule table maps model-space names to mesh axes.

Models annotate every parameter / activation dimension with a *logical* name
('embed', 'heads', 'mlp', 'vocab', 'experts', 'batch', 'kv_seq', 'rows', …).
A RuleSet maps logical names to physical mesh axes; `spec(...)` resolves a
tuple of logical names to a PartitionSpec.  Swapping the whole distribution
strategy (pure DP, Megatron TP, FSDP, EP, sequence-parallel decode) is a
rule-table edit, not a model edit — this is what makes the §Perf hillclimb
iterations one-line changes.

Mesh conventions (launch/mesh.py):
  single-pod: (data=16, model=16)           axes ('data', 'model')
  multi-pod:  (pod=2, data=16, model=16)    axes ('pod', 'data', 'model')

The 'pod' axis is pure data parallelism: everything latency-critical stays
intra-pod (the paper's "walk never crosses machines", one level up).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class RuleSet:
    """Logical axis name -> mesh axes (None = replicate)."""

    rules: Dict[str, Axes]

    def axes_for(self, name: Optional[str], mesh: Mesh) -> Axes:
        if name is None:
            return None
        ax = self.rules.get(name)
        if ax is None:
            return None
        if isinstance(ax, str):
            ax = (ax,)
        # drop axes the mesh doesn't have (e.g. 'pod' on the single-pod mesh)
        present = tuple(a for a in ax if a in mesh.axis_names)
        if not present:
            return None
        return present if len(present) > 1 else present[0]

    def spec(self, logical: Tuple[Optional[str], ...], mesh: Mesh) -> P:
        return P(*(self.axes_for(name, mesh) for name in logical))

    def sharding(
        self, logical: Tuple[Optional[str], ...], mesh: Mesh
    ) -> NamedSharding:
        return NamedSharding(mesh, self.spec(logical, mesh))

    def tree_specs(self, logical_tree, mesh: Mesh):
        """Map a pytree of logical-name tuples to a pytree of PartitionSpecs."""
        return jax.tree.map(
            lambda names: self.spec(names, mesh),
            logical_tree,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(n is None or isinstance(n, str) for n in x),
        )

    def with_overrides(self, **kv: Axes) -> "RuleSet":
        new = dict(self.rules)
        new.update(kv)
        return RuleSet(new)


# ---------------------------------------------------------------------------
# Default rule tables per model family
# ---------------------------------------------------------------------------

# Megatron-style TP on 'model' + DP/FSDP on ('pod','data') for LM training.
LM_TRAIN_RULES = RuleSet({
    "batch": ("pod", "data"),
    "seq": None,
    "embed": "data",          # FSDP: gather params per layer inside scan
    "embed_kv": None,         # see transformer._block_logical
    "heads": "model",         # TP: attention heads
    "kv_heads": None,         # small GQA kv counts don't divide 16; replicate
    "head_dim": None,
    "mlp": "model",           # TP: FFN hidden
    "vocab": "model",         # TP: output projection + embedding
    "experts": "model",       # EP: routed experts
    "expert_mlp": None,
    "capacity": None,
    "layers": None,
    "kv_seq": None,
})

# Decode: batch over data, KV sequence over model (sequence parallelism).
LM_SERVE_RULES = RuleSet({
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "embed_kv": None,
    "heads": "model",
    "kv_heads": None,
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_mlp": None,
    "capacity": None,
    "layers": None,
    "kv_seq": "model",        # long-context KV cache sharded along sequence
})

# GNN: edges across every device; node state replicated (baseline).
GNN_RULES = RuleSet({
    "edges": ("pod", "data", "model"),
    "nodes": None,
    "feat": None,
    "hidden": None,
    "batch": ("pod", "data"),
    "layers": None,
})

# RecSys: mega embedding table row-sharded on 'model', MLPs data-parallel.
RECSYS_RULES = RuleSet({
    "batch": ("pod", "data"),
    "rows": "model",          # embedding-table rows
    "dim": None,
    "features": None,
    "mlp_in": None,
    "mlp_out": None,
    "seq": None,
    "heads": None,
    "candidates": "model",    # retrieval scoring: candidate axis
    "layers": None,
})

# Pixie graph serving: CSR arrays node-range-sharded on 'model',
# query batch on ('pod','data').
PIXIE_RULES = RuleSet({
    "batch": ("pod", "data"),
    "graph_nodes": "model",
    "graph_edges": "model",
    "slots": None,
    "walkers": None,
    "pins": None,
})


def param_shardings(logical_tree, rules: RuleSet, mesh: Mesh):
    """Pytree of NamedShardings from a pytree of logical-name tuples."""
    return jax.tree.map(
        lambda names: rules.sharding(names, mesh),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(n is None or isinstance(n, str) for n in x),
    )
