"""gin-tu [arXiv:1810.00826]: 5 layers, d_hidden=64, sum aggregator,
learnable eps — the TU-benchmark GIN config."""

from repro.configs.registry import ArchSpec, GNN_SHAPES, register
from repro.models.gnn import GINConfig

# d_in / n_classes are shape-cell properties for GNNs; the registry config
# carries the architecture (depth/width/aggregator) and the dry-run builder
# specializes d_in per cell.
FULL = GINConfig(
    name="gin-tu",
    n_layers=5,
    d_hidden=64,
    d_in=1433,       # overridden per shape cell
    n_classes=7,
    train_eps=True,
)

SMOKE = GINConfig(
    name="gin-tu-smoke",
    n_layers=3,
    d_hidden=16,
    d_in=32,
    n_classes=3,
    train_eps=True,
)


@register("gin-tu")
def spec() -> ArchSpec:
    return ArchSpec(
        name="gin-tu",
        family="gnn",
        source="arXiv:1810.00826",
        config=FULL,
        smoke_config=SMOKE,
        shapes=GNN_SHAPES,
    )
