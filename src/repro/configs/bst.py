"""bst [arXiv:1905.06874]: Behavior Sequence Transformer (Alibaba) —
dim=32, seq_len=20, 1 block, 8 heads, MLP head 1024-512-256.
Item catalog sized at 10M."""

from repro.configs.registry import ArchSpec, RECSYS_SHAPES, register
from repro.models.sequential_rec import SeqRecConfig

FULL = SeqRecConfig(
    name="bst",
    kind="bst",
    n_items=10_000_000,
    embed_dim=32,
    seq_len=20,
    n_blocks=1,
    n_heads=8,
    mlp_dims=(1024, 512, 256),
)

SMOKE = SeqRecConfig(
    name="bst-smoke",
    kind="bst",
    n_items=500,
    embed_dim=16,
    seq_len=8,
    n_blocks=1,
    n_heads=4,
    mlp_dims=(32, 16),
)


@register("bst")
def spec() -> ArchSpec:
    return ArchSpec(
        name="bst",
        family="recsys",
        source="arXiv:1905.06874",
        config=FULL,
        smoke_config=SMOKE,
        shapes=RECSYS_SHAPES,
    )
