"""deepseek-moe-16b [arXiv:2401.06066]: 28L d=2048 16H (MHA kv=16)
expert-ff=1408 vocab=102400 — 2 shared + 64 routed experts top-6,
fine-grained segmentation; layer 0 is a dense FFN (d_ff=10944)."""

import jax.numpy as jnp

from repro.configs.registry import ArchSpec, LM_SHAPES, register
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="deepseek-moe-16b",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    rope_theta=10_000.0,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
                  ep_shard_map=True),
    first_dense_ff=10944,
)

SMOKE = LMConfig(
    name="deepseek-moe-smoke",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=48,
    vocab_size=512,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=48, n_shared=2),
    first_dense_ff=96,
    remat=False,
    compute_dtype=jnp.float32,
)


@register("deepseek-moe-16b")
def spec() -> ArchSpec:
    return ArchSpec(
        name="deepseek-moe-16b",
        family="lm",
        source="arXiv:2401.06066",
        config=FULL,
        smoke_config=SMOKE,
        shapes=LM_SHAPES,
    )
