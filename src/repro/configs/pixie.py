"""pixie — the paper's own architecture as an 11th config (beyond the 40
assigned cells): the Pixie random-walk recommender at production scale.

  * serve_3b_sharded   — the paper's deployed scale: 3B nodes (2B pins +
    1B boards) / 17B edges, node-range-sharded across the 'model' axis of
    one pod; walkers migrate over ICI (core/distributed.py).
  * serve_200m_replicated — a replicated-graph configuration that fits a
    single 16 GB chip (the paper's single-machine regime, scaled to HBM).
"""

import dataclasses
from typing import Tuple

from repro.configs.registry import ArchSpec, ShapeCell, register
from repro.core.distributed import ShardedWalkConfig
from repro.core.walk import WalkConfig


@dataclasses.dataclass(frozen=True)
class PixieArchConfig:
    n_pins: int
    n_boards: int
    n_edges: int
    walk: WalkConfig
    sharded_walk: ShardedWalkConfig
    n_slots: int = 16


FULL = PixieArchConfig(
    n_pins=2_000_000_000,
    n_boards=1_000_000_000,
    n_edges=17_000_000_000,
    walk=WalkConfig(n_steps=200_000, n_walkers=8192, top_k=1000),
    # 24 supersteps x 16 shards x 512 walkers ~ the paper's 200k-step
    # budget per query; fat supersteps minimize all_to_all rounds
    # (EXPERIMENTS.md §Perf pixie iteration 2)
    sharded_walk=ShardedWalkConfig(
        n_supersteps=24, walkers_per_shard=512, top_k=1000
    ),
)

SMOKE = PixieArchConfig(
    n_pins=300,
    n_boards=80,
    n_edges=1500,
    walk=WalkConfig(n_steps=20_000, n_walkers=256, top_k=50),
    sharded_walk=ShardedWalkConfig(
        n_supersteps=32, walkers_per_shard=128, top_k=50
    ),
    n_slots=4,
)

PIXIE_SHAPES = (
    ShapeCell(
        "serve_3b_sharded", "pixie_sharded",
        {"n_pins": FULL.n_pins, "n_boards": FULL.n_boards,
         "n_edges": FULL.n_edges},
        note="paper production scale; graph sharded over 'model', queries "
        "over ('pod','data')",
    ),
    ShapeCell(
        "serve_200m_replicated", "pixie_replicated",
        {"n_pins": 140_000_000, "n_boards": 60_000_000,
         "n_edges": 1_200_000_000, "n_slots": 8},
        note="largest graph that replicates into one 16 GB chip (int32 CSR "
        "~10.6 GB); the paper's single-machine serving regime. 8 query "
        "slots keep packed (slot, pin) events in int32",
    ),
)


@register("pixie")
def spec() -> ArchSpec:
    return ArchSpec(
        name="pixie",
        family="pixie",
        source="this paper (Eksombatchai et al., 2017)",
        config=FULL,
        smoke_config=SMOKE,
        shapes=PIXIE_SHAPES,
    )
