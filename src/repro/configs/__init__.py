"""Importing this package registers every architecture config."""

from repro.configs import (  # noqa: F401
    bst,
    deepseek_moe_16b,
    dlrm_mlperf,
    dlrm_rm2,
    gin_tu,
    granite_moe_3b_a800m,
    minitron_4b,
    pixie,
    qwen2_5_3b,
    sasrec,
    smollm_360m,
)
from repro.configs.registry import (  # noqa: F401
    ArchSpec,
    ShapeCell,
    all_archs,
    get_arch,
)
