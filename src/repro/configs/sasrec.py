"""sasrec [arXiv:1808.09781]: dim=50, 2 blocks, 1 head, seq_len=50,
causal self-attention over the user sequence.  Item catalog sized at 10M
(production-representative; the paper's datasets are small)."""

from repro.configs.registry import ArchSpec, RECSYS_SHAPES, register
from repro.models.sequential_rec import SeqRecConfig

FULL = SeqRecConfig(
    name="sasrec",
    kind="sasrec",
    n_items=10_000_000,
    embed_dim=50,
    seq_len=50,
    n_blocks=2,
    n_heads=1,
    n_negatives=127,
)

SMOKE = SeqRecConfig(
    name="sasrec-smoke",
    kind="sasrec",
    n_items=500,
    embed_dim=16,
    seq_len=12,
    n_blocks=2,
    n_heads=1,
    n_negatives=8,
)


@register("sasrec")
def spec() -> ArchSpec:
    return ArchSpec(
        name="sasrec",
        family="recsys",
        source="arXiv:1808.09781",
        config=FULL,
        smoke_config=SMOKE,
        shapes=RECSYS_SHAPES,
    )
