"""smollm-360m [hf:HuggingFaceTB/SmolLM-360M]: 32L d=960 15H (GQA kv=5)
ff=2560 vocab=49152 — llama-arch small model."""

import jax.numpy as jnp

from repro.configs.registry import ArchSpec, LM_SHAPES, register
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="smollm-360m",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    rope_theta=10_000.0,
    tie_embeddings=True,
    pad_heads_to=16,
)

SMOKE = LMConfig(
    name="smollm-360m-smoke",
    n_layers=2,
    d_model=60,
    n_heads=3,
    n_kv_heads=1,
    head_dim=20,
    d_ff=128,
    vocab_size=512,
    tie_embeddings=True,
    remat=False,
    compute_dtype=jnp.float32,
)


@register("smollm-360m")
def spec() -> ArchSpec:
    return ArchSpec(
        name="smollm-360m",
        family="lm",
        source="hf:HuggingFaceTB/SmolLM-360M",
        config=FULL,
        smoke_config=SMOKE,
        shapes=LM_SHAPES,
    )
