"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0-3b-a800m-base]:
32L d=1536 24H (GQA kv=8) expert-ff=512 vocab=49155, MoE 40 experts top-8.

40 experts do not divide the 16-way 'model' axis; experts are padded to
48 (3/device) for expert parallelism with the shard_map dispatch — pad
experts are router-masked (no tokens, no gradients).  The §Perf log
records the earlier TP-inside-expert baseline this replaced.
"""

import jax.numpy as jnp

from repro.configs.registry import ArchSpec, LM_SHAPES, register
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="granite-moe-3b-a800m",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    rope_theta=10_000.0,
    tie_embeddings=True,
    pad_heads_to=32,
    pad_vocab_to=49168,
    moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512, n_shared=0,
                  pad_experts_to=48, ep_shard_map=True),
)

SMOKE = LMConfig(
    name="granite-moe-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=64,
    vocab_size=512,
    tie_embeddings=True,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64, n_shared=0),
    remat=False,
    compute_dtype=jnp.float32,
)


@register("granite-moe-3b-a800m")
def spec() -> ArchSpec:
    return ArchSpec(
        name="granite-moe-3b-a800m",
        family="lm",
        source="hf:ibm-granite/granite-3.0-3b-a800m-base",
        config=FULL,
        smoke_config=SMOKE,
        shapes=LM_SHAPES,
        # EP over 48 padded experts (see MoEConfig.pad_experts_to)
    )
