"""dlrm-mlperf [arXiv:1906.00091]: MLPerf DLRM benchmark config
(Criteo 1TB): 13 dense + 26 sparse, dim=128, bot 13-512-256-128,
top 1024-1024-512-256-1, dot interaction, ~188M embedding rows."""

from repro.configs.registry import ArchSpec, CRITEO_ROWS, RECSYS_SHAPES, register
import jax.numpy as jnp

from repro.models.dlrm import DLRMConfig

FULL = DLRMConfig(
    name="dlrm-mlperf",
    n_dense=13,
    embed_dim=128,
    bot_mlp=(13, 512, 256, 128),
    top_mlp=(1024, 1024, 512, 256, 1),
    feature_rows=CRITEO_ROWS,
    table_dtype=jnp.bfloat16,
)

SMOKE = DLRMConfig(
    name="dlrm-mlperf-smoke",
    n_dense=13,
    embed_dim=16,
    bot_mlp=(13, 32, 16),
    top_mlp=(64, 32, 1),
    feature_rows=tuple([100] * 26),
)


@register("dlrm-mlperf")
def spec() -> ArchSpec:
    return ArchSpec(
        name="dlrm-mlperf",
        family="recsys",
        source="arXiv:1906.00091 (MLPerf config)",
        config=FULL,
        smoke_config=SMOKE,
        shapes=RECSYS_SHAPES,
    )
