"""dlrm-rm2 [arXiv:1906.00091]: the RM2 variant — dim=64,
bot 13-512-256-64, top 512-512-256-1, dot interaction."""

from repro.configs.registry import ArchSpec, CRITEO_ROWS, RECSYS_SHAPES, register
import jax.numpy as jnp

from repro.models.dlrm import DLRMConfig

FULL = DLRMConfig(
    name="dlrm-rm2",
    n_dense=13,
    embed_dim=64,
    bot_mlp=(13, 512, 256, 64),
    top_mlp=(512, 512, 256, 1),
    feature_rows=CRITEO_ROWS,
    table_dtype=jnp.bfloat16,
)

SMOKE = DLRMConfig(
    name="dlrm-rm2-smoke",
    n_dense=13,
    embed_dim=8,
    bot_mlp=(13, 32, 8),
    top_mlp=(32, 16, 1),
    feature_rows=tuple([64] * 26),
)


@register("dlrm-rm2")
def spec() -> ArchSpec:
    return ArchSpec(
        name="dlrm-rm2",
        family="recsys",
        source="arXiv:1906.00091",
        config=FULL,
        smoke_config=SMOKE,
        shapes=RECSYS_SHAPES,
    )
