"""minitron-4b [arXiv:2407.14679]: 32L d=3072 24H (GQA kv=8) ff=9216
vocab=256000 — width-pruned Nemotron-4."""

import jax.numpy as jnp

from repro.configs.registry import ArchSpec, LM_SHAPES, register
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="minitron-4b",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    rope_theta=10_000.0,
    pad_heads_to=32,
)

SMOKE = LMConfig(
    name="minitron-4b-smoke",
    n_layers=2,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab_size=512,
    remat=False,
    compute_dtype=jnp.float32,
)


@register("minitron-4b")
def spec() -> ArchSpec:
    return ArchSpec(
        name="minitron-4b",
        family="lm",
        source="arXiv:2407.14679",
        config=FULL,
        smoke_config=SMOKE,
        shapes=LM_SHAPES,
        # 24 heads over the 16-way 'model' axis: GSPMD pads to 32 slots
        # (25% attention waste, recorded in the roofline notes).
    )
