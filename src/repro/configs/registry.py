"""Architecture registry: --arch <id> resolves here.

Each arch module contributes an ArchSpec: the exact published full config,
a reduced smoke config (CPU-runnable), its shape cells, and optional
per-arch sharding rule overrides (e.g. granite's 40 experts don't divide a
16-way 'model' axis, so granite uses TP *inside* experts instead of EP).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str        # 'train' | 'prefill' | 'decode' | 'serve' | 'retrieval'
    params: Dict[str, Any]
    note: str = ""


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str                       # 'lm' | 'gnn' | 'recsys' | 'pixie'
    source: str                       # citation from the assignment
    config: Any
    smoke_config: Any
    shapes: Tuple[ShapeCell, ...]
    train_rule_overrides: Dict[str, Any] = dataclasses.field(
        default_factory=dict
    )
    serve_rule_overrides: Dict[str, Any] = dataclasses.field(
        default_factory=dict
    )


_REGISTRY: Dict[str, Callable[[], ArchSpec]] = {}


def register(name: str):
    def deco(fn: Callable[[], ArchSpec]):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_arch(name: str) -> ArchSpec:
    if name not in _REGISTRY:
        # import side-effect registration
        from repro import configs  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(_REGISTRY)}"
        )
    return _REGISTRY[name]()


def all_archs() -> Tuple[str, ...]:
    from repro import configs  # noqa: F401

    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# Shared shape-cell tables
# ---------------------------------------------------------------------------

LM_SHAPES = (
    ShapeCell("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    ShapeCell("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
    ShapeCell("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
    ShapeCell(
        "long_500k", "decode", {"seq_len": 524288, "global_batch": 1},
        note="decode vs 524k KV cache is O(seq) (flash-decode, seq-sharded); "
        "runnable for full-attention archs. 500k *prefill* would be "
        "quadratic but is not an assigned cell.",
    ),
)

RECSYS_SHAPES = (
    ShapeCell("train_batch", "train", {"batch": 65536}),
    ShapeCell("serve_p99", "serve", {"batch": 512}),
    ShapeCell("serve_bulk", "serve", {"batch": 262144}),
    ShapeCell(
        "retrieval_cand", "retrieval", {"batch": 1, "n_candidates": 1_000_000}
    ),
)

GNN_SHAPES = (
    ShapeCell(
        "full_graph_sm", "train",
        {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433, "n_classes": 7},
    ),
    ShapeCell(
        "minibatch_lg", "train",
        {
            "n_nodes": 232_965, "n_edges": 114_615_892,
            "batch_nodes": 1024, "fanout": (15, 10),
            "d_feat": 602, "n_classes": 41,
        },
        note="fixed-fanout sampled subgraph (graphs/sampler.py); the jitted "
        "step sees the padded block shape, never the full graph",
    ),
    ShapeCell(
        "ogb_products", "train",
        {
            "n_nodes": 2_449_029, "n_edges": 61_859_140,
            "d_feat": 100, "n_classes": 47,
        },
    ),
    ShapeCell(
        "molecule", "train",
        {"n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 16,
         "n_classes": 2},
    ),
)

# MLPerf DLRM (Criteo 1TB, uncapped) per-feature embedding rows.
CRITEO_ROWS = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63,
    38532951, 2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14,
    39979771, 25641295, 39664984, 585935, 12972, 108, 36,
)
