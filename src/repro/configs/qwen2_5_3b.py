"""qwen2.5-3b [hf:Qwen/Qwen2.5-3B]: 36L d=2048 16H (GQA kv=2) ff=11008
vocab=151936 — GQA with QKV bias, tied embeddings, rope theta 1e6."""

import jax.numpy as jnp

from repro.configs.registry import ArchSpec, LM_SHAPES, register
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="qwen2.5-3b",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab_size=151936,
    rope_theta=1_000_000.0,
    qkv_bias=True,
    tie_embeddings=True,
)

SMOKE = LMConfig(
    name="qwen2.5-3b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    rope_theta=1_000_000.0,
    qkv_bias=True,
    tie_embeddings=True,
    remat=False,
    compute_dtype=jnp.float32,
)


@register("qwen2.5-3b")
def spec() -> ArchSpec:
    return ArchSpec(
        name="qwen2.5-3b",
        family="lm",
        source="hf:Qwen/Qwen2.5-3B",
        config=FULL,
        smoke_config=SMOKE,
        shapes=LM_SHAPES,
    )
