"""The production distribution mode: Pixie on a graph too big for one chip.

Spawns 8 fake devices, shards the graph over a 4-way 'model' axis, and runs
the pod-sharded batched fused walk engine (core/distributed.py) — the same
program the multi-pod dry-run lowers at 3B-node scale.  Must be a fresh
process (device count locks at first jax init), hence the XLA_FLAGS lines
first.

  PYTHONPATH=src python examples/sharded_walk.py
"""

import os
if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed as D
from repro.core import walk as W
from repro.graphs.synthetic import SyntheticGraphConfig, generate
from repro.launch.mesh import make_mesh_compat, set_mesh_compat

def main(
    n_pins: int = 8_000,
    n_boards: int = 800,
    n_shards: int = 4,
    mesh_shape: tuple = (2, 4),
    n_supersteps: int = 48,
    walkers_per_shard: int = 256,
    top_k: int = 15,
    slack: float = 8.0,
):
    """Run the sharded walk demo; parameters shrink it to a smoke test
    (tests/test_examples.py runs a 1-shard single-device configuration
    through this same path).  Returns (overlap, dropped)."""
    sg = generate(SyntheticGraphConfig(n_pins=n_pins, n_boards=n_boards,
                                       seed=3))
    mesh = make_mesh_compat(mesh_shape, ("data", "model")[-len(mesh_shape):])
    shg = D.shard_graph(sg.graph, n_shards)
    print(f"graph sharded {n_shards} ways: {shg.pins_per_shard} pins/shard, "
          f"{shg.boards_per_shard} boards/shard")

    degs = np.asarray(sg.graph.p2b.degrees())
    qs = np.argsort(-degs)[:3]
    qp = jnp.asarray([int(qs[0]), int(qs[1]), int(qs[2]), -1], jnp.int32)
    qw = jnp.asarray([1.0, 0.8, 0.5, 0.0], jnp.float32)

    cfg = D.ShardedWalkConfig(
        n_supersteps=n_supersteps, walkers_per_shard=walkers_per_shard,
        top_k=top_k, slack=slack,
    )
    with set_mesh_compat(mesh):
        res = D.pixie_walk_sharded(shg, qp, qw, jax.random.key(0), cfg, mesh)
    print(f"walkers dropped by routing capacity: {int(res.dropped)}")
    print("top pins (pod-sharded batched fused walk):")
    for s, p in zip(np.asarray(res.top_scores), np.asarray(res.top_pins)):
        if s > 0:
            print(f"  pin {p:6d}  score {s:8.1f}")

    # cross-check against the single-machine walk (the paper's deployment)
    w_total = n_shards * walkers_per_shard
    wcfg = W.WalkConfig(n_steps=n_supersteps * w_total, n_walkers=w_total,
                        bias_beta=0.0, top_k=top_k, n_p=10**9, n_v=10**9)
    scores, ids = W.recommend(
        sg.graph, qp, qw, jnp.asarray(0, jnp.int32), jax.random.key(1), wcfg
    )
    overlap = len(
        set(np.asarray(res.top_pins).tolist())
        & set(np.asarray(ids).tolist())
    )
    print(f"top-{top_k} overlap with replicated walk: {overlap}/{top_k}")
    return overlap, int(res.dropped)

if __name__ == "__main__":
    main()
