"""The production distribution mode: Pixie on a graph too big for one chip.

Spawns 8 fake devices, shards the graph over a 4-way 'model' axis, and runs
the walker-migration walk (core/distributed.py) — the same program the
multi-pod dry-run lowers at 3B-node scale.  Must be a fresh process (device
count locks at first jax init), hence the XLA_FLAGS lines first.

  PYTHONPATH=src python examples/sharded_walk.py
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed as D
from repro.core import walk as W
from repro.graphs.synthetic import SyntheticGraphConfig, generate

def main():
    sg = generate(SyntheticGraphConfig(n_pins=8_000, n_boards=800, seed=3))
    mesh = jax.make_mesh(
        (2, 4), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
    shg = D.shard_graph(sg.graph, 4)
    print(f"graph sharded 4 ways: {shg.pins_per_shard} pins/shard, "
          f"{shg.boards_per_shard} boards/shard")

    degs = np.asarray(sg.graph.p2b.degrees())
    qs = np.argsort(-degs)[:3]
    qp = jnp.asarray([int(qs[0]), int(qs[1]), int(qs[2]), -1], jnp.int32)
    qw = jnp.asarray([1.0, 0.8, 0.5, 0.0], jnp.float32)

    cfg = D.ShardedWalkConfig(
        n_supersteps=48, walkers_per_shard=256, top_k=15
    )
    with jax.set_mesh(mesh):
        res = D.pixie_walk_sharded(shg, qp, qw, jax.random.key(0), cfg, mesh)
    print(f"walkers dropped by routing capacity: {int(res.dropped)}")
    print("top pins (walker-migration walk):")
    for s, p in zip(np.asarray(res.top_scores), np.asarray(res.top_pins)):
        if s > 0:
            print(f"  pin {p:6d}  score {s:8.1f}")

    # cross-check against the single-machine walk (the paper's deployment)
    wcfg = W.WalkConfig(n_steps=48 * 4 * 256, n_walkers=512,
                        bias_beta=0.0, top_k=15, n_p=10**9, n_v=10**9)
    scores, ids = W.recommend(
        sg.graph, qp, qw, jnp.asarray(0, jnp.int32), jax.random.key(1), wcfg
    )
    overlap = len(
        set(np.asarray(res.top_pins).tolist())
        & set(np.asarray(ids).tolist())
    )
    print(f"top-15 overlap with replicated walk: {overlap}/15")

if __name__ == "__main__":
    main()
