"""Quickstart: build a Pinterest-like graph, prune it, get recommendations.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pruning, walk
from repro.graphs.synthetic import SyntheticGraphConfig, generate

def main():
    # 1. generate a synthetic pin/board graph with planted topics+languages
    sg = generate(SyntheticGraphConfig(n_pins=20_000, n_boards=2_000, seed=0))
    print(f"graph: {sg.graph.n_pins} pins, {sg.graph.n_boards} boards, "
          f"{sg.graph.n_edges} edges ({sg.graph.nbytes()/1e6:.1f} MB)")

    # 2. prune it (paper §3.2): drop diverse boards, keep topical edges
    pruned, stats = pruning.prune_graph(
        sg.graph, sg.pin_topics, None,
        pruning.PruneConfig(entropy_board_frac=0.1, delta=0.9),
        board_lang=sg.board_lang, pin_lang=sg.pin_lang, n_langs=4,
    )
    print(f"pruned: kept {stats['edge_keep_frac']:.0%} of edges, "
          f"{pruned.nbytes()/1e6:.1f} MB")

    # 3. a user query: two recently-engaged pins, weighted by recency
    degs = np.asarray(pruned.p2b.degrees())
    q1, q2 = np.argsort(-degs)[:2]
    query_pins = jnp.asarray([q1, q2, -1, -1], jnp.int32)
    query_weights = jnp.asarray([1.0, 0.6, 0.0, 0.0], jnp.float32)

    # 4. Pixie Random Walk (biased to the user's language), top-10 pins
    cfg = walk.WalkConfig(n_steps=30_000, n_walkers=512, top_k=10,
                          n_p=2000, n_v=4)
    user_language = jnp.asarray(int(sg.pin_lang[q1]), jnp.int32)
    scores, pins = walk.recommend(
        pruned, query_pins, query_weights, user_language,
        jax.random.key(0), cfg,
    )
    print("\nquery pins :", int(q1), int(q2),
          f"(topic {sg.pin_topics[q1].argmax()}, lang {sg.pin_lang[q1]})")
    print("recommended:")
    for s, p in zip(np.asarray(scores), np.asarray(pins)):
        if s <= 0:
            continue
        print(f"  pin {p:6d}  score {s:8.1f}  "
              f"topic {sg.pin_topics[p].argmax()}  lang {sg.pin_lang[p]}")

if __name__ == "__main__":
    main()
