"""Two-stage recommender: Pixie retrieval -> ranking, both flavors.

This is the composition DESIGN.md §4 describes: the paper's random walk is
the candidate generator and a ranking model re-orders — the Pinterest
production shape (Related Pins, ref [22] of the paper).  Two stage-2
flavors run over the same graph:

  1. a trained SASRec ranker via the callable-ranker stage boundary
     (``pixie_then_rank`` = walk + ``rank_retrieved``);
  2. the FUSED serving path (``recommend_two_stage``): batched retrieval +
     PinSage-style scenario heads (related-pins vs homefeed) in one jitted
     program — what `PixieServer(ranker=...)` dispatches.

  PYTHONPATH=src python examples/two_stage_recsys.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import walk
from repro.data.pipeline import SeqRecPipeline
from repro.graphs.synthetic import SyntheticGraphConfig, generate
from repro.models import sequential_rec as sr
from repro.serving import ranker as ranker_lib
from repro.serving.recommend import (
    TwoStageConfig,
    pixie_then_rank,
    recommend_two_stage,
    sasrec_ranker,
)
from repro.training import optim

def main(
    n_pins: int = 5_000,
    n_boards: int = 600,
    train_steps: int = 60,
    walk_steps: int = 20_000,
    n_walkers: int = 256,
    final_k: int = 10,
):
    """Run the two-stage pipeline; parameters shrink it to a smoke test
    (tests/test_examples.py runs a tiny graph + 2 train steps through this
    same path).  Returns (sasrec scores, sasrec item ids, fused scores,
    fused item ids) — the last two batched (2, final_k), one row per
    scenario head."""
    # interaction graph for retrieval (pins double as items)
    sg = generate(SyntheticGraphConfig(n_pins=n_pins, n_boards=n_boards,
                                       seed=2))

    # train a small SASRec ranker on synthetic sequences over the same items
    cfg = sr.SeqRecConfig(name="ranker", kind="sasrec", n_items=n_pins,
                          embed_dim=32, seq_len=12, n_blocks=2, n_heads=1,
                          n_negatives=16)
    params = sr.init_params(jax.random.key(0), cfg)
    opt = optim.init(params)
    pipe = SeqRecPipeline(n_items=n_pins, batch=32, seq_len=12,
                          n_negatives=16)
    adamw = optim.AdamWConfig(lr=3e-3, warmup_steps=5,
                              total_steps=max(train_steps, 1))

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(sr.sasrec_loss)(
            params, batch["seq"], batch["targets"], batch["negatives"], cfg
        )
        params, opt, _ = optim.apply_updates(params, grads, opt, adamw)
        return params, opt, loss

    for i in range(train_steps):
        b = jax.tree.map(jnp.asarray, pipe(i))
        params, opt, loss = step(params, opt, b)
        if i % 20 == 0:
            print(f"ranker step {i:3d} loss {float(loss):.3f}")

    # serve: Pixie retrieves candidates from the graph, SASRec re-ranks
    degs = np.asarray(sg.graph.p2b.degrees())
    q = int(np.argmax(degs))
    query_pins = jnp.asarray([q, -1, -1, -1], jnp.int32)
    query_weights = jnp.asarray([1.0, 0, 0, 0], jnp.float32)
    history = jnp.asarray([q] * 12, jnp.int32)

    wcfg = walk.WalkConfig(n_steps=walk_steps, n_walkers=n_walkers,
                           n_p=2000, n_v=4)
    ranker = sasrec_ranker(params, history, cfg)
    scores, items = pixie_then_rank(
        sg.graph, query_pins, query_weights, jnp.asarray(0, jnp.int32),
        jax.random.key(1), wcfg, ranker, TwoStageConfig(final_k=final_k),
    )
    print("\ntwo-stage recommendations (walk-retrieved, SASRec-ordered):")
    for s, it in zip(np.asarray(scores), np.asarray(items)):
        if np.isfinite(s):
            print(f"  item {it:5d}  ranker score {s:7.3f}")

    # fused serving path: same query under both scenario heads in ONE
    # batched two-stage program (the PixieServer dispatch shape)
    rcfg = ranker_lib.RankerConfig(
        n_items=n_pins, d_model=16, n_neighbors=4,
        n_candidates=min(32, final_k * 2), final_k=final_k,
    )
    rank = ranker_lib.RankRequest(
        ranker_lib.init_ranker_params(jax.random.key(3), rcfg), rcfg
    )
    pins_b = jnp.stack([query_pins, query_pins])
    weights_b = jnp.stack([query_weights, query_weights])
    feats_b = jnp.zeros((2,), jnp.int32)
    scenario = jnp.asarray(
        [rcfg.scenario_id("related_pins"), rcfg.scenario_id("homefeed")],
        jnp.int32,
    )
    fused_scores, fused_items = recommend_two_stage(
        sg.graph, pins_b, weights_b, feats_b, jax.random.key(1), wcfg,
        rank, scenario=scenario,
    )
    for row, name in enumerate(rcfg.scenarios):
        head = [int(i) for i in np.asarray(fused_items)[row] if i >= 0][:5]
        print(f"fused head {name:>13}: top items {head}")
    return scores, items, fused_scores, fused_items

if __name__ == "__main__":
    main()
