"""Fault-tolerant LM training driver: a reduced smollm trains a few hundred
steps with two injected node failures; the loop restores from the atomic
checkpoint each time and keeps a straggler log.

  PYTHONPATH=src python examples/train_resilient_lm.py
"""

import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.pipeline import TokenPipeline
from repro.models import transformer as tf
from repro.training import optim, resilience, train_loop

def main():
    cfg = get_arch("smollm-360m").smoke_config
    params = tf.init_params(jax.random.key(0), cfg)
    opt = optim.init(params)
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, batch=8, seq_len=32)

    def loss_fn(p, b):
        return tf.loss_fn(p, b["tokens"], b["labels"], b["mask"], cfg)

    step = train_loop.make_train_step(
        loss_fn,
        train_loop.TrainStepConfig(
            adamw=optim.AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=200),
            n_micro=2,
        ),
    )
    jstep = jax.jit(step)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        rc = resilience.ResilienceConfig(ckpt_dir=ckpt_dir, ckpt_every=25)
        failures = resilience.make_scheduled_failures({40: 1, 110: 1})
        stragglers = []
        state, report = resilience.run_resilient(
            jstep,
            lambda s: jax.tree.map(jnp.asarray, pipe(s)),
            (params, opt),
            n_steps=200,
            cfg=rc,
            failure_hook=failures,
            straggler_hook=lambda s, ratio: stragglers.append((s, ratio)),
        )
        print(f"steps run: {report.steps_run} "
              f"(includes replays after {report.restores} restores)")
        print(f"final loss: {report.final_metrics['loss']:.3f}  "
              f"grad_norm: {report.final_metrics['grad_norm']:.3f}")
        print(f"stragglers flagged: {len(report.stragglers)}")

if __name__ == "__main__":
    main()
