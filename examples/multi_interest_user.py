"""Multi-interest users end to end: action history -> interest clusters ->
one fused walk -> merged recommendations.

The PinnerSage-shaped request path on top of Pixie's walk (DESIGN.md and
the paper's §5.1 homefeed source): a user's raw action history is
clustered host-side into k interest clusters over the graph's pin topic
vectors, each cluster becomes a weighted query lane with an
importance-proportional Eq. 2 step budget, ALL lanes (across all users)
run in ONE batched walk call, and each user's lanes merge back with the
bit-reproducible Eq. 3 cross-cluster booster.  The same path then runs
through the bucketed ``PixieServer`` via ``submit_user`` — same per-(user,
cluster) RNG streams, bit-identical results.

  PYTHONPATH=src python examples/multi_interest_user.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import service, walk
from repro.graphs import synthetic
from repro.serving.recommend import recommend_multi_interest
from repro.serving.server import PixieServer


def main(
    n_pins: int = 5_000,
    n_boards: int = 600,
    n_users: int = 4,
    n_clusters: int = 3,
    n_steps: int = 4_096,
    n_walkers: int = 128,
    top_k: int = 10,
):
    """Run the multi-interest pipeline; parameters shrink it to a smoke
    test (tests/test_examples.py runs a tiny graph through this same
    path).  Returns (merged scores (n_users, top_k), merged ids, server
    results dict, agree flag) — ``agree`` asserts the direct fused path
    and the bucketed server produced bit-identical recommendations."""
    sg = synthetic.generate(synthetic.SyntheticGraphConfig(
        n_pins=n_pins, n_boards=n_boards, seed=2
    ))
    g = sg.graph
    cfg = walk.WalkConfig(n_steps=n_steps, n_walkers=n_walkers, top_k=top_k)

    # seeded synthetic users with PLANTED multi-topic structure
    histories = synthetic.sample_user_histories(
        sg, synthetic.UserHistoryConfig(
            n_users=n_users, n_interests=n_clusters, mean_actions=20, seed=5
        )
    )

    # ---- direct fused path -------------------------------------------------
    uqs = [
        service.build_user_query(
            h.actions, sg.pin_topics, n_slots=8, n_clusters=n_clusters
        )
        for h in histories
    ]
    for u, (h, uq) in enumerate(zip(histories, uqs)):
        print(f"user {u}: {len(h.actions)} actions -> {uq.n_clusters} "
              f"clusters, importance {np.round(np.asarray(uq.importance), 3)}")
    batch = service.batch_user_queries(uqs, n_steps=cfg.n_steps)
    print(f"batched {batch.n_users} users into {batch.pins.shape[0]} lanes, "
          f"per-lane budgets {np.asarray(batch.step_budgets).tolist()}")

    # per-(user, cluster) streams, the same derivation the server uses
    skey = jax.random.key(42)
    lane_of_user = np.asarray(batch.lane_of_user)
    lane_keys = []
    for li in range(batch.pins.shape[0]):
        u = int(batch.lane_user[li])
        ci = int(np.where(lane_of_user[u] == li)[0][0])
        lane_keys.append(
            jax.random.fold_in(jax.random.fold_in(skey, 100 + u), ci)
        )
    scores, ids = recommend_multi_interest(
        g, batch, jnp.stack(lane_keys), cfg
    )
    for u in range(batch.n_users):
        s, i = np.asarray(scores[u]), np.asarray(ids[u])
        print(f"user {u} top-{min(5, top_k)}: "
              f"{[(int(p), round(float(v), 2)) for p, v in zip(i[:5], s[:5])]}")

    # ---- the same users through the bucketed server ------------------------
    srv = PixieServer(
        g, cfg, batch_size=8, n_slots=8, seed=42,
        pin_topics=sg.pin_topics, n_clusters=n_clusters,
    )
    for u, h in enumerate(histories):
        srv.submit_user(h.actions, now=0.001 * u, req_id=100 + u)
    while srv.pending():
        srv.pump(now=srv.next_deadline())
    results = {r.req_id: r for r in srv.harvest()}
    agree = all(
        np.array_equal(results[100 + u].scores, np.asarray(scores[u]))
        and np.array_equal(results[100 + u].ids, np.asarray(ids[u]))
        for u in range(n_users)
    )
    print(f"bucketed server bit-identical to fused path: {agree}")
    return scores, ids, results, agree


if __name__ == "__main__":
    main()
