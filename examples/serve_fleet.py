"""End-to-end serving driver (the paper's kind of workload): a Pixie server
replica answering batched recommendation requests in real time, with a
mid-flight graph swap (the daily reload of §3.3).

  PYTHONPATH=src python examples/serve_fleet.py
"""

import time

import numpy as np

from repro.core import pruning, service, walk
from repro.graphs.synthetic import SyntheticGraphConfig, generate
from repro.serving.server import PixieServer

def main(
    n_pins: int = 20_000,
    n_boards: int = 2_000,
    n_requests: int = 48,
    n_steps: int = 10_000,
    n_walkers: int = 256,
    top_k: int = 50,
    batch_size: int = 8,
):
    """Run the serving driver; parameters shrink it to a smoke test
    (tests/test_examples.py runs a tiny graph through this same path).
    Returns the server's ServerStats."""
    sg = generate(SyntheticGraphConfig(n_pins=n_pins, n_boards=n_boards,
                                       seed=1))
    pruned, _ = pruning.prune_graph(
        sg.graph, sg.pin_topics, None,
        pruning.PruneConfig(entropy_board_frac=0.1, delta=0.9),
        board_lang=sg.board_lang, pin_lang=sg.pin_lang, n_langs=4,
    )

    cfg = walk.WalkConfig(n_steps=n_steps, n_walkers=n_walkers, top_k=top_k,
                          n_p=1000, n_v=4)
    server = PixieServer(pruned, cfg, batch_size=batch_size, n_slots=4)

    # simulate a stream of user action -> query traffic (Homefeed, §5.1)
    rng = np.random.default_rng(0)
    degs = np.asarray(pruned.p2b.degrees())
    hot = np.argsort(-degs)[:min(500, n_pins // 4)]
    actions = ["save", "click", "view"]
    t0 = time.perf_counter()
    for i in range(n_requests):
        history = [
            service.UserAction(
                pin=int(rng.choice(hot)),
                action=str(rng.choice(actions)),
                age_hours=float(rng.exponential(12.0)),
            )
            for _ in range(rng.integers(1, 5))
        ]
        pins, weights = service.build_query(history, n_slots=4)
        server.submit(pins[pins >= 0].tolist(),
                      weights[weights > 0].tolist(),
                      user_feat=int(rng.integers(0, 4)))
        if i == n_requests // 2:
            # daily graph swap: serving continues on the new generation
            server.swap_graph(pruned)
        if (i + 1) % batch_size == 0:
            server.flush()
    server.flush()
    wall = time.perf_counter() - t0

    s = server.stats
    print(f"served {s.queries} queries in {wall:.2f}s "
          f"({s.qps(wall):.1f} QPS on this host)")
    print(f"latency p50 {s.percentile(50):.1f} ms, "
          f"p99 {s.percentile(99):.1f} ms "
          f"(paper: 1,200 QPS / 60 ms p99 per 64-core server)")
    print(f"graph generation: {s.graph_generation}")
    return s

if __name__ == "__main__":
    main()
