"""End-to-end serving driver (the paper's kind of workload): a Pixie server
replica answering batched recommendation requests in real time, with a
mid-flight graph swap (the daily reload of §3.3).

  PYTHONPATH=src python examples/serve_fleet.py
"""

import time

import numpy as np

from repro.core import pruning, service, walk
from repro.graphs.synthetic import SyntheticGraphConfig, generate
from repro.serving.server import PixieServer

def main():
    sg = generate(SyntheticGraphConfig(n_pins=20_000, n_boards=2_000, seed=1))
    pruned, _ = pruning.prune_graph(
        sg.graph, sg.pin_topics, None,
        pruning.PruneConfig(entropy_board_frac=0.1, delta=0.9),
        board_lang=sg.board_lang, pin_lang=sg.pin_lang, n_langs=4,
    )

    cfg = walk.WalkConfig(n_steps=10_000, n_walkers=256, top_k=50,
                          n_p=1000, n_v=4)
    server = PixieServer(pruned, cfg, batch_size=8, n_slots=4)

    # simulate a stream of user action -> query traffic (Homefeed, §5.1)
    rng = np.random.default_rng(0)
    degs = np.asarray(pruned.p2b.degrees())
    hot = np.argsort(-degs)[:500]
    actions = ["save", "click", "view"]
    t0 = time.perf_counter()
    n_requests = 48
    for i in range(n_requests):
        history = [
            service.UserAction(
                pin=int(rng.choice(hot)),
                action=str(rng.choice(actions)),
                age_hours=float(rng.exponential(12.0)),
            )
            for _ in range(rng.integers(1, 5))
        ]
        pins, weights = service.build_query(history, n_slots=4)
        server.submit(pins[pins >= 0].tolist(),
                      weights[weights > 0].tolist(),
                      user_feat=int(rng.integers(0, 4)))
        if i == n_requests // 2:
            # daily graph swap: serving continues on the new generation
            server.swap_graph(pruned)
        if (i + 1) % 8 == 0:
            server.flush()
    server.flush()
    wall = time.perf_counter() - t0

    s = server.stats
    print(f"served {s.queries} queries in {wall:.2f}s "
          f"({s.qps(wall):.1f} QPS on this host)")
    print(f"latency p50 {s.percentile(50):.1f} ms, "
          f"p99 {s.percentile(99):.1f} ms "
          f"(paper: 1,200 QPS / 60 ms p99 per 64-core server)")
    print(f"graph generation: {s.graph_generation}")

if __name__ == "__main__":
    main()
