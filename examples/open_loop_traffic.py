"""Continuous-traffic serving driver: a bucketed deadline-aware Pixie
replica under a seeded open-loop Poisson load, with the daily graph swap
(§3.3) fired mid-run while requests are in flight.

  PYTHONPATH=src python examples/open_loop_traffic.py

Unlike examples/serve_fleet.py (the synchronous flush loop), this is the
production serving shape: mixed-size queries route to small/medium/large
(batch, n_slots) buckets, batches dispatch on max-wait OR full-bucket,
and per-query latency reports the queue-wait vs compute split.

Default load (8 QPS) is sized for a CPU host, where batch compute runs
hundreds of ms; raise ``offered_qps`` on real accelerators (the paper's
number is 1,200 QPS at 60 ms p99 per 64-core server).  Oversubscribing
is informative too: admission control sheds to ``max_backlog_s`` and
the drop rate climbs instead of latency growing without bound.
"""

import numpy as np

from repro.core import walk
from repro.graphs.synthetic import SyntheticGraphConfig, generate
from repro.serving.server import PixieServer
from repro.serving.traffic import (
    OpenLoopConfig, poisson_requests, run_open_loop,
)


def main(
    n_pins: int = 20_000,
    n_boards: int = 2_000,
    n_requests: int = 48,
    offered_qps: float = 8.0,
    n_steps: int = 1_500,
    n_walkers: int = 64,
    top_k: int = 50,
    max_pins: int = 8,
    seed: int = 0,
):
    """Run the open-loop driver; parameters shrink it to a smoke test
    (tests/test_examples.py runs a tiny graph through this same path).
    Returns the TrafficReport."""
    sg = generate(SyntheticGraphConfig(n_pins=n_pins, n_boards=n_boards,
                                       seed=seed + 1))
    cfg = walk.WalkConfig(n_steps=n_steps, n_walkers=n_walkers, top_k=top_k,
                          n_p=1000, n_v=4)
    # small/medium/large buckets; intermediate widths narrower than the
    # largest only (slot widths must be distinct for pin-count routing)
    buckets = [(b, s) for b, s in ((6, 2), (4, 4)) if s < max_pins]
    buckets.append((2, max_pins))
    server = PixieServer(
        sg.graph, cfg, seed=seed, buckets=buckets, max_wait_ms=5.0,
    )

    rng = np.random.default_rng(seed)
    degs = np.asarray(sg.graph.p2b.degrees()).astype(np.float64)
    hot = rng.choice(
        sg.graph.n_pins, size=min(500, n_pins // 4), replace=False,
        p=degs / degs.sum(),
    ).astype(np.int32)
    workload = poisson_requests(hot, OpenLoopConfig(
        offered_qps=offered_qps, n_requests=n_requests, seed=seed,
        max_pins=max_pins,
    ))

    # daily graph swap fired while traffic is in flight: the old graph
    # serves until the new handle is in place, generations move once
    report = run_open_loop(
        server, workload, max_backlog_s=5.0,
        swap_at=n_requests // 2, swap_graph=sg.graph,
    )

    s = report.summary()
    print(f"offered {s['offered_qps']:.1f} QPS, achieved "
          f"{s['achieved_qps']:.1f} QPS, drop rate {s['drop_rate']:.1%}")
    print(f"latency p50 {s['p50_ms']:.1f} ms / p95 {s['p95_ms']:.1f} ms / "
          f"p99 {s['p99_ms']:.1f} ms "
          f"(paper: 1,200 QPS / 60 ms p99 per 64-core server)")
    print(f"  split: wait {s['mean_wait_ms']:.2f} ms, exec queue "
          f"{s['mean_queue_ms']:.2f} ms, compute {s['mean_compute_ms']:.2f} ms")
    gens = sorted(set(report.generations.values()))
    print(f"graph generations served: {gens} "
          f"(swap at request {n_requests // 2})")
    return report


if __name__ == "__main__":
    main()
