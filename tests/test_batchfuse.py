"""Batch-native fused walk engine vs the vmapped per-query path.

The contract under test (core/walk.pixie_random_walk_batched): the query
batch is a first-class axis of the fused engine — all queries' walkers
packed query-major on one walker axis, ONE fused chunk call and ONE
query-major counting call per superstep chunk, one shared while loop with
a per-(query, slot) early-stop mask — and the result is BIT-IDENTICAL to
``jax.vmap(pixie_random_walk)`` over the same ``jax.random.split``-derived
per-query keys: counts, board counts, ``steps_taken``, ``n_high``, scores
and ids, for every batch size, both gather modes, and queries that
early-stop at different chunks.

The lowering claim is pinned by jaxpr inspection: a batched serve step
contains a constant number of ``pallas_call`` eqns inside one
``max_chunks``-bounded while loop, with NO batch-sized leading grid
dimension — the vmapped pallas path (the positive control) prepends the
batch to every kernel grid, i.e. batch x chunks program replication.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import service, walk as walk_lib
from repro.graphs.synthetic import small_test_graph, top_degree_pins
from repro.kernels.introspect import pallas_grids
from repro.kernels.walk_step import DEFAULT_BLOCK_W


@pytest.fixture(scope="module")
def sg():
    return small_test_graph()


def _cfg(**kw):
    kw = {
        "n_steps": 1536, "n_walkers": 64, "chunk_steps": 4, "top_k": 20,
        "n_p": 40, "n_v": 3, "backend": "pallas", **kw,
    }
    return walk_lib.WalkConfig(**kw)


def _mk_batch(sg, batch, n_slots=2):
    qs = top_degree_pins(sg, 2 * batch if 2 * batch <= 32 else 32)
    pins = np.full((batch, n_slots), -1, np.int32)
    weights = np.zeros((batch, n_slots), np.float32)
    for i in range(batch):
        pins[i, 0] = int(qs[(2 * i) % len(qs)])
        pins[i, 1] = int(qs[(2 * i + 1) % len(qs)])
        weights[i] = [1.0, 0.6]
    return (
        jnp.asarray(pins),
        jnp.asarray(weights),
        jnp.zeros((batch,), jnp.int32),
    )


def _vmapped_walk(graph, pins, weights, feats, keys, cfg):
    return jax.vmap(
        lambda qp, qw, uf, k: walk_lib.pixie_random_walk(
            graph, qp, qw, uf, k, cfg
        )
    )(pins, weights, feats, keys)


def _assert_results_equal(got, want):
    for name in ("counts", "board_counts", "steps_taken", "n_high"):
        a, b = getattr(got, name), getattr(want, name)
        assert (a is None) == (b is None), name
        if a is not None:
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b), err_msg=name
            )


@pytest.mark.parametrize("gather_mode", ["scalar", "dma"])
@pytest.mark.parametrize("batch", [1, 4, 16])
def test_batched_bit_identical_to_vmapped(sg, batch, gather_mode):
    """Acceptance matrix: batch {1, 4, 16} x gather modes, early stopping
    ACTIVE so the per-(query, slot) mask and the query-major n_high tally
    are on the line."""
    g = sg.graph
    cfg = _cfg(gather_mode=gather_mode)
    pins, weights, feats = _mk_batch(sg, batch)
    keys = jax.random.split(jax.random.key(11), batch)
    rb = walk_lib.pixie_random_walk_batched(g, pins, weights, feats, keys, cfg)
    rv = _vmapped_walk(g, pins, weights, feats, keys, cfg)
    _assert_results_equal(rb, rv)
    assert int(rb.counts.sum()) > 0  # the walk actually walked
    # the batched engine is also its own xla/pallas parity pair
    if gather_mode == "scalar":
        rx = walk_lib.pixie_random_walk_batched(
            g, pins, weights, feats, keys,
            dataclasses.replace(cfg, backend="xla"),
        )
        _assert_results_equal(rb, rx)


def test_batched_board_counts_bit_identical(sg):
    g = sg.graph
    cfg = _cfg(count_boards=True)
    pins, weights, feats = _mk_batch(sg, 4)
    keys = jax.random.split(jax.random.key(5), 4)
    rb = walk_lib.pixie_random_walk_batched(g, pins, weights, feats, keys, cfg)
    rv = _vmapped_walk(g, pins, weights, feats, keys, cfg)
    assert rb.board_counts is not None
    assert rb.board_counts.shape == (4, 2, g.n_boards)
    _assert_results_equal(rb, rv)


def test_queries_early_stop_at_different_chunks(sg):
    """One query's thresholds trip chunks before another's: the shared
    while loop must keep the fast query frozen (events masked, steps
    frozen) while its neighbours walk on — bit-identically to the
    per-query loops."""
    g = sg.graph
    # query 0: aggressive thresholds would stop it almost immediately if
    # they were global — give it a full-weight hot pin; query 1: a tiny
    # weight means a tiny Eq. 2 budget, so it runs out of steps at a
    # different chunk than query 0's n_high trip
    qs = top_degree_pins(sg, 4)
    pins = jnp.asarray(
        [[int(qs[0]), int(qs[1])], [int(qs[2]), int(qs[3])]], jnp.int32
    )
    weights = jnp.asarray([[1.0, 0.6], [0.05, 1.0]], jnp.float32)
    feats = jnp.zeros((2,), jnp.int32)
    cfg = _cfg(n_steps=2048, n_p=15, n_v=2)
    keys = jax.random.split(jax.random.key(2), 2)
    rb = walk_lib.pixie_random_walk_batched(g, pins, weights, feats, keys, cfg)
    rv = _vmapped_walk(g, pins, weights, feats, keys, cfg)
    _assert_results_equal(rb, rv)
    per_query_steps = np.asarray(rb.steps_taken).sum(axis=1)
    # the point of the test: the queries really stopped at different
    # points, AND before the full budget (early stopping fired)
    assert per_query_steps[0] != per_query_steps[1]
    assert (per_query_steps < cfg.n_steps).any()


def test_serve_batch_routes_pallas_through_batched_engine(sg):
    """serve_batch backend="pallas" (batched) == backend="xla" (vmapped
    oracle twin) bit-identically, scores and ids AND telemetry."""
    g = sg.graph
    pins, weights, feats = _mk_batch(sg, 4)
    cfg = _cfg(backend="xla")
    key = jax.random.key(9)
    outx = service.serve_batch(
        g, pins, weights, feats, key, cfg, backend="xla", with_stats=True
    )
    outp = service.serve_batch(
        g, pins, weights, feats, key, cfg, backend="pallas", with_stats=True
    )
    for a, b, name in zip(outx, outp, ("scores", "ids", "steps", "n_high")):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=name
        )
    assert outp[0].shape == (4, cfg.top_k)
    assert outp[2].shape == (4, 2)


# ---------------------------------------------------------------------------
# Lowering pins: one pallas_call per chunk for the WHOLE batch
# ---------------------------------------------------------------------------


def test_batched_serve_lowers_to_one_call_per_chunk(sg):
    """The fusion claim: a batched serve step contains exactly 2
    pallas_call eqns (fused walk + query-major counter) inside the ONE
    max_chunks-bounded while loop, with rank-1 walk grids sized by total
    walkers — NOT a batch-sized leading grid dim.  The vmapped pallas
    path is the positive control: vmap prepends the batch to every grid
    (batch x chunks program replication), which is exactly what the
    batched engine removes."""
    g = sg.graph
    cfg = _cfg()
    w = cfg.n_walkers
    structures = {}
    for batch in (1, 16):
        pins, weights, feats = _mk_batch(sg, batch)

        def serve(key):
            return service.serve_batch(g, pins, weights, feats, key, cfg)

        grids = pallas_grids(jax.make_jaxpr(serve)(jax.random.key(0)))
        # one fused walk call + one fused count-and-tally call per chunk
        assert len(grids) == 2, grids
        walk_grid, count_grid = grids
        # walk: rank-1 grid over walker blocks covering the WHOLE batch
        # (block_w follows ops.walk_chunk_fused_batched's default rule)
        assert len(walk_grid) == 1, walk_grid
        w_total = batch * w
        block_w = (
            DEFAULT_BLOCK_W if w_total % DEFAULT_BLOCK_W == 0 else w_total
        )
        assert walk_grid[0] == w_total // block_w, (walk_grid, w_total)
        # counter: (n_tiles, n_chunks) — no batch axis
        assert len(count_grid) == 2, count_grid
        structures[batch] = (len(grids), len(walk_grid), len(count_grid))
    # pallas_call count and grid ranks are independent of batch size
    assert structures[1] == structures[16]

    # positive control: the vmapped pallas path replicates per query
    batch = 16
    pins, weights, feats = _mk_batch(sg, batch)
    keys = jax.random.split(jax.random.key(0), batch)

    def vmapped(keys):
        return jax.vmap(
            lambda qp, qw, uf, k: walk_lib.recommend_with_stats(
                g, qp, qw, uf, k, cfg
            )
        )(pins, weights, feats, keys)

    vgrids = pallas_grids(jax.make_jaxpr(vmapped)(keys))
    assert len(vgrids) == 2, vgrids
    for grid in vgrids:
        assert grid[0] == batch, (
            f"vmapped grid {grid} should lead with the batch axis"
        )


def test_batched_engine_fits_envelope():
    """The batched engine's query-major bins must fit int32; serve_batch
    consults this predicate to fall back to the vmapped formulation
    instead of erroring on a (graph, batch) shape the per-query path
    served fine (its flat indexing is per query)."""
    # benchmark scale: fits comfortably
    assert walk_lib.batched_engine_fits(64, 4, 20_000, 2_000, True)
    # production-ish: 64 queries x 4 slots x 10M pins = 2.56e9 bins — the
    # per-query path's 40M bins fit, the combined space does not
    assert not walk_lib.batched_engine_fits(64, 4, 10_000_000)
    assert walk_lib.batched_engine_fits(1, 4, 10_000_000)
    # board counting widens the bin space only when boards are counted
    assert walk_lib.batched_engine_fits(64, 4, 1_000, 10_000_000, False)
    assert not walk_lib.batched_engine_fits(64, 4, 1_000, 10_000_000, True)


def test_serve_batch_falls_back_to_vmapped_past_envelope(sg, monkeypatch):
    """Past the batched envelope, serve_batch must keep serving (vmapped
    grids, batch-replicated) rather than raising where it used to work."""
    g = sg.graph
    batch = 4
    pins, weights, feats = _mk_batch(sg, batch)
    cfg = _cfg()
    monkeypatch.setattr(walk_lib, "batched_engine_fits",
                        lambda *a, **k: False)

    def serve(key):
        return service.serve_batch(g, pins, weights, feats, key, cfg,
                                   backend="pallas")

    grids = pallas_grids(jax.make_jaxpr(serve)(jax.random.key(0)))
    assert all(grid[0] == batch for grid in grids), grids


def test_batched_engine_validates_inputs(sg):
    g = sg.graph
    pins, weights, feats = _mk_batch(sg, 2)
    keys = jax.random.split(jax.random.key(0), 2)
    with pytest.raises(ValueError, match="n_v must be >= 1"):
        walk_lib.pixie_random_walk_batched(
            g, pins, weights, feats, keys, _cfg(n_v=0)
        )
    with pytest.raises(ValueError, match=r"\(n_queries, n_slots\)"):
        walk_lib.pixie_random_walk_batched(
            g, pins[0], weights[0], feats, keys, _cfg()
        )
    with pytest.raises(ValueError, match="unknown gather_mode"):
        walk_lib.pixie_random_walk_batched(
            g, pins, weights, feats, keys, _cfg(gather_mode="warp")
        )
