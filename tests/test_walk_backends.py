"""Fused Pallas walk engine vs the XLA reference engine.

The contract under test (core/walk.py): both backends consume the same
counter-RNG bits and do the same integer arithmetic, so for the same key
they must agree BIT-FOR-BIT — visit counts, emitted events, board counts,
and final recommendations — while the pallas engine fuses all
``chunk_steps`` supersteps of a chunk into a single ``pallas_call``.

Kernels run in interpret mode on CPU hosts (the wrappers auto-detect)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import service, walk as walk_lib
from repro.graphs.synthetic import small_test_graph, top_degree_pins
from repro.kernels import ops, ref


@pytest.fixture(scope="module")
def sg():
    return small_test_graph()


def _cfgs(**kw):
    kw = {
        "n_steps": 3_000, "n_walkers": 128, "chunk_steps": 8,
        "n_p": 10**9, "n_v": 10**9, **kw,
    }
    base = walk_lib.WalkConfig(**kw)
    return base, dataclasses.replace(base, backend="pallas")


def _queries(sg, n_slots=4):
    qs = top_degree_pins(sg, 2)
    qp = jnp.full((n_slots,), -1, jnp.int32).at[:2].set(
        jnp.asarray([int(qs[0]), int(qs[1])], jnp.int32)
    )
    qw = jnp.zeros((n_slots,), jnp.float32).at[:2].set(
        jnp.asarray([1.0, 0.5])
    )
    return qp, qw


@pytest.mark.parametrize("bias_beta", [0.0, 0.9])
def test_dense_counts_bit_identical(sg, bias_beta):
    g = sg.graph
    qp, qw = _queries(sg)
    cfg_x, cfg_p = _cfgs(bias_beta=bias_beta)
    key = jax.random.key(11)
    rx = walk_lib.pixie_random_walk(
        g, qp, qw, jnp.asarray(1, jnp.int32), key, cfg_x
    )
    rp = walk_lib.pixie_random_walk(
        g, qp, qw, jnp.asarray(1, jnp.int32), key, cfg_p
    )
    np.testing.assert_array_equal(
        np.asarray(rx.counts), np.asarray(rp.counts)
    )
    np.testing.assert_array_equal(
        np.asarray(rx.steps_taken), np.asarray(rp.steps_taken)
    )
    assert int(rx.counts.sum()) > 0  # walk actually walked


def test_event_buffers_bit_identical(sg):
    g = sg.graph
    qp, qw = _queries(sg)
    cfg_x, cfg_p = _cfgs()
    key = jax.random.key(5)
    ex = walk_lib.pixie_walk_events(
        g, qp, qw, jnp.asarray(0, jnp.int32), key, cfg_x, check_every=10**9
    )
    ep = walk_lib.pixie_walk_events(
        g, qp, qw, jnp.asarray(0, jnp.int32), key, cfg_p, check_every=10**9
    )
    np.testing.assert_array_equal(
        np.asarray(ex.slot_events), np.asarray(ep.slot_events)
    )
    np.testing.assert_array_equal(
        np.asarray(ex.pin_events), np.asarray(ep.pin_events)
    )
    assert int(ex.chunks_run) == int(ep.chunks_run)


def test_board_counts_bit_identical(sg):
    g = sg.graph
    qp, qw = _queries(sg)
    cfg_x, cfg_p = _cfgs(count_boards=True)
    key = jax.random.key(2)
    rx = walk_lib.pixie_random_walk(
        g, qp, qw, jnp.asarray(1, jnp.int32), key, cfg_x
    )
    rp = walk_lib.pixie_random_walk(
        g, qp, qw, jnp.asarray(1, jnp.int32), key, cfg_p
    )
    np.testing.assert_array_equal(
        np.asarray(rx.board_counts), np.asarray(rp.board_counts)
    )


def test_recommendations_identical_through_serve_batch(sg):
    """The whole batched serving path returns the same pins either way."""
    g = sg.graph
    qp, qw = _queries(sg)
    pins = jnp.stack([qp, qp])
    weights = jnp.stack([qw, qw])
    feats = jnp.asarray([0, 1], jnp.int32)
    cfg_x, _ = _cfgs(top_k=20)
    key = jax.random.key(9)
    sx, ix = service.serve_batch(g, pins, weights, feats, key, cfg_x)
    sp, ip = service.serve_batch(
        g, pins, weights, feats, key, cfg_x, backend="pallas"
    )
    np.testing.assert_array_equal(np.asarray(ix), np.asarray(ip))
    np.testing.assert_allclose(np.asarray(sx), np.asarray(sp), rtol=1e-6)


def test_dead_end_restarts_agree():
    """Walkers on a degree-0 pin restart at the query, visit uncounted —
    identically on both backends."""
    # pin 0 has no boards; pin 1 connects to board 0 <-> pins {0, 1}
    from repro.core.graph import CSR, PinBoardGraph

    p2b = CSR(
        offsets=jnp.asarray([0, 0, 2], jnp.int32),
        targets=jnp.asarray([2, 2], jnp.int32),
    )
    b2p = CSR(
        offsets=jnp.asarray([0, 2], jnp.int32),
        targets=jnp.asarray([0, 1], jnp.int32),
    )
    g = PinBoardGraph(p2b=p2b, b2p=b2p, n_pins=2, n_boards=1, max_pin_degree=2)
    qp = jnp.asarray([0], jnp.int32)   # query IS the dead end
    qw = jnp.ones((1,), jnp.float32)
    cfg_x, cfg_p = _cfgs(n_steps=512, n_walkers=64, bias_beta=0.0)
    key = jax.random.key(0)
    rx = walk_lib.pixie_random_walk(
        g, qp, qw, jnp.asarray(0, jnp.int32), key, cfg_x
    )
    rp = walk_lib.pixie_random_walk(
        g, qp, qw, jnp.asarray(0, jnp.int32), key, cfg_p
    )
    np.testing.assert_array_equal(np.asarray(rx.counts), np.asarray(rp.counts))
    # every step restarted at the dead-end query: nothing countable
    assert int(rx.counts.sum()) == 0
    assert int(rp.counts.sum()) == 0


# ---------------------------------------------------------------------------
# chunk-level checks on the fused op itself
# ---------------------------------------------------------------------------


def _chunk_args(key, chunk_steps=8, w=128, n_pins=50, n_boards=12,
                n_slots=4, n_edges=400):
    kp, kb, kr = jax.random.split(key, 3)
    pins = np.asarray(jax.random.randint(kp, (n_edges,), 0, n_pins))
    boards = np.asarray(jax.random.randint(kb, (n_edges,), 0, n_boards))
    order = np.argsort(pins, kind="stable")
    p2b_off = np.zeros(n_pins + 1, np.int32)
    np.cumsum(np.bincount(pins, minlength=n_pins), out=p2b_off[1:])
    p2b_tgt = (boards[order] + n_pins).astype(np.int32)
    order_b = np.argsort(boards, kind="stable")
    b2p_off = np.zeros(n_boards + 1, np.int32)
    np.cumsum(np.bincount(boards, minlength=n_boards), out=b2p_off[1:])
    b2p_tgt = pins[order_b].astype(np.int32)
    k1, k2, k3 = jax.random.split(kr, 3)
    curr = jax.random.randint(k1, (w,), 0, n_pins, dtype=jnp.int32)
    query = jax.random.randint(k2, (w,), 0, n_pins, dtype=jnp.int32)
    rbits = jax.random.bits(k3, (chunk_steps, w, 4), dtype=jnp.uint32)
    slot = jnp.arange(w, dtype=jnp.int32) % n_slots
    feat = jnp.zeros((w,), jnp.int32)
    return dict(
        curr=curr, query=query, feat=feat, slot=slot, rbits=rbits,
        p2b_offsets=jnp.asarray(p2b_off), p2b_targets=jnp.asarray(p2b_tgt),
        b2p_offsets=jnp.asarray(b2p_off), b2p_targets=jnp.asarray(b2p_tgt),
        n_pins=n_pins, n_slots=n_slots, n_boards=n_boards,
    )


@pytest.mark.parametrize("alpha_u32", [0, 2**31, 2**32 - 1])
def test_fused_chunk_kernel_matches_ref(alpha_u32):
    a = _chunk_args(jax.random.key(alpha_u32 % 101))
    common = dict(alpha_u32=alpha_u32, beta_u32=0, count_boards=True)
    got = ops.walk_chunk_fused(use_kernel=True, **a, **common)
    want = ops.walk_chunk_fused(use_kernel=False, **a, **common)
    for g_, w_ in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g_), np.asarray(w_))


def test_one_pallas_call_covers_all_chunk_steps():
    """The fusion claim itself: a chunk of `chunk_steps` supersteps lowers
    to exactly ONE pallas_call (the seed kernel needed one per step)."""
    chunk_steps = 8
    a = _chunk_args(jax.random.key(3), chunk_steps=chunk_steps)

    def chunk(curr, rbits):
        return ops.walk_chunk_fused(
            curr, a["query"], a["feat"], a["slot"], rbits,
            a["p2b_offsets"], a["p2b_targets"],
            a["b2p_offsets"], a["b2p_targets"],
            n_pins=a["n_pins"], n_slots=a["n_slots"], n_boards=a["n_boards"],
            alpha_u32=2**31, beta_u32=0, use_kernel=True,
        )

    jaxpr = jax.make_jaxpr(chunk)(a["curr"], a["rbits"])
    n_calls = str(jaxpr).count("pallas_call")
    assert n_calls == 1, f"expected 1 fused pallas_call, found {n_calls}"
    # and that single call really emits chunk_steps steps of wide events
    _, slot_ev, pin_ev, _ = chunk(a["curr"], a["rbits"])
    assert slot_ev.shape == (chunk_steps, a["curr"].shape[0])
    assert pin_ev.shape == (chunk_steps, a["curr"].shape[0])
    sev, pev = np.asarray(slot_ev), np.asarray(pin_ev)
    # slot lane: valid slots or the n_slots sentinel; pin lane in range
    assert ((sev >= 0) & (sev <= a["n_slots"])).all()
    assert ((pev >= 0) & (pev < a["n_pins"])).all()
    assert (pev[sev == a["n_slots"]] == 0).all()  # sentinel zeroes the lane


def test_chunk_ref_unroll_matches_loop():
    """Cost-model mode (python-unrolled steps) is the same function."""
    a = _chunk_args(jax.random.key(7))
    common = dict(alpha_u32=2**30, beta_u32=0, use_kernel=False)
    got = ops.walk_chunk_fused(unroll=True, **a, **common)
    want = ops.walk_chunk_fused(unroll=False, **a, **common)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
