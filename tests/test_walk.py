"""Pixie walk system tests: statistical agreement with the paper-faithful
sequential oracle, Eq. 1-3 semantics, early stopping, and event-mode
equivalence."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis, or seeded fallback

from repro.core import counter as counter_lib
from repro.core import sampling, walk as walk_lib
from repro.core.reference import (
    basic_random_walk_ref,
    pixie_random_walk_ref,
    scaling_factor_ref,
)
from repro.graphs.synthetic import small_test_graph, top_degree_pins


@pytest.fixture(scope="module")
def sg():
    return small_test_graph()


def test_basic_walk_matches_oracle_distribution(sg):
    """Vectorized walk and sequential oracle sample the same Markov chain:
    their normalized visit distributions converge (TV distance small)."""
    g = sg.graph
    q = int(top_degree_pins(sg, 1)[0])
    v_ref = basic_random_walk_ref(g, q, alpha=0.5, n_steps=40_000, seed=3)
    cfg = walk_lib.WalkConfig(
        n_steps=40_000, n_walkers=512, bias_beta=0.0,
        n_p=10**9, n_v=10**9,
    )
    v_jax = np.asarray(walk_lib.basic_random_walk(g, q, jax.random.key(0), cfg))
    pr = v_ref / max(v_ref.sum(), 1)
    pj = v_jax / max(v_jax.sum(), 1)
    tv = 0.5 * np.abs(pr - pj).sum()
    assert tv < 0.15, f"TV distance {tv}"


def test_biased_walk_matches_biased_oracle(sg):
    g = sg.graph
    q = int(top_degree_pins(sg, 1)[0])
    lang = 1
    v_ref = pixie_random_walk_ref(
        g, q, user_feat=lang, alpha=0.5, n_steps=30_000,
        n_p=10**9, n_v=10**9, beta=0.9, seed=5,
    )
    cfg = walk_lib.WalkConfig(
        n_steps=30_000, n_walkers=512, bias_beta=0.9, n_p=10**9, n_v=10**9
    )
    res = walk_lib.pixie_random_walk(
        g, jnp.asarray([q], jnp.int32), jnp.ones((1,), jnp.float32),
        jnp.asarray(lang, jnp.int32), jax.random.key(1), cfg,
    )
    v_jax = np.asarray(res.counts[0])
    pr = v_ref / max(v_ref.sum(), 1)
    pj = v_jax / max(v_jax.sum(), 1)
    tv = 0.5 * np.abs(pr - pj).sum()
    assert tv < 0.2, f"TV distance {tv}"


def test_multi_hit_booster_prefers_multi_query_pins():
    """Eq. 3: (sqrt(a)+sqrt(b))^2 > a+b for a,b>0 — multi-hit pins beat
    single-hit pins of the same total count."""
    counts = jnp.asarray([[9, 16, 0], [9, 0, 25]], jnp.int32)
    boosted = np.asarray(counter_lib.boost_combine(counts))
    # pin 0: visited from both queries (9+9=18 total)
    # pin 1: 16 from one; pin 2: 25 from one
    assert boosted[0] == pytest.approx((3 + 3) ** 2)
    assert boosted[1] == pytest.approx(16.0)
    assert boosted[2] == pytest.approx(25.0)
    assert boosted[0] > boosted[2] > boosted[1]


def test_early_stopping_reduces_steps(sg):
    g = sg.graph
    q = int(top_degree_pins(sg, 1)[0])
    qp = jnp.asarray([q], jnp.int32)
    qw = jnp.ones((1,), jnp.float32)
    base = walk_lib.WalkConfig(n_steps=40_000, n_walkers=256)
    no_stop = dataclasses.replace(base, n_p=10**9, n_v=10**9)
    stop = dataclasses.replace(base, n_p=50, n_v=4)
    r1 = walk_lib.pixie_random_walk(
        g, qp, qw, jnp.asarray(0, jnp.int32), jax.random.key(0), no_stop
    )
    r2 = walk_lib.pixie_random_walk(
        g, qp, qw, jnp.asarray(0, jnp.int32), jax.random.key(0), stop
    )
    assert int(r2.steps_taken[0]) < int(r1.steps_taken[0])
    assert int(r2.n_high[0]) > 50


def test_event_mode_matches_dense_mode(sg):
    """The scale-free event path aggregates to the same counts as the
    dense scatter path under identical RNG."""
    g = sg.graph
    qs = top_degree_pins(sg, 2)
    qp = jnp.asarray([int(qs[0]), int(qs[1])], jnp.int32)
    qw = jnp.asarray([1.0, 0.5], jnp.float32)
    cfg = walk_lib.WalkConfig(
        n_steps=8_000, n_walkers=128, n_p=10**9, n_v=10**9
    )
    key = jax.random.key(7)
    dense = walk_lib.pixie_random_walk(
        g, qp, qw, jnp.asarray(0, jnp.int32), key, cfg
    )
    ev = walk_lib.pixie_walk_events(
        g, qp, qw, jnp.asarray(0, jnp.int32), key, cfg,
        check_every=10**9,
    )
    # aggregate wide event lanes -> per-slot counts
    slot_ev = np.asarray(ev.slot_events)
    pin_ev = np.asarray(ev.pin_events)
    valid = slot_ev < 2  # slot lane sentinel = n_slots marks invalid steps
    slot = slot_ev[valid]
    pin = pin_ev[valid]
    counts = np.zeros((2, g.n_pins), np.int64)
    np.add.at(counts, (slot, pin), 1)
    dense_counts = np.asarray(dense.counts)
    # dense mode zeroes the query pins after the walk; do the same
    counts[0, int(qs[0])] = 0
    counts[1, int(qs[1])] = 0
    np.testing.assert_array_equal(counts, dense_counts)


def test_basic_walk_pins_algorithm_1_contract(sg):
    """Algorithm 1: unbiased, single query, FULL fixed budget — early
    stopping must be disabled through the incremental-tally API without the
    huge n_v sentinel corrupting anything."""
    g = sg.graph
    q = int(top_degree_pins(sg, 1)[0])
    cfg = walk_lib.WalkConfig(n_steps=2_048, n_walkers=128, chunk_steps=4)
    # the sentinel config basic_random_walk builds internally
    cfg_off = dataclasses.replace(cfg, bias_beta=0.0).without_early_stop()
    assert cfg_off.n_v == walk_lib.NO_EARLY_STOP_NV
    assert cfg_off.n_p == cfg.n_steps + 1
    res = walk_lib.pixie_random_walk(
        g, jnp.asarray([q], jnp.int32), jnp.ones((1,), jnp.float32),
        jnp.asarray(0, jnp.int32), jax.random.key(4), cfg_off,
    )
    # no pin can reach the sentinel threshold: tally stays exactly zero
    assert int(res.n_high[0]) == 0
    # full budget spent: the walk never stopped early
    assert int(res.steps_taken[0]) >= cfg.n_steps
    # basic_random_walk is that walk's slot-0 counts
    v = walk_lib.basic_random_walk(g, q, jax.random.key(4), cfg)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(res.counts[0]))
    assert int(v.sum()) > 0
    assert int(v[q]) == 0  # query pin never recommended
    # and both step engines agree on Algorithm 1 too
    v_p = walk_lib.basic_random_walk(
        g, q, jax.random.key(4), dataclasses.replace(cfg, backend="pallas")
    )
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_p))


def test_recommend_excludes_query_pins(sg):
    g = sg.graph
    qs = top_degree_pins(sg, 2)
    qp = jnp.asarray([int(qs[0]), int(qs[1]), -1, -1], jnp.int32)
    qw = jnp.asarray([1.0, 1.0, 0.0, 0.0], jnp.float32)
    cfg = walk_lib.WalkConfig(n_steps=20_000, n_walkers=256, top_k=50)
    scores, ids = walk_lib.recommend(
        g, qp, qw, jnp.asarray(0, jnp.int32), jax.random.key(0), cfg
    )
    ids = np.asarray(ids)[np.asarray(scores) > 0]
    assert int(qs[0]) not in ids
    assert int(qs[1]) not in ids


# ---------------------------------------------------------------------------
# Eq. 1-2 properties (hypothesis)
# ---------------------------------------------------------------------------


def test_scaling_factor_matches_reference():
    for deg in (0, 1, 5, 100, 4096):
        got = float(sampling.scaling_factor(
            jnp.asarray(deg), jnp.asarray(4096)
        ))
        want = scaling_factor_ref(deg, 4096)
        assert got == pytest.approx(want, rel=1e-5), deg


@settings(max_examples=50, deadline=None)
@given(
    degs=st.lists(st.integers(0, 10_000), min_size=1, max_size=16),
    n_total=st.integers(100, 1_000_000),
)
def test_allocate_steps_properties(degs, n_total):
    degs_a = jnp.asarray(degs, jnp.int32)
    w = jnp.ones((len(degs),), jnp.float32)
    max_deg = jnp.asarray(max(max(degs), 1))
    n_q = np.asarray(sampling.allocate_steps(w, degs_a, max_deg, n_total))
    active = np.asarray(degs) > 0
    # every active query pin gets at least one step (paper's stated goal)
    assert (n_q[active] >= 1).all()
    assert (n_q[~active] == 0).all()
    # total stays within budget + per-pin rounding slack
    assert n_q.sum() <= n_total + len(degs)


@settings(max_examples=30, deadline=None)
@given(
    n_q=st.lists(st.integers(0, 1000), min_size=1, max_size=8),
    n_walkers=st.integers(8, 512),
)
def test_allocate_walkers_partition(n_q, n_walkers):
    n_q_a = jnp.asarray(n_q, jnp.int32)
    slot, _ = sampling.allocate_walkers(n_q_a, n_walkers)
    slot = np.asarray(slot)
    assert slot.shape == (n_walkers,)
    assert (slot >= 0).all() and (slot < len(n_q)).all()
    # walkers assigned to zero-budget slots only if every slot is zero
    if sum(n_q) > 0:
        used = set(slot.tolist())
        zero_slots = {i for i, v in enumerate(n_q) if v == 0}
        # at most rounding spill into zero slots
        assert len(used & zero_slots) <= max(1, len(n_q) // 2)
