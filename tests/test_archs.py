"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs — one test per assigned arch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get_arch
from repro.data.pipeline import ClickLogPipeline, SeqRecPipeline, TokenPipeline
from repro.graphs import gnn_data
from repro.models import dlrm as dlrm_lib
from repro.models import gnn as gnn_lib
from repro.models import sequential_rec as sr
from repro.models import transformer as tf
from repro.training import optim

LM_ARCHS = [
    "qwen2.5-3b", "minitron-4b", "smollm-360m",
    "granite-moe-3b-a800m", "deepseek-moe-16b",
]


def _assert_finite(tree):
    for leaf in jax.tree.leaves(tree):
        assert not bool(jnp.isnan(leaf).any()), "NaN in output"
        assert not bool(jnp.isinf(leaf).any()), "Inf in output"


def test_registry_has_all_assigned_archs():
    names = set(all_archs())
    assigned = set(LM_ARCHS) | {
        "gin-tu", "dlrm-mlperf", "dlrm-rm2", "sasrec", "bst",
    }
    assert assigned <= names
    assert "pixie" in names


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    spec = get_arch(arch)
    cfg = spec.smoke_config
    params = tf.init_params(jax.random.key(0), cfg)
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, batch=4, seq_len=16)
    batch = jax.tree.map(jnp.asarray, pipe(0))

    def loss_fn(p):
        return tf.loss_fn(p, batch["tokens"], batch["labels"], batch["mask"], cfg)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert loss.shape == ()
    _assert_finite(loss)
    _assert_finite(grads)
    # one optimizer step moves the loss
    state = optim.init(params)
    new_params, _, _ = optim.apply_updates(
        params, grads, state, optim.AdamWConfig(lr=1e-2, warmup_steps=1)
    )
    l2 = loss_fn(new_params)
    assert float(l2) < float(loss) + 1.0  # moved, not exploded
    _assert_finite(l2)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_decode_step(arch):
    spec = get_arch(arch)
    cfg = spec.smoke_config
    params = tf.init_params(jax.random.key(0), cfg)
    b, s = 2, 8
    cache = tf.init_kv_cache(cfg, b, s)
    tokens = jax.random.randint(jax.random.key(1), (b,), 0, cfg.vocab_size)
    logits, cache = tf.decode_step(
        params, cache, tokens, jnp.asarray(0, jnp.int32), cfg
    )
    assert logits.shape == (b, cfg.vocab_size)
    _assert_finite(logits)
    assert cache["k"].shape[0] == cfg.n_layers


def test_gin_smoke_all_cells():
    spec = get_arch("gin-tu")
    cfg = spec.smoke_config
    # full-graph cell (reduced cora-like)
    g = gnn_data.cora_like(scale=0.05)
    gcfg = gnn_lib.GINConfig(
        name="t", n_layers=cfg.n_layers, d_hidden=cfg.d_hidden,
        d_in=g.feats.shape[1], n_classes=7,
    )
    params = gnn_lib.init_params(jax.random.key(0), gcfg)

    def loss_fn(p):
        return gnn_lib.node_classification_loss(
            p, jnp.asarray(g.feats), jnp.asarray(g.edge_src),
            jnp.asarray(g.edge_dst), jnp.asarray(g.labels),
            jnp.asarray(g.train_mask), gcfg,
        )

    loss, grads = jax.value_and_grad(loss_fn)(params)
    _assert_finite(loss)
    _assert_finite(grads)

    # molecule cell (batched graphs, sum readout)
    mb = gnn_data.molecule_batch(batch=8, d_feat=16)
    mcfg = gnn_lib.GINConfig(
        name="m", n_layers=cfg.n_layers, d_hidden=cfg.d_hidden,
        d_in=16, n_classes=2, readout="sum",
    )
    mp = gnn_lib.init_params(jax.random.key(1), mcfg)
    out = gnn_lib.forward(
        mp, jnp.asarray(mb.feats), jnp.asarray(mb.edge_src),
        jnp.asarray(mb.edge_dst), mcfg,
        graph_ids=jnp.asarray(mb.graph_ids), n_graphs=8,
    )
    assert out.shape == (8, 2)
    _assert_finite(out)


@pytest.mark.parametrize("arch", ["dlrm-mlperf", "dlrm-rm2"])
def test_dlrm_smoke(arch):
    spec = get_arch(arch)
    cfg = spec.smoke_config
    params = dlrm_lib.init_params(jax.random.key(0), cfg)
    pipe = ClickLogPipeline(
        n_dense=cfg.n_dense, feature_rows=cfg.feature_rows, batch=16
    )
    b = pipe(0)
    logits = dlrm_lib.forward(
        params, jnp.asarray(b["dense"]), jnp.asarray(b["sparse"]), cfg
    )
    assert logits.shape == (16,)
    _assert_finite(logits)
    loss, grads = jax.value_and_grad(dlrm_lib.bce_loss)(
        params, jnp.asarray(b["dense"]), jnp.asarray(b["sparse"]),
        jnp.asarray(b["labels"]), cfg,
    )
    _assert_finite(loss)
    _assert_finite(grads)
    # retrieval cell
    s, i = dlrm_lib.retrieval_score(
        params, jnp.asarray(b["dense"][0]), jnp.asarray(b["sparse"][0]),
        jnp.arange(50), cfg, top_k=5,
    )
    assert s.shape == (5,)
    _assert_finite(s)


def test_sasrec_smoke():
    spec = get_arch("sasrec")
    cfg = spec.smoke_config
    params = sr.init_params(jax.random.key(0), cfg)
    pipe = SeqRecPipeline(
        n_items=cfg.n_items, batch=8, seq_len=cfg.seq_len,
        n_negatives=cfg.n_negatives,
    )
    b = pipe(0)
    loss, grads = jax.value_and_grad(sr.sasrec_loss)(
        params, jnp.asarray(b["seq"]), jnp.asarray(b["targets"]),
        jnp.asarray(b["negatives"]), cfg,
    )
    _assert_finite(loss)
    _assert_finite(grads)
    us = sr.sasrec_user_state(params, jnp.asarray(b["seq"]), cfg)
    assert us.shape == (8, cfg.embed_dim)
    sv, si = sr.score_candidates(params, us, jnp.arange(100), cfg, top_k=7)
    assert sv.shape == (8, 7)
    _assert_finite(sv)


def test_bst_smoke():
    spec = get_arch("bst")
    cfg = spec.smoke_config
    params = sr.init_params(jax.random.key(0), cfg)
    pipe = SeqRecPipeline(
        n_items=cfg.n_items, batch=8, seq_len=cfg.seq_len, with_candidate=True
    )
    b = pipe(0)
    loss, grads = jax.value_and_grad(sr.bst_loss)(
        params, jnp.asarray(b["seq"]), jnp.asarray(b["candidate"]),
        jnp.asarray(b["labels"]), cfg,
    )
    _assert_finite(loss)
    _assert_finite(grads)
    logits = sr.bst_forward(
        params, jnp.asarray(b["seq"]), jnp.asarray(b["candidate"]), cfg
    )
    assert logits.shape == (8,)


def test_pixie_smoke():
    from repro.core import walk as walk_lib
    from repro.graphs.synthetic import small_test_graph, top_degree_pins

    spec = get_arch("pixie")
    cfg = spec.smoke_config
    sg = small_test_graph()
    qs = top_degree_pins(sg, 2)
    qp = jnp.full((cfg.n_slots,), -1, jnp.int32).at[:2].set(jnp.asarray(qs[:2]))
    qw = jnp.zeros((cfg.n_slots,), jnp.float32).at[:2].set(1.0)
    scores, ids = walk_lib.recommend(
        sg.graph, qp, qw, jnp.asarray(0, jnp.int32), jax.random.key(0),
        cfg.walk,
    )
    assert scores.shape == (cfg.walk.top_k,)
    assert bool((scores[:5] > 0).all())
    _assert_finite(scores)


@pytest.mark.parametrize("arch", sorted(set(LM_ARCHS)))
def test_lm_param_count_matches_shapes(arch):
    """cfg.physical_param_count() must equal the real tree (and equal
    param_count() when no head padding is configured)."""
    spec = get_arch(arch)
    cfg = spec.smoke_config
    params = tf.init_params(jax.random.key(0), cfg)
    n_actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    assert n_actual == cfg.physical_param_count()
    if cfg.pad_heads_to is None:
        assert n_actual == cfg.param_count()
    # full configs: padding accounted exactly
    full = spec.config
    pf = tf.init_params(
        jax.random.key(0),
        # scale down depth only — widths stay exact
        __import__("dataclasses").replace(full, n_layers=2 + (1 if full.first_dense_ff else 0)),
    ) if False else None
    assert full.physical_param_count() >= full.param_count()
