"""Pod-sharded batched fused walk engine (core/distributed.py).

The acceptance claims of the sharded engine, each pinned here:

  * **Bit parity.**  On a replicated-graph control, the sharded engine's
    folded counts / board counts / ``steps_taken`` / ``n_high`` are
    bit-identical to ``walk.pixie_random_walk_batched`` — fused pallas
    supersteps (both gather modes) AND the plain-XLA oracle twin, across
    shard counts, with Algorithm 3's early stopping active and zero
    routed-walker drops.
  * **Drops are counted, never silent.**  Starving the ``_route`` fabric
    (tiny ``slack``) produces a positive ``dropped`` tally surfaced all
    the way through ``serve_batch(with_stats=True)``; raising ``slack``
    drives it back to zero — at which point sharded serving's scores
    match unsharded serving exactly.
  * **Per-shard supersteps, not per-query.**  The number of fused
    ``pallas_call``s in a sharded superstep is independent of the batch
    size (the whole batch shares each shard's kernels), and the
    early-stop fold inside the ``while`` body is the incremental carried
    tally — no reduction over a full count buffer.
  * **``shard_graph`` edge cases.**  Indivisible id spaces pad with
    degree-0 ghost rows, empty shard-local CSR rows survive the slicing,
    and ``abstract_sharded_graph`` (the dry-run stand-in) agrees with
    ``shard_graph``'s real output on shapes, dtypes and padded sizes.

Multi-device tests run in subprocesses (device count locks at jax init);
trace-only structural pins run in-process on a 1-device model mesh.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import counter as counter_lib
from repro.core import distributed as dist_lib
from repro.core import walk as walk_lib
from repro.core.graph import build_graph
from repro.graphs.synthetic import small_test_graph, top_degree_pins
from repro.launch.mesh import make_mesh_compat, set_mesh_compat
from test_distributed import _run
from test_earlystop_parity import _full_buffer_reduces, _iter_eqns


# ---------------------------------------------------------------------------
# bit parity vs the unsharded batched engine (replicated-graph control)
# ---------------------------------------------------------------------------

_PARITY_BODY = """
    import dataclasses
    from repro.graphs.synthetic import small_test_graph, top_degree_pins
    from repro.core import counter as C, distributed as D, walk as W

    n_shards = %d
    sg = small_test_graph()
    g = sg.graph
    mesh = make_mesh_compat(%s)
    shg = D.shard_graph(g, n_shards)
    qs = top_degree_pins(sg, 4)
    qp = jnp.asarray([[int(qs[0]), int(qs[1]), -1, -1],
                      [int(qs[2]), int(qs[3]), int(qs[0]), -1]], jnp.int32)
    qw = jnp.asarray([[1.0, 0.7, 0.0, 0.0],
                      [1.0, 0.5, 0.25, 0.0]], jnp.float32)
    uf = jnp.zeros((2,), jnp.int32)
    keys = jax.random.split(jax.random.key(7), 2)
    base = W.WalkConfig(n_steps=6144, n_walkers=64, chunk_steps=4,
                        n_p=30, n_v=3, bias_beta=0.0, count_boards=True)

    out = {}
    with set_mesh_compat(mesh):
        for backend, gather in (("xla", "scalar"), ("pallas", "scalar"),
                                ("pallas", "dma")):
            cfg = dataclasses.replace(base, backend=backend,
                                      gather_mode=gather)
            ref = W.pixie_random_walk_batched(g, qp, qw, uf, keys, cfg)
            res = D.pixie_walk_sharded_batched(
                shg, qp, qw, keys, cfg, mesh, slack=2.0 * n_shards)
            counts = C.fold_sharded_counts(
                res.counts, 2, 4, shg.pins_per_shard)[..., :g.n_pins]
            bc = C.fold_sharded_counts(
                res.board_counts, 2, 4,
                shg.boards_per_shard)[..., :g.n_boards]
            out[backend + "/" + gather] = {
                "counts": bool((np.asarray(counts)
                                == np.asarray(ref.counts)).all()),
                "boards": bool((np.asarray(bc)
                                == np.asarray(ref.board_counts)).all()),
                "steps": bool((np.asarray(res.steps_taken)
                               == np.asarray(ref.steps_taken)).all()),
                "n_high": bool((np.asarray(res.n_high)
                                == np.asarray(ref.n_high)).all()),
                "dropped": int(res.dropped),
                "stopped_early": bool(
                    (np.asarray(ref.n_high) > cfg.n_p).any()),
            }
    print(json.dumps(out))
"""


@pytest.mark.parametrize(
    "n_shards,mesh_spec",
    [(2, '(2, 2), ("data", "model")'), (4, '(4,), ("model",)')],
)
def test_sharded_engine_bit_parity_with_unsharded_batched(
    n_shards, mesh_spec
):
    """Acceptance criterion: fused sharded == xla sharded == unsharded
    batched, bit-for-bit, with early stopping active and zero drops."""
    res = _run(4, _PARITY_BODY % (n_shards, mesh_spec))
    for combo, r in res.items():
        assert r["dropped"] == 0, (combo, r)
        assert r["counts"] and r["boards"], (combo, r)
        assert r["steps"] and r["n_high"], (combo, r)
        # the control is only meaningful if Algorithm 3 actually fired
        assert r["stopped_early"], (combo, r)


# ---------------------------------------------------------------------------
# routing-overflow drops: counted, surfaced, tunable to zero
# ---------------------------------------------------------------------------


def test_route_drops_counted_and_zeroed_by_slack():
    """Capacity overflow must never be silent: a starved fabric reports a
    positive ``dropped`` through ``serve_batch(with_stats=True)`` and
    through ``ShardedWalkConfig.slack``; raising slack zeroes it, and a
    drop-free sharded serve matches unsharded serving score-for-score."""
    res = _run(4, """
        import dataclasses
        from repro.graphs.synthetic import small_test_graph, top_degree_pins
        from repro.core import distributed as D, service as S, walk as W

        sg = small_test_graph()
        g = sg.graph
        mesh = make_mesh_compat((2, 2), ("data", "model"))
        shg = D.shard_graph(g, 2)
        qs = top_degree_pins(sg, 4)
        qp = jnp.asarray([[int(qs[0]), int(qs[1]), -1, -1],
                          [int(qs[2]), int(qs[3]), -1, -1]], jnp.int32)
        qw = jnp.asarray([[1.0, 0.7, 0.0, 0.0],
                          [1.0, 0.5, 0.0, 0.0]], jnp.float32)
        uf = jnp.zeros((2,), jnp.int32)
        key = jax.random.key(11)
        cfg = W.WalkConfig(n_steps=8192, n_walkers=256, chunk_steps=4,
                           n_p=10**9, n_v=10**9, bias_beta=0.0, top_k=25)

        out = {}
        with set_mesh_compat(mesh):
            starved = S.serve_batch(shg, qp, qw, uf, key, cfg,
                                    with_stats=True, mesh=mesh, slack=0.05)
            roomy = S.serve_batch(shg, qp, qw, uf, key, cfg,
                                  with_stats=True, mesh=mesh, slack=4.0)
            plain = S.serve_batch(g, qp, qw, uf, key, cfg, with_stats=True)
            wcfg = D.ShardedWalkConfig(
                n_supersteps=32, walkers_per_shard=128, top_k=25, slack=0.05)
            starved_w = D.pixie_walk_sharded(
                shg, qp[0], qw[0], jax.random.key(3), wcfg, mesh)
            roomy_w = D.pixie_walk_sharded(
                shg, qp[0], qw[0], jax.random.key(3),
                dataclasses.replace(wcfg, slack=8.0), mesh)
        out["starved_len"] = len(starved)
        out["roomy_len"] = len(roomy)
        out["starved_dropped"] = int(starved[4])
        out["roomy_dropped"] = int(roomy[4])
        out["scores_match"] = bool(
            (np.asarray(roomy[0]) == np.asarray(plain[0])).all())
        out["steps_match"] = bool(
            (np.asarray(roomy[2]) == np.asarray(plain[2])).all())
        out["wrapper_starved"] = int(starved_w.dropped)
        out["wrapper_roomy"] = int(roomy_w.dropped)
        print(json.dumps(out))
    """)
    # the 5th stats element is the drop counter (scores, ids, steps,
    # n_high, dropped)
    assert res["starved_len"] == 5 and res["roomy_len"] == 5
    assert res["starved_dropped"] > 0, res
    assert res["roomy_dropped"] == 0, res
    assert res["wrapper_starved"] > 0, res
    assert res["wrapper_roomy"] == 0, res
    # drop-free sharded serving reproduces unsharded serving exactly
    assert res["scores_match"] and res["steps_match"], res


def test_pixie_server_serves_sharded_replica():
    """The serving fleet path: a PixieServer holding a ShardedGraph
    replica routes through the pod-sharded engine and returns the same
    scores as a plain replica on the unsharded graph (same seed, same
    batching); the daily graph swap re-jits the sharded program."""
    res = _run(4, """
        from repro.graphs.synthetic import small_test_graph, top_degree_pins
        from repro.core import distributed as D, walk as W
        from repro.serving.server import PixieServer

        sg = small_test_graph()
        mesh = make_mesh_compat((2, 2), ("data", "model"))
        shg = D.shard_graph(sg.graph, 2)
        qs = [int(x) for x in top_degree_pins(sg, 4)]
        cfg = W.WalkConfig(n_steps=4096, n_walkers=128, chunk_steps=4,
                           n_p=10**9, n_v=10**9, bias_beta=0.0, top_k=15)
        with set_mesh_compat(mesh):
            srv = PixieServer(shg, cfg, batch_size=2, n_slots=4, seed=5,
                              mesh=mesh, slack=4.0)
            ref = PixieServer(sg.graph, cfg, batch_size=2, n_slots=4,
                              seed=5)
            for s in (srv, ref):
                s.submit(qs[:2], [1.0, 0.6])
                s.submit(qs[2:3], [1.0])
                s.submit(qs[3:4], [0.8])
            got = srv.flush()
            want = ref.flush()
            match = all(
                bool((np.asarray(a[0]) == np.asarray(b[0])).all())
                for a, b in zip(got, want)
            )
            srv.swap_graph(D.shard_graph(sg.graph, 2))
            srv.submit(qs[:1], [1.0])
            post_swap = srv.flush()
        print(json.dumps({
            "n": len(got), "match": match,
            "generation": srv.stats.graph_generation,
            "post_swap_scored": bool(np.asarray(post_swap[0][0]).max() > 0),
        }))
    """)
    assert res["n"] == 3
    assert res["match"], res
    assert res["generation"] == 1
    assert res["post_swap_scored"], res


# ---------------------------------------------------------------------------
# structural pins: per-shard kernels, incremental early-stop fold
# ---------------------------------------------------------------------------


def _traced_sharded_walk(n_queries, backend, count_boards=True):
    g = small_test_graph().graph
    mesh = make_mesh_compat((1,), ("model",))
    shg = dist_lib.shard_graph(g, 1)
    qp = jnp.tile(jnp.asarray([[3, 9, -1, -1]], jnp.int32), (n_queries, 1))
    qw = jnp.tile(
        jnp.asarray([[1.0, 0.5, 0.0, 0.0]], jnp.float32), (n_queries, 1)
    )
    cfg = walk_lib.WalkConfig(
        n_steps=2048, n_walkers=64, chunk_steps=4, n_p=40, n_v=3,
        bias_beta=0.0, count_boards=count_boards, backend=backend,
    )
    jaxpr = jax.make_jaxpr(
        lambda ks: dist_lib.pixie_walk_sharded_batched(
            shg, qp, qw, ks, cfg, mesh
        )
    )(jax.random.split(jax.random.key(0), n_queries)).jaxpr
    return jaxpr, shg, cfg


def test_superstep_pallas_calls_per_shard_not_per_query():
    """Acceptance criterion: a sharded superstep runs the fused kernels
    once per SHARD — the pallas_call count in the traced program is
    independent of the batch size (the whole batch shares each shard's
    hop + counter kernels) and covers both hops plus both counters."""
    n_calls = {}
    for b in (1, 4):
        jaxpr, _, _ = _traced_sharded_walk(b, "pallas")
        n_calls[b] = sum(
            1 for e in _iter_eqns(jaxpr) if e.primitive.name == "pallas_call"
        )
    # 2 walk hops + visit counter + board counter per superstep trace
    assert n_calls[1] >= 4, n_calls
    assert n_calls[1] == n_calls[4], (
        f"pallas_call count scales with batch size: {n_calls}"
    )


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_sharded_while_body_has_no_full_buffer_reduction(backend):
    """Acceptance criterion: the early-stop fold in the sharded chunk loop
    is the incrementally carried ``high`` tally — no reduction over the
    (query, slot, pin)-sized count buffer inside any while body."""
    n_queries = 2
    jaxpr, shg, _ = _traced_sharded_walk(n_queries, backend)
    whiles = [e for e in _iter_eqns(jaxpr) if e.primitive.name == "while"]
    assert whiles, "sharded walk lost its chunk while loop?"
    n_bins = n_queries * 4 * shg.pins_per_shard
    for w in whiles:
        found = _full_buffer_reduces(w.params["body_jaxpr"].jaxpr, n_bins)
        assert not found, (
            f"sharded while body reduces a full count buffer on "
            f"{backend}: {found}"
        )


def test_unrolled_cost_model_mode_is_loop_free():
    """launch/dryrun's cost-model mode (``unroll=True``) must contain no
    while/fori loops at all — XLA cost analysis needs a flat program."""
    g = small_test_graph().graph
    mesh = make_mesh_compat((1,), ("model",))
    shg = dist_lib.shard_graph(g, 1)
    qp = jnp.asarray([[3, 9, -1, -1]], jnp.int32)
    qw = jnp.asarray([[1.0, 0.5, 0.0, 0.0]], jnp.float32)
    cfg = walk_lib.WalkConfig(
        n_steps=512, n_walkers=64, chunk_steps=4, n_p=10**9, n_v=10**9,
        bias_beta=0.0,
    )
    jaxpr = jax.make_jaxpr(
        lambda ks: dist_lib.pixie_walk_sharded_batched(
            shg, qp, qw, ks, cfg, mesh, unroll=True
        )
    )(jax.random.split(jax.random.key(0), 1)).jaxpr
    assert not any(
        e.primitive.name in ("while", "scan") for e in _iter_eqns(jaxpr)
    )


# ---------------------------------------------------------------------------
# shard_graph edge cases
# ---------------------------------------------------------------------------


def _tiny_graph(n_pins=10, n_boards=7):
    """10 pins / 7 boards with pins 4 and 7 deliberately degree-0 and
    board 5 empty — exercises ghost-row padding and empty CSR rows."""
    edges = [
        (0, 0), (0, 1), (1, 0), (2, 2), (3, 3), (5, 1), (5, 4),
        (6, 6), (8, 2), (9, 6), (9, 0),
    ]
    pins = np.asarray([e[0] for e in edges])
    boards = np.asarray([e[1] for e in edges])
    return build_graph(pins, boards, n_pins=n_pins, n_boards=n_boards)


def test_shard_graph_pads_indivisible_id_spaces():
    g = _tiny_graph()
    shg = dist_lib.shard_graph(g, 3)
    # 10 pins / 7 boards round up to 12 / 9 across 3 shards
    assert shg.n_pins == 12 and shg.pins_per_shard == 4
    assert shg.n_boards == 9 and shg.boards_per_shard == 3
    assert shg.p2b_offsets.shape == (3, 5)
    assert shg.b2p_offsets.shape == (3, 4)
    assert shg.max_pin_degree == g.max_pin_degree

    # per-pin degrees survive the slicing; ghost pins 10, 11 are degree 0
    ref_deg = np.diff(np.asarray(g.p2b.offsets))
    off = np.asarray(shg.p2b_offsets)
    for s in range(3):
        assert (np.diff(off[s]) >= 0).all()  # offsets stay monotone
        for r in range(4):
            pin = s * 4 + r
            want = int(ref_deg[pin]) if pin < g.n_pins else 0
            assert off[s, r + 1] - off[s, r] == want, (pin, s, r)

    # sliced targets are the original rows: board *indices* on p2b,
    # global pin ids on b2p
    p_tgt = np.asarray(g.p2b.targets) - g.n_pins
    s_tgt = np.asarray(shg.p2b_targets)
    for pin in range(g.n_pins):
        s, r = divmod(pin, 4)
        got = s_tgt[s, off[s, r]:off[s, r + 1]]
        want = p_tgt[
            int(g.p2b.offsets[pin]):int(g.p2b.offsets[pin + 1])
        ]
        np.testing.assert_array_equal(got, want)
    boff = np.asarray(shg.b2p_offsets)
    b_tgt = np.asarray(shg.b2p_targets)
    for s in range(3):
        seg = b_tgt[s, :boff[s, -1]]
        assert ((seg >= 0) & (seg < g.n_pins)).all()


def test_shard_graph_keeps_empty_local_rows():
    """Degree-0 pins/boards inside a shard's owned range stay empty rows
    (not dropped, not collapsed) so local hops on them dead-end cleanly."""
    g = _tiny_graph()
    shg = dist_lib.shard_graph(g, 2)  # pps=5: pins 4 (shard 0), 7 (shard 1)
    off = np.asarray(shg.p2b_offsets)
    assert off[0, 5] - off[0, 4] == 0        # pin 4, empty, mid-shard
    assert off[1, 3] - off[1, 2] == 0        # pin 7, empty
    boff = np.asarray(shg.b2p_offsets)
    s, r = divmod(5, shg.boards_per_shard)   # board 5 has no pins
    assert boff[s, r + 1] - boff[s, r] == 0
    # a walk on the sharded graph with an empty-row query pin still runs
    mesh = make_mesh_compat((1,), ("model",))
    shg1 = dist_lib.shard_graph(g, 1)
    cfg = walk_lib.WalkConfig(
        n_steps=256, n_walkers=32, chunk_steps=4, n_p=10**9, n_v=10**9,
        bias_beta=0.0,
    )
    res = dist_lib.pixie_walk_sharded_batched(
        shg1,
        jnp.asarray([[4, 0, -1, -1]], jnp.int32),
        jnp.asarray([[1.0, 1.0, 0.0, 0.0]], jnp.float32),
        jax.random.split(jax.random.key(0), 1), cfg, mesh,
    )
    counts = counter_lib.fold_sharded_counts(
        res.counts, 1, 4, shg1.pins_per_shard
    )
    # the dead-end slot visits nothing; the live slot walks normally
    assert int(np.asarray(counts)[0, 0].sum()) == 0
    assert int(np.asarray(counts)[0, 1].sum()) > 0
    assert int(res.dropped) == 0


def test_abstract_sharded_graph_agrees_with_shard_graph():
    """The dry-run stand-in must lower with the same structure the real
    ``shard_graph`` output carries: identical offset shapes, int32 arrays
    throughout, padded id spaces, and target capacity >= reality."""
    g = small_test_graph().graph
    n_shards = 4
    real = dist_lib.shard_graph(g, n_shards)
    n_edges = int(np.asarray(g.p2b.offsets)[-1])
    abstract = dist_lib.abstract_sharded_graph(
        g.n_pins, g.n_boards, n_edges, n_shards
    )
    assert abstract.p2b_offsets.shape == real.p2b_offsets.shape
    assert abstract.b2p_offsets.shape == real.b2p_offsets.shape
    assert abstract.n_pins == real.n_pins
    assert abstract.n_boards == real.n_boards
    assert abstract.n_shards == real.n_shards
    for name in ("p2b_offsets", "p2b_targets", "b2p_offsets", "b2p_targets"):
        a, r = getattr(abstract, name), getattr(real, name)
        assert a.dtype == r.dtype == jnp.int32, name
        assert a.shape[0] == n_shards, name
        # abstract target capacity covers the real (balanced) slice widths
        assert a.shape[1] >= 1
    assert abstract.p2b_targets.shape[1] >= real.p2b_targets.shape[1]
    assert abstract.b2p_targets.shape[1] >= real.b2p_targets.shape[1]
    # the partition specs cover exactly the four device arrays
    specs = dist_lib.sharded_graph_specs()
    from jax.sharding import PartitionSpec as P

    for name in ("p2b_offsets", "p2b_targets", "b2p_offsets", "b2p_targets"):
        assert getattr(specs, name) == P("model", None), name
