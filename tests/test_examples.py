"""Smoke coverage for the runnable examples (previously zero test
coverage on ``examples/``): each example's ``main`` runs end to end on a
tiny graph with shrunk budgets — the same code path as the documented
``PYTHONPATH=src python examples/<name>.py`` invocation, parameterized
down so the whole file stays in CI's tier-1 budget."""

import importlib.util
import pathlib
import sys

import numpy as np
import pytest

EXAMPLES_DIR = pathlib.Path(__file__).resolve().parent.parent / "examples"


def _load(name: str):
    spec = importlib.util.spec_from_file_location(
        f"examples_{name}", EXAMPLES_DIR / f"{name}.py"
    )
    mod = importlib.util.module_from_spec(spec)
    # register before exec so dataclasses/typing introspection inside the
    # example can resolve its own module
    sys.modules[spec.name] = mod
    spec.loader.exec_module(mod)
    return mod


def test_serve_fleet_example_smoke():
    mod = _load("serve_fleet")
    stats = mod.main(
        n_pins=600, n_boards=80, n_requests=6, n_steps=512, n_walkers=64,
        top_k=10, batch_size=2,
    )
    assert stats.queries == 6
    # the mid-stream graph swap really happened and serving continued
    assert stats.graph_generation == 1
    assert stats.batches >= 3
    assert stats.percentile(50) > 0


def test_open_loop_traffic_example_smoke():
    mod = _load("open_loop_traffic")
    report = mod.main(
        n_pins=600, n_boards=80, n_requests=8, offered_qps=400.0,
        n_steps=512, n_walkers=64, top_k=10, max_pins=4,
    )
    assert report.n_served + report.n_dropped == 8
    assert report.n_served > 0
    # the mid-stream swap really happened: both generations observable
    # only when some batch dispatched before it — at minimum the swap
    # bumped the server generation and post-swap requests carry it
    assert max(report.generations.values()) == 1
    assert report.percentile(99) >= report.percentile(50) > 0


def test_sharded_walk_example_smoke():
    # single-device in-process configuration (n_shards=1 on a (1,) mesh);
    # the multi-device path is covered by tests/test_sharded_engine.py's
    # subprocess runs
    mod = _load("sharded_walk")
    overlap, dropped = mod.main(
        n_pins=500, n_boards=60, n_shards=1, mesh_shape=(1,),
        n_supersteps=32, walkers_per_shard=128, top_k=10, slack=4.0,
    )
    assert overlap >= 5
    assert dropped == 0  # one shard: every route is shard-local


def test_two_stage_recsys_example_smoke():
    mod = _load("two_stage_recsys")
    scores, items, fused_scores, fused_items = mod.main(
        n_pins=400, n_boards=60, train_steps=2, walk_steps=512,
        n_walkers=64, final_k=5,
    )
    scores, items = np.asarray(scores), np.asarray(items)
    assert items.shape == (5,)
    finite = np.isfinite(scores)
    assert finite.any()
    # ranked items are real graph items, never the -inf padding id
    assert ((items[finite] >= 0) & (items[finite] < 400)).all()
    # fused path: one row per scenario head, same contracts per row
    fused_scores = np.asarray(fused_scores)
    fused_items = np.asarray(fused_items)
    assert fused_items.shape == (2, 5) and fused_scores.shape == (2, 5)
    ffin = np.isfinite(fused_scores)
    assert ffin.any(axis=1).all()
    assert (
        (fused_items[ffin] >= 0) & (fused_items[ffin] < 400)
    ).all()
    # the two scenario heads rank the same retrieval differently
    assert not np.array_equal(fused_scores[0], fused_scores[1])


def test_multi_interest_user_example_smoke():
    mod = _load("multi_interest_user")
    scores, ids, results, agree = mod.main(
        n_pins=400, n_boards=60, n_users=3, n_clusters=2, n_steps=512,
        n_walkers=64, top_k=8,
    )
    scores, ids = np.asarray(scores), np.asarray(ids)
    assert scores.shape == ids.shape == (3, 8)
    assert agree  # server path bit-identical to the fused path
    assert len(results) == 3
    live = ids >= 0
    assert live.any(axis=1).all()  # every user got recommendations
    assert (ids[live] < 400).all()
    # merged scores sorted descending per user over the live prefix
    for u in range(3):
        s = scores[u][live[u]]
        assert (np.diff(s) <= 0).all()
