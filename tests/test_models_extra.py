"""Model-substrate invariants beyond the per-arch smokes: MoE dispatch vs
dense oracle, prefill/decode/forward consistency, embedding-bag parity,
data-pipeline determinism, GIN permutation invariance."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis, or seeded fallback

from repro.data.pipeline import ClickLogPipeline, SeqRecPipeline, TokenPipeline
from repro.models import embedding as emb_lib
from repro.models import gnn as gnn_lib
from repro.models import transformer as tf
from repro.models.moe import MoEConfig, init_moe_params, moe_ffn


# ---------------------------------------------------------------------------
# MoE: sort-based capacity dispatch == dense all-experts oracle
# ---------------------------------------------------------------------------


def _dense_moe_oracle(x, params, cfg):
    """Compute every expert on every token; combine with top-k gates."""
    logits = x @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, sel = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    g = jnp.einsum("td,edf->tef", x, params["w_gate"])
    u = jnp.einsum("td,edf->tef", x, params["w_up"])
    h = jax.nn.silu(g) * u
    y = jnp.einsum("tef,efd->ted", h, params["w_down"])   # (t, e, d)
    comb = jnp.zeros((x.shape[0], cfg.n_experts))
    comb = comb.at[jnp.arange(x.shape[0])[:, None], sel].set(gate)
    out = jnp.einsum("te,ted->td", comb, y)
    if cfg.n_shared:
        gs = x @ params["shared_gate"]
        us = x @ params["shared_up"]
        out = out + (jax.nn.silu(gs) * us) @ params["shared_down"]
    return out


@pytest.mark.parametrize("n_shared", [0, 1])
def test_moe_dispatch_matches_dense_oracle(n_shared):
    cfg = MoEConfig(
        n_experts=8, top_k=2, d_ff_expert=16, n_shared=n_shared,
        capacity_factor=8.0,  # high capacity: no drops -> exact match
    )
    params = init_moe_params(jax.random.key(0), 32, cfg)
    x = jax.random.normal(jax.random.key(1), (64, 32))
    got, aux = moe_ffn(x, params, cfg)
    want = _dense_moe_oracle(x, params, cfg)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4
    )
    assert float(aux) >= 0.0


def test_moe_capacity_drops_tokens_but_stays_finite():
    cfg = MoEConfig(
        n_experts=4, top_k=2, d_ff_expert=8, capacity_factor=0.25
    )
    params = init_moe_params(jax.random.key(0), 16, cfg)
    x = jax.random.normal(jax.random.key(1), (128, 16))
    out, _ = moe_ffn(x, params, cfg)
    assert not bool(jnp.isnan(out).any())
    # dropped tokens exist: output norm below the no-drop oracle's
    want = _dense_moe_oracle(x, params, cfg)
    assert float(jnp.linalg.norm(out)) <= float(jnp.linalg.norm(want)) + 1e-3


# ---------------------------------------------------------------------------
# decode == forward (causal consistency across the serving path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("moe", [False, True])
def test_prefill_plus_decode_matches_forward(moe):
    cfg = tf.LMConfig(
        name="t", n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
        head_dim=12, d_ff=96, vocab_size=160, qkv_bias=True, remat=False,
        compute_dtype=jnp.float32, cache_dtype=jnp.float32,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=24,
                      capacity_factor=8.0) if moe else None,
    )
    params = tf.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 10), 0, 160)

    # ground truth: full forward logits at every position
    h, _ = tf.forward(params, toks, cfg)
    head = tf.lm_head_weight(params, cfg)
    full = h @ head

    # serving path: prefill 6 tokens, decode 4 more (bf16 KV cache
    # rounding bounds the tolerance)
    logits_p, cache = tf.prefill(params, toks[:, :6], cfg, max_seq=10)
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full[:, 5]), rtol=2e-4, atol=2e-4
    )
    for i in range(6, 10):
        logits_d, cache = tf.decode_step(
            params, cache, toks[:, i], jnp.asarray(i, jnp.int32), cfg
        )
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(full[:, i]),
            rtol=2e-4, atol=2e-4,
            err_msg=f"decode step {i}",
        )


# ---------------------------------------------------------------------------
# embedding substrate
# ---------------------------------------------------------------------------


def test_pooled_lookup_matches_kernel_ref():
    from repro.kernels import ref as kref

    cfg = emb_lib.MegaTableConfig(
        feature_rows=(30,), dim=16, pad_to_multiple=1
    )
    table = jax.random.normal(jax.random.key(0), (30, 16))
    ids = jax.random.randint(jax.random.key(1), (8, 1, 5), -1, 30)
    got = emb_lib.pooled_lookup(table, ids, cfg, mode="sum")[:, 0]
    want = kref.embedding_bag_ref(table, ids[:, 0], mode="sum")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(rows=st.lists(st.integers(1, 50), min_size=1, max_size=6))
def test_global_ids_respect_feature_offsets(rows):
    cfg = emb_lib.MegaTableConfig(
        feature_rows=tuple(rows), dim=4, pad_to_multiple=1
    )
    ids = jnp.zeros((2, len(rows)), jnp.int32)  # local id 0 per feature
    g = np.asarray(emb_lib.global_ids(ids, cfg))
    want = np.concatenate([[0], np.cumsum(rows)[:-1]])
    np.testing.assert_array_equal(g[0], want)
    # max local ids stay inside the table
    ids_max = jnp.asarray([r - 1 for r in rows], jnp.int32)[None]
    g_max = np.asarray(emb_lib.global_ids(ids_max, cfg))
    assert (g_max < sum(rows)).all()


# ---------------------------------------------------------------------------
# GNN invariants
# ---------------------------------------------------------------------------


def test_gin_edge_permutation_invariance():
    cfg = gnn_lib.GINConfig(name="t", n_layers=2, d_hidden=16, d_in=8,
                            n_classes=3)
    params = gnn_lib.init_params(jax.random.key(0), cfg)
    feats = jax.random.normal(jax.random.key(1), (20, 8))
    src = jax.random.randint(jax.random.key(2), (50,), 0, 20)
    dst = jax.random.randint(jax.random.key(3), (50,), 0, 20)
    out1 = gnn_lib.forward(params, feats, src, dst, cfg)
    perm = jax.random.permutation(jax.random.key(4), 50)
    out2 = gnn_lib.forward(params, feats, src[perm], dst[perm], cfg)
    np.testing.assert_allclose(
        np.asarray(out1), np.asarray(out2), rtol=1e-4, atol=1e-5
    )


def test_gin_isolated_node_keeps_self_signal():
    cfg = gnn_lib.GINConfig(name="t", n_layers=1, d_hidden=8, d_in=4,
                            n_classes=2)
    params = gnn_lib.init_params(jax.random.key(0), cfg)
    feats = jax.random.normal(jax.random.key(1), (4, 4))
    # node 3 has no edges: output = MLP((1+eps) h_3)
    src = jnp.asarray([0, 1], jnp.int32)
    dst = jnp.asarray([1, 0], jnp.int32)
    out = gnn_lib.forward(params, feats, src, dst, cfg)
    assert not bool(jnp.isnan(out[3]).any())
    assert float(jnp.abs(out[3]).sum()) > 0


# ---------------------------------------------------------------------------
# data pipelines: stateless determinism (the resilience contract)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pipe", [
    TokenPipeline(vocab_size=100, batch=4, seq_len=8),
    ClickLogPipeline(n_dense=3, feature_rows=(10, 20), batch=4),
    SeqRecPipeline(n_items=50, batch=4, seq_len=6, n_negatives=2),
    SeqRecPipeline(n_items=50, batch=4, seq_len=6, with_candidate=True),
])
def test_pipelines_deterministic_per_step(pipe):
    a = pipe(17)
    b = pipe(17)
    c = pipe(18)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    assert any(not np.array_equal(a[k], c[k]) for k in a)
