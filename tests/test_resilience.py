"""Degraded-mode serving: elastic shed budgets, admission accounting,
dead-shard tolerance, and the seeded fault-injection harness.

The contract under test (serving/resilience.py -> server dispatch ->
core/distributed.py -> serving/traffic.py chaos mode):

  * **Shedding is deterministic data, never shape**: a request whose
    queue wait passes ``shed_start_ms`` dispatches with a linearly
    shrunk Eq. 2 budget riding the ``(batch,)`` step_budgets axis — the
    shed result is BIT-identical to an unloaded oracle dispatched via
    ``submit(budget=...)`` with the same number, and shrinking never
    retraces the serve program.
  * **Degradation is accounted**: admission rejections land per-bucket
    in ``ServerStats.rejected`` (while ``dropped`` stays the historical
    total), shed budgets are visible on every ``QueryResult``, and dead
    shards report ``killed`` walkers and a quantified ``overlap_at_k``.
  * **Faults are pure functions of a seed**: the same ``ChaosConfig``
    draws the same ``FaultSchedule``; bursts warp arrivals monotonically
    and spikes defer dispatch to window ends, all on the virtual clock.
  * **Generation barrier** (swap-during-in-flight-user bugfix): a
    multi-interest user's generation is stamped at ``submit_user`` and
    ``swap_graph`` drains every queue before moving the handle, so one
    user's lanes can never mix graph generations.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import walk as walk_lib
from repro.graphs.synthetic import (
    sample_user_histories, small_test_graph, top_degree_pins,
    UserHistoryConfig,
)
from repro.serving.resilience import (
    ResilienceConfig, elastic_step_budget, overlap_at_k,
)
from repro.serving.server import LatencyRing, PixieServer
from repro.serving.traffic import (
    ChaosConfig, FaultEvent, FaultSchedule, OpenLoopConfig,
    apply_traffic_bursts, poisson_requests, run_open_loop,
    sample_fault_schedule,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg(**kw):
    base = dict(n_steps=512, n_walkers=32, chunk_steps=8, top_k=20,
                n_p=60, n_v=3)
    base.update(kw)
    return walk_lib.WalkConfig(**base)


# ---------------------------------------------------------------------------
# elastic_step_budget / ResilienceConfig / overlap_at_k: the pure pieces
# ---------------------------------------------------------------------------


def test_elastic_step_budget_policy_curve():
    r = ResilienceConfig(deadline_ms=60.0, shed_start_ms=10.0,
                         min_budget_frac=0.25)
    # at or below shed_start: full budget, untouched
    assert elastic_step_budget(1000, 0.0, r) == 1000
    assert elastic_step_budget(1000, 10.0, r) == 1000
    # linear shrink across the remaining window: wait=35 is halfway
    assert elastic_step_budget(1000, 35.0, r) == 500
    # floor engages before the deadline and holds past it
    assert elastic_step_budget(1000, 60.0, r) == 250
    assert elastic_step_budget(1000, 10_000.0, r) == 250
    # never below one step, even for tiny lane budgets
    assert elastic_step_budget(2, 10_000.0, r) == 1
    assert elastic_step_budget(1, 10_000.0, r) == 1


def test_resilience_config_validates():
    with pytest.raises(ValueError, match="deadline_ms"):
        ResilienceConfig(deadline_ms=0.0)
    with pytest.raises(ValueError, match="shed_start_ms"):
        ResilienceConfig(deadline_ms=10.0, shed_start_ms=10.0)
    with pytest.raises(ValueError, match="min_budget_frac"):
        ResilienceConfig(min_budget_frac=0.0)
    with pytest.raises(ValueError, match="min_budget_frac"):
        ResilienceConfig(min_budget_frac=1.5)


def test_overlap_at_k_edges():
    a = np.array([[1, 2, 3], [4, 5, 6]])
    assert overlap_at_k(a, a) == 1.0
    assert overlap_at_k(a, np.array([[7, 8, 9], [10, 11, 12]])) == 0.0
    # half the oracle's ids recovered, averaged over rows
    got = overlap_at_k(np.array([[1, 2, 7], [4, 8, 9]]), a, k=2)
    assert got == pytest.approx(0.5 * (1.0 + 0.5))
    # padding (-1) is ignored on both sides
    assert overlap_at_k(np.array([[1, 2, -1]]), np.array([[1, 2, -1]])) == 1.0
    # an all-padding oracle row: perfect iff the degraded row is too
    assert overlap_at_k(np.array([[-1, -1]]), np.array([[-1, -1]])) == 1.0
    assert overlap_at_k(np.array([[3, -1]]), np.array([[-1, -1]])) == 0.0
    # 1-D inputs promote to one row
    assert overlap_at_k(np.array([1, 2]), np.array([2, 1])) == 1.0
    with pytest.raises(ValueError, match="rows"):
        overlap_at_k(np.zeros((2, 3)), np.zeros((3, 3)))


# ---------------------------------------------------------------------------
# LatencyRing.percentile edge cases
# ---------------------------------------------------------------------------


def test_latency_ring_percentile_empty_and_single():
    ring = LatencyRing(capacity=4)
    assert ring.percentile(50) == 0.0      # idle replica: 0, not NaN
    assert ring.percentile(99) == 0.0
    ring.append(7.5)
    for p in (0, 50, 99, 100):
        assert ring.percentile(p) == 7.5   # one sample IS every percentile


def test_latency_ring_percentile_exact_capacity_wraparound():
    ring = LatencyRing(capacity=4)
    ring.extend([1.0, 2.0, 3.0, 4.0])      # exactly full, head wrapped to 0
    np.testing.assert_array_equal(ring.values(), [1.0, 2.0, 3.0, 4.0])
    assert ring.percentile(0) == 1.0
    assert ring.percentile(100) == 4.0
    assert ring.percentile(50) == pytest.approx(2.5)
    ring.append(10.0)                      # evicts the oldest (1.0)
    np.testing.assert_array_equal(ring.values(), [2.0, 3.0, 4.0, 10.0])
    assert ring.percentile(0) == 2.0
    assert ring.percentile(100) == 10.0


# ---------------------------------------------------------------------------
# Elastic shed on the server: budgets are data, results match the oracle
# ---------------------------------------------------------------------------


def test_shed_budget_matches_submit_budget_oracle():
    """A request shed at dispatch serves BIT-identically to an unloaded
    server handed the same shrunk budget via submit(budget=...) — the
    whole degradation is the budget number, not timing or batching."""
    sg = small_test_graph()
    cfg = _cfg()
    qs = top_degree_pins(sg, 4)
    rcfg = ResilienceConfig(deadline_ms=60.0, shed_start_ms=10.0,
                            min_budget_frac=0.25)
    srv = PixieServer(sg.graph, cfg, batch_size=2, n_slots=4, seed=7,
                      max_wait_ms=5.0, resilience=rcfg)
    srv.submit([int(qs[0]), int(qs[1])], [1.0, 0.6], now=0.0, req_id=0)
    srv.submit([int(qs[2])], [1.0], now=0.0, req_id=1)
    srv.pump(now=0.035)                    # 35 ms wait: halfway shrink
    shed = {r.req_id: r for r in srv.harvest()}
    want = elastic_step_budget(cfg.n_steps, 35.0, rcfg)
    assert want < cfg.n_steps
    assert shed[0].budget == want and shed[1].budget == want

    oracle = PixieServer(sg.graph, cfg, batch_size=2, n_slots=4, seed=7)
    oracle.submit([int(qs[0]), int(qs[1])], [1.0, 0.6], req_id=0,
                  budget=want)
    oracle.submit([int(qs[2])], [1.0], req_id=1, budget=want)
    ref = {r.req_id: r for r in oracle.flush()}
    for rid in (0, 1):
        np.testing.assert_array_equal(shed[rid].scores, ref[rid].scores)
        np.testing.assert_array_equal(shed[rid].ids, ref[rid].ids)
        assert ref[rid].budget == want


def test_unloaded_resilient_server_is_bit_identical_to_plain():
    """Waits under shed_start_ms never shrink: the resilience layer costs
    nothing on a good day (the zero-fault half of verdict 17)."""
    sg = small_test_graph()
    cfg = _cfg()
    qs = top_degree_pins(sg, 2)

    def serve(resilience):
        srv = PixieServer(sg.graph, cfg, batch_size=2, n_slots=4, seed=3,
                          resilience=resilience)
        srv.submit([int(qs[0])], [1.0], now=0.0, req_id=0)
        srv.submit([int(qs[1])], [1.0], now=0.0, req_id=1)
        return {r.req_id: r for r in srv.flush(now=0.0)}

    plain = serve(None)
    idle = serve(ResilienceConfig(deadline_ms=60.0, shed_start_ms=10.0))
    for rid in (0, 1):
        np.testing.assert_array_equal(plain[rid].scores, idle[rid].scores)
        np.testing.assert_array_equal(plain[rid].ids, idle[rid].ids)
        assert idle[rid].budget == cfg.n_steps


def test_submit_budget_validates():
    sg = small_test_graph()
    srv = PixieServer(sg.graph, _cfg(), batch_size=2, n_slots=4)
    with pytest.raises(ValueError, match="budget"):
        srv.submit([1], [1.0], budget=0)
    with pytest.raises(ValueError, match="budget"):
        srv.submit([1], [1.0], budget=srv.cfg.n_steps + 1)
    assert srv.pending() == 0


def test_ranked_replica_rejects_elastic_resilience():
    import jax

    from repro.serving import ranker as ranker_lib

    sg = small_test_graph()
    rcfg = ranker_lib.RankerConfig(
        n_items=sg.graph.n_pins, d_model=16, n_neighbors=4,
        n_candidates=16, final_k=8,
    )
    ranker = ranker_lib.RankRequest(
        ranker_lib.init_ranker_params(jax.random.key(7), rcfg), rcfg
    )
    with pytest.raises(ValueError, match="elastic"):
        PixieServer(sg.graph, _cfg(), ranker=ranker,
                    resilience=ResilienceConfig())
    # admission-only resilience is fine on a ranked replica
    srv = PixieServer(sg.graph, _cfg(), ranker=ranker,
                      resilience=ResilienceConfig(elastic=False,
                                                  max_queue_per_bucket=4))
    assert srv.max_queue_per_bucket == 4


# ---------------------------------------------------------------------------
# Admission accounting: per-bucket rejections (satellite bugfix)
# ---------------------------------------------------------------------------


def test_rejections_accounted_per_bucket():
    """Submit-time rejections used to vanish into the undifferentiated
    ``dropped`` counter; they are now attributable per bucket while
    ``dropped`` keeps the historical total-refused-work meaning."""
    sg = small_test_graph()
    srv = PixieServer(sg.graph, _cfg(n_steps=256),
                      buckets=[(4, 2), (4, 8)], max_queue_per_bucket=1)
    qs = top_degree_pins(sg, 6)
    small = [int(qs[0])]
    large = [int(q) for q in qs[:6]]
    assert srv.submit(small, [1.0]) is not None
    assert srv.submit(small, [1.0]) is None          # 2-slot queue full
    assert srv.submit(small, [1.0]) is None
    assert srv.submit(large, [1.0] * 6) is not None
    assert srv.submit(large, [1.0] * 6) is None      # 8-slot queue full
    assert srv.stats.rejected == {2: 2, 8: 1}
    assert srv.stats.rejected_total == 3
    assert srv.stats.dropped == 3                    # total stays total
    srv.flush()


def test_open_loop_report_carries_rejections_and_budgets():
    """The harness surfaces admission rejections (part of n_dropped) and
    the per-request dispatched budgets — the replay record."""
    sg = small_test_graph()
    candidates = top_degree_pins(sg, 8).astype(np.int32)
    workload = poisson_requests(candidates, OpenLoopConfig(
        offered_qps=100_000.0, n_requests=10, seed=0, max_pins=2,
    ))
    # bucket batch (4) > queue bound (2): arrivals 10 us apart overflow
    # the queue before the 1 ms formation deadline can drain it
    srv = PixieServer(sg.graph, _cfg(n_steps=256), buckets=[(4, 2)],
                      max_wait_ms=1.0, max_queue_per_bucket=2)
    report = run_open_loop(srv, workload)
    assert report.n_rejected > 0
    assert report.n_rejected <= report.n_dropped     # part of, not extra
    assert report.n_served + report.n_dropped == report.n_offered
    assert report.summary()["n_rejected"] == report.n_rejected
    # every served request reports the budget it dispatched with
    assert set(report.budgets) == set(report.results)
    assert all(b == 256 for b in report.budgets.values())  # no resilience


# ---------------------------------------------------------------------------
# Seeded fault injection: pure functions of the chaos seed
# ---------------------------------------------------------------------------


def test_fault_schedule_is_seeded_and_validates():
    cfg = ChaosConfig(horizon_s=1.0, seed=9, n_spikes=3, n_bursts=2,
                      n_shard_deaths=2, n_shards=4)
    a = sample_fault_schedule(cfg)
    b = sample_fault_schedule(cfg)
    assert a == b                                    # frozen, bit-equal
    assert len(a.events) == 7
    assert len(a.of_kind("latency_spike")) == 3
    assert len(a.of_kind("traffic_burst")) == 2
    deaths = a.of_kind("shard_death")
    assert all(0 <= e.shard < 4 for e in deaths)
    assert sample_fault_schedule(
        ChaosConfig(horizon_s=1.0, seed=10, n_spikes=3)
    ) != a
    with pytest.raises(ValueError, match="horizon_s"):
        ChaosConfig(horizon_s=0.0)
    with pytest.raises(ValueError, match="burst_factor"):
        ChaosConfig(horizon_s=1.0, burst_factor=0.5)
    with pytest.raises(ValueError, match="n_shards"):
        ChaosConfig(horizon_s=1.0, n_shard_deaths=1)


def test_defer_slides_past_cascading_spike_windows():
    faults = FaultSchedule(events=(
        FaultEvent(kind="latency_spike", t_start=1.0, duration_s=0.5),
        FaultEvent(kind="latency_spike", t_start=1.4, duration_s=0.5),
    ))
    assert faults.defer(0.5) == 0.5                  # outside: untouched
    assert faults.defer(1.2) == 1.9                  # chains both windows
    assert faults.defer(1.9) == 1.9                  # boundary is open
    assert FaultSchedule().defer(3.0) == 3.0         # empty schedule


def test_traffic_bursts_warp_monotonically_and_keep_payloads():
    candidates = np.arange(50, dtype=np.int32)
    reqs = poisson_requests(candidates, OpenLoopConfig(
        offered_qps=100.0, n_requests=20, seed=4, max_pins=4,
    ))
    faults = FaultSchedule(events=(
        FaultEvent(kind="traffic_burst", t_start=0.05, duration_s=0.1,
                   factor=4.0),
    ))
    warped = apply_traffic_bursts(reqs, faults)
    ts = [r.t_arrival for r in warped]
    assert ts == sorted(ts)                          # order preserved
    assert any(w.t_arrival < r.t_arrival for w, r in zip(warped, reqs))
    for w, r in zip(warped, reqs):                   # payloads untouched
        assert (w.req_id, w.pins, w.weights) == (r.req_id, r.pins, r.weights)
        assert w.t_arrival <= r.t_arrival
        if not (0.05 <= r.t_arrival < 0.15):
            assert w.t_arrival == r.t_arrival


def test_zero_fault_chaos_run_is_bit_identical_to_plain():
    """An empty FaultSchedule plus never-engaging thresholds reproduce
    the plain open-loop run exactly (the verdict-17 zero-fault leg, in
    miniature)."""
    sg = small_test_graph()
    cfg = _cfg(n_steps=256)
    candidates = top_degree_pins(sg, 8).astype(np.int32)
    workload = poisson_requests(candidates, OpenLoopConfig(
        offered_qps=300.0, n_requests=8, seed=2, max_pins=4,
    ))

    def serve(resilience, faults):
        srv = PixieServer(sg.graph, cfg, seed=2, buckets=[(2, 2), (2, 4)],
                          max_wait_ms=3.0, resilience=resilience)
        return run_open_loop(srv, workload, faults=faults)

    plain = serve(None, None)
    idle = serve(ResilienceConfig(deadline_ms=1e6, shed_start_ms=1e5),
                 FaultSchedule())
    assert len(plain.results) == len(idle.results) == len(workload)
    for rid, p in plain.results.items():
        np.testing.assert_array_equal(p.scores, idle.results[rid].scores)
        np.testing.assert_array_equal(p.ids, idle.results[rid].ids)
    assert all(b == cfg.n_steps for b in idle.budgets.values())


# ---------------------------------------------------------------------------
# Generation barrier: swap during an in-flight multi-interest user
# ---------------------------------------------------------------------------


def test_swap_graph_never_mixes_generations_within_a_user():
    """Regression (satellite bugfix): a user whose lanes straddled a
    ``swap_graph`` used to walk SOME lanes on the old graph and the rest
    on the new one, max-folding the generations into one merged result.
    Now the generation is stamped at ``submit_user`` and the swap drains
    every queue first, so the user serves entirely on the graph it was
    admitted under — bit-identical to a no-swap oracle."""
    sg = small_test_graph()
    other = small_test_graph(123)        # same shape, different content
    assert not np.array_equal(
        np.asarray(sg.graph.p2b.targets), np.asarray(other.graph.p2b.targets)
    )
    hist = sample_user_histories(sg, UserHistoryConfig(
        n_users=1, n_interests=3, mean_actions=18, seed=5,
    ))[0]
    cfg = _cfg(n_steps=256, backend="xla")

    def serve(swap):
        srv = PixieServer(sg.graph, cfg, batch_size=2, n_slots=8, seed=11,
                          pin_topics=sg.pin_topics, n_clusters=3)
        rid = srv.submit_user(hist.actions, user_feat=1, now=0.0, req_id=42)
        srv.pump(now=0.0)                # full 2-lane batch dispatches
        if swap:
            assert srv.pending() >= 1    # a lane is still queued
            srv.swap_graph(other.graph, now=0.0)   # barrier drains it
            assert srv.pending() == 0
        while srv.pending():
            srv.pump(now=srv.next_deadline())
        out = {r.req_id: r for r in srv.harvest()}
        return srv, out[rid]

    srv_swap, swapped = serve(swap=True)
    assert srv_swap.stats.graph_generation == 1
    # the user was admitted under generation 0 and served entirely there
    assert swapped.generation == 0
    _, oracle = serve(swap=False)
    np.testing.assert_array_equal(swapped.scores, oracle.scores)
    np.testing.assert_array_equal(swapped.ids, oracle.ids)


# ---------------------------------------------------------------------------
# Dead-shard tolerance: the pod engine under a death schedule
# ---------------------------------------------------------------------------


def _run(n_devices: int, body: str) -> dict:
    """Execute `body` in a fresh python with n fake devices; body must
    print a single json object on its last line (same harness as
    test_distributed.py — jax locks its device count at import)."""
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_devices}"
        import json
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.launch.mesh import make_mesh_compat, set_mesh_compat
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True, text=True, env=env, timeout=540,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    import json
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_dead_shard_kills_walkers_and_renormalizes():
    """A shard dying mid-walk: its resident walkers are killed (counted,
    distinct from capacity drops) and reborn at home, its counts zero out
    of the merge, an all-INT32_MAX schedule is bit-identical to the
    healthy None path, and the same schedule replays bit-identically."""
    res = _run(2, """
        from repro.core import distributed as D, walk as W
        from repro.graphs.synthetic import small_test_graph, top_degree_pins

        sg = small_test_graph()
        g = sg.graph
        mesh = make_mesh_compat((2,), ("model",))
        shg = D.shard_graph(g, 2)
        qs = top_degree_pins(sg, 4)
        cfg = W.WalkConfig(n_steps=1024, n_walkers=32, chunk_steps=4,
                           n_p=30, n_v=3, bias_beta=0.0, count_boards=True)
        pins = np.full((2, 2), -1, np.int32)
        weights = np.zeros((2, 2), np.float32)
        for b in range(2):
            pins[b] = qs[2 * b:2 * b + 2]
            weights[b] = (1.0, 0.6)
        keys = jax.random.split(jax.random.key(0), 2)
        never = np.iinfo(np.int32).max

        with set_mesh_compat(mesh):
            def walk(dead):
                return jax.block_until_ready(D.pixie_walk_sharded_batched(
                    shg, jnp.asarray(pins), jnp.asarray(weights), keys,
                    cfg, mesh, slack=8.0,
                    shard_dead_at=None if dead is None else jnp.asarray(
                        np.asarray(dead, np.int32)),
                ))

            healthy = walk(None)
            all_never = walk([never, never])
            faulted = walk([never, 2])
            faulted2 = walk([never, 2])

        def eq(a, b):
            return all(
                np.array_equal(np.asarray(x), np.asarray(y))
                for x, y in ((a.counts, b.counts),
                             (a.steps_taken, b.steps_taken),
                             (a.n_high, b.n_high))
            )

        from repro.core import counter as C
        folded = np.asarray(C.fold_sharded_counts(
            faulted.counts, 2, 2, shg.pins_per_shard))
        pps = shg.pins_per_shard
        print(json.dumps({
            "never_is_healthy": eq(healthy, all_never)
                                 and int(all_never.killed) == 0,
            "healthy_killed_is_none": healthy.killed is None,
            "killed": int(faulted.killed),
            "dropped": int(faulted.dropped),
            "dead_zeroed": bool(folded[..., pps:].sum() == 0),
            "survivors": bool(folded[..., :pps].sum() > 0),
            "replays": eq(faulted, faulted2)
                        and int(faulted2.killed) == int(faulted.killed),
        }))
    """)
    assert res["never_is_healthy"], res
    assert res["healthy_killed_is_none"], res
    assert res["killed"] > 0, res
    assert res["dropped"] == 0, res          # kills are NOT capacity drops
    assert res["dead_zeroed"], res
    assert res["survivors"], res
    assert res["replays"], res


def test_dead_shard_validation_and_plain_replica_guards():
    """The fault surface fails loudly where it can't apply: wrong-shape
    schedules, unsharded serve_batch, kill_shard on a plain replica."""
    import jax
    import jax.numpy as jnp

    from repro.core import service

    sg = small_test_graph()
    srv = PixieServer(sg.graph, _cfg(n_steps=256), batch_size=2, n_slots=2)
    with pytest.raises(ValueError, match="sharded"):
        srv.kill_shard(0)
    with pytest.raises(ValueError, match="sharded"):
        srv.revive_shards()
    assert srv.dead_shards() == []
    with pytest.raises(ValueError, match="ShardedGraph"):
        service.serve_batch(
            sg.graph,
            jnp.asarray(np.full((1, 2), -1, np.int32)),
            jnp.zeros((1, 2), jnp.float32),
            jnp.zeros((1,), jnp.int32),
            jax.random.key(0),
            _cfg(n_steps=256),
            shard_dead_at=jnp.zeros((2,), jnp.int32),
        )
