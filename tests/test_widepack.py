"""Wide (slot, pin) event lanes + incremental event-mode early stopping.

The two production-scale claims of the wide-pack engine:

  * **No id-space cliff.**  Events are (slot, pin) int32 lane pairs — no
    lane ever holds the packed ``slot * n_pins + pin`` product — so a walk
    whose packed id space exceeds 2**31 runs on ``backend="pallas"`` with
    event-mode counting, bit-identical to the xla twin, with NO fallback
    branch anywhere (``select_count_engine`` validates, never reroutes).
  * **No full-buffer re-sort.**  The event walk's ``check_every`` body
    folds only the new window's events into a carried
    ``counter_lib.EventHighState`` (sorted runs per window): the only sort
    in the while body is window-sized, pinned by jaxpr inspection, and the
    running ``n_high`` tally is bit-identical to the old full-buffer
    re-sort (``check_mode="full"``) at every check point — including keys
    whose counts cross ``n_v`` across window boundaries.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import counter as counter_lib
from repro.core import walk as walk_lib
from repro.graphs.synthetic import sparse_wide_graph as _sparse_wide_graph
from test_earlystop_parity import _iter_eqns


# ---------------------------------------------------------------------------
# the acceptance walk: packed id space past 2**31, pallas == xla, top-k too
# ---------------------------------------------------------------------------


def test_event_walk_past_int32_packed_space_bit_identical():
    """65536 slots x 40000 pins = 2.6e9 packed ids (> 2**31): the fused
    pallas engine runs it in event mode — wide int32 lanes, memory
    O(events) — and every output (lane buffers, n_high, steps_taken,
    top-k) is bit-identical to the xla twin.  No fallback is consulted:
    select_count_engine never reroutes a backend anymore."""
    n_slots, n_pins = 65_536, 40_000
    assert n_slots * n_pins >= 2**31
    g = _sparse_wide_graph(
        0, n_pins=n_pins, n_boards=64, n_edges=4_000, hot_pins=2_000
    )
    qp = np.full((n_slots,), -1, np.int32)
    qw = np.zeros((n_slots,), np.float32)
    qp[0], qp[1] = 3, 17
    qw[0], qw[1] = 1.0, 0.5
    qp, qw = jnp.asarray(qp), jnp.asarray(qw)
    cfg = walk_lib.WalkConfig(
        n_steps=2_048, n_walkers=64, chunk_steps=4, n_p=500, n_v=3,
        bias_beta=0.0,
    )
    key = jax.random.key(1)
    res = {}
    for backend in ("xla", "pallas"):
        bcfg = dataclasses.replace(cfg, backend=backend)
        r = walk_lib.pixie_walk_events(
            g, qp, qw, jnp.asarray(0, jnp.int32), key, bcfg, check_every=2
        )
        s, i = walk_lib.recommend_from_events(r, n_slots, n_pins, qp, 20)
        res[backend] = tuple(np.asarray(x) for x in (*r, s, i))
    for a, b in zip(res["xla"], res["pallas"]):
        np.testing.assert_array_equal(a, b)
    # the walk actually visited pins and the top-k is non-trivial
    slot_ev = res["xla"][0]
    assert (slot_ev < n_slots).sum() > 0
    scores = res["xla"][5]  # tuple layout: 5 EventWalkResult fields, s, i
    assert (scores[:5] > 0).all()  # top-5 boosted scores positive


# ---------------------------------------------------------------------------
# incremental check body: only window-sized sorts, bit-identical to full
# ---------------------------------------------------------------------------


def _walk_sorts_in_while_body(g, qp, qw, cfg, check_every, check_mode):
    jaxpr = jax.make_jaxpr(
        lambda k: walk_lib.pixie_walk_events(
            g, qp, qw, jnp.asarray(0, jnp.int32), k, cfg,
            check_every=check_every, check_mode=check_mode,
        )
    )(jax.random.key(0)).jaxpr
    whiles = [e for e in _iter_eqns(jaxpr) if e.primitive.name == "while"]
    assert whiles, "event walk lost its while loop?"
    sizes = []
    for w in whiles:
        for eqn in _iter_eqns(w.params["body_jaxpr"].jaxpr):
            if eqn.primitive.name == "sort":
                sizes.append(
                    max(getattr(v.aval, "size", 0) for v in eqn.invars)
                )
    return sizes


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_event_check_body_sorts_only_the_window(backend):
    """Acceptance pin: with check_mode="incremental" every sort inside the
    while body is window-sized (check_every * per_chunk), never
    max_events-sized; the old full re-sort formulation IS flagged by the
    same inspection (positive control)."""
    g = _sparse_wide_graph(3, n_pins=500, n_boards=16, n_edges=600,
                           hot_pins=200)
    qp = jnp.asarray([0, 7], jnp.int32)
    qw = jnp.asarray([1.0, 1.0], jnp.float32)
    cfg = walk_lib.WalkConfig(
        n_steps=4_096, n_walkers=32, chunk_steps=4, n_p=100, n_v=3,
        bias_beta=0.0, backend=backend,
    )
    check_every = 2
    per_chunk = cfg.n_walkers * cfg.chunk_steps
    window = check_every * per_chunk
    max_events = cfg.max_chunks() * per_chunk
    assert max_events >= 4 * window  # the distinction is real at this shape

    inc = _walk_sorts_in_while_body(g, qp, qw, cfg, check_every, "incremental")
    assert inc, "incremental check body should sort the new window"
    assert max(inc) <= window, (
        f"incremental body sorts {max(inc)} elements (> window {window})"
    )

    full = _walk_sorts_in_while_body(g, qp, qw, cfg, check_every, "full")
    assert max(full) >= max_events, (
        "positive control: the full re-sort formulation must be flagged"
    )


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_incremental_matches_full_resort_walk(backend):
    """check_mode="incremental" and the old full-buffer re-sort make
    identical stop decisions: same chunks_run, steps_taken, n_high, and
    event buffers — with thresholds that fire mid-walk so the tally is
    load-bearing, and check_every > 1 so crossings straddle windows."""
    g = _sparse_wide_graph(5, n_pins=400, n_boards=12, n_edges=800,
                           hot_pins=120)
    qp = jnp.asarray([2, 9, -1], jnp.int32)
    qw = jnp.asarray([1.0, 0.8, 0.0], jnp.float32)
    key = jax.random.key(4)
    cfg = walk_lib.WalkConfig(
        n_steps=8_192, n_walkers=64, chunk_steps=4, n_p=40, n_v=2,
        bias_beta=0.0, backend=backend,
    )
    ri = walk_lib.pixie_walk_events(
        g, qp, qw, jnp.asarray(0, jnp.int32), key, cfg,
        check_every=3, check_mode="incremental",
    )
    rf = walk_lib.pixie_walk_events(
        g, qp, qw, jnp.asarray(0, jnp.int32), key, cfg,
        check_every=3, check_mode="full",
    )
    for a, b in zip(ri, rf):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # early stopping actually fired before the budget
    assert int(ri.chunks_run) < cfg.max_chunks()
    assert (np.asarray(ri.n_high)[:2] > cfg.n_p).any()


def test_event_walk_n_high_matches_full_oracle_post_hoc():
    """The walk's carried n_high equals a from-scratch full re-aggregation
    of exactly the checked prefix of the event buffer."""
    g = _sparse_wide_graph(8, n_pins=300, n_boards=10, n_edges=500,
                           hot_pins=100)
    qp = jnp.asarray([1, 4], jnp.int32)
    qw = jnp.asarray([1.0, 1.0], jnp.float32)
    cfg = walk_lib.WalkConfig(
        n_steps=4_096, n_walkers=32, chunk_steps=4, n_p=10**9,
        n_v=2, bias_beta=0.0,
    )
    check_every = 2
    r = walk_lib.pixie_walk_events(
        g, qp, qw, jnp.asarray(0, jnp.int32), jax.random.key(2), cfg,
        check_every=check_every,
    )
    per_chunk = cfg.n_walkers * cfg.chunk_steps
    checked_chunks = (int(r.chunks_run) // check_every) * check_every
    cut = checked_chunks * per_chunk
    n_slots = qp.shape[0]
    sev = np.asarray(r.slot_events).copy()
    sev[cut:] = n_slots  # mask events past the last completed check window
    want = counter_lib.events_n_high_per_slot(
        jnp.asarray(sev), r.pin_events, n_slots, g.n_pins, cfg.n_v,
        sev.shape[0],
    )
    np.testing.assert_array_equal(np.asarray(r.n_high), np.asarray(want))


def test_events_high_fold_cross_window_crossing_counts_once():
    """A (slot, pin) key that reaches n_v - 1 in window 1 and crosses in
    window 3 is tallied exactly once, in window 3 — the prior-count sum
    over stored segments is what makes the crossing unique."""
    n_slots, n_pins, n_v, seg_cap = 2, 50, 4, 16
    state = counter_lib.events_high_init(n_slots, 4, seg_cap)

    def window(pairs):
        s = np.full((seg_cap,), n_slots, np.int32)
        p = np.zeros((seg_cap,), np.int32)
        for i, (sl, pi) in enumerate(pairs):
            s[i], p[i] = sl, pi
        return jnp.asarray(s), jnp.asarray(p)

    # window 1: pin (1, 7) visited n_v - 1 times -> no crossing
    state = counter_lib.events_high_fold(
        state, *window([(1, 7)] * (n_v - 1)), n_slots, n_pins, n_v,
        seg_cap=seg_cap,
    )
    assert np.asarray(state.high).tolist() == [0, 0]
    # window 2: unrelated traffic -> still no crossing
    state = counter_lib.events_high_fold(
        state, *window([(0, 3), (0, 4)]), n_slots, n_pins, n_v,
        seg_cap=seg_cap,
    )
    assert np.asarray(state.high).tolist() == [0, 0]
    # window 3: one more visit crosses; extra duplicates don't re-count
    state = counter_lib.events_high_fold(
        state, *window([(1, 7), (1, 7), (1, 7)]), n_slots, n_pins, n_v,
        seg_cap=seg_cap,
    )
    assert np.asarray(state.high).tolist() == [0, 1]
    # window 4: the key stays above threshold; never tallied again
    state = counter_lib.events_high_fold(
        state, *window([(1, 7)]), n_slots, n_pins, n_v, seg_cap=seg_cap
    )
    assert np.asarray(state.high).tolist() == [0, 1]
    assert int(state.n_checks) == 4


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_events_high_fold_random_windows_match_oracle(seed):
    """Property-style: random window streams, fold == full re-aggregation
    after every window."""
    rng = np.random.default_rng(seed)
    n_slots, n_pins, n_v, seg_cap, n_windows = 3, 40, 3, 64, 5
    state = counter_lib.events_high_init(n_slots, n_windows, seg_cap)
    all_s, all_p = [], []
    for _ in range(n_windows):
        s = rng.integers(0, n_slots + 1, seg_cap).astype(np.int32)
        p = np.where(s < n_slots, rng.integers(0, 10, seg_cap), 0).astype(
            np.int32
        )
        all_s.append(s)
        all_p.append(p)
        state = counter_lib.events_high_fold(
            state, jnp.asarray(s), jnp.asarray(p), n_slots, n_pins, n_v,
            seg_cap=seg_cap,
        )
        fs, fp = np.concatenate(all_s), np.concatenate(all_p)
        want = counter_lib.events_n_high_per_slot(
            jnp.asarray(fs), jnp.asarray(fp), n_slots, n_pins, n_v,
            fs.shape[0],
        )
        np.testing.assert_array_equal(np.asarray(state.high), np.asarray(want))


def test_events_high_fold_rejects_wrong_window_size():
    state = counter_lib.events_high_init(2, 2, 8)
    with pytest.raises(ValueError, match="seg_cap"):
        counter_lib.events_high_fold(
            state, jnp.zeros((4,), jnp.int32), jnp.zeros((4,), jnp.int32),
            2, 10, 2, seg_cap=8,
        )


def test_event_walk_rejects_unknown_check_mode():
    g = _sparse_wide_graph(0, n_pins=50, n_boards=4, n_edges=80, hot_pins=20)
    qp = jnp.asarray([0], jnp.int32)
    qw = jnp.ones((1,), jnp.float32)
    cfg = walk_lib.WalkConfig(n_steps=64, n_walkers=32)
    with pytest.raises(ValueError, match="check_mode"):
        walk_lib.pixie_walk_events(
            g, qp, qw, jnp.asarray(0, jnp.int32), jax.random.key(0), cfg,
            check_mode="sometimes",
        )
