"""Differential tests for the fused early-stop counting path (Algorithm 3).

The dense walk engine's while-loop no longer re-reduces the whole
``n_slots * n_pins`` count buffer per chunk to recompute ``n_high``; it
carries a running tally updated incrementally by
``counter_lib.accumulate_packed_events_with_high`` (xla: chunk-local sort +
gather at the touched bins; pallas: crossings emitted by the fused
``visit_counter_update_high`` kernel).  These tests pin down:

  * xla vs pallas bit-identity of counts / n_high / steps_taken across
    random graphs, chunk sizes, and (n_v, n_p) thresholds;
  * the tally == full-recount invariant, including chunk-boundary
    crossings (a bin reaching n_v across two accumulate calls, and a slot
    crossing n_p mid-walk);
  * the wide-lane scale contract: events are (slot, pin) int32 lane pairs
    on BOTH engines (no packed product, no int64, no fallback branch);
    dense counting rejects un-materializable bin spaces loudly at SHAPE
    level (no giant buffers materialized) — event mode has no such limit
    (tests/test_widepack.py);
  * the structural claim itself, by jaxpr inspection: the while-loop body
    contains no reduction over an ``n_slots * n_pins``-sized operand.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis, or seeded fallback

from repro.core import counter as counter_lib
from repro.core import walk as walk_lib
from repro.core.graph import CSR, PinBoardGraph
from repro.kernels import ref


def _random_graph(seed: int, n_pins: int, n_boards: int, n_edges: int):
    rng = np.random.default_rng(seed)
    pins = rng.integers(0, n_pins, n_edges)
    boards = rng.integers(0, n_boards, n_edges)
    p2b_off = np.zeros(n_pins + 1, np.int32)
    np.cumsum(np.bincount(pins, minlength=n_pins), out=p2b_off[1:])
    p2b_tgt = (boards[np.argsort(pins, kind="stable")] + n_pins).astype(np.int32)
    b2p_off = np.zeros(n_boards + 1, np.int32)
    np.cumsum(np.bincount(boards, minlength=n_boards), out=b2p_off[1:])
    b2p_tgt = pins[np.argsort(boards, kind="stable")].astype(np.int32)
    return PinBoardGraph(
        p2b=CSR(offsets=jnp.asarray(p2b_off), targets=jnp.asarray(p2b_tgt)),
        b2p=CSR(offsets=jnp.asarray(b2p_off), targets=jnp.asarray(b2p_tgt)),
        n_pins=n_pins,
        n_boards=n_boards,
        max_pin_degree=max(1, int(np.diff(p2b_off).max())),
    )


def _walk_both(graph, qp, qw, key, cfg):
    rx = walk_lib.pixie_random_walk(
        graph, qp, qw, jnp.asarray(0, jnp.int32), key, cfg
    )
    rp = walk_lib.pixie_random_walk(
        graph, qp, qw, jnp.asarray(0, jnp.int32), key,
        dataclasses.replace(cfg, backend="pallas"),
    )
    return rx, rp


def _assert_walks_identical(rx, rp):
    np.testing.assert_array_equal(np.asarray(rx.counts), np.asarray(rp.counts))
    np.testing.assert_array_equal(np.asarray(rx.n_high), np.asarray(rp.n_high))
    np.testing.assert_array_equal(
        np.asarray(rx.steps_taken), np.asarray(rp.steps_taken)
    )


# ---------------------------------------------------------------------------
# property-style differential tests: xla vs pallas across random settings
# ---------------------------------------------------------------------------


@settings(max_examples=5)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    chunk_steps=st.integers(min_value=2, max_value=9),
    n_v=st.integers(min_value=1, max_value=5),
    n_p=st.integers(min_value=1, max_value=60),
)
def test_walk_parity_random_graphs_and_thresholds(seed, chunk_steps, n_v, n_p):
    """xla and pallas engines agree bit-for-bit on counts, n_high, and
    steps_taken for random graphs and random early-stop thresholds."""
    rng = np.random.default_rng(seed)
    g = _random_graph(
        seed,
        n_pins=int(rng.integers(40, 160)),
        n_boards=int(rng.integers(8, 32)),
        n_edges=int(rng.integers(150, 500)),
    )
    qp = jnp.asarray([int(rng.integers(0, g.n_pins)), -1], jnp.int32)
    qw = jnp.asarray([1.0, 0.0], jnp.float32)
    cfg = walk_lib.WalkConfig(
        n_steps=1024, n_walkers=32, chunk_steps=chunk_steps,
        n_p=n_p, n_v=n_v, bias_beta=0.0,
    )
    rx, rp = _walk_both(g, qp, qw, jax.random.key(seed), cfg)
    _assert_walks_identical(rx, rp)
    # the running tally must equal a full recount of the final counts
    np.testing.assert_array_equal(
        np.asarray(rx.n_high),
        np.asarray(counter_lib.n_high_visited(rx.counts, n_v)),
    )


@settings(max_examples=8)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    n_slots=st.integers(min_value=1, max_value=5),
    n_pins=st.integers(min_value=16, max_value=900),
    n_v=st.integers(min_value=1, max_value=6),
)
def test_counter_api_parity_and_tally_invariant(seed, n_slots, n_pins, n_v):
    """accumulate_packed_events_with_high: xla path == pallas path ==
    full-recount oracle, for random prior counts and event chunks."""
    n_bins = n_slots * n_pins
    kp, ks, ke = jax.random.split(jax.random.key(seed), 3)
    prior = jax.random.randint(kp, (n_bins,), 0, n_v + 2, dtype=jnp.int32)
    # include negatives and the slot sentinel among the wide lanes
    slot_ev = jax.random.randint(ks, (1024,), -1, n_slots + 2, dtype=jnp.int32)
    pin_ev = jax.random.randint(ke, (1024,), -2, n_pins + 3, dtype=jnp.int32)
    high0 = counter_lib.n_high_visited(
        prior.reshape(n_slots, n_pins), n_v
    )
    want_c, want_d = ref.visit_counter_update_high_ref(
        prior, slot_ev, pin_ev, n_slots, n_pins, n_v
    )
    for backend in ("xla", "pallas"):
        got_c, got_h = counter_lib.accumulate_packed_events_with_high(
            prior, high0, slot_ev, pin_ev, n_slots, n_pins, n_v, backend
        )
        np.testing.assert_array_equal(np.asarray(got_c), np.asarray(want_c))
        np.testing.assert_array_equal(
            np.asarray(got_h), np.asarray(high0 + want_d)
        )
        # invariant: running tally == full recount of the new counts
        np.testing.assert_array_equal(
            np.asarray(got_h),
            np.asarray(
                counter_lib.n_high_visited(
                    got_c.reshape(n_slots, n_pins), n_v
                )
            ),
        )


# ---------------------------------------------------------------------------
# chunk-boundary crossings
# ---------------------------------------------------------------------------


def test_crossing_split_across_chunk_boundary():
    """A bin that reaches n_v-1 in one accumulate call and crosses in the
    next must be tallied exactly once, in the second call — on both paths."""
    n_slots, n_pins, n_v = 2, 300, 4
    bin_id = 1 * n_pins + 7  # slot 1, pin 7
    s1 = jnp.full((n_v - 1,), 1, jnp.int32)            # reaches n_v - 1
    p1 = jnp.full((n_v - 1,), 7, jnp.int32)
    s2 = jnp.asarray([1, 1], jnp.int32)                # crosses, then above
    p2 = jnp.asarray([7, 7], jnp.int32)
    for backend in ("xla", "pallas"):
        counts = jnp.zeros((n_slots * n_pins,), jnp.int32)
        high = jnp.zeros((n_slots,), jnp.int32)
        counts, high = counter_lib.accumulate_packed_events_with_high(
            counts, high, s1, p1, n_slots, n_pins, n_v, backend
        )
        assert high.tolist() == [0, 0], backend
        counts, high = counter_lib.accumulate_packed_events_with_high(
            counts, high, s2, p2, n_slots, n_pins, n_v, backend
        )
        assert high.tolist() == [0, 1], backend
        assert int(counts[bin_id]) == n_v + 1


def test_crossing_within_one_chunk_counts_once():
    """Many duplicates of one bin inside a single chunk: one crossing."""
    n_slots, n_pins, n_v = 1, 64, 3
    slot_ev = jnp.zeros((16,), jnp.int32)   # 16 visits to pin 5 at once
    pin_ev = jnp.full((16,), 5, jnp.int32)
    for backend in ("xla", "pallas"):
        counts, high = counter_lib.accumulate_packed_events_with_high(
            jnp.zeros((n_pins,), jnp.int32),
            jnp.zeros((1,), jnp.int32),
            slot_ev, pin_ev, n_slots, n_pins, n_v, backend,
        )
        assert high.tolist() == [1], backend
        assert int(counts[5]) == 16


def test_walk_parity_when_slot_crosses_n_p_mid_walk():
    """Early stop fires mid-walk (n_p crossed between chunks): both engines
    stop at the same chunk with identical tallies."""
    g = _random_graph(3, n_pins=120, n_boards=16, n_edges=500)
    qp = jnp.asarray([0, 11], jnp.int32)
    qw = jnp.asarray([1.0, 1.0], jnp.float32)
    cfg = walk_lib.WalkConfig(
        n_steps=8192, n_walkers=64, chunk_steps=4, n_p=5, n_v=2,
        bias_beta=0.0,
    )
    rx, rp = _walk_both(g, qp, qw, jax.random.key(1), cfg)
    _assert_walks_identical(rx, rp)
    # the stop actually happened early (budget not exhausted)
    assert (np.asarray(rx.steps_taken) < cfg.n_steps).all()
    assert (np.asarray(rx.n_high) > cfg.n_p).any()


# ---------------------------------------------------------------------------
# production-scale shape contract (shape-level, nothing giant materialized)
# ---------------------------------------------------------------------------


def test_count_engine_selection_shape_level():
    """No fallback branch: the chooser returns the requested backend at
    every dense-materializable scale, and rejects un-materializable dense
    bin spaces loudly (event mode is the production path there)."""
    assert walk_lib.select_count_engine("pallas", 4, 1000) == "pallas"
    assert walk_lib.select_count_engine("xla", 4, 1000) == "xla"
    # close to the dense ceiling: still the requested backend, no fallback
    assert walk_lib.select_count_engine("pallas", 4, 2**28) == "pallas"
    # 4 slots * 2^29 pins = 2^31 bins: dense counting cannot materialize
    # that buffer on ANY backend — loud error pointing at event mode
    with pytest.raises(ValueError, match="event-mode"):
        walk_lib.select_count_engine("pallas", 4, 2**29)
    with pytest.raises(ValueError, match="event-mode"):
        walk_lib.select_count_engine("xla", 4, 1000, 2**29)
    with pytest.raises(ValueError, match="backend"):
        walk_lib.select_count_engine("tpu??", 4, 1000)
    # wide lanes: the per-lane dtype is int32 at EVERY id-space scale
    assert walk_lib.packed_event_dtype(4, 2**29) == jnp.int32
    assert walk_lib.packed_event_dtype(4, 1000) == jnp.int32


def test_pixie_random_walk_routes_through_engine_selection(monkeypatch):
    """pixie_random_walk consults select_count_engine and hands its verdict
    to the counting API — checked by forcing an answer on a small graph and
    recording what the counter receives."""
    g = _random_graph(0, n_pins=60, n_boards=10, n_edges=200)
    seen = {}

    def fake_select(backend, n_slots, n_pins, n_boards=0):
        seen["dims"] = (backend, n_slots, n_pins, n_boards)
        return "xla"  # forced verdict, must reach the counting API

    real_acc = counter_lib.accumulate_packed_events_with_high

    def recording_acc(counts, high, sev, pev, n_slots, n_pins, n_v, backend):
        seen["count_backend"] = backend
        return real_acc(counts, high, sev, pev, n_slots, n_pins, n_v, backend)

    monkeypatch.setattr(walk_lib, "select_count_engine", fake_select)
    monkeypatch.setattr(
        counter_lib, "accumulate_packed_events_with_high", recording_acc
    )
    cfg = walk_lib.WalkConfig(
        n_steps=256, n_walkers=32, chunk_steps=4, n_p=10**9, n_v=10**9 // 2,
        bias_beta=0.0, backend="pallas",
    )
    walk_lib.pixie_random_walk(
        g, jnp.asarray([1], jnp.int32), jnp.ones((1,), jnp.float32),
        jnp.asarray(0, jnp.int32), jax.random.key(0), cfg,
    )
    # count_boards=False: board ids are not counted, so they must not enter
    # the shape validation (a huge board space must not reject a pin walk)
    assert seen["dims"] == ("pallas", 1, g.n_pins, 0)
    assert seen["count_backend"] == "xla"


def test_board_space_only_gates_engine_when_counted(monkeypatch):
    g = _random_graph(1, n_pins=60, n_boards=10, n_edges=200)
    seen = {}
    real_select = walk_lib.select_count_engine

    def recording_select(backend, n_slots, n_pins, n_boards=0):
        seen["n_boards"] = n_boards
        return real_select(backend, n_slots, n_pins, n_boards)

    monkeypatch.setattr(walk_lib, "select_count_engine", recording_select)
    cfg = walk_lib.WalkConfig(
        n_steps=256, n_walkers=32, chunk_steps=4, n_p=10**9, n_v=10**9 // 2,
        bias_beta=0.0, count_boards=True,
    )
    walk_lib.pixie_random_walk(
        g, jnp.asarray([1], jnp.int32), jnp.ones((1,), jnp.float32),
        jnp.asarray(0, jnp.int32), jax.random.key(0), cfg,
    )
    assert seen["n_boards"] == g.n_boards


def test_one_sided_feat_bounds_rejected_for_biased_walks():
    g = _random_graph(2, n_pins=40, n_boards=8, n_edges=120)
    lopsided = PinBoardGraph(
        p2b=CSR(
            offsets=g.p2b.offsets, targets=g.p2b.targets,
            feat_bounds=jnp.zeros((g.n_pins, 3), jnp.int32),
        ),
        b2p=g.b2p,  # no feat_bounds on this side
        n_pins=g.n_pins, n_boards=g.n_boards,
        max_pin_degree=g.max_pin_degree,
    )
    qp = jnp.asarray([0], jnp.int32)
    qw = jnp.ones((1,), jnp.float32)
    biased = walk_lib.WalkConfig(n_steps=128, n_walkers=32, bias_beta=0.9)
    with pytest.raises(ValueError, match="feat_bounds"):
        walk_lib.pixie_random_walk(
            lopsided, qp, qw, jnp.asarray(0, jnp.int32),
            jax.random.key(0), biased,
        )
    # with biasing off the same graph walks fine
    res = walk_lib.pixie_random_walk(
        lopsided, qp, qw, jnp.asarray(0, jnp.int32), jax.random.key(0),
        dataclasses.replace(biased, bias_beta=0.0),
    )
    assert int(res.counts.sum()) >= 0


def test_counter_api_rejects_unmaterializable_dense_bins():
    """Dense counting with a >= 2^31 bin space must raise on BOTH backends
    (there is no buffer to scatter into), pointing at event mode — the
    wide-lane replacement for the old silent int64 fallback."""
    n_slots, n_pins = 4, 2**29
    counts = jnp.zeros((64,), jnp.int32)  # stand-in slice; never reached
    high = jnp.zeros((n_slots,), jnp.int32)
    sev = jnp.asarray([0, 0, 0], jnp.int32)
    pev = jnp.asarray([1, 2, 2], jnp.int32)
    for backend in ("xla", "pallas"):
        with pytest.raises(ValueError, match="event-mode"):
            counter_lib.accumulate_packed_events_with_high(
                counts, high, sev, pev, n_slots, n_pins, 2, backend
            )
        with pytest.raises(ValueError, match="event-mode"):
            counter_lib.accumulate_packed_events(
                counts, sev, pev, n_slots, n_pins, backend
            )


def test_counter_api_empty_events_both_backends():
    """Zero events: counts and tally unchanged on BOTH paths (the kernel
    wrapper must not build a zero-size grid)."""
    n_slots, n_pins = 2, 100
    counts = jnp.arange(n_slots * n_pins, dtype=jnp.int32) % 5
    high = counter_lib.n_high_visited(counts.reshape(n_slots, n_pins), 3)
    empty = jnp.zeros((0,), jnp.int32)
    for backend in ("xla", "pallas"):
        got_c, got_h = counter_lib.accumulate_packed_events_with_high(
            counts, high, empty, empty, n_slots, n_pins, 3, backend
        )
        np.testing.assert_array_equal(np.asarray(got_c), np.asarray(counts))
        np.testing.assert_array_equal(np.asarray(got_h), np.asarray(high))
        # the plain histogram API must tolerate empty lanes the same way
        got_p = counter_lib.accumulate_packed_events(
            counts, empty, empty, n_slots, n_pins, backend
        )
        np.testing.assert_array_equal(np.asarray(got_p), np.asarray(counts))


def test_counter_api_rejects_nonpositive_n_v():
    with pytest.raises(ValueError, match="n_v"):
        counter_lib.accumulate_packed_events_with_high(
            jnp.zeros((8,), jnp.int32), jnp.zeros((1,), jnp.int32),
            jnp.zeros((4,), jnp.int32), jnp.zeros((4,), jnp.int32),
            1, 8, 0, "xla",
        )
    bad_cfg = walk_lib.WalkConfig(n_steps=64, n_walkers=32, n_v=0)
    g = _random_graph(0, 30, 8, 60)
    qp = jnp.asarray([0], jnp.int32)
    qw = jnp.ones((1,), jnp.float32)
    uf = jnp.asarray(0, jnp.int32)
    with pytest.raises(ValueError, match="n_v"):
        walk_lib.pixie_random_walk(g, qp, qw, uf, jax.random.key(0), bad_cfg)
    # both engines reject the misconfiguration the same loud way
    with pytest.raises(ValueError, match="n_v"):
        walk_lib.pixie_walk_events(g, qp, qw, uf, jax.random.key(0), bad_cfg)


# ---------------------------------------------------------------------------
# the structural claim: no full-buffer reduction inside the while body
# ---------------------------------------------------------------------------

_REDUCE_PRIMS = {
    "reduce_sum", "reduce_max", "reduce_min", "reduce_and", "reduce_or",
    "reduce_prod", "argmax", "argmin",
}


def _sub_jaxprs(val):
    from jax.core import ClosedJaxpr, Jaxpr

    if isinstance(val, ClosedJaxpr):
        yield val.jaxpr
    elif isinstance(val, Jaxpr):
        yield val
    elif isinstance(val, (list, tuple)):
        for v in val:
            yield from _sub_jaxprs(v)


def _iter_eqns(jaxpr):
    """All equations, recursing into sub-jaxprs but not into pallas_call
    (kernel-internal tile math is VMEM-resident, not a buffer reduction)."""
    for eqn in jaxpr.eqns:
        yield eqn
        if "pallas" in eqn.primitive.name:
            continue
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from _iter_eqns(sub)


def _full_buffer_reduces(jaxpr, min_size):
    found = []
    for eqn in _iter_eqns(jaxpr):
        if eqn.primitive.name in _REDUCE_PRIMS:
            for v in eqn.invars:
                aval = getattr(v, "aval", None)
                if aval is not None and getattr(aval, "size", 0) >= min_size:
                    found.append((eqn.primitive.name, tuple(aval.shape)))
    return found


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_while_body_has_no_full_buffer_reduction(backend):
    """Acceptance criterion: the dense-mode while_loop body contains no
    reduction over an n_slots * n_pins-sized operand, on either engine."""
    g = _random_graph(7, n_pins=130, n_boards=20, n_edges=400)
    n_slots = 4
    qp = jnp.asarray([0, 5, -1, -1], jnp.int32)
    qw = jnp.asarray([1.0, 0.5, 0.0, 0.0], jnp.float32)
    cfg = walk_lib.WalkConfig(
        n_steps=2048, n_walkers=64, chunk_steps=4, n_p=40, n_v=3,
        bias_beta=0.0, backend=backend,
    )
    jaxpr = jax.make_jaxpr(
        lambda k: walk_lib.pixie_random_walk(
            g, qp, qw, jnp.asarray(0, jnp.int32), k, cfg
        )
    )(jax.random.key(0)).jaxpr
    whiles = [e for e in _iter_eqns(jaxpr) if e.primitive.name == "while"]
    assert whiles, "dense walk lost its while loop?"
    n_bins = n_slots * g.n_pins
    for w in whiles:
        found = _full_buffer_reduces(w.params["body_jaxpr"].jaxpr, n_bins)
        assert not found, (
            f"while body reduces a full count buffer on {backend}: {found}"
        )


def test_reduction_checker_catches_the_old_pattern():
    """Positive control: the pre-fusion formulation (full n_high recount
    per chunk) IS flagged by the same checker."""
    n_slots, n_pins = 4, 130
    jaxpr = jax.make_jaxpr(
        lambda c: counter_lib.n_high_visited(c.reshape(n_slots, n_pins), 3)
    )(jnp.zeros((n_slots * n_pins,), jnp.int32)).jaxpr
    assert _full_buffer_reduces(jaxpr, n_slots * n_pins)


# ---------------------------------------------------------------------------
# kernel-level: fused update kernel vs oracle across tilings
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tile,chunk", [(128, 256), (512, 2048)])
@pytest.mark.parametrize("n_slots,n_pins", [(1, 100), (3, 700), (8, 512)])
def test_update_high_kernel_matches_ref(tile, chunk, n_slots, n_pins):
    from repro.kernels.visit_counter import visit_counter_update_high

    n_bins = n_slots * n_pins
    kp, ks, ke = jax.random.split(jax.random.key(n_bins + tile), 3)
    prior = jax.random.randint(kp, (n_bins,), 0, 4, dtype=jnp.int32)
    slot_ev = jax.random.randint(ks, (3000,), -1, n_slots + 2, dtype=jnp.int32)
    pin_ev = jax.random.randint(ke, (3000,), -2, n_pins + 4, dtype=jnp.int32)
    got_c, got_d = visit_counter_update_high(
        prior, slot_ev, pin_ev, n_slots=n_slots, n_pins=n_pins, n_v=3,
        tile=tile, chunk=chunk, interpret=True,
    )
    want_c, want_d = ref.visit_counter_update_high_ref(
        prior, slot_ev, pin_ev, n_slots, n_pins, 3
    )
    np.testing.assert_array_equal(np.asarray(got_c), np.asarray(want_c))
    np.testing.assert_array_equal(np.asarray(got_d), np.asarray(want_d))


@pytest.mark.parametrize("tile,chunk", [(128, 256), (512, 2048)])
def test_wide_histogram_kernel_matches_ref(tile, chunk):
    from repro.kernels.visit_counter import visit_counter_wide

    n_slots, n_dim = 3, 700
    ks, ke = jax.random.split(jax.random.key(tile + chunk))
    slot_ev = jax.random.randint(ks, (3000,), -1, n_slots + 2, dtype=jnp.int32)
    id_ev = jax.random.randint(ke, (3000,), -2, n_dim + 4, dtype=jnp.int32)
    got = visit_counter_wide(
        slot_ev, id_ev, n_slots=n_slots, n_dim=n_dim,
        tile=tile, chunk=chunk, interpret=True,
    )
    want = ref.visit_counter_wide_ref(slot_ev, id_ev, n_slots, n_dim)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
