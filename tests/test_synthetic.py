"""Invariants of the synthetic pin-board graph generator and the planted
multi-topic user-history sampler (graphs/synthetic.py).

These are the workload's ground-truth guarantees every benchmark and
agreement verdict leans on: same seed -> same graph and same histories
byte for byte; pin popularity heavy-tailed (§3.2's graph pruning target);
heldout future-saves disjoint from the training CSR (the hit-rate
evaluation's train/test split); sampled users ACTUALLY multi-topic (the
clustering layer has planted structure to recover).
"""

import numpy as np
import pytest

from repro.graphs import synthetic


@pytest.fixture(scope="module")
def sg():
    return synthetic.small_test_graph(seed=0)


@pytest.fixture(scope="module")
def histories(sg):
    cfg = synthetic.UserHistoryConfig(
        n_users=12, n_interests=3, mean_actions=24, seed=11
    )
    return synthetic.sample_user_histories(sg, cfg)


# ---------------------------------------------------------------------------
# Graph generator
# ---------------------------------------------------------------------------


def test_generate_seeded_deterministic():
    cfg = synthetic.SyntheticGraphConfig(
        n_pins=400, n_boards=60, n_topics=8, seed=13
    )
    a = synthetic.generate(cfg)
    b = synthetic.generate(cfg)
    np.testing.assert_array_equal(np.asarray(a.graph.p2b.offsets),
                                  np.asarray(b.graph.p2b.offsets))
    np.testing.assert_array_equal(np.asarray(a.graph.p2b.targets),
                                  np.asarray(b.graph.p2b.targets))
    np.testing.assert_array_equal(np.asarray(a.graph.b2p.targets),
                                  np.asarray(b.graph.b2p.targets))
    np.testing.assert_array_equal(a.pin_topics, b.pin_topics)
    np.testing.assert_array_equal(a.heldout_pins, b.heldout_pins)
    np.testing.assert_array_equal(a.heldout_boards, b.heldout_boards)
    # and a different seed is a different graph
    c = synthetic.generate(
        synthetic.SyntheticGraphConfig(n_pins=400, n_boards=60,
                                       n_topics=8, seed=14)
    )
    assert not np.array_equal(np.asarray(a.graph.p2b.targets),
                              np.asarray(c.graph.p2b.targets))


def test_pin_degree_heavy_tailed(sg):
    """Zipf-ish popularity: the top 10% of pins hold well more than 10%
    of the edges (several times the uniform share)."""
    degs = np.sort(np.asarray(sg.graph.p2b.degrees(), np.int64))[::-1]
    total = degs.sum()
    assert total > 0
    top = max(1, len(degs) // 10)
    top_share = degs[:top].sum() / total
    assert top_share > 0.25, f"top-10% share {top_share:.3f} not heavy-tailed"


def test_heldout_disjoint_from_training(sg):
    """Every heldout (board, pin) future-save is absent from the training
    CSR in BOTH directions — the hit-rate metric never rewards recalling
    an edge the walk could simply read."""
    p2b_off = np.asarray(sg.graph.p2b.offsets)
    p2b_tgt = np.asarray(sg.graph.p2b.targets)
    b2p_off = np.asarray(sg.graph.b2p.offsets)
    b2p_tgt = np.asarray(sg.graph.b2p.targets)
    n_pins = sg.graph.n_pins
    assert len(sg.heldout_pins) == len(sg.heldout_boards) > 0
    for pin, board in zip(sg.heldout_pins, sg.heldout_boards):
        pin, lo = int(pin), int(board)  # heldout boards are LOCAL rows
        nbrs = p2b_tgt[p2b_off[pin]:p2b_off[pin + 1]]
        assert (n_pins + lo) not in nbrs, (pin, lo)
        members = b2p_tgt[b2p_off[lo]:b2p_off[lo + 1]]
        assert pin not in members, (pin, lo)


# ---------------------------------------------------------------------------
# User-history sampler
# ---------------------------------------------------------------------------


def test_histories_seeded_deterministic(sg, histories):
    cfg = synthetic.UserHistoryConfig(
        n_users=12, n_interests=3, mean_actions=24, seed=11
    )
    again = synthetic.sample_user_histories(sg, cfg)
    assert len(again) == len(histories)
    for a, b in zip(histories, again):
        assert a.actions == b.actions
        np.testing.assert_array_equal(a.topics, b.topics)
        np.testing.assert_array_equal(
            a.mixture.view(np.uint32), b.mixture.view(np.uint32)
        )


def test_histories_planted_structure(sg, histories):
    """The planted ground truth is recoverable: distinct planted topics,
    mixtures on the simplex, and the bulk of each user's actions land on
    pins whose main topic is one of the planted ones (only the seeded
    offtopic fraction may stray)."""
    pin_main_topic = sg.pin_topics.argmax(axis=1)
    for h in histories:
        assert len(set(h.topics.tolist())) == len(h.topics) == 3
        np.testing.assert_allclose(h.mixture.sum(), 1.0, rtol=1e-5)
        assert len(h.actions) >= 3
        planted = set(h.topics.tolist())
        on_topic = sum(
            1 for a in h.actions if int(pin_main_topic[a.pin]) in planted
        )
        assert on_topic / len(h.actions) > 0.5, (
            f"only {on_topic}/{len(h.actions)} actions on planted topics"
        )


def test_histories_actions_well_formed(sg, histories):
    degs = np.asarray(sg.graph.p2b.degrees())
    for h in histories:
        for a in h.actions:
            assert 0 <= a.pin < sg.graph.n_pins
            assert degs[a.pin] > 0          # acted pins are connected
            assert a.action in ("save", "click", "like", "view")
            assert 0.0 <= a.age_hours <= 72.0


def test_histories_validate_config(sg):
    with pytest.raises(ValueError, match="n_interests"):
        synthetic.sample_user_histories(
            sg, synthetic.UserHistoryConfig(n_users=1, n_interests=0)
        )
    with pytest.raises(ValueError, match="exceeds"):
        synthetic.sample_user_histories(
            sg,
            synthetic.UserHistoryConfig(
                n_users=1, n_interests=sg.pin_topics.shape[1] + 1
            ),
        )
