"""Fused two-stage retrieval -> ranking on the serving path.

The contract under test (serving/ranker.py + service.serve_batch(rank=)):

  * **Verdict-15 parity**: the fused pallas two-stage path is BIT-identical
    to the XLA oracle — candidate ids, ranker scores, final ordering — for
    batch {1, 4, 16} x gather {scalar, dma}.  Parity is by construction:
    the walk engines are integer-exact twins, and every stage-2 float op
    is ONE shared program for both backends (the bag op's lowering is
    platform-defaulted, never backend-derived).
  * **Lowering pin**: a batched two-stage serve step has a CONSTANT
    pallas_call count independent of batch size — 2 walk-engine calls
    inside the chunk loop, plus 2 rank-1-grid embedding-bag calls when
    stage 2 lowers through the kernel (the TPU shape) — via
    kernels/introspect.pallas_grids.
  * **Stage boundary**: stage 2 (`rank_candidates`, `rank_retrieved`)
    takes precomputed ``(ids, scores)`` directly — no re-retrieval — and
    ``pixie_then_rank`` is exactly walk + ``rank_retrieved``.
  * **Scenario axis**: >= 2 ranker heads (related-pins vs homefeed),
    selected per request, threaded through `PixieServer(ranker=...)`.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import service, walk as walk_lib
from repro.graphs.synthetic import small_test_graph, top_degree_pins
from repro.kernels.introspect import pallas_grids
from repro.models import sequential_rec as sr
from repro.serving import ranker as ranker_lib
from repro.serving.recommend import (
    TwoStageConfig,
    pixie_then_rank,
    rank_retrieved,
    recommend_two_stage,
    sasrec_ranker,
)
from repro.serving.server import PixieServer


@pytest.fixture(scope="module")
def sg():
    return small_test_graph()


@pytest.fixture(scope="module")
def rank(sg):
    cfg = ranker_lib.RankerConfig(
        n_items=sg.graph.n_pins, d_model=16, n_neighbors=4,
        n_candidates=16, final_k=8,
    )
    params = ranker_lib.init_ranker_params(jax.random.key(7), cfg)
    return ranker_lib.RankRequest(params, cfg)


def _cfg(**kw):
    kw = {
        "n_steps": 1536, "n_walkers": 64, "chunk_steps": 4, "top_k": 20,
        "n_p": 40, "n_v": 3, "backend": "pallas", **kw,
    }
    return walk_lib.WalkConfig(**kw)


def _mk_batch(sg, batch, n_slots=2):
    qs = top_degree_pins(sg, min(2 * batch, 32))
    pins = np.full((batch, n_slots), -1, np.int32)
    weights = np.zeros((batch, n_slots), np.float32)
    for i in range(batch):
        pins[i, 0] = int(qs[(2 * i) % len(qs)])
        pins[i, 1] = int(qs[(2 * i + 1) % len(qs)])
        weights[i] = [1.0, 0.6]
    return (
        jnp.asarray(pins),
        jnp.asarray(weights),
        jnp.zeros((batch,), jnp.int32),
    )


def _scenarios(batch):
    return jnp.asarray([i % 2 for i in range(batch)], jnp.int32)


# ---------------------------------------------------------------------------
# Verdict 15: backend parity across batch x gather
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("gather_mode", ["scalar", "dma"])
@pytest.mark.parametrize("batch", [1, 4, 16])
def test_two_stage_backends_agree(sg, rank, batch, gather_mode):
    """The acceptance matrix: pallas (both gather modes) vs the XLA oracle,
    bit-identical on candidate ids (stage 1), ranker scores and final
    ordering (stage 2), plus the walk telemetry."""
    g = sg.graph
    pins, weights, feats = _mk_batch(sg, batch)
    key = jax.random.key(11)
    scen = _scenarios(batch)
    cfg = _cfg(gather_mode=gather_mode)

    # stage-1 candidates agree (ranked retrieval runs top_k = n_candidates)
    ret_cfg = dataclasses.replace(cfg, top_k=rank.cfg.n_candidates)
    cand_p = service.serve_batch(
        g, pins, weights, feats, key, ret_cfg, backend="pallas"
    )
    cand_x = service.serve_batch(
        g, pins, weights, feats, key, ret_cfg, backend="xla"
    )
    for a, b, name in zip(cand_p, cand_x, ("scores", "ids")):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"stage-1 {name}"
        )

    # full two-stage parity, scores AND ordering AND telemetry
    out_p = service.serve_batch(
        g, pins, weights, feats, key, cfg, backend="pallas",
        rank=rank, scenario=scen, with_stats=True,
    )
    out_x = service.serve_batch(
        g, pins, weights, feats, key, cfg, backend="xla",
        rank=rank, scenario=scen, with_stats=True,
    )
    for a, b, name in zip(out_p, out_x, ("scores", "ids", "steps", "n_high")):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=name
        )
    scores, ids = np.asarray(out_p[0]), np.asarray(out_p[1])
    assert scores.shape == (batch, rank.cfg.final_k)
    assert ids.shape == (batch, rank.cfg.final_k)
    finite = np.isfinite(scores)
    assert finite.any(axis=1).all()  # every query got ranked results
    assert ((ids[finite] >= 0) & (ids[finite] < g.n_pins)).all()
    assert (ids[~finite] == -1).all()
    # ranked scores are sorted descending per query
    assert (np.diff(scores, axis=1) <= 0).all()


def test_recommend_two_stage_is_serve_batch(sg, rank):
    """The named entry point is the serve_batch(rank=...) program."""
    g = sg.graph
    pins, weights, feats = _mk_batch(sg, 4)
    key = jax.random.key(3)
    scen = _scenarios(4)
    cfg = _cfg()
    a = recommend_two_stage(
        g, pins, weights, feats, key, cfg, rank, scenario=scen,
        backend="pallas",
    )
    b = service.serve_batch(
        g, pins, weights, feats, key, cfg, backend="pallas",
        rank=rank, scenario=scen,
    )
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


# ---------------------------------------------------------------------------
# Lowering pin: constant pallas_call count, independent of batch size
# ---------------------------------------------------------------------------


def test_two_stage_lowers_to_constant_calls(sg, rank):
    """A batched two-stage serve step with stage 2 lowered through the bag
    KERNEL (the TPU shape) contains exactly 4 pallas_call eqns — 2 walk
    calls inside the one chunk while loop + 2 rank-1-grid embedding bags
    (candidate neighborhoods, query pool) — for EVERY batch size: batch
    scales grid cells, never launches."""
    g = sg.graph
    cfg = _cfg()
    ret_cfg = dataclasses.replace(cfg, top_k=rank.cfg.n_candidates)
    structures = {}
    for batch in (1, 16):
        pins, weights, feats = _mk_batch(sg, batch)
        scen = _scenarios(batch)

        def two_stage(key):
            s, i, st, nh = service.serve_batch(
                g, pins, weights, feats, key, ret_cfg, with_stats=True
            )
            return ranker_lib.rank_candidates(
                rank.params, rank.cfg, g, i, s, scen, use_kernel=True
            )

        grids = pallas_grids(jax.make_jaxpr(two_stage)(jax.random.key(0)))
        assert len(grids) == 4, grids
        # 2 walk-engine calls (rank-1 walk grid + rank-2 counter) and two
        # rank-1 bag grids; no grid anywhere leads with the batch axis
        assert sorted(len(grid) for grid in grids) == [1, 1, 1, 2], grids
        structures[batch] = (len(grids), sorted(len(g_) for g_ in grids))
    assert structures[1] == structures[16]

    # the platform-default path (CPU: oracle bags) is also batch-constant
    for batch in (1, 16):
        pins, weights, feats = _mk_batch(sg, batch)
        scen = _scenarios(batch)

        def ranked_serve(key):
            return service.serve_batch(
                g, pins, weights, feats, key, cfg, rank=rank, scenario=scen
            )

        grids = pallas_grids(jax.make_jaxpr(ranked_serve)(jax.random.key(0)))
        structures[f"serve{batch}"] = len(grids)
    assert structures["serve1"] == structures["serve16"]


# ---------------------------------------------------------------------------
# Stage boundary + scenario axis
# ---------------------------------------------------------------------------


def test_rank_candidates_takes_precomputed_stats(sg, rank):
    """Stage 2 consumes (ids, scores) directly: feeding it the SAME
    retrieval twice gives the same ranking with no walk in between (the
    old pixie_then_rank re-ran retrieval internally)."""
    g = sg.graph
    pins, weights, feats = _mk_batch(sg, 4)
    cfg = dataclasses.replace(_cfg(), top_k=rank.cfg.n_candidates)
    scores, ids = service.serve_batch(
        g, pins, weights, feats, jax.random.key(0), cfg
    )
    scen = _scenarios(4)
    a = ranker_lib.rank_candidates(rank.params, rank.cfg, g, ids, scores, scen)
    b = ranker_lib.rank_candidates(rank.params, rank.cfg, g, ids, scores, scen)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))
    # and the fused path produces exactly rank_candidates on its own
    # stage-1 output
    fused = service.serve_batch(
        g, pins, weights, feats, jax.random.key(0), cfg,
        rank=rank, scenario=scen,
    )
    np.testing.assert_array_equal(np.asarray(fused[0]), np.asarray(a[0]))
    np.testing.assert_array_equal(np.asarray(fused[1]), np.asarray(a[1]))


def test_scenario_heads_differ_and_select_per_request(sg, rank):
    """>= 2 heads, selected PER REQUEST: a mixed batch row equals the
    uniform-scenario run of the same row (head gather is per query), and
    the two heads genuinely rank differently."""
    g = sg.graph
    pins, weights, feats = _mk_batch(sg, 4)
    cfg = dataclasses.replace(_cfg(), top_k=rank.cfg.n_candidates)
    scores, ids = service.serve_batch(
        g, pins, weights, feats, jax.random.key(5), cfg
    )
    mixed = ranker_lib.rank_candidates(
        rank.params, rank.cfg, g, ids, scores, _scenarios(4)
    )
    uni0 = ranker_lib.rank_candidates(
        rank.params, rank.cfg, g, ids, scores, jnp.zeros((4,), jnp.int32)
    )
    uni1 = ranker_lib.rank_candidates(
        rank.params, rank.cfg, g, ids, scores, jnp.ones((4,), jnp.int32)
    )
    for row in range(4):
        src = uni0 if row % 2 == 0 else uni1
        np.testing.assert_array_equal(
            np.asarray(mixed[0])[row], np.asarray(src[0])[row]
        )
        np.testing.assert_array_equal(
            np.asarray(mixed[1])[row], np.asarray(src[1])[row]
        )
    assert not np.array_equal(np.asarray(uni0[0]), np.asarray(uni1[0]))


def test_rank_candidates_underfull_and_empty_queries(sg, rank):
    """Queries retrieving fewer than final_k real candidates report -1 ids
    (-inf scores) in the tail; an all-padding retrieval ranks to nothing."""
    g = sg.graph
    k = rank.cfg.n_candidates
    cand = jnp.tile(jnp.arange(k, dtype=jnp.int32)[None], (2, 1))
    scores = jnp.stack([
        jnp.where(jnp.arange(k) < 3, 1.0, 0.0),   # 3 real candidates
        jnp.zeros((k,)),                           # none
    ]).astype(jnp.float32)
    vals, ids = ranker_lib.rank_candidates(
        rank.params, rank.cfg, g, cand, scores, jnp.zeros((2,), jnp.int32)
    )
    vals, ids = np.asarray(vals), np.asarray(ids)
    assert set(ids[0][:3]) <= {0, 1, 2}
    assert (ids[0][3:] == -1).all() and np.isneginf(vals[0][3:]).all()
    assert (ids[1] == -1).all() and np.isneginf(vals[1]).all()


def test_pixie_then_rank_is_walk_plus_rank_retrieved(sg):
    """The refactor didn't change the callable-ranker path: pixie_then_rank
    == recommend(...) + rank_retrieved(...) on the same stats."""
    g = sg.graph
    qs = top_degree_pins(sg, 2)
    qp = jnp.asarray([int(qs[0]), int(qs[1])], jnp.int32)
    qw = jnp.asarray([1.0, 0.6], jnp.float32)
    cfg = _cfg(backend="xla")
    ts = TwoStageConfig(n_candidates=16, final_k=8)
    key = jax.random.key(2)

    def ranker(cand):
        return -cand.astype(jnp.float32)  # deterministic toy ranker

    feat = jnp.asarray(0, jnp.int32)
    a = pixie_then_rank(g, qp, qw, feat, key, cfg, ranker, ts)
    walk_cfg = dataclasses.replace(cfg, top_k=ts.n_candidates)
    ws, cand = walk_lib.recommend(g, qp, qw, feat, key, walk_cfg)
    b = rank_retrieved(ws, cand, ranker, ts.final_k)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_sasrec_ranker_masks_underfull_ids():
    """Regression: a -1 (under-full) candidate id must score -inf, not the
    embedding of item 0."""
    cfg = sr.SeqRecConfig(name="r", kind="sasrec", n_items=50, embed_dim=8,
                          seq_len=4, n_blocks=1, n_heads=1, n_negatives=2)
    params = sr.init_params(jax.random.key(0), cfg)
    score = sasrec_ranker(params, jnp.asarray([1, 2, 3, 4], jnp.int32), cfg)
    cand = jnp.asarray([5, -1, 0, -1], jnp.int32)
    s = np.asarray(score(cand))
    assert np.isneginf(s[[1, 3]]).all()
    assert np.isfinite(s[[0, 2]]).all()
    # item 0's finite score is untouched by the masking
    np.testing.assert_array_equal(
        s[2], np.asarray(score(jnp.asarray([0], jnp.int32)))[0]
    )


# ---------------------------------------------------------------------------
# Validation
# ---------------------------------------------------------------------------


def test_two_stage_validation(sg, rank):
    g = sg.graph
    pins, weights, feats = _mk_batch(sg, 2)
    with pytest.raises(ValueError, match="needs rank="):
        service.serve_batch(
            g, pins, weights, feats, jax.random.key(0), _cfg(),
            scenario=jnp.zeros((2,), jnp.int32),
        )
    with pytest.raises(ValueError, match="final_k"):
        ranker_lib.RankerConfig(n_items=10, n_candidates=4, final_k=8)
    with pytest.raises(ValueError, match="unknown scenario"):
        rank.cfg.scenario_id("shopping")
    assert rank.cfg.scenario_id("homefeed") == 1
    with pytest.raises(ValueError, match="item table"):
        bad = ranker_lib.RankerConfig(
            n_items=sg.graph.n_pins + 1, n_candidates=16, final_k=8
        )
        ranker_lib.rank_candidates(
            ranker_lib.init_ranker_params(jax.random.key(0), bad), bad, g,
            jnp.zeros((1, 16), jnp.int32), jnp.zeros((1, 16)),
            jnp.zeros((1,), jnp.int32),
        )
    with pytest.raises(ValueError, match="batched"):
        ranker_lib.rank_candidates(
            rank.params, rank.cfg, g, jnp.zeros((16,), jnp.int32),
            jnp.zeros((16,)), 0,
        )


# ---------------------------------------------------------------------------
# PixieServer: ranked dispatch on the continuous-traffic path
# ---------------------------------------------------------------------------


def test_server_ranked_dispatch_matches_direct_serve(sg, rank):
    """A ranked replica's flush equals serve_batch(rank=...) on the same
    requests with the same fold_in keys and scenarios — the two-stage
    program rides the PR 7 dispatch machinery unchanged."""
    g = sg.graph
    cfg = _cfg()
    qs = top_degree_pins(sg, 8)
    srv = PixieServer(g, cfg, batch_size=4, n_slots=2, seed=13, ranker=rank)
    scen = [0, 1, 1, 0]
    for i in range(4):
        srv.submit(
            [int(qs[2 * i]), int(qs[2 * i + 1])], [1.0, 0.6],
            scenario=scen[i],
        )
    results = srv.flush()
    assert [r.req_id for r in results] == [0, 1, 2, 3]

    pins = jnp.asarray(
        [[int(qs[2 * i]), int(qs[2 * i + 1])] for i in range(4)], jnp.int32
    )
    weights = jnp.tile(jnp.asarray([1.0, 0.6], jnp.float32)[None], (4, 1))
    feats = jnp.zeros((4,), jnp.int32)
    keys = jnp.stack(
        [jax.random.fold_in(jax.random.key(13), i) for i in range(4)]
    )
    # the oracle must be jitted exactly like the server's program: stage 2
    # runs float math, and an eager (op-by-op) evaluation can differ from
    # the fused XLA program in the last ulp — bit-parity contracts here
    # are per compiled program, the same rule the backend-parity tests use
    oracle = jax.jit(
        lambda graph, p, w, f, k, sc: service.serve_batch(
            graph, p, w, f, k, cfg, rank=rank, scenario=sc
        )
    )
    want_s, want_i = oracle(
        g, pins, weights, feats, keys, jnp.asarray(scen, jnp.int32)
    )
    for i, r in enumerate(results):
        np.testing.assert_array_equal(r.scores, np.asarray(want_s)[i])
        np.testing.assert_array_equal(r.ids, np.asarray(want_i)[i])
        assert r.ids.shape == (rank.cfg.final_k,)


def test_server_scenario_validation(sg, rank):
    srv = PixieServer(sg.graph, _cfg(), batch_size=2, n_slots=2, seed=0,
                      ranker=rank)
    with pytest.raises(ValueError, match="out of range"):
        srv.submit([1, 2], [1.0, 1.0], scenario=rank.cfg.n_scenarios)
    plain = PixieServer(sg.graph, _cfg(), batch_size=2, n_slots=2, seed=0)
    with pytest.raises(ValueError, match="retrieval-only"):
        plain.submit([1, 2], [1.0, 1.0], scenario=1)


def test_server_ranked_partial_batch_padding(sg, rank):
    """A deadline-dispatched partial batch pads with zero-weight queries;
    what rides the OTHER lanes of the batch — padding or real traffic —
    must not perturb a request's ranked result.  (Same bucket shape both
    times: per-program bit-parity, like traffic_buckets_agree.)"""
    g = sg.graph
    cfg = _cfg()
    qs = top_degree_pins(sg, 8)
    a = PixieServer(g, cfg, batch_size=4, n_slots=2, seed=4, ranker=rank)
    a.submit([int(qs[0]), int(qs[1])], [1.0, 0.6], scenario=1)
    ra = a.flush()[0]  # dispatched padded: 1 real lane + 3 zero-weight
    b = PixieServer(g, cfg, batch_size=4, n_slots=2, seed=4, ranker=rank)
    b.submit([int(qs[0]), int(qs[1])], [1.0, 0.6], scenario=1)
    for i in range(1, 4):  # same req 0 (same fold_in key) + real traffic
        b.submit([int(qs[2 * i]), int(qs[2 * i + 1])], [1.0, 0.6],
                 scenario=i % 2)
    rb = next(r for r in b.flush() if r.req_id == 0)
    np.testing.assert_array_equal(ra.scores, rb.scores)
    np.testing.assert_array_equal(ra.ids, rb.ids)
