"""Fallback so property tests collect (and run) without `hypothesis`.

The container image does not ship hypothesis; a bare `from hypothesis import
...` aborts collection of the whole module, which under `pytest -x` kills the
entire tier-1 run.  When the real library is available we re-export it
untouched.  When it is missing, `given`/`settings`/`st` degrade to a tiny
seeded-random sampler: each property test runs against a deterministic batch
of random examples drawn from the same strategy shapes.  That is weaker than
real shrinking-and-database hypothesis, but it keeps every property assertion
exercised on every CI run instead of skipping the module wholesale.

Only the strategy surface this repo uses is implemented: `st.integers`,
`st.floats`, `st.booleans`, `st.sampled_from`, and (nested) `st.lists`.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import functools
    import random

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 10
    _MAX_EXAMPLES_CAP = 25  # keep the fallback fast; hypothesis-proper sweeps more

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=2**31 - 1):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

        @staticmethod
        def lists(elements, min_size=0, max_size=10, **_kw):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.example(rng) for _ in range(n)]

            return _Strategy(draw)

    st = _Strategies()

    def settings(*, max_examples=_DEFAULT_EXAMPLES, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = min(
                    getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES),
                    _MAX_EXAMPLES_CAP,
                )
                rng = random.Random(0x9137)
                for _ in range(n):
                    drawn = {k: s.example(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)

            # hide the strategy-drawn parameters from pytest's fixture
            # resolver (hypothesis-proper does the same)
            import inspect

            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies
            ])
            return wrapper

        return deco
