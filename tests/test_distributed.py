"""Distribution tests that need multiple devices: run in a subprocess with
--xla_force_host_platform_device_count (device count locks at jax init, so
the main pytest process must keep seeing 1 CPU device)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(n_devices: int, body: str) -> dict:
    """Execute `body` in a fresh python with n fake devices; body must print
    a single json object on its last line.

    The prelude imports the version-compat shims `make_mesh_compat` (the
    pinned JAX has no jax.sharding.AxisType / axis_types kwarg) and
    `set_mesh_compat` (no jax.set_mesh; explicit mesh= arguments make the
    ambient mesh unnecessary there, so it degrades to a null context) from
    repro.launch.mesh.
    """
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_devices}"
        import json
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.launch.mesh import make_mesh_compat, set_mesh_compat
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True, text=True, env=env, timeout=540,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_walk_agrees_with_replicated():
    res = _run(4, """
        from repro.graphs.synthetic import small_test_graph, top_degree_pins
        from repro.core import distributed as D, walk as W
        sg = small_test_graph()
        mesh = make_mesh_compat((2, 2), ("data", "model"))
        shg = D.shard_graph(sg.graph, 2)
        qs = top_degree_pins(sg, 2)
        qp = jnp.asarray([int(qs[0]), int(qs[1]), -1, -1], jnp.int32)
        qw = jnp.asarray([1.0, 0.7, 0.0, 0.0], jnp.float32)
        cfg = D.ShardedWalkConfig(n_supersteps=64, walkers_per_shard=128,
                                  top_k=20)
        with set_mesh_compat(mesh):
            res = D.pixie_walk_sharded(shg, qp, qw, jax.random.key(0), cfg,
                                       mesh)
        wcfg = W.WalkConfig(n_steps=30000, n_walkers=256, bias_beta=0.0,
                            top_k=20, n_p=10**9, n_v=10**9)
        _, ids = W.recommend(sg.graph, qp, qw, jnp.asarray(0, jnp.int32),
                             jax.random.key(1), wcfg)
        ov = len(set(np.asarray(res.top_pins).tolist())
                 & set(np.asarray(ids).tolist()))
        print(json.dumps({"overlap": ov, "dropped": int(res.dropped)}))
    """)
    assert res["overlap"] >= 10, res  # statistical agreement of top-20


def test_sharded_embedding_lookup_matches_replicated():
    res = _run(4, """
        from repro.models import embedding as E
        mesh = make_mesh_compat((2, 2), ("data", "model"))
        cfg = E.MegaTableConfig(feature_rows=(40, 24), dim=8,
                                pad_to_multiple=8)
        table = jax.random.normal(jax.random.key(0),
                                  (cfg.total_rows, cfg.dim))
        ids = jnp.stack([
            jax.random.randint(jax.random.key(1), (16,), 0, 40),
            jax.random.randint(jax.random.key(2), (16,), 0, 24),
        ], axis=1)
        want = E.lookup(table, ids, cfg)
        with set_mesh_compat(mesh):
            got = E.lookup_sharded(table, ids, cfg, mesh)
        err = float(jnp.abs(want - got).max())
        print(json.dumps({"max_err": err}))
    """)
    assert res["max_err"] < 1e-5


def test_checkpoint_reshards_onto_different_mesh():
    """Elastic restart: save on a (4,)-mesh sharded layout, restore onto a
    (2,)-mesh — the checkpoint is topology-agnostic."""
    body_save = """
        import tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.training import checkpoint
        mesh = make_mesh_compat((%d,), ("model",))
        x = jnp.arange(32.0).reshape(8, 4)
        sharded = jax.device_put(x, NamedSharding(mesh, P("model", None)))
        checkpoint.save("%s", 3, {"x": sharded})
        restored, step = checkpoint.restore(
            "%s", {"x": jnp.zeros((8, 4))},
            shardings={"x": NamedSharding(mesh, P("model", None))},
        )
        ok = bool(jnp.allclose(restored["x"], x))
        print(json.dumps({"ok": ok, "step": step}))
    """
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        res4 = _run(4, body_save % (4, d, d))
        assert res4["ok"]
        # restore the same checkpoint in a 2-device world
        res2 = _run(2, """
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.training import checkpoint
            mesh = make_mesh_compat((2,), ("model",))
            restored, step = checkpoint.restore(
                "%s", {"x": jnp.zeros((8, 4))},
                shardings={"x": NamedSharding(mesh, P("model", None))},
            )
            want = jnp.arange(32.0).reshape(8, 4)
            ok = bool(jnp.allclose(restored["x"], want))
            n_shards = len(restored["x"].sharding.device_set)
            print(json.dumps({"ok": ok, "step": step,
                              "n_shards": n_shards}))
        """ % d)
        assert res2["ok"] and res2["step"] == 3 and res2["n_shards"] == 2


def test_compressed_psum_averages_across_shards():
    res = _run(4, """
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.training import compression
        mesh = make_mesh_compat((4,), ("data",))
        # per-shard gradients 0,1,2,3 -> mean 1.5
        g = jnp.repeat(jnp.arange(4.0)[:, None], 8, axis=1)
        r = jnp.zeros_like(g)
        def f(gg, rr):
            out, nr = compression.compressed_psum(
                {"w": gg[0]}, {"w": rr[0]}, "data")
            return out["w"][None], nr["w"][None]
        with set_mesh_compat(mesh):
            out, _ = shard_map(f, mesh=mesh,
                               in_specs=(P("data", None), P("data", None)),
                               out_specs=(P("data", None), P("data", None)),
                               check_rep=False)(g, r)
        err = float(jnp.abs(out - 1.5).max())
        print(json.dumps({"max_err": err}))
    """)
    assert res["max_err"] < 0.02  # within int8 quantization noise
