"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracle.

Sweeps shapes and dtypes per kernel; integer kernels must match exactly,
floating kernels within documented tolerances.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.embedding_bag import embedding_bag, embedding_bag_batched
from repro.kernels.visit_counter import visit_counter
from repro.kernels.walk_step import walk_step


# ---------------------------------------------------------------------------
# visit_counter
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", [128, 2048, 5000])
@pytest.mark.parametrize("n_bins", [64, 512, 1300])
def test_visit_counter_matches_ref(m, n_bins):
    key = jax.random.key(m * 7 + n_bins)
    events = jax.random.randint(key, (m,), -5, n_bins + 20, dtype=jnp.int32)
    got = visit_counter(events, n_bins, interpret=True)
    want = ref.visit_counter_ref(events, n_bins)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(got.sum()) <= m


@pytest.mark.parametrize("tile,chunk", [(128, 256), (512, 2048), (256, 1024)])
def test_visit_counter_tilings(tile, chunk):
    key = jax.random.key(0)
    events = jax.random.randint(key, (4096,), 0, 777, dtype=jnp.int32)
    got = visit_counter(events, 777, tile=tile, chunk=chunk, interpret=True)
    want = ref.visit_counter_ref(events, 777)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_visit_counter_all_invalid():
    events = jnp.full((512,), -1, jnp.int32)
    got = visit_counter(events, 256, interpret=True)
    assert int(got.sum()) == 0


# ---------------------------------------------------------------------------
# walk_step
# ---------------------------------------------------------------------------


def _tiny_csr(key, n_pins=50, n_boards=12, n_edges=400):
    kp, kb = jax.random.split(key)
    pins = jax.random.randint(kp, (n_edges,), 0, n_pins)
    boards = jax.random.randint(kb, (n_edges,), 0, n_boards)
    pins = np.asarray(pins)
    boards = np.asarray(boards)
    # p2b
    order = np.argsort(pins, kind="stable")
    p2b_off = np.zeros(n_pins + 1, np.int32)
    np.cumsum(np.bincount(pins, minlength=n_pins), out=p2b_off[1:])
    p2b_tgt = (boards[order] + n_pins).astype(np.int32)
    # b2p
    order_b = np.argsort(boards, kind="stable")
    b2p_off = np.zeros(n_boards + 1, np.int32)
    np.cumsum(np.bincount(boards, minlength=n_boards), out=b2p_off[1:])
    b2p_tgt = pins[order_b].astype(np.int32)
    return (
        jnp.asarray(p2b_off), jnp.asarray(p2b_tgt),
        jnp.asarray(b2p_off), jnp.asarray(b2p_tgt),
        n_pins,
    )


@pytest.mark.parametrize("w,block_w", [(256, 256), (512, 128), (1024, 256)])
@pytest.mark.parametrize("alpha_u32", [0, 2**31, 2**32 - 1])
def test_walk_step_matches_ref(w, block_w, alpha_u32):
    key = jax.random.key(w + alpha_u32 % 97)
    p2b_off, p2b_tgt, b2p_off, b2p_tgt, n_pins = _tiny_csr(key)
    k1, k2, k3 = jax.random.split(key, 3)
    curr = jax.random.randint(k1, (w,), 0, n_pins, dtype=jnp.int32)
    query = jax.random.randint(k2, (w,), 0, n_pins, dtype=jnp.int32)
    rbits = jax.random.bits(k3, (w, 3), dtype=jnp.uint32)
    got = walk_step(
        curr, query, rbits, p2b_off, p2b_tgt, b2p_off, b2p_tgt,
        n_pins=n_pins, alpha_u32=alpha_u32, block_w=block_w, interpret=True,
    )
    want = ref.walk_step_ref(
        curr, query, rbits, p2b_off, p2b_tgt, b2p_off, b2p_tgt,
        n_pins=n_pins, alpha_u32=alpha_u32,
    )
    for g, w_ in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w_))


def test_walk_step_dead_end_restarts():
    # pin 0 has no boards: walkers there must restart at query, invalid visit
    p2b_off = jnp.asarray([0, 0, 2], jnp.int32)        # pin0 deg 0, pin1 deg 2
    p2b_tgt = jnp.asarray([2, 2], jnp.int32)           # board id 2 (= n_pins)
    b2p_off = jnp.asarray([0, 2], jnp.int32)
    b2p_tgt = jnp.asarray([0, 1], jnp.int32)
    w = 256
    curr = jnp.zeros((w,), jnp.int32)                  # all at dead-end pin 0
    query = jnp.ones((w,), jnp.int32)
    rbits = jax.random.bits(jax.random.key(0), (w, 3), dtype=jnp.uint32)
    nxt, vis, ok = walk_step(
        curr, query, rbits, p2b_off, p2b_tgt, b2p_off, b2p_tgt,
        n_pins=2, alpha_u32=0, block_w=128, interpret=True,
    )
    assert not bool(ok.any())
    np.testing.assert_array_equal(np.asarray(nxt), np.ones(w, np.int32))


# ---------------------------------------------------------------------------
# embedding_bag
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,l,v,d", [(32, 4, 100, 64), (100, 1, 50, 128), (64, 8, 1000, 32)])
@pytest.mark.parametrize("mode", ["sum", "mean"])
def test_embedding_bag_matches_ref(dtype, b, l, v, d, mode):
    key = jax.random.key(b * l + d)
    kt, ki, kw = jax.random.split(key, 3)
    table = jax.random.normal(kt, (v, d), dtype=jnp.float32).astype(dtype)
    ids = jax.random.randint(ki, (b, l), -1, v, dtype=jnp.int32)
    weights = jax.random.uniform(kw, (b, l), dtype=jnp.float32)
    got = embedding_bag(table, ids, weights, mode=mode, interpret=True)
    want = ref.embedding_bag_ref(table, ids, weights, mode=mode)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_embedding_bag_all_padding():
    table = jnp.ones((10, 16), jnp.float32)
    ids = jnp.full((8, 4), -1, jnp.int32)
    out = embedding_bag(table, ids, mode="mean", interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.zeros((8, 16)))


@pytest.mark.parametrize("with_weights", [True, False])
@pytest.mark.parametrize("mode", ["sum", "mean"])
def test_embedding_bag_weight_padding_zeroed(mode, with_weights):
    """A padded (-1) slot contributes NOTHING even when its weight lane
    holds garbage — validity gates the weight, not the other way round."""
    table = jax.random.normal(jax.random.key(0), (20, 8), jnp.float32)
    ids_clean = jnp.asarray([[3, 5, -1, -1], [7, -1, -1, -1]], jnp.int32)
    w = jnp.asarray(
        [[0.5, 1.5, 99.0, -7.0], [2.0, 123.0, 4.0, 5.0]], jnp.float32
    )
    w_clean = jnp.where(ids_clean >= 0, w, 0.0)
    kw = dict(mode=mode, interpret=True)
    got = embedding_bag(table, ids_clean, w if with_weights else None, **kw)
    want = embedding_bag(
        table, ids_clean, w_clean if with_weights else None, **kw
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# embedding_bag_batched (the two-stage serving shape)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,k,l,v,d",
    [
        (1, 8, 4, 100, 32),     # single query
        (4, 16, 8, 500, 16),    # serving-ish
        (3, 33, 5, 50, 8),      # b*k not a block_b multiple (padding path)
        (2, 64, 1, 40, 128),    # single-hot bags
    ],
)
@pytest.mark.parametrize("mode", ["sum", "mean"])
def test_embedding_bag_batched_matches_ref(dtype, b, k, l, v, d, mode):
    """Kernel vs the ORDER-MATCHED oracle: both accumulate each bag in
    ascending element order, so the only residual divergence is compiler
    FMA contraction — last-ulp, hence the tight (not zero) tolerance."""
    key = jax.random.key(b * 1000 + k * 10 + l)
    kt, ki, kw = jax.random.split(key, 3)
    table = jax.random.normal(kt, (v, d), dtype=jnp.float32).astype(dtype)
    ids = jax.random.randint(ki, (b, k, l), -1, v, dtype=jnp.int32)
    weights = jax.random.uniform(kw, (b, k, l), dtype=jnp.float32)
    for w in (weights, None):
        got = embedding_bag_batched(table, ids, w, mode=mode, interpret=True)
        want = ref.embedding_bag_batched_ref(table, ids, w, mode=mode)
        assert got.shape == (b, k, d)
        tol = 2e-6 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=tol, atol=tol,
        )


def test_embedding_bag_batched_matches_flat():
    """(b, k, l) bags are EXACTLY the flattened (b*k, l) bags through the
    per-bag kernel — same kernel body, same launch plumbing, so this is
    array_equal, not allclose."""
    kt, ki, kw = jax.random.split(jax.random.key(3), 3)
    b, k, l, v, d = 3, 20, 6, 80, 16
    table = jax.random.normal(kt, (v, d), jnp.float32)
    ids = jax.random.randint(ki, (b, k, l), -1, v, dtype=jnp.int32)
    weights = jax.random.uniform(kw, (b, k, l), jnp.float32)
    got = embedding_bag_batched(table, ids, weights, mode="mean",
                                interpret=True)
    flat = embedding_bag(table, ids.reshape(b * k, l),
                         weights.reshape(b * k, l), mode="mean",
                         interpret=True)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(flat.reshape(b, k, d))
    )


def test_embedding_bag_batched_ragged_and_empty():
    """Ragged neighborhoods: rows mixing full, partial, and EMPTY (all -1)
    bags — empty bags pool to exact zero in both modes (mean's denominator
    clamps at 1), never NaN."""
    table = jax.random.normal(jax.random.key(1), (30, 8), jnp.float32)
    ids = jnp.asarray(
        [
            [[1, 2, 3], [4, -1, -1], [-1, -1, -1]],
            [[-1, -1, -1], [-1, -1, -1], [29, 0, -1]],
        ],
        jnp.int32,
    )
    weights = jnp.ones_like(ids, jnp.float32)
    for mode in ("sum", "mean"):
        out = np.asarray(
            embedding_bag_batched(table, ids, weights, mode=mode,
                                  interpret=True)
        )
        want = np.asarray(
            ref.embedding_bag_batched_ref(table, ids, weights, mode=mode)
        )
        assert np.isfinite(out).all()
        np.testing.assert_allclose(out, want, rtol=1e-6, atol=1e-6)
        # empty bags exactly zero
        assert not out[0, 2].any() and not out[1, 0].any() and not out[1, 1].any()


def test_embedding_bag_batched_small_blocks():
    """block_b smaller than a row count that doesn't divide it: the padded
    tail rows must not leak into real outputs."""
    kt, ki = jax.random.split(jax.random.key(5))
    table = jax.random.normal(kt, (25, 4), jnp.float32)
    ids = jax.random.randint(ki, (2, 7, 3), -1, 25, dtype=jnp.int32)
    got = embedding_bag_batched(table, ids, None, mode="sum", block_b=4,
                                interpret=True)
    want = ref.embedding_bag_batched_ref(table, ids, None, mode="sum")
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6
    )


def test_embedding_bag_batched_rejects_2d():
    table = jnp.ones((4, 4), jnp.float32)
    with pytest.raises(ValueError, match="batch, bags, bag_size"):
        embedding_bag_batched(table, jnp.zeros((3, 2), jnp.int32),
                              interpret=True)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,kh,dh,s", [(2, 8, 2, 64, 512), (1, 16, 16, 128, 300), (4, 4, 1, 128, 1024)]
)
def test_decode_attention_matches_ref(dtype, b, h, kh, dh, s):
    key = jax.random.key(h * s + dh)
    kq, kk, kv, kl = jax.random.split(key, 4)
    q = jax.random.normal(kq, (b, h, dh), dtype=jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (b, s, kh, dh), dtype=jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (b, s, kh, dh), dtype=jnp.float32).astype(dtype)
    lengths = jax.random.randint(kl, (b,), 1, s + 1, dtype=jnp.int32)
    got = decode_attention(q, k, v, lengths, block_s=256, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lengths)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=tol, atol=tol
    )


def test_decode_attention_length_one():
    # every sequence has exactly 1 valid kv: output == v[:, 0]
    b, h, kh, dh, s = 2, 4, 2, 64, 256
    key = jax.random.key(0)
    q = jax.random.normal(key, (b, h, dh))
    k = jax.random.normal(jax.random.key(1), (b, s, kh, dh))
    v = jax.random.normal(jax.random.key(2), (b, s, kh, dh))
    lengths = jnp.ones((b,), jnp.int32)
    out = decode_attention(q, k, v, lengths, interpret=True)
    want = jnp.repeat(v[:, 0], h // kh, axis=1).reshape(b, h, dh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)
