"""Counter aggregation + graph pruning behaviour tests (+hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis, or seeded fallback

from repro.core import counter as counter_lib
from repro.core import pruning
from repro.graphs.synthetic import small_test_graph


# ---------------------------------------------------------------------------
# events_to_counts: sort-aggregation == numpy bincount
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    events=st.lists(st.integers(0, 99), min_size=1, max_size=300),
    n_invalid=st.integers(0, 50),
)
def test_events_to_counts_matches_bincount(events, n_invalid):
    # single query slot; invalid events carry the slot-lane sentinel (= 1)
    pin_ev = np.asarray(events + [0] * n_invalid, np.int32)
    slot_ev = np.asarray([0] * len(events) + [1] * n_invalid, np.int32)
    perm = np.random.default_rng(0).permutation(pin_ev.shape[0])
    pin_ev, slot_ev = pin_ev[perm], slot_ev[perm]
    uniq_slot, uniq_pin, counts = counter_lib.events_to_counts(
        jnp.asarray(slot_ev), jnp.asarray(pin_ev),
        n_slots=1, max_unique=pin_ev.shape[0],
    )
    uniq_slot = np.asarray(uniq_slot)
    uniq_pin, counts = np.asarray(uniq_pin), np.asarray(counts)
    got = {}
    for s, u, c in zip(uniq_slot, uniq_pin, counts):
        if c > 0 and s < 1:
            got[int(u)] = got.get(int(u), 0) + int(c)
    want = {int(k): int(v) for k, v in
            zip(*np.unique(np.asarray(events), return_counts=True))}
    assert got == want
    # the run arrays stay lexicographically sorted (the incremental
    # early-stop fold binary-searches them)
    key = uniq_slot.astype(np.int64) * 2**32 + uniq_pin
    assert (np.diff(key) >= 0).all()


@settings(max_examples=30, deadline=None)
@given(
    counts=st.lists(
        st.lists(st.integers(0, 50), min_size=4, max_size=4),
        min_size=1, max_size=4,
    )
)
def test_boost_combine_eq3(counts):
    c = jnp.asarray(counts, jnp.int32)
    got = np.asarray(counter_lib.boost_combine(c))
    want = np.square(np.sqrt(np.asarray(counts, np.float64)).sum(axis=0))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_boosted_from_events_cross_slot():
    # slot 0 visits pin 3 four times; slot 1 visits pin 3 nine times
    n_slots, n_pins = 2, 10
    slot_ev = jnp.asarray([0] * 4 + [1] * 9 + [n_slots] * 3, jnp.int32)
    pin_ev = jnp.asarray([3] * 4 + [3] * 9 + [0] * 3, jnp.int32)
    uniq_slot, uniq_pin, counts = counter_lib.events_to_counts(
        slot_ev, pin_ev, n_slots, slot_ev.shape[0]
    )
    pins, boosted = counter_lib.boosted_from_events(
        uniq_slot, uniq_pin, counts, n_slots, n_pins, slot_ev.shape[0]
    )
    pins, boosted = np.asarray(pins), np.asarray(boosted)
    idx = np.where(pins == 3)[0]
    assert idx.size == 1
    assert boosted[idx[0]] == pytest.approx((2 + 3) ** 2)


# ---------------------------------------------------------------------------
# pruning
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def sg():
    return small_test_graph()


def test_entropy_pruning_targets_diverse_boards(sg):
    from repro.core.graph import edge_list

    pins, boards = edge_list(sg.graph)
    ent = pruning.board_entropy(
        pins, boards, sg.pin_topics, sg.graph.n_boards
    )
    # diverse boards (near-uniform planted mixtures) should rank high
    board_ent_rank = np.argsort(-ent)
    top_drop = set(board_ent_rank[: int(0.1 * sg.graph.n_boards)].tolist())
    # entropy of dropped boards strictly above the median board
    assert ent[list(top_drop)].min() >= np.median(ent[ent > 0])


@pytest.mark.parametrize("delta", [1.0, 0.9, 0.7])
def test_degree_pruning_bounds(sg, delta):
    cfg = pruning.PruneConfig(entropy_board_frac=0.0, delta=delta)
    pruned, stats = pruning.prune_graph(
        sg.graph, sg.pin_topics, None, cfg
    )
    degs_before = np.asarray(sg.graph.p2b.degrees())
    degs_after = np.asarray(pruned.p2b.degrees())
    # per-pin: ceil(d^delta) edges kept (within min_keep floor)
    target = np.maximum(
        np.ceil(degs_before.astype(np.float64) ** delta),
        np.minimum(degs_before, cfg.min_keep),
    )
    assert (degs_after <= target + 1e-9).all()
    if delta == 1.0:
        assert stats["edges_after"] == stats["edges_after_entropy"]


def test_pruning_monotone_in_delta(sg):
    edges = []
    for delta in (1.0, 0.9, 0.8, 0.6):
        cfg = pruning.PruneConfig(entropy_board_frac=0.1, delta=delta)
        _, stats = pruning.prune_graph(sg.graph, sg.pin_topics, None, cfg)
        edges.append(stats["edges_after"])
    assert edges == sorted(edges, reverse=True)


def _tiny_edge_graph():
    """Hand-built graph with degree-0, degree-1, and high-degree pins.

    pin 0: isolated (degree 0); pin 1: one edge; pin 2: two edges;
    pin 3: six edges across three boards.
    """
    from repro.core.graph import build_graph

    pins = np.asarray([1, 2, 2, 3, 3, 3, 3, 3, 3])
    boards = np.asarray([0, 0, 1, 0, 1, 2, 0, 1, 2])
    g = build_graph(pins, boards, n_pins=4, n_boards=3)
    rng = np.random.default_rng(0)
    pin_topics = rng.dirichlet(np.ones(4), size=4).astype(np.float32)
    return g, pin_topics


def test_prune_graph_degree_0_and_1_pins_with_min_keep():
    """Degree pruning must never invent or drop edges below the min_keep
    floor: a degree-0 pin stays empty, a degree-1 pin keeps its edge even
    at aggressive delta, and no pin drops below min(degree, min_keep)."""
    g, pin_topics = _tiny_edge_graph()
    cfg = pruning.PruneConfig(entropy_board_frac=0.0, delta=0.1, min_keep=2)
    pruned, stats = pruning.prune_graph(g, pin_topics, None, cfg)
    degs_before = np.asarray(g.p2b.degrees())
    degs_after = np.asarray(pruned.p2b.degrees())
    assert degs_before.tolist() == [0, 1, 2, 6]
    assert degs_after[0] == 0            # degree-0: nothing to keep
    assert degs_after[1] == 1            # degree-1: min_keep floor holds it
    assert degs_after[2] == 2            # at the floor already
    # min(degree, min_keep) is a hard floor for every pin
    floor = np.minimum(degs_before, cfg.min_keep)
    assert (degs_after >= floor).all()
    assert (degs_after <= degs_before).all()
    assert stats["edges_after"] <= stats["edges_before"]


def test_prune_graph_zero_entropy_frac_drops_no_boards():
    """entropy_board_frac=0.0 must be a no-op for stage 1: every edge
    survives to the degree-pruning stage and no board disappears."""
    g, pin_topics = _tiny_edge_graph()
    cfg = pruning.PruneConfig(entropy_board_frac=0.0, delta=1.0)
    pruned, stats = pruning.prune_graph(g, pin_topics, None, cfg)
    assert "boards_dropped" not in stats
    assert stats["edges_after_entropy"] == stats["edges_before"]
    # delta=1.0 keeps ceil(d^1) = d edges: the whole graph passes through
    assert stats["edges_after"] == stats["edges_before"]
    np.testing.assert_array_equal(
        np.asarray(pruned.p2b.degrees()), np.asarray(g.p2b.degrees())
    )


@pytest.mark.parametrize("frac,delta", [(0.0, 0.9), (0.34, 0.7), (0.1, 1.0)])
def test_prune_graph_stats_invariants(sg, frac, delta):
    """Invariants every pruning config must satisfy: edge counts only
    shrink stage to stage, and the keep fraction lands in (0, 1]."""
    cfg = pruning.PruneConfig(entropy_board_frac=frac, delta=delta)
    _, stats = pruning.prune_graph(sg.graph, sg.pin_topics, None, cfg)
    assert stats["edges_after"] <= stats["edges_after_entropy"]
    assert stats["edges_after_entropy"] <= stats["edges_before"]
    assert 0.0 < stats["edge_keep_frac"] <= 1.0
    assert stats["bytes_after"] <= stats["bytes_before"]
    if frac > 0.0:
        assert stats["boards_dropped"] == int(frac * sg.graph.n_boards)


def test_pruning_keeps_topical_edges(sg):
    """The edges kept must have higher pin-board cosine sim than dropped."""
    from repro.core.graph import edge_list

    cfg = pruning.PruneConfig(entropy_board_frac=0.0, delta=0.7)
    pruned, _ = pruning.prune_graph(sg.graph, sg.pin_topics, None, cfg)
    # board topic dists from the original graph
    pins_b, boards_b = edge_list(sg.graph)
    nt = sg.pin_topics.shape[1]
    sums = np.zeros((sg.graph.n_boards, nt))
    np.add.at(sums, boards_b, sg.pin_topics[pins_b])
    cnt = np.maximum(np.bincount(boards_b, minlength=sg.graph.n_boards), 1)
    bt = sums / cnt[:, None]

    def mean_sim(graph):
        p, b = edge_list(graph)
        return pruning.cosine_sim(sg.pin_topics[p], bt[b]).mean()

    assert mean_sim(pruned) > mean_sim(sg.graph)
