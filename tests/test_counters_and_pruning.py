"""Counter aggregation + graph pruning behaviour tests (+hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis, or seeded fallback

from repro.core import counter as counter_lib
from repro.core import pruning
from repro.graphs.synthetic import small_test_graph


# ---------------------------------------------------------------------------
# events_to_counts: sort-aggregation == numpy bincount
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    events=st.lists(st.integers(0, 99), min_size=1, max_size=300),
    n_invalid=st.integers(0, 50),
)
def test_events_to_counts_matches_bincount(events, n_invalid):
    sentinel = 1000
    ev = np.asarray(events + [sentinel] * n_invalid, np.int64)
    np.random.default_rng(0).shuffle(ev)
    uniq, counts = counter_lib.events_to_counts(
        jnp.asarray(ev), n_slots=1, max_unique=ev.shape[0]
    )
    uniq, counts = np.asarray(uniq), np.asarray(counts)
    got = {}
    for u, c in zip(uniq, counts):
        if c > 0 and u < sentinel:
            got[int(u)] = got.get(int(u), 0) + int(c)
    want = {int(k): int(v) for k, v in
            zip(*np.unique(np.asarray(events), return_counts=True))}
    assert got == want


@settings(max_examples=30, deadline=None)
@given(
    counts=st.lists(
        st.lists(st.integers(0, 50), min_size=4, max_size=4),
        min_size=1, max_size=4,
    )
)
def test_boost_combine_eq3(counts):
    c = jnp.asarray(counts, jnp.int32)
    got = np.asarray(counter_lib.boost_combine(c))
    want = np.square(np.sqrt(np.asarray(counts, np.float64)).sum(axis=0))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_boosted_from_events_cross_slot():
    # slot 0 visits pin 3 four times; slot 1 visits pin 3 nine times
    n_pins, sentinel = 10, 2 * 10
    events = jnp.asarray([3] * 4 + [13] * 9 + [sentinel] * 3, jnp.int64)
    uniq, counts = counter_lib.events_to_counts(events, 2, events.shape[0])
    pins, boosted = counter_lib.boosted_from_events(
        uniq, counts, n_pins, sentinel, events.shape[0]
    )
    pins, boosted = np.asarray(pins), np.asarray(boosted)
    idx = np.where(pins == 3)[0]
    assert idx.size == 1
    assert boosted[idx[0]] == pytest.approx((2 + 3) ** 2)


# ---------------------------------------------------------------------------
# pruning
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def sg():
    return small_test_graph()


def test_entropy_pruning_targets_diverse_boards(sg):
    from repro.core.graph import edge_list

    pins, boards = edge_list(sg.graph)
    ent = pruning.board_entropy(
        pins, boards, sg.pin_topics, sg.graph.n_boards
    )
    # diverse boards (near-uniform planted mixtures) should rank high
    board_ent_rank = np.argsort(-ent)
    top_drop = set(board_ent_rank[: int(0.1 * sg.graph.n_boards)].tolist())
    # entropy of dropped boards strictly above the median board
    assert ent[list(top_drop)].min() >= np.median(ent[ent > 0])


@pytest.mark.parametrize("delta", [1.0, 0.9, 0.7])
def test_degree_pruning_bounds(sg, delta):
    cfg = pruning.PruneConfig(entropy_board_frac=0.0, delta=delta)
    pruned, stats = pruning.prune_graph(
        sg.graph, sg.pin_topics, None, cfg
    )
    degs_before = np.asarray(sg.graph.p2b.degrees())
    degs_after = np.asarray(pruned.p2b.degrees())
    # per-pin: ceil(d^delta) edges kept (within min_keep floor)
    target = np.maximum(
        np.ceil(degs_before.astype(np.float64) ** delta),
        np.minimum(degs_before, cfg.min_keep),
    )
    assert (degs_after <= target + 1e-9).all()
    if delta == 1.0:
        assert stats["edges_after"] == stats["edges_after_entropy"]


def test_pruning_monotone_in_delta(sg):
    edges = []
    for delta in (1.0, 0.9, 0.8, 0.6):
        cfg = pruning.PruneConfig(entropy_board_frac=0.1, delta=delta)
        _, stats = pruning.prune_graph(sg.graph, sg.pin_topics, None, cfg)
        edges.append(stats["edges_after"])
    assert edges == sorted(edges, reverse=True)


def test_pruning_keeps_topical_edges(sg):
    """The edges kept must have higher pin-board cosine sim than dropped."""
    from repro.core.graph import edge_list

    cfg = pruning.PruneConfig(entropy_board_frac=0.0, delta=0.7)
    pruned, _ = pruning.prune_graph(sg.graph, sg.pin_topics, None, cfg)
    # board topic dists from the original graph
    pins_b, boards_b = edge_list(sg.graph)
    nt = sg.pin_topics.shape[1]
    sums = np.zeros((sg.graph.n_boards, nt))
    np.add.at(sums, boards_b, sg.pin_topics[pins_b])
    cnt = np.maximum(np.bincount(boards_b, minlength=sg.graph.n_boards), 1)
    bt = sums / cnt[:, None]

    def mean_sim(graph):
        p, b = edge_list(graph)
        return pruning.cosine_sim(sg.pin_topics[p], bt[b]).mean()

    assert mean_sim(pruned) > mean_sim(sg.graph)
