"""Training substrate tests: optimizer, microbatching, checkpointing,
resilience (failure injection, bit-exact replay), compression."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import (
    checkpoint,
    compression,
    microbatch,
    optim,
    resilience,
)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = optim.init(params)
    cfg = optim.AdamWConfig(
        lr=0.3, weight_decay=0.0, warmup_steps=1, total_steps=200,
        schedule="constant",
    )
    for _ in range(150):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = optim.apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip_limits_norm():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    assert float(optim.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    assert float(norm) == pytest.approx(np.sqrt(10 * 100.0 ** 2), rel=1e-5)


def test_schedule_warmup_and_decay():
    cfg = optim.AdamWConfig(
        lr=1.0, warmup_steps=10, total_steps=100, schedule="cosine",
        min_lr_frac=0.1,
    )
    lr5 = float(optim.schedule_lr(cfg, jnp.asarray(5)))
    lr10 = float(optim.schedule_lr(cfg, jnp.asarray(10)))
    lr100 = float(optim.schedule_lr(cfg, jnp.asarray(100)))
    assert lr5 == pytest.approx(0.5, rel=1e-3)
    assert lr10 == pytest.approx(1.0, rel=1e-3)
    assert lr100 == pytest.approx(0.1, rel=1e-2)


def test_microbatch_grads_match_full_batch():
    params = {"w": jnp.arange(4.0)}
    batch = {"x": jnp.arange(8.0).reshape(8, 1)}

    def loss_fn(p, b):
        return jnp.mean((b["x"][:, 0] - jnp.sum(p["w"])) ** 2)

    l1, g1 = microbatch.accumulated_grads(loss_fn, params, batch, 1)
    l4, g4 = microbatch.accumulated_grads(loss_fn, params, batch, 4)
    assert float(l1) == pytest.approx(float(l4), rel=1e-6)
    np.testing.assert_allclose(
        np.asarray(g1["w"]), np.asarray(g4["w"]), rtol=1e-6
    )


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "a": jax.random.normal(k, (4, 3)),
        "nested": {"b": jnp.arange(5), "c": jnp.asarray(2.5)},
    }


def test_checkpoint_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        tree = _tree()
        checkpoint.save(d, 7, tree)
        restored, step = checkpoint.restore(d, tree)
        assert step == 7
        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b)
            ),
            tree, restored,
        )


def test_checkpoint_keep_last_and_latest_pointer():
    with tempfile.TemporaryDirectory() as d:
        for s in range(6):
            checkpoint.save(d, s, _tree(s), keep_last=2)
        steps = sorted(
            x for x in os.listdir(d) if x.startswith("step_")
        )
        assert len(steps) == 2
        assert checkpoint.latest_step(d) == 5


def test_checkpoint_structure_mismatch_rejected():
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, 0, _tree())
        bad = {"a": jnp.zeros((4, 3)), "nested": {"b": jnp.arange(5)}}
        with pytest.raises(ValueError):
            checkpoint.restore(d, bad)


# ---------------------------------------------------------------------------
# resilience
# ---------------------------------------------------------------------------


def test_resilient_run_replays_bit_exact():
    """After an injected failure, the replayed trajectory must land on the
    same final state as an uninterrupted run (stateless step-indexed data +
    checkpoint restore)."""
    params = {"w": jnp.zeros((3,))}
    cfg = optim.AdamWConfig(lr=0.1, warmup_steps=1, total_steps=50,
                            schedule="constant")

    def step(state, batch):
        p, o = state
        grads = jax.grad(
            lambda q: jnp.mean((batch - jnp.sum(q["w"])) ** 2)
        )(p)
        p, o, m = optim.apply_updates(p, grads, o, cfg)
        return (p, o), m

    def batch_fn(s):
        return jnp.asarray(float(s % 5))

    def run(failures):
        with tempfile.TemporaryDirectory() as d:
            rc = resilience.ResilienceConfig(ckpt_dir=d, ckpt_every=4)
            state = ({"w": jnp.zeros((3,))}, optim.init(params))
            hook = resilience.make_scheduled_failures(failures)
            final, report = resilience.run_resilient(
                step, batch_fn, state, 20, rc, failure_hook=hook
            )
            return final, report

    clean, _ = run({})
    faulty, report = run({6: 1, 13: 2})
    assert report.restores == 3
    np.testing.assert_allclose(
        np.asarray(clean[0]["w"]), np.asarray(faulty[0]["w"]), rtol=1e-6
    )


def test_straggler_hook_fires():
    import time

    calls = []

    def step(state, batch):
        if batch == 15:
            time.sleep(0.25)
        else:
            time.sleep(0.01)
        return state, {"loss": jnp.asarray(0.0)}

    with tempfile.TemporaryDirectory() as d:
        rc = resilience.ResilienceConfig(
            ckpt_dir=d, ckpt_every=100, straggler_factor=5.0
        )
        _, report = resilience.run_resilient(
            step, lambda s: s, {"x": jnp.zeros(())}, 20, rc,
            straggler_hook=lambda s, r: calls.append((s, r)),
        )
    assert report.stragglers, "slow step not flagged"
    assert calls


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_quantize_dequantize_bounded_error():
    g = jax.random.normal(jax.random.key(0), (1000,))
    q, scale = compression.quantize(g)
    err = np.abs(np.asarray(compression.dequantize(q, scale) - g))
    assert err.max() <= float(scale) / 2 + 1e-7


def test_compressed_psum_error_feedback():
    """Mean over the axis is preserved to within int8 quantization noise,
    and the residual carries the quantization error."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.launch.mesh import make_mesh_compat, set_mesh_compat

    # the pinned JAX has neither jax.sharding.AxisType nor jax.set_mesh;
    # shard_map receives the mesh explicitly so the ambient mesh is optional
    mesh = make_mesh_compat((1,), ("data",))
    g = {"w": jax.random.normal(jax.random.key(1), (64,))}
    r = compression.init_residual(g)

    def f(gg, rr):
        return compression.compressed_psum(gg, rr, "data")

    with set_mesh_compat(mesh):
        out, new_r = shard_map(
            f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
            check_rep=False,
        )(g, r)
    # single-device psum: reduced == dequant(quant(g)); residual = g - that
    np.testing.assert_allclose(
        np.asarray(out["w"] + new_r["w"]), np.asarray(g["w"]),
        rtol=1e-5, atol=1e-6,
    )
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.abs(new_r["w"]).max()) <= scale
