"""Multi-interest users as a first-class query layer.

The contract under test (service.UserQuery -> walk budget plumbing ->
recommend.recommend_multi_interest -> PixieServer.submit_user):

  * **Clustering is a pure function of the action multiset**: the same
    actions in any order build the SAME ``UserQuery`` (pins, weights,
    importance, lane order) — agglomeration is seeded-free determinstic
    numpy with canonical tie-breaks, never RNG.
  * **Lanes, not launches**: all of a batch's cluster lanes ride the PR 5
    query axis of ONE batched walk — the ``pallas_call`` count of a
    multi-interest serve step is CONSTANT as k grows (jaxpr-pinned).
  * **Verdict-16 parity** (``multi_interest_agrees``): the fused path —
    per-lane Eq. 2 budgets as traced data + ``merge_interest_topk`` —
    is BIT-identical to the per-cluster oracle (independent single-query
    walks, each with its cluster's budget, merged host-side by the same
    jitted merge at the live-k shape), across backend x gather x k.
  * **k=1 collapses exactly**: a single-cluster user's merged result is
    its lane VERBATIM — the flat §5.1 homefeed path, bit for bit.
  * **Budgets are data, not shape**: ``step_budgets`` rides the batch as
    an int32 array; ``None`` vs the full-budget array is bit-identical,
    so ragged users share compiled programs.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import service, walk as walk_lib
from repro.core.service import UserAction
from repro.graphs import synthetic
from repro.kernels.introspect import pallas_grids
from repro.serving.recommend import recommend_multi_interest


@pytest.fixture(scope="module")
def sg():
    return synthetic.small_test_graph()


@pytest.fixture(scope="module")
def histories(sg):
    cfg = synthetic.UserHistoryConfig(
        n_users=16, n_interests=3, mean_actions=14, seed=5
    )
    return synthetic.sample_user_histories(sg, cfg)


def _cfg(**kw):
    kw = {
        "n_steps": 768, "n_walkers": 32, "chunk_steps": 4, "top_k": 16,
        "n_p": 40, "n_v": 3, "backend": "pallas", **kw,
    }
    return walk_lib.WalkConfig(**kw)


def _user_batch(sg, histories, n_users, n_clusters, n_steps, n_slots=8):
    uqs = [
        service.build_user_query(
            h.actions, sg.pin_topics, n_slots=n_slots, n_clusters=n_clusters
        )
        for h in histories[:n_users]
    ]
    return service.batch_user_queries(uqs, n_steps=n_steps), uqs


# ---------------------------------------------------------------------------
# UserQuery construction: determinism + clustering invariants
# ---------------------------------------------------------------------------


def test_user_query_order_independent(sg, histories):
    """Shuffled action order -> bit-identical UserQuery."""
    actions = list(histories[0].actions)
    uq = service.build_user_query(actions, sg.pin_topics, n_slots=8)
    rng = np.random.default_rng(3)
    for _ in range(4):
        perm = [actions[i] for i in rng.permutation(len(actions))]
        uq2 = service.build_user_query(perm, sg.pin_topics, n_slots=8)
        np.testing.assert_array_equal(uq.cluster_pins, uq2.cluster_pins)
        np.testing.assert_array_equal(
            np.asarray(uq.cluster_weights).view(np.uint32),
            np.asarray(uq2.cluster_weights).view(np.uint32),
        )
        np.testing.assert_array_equal(
            np.asarray(uq.importance).view(np.uint32),
            np.asarray(uq2.importance).view(np.uint32),
        )


def test_user_query_clustering_invariants(sg, histories):
    """Clusters partition the acted pins; importance sums to 1, sorted
    descending; every lane's slots are the cluster's heaviest pins."""
    for h in histories[:6]:
        uq = service.build_user_query(
            h.actions, sg.pin_topics, n_slots=8, n_clusters=3
        )
        pins = np.asarray(uq.cluster_pins)
        acted = sorted({a.pin for a in h.actions})
        placed = sorted(int(p) for p in pins[pins >= 0])
        # every placed pin acted, no pin in two clusters (slots may
        # truncate a big cluster, so placed is a SUBSET of acted)
        assert len(placed) == len(set(placed))
        assert set(placed) <= set(acted)
        imp = np.asarray(uq.importance)
        assert imp.shape == (uq.n_clusters,)
        np.testing.assert_allclose(imp.sum(), 1.0, rtol=1e-6)
        assert (np.diff(imp) <= 0).all()  # lanes ordered by importance
        assert (imp > 0).all()
        # padding slots carry zero weight, live slots positive
        w = np.asarray(uq.cluster_weights)
        assert (w[pins < 0] == 0).all()
        assert (w[pins >= 0] > 0).all()


def test_user_query_k_caps_at_distinct_pins(sg):
    """A user with fewer distinct pins than n_clusters gets one cluster
    per pin, never an empty lane."""
    acts = [UserAction(pin=3, action="save", age_hours=0.0),
            UserAction(pin=3, action="click", age_hours=1.0)]
    uq = service.build_user_query(acts, sg.pin_topics, n_slots=4,
                                  n_clusters=3)
    assert uq.n_clusters == 1
    assert int(uq.cluster_pins[0, 0]) == 3
    np.testing.assert_allclose(np.asarray(uq.importance), [1.0])


def test_cluster_step_budgets():
    imp = np.asarray([0.6, 0.3, 0.1], np.float32)
    b = service.cluster_step_budgets(imp, 1000)
    assert b.dtype == np.int32
    np.testing.assert_array_equal(b, [600, 300, 100])
    # a live lane never rounds to zero steps; a dead lane stays zero
    tiny = np.asarray([0.9995, 0.0005, 0.0], np.float32)
    np.testing.assert_array_equal(
        service.cluster_step_budgets(tiny, 100), [99, 1, 0]
    )


def test_batch_user_queries_lane_maps(sg, histories):
    batch, uqs = _user_batch(sg, histories, 4, 3, n_steps=1536)
    lane_user = np.asarray(batch.lane_user)
    lane_of_user = np.asarray(batch.lane_of_user)
    n_lanes = batch.pins.shape[0]
    assert n_lanes == sum(u.n_clusters for u in uqs)
    # lane_of_user is the exact inverse of lane_user
    for u in range(batch.n_users):
        row = lane_of_user[u]
        live = row[row >= 0]
        assert (lane_user[live] == u).all()
        assert len(live) == uqs[u].n_clusters
    # budgets recompute per user from importance
    for u in range(batch.n_users):
        row = lane_of_user[u]
        live = row[row >= 0]
        np.testing.assert_array_equal(
            np.asarray(batch.step_budgets)[live],
            service.cluster_step_budgets(uqs[u].importance, 1536),
        )


def test_batch_user_queries_slot_mismatch_message(sg, histories):
    """The error names the integer slot counts, not a shape tuple."""
    a = service.build_user_query(histories[0].actions, sg.pin_topics,
                                 n_slots=8)
    b = service.build_user_query(histories[1].actions, sg.pin_topics,
                                 n_slots=4)
    with pytest.raises(ValueError, match=r"4 slots but the batch has 8"):
        service.batch_user_queries([a, b], n_steps=100)


# ---------------------------------------------------------------------------
# merge_interest_topk: the bit-reproducible Eq. 3 cross-cluster merge
# ---------------------------------------------------------------------------


def test_merge_single_lane_verbatim():
    """k=1 (and k>1 with one live lane) passes the lane through VERBATIM —
    no sqrt/square round trip, so the flat path collapse is exact."""
    s = jnp.asarray([[2.0, 1.5, 0.0]])
    i = jnp.asarray([[7, 3, -1]], jnp.int32)
    ms, mi = walk_lib.merge_interest_topk(s, i, jnp.asarray([1.0]))
    np.testing.assert_array_equal(np.asarray(ms), np.asarray(s[0]))
    np.testing.assert_array_equal(np.asarray(mi), np.asarray(i[0]))
    # one live + one padding lane: still verbatim
    s2 = jnp.concatenate([s, jnp.zeros_like(s)])
    i2 = jnp.concatenate([i, jnp.full_like(i, -1)])
    ms2, mi2 = walk_lib.merge_interest_topk(
        s2, i2, jnp.asarray([1.0, 0.0])
    )
    np.testing.assert_array_equal(
        np.asarray(ms2).view(np.uint32), np.asarray(ms).view(np.uint32)
    )
    np.testing.assert_array_equal(np.asarray(mi2), np.asarray(mi))


def test_merge_eq3_values_and_tiebreak():
    """Eq. 3 across clusters: V[p] = (sum_c imp_c * sqrt(V_c[p]))^2,
    multi-cluster hits boosted, score ties broken by ascending pin id."""
    scores = jnp.asarray([[4.0, 1.0, 0.0], [4.0, 1.0, 0.0]])
    ids = jnp.asarray([[2, 5, -1], [7, 2, -1]], jnp.int32)
    imp = jnp.asarray([0.5, 0.5])
    ms, mi = walk_lib.merge_interest_topk(scores, ids, imp)
    # pin 2: (.5*sqrt(4) + .5*sqrt(1))^2 = 2.25; pin 7: (.5*2)^2 = 1;
    # pin 5: (.5*1)^2 = .25
    np.testing.assert_allclose(np.asarray(ms), [2.25, 1.0, 0.25])
    np.testing.assert_array_equal(np.asarray(mi), [2, 7, 5])


def test_merge_lane_order_invariant():
    scores = jnp.asarray([[4.0, 1.0], [9.0, 4.0], [1.0, 0.0]])
    ids = jnp.asarray([[2, 5], [7, 2], [5, -1]], jnp.int32)
    imp = jnp.asarray([0.5, 0.3, 0.2])
    a = walk_lib.merge_interest_topk(scores, ids, imp)
    perm = jnp.asarray([2, 0, 1])
    b = walk_lib.merge_interest_topk(scores[perm], ids[perm], imp[perm])
    np.testing.assert_array_equal(
        np.asarray(a[0]).view(np.uint32), np.asarray(b[0]).view(np.uint32)
    )
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_merge_padding_lanes_are_noops():
    """Zero-importance lanes change nothing bitwise — the fused path's
    k_max padding is invisible to the merge."""
    scores = jnp.asarray([[4.0, 1.0], [9.0, 4.0]])
    ids = jnp.asarray([[2, 5], [7, 2]], jnp.int32)
    imp = jnp.asarray([0.6, 0.4])
    a = walk_lib.merge_interest_topk(scores, ids, imp)
    pad_s = jnp.concatenate([scores, jnp.asarray([[123.0, 5.0]])])
    pad_i = jnp.concatenate([ids, jnp.asarray([[1, 4]], jnp.int32)])
    pad_imp = jnp.concatenate([imp, jnp.asarray([0.0])])
    b = walk_lib.merge_interest_topk(pad_s, pad_i, pad_imp)
    np.testing.assert_array_equal(
        np.asarray(a[0]).view(np.uint32), np.asarray(b[0]).view(np.uint32)
    )
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


# ---------------------------------------------------------------------------
# Budgets are data: traced step budgets == static cfg.n_steps programs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_step_budgets_none_equals_full_array(sg, backend):
    """step_budgets=None (every legacy caller) is bit-identical to an
    explicit full-budget array: the traced Eq. 2 allocation reproduces
    the static one exactly for budgets < 2^24."""
    g = sg.graph
    cfg = _cfg(backend=backend)
    qs = synthetic.top_degree_pins(sg, 8)
    pins = jnp.asarray(np.asarray(qs[:8]).reshape(4, 2), jnp.int32)
    weights = jnp.full((4, 2), 1.0, jnp.float32)
    feats = jnp.zeros((4,), jnp.int32)
    key = jax.random.key(2)
    a = service.serve_batch(g, pins, weights, feats, key, cfg,
                            with_stats=True)
    b = service.serve_batch(
        g, pins, weights, feats, key, cfg, with_stats=True,
        step_budgets=jnp.full((4,), cfg.n_steps, jnp.int32),
    )
    for x, y, name in zip(a, b, ("scores", "ids", "steps", "n_high")):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y), err_msg=name
        )


# ---------------------------------------------------------------------------
# Verdict 16: fused multi-interest vs the per-cluster oracle
# ---------------------------------------------------------------------------


def _oracle_users(g, batch, uqs, lane_keys, cfg):
    """Per-cluster single-query walks + the same jitted merge at each
    user's LIVE-k shape (the fused path pads to k_max; padding lanes are
    proven bitwise-invisible above)."""
    single = jax.jit(
        lambda qp, qw, uf, k, sb: walk_lib.recommend_with_stats(
            g, qp, qw, uf, k, cfg, step_budget=sb
        )
    )
    merge = jax.jit(walk_lib.merge_interest_topk, static_argnames=())
    out_s, out_i = [], []
    lane_of_user = np.asarray(batch.lane_of_user)
    for u, uq in enumerate(uqs):
        lanes = lane_of_user[u]
        lanes = lanes[lanes >= 0]
        ss, ii = [], []
        for li in lanes:
            s, i, _, _ = single(
                batch.pins[li], batch.weights[li], batch.feats[li],
                lane_keys[li], batch.step_budgets[li],
            )
            ss.append(s)
            ii.append(i)
        ms, mi = merge(
            jnp.stack(ss), jnp.stack(ii), jnp.asarray(uq.importance)
        )
        out_s.append(np.asarray(ms))
        out_i.append(np.asarray(mi))
    return np.stack(out_s), np.stack(out_i)


@pytest.mark.parametrize("gather_mode", ["scalar", "dma"])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_multi_interest_agrees_with_oracle(sg, histories, backend,
                                           gather_mode):
    """The acceptance matrix heart: fused multi-interest serving (all
    lanes in ONE batched walk, budgets as data, jitted merge) bit-equals
    per-cluster independent walks merged host-side."""
    if backend == "xla" and gather_mode == "dma":
        pytest.skip("gather_mode is a pallas-kernel axis")
    g = sg.graph
    cfg = _cfg(backend=backend, gather_mode=gather_mode)
    batch, uqs = _user_batch(sg, histories, 4, 3, n_steps=cfg.n_steps)
    key = jax.random.key(17)
    lane_keys = jax.random.split(key, batch.pins.shape[0])
    ms, mi = recommend_multi_interest(g, batch, lane_keys, cfg)
    os_, oi = _oracle_users(g, batch, uqs, lane_keys, cfg)
    np.testing.assert_array_equal(
        np.asarray(ms).view(np.uint32), os_.view(np.uint32)
    )
    np.testing.assert_array_equal(np.asarray(mi), oi)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_k1_collapses_to_flat_serve(sg, histories, backend):
    """n_clusters=1 users through the multi-interest path == the flat
    homefeed serve_batch on the same single-cluster queries, bit for
    bit (the verbatim lane passthrough, end to end)."""
    g = sg.graph
    cfg = _cfg(backend=backend)
    batch, uqs = _user_batch(sg, histories, 3, 1, n_steps=cfg.n_steps)
    key = jax.random.key(23)
    lane_keys = jax.random.split(key, batch.pins.shape[0])
    ms, mi = recommend_multi_interest(g, batch, lane_keys, cfg)
    fs, fi = service.serve_batch(
        g, batch.pins, batch.weights, batch.feats, lane_keys, cfg
    )
    np.testing.assert_array_equal(
        np.asarray(ms).view(np.uint32), np.asarray(fs).view(np.uint32)
    )
    np.testing.assert_array_equal(np.asarray(mi), np.asarray(fi))


# ---------------------------------------------------------------------------
# Lowering pin: clusters add lanes, never pallas_calls
# ---------------------------------------------------------------------------


def test_multi_interest_lowers_to_constant_calls(sg, histories):
    """The pallas_call count of a multi-interest serve step is constant
    as k grows from 1 to 4: cluster lanes ride the batch (query) axis of
    the SAME 2-call chunk program — lanes scale rows, not launches."""
    g = sg.graph
    cfg = _cfg()
    structures = {}
    for k in (1, 2, 4):
        batch, _ = _user_batch(sg, histories, 4, k, n_steps=cfg.n_steps)
        n_lanes = batch.pins.shape[0]

        def step(key, batch=batch, n_lanes=n_lanes):
            return recommend_multi_interest(
                g, batch, jax.random.split(key, n_lanes), cfg
            )

        grids = pallas_grids(jax.make_jaxpr(step)(jax.random.key(0)))
        structures[k] = (len(grids), sorted(len(g_) for g_ in grids))
    assert structures[1] == structures[2] == structures[4], structures
    assert structures[1][0] == 2  # the 2 walk-engine calls per chunk


# ---------------------------------------------------------------------------
# Sampler-driven end to end (the workload generator feeding the server)
# ---------------------------------------------------------------------------


def test_sampled_histories_build_valid_batches(sg, histories):
    batch, uqs = _user_batch(sg, histories, len(histories), 3,
                             n_steps=1024)
    pins = np.asarray(batch.pins)
    assert ((pins >= -1) & (pins < sg.graph.n_pins)).all()
    assert (np.asarray(batch.step_budgets) >= 0).all()
    # every user's budgets sum to <= n_steps (Eq. 2 floor rounding)
    lane_of_user = np.asarray(batch.lane_of_user)
    for u in range(batch.n_users):
        live = lane_of_user[u][lane_of_user[u] >= 0]
        assert np.asarray(batch.step_budgets)[live].sum() <= 1024 + len(live)


# ---------------------------------------------------------------------------
# Server intake: submit_user -> bucketed dispatch -> harvest reassembly
# ---------------------------------------------------------------------------


def _drain(srv):
    out = []
    while srv.pending():
        srv.pump(now=srv.next_deadline())
    out.extend(srv.harvest())
    return {r.req_id: r for r in out}


def test_server_submit_user_matches_fused_path(sg, histories):
    """The bucketed server's per-user merged results are bit-identical to
    recommend_multi_interest on the same lanes with the same
    fold_in(fold_in(server_key, req_id), cluster_idx) streams."""
    from repro.serving.server import PixieServer

    g = sg.graph
    cfg = _cfg(backend="xla", n_steps=256)
    users = histories[:4]
    srv = PixieServer(
        g, cfg, batch_size=8, n_slots=8, seed=42,
        pin_topics=sg.pin_topics, n_clusters=3,
    )
    rids = [
        srv.submit_user(u.actions, user_feat=i % 4, now=0.001 * i,
                        req_id=100 + i)
        for i, u in enumerate(users)
    ]
    res = _drain(srv)
    assert sorted(res) == sorted(rids)

    uqs = [
        service.build_user_query(u.actions, sg.pin_topics, n_slots=8,
                                 n_clusters=3)
        for u in users
    ]
    batch = service.batch_user_queries(uqs, n_steps=cfg.n_steps)
    skey = jax.random.key(42)
    lane_keys = []
    lane_of_user = np.asarray(batch.lane_of_user)
    for li in range(batch.pins.shape[0]):
        u = int(batch.lane_user[li])
        ci = int(np.where(lane_of_user[u] == li)[0][0])
        lane_keys.append(
            jax.random.fold_in(jax.random.fold_in(skey, rids[u]), ci)
        )
    feats = np.asarray(batch.lane_user) % 4
    batch = batch._replace(feats=jnp.asarray(feats, jnp.int32))
    ms, mi = recommend_multi_interest(g, batch, jnp.stack(lane_keys), cfg)
    for u, rid in enumerate(rids):
        np.testing.assert_array_equal(
            res[rid].scores.view(np.uint32),
            np.asarray(ms[u]).view(np.uint32), err_msg=f"user {u} scores",
        )
        np.testing.assert_array_equal(
            res[rid].ids, np.asarray(mi[u]), err_msg=f"user {u} ids"
        )


def test_server_user_results_batch_composition_independent(sg, histories):
    """Submission order, batch size, and interleaved flushes never change
    a user's merged recommendations — per-(user, cluster) streams, not
    batch position, seed the walks."""
    from repro.serving.server import PixieServer

    g = sg.graph
    cfg = _cfg(backend="xla", n_steps=256)
    users = histories[:5]

    def run(order, batch_size, interleave):
        srv = PixieServer(
            g, cfg, batch_size=batch_size, n_slots=8, seed=42,
            pin_topics=sg.pin_topics, n_clusters=3,
        )
        out = []
        for j, i in enumerate(order):
            srv.submit_user(users[i].actions, user_feat=i % 4,
                            now=0.01 * j, req_id=100 + i)
            if interleave:
                out.extend(srv.flush())
        d = _drain(srv)
        d.update({r.req_id: r for r in out})
        return d

    a = run(range(5), 8, False)
    b = run(list(reversed(range(5))), 4, True)
    assert sorted(a) == sorted(b)
    for rid in a:
        np.testing.assert_array_equal(
            a[rid].scores.view(np.uint32), b[rid].scores.view(np.uint32)
        )
        np.testing.assert_array_equal(a[rid].ids, b[rid].ids)


def test_server_submit_user_requires_pin_topics(sg, histories):
    from repro.serving.server import PixieServer

    srv = PixieServer(sg.graph, _cfg(backend="xla", n_steps=256),
                      batch_size=4, n_slots=8)
    with pytest.raises(ValueError, match="pin_topics"):
        srv.submit_user(histories[0].actions)


def test_open_loop_user_traffic_replays_bitwise(sg, histories):
    """The open-loop harness drives submit_user end to end; the same
    seeded schedule replayed against a server with a DIFFERENT batch
    size serves every user bit-identically."""
    from repro.serving import traffic
    from repro.serving.server import PixieServer

    cfg = _cfg(backend="xla", n_steps=256)
    ol = traffic.OpenLoopConfig(offered_qps=500.0, n_requests=10, seed=5)
    reqs = traffic.poisson_user_requests(histories[:4], ol)
    assert all(r.actions is not None for r in reqs)

    def run(batch_size):
        srv = PixieServer(
            sg.graph, cfg, batch_size=batch_size, n_slots=8, seed=9,
            pin_topics=sg.pin_topics, n_clusters=2,
        )
        return traffic.run_open_loop(srv, reqs)

    a, b = run(4), (run(7))
    assert a.n_served == b.n_served == 10
    for rid in a.results:
        np.testing.assert_array_equal(
            a.results[rid].scores.view(np.uint32),
            b.results[rid].scores.view(np.uint32),
        )
        np.testing.assert_array_equal(a.results[rid].ids, b.results[rid].ids)


def test_multi_interest_then_rank(sg, histories):
    """rank= chains the stage-2 scenario head onto the MERGED per-user
    candidate bag: walk top_k widens to n_candidates, scenario indexes
    per USER, and the ranked output keeps the two-stage contracts."""
    from repro.serving import ranker as ranker_lib

    g = sg.graph
    rcfg = ranker_lib.RankerConfig(
        n_items=g.n_pins, d_model=16, n_neighbors=4,
        n_candidates=16, final_k=6,
    )
    rank = ranker_lib.RankRequest(
        ranker_lib.init_ranker_params(jax.random.key(7), rcfg), rcfg
    )
    cfg = _cfg(backend="xla", n_steps=256, top_k=4)  # top_k overridden
    batch, _ = _user_batch(sg, histories, 3, 2, n_steps=cfg.n_steps)
    lane_keys = jax.random.split(jax.random.key(29), batch.pins.shape[0])
    scen = jnp.asarray([0, 1, 0], jnp.int32)
    scores, ids = recommend_multi_interest(
        g, batch, lane_keys, cfg, rank=rank, scenario=scen
    )
    scores, ids = np.asarray(scores), np.asarray(ids)
    assert scores.shape == ids.shape == (3, rcfg.final_k)
    finite = np.isfinite(scores)
    assert finite.any(axis=1).all()
    assert ((ids[finite] >= 0) & (ids[finite] < g.n_pins)).all()
    assert (ids[~finite] == -1).all()
    assert (np.diff(scores, axis=1) <= 0).all()
    # scenario without rank raises
    with pytest.raises(ValueError, match="scenario"):
        recommend_multi_interest(g, batch, lane_keys, cfg, scenario=scen)
