"""Coverage for the remaining paper features: board recommendations (§5.3),
per-surface walk configs (§5.1/5.2), the kernels/ops dispatcher, and a
multi-step chain through the Pallas walk_step kernel."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import service, walk as walk_lib
from repro.graphs.synthetic import small_test_graph, top_degree_pins
from repro.kernels import ops, ref


def test_board_recommendation_counts(sg=None):
    """§5.3: with count_boards=True the walk also scores boards; the top
    boards must include boards adjacent to the query pin."""
    sg = sg or small_test_graph()
    g = sg.graph
    q = int(top_degree_pins(sg, 1)[0])
    cfg = service.board_rec_config(
        walk_lib.WalkConfig(n_steps=10_000, n_walkers=128, n_p=10**9,
                            n_v=10**9)
    )
    assert cfg.count_boards
    res = walk_lib.pixie_random_walk(
        g, jnp.asarray([q], jnp.int32), jnp.ones((1,), jnp.float32),
        jnp.asarray(0, jnp.int32), jax.random.key(0), cfg,
    )
    assert res.board_counts is not None
    bc = np.asarray(res.board_counts[0])
    assert bc.sum() > 0
    # the query pin's own boards should rank among the most-visited
    off = np.asarray(g.p2b.offsets)
    tgt = np.asarray(g.p2b.targets)
    own = set((tgt[off[q]:off[q + 1]] - g.n_pins).tolist())
    top20 = set(np.argsort(-bc)[:20].tolist())
    assert own & top20, "no query-adjacent board in the top-20"


def test_surface_configs_change_walk_breadth():
    """§5.1/§5.2: Related Pins uses shorter walks (higher alpha) than
    Homefeed; shorter walks concentrate visits nearer the query."""
    base = walk_lib.WalkConfig(n_steps=10_000, n_walkers=128)
    home = service.homefeed_config(base)
    related = service.related_pins_config(base)
    assert related.alpha > home.alpha
    sg = small_test_graph()
    q = int(top_degree_pins(sg, 1)[0])

    def n_distinct(cfg):
        res = walk_lib.pixie_random_walk(
            sg.graph, jnp.asarray([q], jnp.int32),
            jnp.ones((1,), jnp.float32), jnp.asarray(0, jnp.int32),
            jax.random.key(0),
            dataclasses.replace(cfg, n_p=10**9, n_v=10**9),
        )
        return int((np.asarray(res.counts[0]) > 0).sum())

    # broader walk reaches at least as many distinct pins
    assert n_distinct(home) >= n_distinct(related) * 0.8


def test_ops_dispatcher_kernel_vs_oracle_parity():
    """kernels/ops.py: both dispatch paths agree for every op."""
    key = jax.random.key(0)
    ev = jax.random.randint(key, (512,), -2, 100, dtype=jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(ops.visit_counts(ev, 100, use_kernel=False)),
        np.asarray(ops.visit_counts(ev, 100, use_kernel=True)),
    )
    table = jax.random.normal(key, (50, 32))
    ids = jax.random.randint(key, (16, 4), -1, 50)
    np.testing.assert_allclose(
        np.asarray(ops.embedding_bag(table, ids, use_kernel=False)),
        np.asarray(ops.embedding_bag(table, ids, use_kernel=True)),
        rtol=1e-5, atol=1e-6,
    )
    q = jax.random.normal(key, (2, 4, 64))
    k = jax.random.normal(jax.random.key(1), (2, 256, 2, 64))
    v = jax.random.normal(jax.random.key(2), (2, 256, 2, 64))
    lengths = jnp.asarray([100, 256], jnp.int32)
    np.testing.assert_allclose(
        np.asarray(ops.decode_attention(q, k, v, lengths, use_kernel=False)),
        np.asarray(ops.decode_attention(q, k, v, lengths, use_kernel=True)),
        rtol=1e-4, atol=1e-4,
    )


def test_walk_step_kernel_multi_step_chain():
    """Chaining the Pallas walk_step kernel for several supersteps stays in
    lockstep with the jnp oracle (positions identical under the same rng)."""
    sg = small_test_graph()
    g = sg.graph
    p2b_off = g.p2b.offsets.astype(jnp.int32)
    p2b_tgt = g.p2b.targets.astype(jnp.int32)
    b2p_off = g.b2p.offsets.astype(jnp.int32)
    b2p_tgt = g.b2p.targets.astype(jnp.int32)
    w = 256
    qs = top_degree_pins(sg, 4)
    query = jnp.asarray(np.resize(qs, w), jnp.int32)
    curr_k = curr_r = query
    for step in range(5):
        rbits = jax.random.bits(jax.random.key(step), (w, 3), dtype=jnp.uint32)
        out_k = ops.walk_step(
            curr_k, query, rbits, p2b_off, p2b_tgt, b2p_off, b2p_tgt,
            n_pins=g.n_pins, alpha_u32=2**31, use_kernel=True,
        )
        out_r = ref.walk_step_ref(
            curr_r, query, rbits, p2b_off, p2b_tgt, b2p_off, b2p_tgt,
            n_pins=g.n_pins, alpha_u32=2**31,
        )
        for a, b in zip(out_k, out_r):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        curr_k, curr_r = out_k[0], out_r[0]
