"""Continuous-traffic serving: bucketed deadline-aware batch formation,
the open-loop Poisson harness, the request-path bugfix regressions, and
the graph swap under in-flight traffic.

The four bugfix regression tests each pin behavior that FAILED on the old
request path: silent query truncation, unvalidated pins/weights length
mismatch, unbounded latency-list growth, and queue-wait time excluded
from reported latency."""

import jax
import numpy as np
import pytest

from repro.core import service, walk as walk_lib
from repro.graphs.synthetic import small_test_graph, top_degree_pins
from repro.serving.server import LatencyRing, PixieServer, ServerStats
from repro.serving.traffic import (
    OpenLoopConfig, poisson_requests, run_open_loop,
)


def _cfg(**kw):
    base = dict(n_steps=1_000, n_walkers=64, chunk_steps=8, top_k=20,
                n_p=60, n_v=3)
    base.update(kw)
    return walk_lib.WalkConfig(**base)


# -- bugfix regressions ------------------------------------------------------


def test_submit_rejects_oversized_query():
    """Old path: ``n = min(len(pins), n_slots)`` silently DROPPED pins past
    n_slots, skewing every Eq. 2 step budget downstream.  Now an
    oversized query must raise (single bucket) or route to a larger
    bucket (multi-bucket) — never truncate."""
    sg = small_test_graph()
    server = PixieServer(sg.graph, _cfg(), batch_size=2, n_slots=4)
    with pytest.raises(ValueError, match="6 pins.*4 slots"):
        server.submit(list(range(6)), [1.0] * 6)
    assert server.pending() == 0  # nothing partially enqueued

    # multi-bucket: the same query routes to a bucket that FITS it
    bucketed = PixieServer(
        sg.graph, _cfg(), buckets=[(2, 4), (2, 8)]
    )
    assert bucketed.submit(list(range(6)), [1.0] * 6) is not None
    assert len(bucketed._queues[8]) == 1  # landed in the 8-slot bucket
    with pytest.raises(ValueError, match="9 pins.*8 slots"):
        bucketed.submit(list(range(9)), [1.0] * 9)


def test_submit_rejects_mismatched_weights():
    """Old path: ``len(weights) != len(pins)`` either crashed with an
    opaque NumPy broadcast error (fewer weights) or silently misaligned
    truncated weights to the wrong pins (more weights)."""
    sg = small_test_graph()
    server = PixieServer(sg.graph, _cfg(), batch_size=2, n_slots=4)
    with pytest.raises(ValueError, match="2 pins but 1 weights"):
        server.submit([1, 2], [1.0])
    with pytest.raises(ValueError, match="2 pins but 3 weights"):
        server.submit([1, 2], [1.0, 0.5, 0.3])
    assert server.pending() == 0


def test_latency_ring_is_bounded_and_percentile_correct():
    """Old ``ServerStats.latencies_ms`` was an unbounded list — a
    long-lived replica leaked memory with every query.  The ring keeps
    only the newest ``capacity`` samples and percentiles stay exact over
    that window."""
    ring = LatencyRing(capacity=8)
    ring.extend(float(i) for i in range(100))
    assert len(ring) == 8
    np.testing.assert_array_equal(ring.values(),
                                  np.arange(92, 100, dtype=np.float64))
    stats = ServerStats(capacity=8)
    stats.latencies_ms.extend(float(i) for i in range(100))
    assert stats.percentile(50) == pytest.approx(
        np.percentile(np.arange(92, 100), 50)
    )
    # the server-level bound: heavy traffic never grows stats memory
    sg = small_test_graph()
    server = PixieServer(sg.graph, _cfg(n_steps=256, n_walkers=32),
                         batch_size=2, n_slots=2, stats_capacity=4)
    qs = top_degree_pins(sg, 2)
    for _ in range(6):
        server.submit([int(qs[0])], [1.0])
    server.flush()
    assert server.stats.queries == 6
    assert len(server.stats.latencies_ms) == 4
    assert len(server.stats.wait_ms) == 4
    with pytest.raises(ValueError, match="capacity"):
        LatencyRing(capacity=0)


def test_latency_includes_queue_wait():
    """Old ``flush()`` measured only the jitted call: a request that sat
    queued for 100 ms reported the same latency as one served instantly.
    Enqueue time is now stamped in ``submit`` and wait is reported
    separately from compute, with latency = wait + compute."""
    sg = small_test_graph()
    server = PixieServer(sg.graph, _cfg(n_steps=256, n_walkers=32),
                         batch_size=2, n_slots=2)
    qs = top_degree_pins(sg, 2)
    server.submit([int(qs[0])], [1.0], now=0.0)
    server.submit([int(qs[1])], [1.0], now=0.040)
    out = server.flush(now=0.100)  # both dispatch 100 ms after t=0
    assert len(out) == 2
    assert out[0].wait_ms == pytest.approx(100.0)
    assert out[1].wait_ms == pytest.approx(60.0)
    for r in out:
        assert r.compute_ms > 0.0
        assert r.latency_ms == pytest.approx(r.wait_ms + r.compute_ms)
    assert server.stats.percentile(50, which="wait") == pytest.approx(80.0)
    # the aggregate latency percentile includes the wait term
    assert server.stats.percentile(99) > server.stats.percentile(
        99, which="compute"
    )


# -- graph swap under in-flight traffic --------------------------------------


def test_swap_graph_under_inflight_traffic_generations_and_no_retrace():
    """Generation moves exactly once per swap; results whose batch
    dispatched BEFORE the swap carry the old generation even when
    harvested after it; and a same-shape plain-graph swap reuses the
    compiled serve program (no retrace)."""
    sg = small_test_graph()
    server = PixieServer(sg.graph, _cfg(n_steps=512, n_walkers=64),
                         batch_size=2, n_slots=2)
    qs = top_degree_pins(sg, 4)
    server.submit([int(qs[0])], [1.0], now=0.0)
    server.submit([int(qs[1])], [1.0], now=0.0)
    server.pump(now=0.0)              # full bucket: dispatched, in flight
    assert server.pending() == 0

    compiles_before = server._plain_serve._cache_size()
    server.swap_graph(sg.graph)       # same-shape daily swap, under load
    assert server.stats.graph_generation == 1

    # post-swap traffic dispatches under the NEW generation
    server.submit([int(qs[2])], [1.0], now=1.0)
    server.submit([int(qs[3])], [1.0], now=1.0)
    server.pump(now=1.0)
    results = server.harvest()
    assert len(results) == 4
    by_req = {r.req_id: r for r in results}
    assert by_req[0].generation == 0 and by_req[1].generation == 0
    assert by_req[2].generation == 1 and by_req[3].generation == 1
    # same shape, graph passed as a jit argument: NO recompilation
    assert server._plain_serve._cache_size() == compiles_before

    server.swap_graph(sg.graph)
    assert server.stats.graph_generation == 2  # exactly once per swap


# -- bucketed serving vs the flush oracle ------------------------------------


def test_bucketed_serving_matches_single_bucket_flush_oracle():
    """The tentpole contract (the ``traffic_buckets_agree`` verdict, in
    miniature): deadline-aware multi-bucket serving returns bit-identical
    scores AND ids to the single-bucket flush() oracle on the same
    requests — per-request fold_in RNG streams make the walk independent
    of batch composition and bucket shape."""
    sg = small_test_graph()
    cfg = _cfg(n_steps=512, n_walkers=64)
    candidates = top_degree_pins(sg, 12).astype(np.int32)
    workload = poisson_requests(candidates, OpenLoopConfig(
        offered_qps=300.0, n_requests=10, seed=3, max_pins=4,
    ))

    bucketed = PixieServer(
        sg.graph, cfg, seed=5, buckets=[(3, 2), (2, 4)], max_wait_ms=3.0,
    )
    report = run_open_loop(bucketed, workload)
    assert report.n_served == len(workload)
    assert bucketed.stats.batches >= 3  # really split across shapes

    oracle = PixieServer(sg.graph, cfg, batch_size=4, n_slots=4, seed=5)
    for req in workload:
        oracle.submit(list(req.pins), list(req.weights), req.user_feat,
                      req_id=req.req_id)
    oracle_out = {r.req_id: r for r in oracle.flush()}

    for req in workload:
        b, o = report.results[req.req_id], oracle_out[req.req_id]
        np.testing.assert_array_equal(b.scores, o.scores)
        np.testing.assert_array_equal(b.ids, o.ids)


def test_bucket_routing_and_deadline_dispatch():
    """Dispatch fires on max-wait OR full bucket, whichever first."""
    sg = small_test_graph()
    server = PixieServer(
        sg.graph, _cfg(n_steps=256, n_walkers=32),
        buckets=[(2, 2), (2, 4)], max_wait_ms=10.0,
    )
    qs = top_degree_pins(sg, 4)
    # one small query: not full, deadline not reached -> stays queued
    server.submit([int(qs[0])], [1.0], now=0.0)
    assert server.pump(now=0.005) == 0
    assert server.pending() == 1
    assert server.next_deadline() == pytest.approx(0.010)
    # deadline reached -> partial batch dispatches
    assert server.pump(now=server.next_deadline()) == 1
    assert server.pending() == 0
    assert len(server.harvest()) == 1
    # full bucket dispatches immediately, before any deadline
    server.submit([int(qs[0])], [1.0], now=1.0)
    server.submit([int(qs[1]), int(qs[2]), int(qs[3])], [1.0, 0.5, 0.2],
                  now=1.0)  # 3 pins -> the 4-slot bucket
    server.submit([int(qs[1])], [1.0], now=1.0)
    assert server.pump(now=1.0) == 1   # 2-slot bucket full; 4-slot waits
    assert server.pending() == 1
    results = server.harvest()
    assert len(results) == 2
    assert all(len(r.scores) == server.cfg.top_k for r in results)


def test_open_loop_drop_accounting_and_admission_bound():
    """Open-loop load shedding is counted, never silent: a backlogged
    executor drops arrivals (harness), and a bounded bucket queue sheds
    at submit (server)."""
    sg = small_test_graph()
    candidates = top_degree_pins(sg, 8).astype(np.int32)
    # absurd offered load + tiny backlog bound: drops must happen
    workload = poisson_requests(candidates, OpenLoopConfig(
        offered_qps=100_000.0, n_requests=12, seed=0, max_pins=2,
    ))
    server = PixieServer(sg.graph, _cfg(n_steps=256, n_walkers=32),
                         buckets=[(2, 2)], max_wait_ms=1.0)
    report = run_open_loop(server, workload, max_backlog_s=1e-5)
    assert report.n_dropped > 0
    assert report.n_served + report.n_dropped == report.n_offered
    assert report.drop_rate == pytest.approx(
        report.n_dropped / report.n_offered
    )
    assert server.stats.dropped == report.n_dropped

    # server-side admission bound
    bounded = PixieServer(sg.graph, _cfg(n_steps=256, n_walkers=32),
                          buckets=[(4, 2)], max_queue_per_bucket=2)
    ids = [bounded.submit([int(candidates[0])], [1.0]) for _ in range(4)]
    assert ids[:2] == [0, 1] and ids[2:] == [None, None]
    assert bounded.stats.dropped == 2


def test_poisson_workload_is_seeded_and_validates():
    candidates = np.arange(100, dtype=np.int32)
    cfg = OpenLoopConfig(offered_qps=50.0, n_requests=8, seed=11, max_pins=4)
    a = poisson_requests(candidates, cfg)
    b = poisson_requests(candidates, cfg)
    assert [r.t_arrival for r in a] == [r.t_arrival for r in b]
    assert [r.pins for r in a] == [r.pins for r in b]
    assert all(1 <= len(r.pins) <= 4 for r in a)
    assert all(len(r.weights) == len(r.pins) for r in a)
    with pytest.raises(ValueError, match="offered_qps"):
        poisson_requests(candidates, OpenLoopConfig(
            offered_qps=0.0, n_requests=1))
    with pytest.raises(ValueError, match="max_pins"):
        poisson_requests(np.arange(2, dtype=np.int32), OpenLoopConfig(
            offered_qps=1.0, n_requests=1, max_pins=4))


# -- serve_batch per-query key plumbing --------------------------------------


def test_serve_batch_per_query_keys_match_split_keys():
    """A (batch,) key array must reproduce exactly what a scalar key's
    ``jax.random.split`` streams produce — and a wrong-length key array
    must fail loudly."""
    import jax.numpy as jnp

    sg = small_test_graph()
    g = sg.graph
    qs = top_degree_pins(sg, 4)
    pins = jnp.asarray(np.asarray(qs).reshape(2, 2), jnp.int32)
    weights = jnp.full((2, 2), 0.8, jnp.float32)
    feats = jnp.zeros((2,), jnp.int32)
    cfg = _cfg(n_steps=512, n_walkers=64)
    key = jax.random.key(9)
    s_scalar, i_scalar = service.serve_batch(g, pins, weights, feats, key, cfg)
    s_keys, i_keys = service.serve_batch(
        g, pins, weights, feats, jax.random.split(key, 2), cfg
    )
    np.testing.assert_array_equal(np.asarray(s_scalar), np.asarray(s_keys))
    np.testing.assert_array_equal(np.asarray(i_scalar), np.asarray(i_keys))
    with pytest.raises(ValueError, match="3 keys for a batch of 2"):
        service.serve_batch(
            g, pins, weights, feats, jax.random.split(key, 3), cfg
        )


def test_query_walk_invariant_to_bucket_slot_padding():
    """The property bucket routing leans on: padding a query into a wider
    n_slots shape (zero-weight slots) never changes its walk."""
    import jax.numpy as jnp

    sg = small_test_graph()
    qs = top_degree_pins(sg, 2)
    cfg = _cfg(n_steps=512, n_walkers=64)
    key = jax.random.fold_in(jax.random.key(1), 42)

    outs = []
    for n_slots in (2, 8):
        qp = np.full(n_slots, -1, np.int32)
        qw = np.zeros(n_slots, np.float32)
        qp[:2] = [int(qs[0]), int(qs[1])]
        qw[:2] = [1.0, 0.6]
        s, i, _, _ = walk_lib.recommend_with_stats(
            sg.graph, jnp.asarray(qp), jnp.asarray(qw),
            jnp.asarray(0, jnp.int32), key, cfg,
        )
        outs.append((np.asarray(s), np.asarray(i)))
    np.testing.assert_array_equal(outs[0][0], outs[1][0])
    np.testing.assert_array_equal(outs[0][1], outs[1][1])
