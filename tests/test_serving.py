"""Serving-layer tests: LM generation, Pixie server batching/swap,
two-stage recommendation, query construction, and serve_batch
backend-override parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import service, walk as walk_lib
from repro.graphs.synthetic import small_test_graph, top_degree_pins
from repro.models import sequential_rec as sr
from repro.models import transformer as tf
from repro.serving import decode as decode_lib
from repro.serving.recommend import TwoStageConfig, pixie_then_rank, sasrec_ranker
from repro.serving.server import PixieServer


def test_generate_greedy_shapes_and_determinism():
    cfg = tf.LMConfig(
        name="t", n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
        head_dim=12, d_ff=96, vocab_size=128, remat=False,
        compute_dtype=jnp.float32, cache_dtype=jnp.float32,
    )
    params = tf.init_params(jax.random.key(0), cfg)
    prompt = jax.random.randint(jax.random.key(1), (2, 5), 0, 128)
    out1 = decode_lib.generate(params, prompt, cfg, max_new_tokens=6)
    out2 = decode_lib.generate(params, prompt, cfg, max_new_tokens=6)
    assert out1.shape == (2, 11)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    # prompt preserved
    np.testing.assert_array_equal(np.asarray(out1[:, :5]), np.asarray(prompt))


def test_pixie_server_serves_and_swaps():
    sg = small_test_graph()
    cfg = walk_lib.WalkConfig(
        n_steps=5_000, n_walkers=128, top_k=20, n_p=500, n_v=4
    )
    server = PixieServer(sg.graph, cfg, batch_size=4, n_slots=4)
    qs = top_degree_pins(sg, 8)
    for i in range(6):  # 6 requests -> 2 batches (one padded)
        server.submit([int(qs[i])], [1.0], user_feat=0)
    out = server.flush()
    assert len(out) == 6
    for scores, ids in out:
        assert scores.shape == (20,)
        assert (scores[:3] > 0).all()
    assert server.stats.queries == 6
    assert server.stats.batches == 2
    assert server.stats.percentile(50) > 0
    server.swap_graph(sg.graph)
    assert server.stats.graph_generation == 1
    # serving continues after the swap
    server.submit([int(qs[0])], [1.0])
    assert len(server.flush()) == 1


def test_build_query_weights_decay_and_rank():
    actions = [
        service.UserAction(pin=1, action="save", age_hours=0.0),
        service.UserAction(pin=2, action="view", age_hours=0.0),
        service.UserAction(pin=3, action="save", age_hours=240.0),
    ]
    pins, weights = service.build_query(actions, n_slots=4)
    assert pins[0] == 1            # fresh save ranks first
    assert weights[0] > weights[1] > 0
    # 10-day-old save decayed below a fresh view
    idx3 = list(pins).index(3)
    assert weights[idx3] < weights[1]
    assert pins[3] == -1 and weights[3] == 0.0  # padding


def test_build_query_unknown_action_raises_unless_default_given():
    """A typo'd action type must fail loudly, not silently weigh 0.1."""
    actions = [service.UserAction(pin=1, action="sav", age_hours=0.0)]
    with pytest.raises(ValueError, match="unknown action"):
        service.build_query(actions, n_slots=2)
    # explicit opt-in keeps the old catch-all behavior
    pins, weights = service.build_query(actions, n_slots=2,
                                        default_weight=0.1)
    assert pins[0] == 1
    assert weights[0] == pytest.approx(0.1)


def test_build_query_truncation_tie_break_is_deterministic():
    """Equal-weight pins at the top-n_slots cut must truncate identically
    regardless of action (and hence dict-insertion) order."""
    def acts(order):
        return [service.UserAction(pin=p, action="save", age_hours=0.0)
                for p in order]

    pins_a, w_a = service.build_query(acts([7, 3, 5]), n_slots=2)
    pins_b, w_b = service.build_query(acts([5, 7, 3]), n_slots=2)
    np.testing.assert_array_equal(pins_a, pins_b)
    np.testing.assert_array_equal(w_a, w_b)
    # ties break by pin id ascending: the kept pair is {3, 5}, ordered
    np.testing.assert_array_equal(pins_a, [3, 5])


def test_batch_queries_stacks_well_formed_batch():
    q0 = (np.asarray([1, 2, -1], np.int32), np.asarray([1.0, 0.5, 0], np.float32))
    q1 = (np.asarray([3, -1, -1], np.int32), np.asarray([1.0, 0, 0], np.float32))
    pins, weights, feats = service.batch_queries([q0, q1], [0, 3])
    assert pins.shape == (2, 3) and weights.shape == (2, 3)
    np.testing.assert_array_equal(np.asarray(feats), [0, 3])


def test_batch_queries_ragged_slots_raise():
    """Mismatched n_slots must fail naming the query, not as an opaque
    np.stack shape error."""
    q0 = (np.asarray([1, 2], np.int32), np.asarray([1.0, 0.5], np.float32))
    q1 = (np.asarray([3, 4, 5], np.int32),
          np.asarray([1.0, 0.5, 0.2], np.float32))
    with pytest.raises(ValueError, match="query 1 is ragged"):
        service.batch_queries([q0, q1], [0, 0])
    # pins/weights length mismatch WITHIN a query is ragged too
    q2 = (np.asarray([1, 2], np.int32), np.asarray([1.0], np.float32))
    with pytest.raises(ValueError, match="query 1 is ragged"):
        service.batch_queries([q0, q2], [0, 0])


def test_batch_queries_nonfloat_weights_raise():
    q0 = (np.asarray([1, 2], np.int32), np.asarray([1, 0], np.int32))
    with pytest.raises(ValueError, match="query 0 weights.*float"):
        service.batch_queries([q0], [0])


def test_batch_queries_feat_count_mismatch_raises():
    q0 = (np.asarray([1, 2], np.int32), np.asarray([1.0, 0.5], np.float32))
    with pytest.raises(ValueError, match="user_feats"):
        service.batch_queries([q0, q0], [0])
    with pytest.raises(ValueError, match="at least one query"):
        service.batch_queries([], [])


@pytest.mark.parametrize(
    "shape_cfg",
    [service.homefeed_config, service.related_pins_config,
     service.board_rec_config],
    ids=["homefeed", "related_pins", "board_rec"],
)
def test_serve_batch_backend_override_parity(shape_cfg):
    """Same key, backend="xla" vs "pallas": bit-identical recommendations
    (ids AND scores) plus identical early-stop telemetry across the §5
    query shapes — early stopping active so the incremental n_high tally is
    on the line."""
    sg = small_test_graph()
    g = sg.graph
    qs = top_degree_pins(sg, 8)
    batch, n_slots = 4, 2
    pins = np.full((batch, n_slots), -1, np.int32)
    weights = np.zeros((batch, n_slots), np.float32)
    for i in range(batch):
        pins[i, 0] = int(qs[2 * i])
        pins[i, 1] = int(qs[2 * i + 1])
        weights[i] = [1.0, 0.6]
    pins_j, weights_j = jnp.asarray(pins), jnp.asarray(weights)
    feats = jnp.zeros((batch,), jnp.int32)
    cfg = shape_cfg(
        walk_lib.WalkConfig(
            n_steps=3_000, n_walkers=128, chunk_steps=8, top_k=20,
            n_p=60, n_v=3,
        )
    )
    key = jax.random.key(17)
    sx, ix, stx, nhx = service.serve_batch(
        g, pins_j, weights_j, feats, key, cfg, backend="xla",
        with_stats=True,
    )
    sp, ip, stp, nhp = service.serve_batch(
        g, pins_j, weights_j, feats, key, cfg, backend="pallas",
        with_stats=True,
    )
    np.testing.assert_array_equal(np.asarray(ix), np.asarray(ip))
    np.testing.assert_array_equal(np.asarray(sx), np.asarray(sp))
    np.testing.assert_array_equal(np.asarray(stx), np.asarray(stp))
    np.testing.assert_array_equal(np.asarray(nhx), np.asarray(nhp))
    assert (np.asarray(nhx) >= 0).all()


def test_two_stage_recommendation_returns_walk_candidates():
    sg = small_test_graph()
    q = int(top_degree_pins(sg, 1)[0])
    cfg = sr.SeqRecConfig(
        name="r", kind="sasrec", n_items=sg.graph.n_pins, embed_dim=16,
        seq_len=8, n_blocks=1, n_heads=1,
    )
    params = sr.init_params(jax.random.key(0), cfg)
    history = jnp.full((8,), q, jnp.int32)
    ranker = sasrec_ranker(params, history, cfg)
    qp = jnp.asarray([q, -1, -1, -1], jnp.int32)
    qw = jnp.asarray([1.0, 0, 0, 0], jnp.float32)
    wcfg = walk_lib.WalkConfig(n_steps=8_000, n_walkers=128, n_p=10**9,
                               n_v=10**9)
    scores, items = pixie_then_rank(
        sg.graph, qp, qw, jnp.asarray(0, jnp.int32), jax.random.key(1),
        wcfg, ranker, TwoStageConfig(n_candidates=50, final_k=10),
    )
    items = np.asarray(items)
    scores = np.asarray(scores)
    assert items.shape == (10,)
    valid = np.isfinite(scores)
    assert valid.any()
    # ranked items must come from the graph (and not be the query pin)
    assert q not in items[valid]


def test_two_stage_underfull_candidates_return_minus1():
    """Fewer positive-walk-score candidates than final_k: the -inf tail
    must report id -1, never an arbitrary padding candidate's pin id."""
    from repro.core.graph import CSR, PinBoardGraph

    # pins {0, 1} share board 0; pins {2..7} share board 1, UNREACHABLE
    # from pin 1 — so a walk from pin 1 only ever visits {0, 1}, and the
    # query pin itself is masked -> exactly 1 positive-score candidate
    p2b = CSR(
        offsets=jnp.asarray(list(range(9)), jnp.int32),
        targets=jnp.asarray([8, 8] + [9] * 6, jnp.int32),
    )
    b2p = CSR(
        offsets=jnp.asarray([0, 2, 8], jnp.int32),
        targets=jnp.asarray(list(range(8)), jnp.int32),
    )
    g = PinBoardGraph(p2b=p2b, b2p=b2p, n_pins=8, n_boards=2,
                      max_pin_degree=1)
    qp = jnp.asarray([1, -1], jnp.int32)
    qw = jnp.asarray([1.0, 0.0], jnp.float32)
    wcfg = walk_lib.WalkConfig(
        n_steps=512, n_walkers=64, bias_beta=0.0, n_p=10**9, n_v=10**9
    )
    ranker = lambda cand: jnp.ones(cand.shape, jnp.float32)
    scores, items = pixie_then_rank(
        g, qp, qw, jnp.asarray(0, jnp.int32), jax.random.key(4),
        wcfg, ranker, TwoStageConfig(n_candidates=8, final_k=5),
    )
    scores, items = np.asarray(scores), np.asarray(items)
    finite = np.isfinite(scores)
    assert finite.sum() == 1 and items[finite][0] == 0
    np.testing.assert_array_equal(items[~finite], -1)


def test_build_query_float_sum_order_independent():
    """Repeated actions on one pin must sum in CANONICAL order, not
    arrival order.  The weights here are crafted so naive left-to-right
    f64 accumulation lands on opposite sides of an f32 rounding boundary
    depending on order: 1.0 + 2^-24 sits exactly on the round-to-even
    midpoint, and the two ~1.15*2^-54 crumbs (each below 1.0's f64
    half-ulp, together above it) decide which way it tips — BEFORE the
    canonical-order fix, abcd summed to f32 1.0 but cdab to 1.0000001."""
    import math

    age_cd = 24.0 * (53 - math.log2(1.15))
    a = service.UserAction(pin=7, action="save", age_hours=0.0)
    b = service.UserAction(pin=7, action="like", age_hours=552.0)
    c = service.UserAction(pin=7, action="like", age_hours=age_cd)
    d = service.UserAction(pin=7, action="like", age_hours=age_cd)
    _, w_ref = service.build_query([a, b, c, d], n_slots=2)
    for order in ([c, d, a, b], [b, a, d, c], [d, b, c, a]):
        _, w = service.build_query(order, n_slots=2)
        np.testing.assert_array_equal(
            np.asarray(w_ref).view(np.uint32), np.asarray(w).view(np.uint32),
            err_msg=f"order {[x.action for x in order]}",
        )


def test_batch_queries_slot_mismatch_names_integer_slot_count():
    """The ragged-batch error reports '3 slots', never the shape tuple
    '(3,)' masquerading as a count."""
    q0 = (np.asarray([1, 2, 5], np.int32),
          np.asarray([1.0, 0.5, 0.1], np.float32))
    q1 = (np.asarray([3, 4], np.int32), np.asarray([1.0, 0.5], np.float32))
    with pytest.raises(ValueError, match=r"the batch has 3 slots") as ei:
        service.batch_queries([q0, q1], [0, 0])
    assert "(3,)" not in str(ei.value)
