"""DMA gather mode of the fused walk kernel + walk-path consistency fixes.

The contract under test (kernels/walk_step.py): ``gather_mode="dma"``
(phase-split double-buffered async-copy CSR prefetch) is bit-for-bit
interchangeable with ``gather_mode="scalar"`` (blocking scalar gathers) and
with the XLA reference engine — counts, top-k, early-stop observables
(``steps_taken``, ``n_high``), board counts — across walker block sizes,
chunk boundaries, bias on/off, and ``count_boards`` on/off.  The dma-mode
kernel must actually lower async copies when not interpreting (jaxpr pin),
and the same code path must run under interpret mode on CPU hosts (every
execution test in this file does exactly that).

Also pins the legacy-path ``_RMASK`` fix: raw uint32 random bits must be
masked BEFORE the int32 cast everywhere — a high-bit draw cast raw becomes
a negative modulo operand whose result depends on the lowering.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from test_walk_backends import _chunk_args  # shared CSR chunk fixture

from repro.core import walk as walk_lib
from repro.graphs.synthetic import small_test_graph, top_degree_pins
from repro.kernels import ops
from repro.kernels.walk_step import _RMASK, GATHER_MODES, walk_steps_fused


@pytest.fixture(scope="module")
def sg():
    return small_test_graph()


def _queries(sg, n_slots=4):
    qs = top_degree_pins(sg, 2)
    qp = jnp.full((n_slots,), -1, jnp.int32).at[:2].set(
        jnp.asarray([int(qs[0]), int(qs[1])], jnp.int32)
    )
    qw = jnp.zeros((n_slots,), jnp.float32).at[:2].set(
        jnp.asarray([1.0, 0.5])
    )
    return qp, qw


# ---------------------------------------------------------------------------
# parity matrix: dma == scalar == xla through the full dense walk
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("block_w", [128, 256])
@pytest.mark.parametrize("bias_beta", [0.0, 0.9])
@pytest.mark.parametrize("count_boards", [False, True])
def test_walk_parity_matrix(sg, block_w, bias_beta, count_boards):
    """Bit-identity across the gather-mode matrix, early stopping ACTIVE
    (so steps_taken / n_high are live observables) and a step budget that
    crosses chunk boundaries (n_steps > n_walkers * chunk_steps)."""
    g = sg.graph
    qp, qw = _queries(sg)
    base = walk_lib.WalkConfig(
        n_steps=2_500, n_walkers=256, chunk_steps=4,
        n_p=60, n_v=3, bias_beta=bias_beta, count_boards=count_boards,
        pallas_block_w=block_w,
    )
    key = jax.random.key(13)
    results = {}
    for label, cfg in (
        ("xla", dataclasses.replace(base, backend="xla")),
        ("scalar", dataclasses.replace(base, backend="pallas",
                                       gather_mode="scalar")),
        ("dma", dataclasses.replace(base, backend="pallas",
                                    gather_mode="dma")),
    ):
        results[label] = walk_lib.pixie_random_walk(
            g, qp, qw, jnp.asarray(1, jnp.int32), key, cfg
        )
    rx = results["xla"]
    assert int(rx.counts.sum()) > 0  # the walk actually walked
    for label in ("scalar", "dma"):
        r = results[label]
        np.testing.assert_array_equal(
            np.asarray(rx.counts), np.asarray(r.counts), err_msg=label
        )
        np.testing.assert_array_equal(
            np.asarray(rx.steps_taken), np.asarray(r.steps_taken),
            err_msg=label,
        )
        np.testing.assert_array_equal(
            np.asarray(rx.n_high), np.asarray(r.n_high), err_msg=label
        )
        if count_boards:
            np.testing.assert_array_equal(
                np.asarray(rx.board_counts), np.asarray(r.board_counts),
                err_msg=label,
            )


def test_topk_recommendations_identical(sg):
    """The full recommend() path (walk -> booster -> top-k) is bit-identical
    across gather modes and against the xla engine."""
    g = sg.graph
    qp, qw = _queries(sg)
    base = walk_lib.WalkConfig(
        n_steps=3_000, n_walkers=128, chunk_steps=8, top_k=20,
        n_p=10**9, n_v=10**9,
    )
    key = jax.random.key(3)
    outs = {}
    for label, cfg in (
        ("xla", base),
        ("scalar", dataclasses.replace(base, backend="pallas")),
        ("dma", dataclasses.replace(base, backend="pallas",
                                    gather_mode="dma")),
    ):
        outs[label] = walk_lib.recommend(
            g, qp, qw, jnp.asarray(0, jnp.int32), key, cfg
        )
    for label in ("scalar", "dma"):
        np.testing.assert_array_equal(
            np.asarray(outs["xla"][1]), np.asarray(outs[label][1]),
            err_msg=label,
        )
        np.testing.assert_array_equal(
            np.asarray(outs["xla"][0]), np.asarray(outs[label][0]),
            err_msg=label,
        )


def test_event_buffers_identical_across_gather_modes(sg):
    """Event-mode walks (the production-scale path) emit identical wide
    lane buffers from both gather modes."""
    g = sg.graph
    qp, qw = _queries(sg)
    base = walk_lib.WalkConfig(
        n_steps=2_000, n_walkers=128, chunk_steps=8,
        n_p=10**9, n_v=10**9, backend="pallas",
    )
    key = jax.random.key(21)
    es = walk_lib.pixie_walk_events(
        g, qp, qw, jnp.asarray(0, jnp.int32), key, base, check_every=10**9
    )
    ed = walk_lib.pixie_walk_events(
        g, qp, qw, jnp.asarray(0, jnp.int32), key,
        dataclasses.replace(base, gather_mode="dma"), check_every=10**9
    )
    np.testing.assert_array_equal(
        np.asarray(es.slot_events), np.asarray(ed.slot_events)
    )
    np.testing.assert_array_equal(
        np.asarray(es.pin_events), np.asarray(ed.pin_events)
    )
    assert int(es.chunks_run) == int(ed.chunks_run)


# ---------------------------------------------------------------------------
# chunk-level: op parity and the lowering pin (CSR fixture shared with
# test_walk_backends._chunk_args)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("alpha_u32", [0, 2**31, 2**32 - 1])
def test_dma_chunk_matches_scalar_and_ref(alpha_u32):
    a = _chunk_args(jax.random.key(alpha_u32 % 97))
    common = dict(alpha_u32=alpha_u32, beta_u32=0, count_boards=True)
    want = ops.walk_chunk_fused(use_kernel=False, **a, **common)
    scalar = ops.walk_chunk_fused(
        use_kernel=True, gather_mode="scalar", **a, **common
    )
    dma = ops.walk_chunk_fused(
        use_kernel=True, gather_mode="dma", **a, **common
    )
    for g_, s_, w_ in zip(dma, scalar, want):
        np.testing.assert_array_equal(np.asarray(g_), np.asarray(w_))
        np.testing.assert_array_equal(np.asarray(g_), np.asarray(s_))


def _fused_jaxpr(a, gather_mode):
    """Trace (don't run) the fused kernel with interpret=False, so the pin
    sees what a TPU lowering would see."""
    return str(jax.make_jaxpr(lambda: walk_steps_fused(
        a["curr"], a["query"], a["feat"], a["slot"], a["rbits"],
        a["p2b_offsets"], a["p2b_targets"],
        a["b2p_offsets"], a["b2p_targets"],
        n_pins=a["n_pins"], n_slots=a["n_slots"], n_boards=a["n_boards"],
        alpha_u32=2**30, beta_u32=0, block_w=128,
        gather_mode=gather_mode, interpret=False,
    ))())


def test_dma_mode_lowers_async_copies():
    """The dma kernel really is a DMA pipeline: its (non-interpret) jaxpr
    contains async-copy start/wait ops; the scalar kernel contains none."""
    a = _chunk_args(jax.random.key(5))
    dma_jaxpr = _fused_jaxpr(a, "dma")
    assert "dma_start" in dma_jaxpr and "dma_wait" in dma_jaxpr
    scalar_jaxpr = _fused_jaxpr(a, "scalar")
    assert "dma_start" not in scalar_jaxpr


def test_gather_mode_validated():
    a = _chunk_args(jax.random.key(1))
    with pytest.raises(ValueError, match="gather_mode"):
        walk_steps_fused(
            a["curr"], a["query"], a["feat"], a["slot"], a["rbits"],
            a["p2b_offsets"], a["p2b_targets"],
            a["b2p_offsets"], a["b2p_targets"],
            n_pins=a["n_pins"], n_slots=a["n_slots"],
            n_boards=a["n_boards"], alpha_u32=0, beta_u32=0,
            gather_mode="bogus",
        )
    assert set(GATHER_MODES) == {"scalar", "dma"}


def test_walk_config_gather_mode_validated(sg):
    qp, qw = _queries(sg)
    cfg = walk_lib.WalkConfig(
        n_steps=256, n_walkers=64, n_p=10**9, n_v=10**9,
        gather_mode="turbo",
    )
    with pytest.raises(ValueError, match="gather_mode"):
        walk_lib.pixie_random_walk(
            sg.graph, qp, qw, jnp.asarray(0, jnp.int32),
            jax.random.key(0), cfg
        )


# ---------------------------------------------------------------------------
# legacy-path _RMASK regression (satellite bugfix)
# ---------------------------------------------------------------------------


def _numpy_walk_step(curr, query, rbits, p2b_off, p2b_tgt, b2p_off, b2p_tgt,
                     n_pins, alpha_u32):
    """Independent numpy model of one superstep with the MASKED arithmetic
    (the documented contract of both the kernel and the jnp reference)."""
    restart = rbits[:, 0] < np.uint32(alpha_u32)
    pos = np.where(restart, query, curr)
    r_board = (rbits[:, 1] & _RMASK).astype(np.int64)
    r_pin = (rbits[:, 2] & _RMASK).astype(np.int64)
    start = p2b_off[pos]
    deg = p2b_off[pos + 1] - start
    idx = start + (r_board % np.maximum(deg, 1))
    board = p2b_tgt[idx]
    board_ok = deg > 0
    b_local = np.where(board_ok, board - n_pins, 0)
    bstart = b2p_off[b_local]
    bdeg = b2p_off[b_local + 1] - bstart
    bidx = bstart + (r_pin % np.maximum(bdeg, 1))
    nxt = b2p_tgt[bidx]
    ok = board_ok & (bdeg > 0)
    return (np.where(ok, nxt, query).astype(np.int32),
            np.where(ok, nxt, 0).astype(np.int32), ok)


@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["ref", "kernel"])
def test_legacy_walk_step_masks_high_random_bits(use_kernel):
    """Feed the single-step path draws >= 2**31: the raw int32 cast used to
    make these negative modulo operands (lowering-dependent picks); both
    the jnp reference and the Pallas kernel must match the masked model."""
    w = 256  # the legacy kernel's default walker block
    a = _chunk_args(jax.random.key(42), w=w)
    rng = np.random.default_rng(7)
    # every draw has the high bit set — the regression regime
    rbits = (rng.integers(2**31, 2**32, size=(w, 3), dtype=np.uint32))
    got = ops.walk_step(
        a["curr"], a["query"], jnp.asarray(rbits),
        a["p2b_offsets"], a["p2b_targets"],
        a["b2p_offsets"], a["b2p_targets"],
        n_pins=a["n_pins"], alpha_u32=2**31, use_kernel=use_kernel,
    )
    want = _numpy_walk_step(
        np.asarray(a["curr"]), np.asarray(a["query"]), rbits,
        np.asarray(a["p2b_offsets"]), np.asarray(a["p2b_targets"]),
        np.asarray(a["b2p_offsets"]), np.asarray(a["b2p_targets"]),
        a["n_pins"], 2**31,
    )
    for g_, w_ in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g_), w_)
    # and the two legacy implementations agree with each other
    other = ops.walk_step(
        a["curr"], a["query"], jnp.asarray(rbits),
        a["p2b_offsets"], a["p2b_targets"],
        a["b2p_offsets"], a["b2p_targets"],
        n_pins=a["n_pins"], alpha_u32=2**31, use_kernel=not use_kernel,
    )
    for g_, o_ in zip(got, other):
        np.testing.assert_array_equal(np.asarray(g_), np.asarray(o_))
