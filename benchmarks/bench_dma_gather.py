"""DMA-gather sweep: double-buffered async-copy CSR prefetch vs scalar loads.

Quantifies the gather-mode tentpole on the serving path: the fused Pallas
walk engine with ``gather_mode="scalar"`` (blocking per-walker scalar CSR
gathers) vs ``gather_mode="dma"`` (phase-split double-buffered
``make_async_copy`` prefetch), with the XLA engine as the reference, across
walker block sizes and bias on/off.

The agreement verdict is the regression signal: ``dma_backends_agree``
asserts dma == scalar == xla bit-identically on recommendations AND the
early-stop observables (steps_taken, n_high) for the same key.  On CPU
hosts the kernels run in interpret mode — the interpreter executes the
async copies synchronously, so dma-mode *timings* there measure plumbing,
not the latency hiding (only meaningful on TPU hosts); regress on
``dma_backends_agree``, not the CPU ratio.

Results are returned for ``results/bench.json`` AND merged into
``BENCH_serving.json`` as the ``dma`` section, next to the other
backend-agreement verdicts.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import merge_serving_section, timed
from repro.core import service, walk as walk_lib
from repro.graphs.synthetic import SyntheticGraphConfig, generate


def _batch(g, seed, batch=4, n_slots=2):
    rng = np.random.default_rng(seed)
    degs = np.asarray(g.p2b.degrees()).astype(np.float64)
    qs = rng.choice(g.n_pins, size=batch * n_slots, replace=False,
                    p=degs / degs.sum())
    pins = qs.reshape(batch, n_slots).astype(np.int32)
    weights = np.tile(np.asarray([1.0, 0.6], np.float32), (batch, 1))
    return jnp.asarray(pins), jnp.asarray(weights)


def _gather_sweep(seed: int) -> Dict:
    sg = generate(SyntheticGraphConfig(
        n_pins=2_000, n_boards=200, n_topics=8, n_langs=2, seed=seed
    ))
    g = sg.graph
    pins, weights = _batch(g, seed)
    feats = jnp.zeros((pins.shape[0],), jnp.int32)
    key = jax.random.key(seed)

    sweep = []
    agree = True
    for block_w, bias_beta in ((128, 0.0), (128, 0.9), (256, 0.9)):
        cfg = walk_lib.WalkConfig(
            n_steps=2_000, n_walkers=256, chunk_steps=8, top_k=20,
            n_p=60, n_v=3, bias_beta=bias_beta, pallas_block_w=block_w,
        )
        row: Dict = {"block_w": block_w, "bias_beta": bias_beta,
                     "engines": {}}
        outs = {}
        for label, ecfg in (
            ("xla", dataclasses.replace(cfg, backend="xla")),
            ("scalar", dataclasses.replace(cfg, backend="pallas",
                                           gather_mode="scalar")),
            ("dma", dataclasses.replace(cfg, backend="pallas",
                                        gather_mode="dma")),
        ):
            fn = jax.jit(lambda k, c=ecfg: service.serve_batch(
                g, pins, weights, feats, k, c, with_stats=True
            ))
            t = timed(fn, key, warmup=1, iters=3)
            _, ids, steps, n_high = fn(key)
            outs[label] = (np.asarray(ids), np.asarray(steps),
                           np.asarray(n_high))
            row["engines"][label] = {"batch_ms": round(t["mean_ms"], 2)}
        row["agree"] = bool(all(
            np.array_equal(a, b)
            for other in ("scalar", "dma")
            for a, b in zip(outs["xla"], outs[other])
        ))
        agree &= row["agree"]
        row["dma_vs_scalar_x"] = round(
            row["engines"]["scalar"]["batch_ms"]
            / max(row["engines"]["dma"]["batch_ms"], 1e-9), 3
        )
        sweep.append(row)
    # verdict key lives only at the suite top level (run.py counts every
    # occurrence of a verdict key, at any nesting)
    return {"graph": {"n_pins": g.n_pins, "n_boards": g.n_boards},
            "sweep": sweep, "agree_all": agree}


def run(seed: int = 0) -> Dict:
    out: Dict = {
        "host_backend": jax.default_backend(),
        "pallas_interpret": jax.default_backend() == "cpu",
        "gather": _gather_sweep(seed),
    }
    out["dma_backends_agree"] = out["gather"]["agree_all"]
    # merge into the serving trajectory file, next to the other agreement
    # verdicts (bench_smoke writes the base file and preserves this section)
    out["wrote"] = merge_serving_section("dma", {
        "dma_backends_agree": out["dma_backends_agree"],
        "pallas_interpret": out["pallas_interpret"],
        "sweep": [
            {k: row[k] for k in
             ("block_w", "bias_beta", "agree", "dma_vs_scalar_x")}
            for row in out["gather"]["sweep"]
        ],
    })
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))
