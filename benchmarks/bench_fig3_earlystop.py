"""Figure 3: early stopping — runtime/steps saved vs overlap with gold.

Gold = top-100 of a long fixed-budget walk.  Sweep n_v at n_p fixed, then
n_p at n_v fixed; report (steps actually taken, overlap with gold).  Paper
claim: appropriate (n_p, n_v) cuts steps ~2-3x while keeping ~85-90%
overlap with the gold set.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_graph, sample_query_pins
from repro.core import counter as counter_lib
from repro.core import walk as walk_lib


def _top100(g, qp, qw, cfg, key):
    res = walk_lib.pixie_random_walk(
        g, qp, qw, jnp.asarray(0, jnp.int32), key, cfg
    )
    boosted = counter_lib.boost_combine(res.counts)
    vals, ids = counter_lib.topk_dense(boosted, 100)
    ids = np.asarray(ids)[np.asarray(vals) > 0]
    return set(ids.tolist()), int(np.asarray(res.steps_taken).sum())


def run(n_queries: int = 8, seed: int = 0) -> Dict:
    sg = bench_graph()
    g = sg.graph
    queries = sample_query_pins(sg, n_queries, seed)
    budget = 40_000

    gold_cfg = walk_lib.WalkConfig(
        n_steps=budget, n_walkers=256, n_p=10**9, n_v=10**9
    )

    def sweep(param_name, values, fixed):
        rows = []
        for v in values:
            kwargs = dict(fixed)
            kwargs[param_name] = v
            cfg = walk_lib.WalkConfig(
                n_steps=budget, n_walkers=256, **kwargs
            )
            overlaps, steps = [], []
            for i, q in enumerate(queries):
                qp = jnp.asarray([int(q)], jnp.int32)
                qw = jnp.ones((1,), jnp.float32)
                key = jax.random.key(seed * 31 + i)
                gold, _ = _top100(g, qp, qw, gold_cfg, key)
                got, n_steps = _top100(g, qp, qw, cfg, key)
                if gold:
                    overlaps.append(len(gold & got) / len(gold))
                steps.append(n_steps)
            rows.append({
                param_name: v,
                "overlap_with_gold": round(float(np.mean(overlaps)), 3),
                "mean_steps": float(np.mean(steps)),
                "step_fraction": round(float(np.mean(steps)) / budget, 3),
            })
        return rows

    out = {
        "vary_nv": sweep("n_v", [2, 4, 8, 16], {"n_p": 500}),
        "vary_np": sweep("n_p", [100, 300, 1000, 3000], {"n_v": 4}),
    }
    # reproduction: some setting cuts steps >= 2x with overlap >= 0.7
    ok = any(
        r["step_fraction"] <= 0.55 and r["overlap_with_gold"] >= 0.7
        for r in out["vary_nv"] + out["vary_np"]
    )
    out["early_stop_saves_steps"] = bool(ok)
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))
