"""Figure 1: Pixie runtime (a) vs number of steps, (b) vs query-set size.

Paper claims: runtime linear in N (50 ms under 200k steps on their CPU
fleet); runtime grows slowly with query size (cache effects).  On this CPU
host the absolute numbers are not the paper's; the claim under test is the
SHAPE: near-linear in steps, sub-linear in query size.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_graph, sample_query_pins, timed
from repro.core import walk as walk_lib


def run(seed: int = 0) -> Dict:
    sg = bench_graph()
    g = sg.graph
    qs = sample_query_pins(sg, 16, seed)

    out: Dict = {"runtime_vs_steps": [], "runtime_vs_query_size": []}

    # (a) runtime vs steps, |Q| = 1
    for n_steps in (5_000, 10_000, 20_000, 40_000):
        cfg = walk_lib.WalkConfig(
            n_steps=n_steps, n_walkers=256, top_k=100, n_p=10**9, n_v=10**9
        )
        qp = jnp.asarray([int(qs[0])], jnp.int32)
        qw = jnp.ones((1,), jnp.float32)
        fn = jax.jit(
            lambda k: walk_lib.recommend(
                g, qp, qw, jnp.asarray(0, jnp.int32), k, cfg
            )
        )
        t = timed(fn, jax.random.key(seed), warmup=1, iters=3)
        out["runtime_vs_steps"].append(
            {"n_steps": n_steps, **t}
        )

    # (b) runtime vs query size, fixed steps
    for q_size in (1, 2, 4, 8):
        cfg = walk_lib.WalkConfig(
            n_steps=20_000, n_walkers=256, top_k=100, n_p=10**9, n_v=10**9
        )
        qp = jnp.full((8,), -1, jnp.int32).at[:q_size].set(
            jnp.asarray(qs[:q_size])
        )
        qw = jnp.zeros((8,), jnp.float32).at[:q_size].set(1.0)
        fn = jax.jit(
            lambda k: walk_lib.recommend(
                g, qp, qw, jnp.asarray(0, jnp.int32), k, cfg
            )
        )
        t = timed(fn, jax.random.key(seed), warmup=1, iters=3)
        out["runtime_vs_query_size"].append({"q_size": q_size, **t})

    # (c) walk-engine sweep: same single-query walk on both step backends.
    # On CPU the pallas engine runs interpreted (plumbing check, not perf);
    # on TPU this is the fused-kernel speedup for the Fig. 1 workload.
    out["backend_sweep"] = []
    for backend in ("xla", "pallas"):
        cfg = walk_lib.WalkConfig(
            n_steps=5_000, n_walkers=256, top_k=100, n_p=10**9, n_v=10**9,
            backend=backend,
        )
        qp = jnp.asarray([int(qs[0])], jnp.int32)
        qw = jnp.ones((1,), jnp.float32)
        fn = jax.jit(
            lambda k, c=cfg: walk_lib.recommend(
                g, qp, qw, jnp.asarray(0, jnp.int32), k, c
            )
        )
        t = timed(fn, jax.random.key(seed), warmup=1, iters=2)
        out["backend_sweep"].append({"backend": backend, **t})
    bs = out["backend_sweep"]
    out["pallas_speedup_x"] = round(
        bs[0]["mean_ms"] / max(bs[1]["mean_ms"], 1e-9), 3
    )

    # shape checks
    r = out["runtime_vs_steps"]
    lin = r[-1]["mean_ms"] / max(r[0]["mean_ms"], 1e-9)
    steps_ratio = r[-1]["n_steps"] / r[0]["n_steps"]
    out["steps_scaling_ratio"] = {
        "time_ratio": round(lin, 2), "steps_ratio": steps_ratio,
        "near_linear": bool(lin < 1.6 * steps_ratio),
    }
    q = out["runtime_vs_query_size"]
    out["query_size_sublinear"] = bool(
        q[-1]["mean_ms"] / max(q[0]["mean_ms"], 1e-9) < 8
    )
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))
