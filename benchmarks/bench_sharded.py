"""Pod-sharded batched fused walk engine sweep: per-shard supersteps on
the bounded ``_route`` fabric vs the unsharded batched engine.

Quantifies the sharding tentpole (``core/distributed.py``): the graph CSR
node-range-sharded over a 'model' mesh axis, each per-shard superstep
running the fused hop kernels (or their XLA oracle twins) on shard-local
slices, ONE bounded-capacity all_to_all route per hop for the whole query
batch — swept over n_shards {1, 2, 4, 8} x engine {xla, fused} x batch
{1, 8} on 8 forced host devices.

Recorded per cell: walk ms and per-superstep ms, routed-walker occupancy
vs route capacity (``max_occupancy`` telemetry from ``_route``), and
dropped-walker counts.  A deliberately starved-slack row shows drops are
counted, never silent.

The agreement verdict is the regression signal: ``sharded_engine_agrees``
asserts fused sharded == xla sharded == unsharded batched bit-identically
(counts, board counts, steps_taken, n_high) for every swept cell, with
zero drops at parity slack.  On CPU hosts the kernels run in interpret
mode and the 8 "devices" share one machine — ms columns measure plumbing,
not ICI; regress on ``sharded_engine_agrees``, not the CPU ratios.

Needs a multi-device jax, but the driver imports suites after jax locks
its device count — so ``run()`` re-executes this module in a child
process with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Results land in ``results/bench.json`` AND merge into
``BENCH_serving.json`` as the ``sharded`` section.
"""

from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import time
from typing import Dict

N_DEVICES = 8
SHARDS = (1, 2, 4, 8)
BATCHES = (1, 8)
WALKERS_PER_QUERY = 32
N_SLOTS = 4


def _child_sweep(seed: int) -> Dict:
    """Runs inside the 8-device child process."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import counter as counter_lib
    from repro.core import distributed as dist_lib
    from repro.core import walk as walk_lib
    from repro.graphs.synthetic import small_test_graph, top_degree_pins
    from repro.launch.mesh import make_mesh_compat, set_mesh_compat

    sg = small_test_graph(seed)
    g = sg.graph
    qs = top_degree_pins(sg, 16)
    base = walk_lib.WalkConfig(
        n_steps=2_048, n_walkers=WALKERS_PER_QUERY, chunk_steps=4,
        n_p=30, n_v=3, bias_beta=0.0, count_boards=True,
    )

    def queries(batch):
        pins = np.full((batch, N_SLOTS), -1, np.int32)
        weights = np.zeros((batch, N_SLOTS), np.float32)
        for b in range(batch):
            pins[b, :3] = qs[(3 * b) % 12:(3 * b) % 12 + 3]
            weights[b, :3] = (1.0, 0.7, 0.4)
        return jnp.asarray(pins), jnp.asarray(weights)

    def timed(fn, arg, iters=2):
        out = jax.block_until_ready(fn(arg))  # compile + warm
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            out = jax.block_until_ready(fn(arg))
            times.append(time.perf_counter() - t0)
        return out, 1e3 * float(np.mean(times))

    refs = {}  # unsharded batched oracle per batch size
    for batch in BATCHES:
        pins, weights = queries(batch)
        keys = jax.random.split(jax.random.key(seed), batch)
        r = walk_lib.pixie_random_walk_batched(
            g, pins, weights, jnp.zeros((batch,), jnp.int32), keys, base
        )
        refs[batch] = tuple(
            np.asarray(x) for x in (r.counts, r.board_counts,
                                    r.steps_taken, r.n_high)
        )

    sweep = []
    agree_all = True
    supersteps = base.max_chunks() * base.chunk_steps
    for n_shards in SHARDS:
        mesh = make_mesh_compat((n_shards,), ("model",))
        shg = dist_lib.shard_graph(g, n_shards)
        for batch in BATCHES:
            pins, weights = queries(batch)
            keys = jax.random.split(jax.random.key(seed), batch)
            w_total = batch * WALKERS_PER_QUERY
            # parity slack: capacity >= the whole walker pool, so routing
            # can never drop (occupancy telemetry still shows real skew)
            slack = float(n_shards * n_shards)
            cap = dist_lib.route_capacity(n_shards, w_total, slack)
            row: Dict = {"n_shards": n_shards, "batch": batch,
                         "route_capacity": cap, "engines": {}}
            engines = [("xla", "scalar"), ("fused_scalar", "scalar")]
            if n_shards in (2, 4):
                engines.append(("fused_dma", "dma"))
            row_ok = True
            with set_mesh_compat(mesh):
                for label, gather in engines:
                    cfg = dataclasses.replace(
                        base,
                        backend="xla" if label == "xla" else "pallas",
                        gather_mode=gather,
                    )
                    fn = jax.jit(
                        lambda ks, cfg=cfg: dist_lib.pixie_walk_sharded_batched(
                            shg, pins, weights, ks, cfg, mesh, slack=slack
                        )
                    )
                    res, ms = timed(fn, keys)
                    counts = counter_lib.fold_sharded_counts(
                        res.counts, batch, N_SLOTS, shg.pins_per_shard
                    )[..., :g.n_pins]
                    bc = counter_lib.fold_sharded_counts(
                        res.board_counts, batch, N_SLOTS,
                        shg.boards_per_shard
                    )[..., :g.n_boards]
                    got = tuple(np.asarray(x)
                                for x in (counts, bc, res.steps_taken,
                                          res.n_high))
                    ok = all(np.array_equal(a, b)
                             for a, b in zip(got, refs[batch]))
                    ok = ok and int(res.dropped) == 0
                    row_ok &= ok
                    occ = int(res.max_occupancy)
                    row["engines"][label] = {
                        "walk_ms": round(ms, 2),
                        "per_superstep_ms": round(ms / supersteps, 3),
                        "dropped": int(res.dropped),
                        "max_occupancy": occ,
                        "occupancy_frac": round(occ / cap, 3),
                        "agrees_with_unsharded": ok,
                    }
            row["agree"] = row_ok
            agree_all &= row_ok
            sweep.append(row)

    # starved-slack illustration: drops are COUNTED, not silent (no parity
    # claim here — dropped walkers are bounded Monte Carlo slack)
    mesh = make_mesh_compat((2,), ("model",))
    shg = dist_lib.shard_graph(g, 2)
    pins, weights = queries(8)
    keys = jax.random.split(jax.random.key(seed), 8)
    with set_mesh_compat(mesh):
        res = jax.block_until_ready(
            dist_lib.pixie_walk_sharded_batched(
                shg, pins, weights, keys, base, mesh, slack=0.05
            )
        )
    starved = {
        "n_shards": 2, "batch": 8, "slack": 0.05,
        "route_capacity": dist_lib.route_capacity(2, 8 * WALKERS_PER_QUERY,
                                                  0.05),
        "dropped": int(res.dropped),
        "max_occupancy": int(res.max_occupancy),
        "drops_counted": int(res.dropped) > 0,
    }

    return {
        "host_backend": jax.default_backend(),
        "pallas_interpret": jax.default_backend() == "cpu",
        "n_devices": len(jax.devices()),
        "graph": {"n_pins": g.n_pins, "n_boards": g.n_boards},
        "config": {"walkers_per_query": WALKERS_PER_QUERY,
                   "n_steps": base.n_steps, "chunk_steps": base.chunk_steps,
                   "supersteps": supersteps, "n_slots": N_SLOTS},
        "sweep": sweep,
        "starved": starved,
        "agree_all": agree_all,
        "drops_counted": starved["drops_counted"],
    }


def run(seed: int = 0) -> Dict:
    """Driver entry: re-exec in a child with 8 forced host devices."""
    from benchmarks.common import merge_serving_section

    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={N_DEVICES}"
    ).strip()
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo, "src"), repo, env.get("PYTHONPATH", "")]
    )
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_sharded", "--child",
         "--seed", str(seed)],
        capture_output=True, text=True, env=env, cwd=repo, timeout=3600,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"bench_sharded child failed:\n{proc.stderr[-3000:]}"
        )
    out: Dict = {"sharded": json.loads(proc.stdout.strip().splitlines()[-1])}
    # verdict: fused sharded == xla sharded == unsharded batched engine,
    # bit-identically (counts, board counts, steps_taken, n_high), zero
    # drops at parity slack, for every (n_shards, batch) cell — and
    # capacity-overflow drops are counted when the fabric is starved
    out["sharded_engine_agrees"] = bool(
        out["sharded"]["agree_all"] and out["sharded"]["drops_counted"]
    )
    out["wrote"] = merge_serving_section("sharded", {
        "sharded_engine_agrees": out["sharded_engine_agrees"],
        "pallas_interpret": out["sharded"]["pallas_interpret"],
        "starved": out["sharded"]["starved"],
        "sweep": [
            {
                "n_shards": row["n_shards"],
                "batch": row["batch"],
                "agree": row["agree"],
                "route_capacity": row["route_capacity"],
                "per_superstep_ms": {
                    k: v["per_superstep_ms"]
                    for k, v in row["engines"].items()
                },
                "occupancy_frac": {
                    k: v["occupancy_frac"]
                    for k, v in row["engines"].items()
                },
                "dropped": {
                    k: v["dropped"] for k, v in row["engines"].items()
                },
            }
            for row in out["sharded"]["sweep"]
        ],
    })
    return out


def _child_main() -> int:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.child:
        print(json.dumps(_child_sweep(args.seed)))
        return 0
    print(json.dumps(run(args.seed), indent=2))
    return 0


if __name__ == "__main__":
    sys.exit(_child_main())
