"""Figure 2: variance of top results vs number of steps.

Run the same query R times with different RNG keys; for each step budget,
count how many of the top-100 pins appear in >= 50% / 100% of runs.  Paper
claim: stability grows with steps and saturates (several hundred thousand
steps suffice at production scale; proportionally fewer here).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_graph, sample_query_pins
from repro.core import walk as walk_lib


def run(n_repeats: int = 10, seed: int = 0) -> Dict:
    sg = bench_graph()
    g = sg.graph
    q = int(sample_query_pins(sg, 1, seed)[0])
    qp = jnp.asarray([q], jnp.int32)
    qw = jnp.ones((1,), jnp.float32)

    out = {"stability": []}
    for n_steps in (5_000, 15_000, 40_000):
        cfg = walk_lib.WalkConfig(
            n_steps=n_steps, n_walkers=256, top_k=100, n_p=10**9, n_v=10**9
        )
        fn = jax.jit(
            lambda k: walk_lib.recommend(
                g, qp, qw, jnp.asarray(0, jnp.int32), k, cfg
            )
        )
        counts: Dict[int, int] = {}
        for r in range(n_repeats):
            vals, ids = fn(jax.random.key(seed * 97 + r))
            ids = np.asarray(ids)[np.asarray(vals) > 0][:100]
            for p in ids:
                counts[int(p)] = counts.get(int(p), 0) + 1
        in_half = sum(1 for c in counts.values() if c >= n_repeats * 0.5)
        in_all = sum(1 for c in counts.values() if c == n_repeats)
        out["stability"].append(
            {"n_steps": n_steps, "in_50pct": in_half, "in_100pct": in_all}
        )
    s = out["stability"]
    out["stability_grows_with_steps"] = bool(
        s[-1]["in_100pct"] >= s[0]["in_100pct"]
    )
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))
