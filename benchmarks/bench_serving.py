"""Serving-fleet benchmark (paper §3.3 / conclusion: 1,200 QPS at 60 ms p99
per server).

Runs the batched PixieServer on the synthetic graph, reports QPS and
latency percentiles on this host.  On a single CPU core the vmapped SPMD
lanes SERIALIZE, so batching cannot raise QPS here (it does on TPU, where
lanes are parallel); the host-testable claim is that the batching path
adds only bounded overhead (per-query cost roughly flat across batch
sizes) while per-query p50 at batch 1 lands in the paper's latency
regime.
"""

from __future__ import annotations

import time
from typing import Dict

import numpy as np

from benchmarks.common import bench_graph, sample_query_pins
from repro.core import walk as walk_lib
from repro.serving.server import PixieServer


def run(n_requests: int = 64, seed: int = 0) -> Dict:
    sg = bench_graph()
    qs = sample_query_pins(sg, 64, seed)
    rng = np.random.default_rng(seed)

    out = {"batch_sweep": []}
    for batch in (1, 8, 16):
        cfg = walk_lib.WalkConfig(
            n_steps=10_000, n_walkers=256, top_k=100, n_p=2000, n_v=4
        )
        server = PixieServer(
            sg.graph, cfg, batch_size=batch, n_slots=4, seed=seed
        )
        # warm-up: compile the serve program before timing
        server.submit([int(qs[0])], [1.0], user_feat=0)
        server.flush()
        server.stats.latencies_ms.clear()
        server.stats.queries = 0
        for i in range(n_requests):
            k = rng.integers(1, 4)
            pins = rng.choice(qs, size=k, replace=False)
            server.submit(pins.tolist(), [1.0] * k, user_feat=0)
        t0 = time.perf_counter()
        server.flush()
        wall = time.perf_counter() - t0
        out["batch_sweep"].append({
            "batch": batch,
            "qps": round(server.stats.qps(wall), 1),
            "p50_ms": round(server.stats.percentile(50), 1),
            "p99_ms": round(server.stats.percentile(99), 1),
        })
    rows = out["batch_sweep"]
    # host-testable: batching overhead bounded (QPS roughly flat on one
    # core; on TPU the lanes are parallel and QPS scales with batch)
    out["batching_overhead_bounded"] = bool(
        rows[-1]["qps"] >= 0.5 * rows[0]["qps"]
    )

    # walk-engine sweep: the same serving path on both step backends.  On a
    # CPU host the pallas engine runs in interpret mode (correctness
    # plumbing, expect a big slowdown); on TPU this reports the real fused
    # kernel speedup.  Smaller request count: interpret mode is slow.
    out["backend_sweep"] = _backend_sweep(sg, qs, seed, n_requests=8)
    return out


def _backend_sweep(sg, qs, seed: int, n_requests: int) -> Dict:
    rng = np.random.default_rng(seed + 1)
    res: Dict = {"rows": []}
    for backend in ("xla", "pallas"):
        cfg = walk_lib.WalkConfig(
            n_steps=4_000, n_walkers=256, top_k=100, n_p=2000, n_v=4,
            backend=backend,
        )
        server = PixieServer(
            sg.graph, cfg, batch_size=8, n_slots=4, seed=seed
        )
        server.submit([int(qs[0])], [1.0], user_feat=0)
        server.flush()
        server.stats.latencies_ms.clear()
        server.stats.queries = 0
        for _ in range(n_requests):
            k = rng.integers(1, 4)
            pins = rng.choice(qs, size=k, replace=False)
            server.submit(pins.tolist(), [1.0] * k, user_feat=0)
        t0 = time.perf_counter()
        server.flush()
        wall = time.perf_counter() - t0
        res["rows"].append({
            "backend": backend,
            "qps": round(server.stats.qps(wall), 1),
            "p50_ms": round(server.stats.percentile(50), 1),
        })
    x, p = res["rows"][0], res["rows"][1]
    res["pallas_speedup_x"] = round(
        x["p50_ms"] / max(p["p50_ms"], 1e-9), 3
    )
    import jax

    res["pallas_interpret_mode"] = jax.default_backend() == "cpu"
    return res


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))
